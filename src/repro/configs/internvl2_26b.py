"""InternVL2-26B language backbone (InternLM2-20B); InternViT frontend is the
sanctioned stub supplying patch embeddings. [arXiv:2404.16821]"""
from repro.configs.base import ModelConfig, register

CONFIG = register(ModelConfig(
    name="internvl2-26b",
    kind="vlm",
    n_layers=48,
    d_model=6144,
    n_heads=48,
    n_kv_heads=8,
    head_dim=128,
    d_ff=16384,
    vocab_size=92553,
    frontend="patch",
    frontend_tokens=256,  # ViT patch embeddings per image (stub)
    rope_theta=1e6,
    optimizer="adafactor",
    source="arXiv:2404.16821 (assignment: 48L d6144 48H kv8, ViT stub)",
))
