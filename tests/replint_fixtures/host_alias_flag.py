"""FLAG fixture: the PR-5 paged-decode race — a live numpy block table
zero-copied into a jitted step while the host keeps mutating it.
Parsed by replint only — never imported."""
import jax
import jax.numpy as jnp
import numpy as np


class DecodeWorker:
    def __init__(self, n):
        self.block_table = np.zeros((n, 16), np.int32)
        self.seq_lens = np.zeros((n,), np.int32)
        self._step = jax.jit(lambda tbl, lens: (tbl, lens))

    def step(self, width):
        # the PR-5 bug verbatim: jnp.asarray of a live table view keeps
        # aliasing host memory on CPU; _prepare_writes mutates the table
        # while the async step still reads it
        tbl = jnp.asarray(self.block_table[:, :width])
        lens = jnp.asarray(self.seq_lens)
        return self._step(tbl, lens)                   # 2 findings

    def step_direct(self):
        return self._step(self.block_table, self.seq_lens)  # 2 findings

    def step_star(self, width):
        # *args splat must not launder taint: the tuple still holds live
        # views of the numpy table
        args = (self.block_table[:, :width], self.seq_lens)
        return self._step(*args)                       # 1 finding
