"""Cost model (Figure 2) shape properties + layer-wise schedule (§5.2)."""
import pytest
try:
    from hypothesis import given, settings, strategies as st
except ImportError:  # sandboxed env: vendored shim (seeded random)
    from _hypothesis_shim import given, settings, strategies as st

from repro.configs.base import get_config
from repro.core.costmodel import CostModel, InstanceSpec
from repro.serving.layerwise import occupation_cost, schedule

CM = CostModel(get_config("llama2-70b"), InstanceSpec())


def test_prefill_superlinear_in_length():
    """Figure 2 left: time/token grows with input length."""
    per_tok = [CM.prefill_time(L) / L for L in (4096, 16384, 65536, 262144)]
    assert all(b > a for a, b in zip(per_tok, per_tok[1:]))


def test_decode_sublinear_in_batch():
    """Figure 2 right: time/iteration grows sublinearly with batch size."""
    ts = [CM.decode_iter_time(b, 8192) for b in (1, 8, 64)]
    assert ts[1] < 8 * ts[0]
    assert ts[2] < 8 * ts[1]
    assert ts[1] >= ts[0] and ts[2] > ts[1]


@given(st.integers(1024, 100_000), st.integers(0, 50_000))
@settings(max_examples=40, deadline=None)
def test_prefix_cache_always_helps(L, prefix):
    prefix = min(prefix, L)
    assert CM.prefill_time(L, prefix) <= CM.prefill_time(L, 0) + 1e-12
    # a full (block-rounded, over-covering) hit still recomputes ≥1 token
    # for the first-token logits — positive but tiny
    assert 0 < CM.prefill_flops(L, L) <= CM.prefill_flops(L, 0) * 0.01
    assert CM.prefill_flops(L, 2 * L) == CM.prefill_flops(L, L)


@given(st.integers(1, 256), st.integers(512, 65536))
@settings(max_examples=40, deadline=None)
def test_decode_iter_positive_and_monotone_in_ctx(b, ctx):
    t1 = CM.decode_iter_time(b, ctx)
    t2 = CM.decode_iter_time(b, ctx * 2)
    assert 0 < t1 <= t2


def test_sliding_window_caps_decode_cost():
    swa = CostModel(get_config("mixtral-8x7b"), InstanceSpec())
    t_short = swa.decode_iter_time(16, 4096)
    t_long = swa.decode_iter_time(16, 500_000)
    assert t_long == pytest.approx(t_short)   # window bounds the KV read


def test_ssm_decode_cost_ctx_free():
    ssm = CostModel(get_config("mamba2-2.7b"), InstanceSpec())
    assert ssm.decode_iter_time(16, 1000) == \
        pytest.approx(ssm.decode_iter_time(16, 500_000))


def test_layerwise_schedule_bounds():
    cfg = get_config("llama2-70b")
    for L in (4096, 32768, 131072):
        tl = schedule(cfg, L)
        assert tl.total_overlapped <= tl.total_serial + 1e-9
        assert tl.t_store_layer >= 0 and tl.t_compute_layer > 0


def test_layerwise_store_hidden_at_long_context():
    """§5.2/Figure 7: compute grows quadratically, store linearly — the
    store stream hides behind compute for long inputs."""
    cfg = get_config("llama2-70b")
    assert schedule(cfg, 65536).store_hidden


def test_occupation_cost_favours_layerwise():
    cfg = get_config("llama2-70b")
    oc = occupation_cost(cfg, 32768)
    assert oc["layerwise_cost"] < oc["inline_cost"]
