"""Pure-jnp oracle for the chunked SSD scan — thin wrapper around the
model's own `ssd_chunked` (which is itself validated against a naive
per-token recurrence in tests)."""
from __future__ import annotations

import jax.numpy as jnp

from repro.models.mamba import ssd_chunked


def ssd_scan_ref(x, dt, A, B, C, *, chunk: int, h0=None):
    """x: (b, s, h, p); dt: (b, s, h); A: (h,); B, C: (b, s, n).
    Returns (y (b, s, h, p) fp32, final_state (b, h, p, n) fp32)."""
    y, state = ssd_chunked(x, dt, A, B, C, chunk, h0=h0)
    return y.astype(jnp.float32), state


def ssd_naive_ref(x, dt, A, B, C, h0=None):
    """Per-token recurrence oracle (the ground truth for both the kernel
    and `ssd_chunked`): h_t = exp(dt_t A) h_{t-1} + dt_t B_t x_t."""
    import jax
    b, s, h, p = x.shape
    n = B.shape[-1]
    f32 = jnp.float32
    if h0 is None:
        h0 = jnp.zeros((b, h, p, n), f32)

    def step(hprev, t):
        dA = jnp.exp(dt[:, t].astype(f32) * A[None, :])          # (b,h)
        dBx = jnp.einsum("bn,bhp->bhpn", B[:, t].astype(f32),
                         (x[:, t] * dt[:, t][..., None]).astype(f32))
        hnew = hprev * dA[..., None, None] + dBx
        y = jnp.einsum("bhpn,bn->bhp", hnew, C[:, t].astype(f32))
        return hnew, y

    hT, ys = jax.lax.scan(step, h0, jnp.arange(s))
    return jnp.moveaxis(ys, 0, 1), hT                            # (b,s,h,p)
