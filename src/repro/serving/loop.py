"""Always-on serving loop: continuous batching with interleaved chunked
prefill (§3's workflow as ONE iteration instead of phase-at-a-time).

``ServingLoop`` owns one ``DecodeWorker`` (and through it the shared
``DevicePagePool``) plus N ``PrefillWorker``s, and pulls requests from a
thread-fed arrival queue. Each iteration:

    arrivals → joins → one decode step → prefill chunks in the slack

* **Admission** happens at ``submit()`` against a ``BackpressureSignal``
  snapshot (queue depth, slot occupancy, in-flight prefills, pinned page
  fraction) evaluated by a registered admission policy kind — the live
  engine's counterpart of §7's early/predictive rejection. A rejected
  request never consumes compute.
* **Joins** are slot-level: a finished prefill enters the decode batch
  through ``DecodeWorker.join`` only while ``has_free_slot``; a join that
  hits device-page OOM is deferred and retried once decodes release pages.
* **Chunked prefill interleave**: prefills advance one device chunk at a
  time (``ChunkedPrefill.advance``) between decode steps. With a
  ``tbt_budget_s`` the loop fits as many chunks as the measured chunk EMA
  says fit in the slack the budget leaves after a decode step (always at
  least one whenever any decode slot would otherwise starve prefill);
  with no budget it runs a fixed ``chunks_per_iter`` — deterministic, the
  mode tests and the gated benchmark use.

Because chunk boundaries are suspension points of the SAME generator the
blocking ``PrefillWorker.__call__`` drains, every emitted token is
bit-exact with the request-at-a-time oracle regardless of how the loop
slices the work.
"""
from __future__ import annotations

import queue
import threading
import time
from dataclasses import dataclass, field
from typing import Optional

import numpy as np

from repro.core.policies.admission import BackpressureSignal
from repro.core.policies.base import get_policy
from repro.serving.engine import ChunkedPrefill, DecodeWorker, PrefillWorker


@dataclass
class _Arrival:
    req_id: int
    tokens: np.ndarray
    max_new: int
    session: Optional[object] = None
    priority: int = 0


@dataclass
class _Active:
    """A request whose prefill is mid-chunks on some worker."""
    arrival: _Arrival
    cp: ChunkedPrefill
    worker_idx: int


@dataclass
class RequestOutput:
    req_id: int
    tokens: list = field(default_factory=list)
    token_t: list = field(default_factory=list)   # monotonic emit times
    done: bool = False


class ServingLoop:
    """Continuous-batching loop over one decode worker + N prefill workers.

    ``submit()`` is thread-safe (any number of client threads feed the
    arrival queue); ``run()`` is the engine thread. ``tbt_budget_s=None``
    selects the deterministic interleave (exactly ``chunks_per_iter``
    prefill chunks between decode steps).
    """

    def __init__(self, prefill_workers: list[PrefillWorker],
                 decode_worker: DecodeWorker, *,
                 tbt_budget_s: Optional[float] = None,
                 chunks_per_iter: int = 1, max_queue: int = 64,
                 admission: str = "predictive") -> None:
        assert prefill_workers, "need at least one PrefillWorker"
        self.pws = list(prefill_workers)
        self.dw = decode_worker
        self.page_pool = decode_worker.page_pool
        self.tbt_budget_s = tbt_budget_s
        self.chunks_per_iter = max(chunks_per_iter, 1)
        self.max_queue = max_queue
        self.policy = get_policy("admission", admission)
        self._arrivals: "queue.Queue[_Arrival]" = queue.Queue()
        # guards the client-visible flags/counters that submit() threads
        # and the engine thread both touch
        self._lock = threading.Lock()
        self._intake_open = True              #: guarded_by self._lock
        self._stopping = False                #: guarded_by self._lock
        # engine-thread state
        self._active: list[_Active] = []      # prefills mid-chunks
        self._pending_join: list = []         # (arrival, PrefillResult)
        self._busy: set[int] = set()          # worker idx with a live gen
        self._rr = 0                          # chunk round-robin cursor
        self._t_step_ema: Optional[float] = None
        self.outputs: dict[int, RequestOutput] = {}
        #: guarded_by self._lock
        self.stats = dict(submitted=0, rejected=0, joined=0, completed=0,
                          decode_steps=0, prefill_chunks=0, join_oom=0,
                          iterations=0)

    # ---- client side ---------------------------------------------------
    def signal(self) -> BackpressureSignal:
        """Live occupancy snapshot the admission policy evaluates."""
        pressure = self.page_pool.pressure() if self.page_pool is not None \
            else {}
        return BackpressureSignal(
            queue_depth=self._arrivals.qsize(),
            queue_capacity=self.max_queue,
            slots_used=self.dw.n_active,
            slots_total=self.dw.max_batch,
            prefills_active=len(self._active) + len(self._pending_join),
            pages_pinned=pressure.get("pinned", 0),
            pages_total=pressure.get("capacity", 0))

    def submit(self, req_id: int, tokens: np.ndarray, max_new: int,
               session=None, priority: int = 0) -> bool:
        """Offer a request; False = shed by backpressure (nothing ran)."""
        if not self._intake_is_open():
            raise RuntimeError("serving loop intake is closed")
        self._bump("submitted")
        if self._arrivals.qsize() >= self.max_queue \
                or not self.policy.engine_admit(self.signal(), priority):
            self._bump("rejected")
            return False
        self._arrivals.put(_Arrival(req_id, np.asarray(tokens), max_new,
                                    session, priority))
        return True

    def close_intake(self) -> None:
        """No more submits; ``run()`` returns once in-flight work drains."""
        with self._lock:
            self._intake_open = False

    def stop(self) -> None:
        """Abandon queued + mid-prefill work; finish active decodes."""
        with self._lock:
            self._stopping = True
            self._intake_open = False

    def _intake_is_open(self) -> bool:
        with self._lock:
            return self._intake_open

    def _stop_requested(self) -> bool:
        with self._lock:
            return self._stopping

    def _bump(self, key: str, n: int = 1) -> None:
        with self._lock:
            self.stats[key] += n

    # ---- engine side ---------------------------------------------------
    @property
    def idle(self) -> bool:
        return (self._arrivals.empty() and not self._active
                and not self._pending_join and self.dw.n_active == 0)

    def run(self) -> dict:
        """Drive iterations until intake is closed and everything drained.
        Returns a snapshot of ``self.stats``."""
        while not (self.idle and not self._intake_is_open()):
            if self._stop_requested():
                self._drop_pending()
                if self.dw.n_active == 0:
                    break
            self._iteration()
        with self._lock:
            return dict(self.stats)

    def iterate(self) -> None:
        """One loop iteration (arrivals → joins → decode step → prefill
        chunks) — for drivers that interleave ``submit`` calls with the
        engine deterministically (tests, the gated benchmark) instead of
        feeding from a thread."""
        self._iteration()

    def _drop_pending(self) -> None:
        while True:
            try:
                self._arrivals.get_nowait()
            except queue.Empty:
                break
        for act in self._active:
            self._busy.discard(act.worker_idx)
        self._active.clear()
        for _, pres in self._pending_join:
            pres.release_pages()
        self._pending_join.clear()

    def _iteration(self) -> None:
        self._bump("iterations")
        self._drain_arrivals()
        self._try_joins()
        t_step = self._decode_step()
        self._run_chunks(t_step)

    def _drain_arrivals(self) -> None:
        while True:
            try:
                arr = self._arrivals.get_nowait()
            except queue.Empty:
                return
            self._start_prefill(arr)

    def _start_prefill(self, arr: _Arrival) -> None:
        """Route to the free worker with the deepest pool residency for
        this prompt (Conductor-style cache-aware routing, loop-local);
        every worker busy → round-robin pile-up is fine, generators are
        cheap until advanced."""
        idle = [i for i in range(len(self.pws)) if i not in self._busy]
        cand = idle if idle else list(range(len(self.pws)))
        best, best_depth = cand[0], -1
        for i in cand:
            pw = self.pws[i]
            ids = pw.hasher.hash_ids(arr.tokens, session=arr.session)
            depth = pw.pool.plan_fetch(ids).n_resident
            if depth > best_depth:
                best, best_depth = i, depth
        cp = self.pws[best].start(arr.tokens, session=arr.session)
        self._active.append(_Active(arr, cp, best))
        self._busy.add(best)
        self.outputs[arr.req_id] = RequestOutput(req_id=arr.req_id)

    def _join_headroom_ok(self, pres, max_new: int) -> bool:
        """Admitting this request must leave every active slot's worst-
        case growth obtainable — a join that eats the last free pages
        turns into a mid-decode alloc OOM a few steps later, which no
        amount of deferring can fix (pinned pages of pending joins never
        release themselves)."""
        pp = self.page_pool
        if pp is None:
            return True
        p = pp.pressure()
        pt = pp.page_tokens
        final = pres.prompt_len + max_new
        cand = max(-(-final // pt) - len(pres.pages or ()), 0) + 1
        return p["free"] + p["evictable"] >= \
            self.dw.reserved_growth_pages() + cand

    def _try_joins(self) -> None:
        still: list = []
        for arr, pres in self._pending_join:
            if not self.dw.has_free_slot:
                still.append((arr, pres))
                continue
            if self.dw.n_active > 0 and \
                    not self._join_headroom_ok(pres, arr.max_new):
                self._bump("join_oom")
                still.append((arr, pres))
                continue
            try:
                self.dw.join(arr.req_id, pres, max_new=arr.max_new)
            except MemoryError:
                # device pages exhausted by live slots: wait for decodes
                # to finish and release pages, then retry. With no active
                # decode there is nothing to wait for — fail loudly
                # instead of spinning.
                self._bump("join_oom")
                if self.dw.n_active == 0:
                    raise RuntimeError(
                        f"request {arr.req_id} cannot fit the device page "
                        f"pool even with an empty decode batch") from None
                still.append((arr, pres))
                continue
            self._bump("joined")
            out = self.outputs[arr.req_id]
            out.tokens.append(pres.first_token)
            out.token_t.append(time.monotonic())
        self._pending_join = still

    def _decode_step(self) -> float:
        """One continuous-batching decode iteration; returns its wall
        seconds (0.0 when no slot is active)."""
        if self.dw.n_active == 0:
            return 0.0
        t0 = time.monotonic()
        emitted = self.dw.step()
        dt = time.monotonic() - t0
        self._bump("decode_steps")
        self._t_step_ema = dt if self._t_step_ema is None \
            else 0.7 * self._t_step_ema + 0.3 * dt
        now = time.monotonic()
        for rid, tok, fin in emitted:
            out = self.outputs[rid]
            out.tokens.append(tok)
            out.token_t.append(now)
            if fin:
                out.done = True
                self._bump("completed")
        return dt

    def _advance_one(self) -> bool:
        """Advance the round-robin prefill one chunk; True if any ran."""
        if not self._active:
            return False
        self._rr %= len(self._active)
        act = self._active[self._rr]
        done = act.cp.advance()
        self._bump("prefill_chunks")
        if done:
            self._active.pop(self._rr)
            self._busy.discard(act.worker_idx)
            self._pending_join.append((act.arrival, act.cp.result))
        else:
            self._rr += 1
        return True

    def _run_chunks(self, t_step: float) -> None:
        """Interleave prefill chunks into the post-step slack.

        Budget mode: the TBT budget leaves ``tbt_budget_s − step_ema``
        seconds of slack per iteration; fit chunks by the workers' chunk
        EMA, guaranteeing ≥ 1 so prefill can't starve. No active decode →
        run chunks until one prefill completes (nothing to delay).
        Deterministic mode: exactly ``chunks_per_iter`` chunks."""
        if not self._active:
            return
        if self.dw.n_active == 0:
            # decode is idle: chunk until a prefill finishes so the next
            # iteration has something to join (TTFT over unused slack)
            while self._active and not self._pending_join:
                self._advance_one()
            return
        if self.tbt_budget_s is None:
            for _ in range(self.chunks_per_iter):
                if not self._advance_one():
                    return
            return
        step_ema = self._t_step_ema if self._t_step_ema is not None else t_step
        slack = self.tbt_budget_s - step_ema
        deadline = time.monotonic() + max(slack, 0.0)
        ran = 0
        while self._active:
            chunk_s = max(pw.est_chunk_s() for pw in self.pws)
            if ran > 0 and time.monotonic() + chunk_s > deadline:
                break
            self._advance_one()
            ran += 1

    # ---- reporting -----------------------------------------------------
    def tbt_stats(self) -> dict:
        """Inter-token gap percentiles over every completed request."""
        gaps: list[float] = []
        for out in self.outputs.values():
            ts = out.token_t
            gaps += [b - a for a, b in zip(ts, ts[1:])]
        if not gaps:
            return dict(n=0, p50=0.0, p99=0.0, max=0.0)
        g = np.sort(np.asarray(gaps))
        return dict(n=len(g), p50=float(np.percentile(g, 50)),
                    p99=float(np.percentile(g, 99)), max=float(g[-1]))
