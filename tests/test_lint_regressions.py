"""Regression tests for the true positives repro-lint surfaced (PR 7).

Each test here failed before its fix:

* ``stage_run`` released held pages only on ``MemoryError`` — any other
  exception out of ``write_run``/``register_block`` stranded the run;
* ``DevicePagePool`` had no lock at all — concurrent alloc/release from
  submit threads (pressure snapshots) and the engine raced the free
  list and refcounts;
* ``AsyncPrefetcher``/feeder threads were unnamed or generically named,
  so the conftest leak detector couldn't attribute survivors;
* ``ServingLoop.run()`` returned the live (still mutable) stats dict.
"""
import threading

import numpy as np
import pytest

from repro.configs.base import get_config
from repro.core.trace import BLOCK_TOKENS
from repro.serving.engine import stage_run
from repro.serving.paged_cache import DevicePagePool

CFG = get_config("smollm-360m").reduced()


def _pool(n_pages=64, page_tokens=64):
    return DevicePagePool(CFG, n_pages=n_pages, page_tokens=page_tokens)


def _kv(S):
    La, KV, Dh = CFG.attention_layers, CFG.n_kv_heads, CFG.head_dim
    k = np.zeros((La, S, KV, Dh), np.float32)
    return k, k.copy()


# --------------------------------------------------- stage_run exception path

def test_stage_run_releases_on_non_memoryerror(monkeypatch):
    """Pre-fix: only MemoryError released ``held``; a ValueError out of
    write_run leaked every page acquired so far."""
    pp = _pool(n_pages=1 + 8 * pp_blocks())
    k, v = _kv(BLOCK_TOKENS)
    orig = DevicePagePool.write_run

    def exploding(self, pages, kk, vv):
        raise ValueError("torn buffer")

    monkeypatch.setattr(DevicePagePool, "write_run", exploding)
    with pytest.raises(ValueError):
        stage_run(pp, [101], k, v, BLOCK_TOKENS)
    monkeypatch.setattr(DevicePagePool, "write_run", orig)
    assert pp.used_pages == 0          # nothing stranded
    pp.check_leaks()


def pp_blocks():
    return BLOCK_TOKENS // 64


def test_stage_run_memoryerror_still_returns_none():
    pp = _pool(n_pages=2)              # cannot fit one block (needs 8 pages)
    k, v = _kv(BLOCK_TOKENS)
    assert stage_run(pp, [7], k, v, BLOCK_TOKENS) is None
    assert pp.used_pages == 0
    pp.check_leaks()


# ------------------------------------------------ DevicePagePool thread safety

def test_page_pool_concurrent_alloc_release_consistent():
    """Pre-fix: no lock — concurrent alloc/release corrupted the free
    list (duplicates) and refcounts; check_leaks would trip."""
    pp = _pool(n_pages=257)
    errors = []

    def worker(seed):
        rng = np.random.default_rng(seed)
        held = []
        try:
            for _ in range(300):
                if held and rng.random() < 0.5:
                    pp.release(held.pop())
                else:
                    try:
                        held.append(pp.alloc(int(rng.integers(1, 4))))
                    except MemoryError:
                        pass
                if rng.random() < 0.1:
                    pp.pressure()
        except BaseException as e:     # surface races as test failure
            errors.append(e)
        finally:
            for run in held:
                pp.release(run)

    threads = [threading.Thread(target=worker, args=(s,),
                                name=f"repro-test-stress-{s}")
               for s in range(4)]
    for t in threads:
        t.start()
    for t in threads:
        t.join()
    assert not errors, errors
    assert pp.used_pages == 0
    pp.check_leaks()


def test_page_pool_pressure_snapshot_under_churn():
    """pressure() must be internally consistent even while another
    thread churns the registry (pre-fix it mixed states mid-update)."""
    pp = _pool(n_pages=1 + 8 * pp_blocks())
    k, v = _kv(BLOCK_TOKENS)
    stop = threading.Event()
    errors = []

    def churn():
        i = 0
        try:
            while not stop.is_set():
                pages = stage_run(pp, [1000 + i], k, v, BLOCK_TOKENS)
                if pages is not None:
                    pp.release(pages)
                i += 1
        except BaseException as e:
            errors.append(e)

    t = threading.Thread(target=churn, name="repro-test-churn")
    t.start()
    try:
        for _ in range(200):
            p = pp.pressure()
            assert 0 <= p["free"] <= p["capacity"]
            assert p["used"] + p["free"] == p["capacity"]
            assert 0 <= p["pinned"] <= p["used"]
    finally:
        stop.set()
        t.join()
    assert not errors, errors
    pp.check_leaks()


# --------------------------------------------------------- auditable threads

def test_prefetcher_thread_is_named(tmp_path):
    from repro.serving.ssd_store import AsyncPrefetcher, SSDBlockStore
    store = SSDBlockStore(str(tmp_path))
    pf = AsyncPrefetcher(store)
    try:
        assert pf._thread.name == "repro-kv-prefetch"
        assert not pf.closed
    finally:
        pf.close()
        store.close()
    assert pf.closed
    assert not pf._thread.is_alive()   # what the conftest detector checks


# ----------------------------------------------------- run() stats snapshot

def test_serving_loop_run_returns_snapshot():
    from repro.serving.loop import ServingLoop
    from repro.serving.engine import DecodeWorker, HostKVPool, PrefillWorker
    import jax
    params = __import__("repro.models.transformer",
                        fromlist=["init_params"]).init_params(
                            CFG, jax.random.PRNGKey(0))
    pool = HostKVPool(capacity_blocks=8)
    pp = _pool(n_pages=1 + 8 * pp_blocks())
    pw = PrefillWorker(params, CFG, pool, prefill_chunk=64, page_pool=pp)
    dw = DecodeWorker(params, CFG, max_batch=2, max_len=BLOCK_TOKENS * 2,
                      page_pool=pp)
    loop = ServingLoop([pw], dw, chunks_per_iter=2, admission="baseline")
    rng = np.random.default_rng(0)
    loop.submit(0, rng.integers(1, CFG.vocab_size, 40), max_new=4)
    loop.close_intake()
    stats = loop.run()
    assert stats["completed"] == 1
    stats["completed"] = 999           # a snapshot: caller edits are safe
    assert loop.stats()["completed"] == 1
