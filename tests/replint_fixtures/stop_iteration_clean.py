"""CLEAN fixture: PEP-479-safe generator idioms. Parsed by replint
only — never imported."""

_DONE = object()


def chunks(tokens, size):
    for i in range(0, len(tokens), size):
        yield tokens[i:i + size]


def join_stream(gen):
    result = gen.send(None)
    if result is None:
        return None          # a sentinel, not an exception
    return result


def interleave(a, b):
    it = iter(b)
    for x in a:
        yield x
        nxt = next(it, _DONE)
        if nxt is _DONE:
            return           # the PEP 479 way to end a generator
        yield nxt


def first(items):
    # default-less next OUTSIDE a generator body is ordinary control
    # flow: StopIteration propagates to the caller unmangled
    return next(iter(items))
