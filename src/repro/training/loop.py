"""Training loop: data pipeline → sharded train_step → checkpointing.

Used by examples/train_e2e.py (a ~100M-class model for a few hundred
steps on CPU) and, unchanged, by launch/train.py against the production
mesh — the step function is the same one the dry-run lowers.
"""
from __future__ import annotations

import time
from dataclasses import dataclass, field
from typing import Optional

import jax
import numpy as np

from repro.configs.base import ModelConfig
from repro.data.pipeline import SyntheticLM, batch_spec_for
from repro.models.layers import Dist, NO_DIST
from repro.models.transformer import init_params
from repro.training.checkpoint import load_checkpoint, save_checkpoint
from repro.training.optim import make_optimizer


@dataclass
class TrainResult:
    losses: list = field(default_factory=list)
    steps: int = 0
    tokens: int = 0
    wall_s: float = 0.0

    @property
    def tokens_per_s(self) -> float:
        return self.tokens / self.wall_s if self.wall_s else 0.0


def train(cfg: ModelConfig, *, steps: int, batch: int, seq: int,
          dist: Dist = NO_DIST, seed: int = 0,
          checkpoint_dir: Optional[str] = None, checkpoint_every: int = 0,
          log_every: int = 10, resume: bool = False) -> TrainResult:
    # local import: launch.steps imports training.optim (cycle otherwise)
    from repro.launch.steps import make_train_step

    opt_init, _ = make_optimizer(cfg.optimizer)
    params = init_params(cfg, jax.random.PRNGKey(seed))
    opt_state = opt_init(params)
    start_step = 0
    if resume and checkpoint_dir:
        loaded = load_checkpoint(checkpoint_dir, params, opt_state)
        if loaded is not None:
            params, opt_state, start_step = loaded

    step_fn = jax.jit(make_train_step(cfg, dist))
    pipe = SyntheticLM(batch_spec_for(cfg, batch, seq), seed=seed)

    res = TrainResult()
    t0 = time.time()
    for step in range(start_step, start_step + steps):
        np_batch = pipe.batch(step)
        jbatch = {k: jax.numpy.asarray(v) for k, v in np_batch.items()}
        loss, params, opt_state = step_fn(params, opt_state, jbatch)
        loss = float(loss)
        assert np.isfinite(loss), f"loss diverged at step {step}: {loss}"
        res.losses.append(loss)
        res.steps += 1
        res.tokens += batch * seq
        if log_every and (step % log_every == 0):
            dt = time.time() - t0
            print(f"step {step:5d}  loss {loss:7.4f}  "
                  f"{res.tokens / max(dt, 1e-9):9.0f} tok/s")
        if checkpoint_dir and checkpoint_every and \
                (step + 1) % checkpoint_every == 0:
            save_checkpoint(checkpoint_dir, params, opt_state, step + 1)
    res.wall_s = time.time() - t0
    return res
