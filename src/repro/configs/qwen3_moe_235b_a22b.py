"""Qwen3-MoE 235B-A22B. [hf:Qwen/Qwen3-30B-A3B scaled per assignment]"""
from repro.configs.base import ModelConfig, MoEConfig, register

CONFIG = register(ModelConfig(
    name="qwen3-moe-235b-a22b",
    kind="moe",
    n_layers=94,
    d_model=4096,
    n_heads=64,
    n_kv_heads=4,
    head_dim=128,
    d_ff=1536,  # assignment lists the MoE expert FF width here
    vocab_size=151936,
    moe=MoEConfig(n_experts=128, top_k=8, d_ff_expert=1536, parallelism="ep"),
    qk_norm=True,
    rope_theta=1e6,
    optimizer="adafactor",
    source="hf:Qwen/Qwen3-30B-A3B (assignment: 94L d4096 64H kv4 128e top-8)",
))
