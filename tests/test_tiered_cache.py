"""Tiered DRAM+SSD KVCache store + compute-vs-load scheduling tests.

Covers the PR's tentpole invariants: demotion-on-eviction,
promotion-on-hit, cross-tier pinning, a block resident in at most one
tier, per-tier capacity bounds, write-back batching, and the Conductor
choosing load-from-SSD over recompute exactly when the cost model says
it is cheaper — plus a small simulator scenario showing the SSD tier
never hurts goodput at equal DRAM budget.
"""
import pytest

try:
    from hypothesis import given, settings, strategies as st
except ImportError:  # sandboxed env: vendored shim (seeded random)
    from _hypothesis_shim import given, settings, strategies as st

from repro.configs.base import CacheTierSpec, get_config
from repro.core.conductor import Conductor, DecodeInstance, PrefillInstance
from repro.core.costmodel import CostModel, Hardware, InstanceSpec
from repro.core.messenger import Messenger
from repro.core.simulator import MooncakeCluster
from repro.core.tiered import TieredCachePool
from repro.core.trace import BLOCK_TOKENS, Request


# ------------------------------------------------------------ unit: tiers --

def test_demotion_on_eviction():
    pool = TieredCachePool(2, 4, policy="lru", ssd_policy="lru")
    pool.insert([1, 2])
    dropped = pool.insert([3])            # LRU victim 1 demotes, not drops
    assert dropped == []
    assert pool.resident_tier(1) == "ssd"
    assert pool.resident_tier(2) == "dram" and pool.resident_tier(3) == "dram"
    assert pool.demotions == 1 and pool.evictions == 1


def test_no_ssd_tier_behaves_flat():
    pool = TieredCachePool(2, 0, policy="lru")
    pool.insert([1, 2])
    dropped = pool.insert([3])
    assert dropped == [1]                 # destroyed, like the seed pool
    assert 1 not in pool


def test_promotion_on_hit():
    pool = TieredCachePool(2, 4)
    pool.insert([1, 2])
    pool.insert([3])                      # 1 → SSD
    assert pool.resident_tier(1) == "ssd"
    n = pool.lookup([1])
    assert n == 1
    assert pool.resident_tier(1) == "dram"
    assert pool.promotions == 1 and pool.ssd_hits == 1 and pool.dram_hits == 0
    assert 1 not in pool.ssd.blocks       # at most one tier


def test_lookup_prefix_spans_tiers():
    pool = TieredCachePool(2, 8)
    pool.insert([1, 2, 3, 4])             # 1,2 demoted; 3,4 in DRAM
    tp = pool.tier_prefix([1, 2, 3, 4, 5])
    assert (tp.total, tp.dram, tp.ssd) == (4, 2, 2)
    assert pool.prefix_len([1, 2, 3, 4]) == 0   # DRAM-only view unchanged
    assert pool.lookup([1, 2]) == 2             # union view, promotes
    assert pool.hits == 2 and pool.ssd_hits == 2
    assert pool.resident_tier(1) == "dram" and pool.resident_tier(2) == "dram"
    assert pool.resident_tier(3) == "ssd" and pool.resident_tier(4) == "ssd"


def test_lookup_promotion_cascade_keeps_invariants():
    """Promoting a prefix longer than DRAM can hold churns blocks through
    the tiers but never duplicates or loses resident blocks."""
    pool = TieredCachePool(2, 8)
    pool.insert([1, 2, 3, 4])
    assert pool.lookup([1, 2, 3, 4]) == 4       # cascade of promote/demote
    assert not set(pool.blocks) & set(pool.ssd.blocks)
    assert set(pool.blocks) | set(pool.ssd.blocks) == {1, 2, 3, 4}
    assert len(pool.blocks) <= 2


def test_cross_tier_pinning():
    pool = TieredCachePool(1, 1)
    pool.insert([1])
    pool.insert([2])                      # 1 → SSD
    pool.pin([1, 2])                      # pin across BOTH tiers
    assert pool.ssd.blocks[1].pinned == 1 and pool.blocks[2].pinned == 1
    dropped = pool.insert([3])            # DRAM pinned → direct-to-SSD full
    assert dropped == [] and 3 not in pool
    assert pool.resident_tier(1) == "ssd" and pool.resident_tier(2) == "dram"
    pool.unpin([1])
    pool.insert([3])                      # now 2 still pinned; 3 → SSD slot
    assert pool.resident_tier(2) == "dram"
    assert pool.resident_tier(3) == "ssd" and 1 not in pool


def test_promotion_carries_pin_count():
    pool = TieredCachePool(2, 4)
    pool.insert([1, 2])
    pool.insert([3])                      # 1 → SSD
    pool.pin([1])
    pool.lookup([1])                      # promote back to DRAM
    assert pool.resident_tier(1) == "dram" and pool.blocks[1].pinned == 1


def test_writeback_batching():
    pool = TieredCachePool(1, 16, writeback_batch=4)
    for k in range(1, 7):                 # 5 demotions (blocks 1..5)
        pool.insert([k])
    assert pool.demotions == 5
    assert pool.n_writebacks == 1         # one full batch of 4, 1 pending
    assert pool.flush_writeback() == 1
    assert pool.n_writebacks == 2
    assert pool.flush_writeback() == 0    # idempotent when drained
    assert pool.n_writebacks == 2


def test_ssd_eviction_drops_for_good():
    pool = TieredCachePool(1, 2, policy="lru", ssd_policy="lru")
    dropped = []
    for k in [1, 2, 3, 4]:
        dropped += pool.insert([k])
    # DRAM holds 4; SSD holds 2 of {1,2,3}; the oldest demotion fell off
    assert pool.resident_tier(4) == "dram"
    assert len(pool.ssd.blocks) == 2
    assert dropped == [1]
    assert pool.ssd.evictions == 1


# ------------------------------------------------------ property: invariants

@given(st.lists(st.lists(st.integers(0, 40), min_size=1, max_size=8),
                min_size=1, max_size=40),
       st.integers(1, 4), st.integers(1, 8),
       st.sampled_from(["lru", "lfu", "length_aware"]))
@settings(max_examples=50, deadline=None)
def test_capacity_and_single_residency_invariants(chains, dram_cap, ssd_cap,
                                                  policy):
    pool = TieredCachePool(dram_cap, ssd_cap, policy=policy,
                           ssd_policy=policy)
    for i, chain in enumerate(chains):
        if i % 3 == 2:
            pool.pin(chain[:1])
        n = pool.lookup(chain)
        pool.insert(chain[n:], start_pos=n)
        if i % 3 == 2:
            pool.unpin(chain[:1])
        assert len(pool.blocks) <= dram_cap
        assert len(pool.ssd.blocks) <= ssd_cap
        # a block is resident in at most one tier
        assert not set(pool.blocks) & set(pool.ssd.blocks)


@given(st.lists(st.integers(0, 30), min_size=1, max_size=20),
       st.integers(1, 3), st.integers(1, 20))
@settings(max_examples=40, deadline=None)
def test_tiered_insert_idempotent(chain, dram_cap, ssd_cap):
    pool = TieredCachePool(dram_cap, ssd_cap)
    pool.insert(chain)
    resident = set(pool.blocks) | set(pool.ssd.blocks)
    pool.insert(chain)
    # re-inserting resident blocks never drops anything already resident
    assert resident <= (set(pool.blocks) | set(pool.ssd.blocks))


@given(st.lists(st.lists(st.integers(0, 30), min_size=1, max_size=6),
                min_size=1, max_size=30), st.integers(1, 6))
@settings(max_examples=40, deadline=None)
def test_tiered_hit_rate_dominates_flat(chains, dram_cap):
    """At equal DRAM budget the tiered pool's hit rate is ≥ the flat
    pool's on any replay (SSD only ADDS residency)."""
    from repro.core.cache import CachePool
    flat = CachePool(dram_cap, "lru")
    tier = TieredCachePool(dram_cap, None, policy="lru")   # unbounded SSD
    for chain in chains:
        n = flat.lookup(chain)
        flat.insert(chain[n:], start_pos=n)
        m = tier.lookup(chain)
        tier.insert(chain[m:], start_pos=m)
    assert tier.hits >= flat.hits


# ------------------------------------------- conductor: compute vs load ----

def _one_node_conductor(hw: Hardware, dram_cap=2, ssd_cap=64):
    cfg = get_config("llama2-70b")
    inst_spec = InstanceSpec(hw=hw)
    pool = TieredCachePool(dram_cap, ssd_cap)
    P = [PrefillInstance(iid=0, pool=pool,
                         cost=CostModel(cfg, inst_spec))]
    D = [DecodeInstance(iid=100, cost=CostModel(cfg, inst_spec))]
    msg = Messenger([0, 100], bw=hw.net_bw)
    msg.add_ssd_channel(0, hw.ssd_read_bw)
    cond = Conductor(P, D, msg, ttft_slo=1e9, tbt_slo=1e9)
    return cond, P[0]


@pytest.mark.parametrize("ssd_read_bw,expect_load", [
    (100e9, True),     # RAID-class SSD: loading beats recomputing
    (0.01e9, False),   # pathologically slow SSD: recompute wins
])
def test_conductor_compute_vs_load_follows_cost_model(ssd_read_bw,
                                                      expect_load):
    hw = Hardware(ssd_read_bw=ssd_read_bw)
    cond, inst = _one_node_conductor(hw)
    chain = list(range(10))
    inst.pool.insert(chain)               # DRAM cap 2 → blocks 0..7 in SSD
    tp = inst.pool.tier_prefix(chain)
    assert tp.ssd == 8 and tp.total == 10
    L = 10 * BLOCK_TOKENS
    req = Request(req_id=0, timestamp=0, input_length=L, output_length=32,
                  hash_ids=chain)

    # the two arms, straight from the cost model (queue is empty)
    cost = inst.cost
    t_recompute = cost.prefill_time(L, inst.pool.prefix_len(chain)
                                    * BLOCK_TOKENS)
    t_load = cost.ssd_load_time(tp.ssd * BLOCK_TOKENS) \
        + cost.prefill_time(L, tp.total * BLOCK_TOKENS)
    assert (t_load < t_recompute) == expect_load

    dec = cond.schedule(req, now=0.0)
    assert dec.accepted
    if expect_load:
        assert dec.ssd_blocks == tp.ssd
        assert dec.prefix_blocks == tp.total
        assert dec.ssd_load_time > 0
        assert dec.expected_ttft == pytest.approx(t_load)
        # the committed load promoted the prefix into DRAM-visible state
        assert cond.n_ssd_loads == 1
    else:
        assert dec.ssd_blocks == 0
        assert dec.ssd_load_time == 0
        assert dec.expected_ttft == pytest.approx(t_recompute)
        assert cond.n_ssd_loads == 0


def test_conductor_ssd_channel_congestion_feeds_estimate():
    """Two back-to-back SSD loads: the second sees the first's backlog."""
    hw = Hardware(ssd_read_bw=100e9)
    cond, inst = _one_node_conductor(hw, dram_cap=2, ssd_cap=64)
    chain = list(range(10))
    inst.pool.insert(chain)
    L = 10 * BLOCK_TOKENS
    req = Request(req_id=0, timestamp=0, input_length=L, output_length=32,
                  hash_ids=chain)
    d1 = cond.schedule(req, now=0.0)
    assert d1.ssd_blocks > 0
    assert cond.messenger.congestion is not None
    assert cond.messenger.ssd_links[0].n_transfers == 1
    assert cond.messenger.ssd_links[0].busy_until > 0


def test_flat_pool_never_produces_ssd_decisions():
    cfg = get_config("llama2-70b")
    from repro.core.cache import CachePool
    P = [PrefillInstance(iid=0, pool=CachePool(1000),
                         cost=CostModel(cfg, InstanceSpec()))]
    D = [DecodeInstance(iid=100, cost=CostModel(cfg, InstanceSpec()))]
    msg = Messenger([0, 100], bw=100e9)
    cond = Conductor(P, D, msg, ttft_slo=1e9, tbt_slo=1e9)
    req = Request(req_id=0, timestamp=0, input_length=4096, output_length=16,
                  hash_ids=list(range(8)))
    dec = cond.schedule(req, now=0.0)
    assert dec.accepted and dec.ssd_blocks == 0 and dec.ssd_load_time == 0


# ------------------------------------------------------- simulator scenario

@pytest.fixture(scope="module")
def long_context_trace():
    """Long-context sessions whose reuse distance exceeds the DRAM budget:
    14 sessions × 32 blocks = 448 unique blocks vs 200 DRAM blocks, each
    session re-requested after all others ran — the paper's cold-prefix
    workload where a flat pool has destroyed everything by the revisit."""
    reqs, rid = [], 0
    for phase in range(2):
        for s in range(14):
            chain = [s * 1000 + j for j in range(32)]
            reqs.append(Request(
                req_id=rid, timestamp=(phase * 14 + s) * 600,
                input_length=32 * BLOCK_TOKENS, output_length=96,
                hash_ids=chain))
            rid += 1
    return reqs


def test_simulator_ssd_tier_goodput_no_worse(long_context_trace):
    cfg = get_config("llama2-70b")
    kw = dict(n_prefill=2, n_decode=2, ttft_slo=30.0, tbt_slo=0.2)
    flat = MooncakeCluster(cfg, cache_capacity_blocks=200, **kw)
    r_flat = flat.run(long_context_trace)
    tier = MooncakeCluster(
        cfg, cache_spec=CacheTierSpec(dram_blocks=200, ssd_blocks=4000),
        **kw)
    r_tier = tier.run(long_context_trace)
    assert r_tier.n_ssd_loads > 0          # the third arm actually fires
    assert r_tier.goodput(30.0, 0.2) >= r_flat.goodput(30.0, 0.2)
    # loading beats recomputing here, so TTFT strictly improves
    assert r_tier.avg_ttft() < r_flat.avg_ttft()
    # SSD latency is real simulated time: loads show up on records
    loaded = [r for r in r_tier.records if r.ssd_blocks]
    assert loaded and all(r.ssd_load_time > 0 for r in loaded)


def test_simulator_ssd_hit_rate_beats_flat(long_context_trace):
    cfg = get_config("llama2-70b")
    kw = dict(n_prefill=2, n_decode=2)
    flat = MooncakeCluster(cfg, cache_capacity_blocks=200, **kw)
    flat.run(long_context_trace)
    tier = MooncakeCluster(
        cfg, cache_spec=CacheTierSpec(dram_blocks=200, ssd_blocks=4000),
        **kw)
    tier.run(long_context_trace)
    hits = lambda cl: sum(p.pool.hits for p in cl.prefills)
    assert hits(tier) > hits(flat)
