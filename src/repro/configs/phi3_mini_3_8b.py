"""Phi-3-mini 3.8B (RoPE, SwiGLU, MHA). [arXiv:2404.14219]"""
from repro.configs.base import ModelConfig, register

CONFIG = register(ModelConfig(
    name="phi3-mini-3.8b",
    kind="dense",
    n_layers=32,
    d_model=3072,
    n_heads=32,
    n_kv_heads=32,
    head_dim=96,
    d_ff=8192,
    vocab_size=32064,
    rope_theta=1e4,
    source="arXiv:2404.14219 (assignment: 32L d3072 32H kv32)",
))
