"""Transformer building blocks (pure JAX, bf16 compute / fp32 reductions).

Sharding philosophy: parameters are annotated by ``launch/shardings.py``
(FSDP over 'data', tensor-parallel over 'model'); inside the forward we only
place activation constraints at block boundaries and run the MoE hot-path
under ``shard_map`` (expert-parallel all_to_all or tensor-parallel experts),
because XLA's SPMD partitioner handles scatter-based token dispatch poorly.

GQA with head counts not divisible by the model axis (smollm 15H/5KV,
whisper 20H, qwen3-14b 40H): query heads are padded to a multiple of 16 and
K/V are expanded per padded query head with a static gather
(``qh2kv`` map). The gather adds HBM traffic but no FLOPs — the grouped
einsum for divisible archs is a recorded §Perf optimization.
"""
from __future__ import annotations

import dataclasses
from dataclasses import dataclass
from functools import partial
from typing import Any, Optional

import jax
import jax.numpy as jnp
from jax.sharding import Mesh, PartitionSpec as P

from repro.configs.base import ModelConfig, MODEL_AXIS

DTYPE = jnp.bfloat16

# §Perf iteration 2 (EXPERIMENTS.md): grouped GQA attention — contract
# grouped queries against the raw (KV, Dh) cache instead of materialising
# a per-query-head expanded copy (jnp.take over heads). Requires
# padded_heads % n_kv_heads == 0; others keep the expansion path.
GROUPED_ATTN = __import__("os").environ.get("REPRO_GROUPED_ATTN", "1") == "1"
# §Perf iteration 3: Megatron-style sequence-sharded residual stream —
# block-boundary activations sharded over 'model' on the sequence dim so
# TP all-reduces become all-gather + reduce-scatter pairs (half traffic)
# and norms/residuals compute on S/16 shards.
SEQ_SHARDED_RESIDUAL = __import__("os").environ.get(
    "REPRO_SEQ_SHARDED", "1") == "1"

# Pallas hot path: route prefill-attention chunks through the
# flash_prefill kernel (kernels/flash_prefill). Default off on this CPU
# rig (interpret mode is for validation, not speed); on TPU flip it on.
USE_PALLAS_ATTN = __import__("os").environ.get(
    "REPRO_USE_PALLAS", "0") == "1"

# Token count at/below which MoE uses the global (pjit-propagated) dispatch;
# above it, the shard_map expert-parallel path (decode steps are tiny,
# train/prefill are huge).
MOE_GLOBAL_DISPATCH_MAX_TOKENS = 4096
# Query-chunk length for the scanned (flash-style) attention path.
ATTN_CHUNK_Q = 1024
# MoE dispatch group length inside shard_map (bounds the dispatch buffer).
MOE_GROUP_TOKENS = 8192


@dataclass(frozen=True)
class Dist:
    """Distribution context threaded through the model forward."""
    mesh: Optional[Mesh] = None
    batch_axes: Any = ("pod", "data")  # mesh axes carrying the batch dim
    model_axis: str = "model"

    @property
    def active(self) -> bool:
        return self.mesh is not None

    def axis_size(self, name) -> int:
        if not self.active:
            return 1
        if isinstance(name, tuple):
            import math
            return math.prod(self.mesh.shape[a] for a in name if a in self.mesh.shape)
        return self.mesh.shape.get(name, 1)

    def batch_spec(self, *rest) -> P:
        ax = tuple(a for a in self.batch_axes if self.axis_size(a) > 1) or None
        if isinstance(ax, tuple) and len(ax) == 1:
            ax = ax[0]
        return P(ax, *rest)

    def residual_spec(self, seq_len: int) -> P:
        """Block-boundary residual sharding: (batch, seq, d_model).
        §Perf iter 3 (SEQ_SHARDED_RESIDUAL): shard the sequence over
        'model' so TP all-reduces lower to all-gather + reduce-scatter
        (half the traffic) and norms/residuals compute on S/TP shards."""
        if SEQ_SHARDED_RESIDUAL and seq_len > 1 \
                and seq_len % max(self.axis_size(self.model_axis), 1) == 0:
            return self.batch_spec(self.model_axis, None)
        return self.batch_spec(None, None)

    def constrain(self, x, spec: P):
        if self.active:
            return jax.lax.with_sharding_constraint(
                x, jax.sharding.NamedSharding(self.mesh, spec))
        return x


NO_DIST = Dist()


# ---------------------------------------------------------------------------
# primitives
# ---------------------------------------------------------------------------

def rms_norm(x: jax.Array, w: jax.Array, eps: float = 1e-6) -> jax.Array:
    xf = x.astype(jnp.float32)
    var = jnp.mean(xf * xf, axis=-1, keepdims=True)
    return (xf * jax.lax.rsqrt(var + eps)).astype(x.dtype) * w


def rope_freqs(head_dim: int, theta: float) -> jax.Array:
    return 1.0 / (theta ** (jnp.arange(0, head_dim, 2, dtype=jnp.float32)
                            / head_dim))


def apply_rope(x: jax.Array, positions: jax.Array, theta: float) -> jax.Array:
    """x: (B, S, H, D); positions: (B, S) or (S,)."""
    d = x.shape[-1]
    freqs = rope_freqs(d, theta)  # (d/2,)
    if positions.ndim == 1:
        positions = positions[None, :]
    angles = positions[..., None].astype(jnp.float32) * freqs  # (B,S,d/2)
    sin = jnp.sin(angles)[:, :, None, :]
    cos = jnp.cos(angles)[:, :, None, :]
    x1, x2 = jnp.split(x.astype(jnp.float32), 2, axis=-1)
    out = jnp.concatenate([x1 * cos - x2 * sin, x1 * sin + x2 * cos], axis=-1)
    return out.astype(x.dtype)


def qh2kv_map(n_q: int, n_kv: int, padded_q: int) -> jnp.ndarray:
    """Static map padded-query-head -> kv head (llama grouping; padded extra
    heads reuse kv head 0 — their output projection rows are zero-init)."""
    group = max(n_q // max(n_kv, 1), 1)
    idx = [min(h // group, n_kv - 1) if h < n_q else 0 for h in range(padded_q)]
    return jnp.asarray(idx, dtype=jnp.int32)


# ---------------------------------------------------------------------------
# attention
# ---------------------------------------------------------------------------

def _attend(q, k, v, mask, scale):
    """q:(B,Sq,H,D); k,v:(B,Sk,H,D) *or* (B,Sk,KV,D) with H = KV·g
    (grouped GQA — §Perf iteration 2: contract grouped queries against the
    raw KV instead of materialising an H-wide expanded copy).
    mask:(B?,1,Sq,Sk) bool -> (B,Sq,H,D)."""
    B, Sq, H, D = q.shape
    KV = k.shape[2]
    if KV != H:
        g = H // KV
        qg = q.reshape(B, Sq, KV, g, D)
        logits = jnp.einsum("bqkgd,bskd->bkgqs", qg, k,
                            preferred_element_type=jnp.float32) * scale
        logits = jnp.where(mask[:, :, None], logits, -1e30)
        probs = jax.nn.softmax(logits, axis=-1)
        o = jnp.einsum("bkgqs,bskd->bqkgd", probs.astype(v.dtype), v)
        return o.reshape(B, Sq, H, D)
    logits = jnp.einsum("bqhd,bkhd->bhqk", q, k,
                        preferred_element_type=jnp.float32) * scale
    logits = jnp.where(mask, logits, -1e30)
    probs = jax.nn.softmax(logits, axis=-1)
    return jnp.einsum("bhqk,bkhd->bqhd", probs.astype(v.dtype), v)


def causal_attention(q, k, v, q_offset, window: int = 0,
                     chunk_q: int = ATTN_CHUNK_Q):
    """Causal (optionally sliding-window) attention over a full K/V.

    q: (B, Sq, H, D) at absolute positions q_offset + [0, Sq)
    k, v: (B, Sk, H, D) at absolute positions [0, Sk)   (Sk >= q_offset+Sq)
    Scanned over query chunks so the (Sq, Sk) logits never materialize whole.
    """
    B, Sq, H, D = q.shape
    Sk = k.shape[1]
    scale = 1.0 / (D ** 0.5)
    kpos = jnp.arange(Sk)

    def mask_for(qpos):  # qpos (C,) absolute
        m = qpos[:, None] >= kpos[None, :]
        if window:
            m &= (qpos[:, None] - kpos[None, :]) < window
        return m[None, None]  # (1,1,C,Sk)

    if USE_PALLAS_ATTN and Sq % 16 == 0 and Sk % 16 == 0 \
            and isinstance(q_offset, int) and D in (32, 64, 128, 256):
        from repro.kernels.flash_prefill.ops import flash_prefill_attention
        return flash_prefill_attention(q, k, v, q_offset=q_offset,
                                       window=window, use_pallas=True)

    if Sq <= chunk_q:
        qpos = q_offset + jnp.arange(Sq)
        return _attend(q, k, v, mask_for(qpos), scale)

    n_chunks = Sq // chunk_q
    rem = Sq - n_chunks * chunk_q
    qs = q[:, :n_chunks * chunk_q].reshape(B, n_chunks, chunk_q, H, D)
    qs = jnp.moveaxis(qs, 1, 0)  # (n_chunks, B, C, H, D)

    def body(_, qc_i):
        qc, i = qc_i
        qpos = q_offset + i * chunk_q + jnp.arange(chunk_q)
        return None, _attend(qc, k, v, mask_for(qpos), scale)

    _, out = jax.lax.scan(body, None, (qs, jnp.arange(n_chunks)))
    out = jnp.moveaxis(out, 0, 1).reshape(B, n_chunks * chunk_q, H, D)
    if rem:
        qpos = q_offset + n_chunks * chunk_q + jnp.arange(rem)
        tail = _attend(q[:, -rem:], k, v, mask_for(qpos), scale)
        out = jnp.concatenate([out, tail], axis=1)
    return out


def decode_attention(q, k_cache, v_cache, cache_len, window: int = 0):
    """One-token attention against a (possibly ring-buffer) cache.

    q: (B, 1, H, D); k_cache/v_cache: (B, S_cache, H, D) with RoPE already
    applied at write time. ``cache_len`` counts tokens written *including*
    the current one — scalar or (B,) for continuous batching. For sliding
    windows the cache IS the window (ring buffer), so every slot
    < min(cache_len, S_cache) is valid.
    """
    B, S, KVH, D = k_cache.shape      # KVH = H (expanded) or KV (grouped)
    scale = 1.0 / (D ** 0.5)
    clen = jnp.asarray(cache_len)
    if clen.ndim == 1:
        clen = clen[:, None]          # (B, 1)
    valid = jnp.arange(S)[None, :] < jnp.minimum(clen, S)  # (1|B, S)
    if window and S > window:
        # linear (non-ring) cache of a windowed arch: mask slots older
        # than the window (ring callers size the cache AT the window).
        valid &= jnp.arange(S)[None, :] >= clen - window
    mask = valid[:, None, None, :]  # (B|1, 1, 1, S)
    return _attend(q, k_cache, v_cache, mask, scale)


def attention_block(x, p, cfg: ModelConfig, dist: Dist, *,
                    q_offset=0, cache=None, cache_len=None, ring: bool = False,
                    kv_out: bool = False, enc_kv=None, causal: bool = True,
                    window_override: Optional[int] = None):
    """Full attention sub-block: norm -> qkv -> rope -> attend -> out proj.

    Returns (y, new_cache_or_kv):
      * train/prefill (cache is None): new KV (k, v) if kv_out else None
      * decode (cache = (k_cache, v_cache)): updated cache
      * cross-attention (enc_kv given): attends encoder K/V, no cache.
    """
    B, S, _ = x.shape
    Hp, KV, Dh = cfg.padded_heads, cfg.n_kv_heads, cfg.head_dim
    window = cfg.sliding_window if window_override is None else window_override
    # grouped GQA (§Perf iter 2): skip the per-query-head KV expansion.
    # Only when heads are unpadded does the contiguous (KV, g) reshape
    # agree with the qh2kv mapping (padded archs — smollm/whisper/
    # qwen3-14b — keep the gather; group-contiguous head reordering for
    # padded archs is a recorded future iteration).
    grouped = GROUPED_ATTN and Hp == cfg.n_heads and Hp % KV == 0

    def expand(t):
        return t if grouped else jnp.take(t, qh2kv, axis=2)

    h = rms_norm(x, p["ln"], cfg.norm_eps)
    q = (h @ p["wq"]).reshape(B, S, Hp, Dh)
    if cfg.attn_bias:
        q = q + p["bq"].reshape(1, 1, Hp, Dh)
    if cfg.qk_norm:
        q = rms_norm(q, p["q_norm"], cfg.norm_eps)

    qh2kv = qh2kv_map(cfg.n_heads, KV, Hp)

    if enc_kv is not None:  # cross-attention: K/V precomputed from encoder
        k_full, v_full = enc_kv  # (B, S_enc, KV, Dh), rope-free
        k_exp = expand(k_full)
        v_exp = expand(v_full)
        Sk = k_exp.shape[1]
        mask = jnp.ones((1, 1, S, Sk), dtype=bool)
        o = _attend(q, k_exp, v_exp, mask, 1.0 / (Dh ** 0.5))
        y = o.reshape(B, S, Hp * Dh) @ p["wo"]
        return y, None

    k = (h @ p["wk"]).reshape(B, S, KV, Dh)
    v = (h @ p["wv"]).reshape(B, S, KV, Dh)
    if cfg.attn_bias:
        k = k + p["bk"].reshape(1, 1, KV, Dh)
        v = v + p["bv"].reshape(1, 1, KV, Dh)
    if cfg.qk_norm:
        k = rms_norm(k, p["k_norm"], cfg.norm_eps)

    if cache is None:
        positions = q_offset + jnp.arange(S)
        q = apply_rope(q, positions, cfg.rope_theta)
        k = apply_rope(k, positions, cfg.rope_theta)
        # §Perf iteration 5 (REFUTED, reverted — see EXPERIMENTS.md): the
        # partitioner all-gathers the H-headed Q here instead of the
        # KV-headed K/V (H/KV× more traffic than necessary). Explicitly
        # constraining K/V replicated (with or without pinning Q to the
        # sequence shards) back-propagated replication through the whole
        # layer: 11× redundant FLOPs/bytes. GSPMD's Q-gather stands.
        k_exp = expand(k)
        v_exp = expand(v)
        if causal:
            o = causal_attention(q, k_exp, v_exp, q_offset, window)
        else:
            Sk = k_exp.shape[1]
            mask = jnp.ones((1, 1, S, Sk), dtype=bool)
            o = _attend(q, k_exp, v_exp, mask, 1.0 / (Dh ** 0.5))
        y = o.reshape(B, S, Hp * Dh) @ p["wo"]
        return y, ((k, v) if kv_out else None)

    # ---- decode/extend: write S new tokens at absolute position cache_len --
    # ``cache_len`` is scalar (uniform batch: serve_step / chunked prefill)
    # or (B,) (continuous batching: every slot at a different depth).
    k_cache, v_cache = cache  # (B, S_cache, KV, Dh)
    S_cache = k_cache.shape[1]
    pos = jnp.asarray(cache_len)  # absolute position of the first new token
    per_seq = pos.ndim == 1
    positions = (pos[:, None] if per_seq else pos) \
        + jnp.arange(S, dtype=jnp.int32)[None, :]
    q = apply_rope(q, jnp.broadcast_to(positions, (B, S)), cfg.rope_theta)
    k = apply_rope(k, jnp.broadcast_to(positions, (B, S)), cfg.rope_theta)

    def upd(cache_b, new_b, at):
        return jax.lax.dynamic_update_slice_in_dim(cache_b, new_b, at, axis=0)

    if ring:
        # sliding-window ring buffer: the cache IS the window (S == 1 path,
        # used by serve_step for long-context decode of windowed archs).
        slot = pos % S_cache
        if per_seq:
            k_cache = jax.vmap(upd)(k_cache, k, slot)
            v_cache = jax.vmap(upd)(v_cache, v, slot)
        else:
            k_cache = jax.lax.dynamic_update_slice_in_dim(k_cache, k, slot, axis=1)
            v_cache = jax.lax.dynamic_update_slice_in_dim(v_cache, v, slot, axis=1)
        o = decode_attention(q, expand(k_cache), expand(v_cache),
                             pos + 1, window)
    else:
        if per_seq:
            k_cache = jax.vmap(upd)(k_cache, k, pos)
            v_cache = jax.vmap(upd)(v_cache, v, pos)
        else:
            k_cache = jax.lax.dynamic_update_slice_in_dim(k_cache, k, pos, axis=1)
            v_cache = jax.lax.dynamic_update_slice_in_dim(v_cache, v, pos, axis=1)
        if per_seq:
            o = decode_attention(q, expand(k_cache), expand(v_cache),
                                 pos + S, window)
        else:
            o = causal_attention(q, expand(k_cache), expand(v_cache),
                                 pos, window)
    y = o.reshape(B, S, Hp * Dh) @ p["wo"]
    return y, (k_cache, v_cache)


@dataclass(frozen=True)
class PagedShard:
    """shard_map context for the sharded paged decode step: the mesh axis
    that stripes KV heads and its size. ``n_model == 1`` degrades every
    sharded code path to the single-device one (no axis_index, no
    collective), so one implementation serves both."""
    model_axis: str = "model"
    n_model: int = 1


def paged_attention_block(x, p, cfg: ModelConfig, dist: Dist, *,
                          k_pages, v_pages, block_table, seq_lens,
                          use_pallas: bool = False,
                          window_override: Optional[int] = None,
                          shard: Optional[PagedShard] = None):
    """Decode attention sub-block over one layer's PAGED KV store (§3
    step 4 on the block-table substrate): norm → qkv → rope at each
    slot's depth → scatter the new token's K/V into the slot's current
    tail page → attend through the block table (``paged_decode_attention``
    — Pallas on TPU, the dense-numerics oracle here).

    x: (B, 1, D); k_pages/v_pages: (P, page, KV, Dh);
    block_table: (B, max_pages) int32; seq_lens: (B,) tokens already
    written per slot (the new token lands at that position, exactly like
    the dense path's ``cache_len``). Returns (y, (k_pages, v_pages)).

    The engine guarantees host-side that every active slot's write-target
    page is exclusively owned (copy-on-write happens before the step), so
    the scatter never mutates a page another slot can read.

    ``shard`` (inside ``compat_shard_map`` only): KV heads are striped
    over ``shard.model_axis`` — this shard's page slabs hold KV/m heads.
    The projections compute the FULL head set (replicated math, so every
    per-head value is bitwise the single-device one), this shard's head
    slice is written/attended locally (attention is head-local: no
    collective in the inner loop), and the post-attention combine is one
    head-concatenating ``all_gather`` feeding the output projection —
    an exact recombination, never a partial-sum reduce.
    """
    from repro.kernels.paged_attention.ops import paged_decode_attention
    B, S, _ = x.shape
    assert S == 1, "paged decode is one token per slot per step"
    Hp, KV, Dh = cfg.padded_heads, cfg.n_kv_heads, cfg.head_dim
    window = cfg.sliding_window if window_override is None else window_override
    grouped = GROUPED_ATTN and Hp == cfg.n_heads and Hp % KV == 0
    qh2kv = None if grouped else qh2kv_map(cfg.n_heads, KV, Hp)
    n_model = shard.n_model if shard is not None else 1
    if n_model > 1:
        assert grouped and KV % n_model == 0, \
            "model-parallel KV heads require grouped GQA with KV % m == 0"

    h = rms_norm(x, p["ln"], cfg.norm_eps)
    q = (h @ p["wq"]).reshape(B, S, Hp, Dh)
    if cfg.attn_bias:
        q = q + p["bq"].reshape(1, 1, Hp, Dh)
    if cfg.qk_norm:
        q = rms_norm(q, p["q_norm"], cfg.norm_eps)
    k = (h @ p["wk"]).reshape(B, S, KV, Dh)
    v = (h @ p["wv"]).reshape(B, S, KV, Dh)
    if cfg.attn_bias:
        k = k + p["bk"].reshape(1, 1, KV, Dh)
        v = v + p["bv"].reshape(1, 1, KV, Dh)
    if cfg.qk_norm:
        k = rms_norm(k, p["k_norm"], cfg.norm_eps)

    pos = jnp.asarray(seq_lens)
    positions = jnp.broadcast_to(pos[:, None], (B, S))
    q = apply_rope(q, positions, cfg.rope_theta)
    k = apply_rope(k, positions, cfg.rope_theta)

    if n_model > 1:
        # this shard's contiguous KV-head stripe (and the query group that
        # attends it — grouped GQA keeps query heads head-local too)
        kv_loc = KV // n_model
        g = Hp // KV
        mi = jax.lax.axis_index(shard.model_axis)
        q = jax.lax.dynamic_slice_in_dim(q, mi * kv_loc * g, kv_loc * g, 2)
        k = jax.lax.dynamic_slice_in_dim(k, mi * kv_loc, kv_loc, 2)
        v = jax.lax.dynamic_slice_in_dim(v, mi * kv_loc, kv_loc, 2)

    # scatter the new K/V row into each slot's tail page (inactive slots
    # target the null page 0 — always masked, never read)
    pt = k_pages.shape[1]
    pidx = jnp.clip(pos // pt, 0, block_table.shape[1] - 1)
    pids = block_table[jnp.arange(B), pidx]
    offs = pos % pt
    k_pages = k_pages.at[pids, offs].set(k[:, 0])
    v_pages = v_pages.at[pids, offs].set(v[:, 0])

    o = paged_decode_attention(q[:, 0], k_pages, v_pages, block_table,
                               pos + 1, qh2kv=qh2kv, window=window,
                               use_pallas=use_pallas)
    if n_model > 1:
        # exact head-concatenating combine: each head's value comes from
        # exactly one shard, so the recombined o is bitwise the oracle's
        o = jax.lax.all_gather(o, shard.model_axis, axis=1, tiled=True)
    y = o.reshape(B, S, Hp * Dh) @ p["wo"]
    return y, (k_pages, v_pages)


# ---------------------------------------------------------------------------
# dense MLP
# ---------------------------------------------------------------------------

def mlp_block(x, p, cfg: ModelConfig):
    h = rms_norm(x, p["ln"], cfg.norm_eps)
    gate = jax.nn.silu(h @ p["w1"])
    up = h @ p["w3"]
    return (gate * up) @ p["w2"]


# ---------------------------------------------------------------------------
# MoE
# ---------------------------------------------------------------------------

def _dispatch_indices(gates_idx, n_experts: int, capacity: int):
    """gates_idx: (T, k) expert ids -> flat slot ids (T*k,) into an
    (E*C [+1 overflow]) buffer; slot E*C means 'dropped'."""
    Tk = gates_idx.shape[0] * gates_idx.shape[1]
    flat_e = gates_idx.reshape(Tk)
    oh = jax.nn.one_hot(flat_e, n_experts, dtype=jnp.int32)  # (Tk, E)
    pos = jnp.cumsum(oh, axis=0) - oh
    pos_e = jnp.sum(pos * oh, axis=-1)  # (Tk,) position within expert
    slot = flat_e * capacity + pos_e
    return jnp.where(pos_e < capacity, slot, n_experts * capacity)


def _expert_ffn(buf, w1, w2, w3):
    """buf: (E, C, D); w*: (E, D, F)/(E, F, D) -> (E, C, D)."""
    gate = jax.nn.silu(jnp.einsum("ecd,edf->ecf", buf, w1))
    up = jnp.einsum("ecd,edf->ecf", buf, w3)
    return jnp.einsum("ecf,efd->ecd", gate * up, w2)


def _route(xf, router_w, top_k: int):
    logits = (xf @ router_w).astype(jnp.float32)  # (T, E)
    probs = jax.nn.softmax(logits, axis=-1)
    gates, idx = jax.lax.top_k(probs, top_k)  # (T, k)
    gates = gates / jnp.maximum(gates.sum(-1, keepdims=True), 1e-9)
    # load-balance aux loss (Switch-style): E * mean(f_e * p_e)
    E = router_w.shape[-1]
    me = jnp.mean(probs, axis=0)
    ce = jnp.mean(jax.nn.one_hot(idx[:, 0], E, dtype=jnp.float32), axis=0)
    aux = E * jnp.sum(me * ce)
    return gates.astype(xf.dtype), idx, aux


def _moe_dispatch_compute(xf, router_w, w1, w2, w3, top_k, capacity):
    """Scatter-dispatch MoE on a flat token slab (T, D). Local/global agnostic."""
    T, D = xf.shape
    E = router_w.shape[-1]
    gates, idx, aux = _route(xf, router_w, top_k)
    slot = _dispatch_indices(idx, E, capacity)  # (T*k,)
    x_rep = jnp.repeat(xf, top_k, axis=0)  # (T*k, D)
    buf = jnp.zeros((E * capacity + 1, D), dtype=xf.dtype).at[slot].add(x_rep)
    out = _expert_ffn(buf[:-1].reshape(E, capacity, D), w1, w2, w3)
    out_flat = jnp.concatenate(
        [out.reshape(E * capacity, D), jnp.zeros((1, D), dtype=xf.dtype)])
    y = jnp.take(out_flat, slot, axis=0).reshape(T, top_k, D)
    y = jnp.sum(y * gates[:, :, None], axis=1)
    return y, aux


def _moe_ep_local(xf, router_w, w1l, w2l, w3l, *, top_k, capacity,
                  model_axis, ep, batch_axes):
    """Inside shard_map: xf (T_loc, D) local tokens; w*l (E_loc, D, F) local
    experts. all_to_all over the model axis redistributes capacity slabs."""
    T, D = xf.shape
    E_loc = w1l.shape[0]
    E = E_loc * ep
    gates, idx, aux = _route(xf, router_w, top_k)
    n_groups = max(T // MOE_GROUP_TOKENS, 1)
    G = T // n_groups

    def one_group(carry, args):
        xg, idxg, gatesg = args
        slot = _dispatch_indices(idxg, E, capacity)
        x_rep = jnp.repeat(xg, top_k, axis=0)
        buf = jnp.zeros((E * capacity + 1, D), dtype=xg.dtype).at[slot].add(x_rep)
        buf = buf[:-1].reshape(E, capacity, D)
        if ep > 1:
            # (E, C, D) -> peers: send expert-slab i*E_loc..(i+1)E_loc to peer i
            buf = jax.lax.all_to_all(buf, model_axis, split_axis=0,
                                     concat_axis=1, tiled=True)
            # now (E_loc, ep*C, D): all peers' tokens for my local experts
        out = _expert_ffn(buf, w1l, w2l, w3l)
        if ep > 1:
            out = jax.lax.all_to_all(out, model_axis, split_axis=1,
                                     concat_axis=0, tiled=True)
        out_flat = jnp.concatenate(
            [out.reshape(E * capacity, D), jnp.zeros((1, D), dtype=xg.dtype)])
        y = jnp.take(out_flat, slot, axis=0).reshape(G, top_k, D)
        return carry, jnp.sum(y * gatesg[:, :, None], axis=1)

    xg = xf.reshape(n_groups, G, D)
    idxg = idx.reshape(n_groups, G, top_k)
    gatesg = gates.reshape(n_groups, G, top_k)
    _, y = jax.lax.scan(one_group, None, (xg, idxg, gatesg))
    if batch_axes:
        aux = jax.lax.pmean(aux, batch_axes)
    return y.reshape(T, D), aux


def _moe_tp_local(xf, router_w, w1l, w2l, w3l, *, top_k, capacity, model_axis,
                  batch_axes):
    """Inside shard_map: all experts local, expert-FF hidden dim sharded over
    the model axis (row/column parallel) -> psum after the down projection."""
    T, D = xf.shape
    E = router_w.shape[-1]
    gates, idx, aux = _route(xf, router_w, top_k)
    n_groups = max(T // MOE_GROUP_TOKENS, 1)
    G = T // n_groups

    def one_group(carry, args):
        xg, idxg, gatesg = args
        slot = _dispatch_indices(idxg, E, capacity)
        x_rep = jnp.repeat(xg, top_k, axis=0)
        buf = jnp.zeros((E * capacity + 1, D), dtype=xg.dtype).at[slot].add(x_rep)
        out = _expert_ffn(buf[:-1].reshape(E, capacity, D), w1l, w2l, w3l)
        out = jax.lax.psum(out, model_axis)
        out_flat = jnp.concatenate(
            [out.reshape(E * capacity, D), jnp.zeros((1, D), dtype=xg.dtype)])
        y = jnp.take(out_flat, slot, axis=0).reshape(G, top_k, D)
        return carry, jnp.sum(y * gatesg[:, :, None], axis=1)

    xg = xf.reshape(n_groups, G, D)
    idxg = idx.reshape(n_groups, G, top_k)
    gatesg = gates.reshape(n_groups, G, top_k)
    _, y = jax.lax.scan(one_group, None, (xg, idxg, gatesg))
    if batch_axes:
        aux = jax.lax.pmean(aux, batch_axes)
    return y.reshape(T, D), aux


def moe_block(x, p, cfg: ModelConfig, dist: Dist):
    """x: (B, S, D) -> (y, aux_loss)."""
    moe = cfg.moe
    B, S, D = x.shape
    h = rms_norm(x, p["ln"], cfg.norm_eps)
    T_total = B * S
    cap_of = lambda T: max(int(T * moe.top_k / moe.n_experts
                               * moe.capacity_factor + 0.999), moe.top_k)

    if not dist.active or T_total <= MOE_GLOBAL_DISPATCH_MAX_TOKENS:
        y, aux = _moe_dispatch_compute(
            h.reshape(T_total, D), p["router"], p["w1"], p["w2"], p["w3"],
            moe.top_k, cap_of(T_total))
        return y.reshape(B, S, D), aux

    mesh = dist.mesh
    ma = dist.model_axis
    ep = dist.axis_size(ma)
    batch_axes = tuple(a for a in dist.batch_axes if a in mesh.shape)
    dp = dist.axis_size(batch_axes)

    use_ep = moe.parallelism == "ep" and moe.n_experts % ep == 0 \
        and T_total % (max(dp, 1) * ep) == 0
    if use_ep:
        # expert parallelism: tokens are split over the MODEL axis too
        # (each device dispatches its own token slice; the all_to_all
        # exchanges capacity slabs). Without the model-axis split every
        # model-row device would redundantly dispatch the same tokens —
        # ep× wasted FLOPs (EXPERIMENTS.md §Perf iteration 1).
        tok_axes = batch_axes + (ma,)
        T_loc = max(T_total // max(dp * ep, 1), 1)
    else:
        # tensor-parallel experts: hidden dim sharded; tokens replicated
        # over model, partial FF psum'd — the work split is the hidden dim.
        tok_axes = batch_axes
        T_loc = max(T_total // max(dp, 1), 1)
    tok_spec = P(tok_axes if len(tok_axes) != 1 else tok_axes[0], None)
    cap = cap_of(max(T_loc // max(T_loc // MOE_GROUP_TOKENS, 1), 1))

    if use_ep:
        w_spec = P(ma, None, None)
        w2_spec = P(ma, None, None)
        local = partial(_moe_ep_local, top_k=moe.top_k, capacity=cap,
                        model_axis=ma, ep=ep, batch_axes=tok_axes)
    else:
        w_spec = P(None, None, ma)  # shard expert hidden dim
        w2_spec = P(None, ma, None)
        local = partial(_moe_tp_local, top_k=moe.top_k, capacity=cap,
                        model_axis=ma, batch_axes=batch_axes)

    from repro.launch.mesh import compat_shard_map
    fn = compat_shard_map(
        local, mesh=mesh,
        in_specs=(tok_spec, P(None, None), w_spec, w2_spec, w_spec),
        out_specs=(tok_spec, P()),
        check_vma=False)
    y, aux = fn(h.reshape(T_total, D), p["router"], p["w1"], p["w2"], p["w3"])
    return y.reshape(B, S, D), aux
