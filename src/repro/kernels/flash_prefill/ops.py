"""Public op: chunked-prefill flash attention (jit wrapper + dispatch).

``use_pallas`` selects the Pallas kernel (TPU target; interpret=True on
CPU for validation) vs the pure-jnp oracle. The model forward defaults to
the oracle so the dry-run lowers cleanly on the CPU backend; on TPU the
flag flips the hot path to the kernel.
"""
from __future__ import annotations

import jax

from repro.kernels.flash_prefill.kernel import flash_prefill as _kernel
from repro.kernels.flash_prefill.ref import flash_prefill_ref as _ref


def flash_prefill_attention(q, k, v, *, q_offset: int = 0, window: int = 0,
                            use_pallas: bool = False,
                            interpret: bool | None = None):
    """q: (B, Sq, H, D); k, v: (B, Sk, KV, D) → (B, Sq, H, D)."""
    if not use_pallas:
        return _ref(q, k, v, q_offset=q_offset, window=window)
    if interpret is None:
        interpret = jax.default_backend() == "cpu"
    # largest MXU-friendly block that divides the sequence (tests sweep
    # tiny/ragged shapes; production shapes take the full 128)
    def block(s: int) -> int:
        return next(b for b in (128, 64, 32, 16, 8, 4, 2, 1) if s % b == 0)

    return _kernel(q, k, v, q_offset=q_offset, window=window,
                   bq=block(q.shape[1]), bk=block(k.shape[1]),
                   interpret=interpret)
