"""Figure 8: scheduling strategies — random / load-balance / cache-aware /
KVCache-centric — avg TTFT and TTFT-SLO attainment on a replayed trace
(8 prefill + 8 decode instances, as in §6.2's experiment)."""
from __future__ import annotations

from benchmarks.common import emit
from repro.configs.base import get_config
from repro.core.simulator import MooncakeCluster
from repro.core.trace import TraceSpec, generate_trace


def main(fast: bool = False):
    cfg = get_config("llama2-70b")
    n = 4000 if fast else 23_000
    reqs = generate_trace(TraceSpec(n_requests=n, seed=0))
    rows = []
    for strategy in ("random", "load_balance", "cache_aware", "kvcache"):
        mc = MooncakeCluster(cfg, n_prefill=8, n_decode=8,
                             ttft_slo=30.0, tbt_slo=0.1, strategy=strategy)
        res = mc.run(reqs, speedup=2.0)
        ttft_ok, _ = res.slo_attainment(30.0, 0.1)
        rows.append(dict(
            strategy=strategy,
            avg_ttft_s=round(res.avg_ttft(), 3),
            p90_ttft_s=round(res.ttft_p90(), 3),
            ttft_slo_attainment=round(ttft_ok, 4),
            migrations=res.n_migrations,
            completed=len(res.completed()),
        ))
    emit("fig8_scheduling_strategies", rows)
    return rows


if __name__ == "__main__":
    main()
