"""Multi-pod dry-run: lower + compile every (architecture × input shape)
on the production meshes, WITHOUT allocating any real arrays.

    PYTHONPATH=src python -m repro.launch.dryrun --arch smollm-360m \
        --shape train_4k [--multi-pod] [--hlo-out dir/]

Proves the sharding config is coherent: jit(step).lower(ShapeDtypeStructs)
.compile() must succeed on the 16×16 single-pod mesh and the 2×16×16
multi-pod mesh; prints memory_analysis() (fits 16 GB/chip?) and
cost_analysis() (FLOPs/bytes for the roofline).
"""
# The 512 placeholder devices MUST be claimed before jax initialises —
# nothing above these two lines may import jax (directly or transitively).
import os
os.environ.setdefault("XLA_FLAGS", "--xla_force_host_platform_device_count=512")

import argparse
import json
import re
import sys
import time

import jax
import jax.numpy as jnp
from jax.sharding import NamedSharding, PartitionSpec as P

from repro.configs.base import get_config, list_configs
from repro.launch.mesh import make_production_mesh
from repro.launch.shardings import check_divisibility, param_specs
from repro.launch.steps import (INPUT_SHAPES, applicability, cache_specs,
                                input_specs, make_dist, make_prefill_step,
                                make_serve_step, make_train_step,
                                opt_state_specs, opt_state_shapes)
from repro.models.transformer import init_params


from repro.launch.hlo_analysis import analyze as analyze_hlo
from repro.launch.hlo_analysis import roofline_terms

# §Perf iter 3 A/B switch: in-place buffer donation (default ON — the
# shipped configuration; REPRO_DONATE=0 reproduces the baseline).
DONATE = os.environ.get("REPRO_DONATE", "1") == "1"


def lower_one(arch: str, shape_name: str, *, multi_pod: bool = False,
              compile_: bool = True, hlo_out: str | None = None,
              verbose: bool = True) -> dict:
    """Lower (and compile) one (arch, shape, mesh) combination.
    Returns the roofline record."""
    cfg = get_config(arch)
    shape = INPUT_SHAPES[shape_name]
    runs, note = applicability(cfg, shape)
    if not runs:
        return {"arch": arch, "shape": shape_name, "skipped": note}

    mesh = make_production_mesh(multi_pod=multi_pod)
    dist = make_dist(mesh, shape)

    # ---- parameter/optimizer shapes + shardings (no allocation) ----
    p_shapes = jax.eval_shape(lambda k: init_params(cfg, k),
                              jax.random.PRNGKey(0))
    bad = check_divisibility(cfg, p_shapes, mesh)
    assert not bad, f"sharding divisibility violations: {bad[:5]}"
    p_specs = param_specs(cfg, p_shapes)
    p_shard = jax.tree.map(lambda s: NamedSharding(mesh, s), p_specs,
                           is_leaf=lambda s: isinstance(s, P))

    args, a_specs = input_specs(cfg, shape, dist)
    a_shard = jax.tree.map(lambda s: NamedSharding(mesh, s), a_specs,
                           is_leaf=lambda s: isinstance(s, P))

    t0 = time.time()
    with mesh:
        if shape.kind == "train":
            step = make_train_step(cfg, dist)
            o_shapes = opt_state_shapes(cfg, p_shapes)
            o_specs = opt_state_specs(cfg, p_specs, p_shapes)
            o_shard = jax.tree.map(lambda s: NamedSharding(mesh, s), o_specs,
                                   is_leaf=lambda s: isinstance(s, P))
            jitted = jax.jit(
                step,
                in_shardings=(p_shard, o_shard, a_shard["batch"]),
                out_shardings=(NamedSharding(mesh, P()), p_shard, o_shard),
                # §Perf iter 3: donate params + optimizer state so the
                # update aliases in place (no full-state copy per step)
                donate_argnums=(0, 1) if DONATE else ())
            lowered = jitted.lower(p_shapes, o_shapes, args["batch"])
        elif shape.kind == "prefill":
            step = make_prefill_step(cfg, dist)
            extra = {k: v for k, v in args.items() if k != "tokens"}
            extra_shard = {k: a_shard[k] for k in extra}
            c_specs = cache_specs(cfg, shape, dist)
            c_shard = jax.tree.map(lambda s: NamedSharding(mesh, s), c_specs,
                                   is_leaf=lambda s: isinstance(s, P))
            jitted = jax.jit(
                step,
                in_shardings=(p_shard, a_shard["tokens"], extra_shard),
                out_shardings=(NamedSharding(mesh, P()), c_shard))
            lowered = jitted.lower(p_shapes, args["tokens"], extra)
        else:
            step = make_serve_step(cfg, dist, shape)
            c_shard = jax.tree.map(
                lambda s: NamedSharding(mesh, s), a_specs["caches"],
                is_leaf=lambda s: isinstance(s, P))
            jitted = jax.jit(
                step,
                in_shardings=(p_shard, a_shard["tokens"], c_shard),
                out_shardings=(NamedSharding(mesh, P()), c_shard),
                # §Perf iter 3: donate the KV/state caches — the decode
                # update writes in place instead of copying seq_len × L
                # cache bytes every token
                donate_argnums=(2,) if DONATE else ())
            lowered = jitted.lower(p_shapes, args["tokens"], args["caches"])

        t_lower = time.time() - t0
        rec = {"arch": arch, "shape": shape_name,
               "mesh": "2x16x16" if multi_pod else "16x16",
               "n_devices": mesh.size, "lower_s": round(t_lower, 1),
               "note": note}

        if compile_:
            t0 = time.time()
            compiled = lowered.compile()
            rec["compile_s"] = round(time.time() - t0, 1)
            mem = compiled.memory_analysis()
            rec["memory"] = {
                "argument_bytes": int(getattr(mem, "argument_size_in_bytes", 0)),
                "output_bytes": int(getattr(mem, "output_size_in_bytes", 0)),
                "temp_bytes": int(getattr(mem, "temp_size_in_bytes", 0)),
                "peak_bytes": int(getattr(mem, "peak_memory_in_bytes", 0) or
                                  getattr(mem, "temp_size_in_bytes", 0)),
            }
            ca = compiled.cost_analysis()
            if isinstance(ca, (list, tuple)):
                ca = ca[0] if ca else {}
            rec["cost"] = {  # raw XLA numbers (while bodies counted ONCE)
                "flops": float(ca.get("flops", 0.0)),
                "bytes_accessed": float(ca.get("bytes accessed", 0.0)),
                "transcendentals": float(ca.get("transcendentals", 0.0)),
            }
            # post-SPMD HLO walk with while-trip scaling → per-device totals
            hlo = compiled.as_text()
            rec["hlo_analysis"] = analyze_hlo(hlo)
            rec["roofline"] = roofline_terms(rec["hlo_analysis"])
            if hlo_out:
                os.makedirs(hlo_out, exist_ok=True)
                tag = f"{arch}__{shape_name}__{rec['mesh']}"
                with open(os.path.join(hlo_out, tag + ".hlo.txt"), "w") as f:
                    f.write(hlo)
    if verbose:
        print(json.dumps(rec, indent=1))
    return rec


def main(argv=None) -> int:
    ap = argparse.ArgumentParser(description=__doc__)
    ap.add_argument("--arch", default="all",
                    help="architecture id or 'all'")
    ap.add_argument("--shape", default="all",
                    help="input shape name or 'all'")
    ap.add_argument("--multi-pod", action="store_true")
    ap.add_argument("--no-compile", action="store_true",
                    help="lower only (skip XLA compile)")
    ap.add_argument("--hlo-out", default=None)
    ap.add_argument("--json-out", default=None)
    args = ap.parse_args(argv)

    archs = list_configs() if args.arch == "all" else [args.arch]
    shapes = list(INPUT_SHAPES) if args.shape == "all" else [args.shape]

    records, failures = [], []
    for arch in archs:
        for shape in shapes:
            try:
                rec = lower_one(arch, shape, multi_pod=args.multi_pod,
                                compile_=not args.no_compile,
                                hlo_out=args.hlo_out)
                records.append(rec)
            except Exception as e:  # noqa: BLE001 — report, keep going
                failures.append((arch, shape, repr(e)[:300]))
                print(f"FAIL {arch} × {shape}: {e!r}"[:400], file=sys.stderr)
    if args.json_out:
        with open(args.json_out, "w") as f:
            json.dump(records, f, indent=1)
    print(f"\n{len(records)} lowered OK, {len(failures)} failed")
    for a, s, e in failures:
        print(f"  FAIL {a} × {s}: {e}")
    return 1 if failures else 0


if __name__ == "__main__":
    sys.exit(main())
