"""Conductor — Mooncake's KVCache-centric global scheduler (§6, Algorithm 1).

For each request the Conductor selects a (prefill, decode) instance pair by
minimising predicted TTFT over the prefill pool, where each candidate's TTFT
is either

  * cache-aware (local):      T_queue + T_prefill(len, local_prefix)
  * cache-aware + balancing:  T_transfer + T_queue + T_prefill(len, best_prefix)

and, when the instance's pool is a ``TieredCachePool`` with part of the
prefix demoted to SSD, a third arm — the compute-vs-load decision of Jin
et al. ("Compute Or Load KV Cache? Why Not Both?"):

  * load from local SSD:  max(T_queue, T_ssd_load) + T_prefill(len, tier_prefix)

The scheduler picks min(recompute, fetch-from-peer-DRAM, load-from-SSD)
per request. The SSD load is *prefetched*: it starts immediately on the
node's SSD read channel and overlaps the queue wait (Jin et al.'s "why
not both"), so only the slower of queue-drain and load delays the
compute. The channel serialises loads FIFO (``Messenger.estimate_ssd``),
so a node whose SSD is already streaming one long prefix makes the next
load correctly expensive. Arm selection for recompute-vs-peer depends on
whether the best remote prefix beats the local one by more
than ``kvcache_balancing_threshold`` (Algorithm 1 line 8). After selection,
if the chosen instance's local prefix is much worse than the global best,
the best holder's blocks are replicated to it (hot-spot migration, line 28)
— hot blocks spread automatically because they keep winning matches.

Admission (line 25) rejects when the achievable TTFT or the decode pool's
predicted TBT violates the SLO; overload-oriented policies (§7) wrap this
with earlier, load-based rejection — see ``overload.py``.
"""
from __future__ import annotations

from dataclasses import dataclass, field
from typing import Optional

from repro.core.cache import CachePool, StateCache
from repro.core.costmodel import CostModel
from repro.core.messenger import Messenger
from repro.core.trace import BLOCK_TOKENS, Request


@dataclass
class PrefillInstance:
    """One prefill node (group): local cache pool + FIFO work queue."""
    iid: int
    pool: CachePool
    cost: CostModel
    queue_free_at: float = 0.0     # time the queue drains
    total_busy: float = 0.0
    n_scheduled: int = 0

    def queue_time(self, now: float) -> float:
        return max(self.queue_free_at - now, 0.0)

    def utilization(self, now: float) -> float:
        return self.total_busy / now if now > 0 else 0.0


@dataclass
class DecodeInstance:
    """One decoding node: continuous batch of active requests."""
    iid: int
    cost: CostModel
    active: int = 0                 # requests in the batch
    kv_tokens: float = 0.0          # total context tokens held
    pending: int = 0                # accepted, prefill not yet done
    pending_tokens: float = 0.0
    n_scheduled: int = 0

    def avg_ctx(self) -> float:
        return self.kv_tokens / self.active if self.active else 0.0

    def predicted_tbt(self, extra_reqs: int = 0, extra_tokens: float = 0.0,
                      include_pending: bool = True) -> float:
        b = self.active + extra_reqs + (self.pending if include_pending else 0)
        toks = self.kv_tokens + extra_tokens \
            + (self.pending_tokens if include_pending else 0.0)
        if b == 0:
            return 0.0
        return self.cost.decode_iter_time(b, toks / b)

    def vram_ok(self, extra_tokens: float, include_pending: bool = True) -> bool:
        cap = self.cost.decode_capacity_tokens()
        held = self.kv_tokens + (self.pending_tokens if include_pending else 0.0)
        return held + extra_tokens <= cap


@dataclass
class Decision:
    accepted: bool
    prefill: Optional[PrefillInstance] = None
    decode: Optional[DecodeInstance] = None
    expected_ttft: float = 0.0
    expected_tbt: float = 0.0
    prefix_blocks: int = 0              # blocks reused (local or migrated)
    migrated_blocks: int = 0            # hot-spot replication volume
    transfer_from: Optional[int] = None
    ssd_blocks: int = 0                 # prefix blocks loaded from local SSD
    ssd_load_time: float = 0.0          # committed load duration incl. channel
                                        # backlog (overlaps the queue wait)
    reject_reason: str = ""


class Conductor:
    """Algorithm 1 + hot-spot migration. Scheduling strategies:

    * ``kvcache`` — full Algorithm 1 (cache-aware + cache load balancing)
    * ``cache_aware`` — §6.1 only: always use the local prefix, never
      migrate (the Figure 8 "cache-aware" baseline)
    * ``load_balance`` — pick the least-loaded prefill instance
    * ``random`` — uniform random instance
    """

    def __init__(self, prefills: list[PrefillInstance],
                 decodes: list[DecodeInstance], messenger: Messenger, *,
                 ttft_slo: float, tbt_slo: float,
                 balancing_threshold: float = 1.3,
                 strategy: str = "kvcache", rng=None) -> None:
        self.P = prefills
        self.D = decodes
        self.messenger = messenger
        self.ttft_slo = ttft_slo
        self.tbt_slo = tbt_slo
        self.threshold = balancing_threshold
        self.strategy = strategy
        import random as _random
        self.rng = rng or _random.Random(0)
        self.account_pending = True   # baseline admission flips this (§7.2)
        self.n_migrations = 0
        self.migrated_bytes = 0.0
        self.n_ssd_loads = 0
        self.ssd_loaded_bytes = 0.0

    # ---- Algorithm 1, lines 4–23 -------------------------------------
    def _find_best_prefix(self, block_keys: list[int]):
        best_len, best_inst = 0, None
        for inst in self.P:
            n = inst.pool.prefix_len(block_keys)
            if n > best_len:
                best_len, best_inst = n, inst
        return best_len, best_inst

    def _select_prefill(self, req: Request, now: float):
        block_keys = req.hash_ids
        L = req.input_length
        best_len, best_inst = self._find_best_prefix(block_keys)

        if self.strategy == "random":
            inst = self.rng.choice(self.P)
            n = inst.pool.prefix_len(block_keys)
            ttft = inst.queue_time(now) + inst.cost.prefill_time(
                L, n * BLOCK_TOKENS)
            return inst, ttft, n, 0, None, 0
        if self.strategy == "load_balance":
            inst = min(self.P, key=lambda i: i.queue_free_at)
            n = inst.pool.prefix_len(block_keys)
            ttft = inst.queue_time(now) + inst.cost.prefill_time(
                L, n * BLOCK_TOKENS)
            return inst, ttft, n, 0, None, 0

        # candidate: (ttft, inst, prefix, migrate_blocks, src, ssd_blocks)
        best = (float("inf"), None, 0, 0, None, 0)
        for inst in self.P:
            prefix_len = inst.pool.prefix_len(block_keys)
            t_queue = inst.queue_time(now)
            ratio = (best_len / prefix_len) if prefix_len else (
                float("inf") if best_len else 1.0)
            local_only = self.strategy == "cache_aware"
            if ratio < self.threshold or local_only or best_inst is None:
                # arm 1 — recompute on the local DRAM prefix
                t_prefill = inst.cost.prefill_time(L, prefix_len * BLOCK_TOKENS)
                cand = (t_queue + t_prefill, inst, prefix_len, 0, None, 0)
            else:
                # arm 2 — cache balancing: fetch the best peer prefix here
                transfer_blocks = best_len - prefix_len
                nbytes = inst.cost.kv_bytes(transfer_blocks * BLOCK_TOKENS)
                t_transfer = self.messenger.estimate(best_inst.iid, nbytes, now)
                t_prefill = inst.cost.prefill_time(L, best_len * BLOCK_TOKENS)
                cand = (t_transfer + t_queue + t_prefill, inst, best_len,
                        transfer_blocks, best_inst, 0)
            if cand[0] < best[0]:
                best = cand
            # arm 3 — compute-vs-load: the prefix extends into local SSD
            tier_prefix = getattr(inst.pool, "tier_prefix", None)
            if tier_prefix is None:
                continue
            tp = tier_prefix(block_keys)
            if tp.ssd > 0:
                nbytes = inst.cost.kv_bytes(tp.ssd * BLOCK_TOKENS)
                if self.messenger.has_ssd_channel(inst.iid):
                    t_ssd = self.messenger.estimate_ssd(inst.iid, nbytes, now)
                else:
                    t_ssd = inst.cost.ssd_load_time(tp.ssd * BLOCK_TOKENS)
                t_prefill = inst.cost.prefill_time(L, tp.total * BLOCK_TOKENS)
                # the load starts now and overlaps the queue wait; compute
                # starts when both the queue and the load are done
                cand = (max(t_queue, t_ssd) + t_prefill, inst, tp.total,
                        0, None, tp.ssd)
                if cand[0] < best[0]:
                    best = cand
        ttft, inst, prefix, migrate, src, ssd_blocks = best
        return inst, ttft, prefix, migrate, src, ssd_blocks

    def _select_decode(self, req: Request):
        """SelectDecodingInstance: least predicted TBT with VRAM headroom.

        ``account_pending`` distinguishes the §7 policies: the naive
        baseline pre-selects on the CURRENT decode state only (the time-lag
        of §7.2 — accepted-but-still-prefilling requests are invisible),
        while early/predictive policies count in-flight commitments."""
        tokens = req.input_length + req.output_length
        ok = [d for d in self.D if d.vram_ok(tokens, self.account_pending)]
        if not ok:
            return None, float("inf")
        d = min(ok, key=lambda d: d.predicted_tbt(
            1, tokens, include_pending=self.account_pending))
        return d, d.predicted_tbt(1, tokens,
                                  include_pending=self.account_pending)

    # ---- the public entry point ---------------------------------------
    def schedule(self, req: Request, now: float) -> Decision:
        inst, ttft, prefix, migrate, src, ssd_blocks = \
            self._select_prefill(req, now)
        d, tbt = self._select_decode(req)
        if d is None:
            return Decision(False, reject_reason="no decode slot (VRAM)")
        if ttft > self.ttft_slo or tbt > self.tbt_slo:
            reason = "TTFT SLO" if ttft > self.ttft_slo else "TBT SLO"
            return Decision(False, reject_reason=reason,
                            expected_ttft=ttft, expected_tbt=tbt)

        # ---- commit: hot-spot migration (Algorithm 1 line 28) ----
        if migrate and src is not None:
            nbytes = inst.cost.kv_bytes(migrate * BLOCK_TOKENS)
            self.messenger.enqueue(src.iid, nbytes, now)
            inst.pool.insert(req.hash_ids[:prefix], start_pos=0)
            self.n_migrations += 1
            self.migrated_bytes += nbytes

        # ---- commit: SSD prefix load (compute-vs-load 'load' arm) ----
        # The load starts NOW on the node's FIFO SSD read channel and
        # overlaps the queue wait; compute starts once both the queue has
        # drained and the load has landed — real time the simulator sees.
        t_ssd = 0.0
        load_done = now
        if ssd_blocks:
            nbytes = inst.cost.kv_bytes(ssd_blocks * BLOCK_TOKENS)
            if self.messenger.has_ssd_channel(inst.iid):
                load_done = self.messenger.enqueue_ssd(inst.iid, nbytes, now)
            else:
                load_done = now + inst.cost.ssd_load_time(
                    ssd_blocks * BLOCK_TOKENS)
            t_ssd = load_done - now
            self.n_ssd_loads += 1
            self.ssd_loaded_bytes += nbytes

        # queue the prefill work (cache inserts happen at completion in the
        # simulator; here we update the pool optimistically so back-to-back
        # requests in a session see the blocks). For a tiered pool the
        # lookup PROMOTES the loaded SSD blocks into DRAM.
        t_prefill = inst.cost.prefill_time(
            req.input_length, prefix * BLOCK_TOKENS)
        inst.pool.lookup(req.hash_ids[:prefix])
        inst.pool.insert(req.hash_ids[prefix:], start_pos=prefix)
        inst.queue_free_at = max(inst.queue_free_at, load_done,
                                 now) + t_prefill
        inst.total_busy += t_prefill
        inst.n_scheduled += 1
        d.pending += 1
        d.pending_tokens += req.input_length + req.output_length
        d.n_scheduled += 1
        return Decision(True, prefill=inst, decode=d, expected_ttft=ttft,
                        expected_tbt=tbt, prefix_blocks=prefix,
                        migrated_blocks=migrate,
                        transfer_from=src.iid if src else None,
                        ssd_blocks=ssd_blocks, ssd_load_time=t_ssd)
