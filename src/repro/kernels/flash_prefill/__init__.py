from repro.kernels.flash_prefill.ops import flash_prefill_attention
from repro.kernels.flash_prefill.ref import flash_prefill_ref
