"""Paged device KV cache: allocation invariants + gather round trip."""
import jax
import jax.numpy as jnp
import numpy as np
import pytest
try:
    from hypothesis import given, settings, strategies as st
except ImportError:  # sandboxed env: vendored shim (seeded random)
    from _hypothesis_shim import given, settings, strategies as st

from repro.configs.base import get_config
from repro.serving.paged_cache import (assign_seq, free_seq, gather_kv,
                                       grow_seq, init_paged_cache, write_kv)

CFG = get_config("smollm-360m").reduced()


def test_write_gather_round_trip():
    cache = init_paged_cache(CFG, batch=2, n_pages=32, page_tokens=16,
                             max_seq=128)
    cache = assign_seq(cache, 0, 40)
    cache = assign_seq(cache, 1, 70)
    L, KV, Dh = CFG.n_layers, CFG.n_kv_heads, CFG.head_dim
    k0 = jax.random.normal(jax.random.PRNGKey(0), (L, 40, KV, Dh), jnp.bfloat16)
    v0 = -k0
    cache = write_kv(cache, 0, 0, k0, v0)
    k1 = jax.random.normal(jax.random.PRNGKey(1), (L, 70, KV, Dh), jnp.bfloat16)
    cache = write_kv(cache, 1, 0, k1, k1 + 1)
    kg, vg = gather_kv(cache, 80)
    np.testing.assert_array_equal(np.asarray(kg[:, 0, :40]), np.asarray(k0))
    np.testing.assert_array_equal(np.asarray(vg[:, 0, :40]), np.asarray(v0))
    np.testing.assert_array_equal(np.asarray(kg[:, 1, :70]), np.asarray(k1))


def test_append_write_crosses_page_boundary():
    cache = init_paged_cache(CFG, batch=1, n_pages=16, page_tokens=16,
                             max_seq=64)
    cache = assign_seq(cache, 0, 30)
    L, KV, Dh = CFG.n_layers, CFG.n_kv_heads, CFG.head_dim
    k = jnp.ones((L, 30, KV, Dh), jnp.bfloat16)
    cache = write_kv(cache, 0, 0, k, k)
    cache = grow_seq(cache, 0, 10)                  # 30 → 40, new page
    k2 = 2 * jnp.ones((L, 10, KV, Dh), jnp.bfloat16)
    cache = write_kv(cache, 0, 30, k2, k2)
    kg, _ = gather_kv(cache, 48)
    np.testing.assert_array_equal(np.asarray(kg[0, 0, :30, 0, 0]),
                                  np.ones(30, np.float32))
    np.testing.assert_array_equal(np.asarray(kg[0, 0, 30:40, 0, 0]),
                                  2 * np.ones(10, np.float32))


def test_free_returns_pages():
    cache = init_paged_cache(CFG, batch=2, n_pages=8, page_tokens=16,
                             max_seq=64)
    n0 = len(cache.free)
    cache = assign_seq(cache, 0, 60)                # 4 pages
    assert len(cache.free) == n0 - 4
    cache = free_seq(cache, 0)
    assert len(cache.free) == n0
    assert int(cache.seq_lens[0]) == 0


def test_oom_raises():
    cache = init_paged_cache(CFG, batch=1, n_pages=4, page_tokens=16,
                             max_seq=256)
    with pytest.raises(MemoryError):
        assign_seq(cache, 0, 16 * 10)


def test_gather_kv_non_multiple_max_tokens_keeps_tail():
    """max_tokens not a multiple of page_tokens must round UP to whole
    pages and slice, not silently truncate the partial page."""
    cache = init_paged_cache(CFG, batch=1, n_pages=16, page_tokens=16,
                             max_seq=64)
    cache = assign_seq(cache, 0, 40)
    L, KV, Dh = CFG.n_layers, CFG.n_kv_heads, CFG.head_dim
    k = jax.random.normal(jax.random.PRNGKey(2), (L, 40, KV, Dh),
                          jnp.bfloat16)
    cache = write_kv(cache, 0, 0, k, -k)
    kg, vg = gather_kv(cache, 40)              # 2.5 pages
    assert kg.shape[2] == 40
    np.testing.assert_array_equal(np.asarray(kg[:, 0]), np.asarray(k))
    np.testing.assert_array_equal(np.asarray(vg[:, 0]), np.asarray(-k))


def test_write_kv_overrun_raises_not_corrupts():
    """A write past the assigned pages must raise, not scribble on the
    null page (entry 0)."""
    cache = init_paged_cache(CFG, batch=1, n_pages=16, page_tokens=16,
                             max_seq=64)
    cache = assign_seq(cache, 0, 20)           # 2 pages assigned
    L, KV, Dh = CFG.n_layers, CFG.n_kv_heads, CFG.head_dim
    k = jnp.ones((L, 20, KV, Dh), jnp.bfloat16)
    null_before = np.asarray(cache.k_pages[:, 0])
    with pytest.raises(IndexError):
        write_kv(cache, 0, 30, k, k)           # runs into table entry 0
    np.testing.assert_array_equal(np.asarray(cache.k_pages[:, 0]),
                                  null_before)
    with pytest.raises(IndexError):            # past the table itself
        write_kv(cache, 0, 60, k, k)


@given(st.lists(st.tuples(st.integers(0, 3), st.integers(1, 60)),
                min_size=1, max_size=12))
@settings(max_examples=40, deadline=None)
def test_alloc_free_cycles_conserve_pages(ops):
    """Random assign/free cycles: no page leaked, no page double-owned."""
    cache = init_paged_cache(CFG, batch=4, n_pages=64, page_tokens=16,
                             max_seq=64)
    total = len(cache.free)
    active = set()
    for slot, tokens in ops:
        if slot in active:
            cache = free_seq(cache, slot)
            active.discard(slot)
        else:
            try:
                cache = assign_seq(cache, slot, tokens)
                active.add(slot)
            except MemoryError:
                pass
        table = np.asarray(cache.block_table)
        lens = np.asarray(cache.seq_lens)
        owned = []
        for s in range(4):
            n = int(np.ceil(lens[s] / cache.page_tokens))
            owned.extend(int(p) for p in table[s, :n] if p != 0)
        assert len(owned) == len(set(owned)), "page double-owned"
        assert len(owned) + len(cache.free) == total, "page leaked"
