"""Serving engines — the executable counterpart of the simulator.

This is a real (CPU-runnable, reduced-model) implementation of the §3
workflow: a host-DRAM KVCache pool holding 512-token blocks keyed by
prefix-chained hashes, a prefill worker that reuses pool blocks and runs
*chunked incremental prefill* (§3 step 2), layer-wise store-back of fresh
blocks (§5.2 semantics), and a continuous-batching decode worker whose
batch slots sit at independent depths (per-slot cache lengths).

The disaggregated pair (PrefillWorker feeding DecodeWorker through the
pool) is what examples/serve_cluster.py drives with a Conductor in front.
"""
from __future__ import annotations

import hashlib
import threading
import time
import warnings
from dataclasses import dataclass, field
from typing import Optional

import jax
import jax.numpy as jnp
import numpy as np

from repro.configs.base import ModelConfig
from repro.core.cache import CachePool
from repro.core.trace import BLOCK_TOKENS
from repro.models.layers import DTYPE
from repro.models.transformer import (Caches, KVCache, decode_step,
                                      decode_step_paged,
                                      decode_step_paged_sharded, init_caches,
                                      paged_shard_reason, prefill)
from repro.serving.request import ServingRequest
from repro.serving.transport import InProcPeer, PeerError, fallback_reason


def prefix_hash_ids(tokens: np.ndarray, block: int = BLOCK_TOKENS) -> list[int]:
    """Chained block hashes of a token sequence (Figure 3): block i's key
    commits to all tokens ≤ its end, so equal ids ⇔ equal prefixes."""
    out: list[int] = []
    h = hashlib.sha256()
    n_full = len(tokens) // block
    for i in range(n_full):
        h.update(np.ascontiguousarray(tokens[i * block:(i + 1) * block]).tobytes())
        out.append(int.from_bytes(h.copy().digest()[:8], "little"))
    return out


class PrefixHasher:
    """Incremental chained block hasher with a per-session memo.

    ``prefix_hash_ids`` recomputes the full SHA-256 chain per request —
    O(prompt) crypto hashing even when turn t+1 of a session merely
    extends turn t's prompt. The memo keeps, per session, the hasher
    STATE after the deepest previously-hashed block plus the exact tokens
    it commits to; a revisit verifies the prefix with one array compare
    (memcmp speed, ~an order of magnitude cheaper than SHA-256) and
    SHA-hashes only the suffix blocks. A diverging prefix falls back to
    the full chain and replaces the memo — ids are always identical to
    ``prefix_hash_ids``.
    """

    def __init__(self, block: int = BLOCK_TOKENS,
                 capacity_sessions: int = 256) -> None:
        from collections import OrderedDict
        self.block = block
        self.capacity = capacity_sessions
        # session -> (committed tokens, ids, sha256 state after deepest
        # block), LRU-bounded: each entry pins O(prompt) host tokens
        self._memo: "OrderedDict" = OrderedDict()
        self.blocks_hashed = 0
        self.memo_hits = 0

    def hash_ids(self, tokens: np.ndarray, session=None) -> list[int]:
        block = self.block
        n_full = len(tokens) // block
        out: list[int] = []
        h = hashlib.sha256()
        start = 0
        if session is not None:
            m = self._memo.get(session)
            if m is not None:
                mtok, mids, mh = m
                d = len(mids)
                if d <= n_full and np.array_equal(
                        np.asarray(tokens[:d * block]), mtok):
                    out = list(mids)
                    h = mh.copy()
                    start = d
                    self.memo_hits += 1
        for i in range(start, n_full):
            h.update(np.ascontiguousarray(
                tokens[i * block:(i + 1) * block]).tobytes())
            out.append(int.from_bytes(h.copy().digest()[:8], "little"))
        self.blocks_hashed += n_full - start
        if session is not None and n_full:
            self._memo[session] = (
                np.asarray(tokens[:n_full * block]).copy(), list(out), h)
            self._memo.move_to_end(session)
            while len(self._memo) > self.capacity:
                self._memo.popitem(last=False)
        return out


@dataclass
class FetchPlan:
    """Side-effect-free snapshot of a hash chain's residency: which prefix
    blocks are resident and in which tier. The engine plans the §5.2
    load-vs-compute split off this, then commits via ``finish_fetch``.

    With a ``GlobalBlockDirectory`` a miss can resolve to a PEER node
    (tier ``"peer"``); ``sources[i]`` then names the owning node. The
    directory is advisory — every peer block re-verifies at fetch time
    and a stale entry degrades to recompute, never to wrong bytes."""
    hash_ids: list[int]
    tiers: list[str]                # per resident block: dram | ssd | peer
    sources: Optional[list] = None  # per block: owner node id (peer only)

    @property
    def n_resident(self) -> int:
        return len(self.tiers)

    @property
    def has_ssd(self) -> bool:
        return "ssd" in self.tiers

    @property
    def has_remote(self) -> bool:
        return "peer" in self.tiers

    def source(self, i: int):
        return self.sources[i] if self.sources is not None else None

    def truncate(self, n: int) -> "FetchPlan":
        return FetchPlan(self.hash_ids, self.tiers[:n],
                         None if self.sources is None else self.sources[:n])


class PeerSource:
    """Read-side adapter over a peer transport — the Messenger's
    cross-node block channel, transport-agnostic.

    The peer object is either an ``InProcPeer`` (sibling ``HostKVPool``
    in this process) or a ``SocketPeer`` (wire protocol); both raise the
    SAME taxonomy (``PeerUnreachable``/``StaleDirectory``/``TornFrame``
    from ``repro.serving.transport``), so this adapter — and every
    ``fallback_reasons`` branch downstream — cannot tell the transports
    apart. ``read_layer`` maps a taxonomy error to a per-key reason
    (``peer_unreachable`` — the node died; ``stale_directory`` — the
    peer no longer holds the block; ``verify_failed`` — bytes present
    but integrity-rejected) and returns ``None``, exactly like a failed
    local store read, so the fetching pool can log WHY it fell back to
    recompute and self-heal the directory.
    """

    def __init__(self, node, peer) -> None:
        self.node = node
        self.peer = peer
        self.reasons: dict[int, str] = {}

    @property
    def n_layers(self) -> int:
        if self.peer is None:
            return 0
        try:
            return self.peer.n_layers
        except PeerError:
            return 0

    def note_empty(self, key: int) -> None:
        """Classify a fetch that never started: a dead peer vs an alive
        peer with nothing to serve (the directory entry was stale)."""
        if key in self.reasons:
            return
        if self.peer is None:
            self.reasons[key] = "peer_unreachable"
            return
        try:
            self.peer.n_layers
        except PeerError as e:
            self.reasons[key] = fallback_reason(e)
        else:
            self.reasons[key] = "stale_directory"

    def read_layer(self, key: int, layer: int):
        if self.peer is None:
            self.reasons[key] = "peer_unreachable"
            return None
        try:
            return self.peer.read_layer(key, layer)
        except PeerError as e:
            self.reasons[key] = fallback_reason(e)
            return None


class HostKVPool:
    """Two-tier CPU KVCache pool: prefix-hash → per-layer KV block bytes.

    Metadata/eviction delegated to ``CachePool``/``TieredCachePool``
    (``core/tiered.py`` — same demote-on-evict / promote-on-hit semantics
    the simulator prices). Models Figure 3's 'KVCache pool in CPU memory'
    plus the paper's SSD rung:

    * ``ssd_capacity_blocks`` alone keeps demoted bytes in host arrays
      (the pre-SSD-store behaviour — the tier split is the metadata/cost
      model's concern only);
    * with ``ssd_dir`` the SSD tier is REAL: demotions batch-write to a
      checksummed ``SSDBlockStore`` file, promotions read them back, and
      ``start_prefetch``/``finish_fetch`` expose the async layer-wise load
      path the ``PrefillWorker`` overlaps with head recompute (§5.2).
      A block whose on-disk bytes fail verification is discarded from the
      hierarchy and silently becomes a miss — never wrong bytes.

    With a shared ``GlobalBlockDirectory`` (+ ``node_id`` and peers wired
    via ``add_peer``/``connect_pools``) the pool joins the Figure-3
    cluster-wide pool: its tier moves publish to the directory, and
    ``plan_fetch`` resolves local misses to a peer's DRAM or SSD. Peer
    blocks stream through the same ``AsyncPrefetcher`` layer-major queue,
    verify before their metadata enters the local hierarchy, and on ANY
    failure (dead peer, stale directory entry, corrupt remote slot) the
    run truncates to recompute with the reason recorded in
    ``fallback_reasons`` — wrong bytes are impossible.
    """

    def __init__(self, capacity_blocks: Optional[int] = None,
                 policy: str = "lru", ssd_capacity_blocks: int = 0,
                 ssd_policy: str = "lru", writeback_batch: int = 8,
                 ssd_dir: Optional[str] = None,
                 ssd_read_bw: Optional[float] = None,
                 ssd_write_bw: Optional[float] = None,
                 spec=None, directory=None, node_id=None) -> None:
        from repro.configs.base import CacheTierSpec
        if spec is None:
            spec = CacheTierSpec(
                dram_blocks=capacity_blocks, ssd_blocks=ssd_capacity_blocks,
                dram_policy=policy, ssd_policy=ssd_policy,
                writeback_batch=writeback_batch, ssd_dir=ssd_dir)
        self.spec = spec
        self.meta: CachePool = spec.make_pool()
        self.data: dict[int, tuple[np.ndarray, np.ndarray]] = {}
        self.store = None
        self.prefetcher = None
        self.directory = directory
        self.node_id = node_id
        self.peers: dict = {}           # node id -> peer HostKVPool
        self.alive = True               # kill() = failure-injection switch
        self.peer_blocks_fetched = 0
        self.peer_fetch_failures = 0
        self.fallback_reasons: dict[str, int] = {}
        self._inflight: dict[int, tuple[np.ndarray, np.ndarray]] = {}
        # preemption spill slab: req_id -> (k, v, n_tokens) of a victim's
        # exported device run (the HBM→DRAM rung). Unlike the block pool
        # above this is keyed per REQUEST (live decode tails are private,
        # not prefix-shareable) and entries are explicitly popped on
        # restore/abandon. Written by the serving-loop thread, read by
        # stats() from any thread — hence its own lock.
        self._spill_lock = threading.Lock()
        self._spill: dict[int, tuple[np.ndarray, np.ndarray, int]] = {}  #: guarded_by self._spill_lock
        #: guarded_by self._spill_lock
        self._spill_counters = dict(spills=0, spill_restores=0,
                                    spill_drops=0)
        if spec.ssd_dir is not None and not spec.tiered:
            raise ValueError(
                "ssd_dir given but the SSD tier is disabled (ssd_blocks=0) "
                "— nothing would ever reach the file-backed store; set "
                "ssd_capacity_blocks/CacheTierSpec.ssd_blocks > 0")
        if spec.tiered and spec.ssd_dir is not None:
            from repro.core.cache import BlockMeta
            from repro.serving.ssd_store import AsyncPrefetcher, SSDBlockStore
            self.store = SSDBlockStore(
                spec.ssd_dir, writeback_batch=spec.writeback_batch,
                read_bw=ssd_read_bw, write_bw=ssd_write_bw)
            self.prefetcher = AsyncPrefetcher(self.store)
            self.meta.on_demote = self._on_demote
            self.meta.on_promote = self._on_promote
            self.meta.on_drop = self._on_drop
            # restart recovery: blocks a previous run flushed re-enter the
            # SSD tier's metadata (chain hashes are stable across runs, so
            # matching prefixes become hits again; depth is unknown → 0)
            for key in self.store.keys():
                ssd_evicted, placed = self.meta.ssd.insert_meta(
                    BlockMeta(key=key))
                for e in ssd_evicted:
                    self.store.delete(e)
                if not placed:
                    self.store.delete(key)
        # join the global pool AFTER recovery so bind() seeds recovered
        # blocks too; chaining preserves the byte-holder hooks above
        if directory is not None and hasattr(self.meta, "on_demote"):
            directory.bind(node_id, self.meta)

    # ---- global pool membership ----------------------------------------
    def add_peer(self, node_id, peer) -> None:
        """Make a remote node fetchable. Accepts either a peer transport
        (``InProcPeer``/``SocketPeer`` — anything with ``n_layers`` +
        ``read_layer`` raising the shared taxonomy) or, for backward
        compatibility, a raw ``HostKVPool``, which is wrapped in an
        ``InProcPeer`` so BOTH transports fail identically: a killed
        in-process pool and a kill -9'd remote process each surface as
        ``PeerUnreachable`` → ``fallback_reasons["peer_unreachable"]``."""
        if not hasattr(peer, "read_layer"):
            peer = InProcPeer(peer)
        self.peers[node_id] = peer

    def kill(self) -> None:
        """Failure injection: model this node dying — peers' reads against
        it raise ``PeerUnreachable`` from now on (the same error a dead
        socket raises). Local state is left intact so tests can assert
        nothing was served from a dead node."""
        self.alive = False

    def _note_fallback(self, reason: str) -> None:
        self.fallback_reasons[reason] = self.fallback_reasons.get(reason, 0) + 1

    # ---- tier-event hooks (file-backed mode only) ----------------------
    def _on_demote(self, key: int) -> None:
        kv = self.data.pop(key, None)
        if kv is not None:
            self.store.put(key, *kv)    # staged; flushed per writeback batch

    def _on_promote(self, key: int, count_read: bool) -> None:
        if count_read:
            kv = self._inflight.pop(key, None)
            if kv is None:              # promotion outside a verified fetch
                kv = self.store.read_block(key)
            if kv is not None:
                self.data[key] = kv
            # unreadable bytes leave no DRAM copy; the next verified fetch
            # sees the hole and discards the block's metadata
        self.store.delete(key)

    def _on_drop(self, key: int) -> None:
        self.data.pop(key, None)
        if self.store is not None:
            self.store.delete(key)

    # ---- fetch protocol ------------------------------------------------
    def plan_fetch(self, hash_ids: list[int]) -> FetchPlan:
        """Residency snapshot of the chain's prefix (no side effects).
        Local misses consult the global directory: a block a reachable
        peer claims extends the plan with tier ``"peer"``."""
        rt = getattr(self.meta, "resident_tier", None)
        tiers: list[str] = []
        sources: list = []
        for h in hash_ids:
            t = rt(h) if rt is not None \
                else ("dram" if h in self.meta else None)
            src = None
            if t is None and self.directory is not None and self.peers:
                owner = self.directory.pick_owner(
                    h, exclude=(self.node_id,), among=self.peers)
                if owner is not None:
                    t, src = "peer", owner[0]
            if t is None:
                break
            tiers.append(t)
            sources.append(src)
        return FetchPlan(list(hash_ids), tiers, sources)

    def start_prefetch(self, plan: FetchPlan, from_block: int = 0):
        """Enqueue async layer-wise loads of the plan's SSD and peer
        blocks at index ≥ ``from_block``; returns a PrefetchHandle (or
        None). Peer blocks stream through the same layer-major queue,
        read off the owning node via a ``PeerSource``."""
        if self.prefetcher is None:
            return None
        keys: list[int] = []
        sources: dict = {}
        peer_srcs: dict = {}
        for i in range(from_block, plan.n_resident):
            h, t = plan.hash_ids[i], plan.tiers[i]
            if t == "ssd":
                keys.append(h)
            elif t == "peer":
                node = plan.source(i)
                if node not in peer_srcs:
                    peer_srcs[node] = PeerSource(node, self.peers.get(node))
                keys.append(h)
                sources[h] = peer_srcs[node]
        if not keys:
            return None
        handle = self.prefetcher.fetch(keys, sources)
        handle.sources = sources        # finish_fetch reads failure reasons
        return handle

    def _remote_block(self, src: PeerSource, key: int):
        """Synchronous whole-block peer read (the blocking path)."""
        L = src.n_layers
        if L == 0:
            src.note_empty(key)
            return None
        ks, vs = [], []
        for layer in range(L):
            pair = src.read_layer(key, layer)
            if pair is None:
                return None
            ks.append(pair[0])
            vs.append(pair[1])
        return np.stack(ks), np.stack(vs)

    def _take_peer_block(self, i: int, h: int, kv, node) -> bool:
        """Install a VERIFIED peer block: bytes first, then metadata (the
        hierarchy never claims bytes it can't serve). Returns False when
        the local hierarchy has no room — treated as a fetch failure.
        Mirrors ``put``'s byte accounting: eviction victims free their
        bytes, and a pinned-full-DRAM insert that lands straight in the
        SSD tier writes the bytes through to the store."""
        self.data[h] = (np.asarray(kv[0]), np.asarray(kv[1]))
        evicted = self.meta.insert([h], start_pos=i)
        for e in evicted:
            self.data.pop(e, None)      # file-backed: on_drop already freed
        if h not in self.meta:
            self.data.pop(h, None)
            self._note_fallback("no_local_room")
            return False
        rt = getattr(self.meta, "resident_tier", None) \
            if self.store is not None else None
        if rt is not None and rt(h) == "ssd":
            blk = self.data.pop(h)
            if h not in self.store:     # landed straight in the SSD tier
                self.store.put(h, *blk)
        self.peer_blocks_fetched += 1
        return True

    def finish_fetch(self, plan: FetchPlan, handle=None,
                     from_block: int = 0) -> int:
        """Verify + install bytes for plan blocks [from_block:], promote
        their metadata, and return how many CONSECUTIVE blocks from
        ``from_block`` are usable. A block that fails verification is
        discarded from the hierarchy (peer blocks: the stale directory
        claim is withdrawn) and truncates the usable run — the caller
        recomputes from there, with the reason in ``fallback_reasons``
        (crash safety: stale/torn/remote-dead state degrades to
        recompute, never to wrong KV)."""
        if handle is not None:
            handle.wait()               # §5.2 wait-before-attend barrier
        h_sources = getattr(handle, "sources", None) or {}
        n_ok = 0
        local_seg: list[int] = []
        for i in range(from_block, plan.n_resident):
            h, tier = plan.hash_ids[i], plan.tiers[i]
            if tier == "dram":
                if h in self.data or self.store is None:
                    n_ok += 1
                    local_seg.append(h)
                    continue
                self.meta.discard(h)    # metadata claimed bytes we lost
                break
            if tier == "peer":
                node = plan.source(i)
                src = h_sources.get(h)
                kv = handle.result(h) if handle is not None else None
                if kv is None:
                    if src is None:
                        src = PeerSource(node, self.peers.get(node))
                    kv = self._remote_block(src, h)
                if kv is None:
                    reason = (src.reasons.get(h) if src is not None
                              else None) or "peer_fetch_failed"
                    self._note_fallback(reason)
                    self.peer_fetch_failures += 1
                    if self.directory is not None and reason in (
                            "stale_directory", "verify_failed"):
                        self.directory.unregister(h, node)  # self-heal
                    break
                if not self._take_peer_block(i, h, kv, node):
                    break
                n_ok += 1
                continue
            # tier == "ssd" — the local store path
            kv = handle.result(h) if handle is not None else None
            if kv is None and self.store is not None:
                kv = self.store.read_block(h)
            if kv is None:
                self.meta.discard(h)
                self._note_fallback("local_verify_failed")
                break
            self._inflight[h] = kv
            n_ok += 1
            local_seg.append(h)
        if local_seg:
            self.meta.touch_keys(local_seg)  # promotions consume _inflight
        self._inflight.clear()
        return n_ok

    def match_prefix(self, hash_ids: list[int]) -> int:
        if self.store is None and not self.peers:
            return self.meta.lookup(hash_ids)
        n = self.finish_fetch(self.plan_fetch(hash_ids))
        self.meta.misses += len(hash_ids) - n
        return n

    # ---- bytes ---------------------------------------------------------
    def get(self, hash_ids: list[int]):
        """Stack blocks → (L, n*512, KV, Dh) k and v."""
        ks, vs = [], []
        for h in hash_ids:
            kv = self.data.get(h)
            if kv is None and self.store is not None:
                kv = self.store.read_block(h)
            if kv is None:
                raise KeyError(f"block {h} has no readable bytes")
            ks.append(kv[0])
            vs.append(kv[1])
        return np.concatenate(ks, axis=1), np.concatenate(vs, axis=1)

    def put(self, hash_ids: list[int], k: np.ndarray, v: np.ndarray,
            start_pos: int = 0) -> None:
        """k/v: (L, n*512, KV, Dh) covering ``hash_ids`` in order."""
        evicted = self.meta.insert(hash_ids, start_pos=start_pos)
        for e in evicted:
            self.data.pop(e, None)      # file-backed: on_drop already freed
        rt = getattr(self.meta, "resident_tier", None) \
            if self.store is not None else None
        for i, h in enumerate(hash_ids):
            if h not in self.meta or h in self.data:
                continue
            sl = slice(i * BLOCK_TOKENS, (i + 1) * BLOCK_TOKENS)
            blk = (np.ascontiguousarray(k[:, sl]),
                   np.ascontiguousarray(v[:, sl]))
            if rt is not None and rt(h) == "ssd":
                if h not in self.store:  # inserted straight to the SSD tier
                    self.store.put(h, *blk)
            else:
                self.data[h] = blk

    # ---- preemption spill slab (device→host demotion of live runs) -----
    def spill_put(self, req_id: int, k: np.ndarray, v: np.ndarray,
                  n_tokens: int) -> None:
        """Park a preempted slot's exported KV run (from
        ``DevicePagePool.export_run``) until the victim restores. One
        entry per request; overwriting is a bug (the old bytes would be
        silently lost), so it raises."""
        with self._spill_lock:
            if req_id in self._spill:
                raise RuntimeError(
                    f"request {req_id} already has a spilled run — a victim "
                    f"must restore (spill_pop) before it can spill again")
            self._spill[req_id] = (k, v, n_tokens)
            self._spill_counters["spills"] += 1

    def spill_get(self, req_id: int):
        """Peek a spilled run: (k, v, n_tokens). KeyError if absent."""
        with self._spill_lock:
            return self._spill[req_id]

    def spill_pop(self, req_id: int, *, restored: bool = True) -> bool:
        """Drop a spilled run — after a successful restore (counted as
        such) or when the request is abandoned (``restored=False``).
        Returns whether an entry existed."""
        with self._spill_lock:
            had = self._spill.pop(req_id, None) is not None
            if had:
                key = "spill_restores" if restored else "spill_drops"
                self._spill_counters[key] += 1
            return had

    def spill_depth(self) -> int:
        """Number of preempted requests currently parked in the slab —
        the ``BackpressureSignal.spilled`` gauge (each is a restorable
        victim that will want device pages back)."""
        with self._spill_lock:
            return len(self._spill)

    def est_block_read_s(self) -> float:
        """Expected SSD read seconds per block (for the split search)."""
        return self.store.est_block_read_s() if self.store is not None \
            else 0.0

    @property
    def n_blocks(self) -> int:
        return len(self.data) + (len(self.store) if self.store else 0)

    def stats(self) -> dict:
        """Unified snapshot (cross-component ``stats()`` protocol: locked
        where state is shared, plain dict, stable key names): block
        residency, peer-fetch counters, fallback reasons (flattened as
        ``fallback_<reason>``), and the preemption spill slab."""
        out = dict(
            dram_blocks=len(self.data),
            store_blocks=len(self.store) if self.store else 0,
            total_blocks=self.n_blocks,
            peer_blocks_fetched=self.peer_blocks_fetched,
            peer_fetch_failures=self.peer_fetch_failures,
        )
        for reason, n in self.fallback_reasons.items():
            out[f"fallback_{reason}"] = n
        with self._spill_lock:
            out.update(self._spill_counters)
            out["spill_entries"] = len(self._spill)
            out["spill_bytes"] = sum(
                k.nbytes + v.nbytes for k, v, _ in self._spill.values())
        return out

    def close(self) -> None:
        with self._spill_lock:
            self._spill.clear()
        if self.prefetcher is not None:
            self.prefetcher.close()
        if self.store is not None:
            self.store.close()


def connect_pools(pools: list["HostKVPool"]) -> None:
    """Cross-register every pool as a peer of every other (the in-process
    stand-in for Messenger endpoints). Pools must carry distinct
    ``node_id``s and share one ``GlobalBlockDirectory``."""
    for a in pools:
        for b in pools:
            if a is not b:
                a.add_peer(b.node_id, b)


@dataclass
class PrefillResult:
    first_token: int
    kv_k: np.ndarray            # (L, S, KV, Dh) full-depth KV of the request
    kv_v: np.ndarray
    prompt_len: int
    reused_blocks: int
    new_blocks: int
    ssd_blocks: int = 0         # prefix blocks loaded off the SSD store
    peer_blocks: int = 0        # prefix blocks fetched off a PEER's pool
    overlapped: bool = False    # head recompute ∥ tail SSD load was used
    skipped_blocks: int = 0     # DRAM blocks chunk-skipped mid-head-span
    hash_ids: Optional[list] = None   # the request's prefix chain
    pages: Optional[list] = None      # staged device page run (paged substrate)
    page_pool: Optional[object] = None  # the DevicePagePool holding ``pages``
    page_gens: Optional[list] = None  # allocation generations at staging time
    _pages_adopted: bool = False      # first join takes the staging reference

    def release_pages(self) -> None:
        """Drop the staging reference of a result that will never be
        joined (e.g. the request was cancelled after prefill). The first
        ``DecodeWorker.join`` normally consumes it; calling this after a
        join is a no-op."""
        if self.pages is not None and self.page_pool is not None \
                and not self._pages_adopted:
            self.page_pool.release(self.pages)
            self._pages_adopted = True


def paged_supported(cfg: ModelConfig) -> bool:
    """The paged decode substrate covers uniform attention-only stacks;
    hybrid/SSM/encoder archs keep the dense arena. Drivers use this to
    decide whether to build a ``DevicePagePool`` at all (staging into a
    pool no decode worker will ever adopt from just leaks pages)."""
    return cfg.attention_layers == cfg.n_layers and not cfg.encoder_layers


def stage_run(pool, hash_ids: list[int], k_full: np.ndarray,
              v_full: np.ndarray, S: int,
              bank: Optional[int] = None) -> Optional[list[int]]:
    """Stage a request's KV into a ``DevicePagePool`` page run (§3 step 2:
    fresh pages written layer-stacked; step 1: registered prefix runs
    ADOPTED — the physical pages are shared with every slot on the same
    hash chain, no bytes move). Full 512-token blocks register under
    their chain hash for later requests; the partial tail gets private
    pages. On a banked (mesh-sharded) pool the whole run lives in ONE
    data-shard bank — ``bank=None`` picks the bank with the deepest
    registered prefix. The caller owns one reference per returned page.
    Returns None (nothing held) if the pool can't fit the run even after
    evicting registry-only runs."""
    if pool is None:
        return None
    if bank is None:
        bank = pool.best_stage_bank(hash_ids)
    B = BLOCK_TOKENS
    n_full = len(hash_ids)
    held: list[int] = []
    try:
        adopted, pages = pool.adopt_chain(hash_ids, bank=bank)
        held = list(pages)
        for i in range(adopted, n_full):
            run = pool.alloc(pool.pages_per_block, bank=bank)
            held += run
            pool.write_run(run, k_full[:, i * B:(i + 1) * B],
                           v_full[:, i * B:(i + 1) * B])
            pool.register_block(hash_ids[i], run)
            pages += run
        tail = S - n_full * B
        if tail > 0:
            run = pool.alloc(pool.pages_for(tail), bank=bank)
            held += run
            pool.write_run(run, k_full[:, n_full * B:S],
                           v_full[:, n_full * B:S])
            pages += run
        return pages
    except MemoryError:
        pool.release(held)
        return None
    except BaseException:
        # a non-capacity failure (bad shapes, CRC mismatch upstream, an
        # interrupt) must not strand the partially-written run: release
        # everything held so far and let the error propagate
        pool.release(held)
        raise


@dataclass
class RestorePlan:
    """Priced decision for bringing a preempted victim back onto the
    device: reload its spilled bytes through ``stage_run`` vs recompute
    the whole sequence through chunked prefill."""
    mode: str                   # "reload" | "recompute"
    est_reload_s: float
    est_recompute_s: float


def plan_restore(n_tokens: int, *, reload_s_per_block: Optional[float],
                 recompute_s_per_block: Optional[float],
                 mode: str = "auto") -> RestorePlan:
    """Price the two restore arms for a spilled run of ``n_tokens`` (the
    'Why Not Both?' discipline applied to preemption recovery: transfer
    and compute are independent resources, pick the cheaper wall-clock).
    Per-block estimates are measured EMAs — ``None`` means unwarmed, and
    an unwarmed arm loses the comparison (reload wins overall ties: the
    bytes already exist and recompute would re-burn prefill FLOPs)."""
    if mode not in ("auto", "reload", "recompute"):
        raise ValueError(f"unknown restore mode {mode!r}")
    n_blocks = -(-n_tokens // BLOCK_TOKENS)
    tl = (reload_s_per_block or 0.0) * n_blocks
    tc = (recompute_s_per_block or 0.0) * n_blocks
    if mode != "auto":
        chosen = mode
    elif recompute_s_per_block is None:
        chosen = "reload"
    elif reload_s_per_block is None:
        chosen = "recompute"
    else:
        chosen = "reload" if tl <= tc else "recompute"
    return RestorePlan(mode=chosen, est_reload_s=tl, est_recompute_s=tc)


class ChunkedPrefill:
    """A prefill suspended between device chunks — the serving loop's
    interleave unit.

    ``advance()`` runs exactly one device chunk (the first call also does
    the hashing / fetch planning / pool reads that precede it) and
    returns True once the request is complete, with the finished
    ``PrefillResult`` in ``.result``. Draining the generator in one go is
    bit-exact with the old blocking path — it IS the blocking path, which
    is why ``PrefillWorker.__call__`` is now implemented on top of this.
    """

    def __init__(self, worker: "PrefillWorker", tokens: np.ndarray,
                 session=None) -> None:
        self.worker = worker
        self.tokens = np.asarray(tokens)
        self.prompt_len = len(self.tokens)
        self.chunks_done = 0
        self.result: Optional[PrefillResult] = None
        self._gen = worker._chunks(self.tokens, session)

    @property
    def done(self) -> bool:
        return self.result is not None

    def advance(self) -> bool:
        """Run one device chunk; True once the prefill finished."""
        if self.result is not None:
            return True
        try:
            next(self._gen)
            self.chunks_done += 1
            return False
        except StopIteration as e:
            self.chunks_done += 1
            self.result = e.value
            return True

    def drain(self) -> PrefillResult:
        while not self.advance():
            pass
        return self.result


class PrefillWorker:
    """§3 steps 1–3: KVCache reuse → incremental (chunked) prefill →
    layer-wise store-back. One request at a time (B = 1).

    With a file-backed pool, ``ssd_mode`` picks how SSD-resident prefix
    blocks reach the accelerator: ``"blocking"`` loads them synchronously
    before any compute (the naive schedule); ``"overlap"`` — the
    executable ``why_not_both`` — splits the prefix per
    ``layerwise.overlap_split``, RECOMPUTING the head chunks while the
    tail streams from SSD layer-by-layer, and only then computes the
    uncached suffix. Verification failures shrink the loaded tail and the
    lost blocks are recomputed — wrong tokens are impossible.

    Every path is CHUNK-RESUMABLE: ``start()`` returns a
    ``ChunkedPrefill`` whose ``advance()`` runs one device chunk, so a
    serving loop can interleave prefill chunks between decode iterations
    (``__call__`` just drains it — the request-at-a-time oracle). Cold
    prefill runs as the same chunked incremental-extend loop from an
    empty cache, which is bit-identical to a monolithic prefill call.
    """

    def __init__(self, params, cfg: ModelConfig, pool: HostKVPool, *,
                 prefill_chunk: int = 1024, ssd_mode: str = "overlap",
                 page_pool=None) -> None:
        assert ssd_mode in ("blocking", "overlap"), ssd_mode
        self.params = params
        self.cfg = cfg
        self.pool = pool
        self.chunk = prefill_chunk
        self.ssd_mode = ssd_mode
        self.page_pool = page_pool      # shared DevicePagePool (paged handoff)
        self.hasher = PrefixHasher()
        self._extend = jax.jit(
            lambda p, t, c: decode_step(p, t, c, cfg))
        self.counters = dict(reused_blocks=0, computed_tokens=0, requests=0,
                             ssd_loaded_blocks=0, overlapped_requests=0,
                             fallback_blocks=0, peer_blocks=0,
                             skipped_blocks=0, page_oom=0, chunks=0,
                             stage_deferred=0)
        # serving-loop hook: called with the page count a stage would pin;
        # returning False skips staging (the join stages later) so staged
        # results can't eat the decode batch's reserved growth pages
        self.stage_guard = None
        self._t_block_ema: Optional[float] = None  # measured s / 512-tok blk

    def _note_compute(self, tokens: int, dt: float) -> None:
        if tokens <= 0 or dt <= 0:
            return
        per_block = dt * BLOCK_TOKENS / tokens
        self._t_block_ema = per_block if self._t_block_ema is None \
            else 0.7 * self._t_block_ema + 0.3 * per_block

    def est_chunk_s(self) -> float:
        """Measured seconds per device chunk (0.0 until warmed up) — the
        serving loop budgets interleaved chunks against the TBT slack
        with this."""
        if self._t_block_ema is None:
            return 0.0
        return self._t_block_ema * self.chunk / BLOCK_TOKENS

    def _chunk_extend(self, t, caches, lo: int, hi: int):
        """One timed device chunk (the resumable unit)."""
        t0 = time.monotonic()
        logits, caches = self._extend(self.params, t[:, lo:hi], caches)
        jax.block_until_ready(logits)
        self._note_compute(hi - lo, time.monotonic() - t0)
        self.counters["chunks"] += 1
        return logits, caches

    def _stage(self, hash_ids, k_full, v_full, S) -> Optional[list[int]]:
        if self.page_pool is not None and self.stage_guard is not None \
                and not self.stage_guard(self.page_pool.pages_for(S)):
            self.counters["stage_deferred"] += 1
            return None
        pages = stage_run(self.page_pool, hash_ids, k_full, v_full, S)
        if pages is None and self.page_pool is not None:
            self.counters["page_oom"] += 1
        return pages

    def stats(self) -> dict:
        """Unified snapshot (cross-component ``stats()`` protocol):
        lifetime counters + hasher memo effectiveness."""
        out = dict(self.counters)
        out["hash_blocks"] = self.hasher.blocks_hashed
        out["hash_memo_hits"] = self.hasher.memo_hits
        return out

    def _stage_result(self, hash_ids, k_full, v_full, S) -> dict:
        """PrefillResult kwargs for the staged page run (+ generation
        snapshot so late re-joins can detect recycled pages)."""
        pages = self._stage(hash_ids, k_full, v_full, S)
        return dict(
            hash_ids=hash_ids, pages=pages, page_pool=self.page_pool,
            page_gens=None if pages is None
            else self.page_pool.gens_of(pages))

    def start(self, tokens: np.ndarray, session=None) -> ChunkedPrefill:
        """Begin a chunk-resumable prefill (nothing runs until the first
        ``advance()``)."""
        return ChunkedPrefill(self, tokens, session=session)

    def __call__(self, tokens: np.ndarray,
                 session=None) -> PrefillResult:
        return self.start(tokens, session=session).drain()

    def _chunks(self, tokens: np.ndarray, session=None):
        """Generator behind ``ChunkedPrefill``: yields between device
        chunks; its StopIteration value is the ``PrefillResult``."""
        cfg = self.cfg
        assert cfg.attention_layers == cfg.n_layers, \
            "PrefillWorker KV path supports uniform attention stacks"
        S = len(tokens)
        hash_ids = self.hasher.hash_ids(tokens, session=session)

        if self.ssd_mode == "overlap" and self.pool.prefetcher is not None:
            plan = self.pool.plan_fetch(hash_ids)
            n_res = plan.n_resident
            if n_res * BLOCK_TOKENS >= S:    # full hit: keep a tail to
                n_res = max((S - 1) // BLOCK_TOKENS, 0)  # recompute logits
            plan = plan.truncate(n_res)
            if plan.has_ssd or plan.has_remote:
                result = yield from self._chunks_overlapped(
                    tokens, hash_ids, plan)
                return result

        # blocking path: flat pool, legacy tiered pool, or synchronous
        # file-backed/peer loads (ssd_mode="blocking")
        peer0 = self.pool.peer_blocks_fetched
        n_hit = self.pool.match_prefix(hash_ids)
        prefix_tokens = n_hit * BLOCK_TOKENS
        if prefix_tokens >= S:           # full hit: recompute last block's
            n_hit = max((S - 1) // BLOCK_TOKENS, 0)  # tail to get logits
            prefix_tokens = n_hit * BLOCK_TOKENS

        t = jnp.asarray(tokens[None, :], jnp.int32)
        caches = init_caches(cfg, 1, S)
        caches = caches._replace(length=jnp.asarray(0, jnp.int32))
        if n_hit:
            k_np, v_np = self.pool.get(hash_ids[:n_hit])
            kv = KVCache(
                k=caches.kv.k.at[:, 0, :prefix_tokens].set(jnp.asarray(k_np)),
                v=caches.kv.v.at[:, 0, :prefix_tokens].set(jnp.asarray(v_np)))
            caches = caches._replace(kv=kv,
                                     length=jnp.asarray(prefix_tokens, jnp.int32))
        # chunked incremental prefill over the uncached suffix (a cold
        # request is just the n_hit=0 case: extending an empty cache chunk
        # by chunk is bit-identical to a monolithic prefill)
        logits = None
        for lo in range(prefix_tokens, S, self.chunk):
            hi = min(lo + self.chunk, S)
            logits, caches = self._chunk_extend(t, caches, lo, hi)
            if hi < S:
                yield               # suspension point for the serving loop
        first = int(jnp.argmax(logits[0, -1]))
        k_full = np.asarray(caches.kv.k[:, 0])
        v_full = np.asarray(caches.kv.v[:, 0])

        # layer-wise store-back of every fresh full block (§5.2: on TPU the
        # per-layer store launches as soon as that layer's KV exists; here
        # the ordering contract is preserved by storing from the scanned
        # per-layer stack)
        n_total = len(hash_ids)
        if n_total > n_hit:
            sl = slice(n_hit * BLOCK_TOKENS, n_total * BLOCK_TOKENS)
            self.pool.put(hash_ids[n_hit:], k_full[:, sl], v_full[:, sl],
                          start_pos=n_hit)
        n_peer = self.pool.peer_blocks_fetched - peer0
        self.counters["reused_blocks"] += n_hit
        self.counters["computed_tokens"] += S - prefix_tokens
        self.counters["requests"] += 1
        self.counters["peer_blocks"] += n_peer
        return PrefillResult(first_token=first, kv_k=k_full, kv_v=v_full,
                             prompt_len=S, reused_blocks=n_hit,
                             new_blocks=n_total - n_hit, peer_blocks=n_peer,
                             **self._stage_result(hash_ids, k_full, v_full, S))

    def _chunks_overlapped(self, tokens: np.ndarray, hash_ids: list[int],
                           plan: FetchPlan):
        """Head recompute ∥ tail SSD load (§5.2 / Jin et al., executable),
        as a chunk-resumable generator.

        Timeline: pick split s via ``overlap_split``; blocks [0, d0) come
        from DRAM free; launch async layer-wise loads of blocks [s, n);
        recompute chunks over [d0·B, s·B) while they stream; barrier; set
        the loaded tail into the cache arena; compute the uncached suffix.
        """
        from repro.serving.layerwise import overlap_split
        B = BLOCK_TOKENS
        cfg = self.cfg
        S = len(tokens)
        n = plan.n_resident
        peer0 = self.pool.peer_blocks_fetched
        tl = self.pool.est_block_read_s()
        tc = self._t_block_ema if self._t_block_ema is not None else tl
        # peer blocks are loads for the split search (the local read EMA
        # is the available per-block load estimate; the network hop of an
        # in-process peer is free, so it errs mildly toward recompute)
        ov = overlap_split(["dram" if t == "dram" else "ssd"
                            for t in plan.tiers], tc, tl)
        s, d0 = ov.split, ov.dram_head
        handle = self.pool.start_prefetch(plan, from_block=s)
        if d0:
            self.pool.meta.touch_keys(hash_ids[:d0])

        t = jnp.asarray(tokens[None, :], jnp.int32)
        caches = init_caches(cfg, 1, S)
        caches = caches._replace(length=jnp.asarray(0, jnp.int32))
        pos = 0
        if d0:
            k_np, v_np = self.pool.get(hash_ids[:d0])
            kv = KVCache(
                k=caches.kv.k.at[:, 0, :d0 * B].set(jnp.asarray(k_np)),
                v=caches.kv.v.at[:, 0, :d0 * B].set(jnp.asarray(v_np)))
            caches = caches._replace(kv=kv,
                                     length=jnp.asarray(d0 * B, jnp.int32))
            pos = d0 * B

        # head assembly, overlapping the prefetch thread's layer loads:
        # DRAM blocks interleaved inside [d0, s) are chunk-SKIPPED — their
        # KV is set straight into the arena from the pool — and only the
        # non-resident runs between them recompute (incremental prefill
        # resumes after each assembled run, so attention still sees every
        # prior token). Every recompute chunk is a suspension point; the
        # suffix below is guaranteed non-empty, so yielding after each
        # head chunk never strands the result.
        i = d0
        while i < s:
            if plan.tiers[i] == "dram":
                j = i
                while j < s and plan.tiers[j] == "dram":
                    j += 1
                k_np, v_np = self.pool.get(hash_ids[i:j])
                self.pool.meta.touch_keys(hash_ids[i:j])
                kv = caches.kv
                kv = KVCache(
                    k=kv.k.at[:, 0, i * B:j * B].set(jnp.asarray(k_np)),
                    v=kv.v.at[:, 0, i * B:j * B].set(jnp.asarray(v_np)))
                caches = caches._replace(
                    kv=kv, length=jnp.asarray(j * B, jnp.int32))
            else:
                j = i
                while j < s and plan.tiers[j] != "dram":
                    j += 1
                for lo in range(i * B, j * B, self.chunk):
                    hi = min(lo + self.chunk, j * B)
                    _, caches = self._chunk_extend(t, caches, lo, hi)
                    yield
            i = j
        n_skip = ov.head_skipped

        # §5.2 barrier: verify + install the loaded tail
        n_tail = self.pool.finish_fetch(plan, handle, from_block=s)
        usable = s + n_tail
        if n_tail:
            k_np, v_np = self.pool.get(hash_ids[s:usable])
            kv = caches.kv
            kv = KVCache(
                k=kv.k.at[:, 0, s * B:usable * B].set(jnp.asarray(k_np)),
                v=kv.v.at[:, 0, s * B:usable * B].set(jnp.asarray(v_np)))
            caches = caches._replace(kv=kv,
                                     length=jnp.asarray(usable * B, jnp.int32))

        # uncached suffix (+ any blocks lost to verification failures).
        # Always non-empty — the caller truncates full-hit plans so that
        # n_resident·B < S — which guarantees the logits below come from
        # position S-1 even when the head walk ended in a DRAM assembly.
        assert usable * B < S, (usable, S)
        logits = None
        for lo in range(usable * B, S, self.chunk):
            hi = min(lo + self.chunk, S)
            logits, caches = self._chunk_extend(t, caches, lo, hi)
            if hi < S:
                yield
        first = int(jnp.argmax(logits[0, -1]))
        k_full = np.asarray(caches.kv.k[:, 0])
        v_full = np.asarray(caches.kv.v[:, 0])

        # store-back: the RECOMPUTED head runs (chunk-skipped DRAM blocks
        # are already pool-resident) and the fresh suffix blocks
        n_total = len(hash_ids)
        i = d0
        while i < s:
            if plan.tiers[i] == "dram":
                i += 1
                continue
            j = i
            while j < s and plan.tiers[j] != "dram":
                j += 1
            sl = slice(i * B, j * B)
            self.pool.put(hash_ids[i:j], k_full[:, sl], v_full[:, sl],
                          start_pos=i)
            i = j
        if n_total > usable:
            sl = slice(usable * B, n_total * B)
            self.pool.put(hash_ids[usable:n_total], k_full[:, sl],
                          v_full[:, sl], start_pos=usable)

        reused = d0 + n_skip + n_tail
        n_peer = self.pool.peer_blocks_fetched - peer0
        self.counters["reused_blocks"] += reused
        self.counters["computed_tokens"] += S - reused * B
        self.counters["requests"] += 1
        self.counters["ssd_loaded_blocks"] += n_tail
        self.counters["overlapped_requests"] += 1
        self.counters["fallback_blocks"] += n - usable
        self.counters["peer_blocks"] += n_peer
        self.counters["skipped_blocks"] += n_skip
        return PrefillResult(first_token=first, kv_k=k_full, kv_v=v_full,
                             prompt_len=S, reused_blocks=reused,
                             new_blocks=len(hash_ids) - reused,
                             ssd_blocks=n_tail, peer_blocks=n_peer,
                             overlapped=True, skipped_blocks=n_skip,
                             **self._stage_result(hash_ids, k_full, v_full, S))


@dataclass
class _Slot:
    """One occupied decode-batch slot. ``prompt_len`` is the KV depth the
    slot JOINED at (after a preemption restore that includes previously
    decoded tokens); ``final_len`` is the depth it will have grown to at
    completion — the growth-reservation bound, invariant across
    preempt/restore cycles."""
    request: ServingRequest
    prompt_len: int
    final_len: int
    emitted: list = field(default_factory=list)

    @property
    def req_id(self) -> int:
        return self.request.req_id

    @property
    def max_new(self) -> int:
        return self.request.max_new

    @property
    def done(self) -> bool:
        return len(self.emitted) >= self.request.max_new


@dataclass
class PreemptedRun:
    """A victim slot's full decode state after ``DecodeWorker.preempt``:
    the exported KV bytes (ownership transferred out of the device pool)
    plus everything ``join(..., resume_emitted=...)`` needs to resume the
    stream bit-exactly. ``n_tokens`` = prompt + all-but-the-last emitted
    token (the pending input's KV was never written)."""
    request: ServingRequest
    emitted: list
    n_tokens: int
    k: np.ndarray               # (L, n_tokens, KV, Dh) host copies
    v: np.ndarray


def _pow2_ceil(n: int) -> int:
    w = 1
    while w < n:
        w *= 2
    return w


def plan_width_buckets(needs: list[int], max_pages: int,
                       max_buckets: int = 1) -> list[int]:
    """Block-table widths (descending) for one decode step over slots
    needing ``needs`` pages each. Every width is a power of two capped at
    ``max_pages`` (so the jitted step sees at most log2(max_pages) table
    shapes per bucket count); with ``max_buckets=1`` the single width is
    exactly the historical global padding (deepest slot, pow2). More
    buckets keep the top distinct widths and merge shallower slots into
    the smallest kept — a shallow slot in a deep batch then attends a
    short table instead of padding to the deepest slot's width."""
    widths = sorted({min(_pow2_ceil(n), max_pages) for n in needs},
                    reverse=True)
    return widths[:max(max_buckets, 1)] or [1]


def bucket_width(need: int, plan: list[int], max_pages: int) -> int:
    """Smallest plan width covering ``need`` pages (plan from
    ``plan_width_buckets``; its head always covers the deepest slot)."""
    n2 = min(_pow2_ceil(need), max_pages)
    for w in reversed(plan):
        if w >= n2:
            return w
    return plan[0]


class DecodeWorker:
    """§3 step 4: continuous batching with per-slot cache depths.

    Two substrates share the slot/iteration machinery:

    * ``substrate="paged"`` (default): slots attend a block-table paged
      KV store (``DevicePagePool`` — shared with the prefill worker(s),
      the process stand-in for a node's HBM). ``join()`` ADOPTS the
      request's staged page run into the slot's block table — a host-side
      list splice, no full-depth device copy — and slots whose chains
      share a prefix share physical pages (refcounted; copy-on-write if a
      slot must append into a shared partial tail page). ``step()`` runs
      ``paged_decode_attention`` per layer over the live page span (the
      table is sliced to the deepest active slot, padded to a power of
      two to bound recompiles) instead of dense attention over
      ``max_len``.
    * ``substrate="dense"``: the original (L, B, max_len) arena — kept as
      the bit-exactness oracle and for archs the paged path doesn't cover
      (hybrid/SSM/encoder stacks).
    """

    def __init__(self, params, cfg: ModelConfig, *, max_batch: int,
                 max_len: int, substrate: str = "paged",
                 page_pool=None, page_tokens: int = 64,
                 use_pallas: bool = False, mesh=None,
                 width_buckets: int = 1) -> None:
        if substrate == "paged" and not paged_supported(cfg):
            substrate = "dense"     # non-uniform stacks keep the arena
        assert substrate in ("paged", "dense"), substrate
        self.params = params
        self.cfg = cfg
        self.max_batch = max_batch
        self.max_len = max_len
        self.substrate = substrate
        self.width_buckets = max(int(width_buckets), 1)
        self.slots: list[Optional[_Slot]] = [None] * max_batch
        self.tokens = jnp.zeros((max_batch, 1), jnp.int32)
        self.counters = dict(zero_copy_joins=0, staged_joins=0, steps=0,
                             preemptions=0, resumed_joins=0,
                             bucket_substeps=0)
        if substrate == "paged":
            from repro.serving.paged_cache import DevicePagePool
            if page_pool is not None:
                if mesh is not None and mesh is not page_pool.mesh:
                    raise ValueError(
                        "mesh= disagrees with page_pool.mesh — the pool's "
                        "banking fixes the decode mesh; pass one of them")
                mesh = page_pool.mesh
            d = 1 if mesh is None else int(mesh.shape.get("data", 1))
            if max_batch % d:
                raise ValueError(
                    f"max_batch={max_batch} must divide over the mesh's "
                    f"data axis ({d}) — slots partition into per-shard "
                    f"row groups")
            if mesh is not None:
                m = int(mesh.shape.get("model", 1))
                reason = paged_shard_reason(cfg, m, d)
                if reason:
                    raise ValueError(
                        f"cannot shard paged decode over {d}x{m}: {reason}")
            if page_pool is None:
                # standalone sizing (per bank): every slot of the bank at
                # full depth + one extra sequence of staging headroom
                # (registry runs are evictable on top, so this bound
                # holds under sharing too)
                per_seq = (max_len + page_tokens - 1) // page_tokens
                page_pool = DevicePagePool(
                    cfg, n_pages=1 + (max_batch // d + 1) * per_seq,
                    page_tokens=page_tokens, mesh=mesh)
            self.page_pool = page_pool
            self.mesh = mesh
            self.slots_per_bank = max_batch // page_pool.n_banks
            if self.width_buckets > 1 and mesh is not None:
                raise ValueError(
                    "width_buckets>1 sub-batches the step, which breaks "
                    "the mesh's even data-axis row split — pick one")
            pt = page_pool.page_tokens
            self.max_pages = (max_len + pt - 1) // pt
            self.block_table = np.zeros((max_batch, self.max_pages), np.int32)
            self.seq_lens = np.zeros(max_batch, np.int32)
            self.n_pages_slot = np.zeros(max_batch, np.int32)
            self.caches = None
            if mesh is None:
                self._step_paged = jax.jit(
                    lambda p, t, kp, vp, tbl, lens: decode_step_paged(
                        p, t, kp, vp, tbl, lens, cfg, use_pallas=use_pallas))
            else:
                self._step_paged = jax.jit(
                    lambda p, t, kp, vp, tbl, lens:
                    decode_step_paged_sharded(
                        p, t, kp, vp, tbl, lens, cfg, mesh,
                        use_pallas=use_pallas))
        else:
            self.page_pool = None
            self.mesh = None
            self.caches = init_caches(cfg, max_batch, max_len)
            self.caches = self.caches._replace(
                length=jnp.zeros((max_batch,), jnp.int32))
            self._step = jax.jit(lambda p, t, c: decode_step(p, t, c, cfg))

    @property
    def n_active(self) -> int:
        return sum(s is not None for s in self.slots)

    @property
    def free_slots(self) -> int:
        return sum(s is None for s in self.slots)

    @property
    def has_free_slot(self) -> bool:
        return any(s is None for s in self.slots)

    def reserved_growth_pages(self) -> int:
        """Worst-case device pages the active slots may still allocate:
        growth to ``prompt_len + max_new`` plus one copy-on-write of a
        shared tail page each. Admission must keep this many pages
        obtainable (free + evictable) or a mid-decode ``alloc`` can OOM
        a step — pages pinned by not-yet-joined prefills don't release
        themselves."""
        if self.substrate != "paged":
            return 0
        pt = self.page_pool.page_tokens
        need = 0
        for i, s in enumerate(self.slots):
            if s is None:
                continue
            held = int(self.n_pages_slot[i])
            need += max(-(-s.final_len // pt) - held, 0) + 1
        return need

    # ---- paged-substrate plumbing --------------------------------------
    def _slot_bank(self, slot: int) -> int:
        """Data-shard bank of a batch slot: slots partition into
        contiguous per-bank row groups so the mesh step's ``P('data')``
        row split lands each group on the shard holding its pages."""
        return slot // self.slots_per_bank

    def _pick_slot(self, pref_bank: Optional[int]) -> int:
        """Free slot for a join: the staged run's own bank when it has
        room (zero-copy adoption needs slot bank == page bank), else the
        bank with the most free slots (load-balances the data shards).
        Single-bank pools degrade to ``slots.index(None)``."""
        free_by_bank: dict[int, int] = {}
        for i, s in enumerate(self.slots):
            if s is None:
                free_by_bank.setdefault(self._slot_bank(i), i)
        if pref_bank is not None and pref_bank in free_by_bank:
            return free_by_bank[pref_bank]
        counts = {b: sum(1 for i, s in enumerate(self.slots)
                         if s is None and self._slot_bank(i) == b)
                  for b in free_by_bank}
        bank = max(counts, key=lambda b: (counts[b], -b))
        return free_by_bank[bank]

    def _adopt_pages(self, pres: PrefillResult, bank: int = 0) -> list[int]:
        """Take a reference on the request's page run: zero-copy when the
        prefill staged into OUR pool's target bank (first join consumes
        the staging reference; later joins of the same result share the
        run — n-best/beam fan-out), else stage a copy from the dense KV.
        A run staged into a DIFFERENT bank re-stages into ``bank`` (the
        slot's data shard can only attend its own bank) and this join
        consumes the staging reference — the copy is the handoff."""
        pp = self.page_pool
        same_bank = (pres.pages is not None and pres.page_pool is pp
                     and (not pres.pages
                          or pp.bank_of(pres.pages[0]) == bank))
        if same_bank:
            pages = list(pres.pages)
            if pres._pages_adopted:
                # late share (n-best): the staging reference is gone, so the
                # run is only alive through earlier joiners — verify no page
                # was freed + recycled in between (never retain someone
                # else's KV)
                if pp.gens_of(pages) != pres.page_gens:
                    raise RuntimeError(
                        "stale page run: this PrefillResult's pages were "
                        "released (its joined slots finished) and re-used; "
                        "re-prefill instead of re-joining")
                pp.retain(pages)
            else:
                pres._pages_adopted = True
            self.counters["zero_copy_joins"] += 1
            return pages
        hash_ids = pres.hash_ids if pres.hash_ids is not None else []
        pages = stage_run(pp, hash_ids, pres.kv_k, pres.kv_v,
                          pres.prompt_len, bank=bank)
        if pages is None:
            raise MemoryError("device page pool cannot hold the request")
        if pres.page_pool is pp:
            pres.release_pages()    # cross-bank copy consumes the staging ref
        self.counters["staged_joins"] += 1
        return pages

    def _free_slot_pages(self, slot: int) -> None:
        n = int(self.n_pages_slot[slot])
        self.page_pool.release([int(p) for p in self.block_table[slot, :n]])
        self.block_table[slot] = 0
        self.seq_lens[slot] = 0
        self.n_pages_slot[slot] = 0

    def join(self, request, pres: PrefillResult = None,
             max_new: Optional[int] = None, *,
             resume_emitted: Optional[list] = None) -> int:
        """Add a prefilled request to the continuous batch (§3: 'load the
        KVCache and add the request to the continuous batching process').
        Paged substrate: adoption of the staged page run — no dense
        full-depth copy.

        ``request`` is a ``ServingRequest`` (the legacy positional
        ``join(req_id, pres, max_new)`` still works behind a
        ``DeprecationWarning``). ``resume_emitted`` re-joins a preempted
        victim: ``pres`` then wraps the restored KV run (depth =
        ``PreemptedRun.n_tokens``), the stream continues from
        ``resume_emitted[-1]``, and the slot's completion bound stays
        exactly what it was before preemption."""
        if not isinstance(request, ServingRequest):
            warnings.warn(
                "DecodeWorker.join(req_id, pres, max_new) is deprecated; "
                "pass a ServingRequest", DeprecationWarning, stacklevel=2)
            request = ServingRequest(req_id=int(request), tokens=None,
                                     max_new=int(max_new))
        elif max_new is not None and max_new != request.max_new:
            raise ValueError(
                f"max_new={max_new} conflicts with request.max_new="
                f"{request.max_new}; drop the argument")
        if not self.has_free_slot:
            # NOT StopIteration (a bare next() here): inside a driver
            # generator that would be swallowed as silent termination
            raise RuntimeError(
                f"decode batch full: all {self.max_batch} slots occupied — "
                f"check has_free_slot before join")
        if self.substrate == "paged" and self.page_pool.n_banks > 1:
            pref = None
            if pres.pages and pres.page_pool is self.page_pool:
                pref = self.page_pool.bank_of(pres.pages[0])
            slot = self._pick_slot(pref)
        else:
            slot = self.slots.index(None)
        L = pres.prompt_len
        n_emit = 0
        if resume_emitted is not None:
            n_emit = len(resume_emitted)
            if not 1 <= n_emit < request.max_new:
                raise ValueError(
                    f"resume_emitted carries {n_emit} tokens; a resumable "
                    f"victim has emitted at least 1 and fewer than "
                    f"max_new={request.max_new}")
        # depth this slot reaches at completion; for a resume this equals
        # the ORIGINAL prompt_len + max_new (the victim's bound does not
        # drift across preempt/restore cycles)
        final_len = L + request.max_new - max(n_emit - 1, 0)
        # both substrates: an overlong request must fail loudly up front.
        # The dense arena's .at[].set past max_len is silently DROPPED on
        # CPU (jax out-of-bounds update semantics), which decodes wrong
        # tokens instead of erroring; the paged table would outgrow
        # max_pages mid-decode.
        if final_len > self.max_len:
            raise ValueError(
                f"prompt ({L}) + remaining new tokens exceeds max_len "
                f"({self.max_len}) — the slot would outgrow its KV capacity "
                f"mid-decode")
        if self.substrate == "paged":
            pages = self._adopt_pages(pres, bank=self._slot_bank(slot))
            assert len(pages) <= self.max_pages, \
                f"prompt needs {len(pages)} pages > max_len's {self.max_pages}"
            self.block_table[slot, :len(pages)] = pages
            self.block_table[slot, len(pages):] = 0
            self.n_pages_slot[slot] = len(pages)
            self.seq_lens[slot] = L
        else:
            if self.caches.kv is not None:
                kv = self.caches.kv
                kv = KVCache(
                    k=kv.k.at[:, slot, :L].set(jnp.asarray(pres.kv_k[:, :L])),
                    v=kv.v.at[:, slot, :L].set(jnp.asarray(pres.kv_v[:, :L])))
                self.caches = self.caches._replace(kv=kv)
            self.caches = self.caches._replace(
                length=self.caches.length.at[slot].set(L))
        if resume_emitted is not None:
            # continue the stream from the victim's own last token (for a
            # recompute restore pres.first_token is the re-derived argmax —
            # identical when the prefill is bit-exact, but the victim's
            # emitted history is the ground truth either way)
            first = int(resume_emitted[-1])
            emitted = list(resume_emitted)
            self.counters["resumed_joins"] += 1
        else:
            first = pres.first_token
            emitted = [pres.first_token]
        self.tokens = self.tokens.at[slot, 0].set(first)
        self.slots[slot] = _Slot(request=request, prompt_len=L,
                                 final_len=final_len, emitted=emitted)
        return slot

    def preempt(self, slot: int) -> PreemptedRun:
        """Victim-evict an active slot (vLLM-style preemption, the
        device→host demotion rung): export its live page run to host
        bytes via ``export_run`` — ownership of the device pages
        transfers into the returned ``PreemptedRun`` — and free the
        slot. Registered prefix blocks the run adopted stay in the
        registry (the export releases only this slot's references), so a
        reload restore re-adopts them without moving bytes. Paged
        substrate only: the dense arena has no per-slot pages to
        reclaim."""
        if self.substrate != "paged":
            raise RuntimeError(
                "preempt() requires the paged substrate — the dense arena "
                "frees no reclaimable device pages")
        s = self.slots[slot]
        if s is None:
            raise ValueError(f"preempt of empty slot {slot}")
        n_tokens = int(self.seq_lens[slot])
        n = int(self.n_pages_slot[slot])
        pages = [int(p) for p in self.block_table[slot, :n]]
        k, v = self.page_pool.export_run(pages, n_tokens)
        self.block_table[slot] = 0
        self.seq_lens[slot] = 0
        self.n_pages_slot[slot] = 0
        self.slots[slot] = None
        self.counters["preemptions"] += 1
        return PreemptedRun(request=s.request, emitted=list(s.emitted),
                            n_tokens=n_tokens, k=k, v=v)

    def stats(self) -> dict:
        """Unified snapshot (cross-component ``stats()`` protocol):
        lifetime counters + live batch gauges."""
        out = dict(self.counters)
        out["active_slots"] = self.n_active
        out["reserved_growth_pages"] = self.reserved_growth_pages()
        return out

    def _prepare_writes(self, active: list[int]) -> None:
        """Host-side bookkeeping before a step: give every active slot an
        exclusively-owned page at its write position — a fresh page at a
        page boundary, copy-on-write if the tail page is shared."""
        pp = self.page_pool
        pt = pp.page_tokens
        for i in active:
            pidx = int(self.seq_lens[i]) // pt
            if pidx >= self.max_pages:   # join() bounds L+max_new, so this
                raise RuntimeError(      # is a programming error, not load
                    f"slot {i} outgrew its block table (len "
                    f"{int(self.seq_lens[i])} of max_len {self.max_len})")
            if pidx == int(self.n_pages_slot[i]):
                (pg,) = pp.alloc(1, bank=self._slot_bank(i))
                self.block_table[i, pidx] = pg
                self.n_pages_slot[i] += 1
            else:
                pid = int(self.block_table[i, pidx])
                new = pp.make_writable(pid)
                if new != pid:
                    self.block_table[i, pidx] = new

    def _step_full(self, active: list[int]) -> jax.Array:
        """Single-width full-batch step (the historical path; also the
        only mesh path — the sharded step takes the whole batch so its
        ``P('data')`` row split stays even). Returns per-slot next
        tokens (B,) int32."""
        pp = self.page_pool
        pt = pp.page_tokens
        # live page span: deepest active slot, padded to a power of two
        # so the jitted step sees at most log2(max_pages) shapes
        need = max(int(self.seq_lens[i]) // pt + 1 for i in active)
        width = min(_pow2_ceil(need), self.max_pages)
        if self.mesh is None:
            # .copy(): jax CPU zero-copies 2-D numpy buffers, and the host
            # tables mutate (growth/COW/length bumps) while the async step
            # still reads them — hand jit a frozen snapshot
            tbl = jnp.asarray(self.block_table[:, :width].copy())
        else:
            # the sharded step wants BANK-LOCAL page ids: each data shard
            # indexes its own slab stripe (the % makes a fresh array, so
            # no host buffer aliases into the async step)
            tbl = jnp.asarray(self.block_table[:, :width] % pp.bank_pages)
        lens = jnp.asarray(self.seq_lens.copy())
        logits, kp, vp = self._step_paged(
            self.params, self.tokens, pp.k_pages, pp.v_pages, tbl, lens)
        pp.k_pages, pp.v_pages = kp, vp
        return jnp.argmax(logits[:, -1], axis=-1).astype(jnp.int32)

    def _step_bucketed(self, active: list[int]) -> jax.Array:
        """Width-bucketed step: group active slots by the pow2 table
        width they need (``plan_width_buckets``) and run one jitted
        sub-batch per bucket, so a shallow slot in a deep batch attends a
        short table instead of padding to the deepest slot's width.
        Bit-exact with ``_step_full`` — every row's computation is
        row-local, and sub-batch rows pad to a power of two with null
        rows (len 0, table 0), which behave exactly like the full-batch
        path's inactive slots. Buckets run sequentially, threading the
        page slabs through (their KV writes touch disjoint pages).
        Returns per-slot next tokens (B,) int32 (0 for inactive slots —
        same as don't-care argmax noise in the full path)."""
        pp = self.page_pool
        pt = pp.page_tokens
        needs = {i: int(self.seq_lens[i]) // pt + 1 for i in active}
        plan = plan_width_buckets(list(needs.values()), self.max_pages,
                                  self.width_buckets)
        kp, vp = pp.k_pages, pp.v_pages
        toks_host = np.asarray(self.tokens)
        nxt = np.zeros(self.max_batch, np.int32)
        for w in plan:
            rows = [i for i in active
                    if bucket_width(needs[i], plan, self.max_pages) == w]
            if not rows:
                continue
            nr = _pow2_ceil(len(rows))
            # fancy-indexed gathers below are fresh arrays (never views of
            # the mutating host tables), safe to hand the async step
            toks = np.zeros((nr, 1), np.int32)
            toks[:len(rows)] = toks_host[rows]
            tbl = np.zeros((nr, w), np.int32)
            tbl[:len(rows)] = self.block_table[rows][:, :w]
            lens = np.zeros(nr, np.int32)
            lens[:len(rows)] = self.seq_lens[rows]
            logits, kp, vp = self._step_paged(
                self.params, jnp.asarray(toks), kp, vp,
                jnp.asarray(tbl), jnp.asarray(lens))
            sub = np.asarray(jnp.argmax(logits[:, -1], axis=-1))
            for j, i in enumerate(rows):
                nxt[i] = int(sub[j])
            self.counters["bucket_substeps"] += 1
        pp.k_pages, pp.v_pages = kp, vp
        return jnp.asarray(nxt)

    def step(self) -> list[tuple[int, int, bool]]:
        """One continuous-batching iteration.
        Returns [(req_id, token, finished)] for active slots."""
        if self.n_active == 0:
            return []
        self.counters["steps"] += 1
        if self.substrate == "paged":
            active = [i for i, s in enumerate(self.slots) if s is not None]
            self._prepare_writes(active)
            if self.width_buckets > 1:
                nxt = self._step_bucketed(active)
            else:
                nxt = self._step_full(active)
            for i in active:
                self.seq_lens[i] += 1
        else:
            logits, self.caches = self._step(self.params, self.tokens,
                                             self.caches)
            nxt = jnp.argmax(logits[:, -1], axis=-1).astype(jnp.int32)
        self.tokens = nxt[:, None]
        out = []
        for i, s in enumerate(self.slots):
            if s is None:
                continue
            tok = int(nxt[i])
            s.emitted.append(tok)
            if s.done:
                out.append((s.req_id, tok, True))
                self.slots[i] = None
                if self.substrate == "paged":
                    self._free_slot_pages(i)
                else:
                    self.caches = self.caches._replace(
                        length=self.caches.length.at[i].set(0))
            else:
                out.append((s.req_id, tok, False))
        return out


class StateCheckpointWorker:
    """Prefix caching for SSM architectures (DESIGN.md §Arch-applicability).

    Attention-free models have no append-only KVCache; Mooncake's
    prefix-reuse degenerates to *state checkpointing*: after every
    512-token block boundary we snapshot the (constant-size) recurrent
    state keyed by the same prefix-chained hash. A later request sharing
    a prefix restores the DEEPEST checkpoint on its chain and prefills
    only the suffix — transfer cost is O(state), independent of prefix
    length, which strengthens disaggregation for these archs.
    """

    def __init__(self, params, cfg: ModelConfig, *,
                 capacity_checkpoints: Optional[int] = None,
                 chunk: int = BLOCK_TOKENS) -> None:
        from repro.core.cache import StateCache
        assert cfg.kind == "ssm", "state checkpointing is the SSM path"
        self.params = params
        self.cfg = cfg
        self.chunk = chunk
        self.meta = StateCache(capacity_checkpoints)
        self.data: dict[int, tuple] = {}   # hash -> (ssm np, conv np)
        self._prefill = jax.jit(lambda p, t: prefill(p, t, cfg))
        self._extend = jax.jit(lambda p, t, c: decode_step(p, t, c, cfg))
        self.counters = dict(restored_tokens=0, computed_tokens=0)

    def _snapshot(self, hash_id: int, caches: Caches) -> None:
        evicted = self.meta.insert([hash_id])
        for e in evicted:
            self.data.pop(e, None)
        if hash_id in self.meta:
            self.data[hash_id] = (
                np.asarray(caches.ssm.ssm), np.asarray(caches.ssm.conv))

    def __call__(self, tokens: np.ndarray):
        """Prefill one request (B = 1) with state-checkpoint reuse.
        Returns (first_token, final Caches)."""
        cfg = self.cfg
        S = len(tokens)
        hash_ids = prefix_hash_ids(tokens, self.chunk)
        depth = self.meta.lookup(hash_ids)          # deepest checkpoint
        start = depth * self.chunk
        if start >= S:                              # full hit: redo last blk
            depth -= 1
            start = depth * self.chunk
        t = jnp.asarray(tokens[None, :], jnp.int32)

        if depth > 0:
            ssm_np, conv_np = self.data[hash_ids[depth - 1]]
            from repro.models.mamba import MambaState
            caches = Caches(
                kv=None, enc_kv=None,
                ssm=MambaState(ssm=jnp.asarray(ssm_np),
                               conv=jnp.asarray(conv_np)),
                length=jnp.asarray(start, jnp.int32))
            logits = None
        else:
            caches = None
            logits = None

        # chunked continuation, snapshotting at every block boundary
        lo = start
        while lo < S:
            hi = min(lo + self.chunk, S)
            if caches is None:
                logits, caches = self._prefill(self.params, t[:, :hi])
                logits = logits[:, None] if logits.ndim == 2 else logits
            else:
                logits, caches = self._extend(self.params, t[:, lo:hi],
                                              caches)
            if hi % self.chunk == 0:
                self._snapshot(hash_ids[hi // self.chunk - 1], caches)
            lo = hi
        self.counters["restored_tokens"] += start
        self.counters["computed_tokens"] += S - start
        first = int(jnp.argmax(logits[0, -1]))
        return first, caches

    def stats(self) -> dict:
        """Unified snapshot (cross-component ``stats()`` protocol)."""
        out = dict(self.counters)
        out["checkpoints"] = len(self.data)
        return out
