"""Data pipeline: deterministic synthetic token streams for training and
serving-trace token realisation.

The paper's experiments use a dummy model on replayed traces (no real
text), so the pipeline's job is structural: produce shard-able batches of
the right shape with a reproducible RNG, plus token realisations of trace
requests whose PREFIX STRUCTURE matches the trace's hash chains (equal
hash ids ⇒ equal token blocks — so engine-level prefix caching behaves
exactly as the trace says it should).
"""
from __future__ import annotations

from dataclasses import dataclass
from typing import Iterator, Optional

import numpy as np

from repro.configs.base import ModelConfig
from repro.core.trace import BLOCK_TOKENS, Request


@dataclass
class BatchSpec:
    batch: int
    seq: int
    vocab: int
    frontend: str = "none"      # none | patch | audio
    frontend_tokens: int = 0
    d_model: int = 0


class SyntheticLM:
    """Deterministic, seekable synthetic token stream.

    Tokens follow a skewed unigram distribution with short-range structure
    (a degree-2 Markov mix) so the training loss has signal to descend —
    a pure-uniform stream trains to log(V) and nothing moves.
    """

    def __init__(self, spec: BatchSpec, seed: int = 0) -> None:
        self.spec = spec
        self.seed = seed
        rng = np.random.default_rng(seed)
        v = spec.vocab
        self._uni = rng.zipf(1.3, size=4 * v) % v   # skewed unigram pool
        self._shift = rng.integers(1, v, size=64)

    def batch(self, step: int) -> dict:
        """One training batch; labels are next-token shifted."""
        spec = self.spec
        rng = np.random.default_rng((self.seed, step))
        pool = self._uni
        base = pool[rng.integers(0, len(pool),
                                 size=(spec.batch, spec.seq + 1))]
        # inject predictable bigram structure: x[t+1] = (x[t] + s) % V for
        # a per-row shift s on half the positions
        s = self._shift[rng.integers(0, len(self._shift), size=(spec.batch, 1))]
        mask = rng.random((spec.batch, spec.seq + 1)) < 0.5
        seq = base.copy()
        for t in range(1, spec.seq + 1):
            seq[:, t] = np.where(mask[:, t],
                                 (seq[:, t - 1] + s[:, 0]) % spec.vocab,
                                 seq[:, t])
        out = {"tokens": seq[:, :-1].astype(np.int32),
               "labels": seq[:, 1:].astype(np.int32)}
        if spec.frontend == "patch":
            out["patches"] = rng.standard_normal(
                (spec.batch, spec.frontend_tokens, spec.d_model),
                dtype=np.float32)
        if spec.frontend == "audio":
            out["frames"] = rng.standard_normal(
                (spec.batch, spec.frontend_tokens, spec.d_model),
                dtype=np.float32)
        return out

    def __iter__(self) -> Iterator[dict]:
        step = 0
        while True:
            yield self.batch(step)
            step += 1


def batch_spec_for(cfg: ModelConfig, batch: int, seq: int) -> BatchSpec:
    return BatchSpec(batch=batch, seq=seq, vocab=cfg.vocab_size,
                     frontend=cfg.frontend,
                     frontend_tokens=cfg.frontend_tokens,
                     d_model=cfg.d_model)


def realize_request_tokens(req: Request, vocab: int) -> np.ndarray:
    """Materialise a trace request's input tokens such that equal hash ids
    yield equal 512-token blocks (block content is a pure function of its
    hash id). The engine's `prefix_hash_ids` then reproduces the trace's
    prefix-sharing structure bit-exactly."""
    blocks = []
    for h in req.hash_ids:
        rng = np.random.default_rng(h)
        blocks.append(rng.integers(0, vocab, BLOCK_TOKENS, dtype=np.int64))
    flat = np.concatenate(blocks) if blocks else np.empty(0, np.int64)
    n = req.input_length
    if len(flat) < n:
        rng = np.random.default_rng((req.req_id, n))
        flat = np.concatenate(
            [flat, rng.integers(0, vocab, n - len(flat), dtype=np.int64)])
    return flat[:n].astype(np.int32)
