"""CachePool / eviction policies / StateCache — unit + property tests."""
import pytest
try:
    from hypothesis import given, settings, strategies as st
except ImportError:  # sandboxed env: vendored shim (seeded random)
    from _hypothesis_shim import given, settings, strategies as st

from repro.core.cache import (CachePool, LFUPolicy, LRUPolicy,
                              LengthAwarePolicy, StateCache,
                              cache_hit_analysis)
from repro.core.trace import Request


def test_lru_evicts_oldest():
    pool = CachePool(capacity_blocks=2, policy="lru")
    pool.insert([1])
    pool.insert([2])
    pool.lookup([1])          # touch 1 → 2 becomes LRU victim
    evicted = pool.insert([3])
    assert evicted == [2]
    assert 1 in pool and 3 in pool


def test_lfu_evicts_least_frequent():
    pool = CachePool(capacity_blocks=2, policy="lfu")
    pool.insert([1, 2])
    pool.lookup([1])
    pool.lookup([1])
    evicted = pool.insert([3])
    assert evicted == [2]


def test_length_aware_prefers_deeper_blocks():
    pool = CachePool(capacity_blocks=3, policy="length_aware")
    pool.insert([1, 2, 3], start_pos=0)   # positions 0,1,2 — equal hits
    evicted = pool.insert([4])
    assert evicted == [3]                 # deepest (latest in request) first


def test_prefix_len_stops_at_gap():
    pool = CachePool()
    pool.insert([1, 2, 3, 4])
    pool._evict(3)
    assert pool.prefix_len([1, 2, 3, 4]) == 2
    assert pool.prefix_len([9, 1, 2]) == 0


def test_pinned_blocks_survive_eviction():
    pool = CachePool(capacity_blocks=2, policy="lru")
    pool.insert([1, 2])
    pool.pin([1, 2])
    evicted = pool.insert([3])            # nothing evictable
    assert evicted == [] and 3 not in pool
    pool.unpin([1])
    evicted = pool.insert([3])
    assert 1 in evicted or 2 in evicted


def test_state_cache_deepest_hit():
    sc = StateCache()
    sc.insert([10, 11, 12])
    sc._evict(11)                         # chain broken in the middle
    # KV pools would stop at depth 1; a state checkpoint at depth 3 alone
    # suffices for SSMs:
    assert sc.deepest_hit([10, 11, 12]) == 3
    assert sc.deepest_hit([99]) == 0


def test_hit_rate_accounting():
    pool = CachePool()
    pool.insert([1, 2])
    pool.lookup([1, 2, 3])                # 2 hits, 1 miss
    assert pool.hits == 2 and pool.misses == 1
    assert abs(pool.hit_rate - 2 / 3) < 1e-9


# ---------------------------------------------------------------------------
# property tests
# ---------------------------------------------------------------------------

@given(st.lists(st.lists(st.integers(0, 50), min_size=1, max_size=10),
                min_size=1, max_size=50),
       st.sampled_from(["lru", "lfu", "length_aware"]),
       st.integers(1, 8))
@settings(max_examples=60, deadline=None)
def test_pool_capacity_never_exceeded(chains, policy, cap):
    pool = CachePool(capacity_blocks=cap, policy=policy)
    for chain in chains:
        n = pool.lookup(chain)
        pool.insert(chain[n:], start_pos=n)
        assert len(pool) <= cap


@given(st.lists(st.integers(0, 30), min_size=1, max_size=20))
@settings(max_examples=50, deadline=None)
def test_insert_idempotent(chain):
    pool = CachePool()
    pool.insert(chain)
    n1 = len(pool)
    pool.insert(chain)
    assert len(pool) == n1
    assert pool.prefix_len(chain) == len(chain)


@given(st.integers(1, 5), st.integers(0, 100))
@settings(max_examples=30, deadline=None)
def test_infinite_capacity_hit_rate_is_reuse_bound(n_chains, seed):
    """With ∞ capacity, hit rate == (total touches − unique blocks) /
    total touches — the Table 1 ∞ column identity."""
    import numpy as np
    rng = np.random.default_rng(seed)
    reqs = []
    for i in range(30):
        c = int(rng.integers(0, n_chains))
        depth = int(rng.integers(1, 8))
        reqs.append(Request(req_id=i, timestamp=i,
                            input_length=depth * 512, output_length=1,
                            hash_ids=[c * 1000 + j for j in range(depth)]))
    hr = cache_hit_analysis(reqs, "lru", None)
    touches = sum(len(r.hash_ids) for r in reqs)
    uniq = len({h for r in reqs for h in r.hash_ids})
    assert abs(hr - (touches - uniq) / touches) < 1e-9
