"""Quickstart: the Mooncake reproduction in five minutes (CPU).

1. Generate a paper-statistics trace and inspect it (§4).
2. Reproduce the Table-1 cache-policy comparison on it.
3. Schedule requests through the Conductor (Algorithm 1) and compare the
   four scheduling strategies of Figure 8 on a small cluster.
4. Run a real (reduced-model) prefill with prefix reuse through the
   serving engine.

    PYTHONPATH=src python examples/quickstart.py [--requests 3000]

(--requests scales the trace; CI's smoke lane uses a few hundred.)
"""
import argparse

import numpy as np

from repro.configs.base import get_config
from repro.core import (CachePool, ClusterSpec, MooncakeCluster, TraceSpec,
                        cache_hit_analysis, generate_trace, list_policies,
                        trace_stats)


def main(argv=None):
    ap = argparse.ArgumentParser()
    ap.add_argument("--requests", type=int, default=3000,
                    help="trace size for the simulator sections")
    args = ap.parse_args(argv)

    # --- 1. the trace (§4) -------------------------------------------------
    print("=" * 70)
    print("1. Mooncake-format trace with the paper's workload statistics")
    trace = generate_trace(TraceSpec(n_requests=args.requests, seed=0))
    stats = trace_stats(trace)
    print(f"   {stats['n']} requests | avg input {stats['avg_input']:.0f} "
          f"tok (paper: 7,590) | avg output {stats['avg_output']:.0f} "
          f"(paper: 182)")
    print(f"   single-use blocks {stats['frac_blocks_single_use']:.0%} "
          f"(paper: >50%) | reuse ceiling {stats['max_reuse']:.0%} "
          f"(paper: ~50%)")
    r = trace[0]
    print(f"   sample: {r.to_json()[:100]}...")

    # --- 2. Table 1 --------------------------------------------------------
    print("=" * 70)
    print("2. Cache eviction policies (Table 1): block hit rate")
    for policy in ("lru", "lfu", "length_aware"):
        rates = [cache_hit_analysis(trace, policy, cap)
                 for cap in (None, 10_000, 1_000)]
        print(f"   {policy:13s} inf={rates[0]:.2f} 10k={rates[1]:.2f} "
              f"1k={rates[2]:.2f}")

    # --- 3. KVCache-centric scheduling (Fig 8) -----------------------------
    print("=" * 70)
    print("3. Conductor scheduling strategies on a 4P+4D cluster (Fig 8)\n"
          "   (every policy in the registry — including any you add)")
    cfg = get_config("llama2-70b")   # the paper's dummy model
    for strategy in list_policies("prefill"):
        spec = ClusterSpec(n_prefill=4, n_decode=4, strategy=strategy)
        mc = MooncakeCluster.from_spec(cfg, spec)
        res = mc.run(trace)
        print(f"   {strategy:13s} avg TTFT {res.avg_ttft():6.3f}s  "
              f"P90 {res.ttft_p90():6.3f}s  migrations={res.n_migrations}")

    # --- 4. the real engine ------------------------------------------------
    print("=" * 70)
    print("4. Executable engine: chunked prefill with prefix reuse "
          "(reduced smollm, CPU)")
    import jax
    from repro.models.transformer import init_params
    from repro.serving.engine import HostKVPool, PrefillWorker
    scfg = get_config("smollm-360m").reduced()
    params = init_params(scfg, jax.random.PRNGKey(0))
    pool = HostKVPool()
    pw = PrefillWorker(params, scfg, pool, prefill_chunk=128)
    rng = np.random.default_rng(0)
    doc = rng.integers(0, scfg.vocab_size, 1024)       # shared document
    q1 = np.concatenate([doc, rng.integers(0, scfg.vocab_size, 64)])
    q2 = np.concatenate([doc, rng.integers(0, scfg.vocab_size, 64)])
    r1 = pw(q1)
    r2 = pw(q2)
    print(f"   request 1: {r1.prompt_len} tokens, reused "
          f"{r1.reused_blocks} blocks (cold)")
    print(f"   request 2: {r2.prompt_len} tokens, reused "
          f"{r2.reused_blocks} blocks -> computed only "
          f"{r2.prompt_len - 512 * r2.reused_blocks} tokens")
    print("\nquickstart OK")


if __name__ == "__main__":
    main()
