"""Chunked Pipeline Parallelism (CPP) for long-context prefill (§5.1).

The paper's argument: extending TP across nodes costs two RDMA all-reduces
per layer; sequence parallelism (Ring Attention) still communicates every
layer. CPP instead groups X nodes into a *pipelined prefill group*: the
request's input is cut into ``prefill_chunk``-token chunks and chunk i can
run on stage s while chunk i+1 runs on stage s-1 — cross-node traffic only
at stage boundaries (one activation tensor per chunk), easily overlapped.

Why it works for prefill: by autoregressivity, chunk i only attends to
tokens of chunks ≤ i. Each pipeline stage owns a contiguous slice of
layers and accumulates its slice's KV for the chunks it has already
processed — so when chunk i arrives, all the KV it needs (for this
stage's layers) is already resident. KV also ends up *sharded by layer
across stages*, which is exactly the layout layer-wise streaming (§5.2)
wants for store-back.

TPU adaptation (DESIGN.md §3): stage handoff = ``jax.lax.ppermute`` over a
``stage`` mesh axis inside ``shard_map``; the ICI torus plays the role of
the RDMA fabric. The schedule is the classic (C + X − 1)-microstep GPipe
wavefront, expressed as ``lax.scan`` with masked bubbles so the lowered
HLO has one stage body.

Supports the uniform dense stack (the paper's dummy LLaMA2-70B is dense);
MoE/hybrid prefill uses the batch-sharded path instead.
"""
from __future__ import annotations

from functools import partial
from typing import Optional

import jax
import jax.numpy as jnp
from jax.sharding import Mesh, NamedSharding, PartitionSpec as P

from repro.configs.base import ModelConfig
from repro.models.layers import (DTYPE, NO_DIST, attention_block, mlp_block,
                                 rms_norm)
from repro.models.transformer import _embed, _logits_at


def _stage_body(x, p_stack, cfg: ModelConfig, kv_bufs, offset):
    """Run this stage's layer slice on one chunk.

    x: (B, C, D) chunk activations; p_stack: params with leading L_s;
    kv_bufs: (L_s, B, S, KV, Dh) ×2 this stage's accumulated KV;
    offset: scalar — absolute token position of the chunk start.
    Returns (y, updated kv_bufs).
    """
    k_buf, v_buf = kv_bufs

    def layer(carry, xs):
        h = carry
        p, kc, vc = xs
        y, (kc2, vc2) = attention_block(
            h, p["attn"], cfg, NO_DIST, cache=(kc, vc), cache_len=offset)
        h = h + y
        h = h + mlp_block(h, p["mlp"], cfg)
        return h, (kc2, vc2)

    h, (k2, v2) = jax.lax.scan(
        layer, x, ({"attn": p_stack["attn"], "mlp": p_stack["mlp"]},
                   k_buf, v_buf))
    return h, (k2, v2)


def cpp_prefill(params, tokens, cfg: ModelConfig, mesh: Mesh, *,
                stage_axis: str = "stage", prefill_chunk: int = 1024):
    """Pipelined prefill of ONE long request across ``X = mesh[stage_axis]``
    stages. tokens: (B, S) with S % prefill_chunk == 0.

    Returns last-position logits (B, V). Parameters must be stacked
    (n_layers, ...) with n_layers % X == 0; they are consumed sharded on
    the stage axis (each stage holds L/X layers).
    """
    X = mesh.shape[stage_axis]
    B, S = tokens.shape
    assert S % prefill_chunk == 0 and cfg.n_layers % X == 0
    C = S // prefill_chunk
    L_s = cfg.n_layers // X
    KV, Dh, D = cfg.n_kv_heads, cfg.head_dim, cfg.d_model

    x_emb = _embed(params, tokens, cfg)          # (B, S, D)
    chunks = x_emb.reshape(B, C, prefill_chunk, D).transpose(1, 0, 2, 3)

    stage_params = {"attn": params["attn"], "mlp": params["mlp"]}

    def pipeline(chunks_l, p_l):
        """Inside shard_map: one device = one stage. chunks_l is replicated
        (every stage sees the embedded input; only stage 0 consumes it).
        p_l: this stage's (L_s, ...) params."""
        sid = jax.lax.axis_index(stage_axis)
        k_buf = jnp.zeros((L_s, B, S, KV, Dh), DTYPE)
        v_buf = jnp.zeros((L_s, B, S, KV, Dh), DTYPE)
        zero = jnp.zeros((B, prefill_chunk, D), DTYPE)

        def microstep(carry, t):
            k_buf, v_buf, boundary = carry
            # stage 0 takes chunk t from the input; others take the
            # boundary activation handed over by the previous stage
            chunk_in = jnp.where(
                (t < C), jax.lax.dynamic_index_in_dim(
                    chunks_l, jnp.clip(t, 0, C - 1), keepdims=False), zero)
            x = jnp.where(sid == 0, chunk_in, boundary)
            my_chunk = t - sid                     # which chunk this stage sees
            valid = (my_chunk >= 0) & (my_chunk < C)
            offset = jnp.clip(my_chunk, 0, C - 1) * prefill_chunk

            y, (k2, v2) = _stage_body(
                x.astype(DTYPE), p_l, cfg, (k_buf, v_buf), offset)
            # only commit KV/output on valid microsteps (bubbles are masked)
            k_buf = jnp.where(valid, k2, k_buf)
            v_buf = jnp.where(valid, v2, v_buf)
            y = jnp.where(valid, y, zero)
            # hand the processed chunk to the next stage
            boundary = jax.lax.ppermute(
                y, stage_axis, [(i, (i + 1) % X) for i in range(X)])
            # emit the LAST stage's output chunk (post all layers)
            out = jnp.where(sid == X - 1, y, zero)
            return (k_buf, v_buf, boundary), out

        (k_buf, v_buf, _), outs = jax.lax.scan(
            microstep, (k_buf, v_buf, zero), jnp.arange(C + X - 1))
        # outs: (C+X-1, B, chunk, D); chunk c completed at microstep c+X-1.
        h_last = outs[-1]                          # final chunk's activations
        # broadcast the final hidden state from the last stage to all
        h_last = jax.lax.psum(
            jnp.where(sid == X - 1, h_last, jnp.zeros_like(h_last)),
            stage_axis)
        return h_last, (k_buf, v_buf)

    from repro.launch.mesh import compat_shard_map
    fn = compat_shard_map(
        pipeline, mesh=mesh,
        in_specs=(P(), P(stage_axis)),
        out_specs=(P(), P(stage_axis)),
        check_vma=False)
    h_last, kv = fn(chunks, stage_params)
    h_last = rms_norm(h_last, params["final_ln"], cfg.norm_eps)
    logits = _logits_at(params, h_last[:, -1:, :], cfg)[:, 0]
    return logits, kv


def cpp_reference(params, tokens, cfg: ModelConfig):
    """Single-device oracle: plain full prefill (same math, no pipeline)."""
    from repro.models.transformer import prefill
    logits, caches = prefill(params, tokens, cfg)
    return logits, (caches.kv.k, caches.kv.v)
