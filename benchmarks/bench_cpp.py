"""§5.1: Chunked Pipeline Parallelism vs sequence/tensor parallelism —
the paper's multi-node prefill argument, quantified.

Lowers the real `cpp_prefill` (shard_map + ppermute) for the dummy
LLaMA2-70B on a 4-stage pipeline group and reads its ACTUAL cross-node
traffic (collective-permute bytes) from the compiled HLO; compares
against the analytic cross-node traffic of the alternatives the paper
rejects:

  * TP across nodes: 2 all-reduces of the activations per layer
    → 2 · 2 · L · S · d_model · 2B  per request (ring AR ≈ 2× payload)
  * SP (Ring Attention): K/V circulate through every device each layer
    → L · S · KV · Dh · 2 · 2B · (X−1)/X · 2  per request
  * CPP: one boundary activation per chunk per stage handoff
    → (C + X − 2) · chunk · d_model · 2B  per request

Also verifies the pipeline wavefront: HLO microstep trip count =
C + X − 1.
"""
from __future__ import annotations

import json
import os
import subprocess
import sys

from benchmarks.common import emit

SRC = os.path.join(os.path.dirname(os.path.dirname(os.path.abspath(__file__))),
                   "src")

_SUB = r'''
import os
os.environ["XLA_FLAGS"] = "--xla_force_host_platform_device_count=4"
import json, sys
import jax
from repro.configs.base import get_config
from repro.launch.hlo_analysis import analyze
from repro.launch.mesh import make_stage_mesh
from repro.models.transformer import init_params
from repro.serving.cpp import cpp_prefill

S, CHUNK = int(sys.argv[1]), int(sys.argv[2])
cfg = get_config("llama2-70b")
mesh = make_stage_mesh(4)
p_shapes = jax.eval_shape(lambda k: init_params(cfg, k), jax.random.PRNGKey(0))
tok = jax.ShapeDtypeStruct((1, S), jax.numpy.int32)
with mesh:
    lowered = jax.jit(lambda p, t: cpp_prefill(
        p, t, cfg, mesh, prefill_chunk=CHUNK)).lower(p_shapes, tok)
    compiled = lowered.compile()
r = analyze(compiled.as_text())
print(json.dumps({"permute_bytes": r["collective_bytes"]["collective-permute"],
                  "permute_count": r["collective_counts"]["collective-permute"],
                  "flops": r["flops"]}))
'''


def run_cpp_lowering(S: int, chunk: int) -> dict:
    env = dict(os.environ)
    env["PYTHONPATH"] = SRC + os.pathsep + env.get("PYTHONPATH", "")
    env.pop("XLA_FLAGS", None)
    res = subprocess.run([sys.executable, "-c", _SUB, str(S), str(chunk)],
                         env=env, capture_output=True, text=True,
                         timeout=3000)
    if res.returncode != 0:
        raise RuntimeError(res.stderr[-500:])
    return json.loads(res.stdout.strip().splitlines()[-1])


def main(fast: bool = False):
    from repro.configs.base import get_config
    cfg = get_config("llama2-70b")
    X = 4
    rows = []
    cases = [(8192, 1024)] if fast else [(8192, 1024), (32768, 2048),
                                         (131072, 4096)]
    for S, chunk in cases:
        C = S // chunk
        try:
            m = run_cpp_lowering(S, chunk)
            cpp_measured = m["permute_bytes"]
        except Exception as e:  # noqa: BLE001
            m, cpp_measured = {"permute_count": -1}, float("nan")
            print(f"[bench_cpp] lowering failed at S={S}: {e}",
                  file=sys.stderr)
        d, L = cfg.d_model, cfg.n_layers
        KV, Dh = cfg.n_kv_heads, cfg.head_dim
        cpp_analytic = (C + X - 2) * chunk * d * 2
        tp = 2 * 2 * L * S * d * 2
        sp = 2 * L * S * KV * Dh * 2 * 2 * (X - 1) / X
        rows.append(dict(
            seq=S, chunk=chunk, n_chunks=C,
            cpp_measured_gb=round(cpp_measured / 1e9, 3),
            cpp_analytic_gb=round(cpp_analytic / 1e9, 3),
            sp_ring_attn_gb=round(sp / 1e9, 3),
            tp_crossnode_gb=round(tp / 1e9, 3),
            cpp_vs_sp=round(sp / max(cpp_analytic, 1), 1),
            cpp_vs_tp=round(tp / max(cpp_analytic, 1), 1),
            permute_ops=m["permute_count"],
        ))
    emit("sec51_cpp_vs_sp_tp", rows)
    return rows


if __name__ == "__main__":
    main(fast="--fast" in sys.argv)
