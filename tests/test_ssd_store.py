"""File-backed SSD KVCache store: integrity, crash safety, prefetch
overlap, and bit-exactness of SSD-loaded generation (ISSUE 3).

The invariant under test throughout: SSD state may be stale, truncated,
or corrupted, and the engine must degrade to RECOMPUTE — it must never
serve wrong KV bytes or emit different tokens than a cold computation.
"""
import os

import numpy as np
import pytest

from repro.core.trace import BLOCK_TOKENS
from repro.serving.ssd_store import AsyncPrefetcher, SSDBlockStore

L, KV, DH = 2, 1, 4     # tiny per-layer KV geometry for store-level tests


def _blk(rng, tokens=BLOCK_TOKENS):
    return (rng.standard_normal((L, tokens, KV, DH)).astype(np.float32),
            rng.standard_normal((L, tokens, KV, DH)).astype(np.float32))


@pytest.fixture()
def store(tmp_path):
    s = SSDBlockStore(str(tmp_path / "ssd"), writeback_batch=2)
    yield s
    s.close()


# ---------------------------------------------------------------------------
# store integrity
# ---------------------------------------------------------------------------

def test_roundtrip_bit_exact(store):
    rng = np.random.default_rng(0)
    k, v = _blk(rng)
    store.put(1, k, v)
    store.flush()
    out = store.read_block(1)
    assert out is not None
    assert out[0].dtype == k.dtype
    assert np.array_equal(out[0], k) and np.array_equal(out[1], v)


def test_read_layer_matches_block_slices(store):
    rng = np.random.default_rng(1)
    k, v = _blk(rng)
    store.put(7, k, v)
    store.flush()
    for l in range(L):
        kl, vl = store.read_layer(7, l)
        assert np.array_equal(kl, k[l]) and np.array_equal(vl, v[l])


def test_staging_read_your_writes_and_batching(store):
    rng = np.random.default_rng(2)
    k, v = _blk(rng)
    store.put(1, k, v)                    # staged (batch of 2 not reached)
    assert store.staged_blocks == 1 and store.n_flushes == 0
    out = store.read_block(1)             # readable BEFORE the flush
    assert out is not None and np.array_equal(out[0], k)
    k2, v2 = _blk(rng)
    store.put(2, k2, v2)                  # fills the batch → auto-flush
    assert store.staged_blocks == 0 and store.n_flushes == 1
    assert store.blocks_written == 2


def test_delete_reuses_slots(store):
    rng = np.random.default_rng(3)
    for key in (1, 2):
        store.put(key, *_blk(rng))
    store.flush()
    size1 = os.path.getsize(store.path)
    store.delete(1)
    store.put(3, *_blk(rng))
    store.flush()
    assert os.path.getsize(store.path) == size1   # freed slot was reused
    assert store.read_block(1) is None
    assert store.read_block(3) is not None


def test_truncated_file_reads_none(store):
    rng = np.random.default_rng(4)
    store.put(1, *_blk(rng))
    store.flush()
    with open(store.path, "r+b") as f:     # crash mid-write: lose the tail
        f.truncate(os.path.getsize(store.path) // 2)
    assert store.read_block(1) is None
    assert store.read_failures > 0


def test_corrupt_payload_reads_none(store):
    rng = np.random.default_rng(5)
    k, v = _blk(rng)
    store.put(1, k, v)
    store.flush()
    off = store._offsets[1]
    with open(store.path, "r+b") as f:     # flip one payload byte
        f.seek(off + store._hdr_size + 13)
        b = f.read(1)
        f.seek(off + store._hdr_size + 13)
        f.write(bytes([b[0] ^ 0xFF]))
    assert store.read_block(1) is None
    assert store.read_failures > 0


def test_corrupt_header_reads_none(store):
    rng = np.random.default_rng(6)
    store.put(1, *_blk(rng))
    store.flush()
    with open(store.path, "r+b") as f:     # stomp the magic
        f.seek(store._offsets[1])
        f.write(b"XXXX")
    assert store.read_block(1) is None


def test_store_restart_recovers_flushed_blocks(tmp_path):
    rng = np.random.default_rng(9)
    k1, v1 = _blk(rng)
    k2, v2 = _blk(rng)
    s1 = SSDBlockStore(str(tmp_path / "persist"), writeback_batch=8)
    s1.put(1, k1, v1)
    s1.flush()
    s1.put(2, k2, v2)                 # staged, never flushed — crash loses it
    # simulate a crash: drop the handle without the close() flush
    os.close(s1._fd)
    s1._fd = -1
    s2 = SSDBlockStore(str(tmp_path / "persist"), writeback_batch=8)
    out = s2.read_block(1)
    assert out is not None and np.array_equal(out[0], k1)
    assert s2.read_block(2) is None   # staged block was (correctly) lost
    assert s2.keys() == [1]
    s2.close()


# ---------------------------------------------------------------------------
# async layer-wise prefetch
# ---------------------------------------------------------------------------

def test_prefetch_layer_major_and_bit_exact(store):
    rng = np.random.default_rng(7)
    blocks = {key: _blk(rng) for key in (1, 2, 3)}
    for key, (k, v) in blocks.items():
        store.put(key, k, v)
    store.flush()
    pf = AsyncPrefetcher(store)
    h = pf.fetch([1, 2, 3])
    assert h.wait(10.0)
    assert not h.failed
    for key, (k, v) in blocks.items():
        out = h.result(key)
        assert np.array_equal(out[0], k) and np.array_equal(out[1], v)
    # §5.2 stream order: every layer-l read precedes every layer-(l+1) read
    layers_seen = [layer for _key, layer, _t in h.layer_log]
    assert layers_seen == sorted(layers_seen)
    pf.close()


def test_prefetch_marks_corrupt_block_failed(store):
    rng = np.random.default_rng(8)
    for key in (1, 2):
        store.put(key, *_blk(rng))
    store.flush()
    with open(store.path, "r+b") as f:
        f.seek(store._offsets[2] + store._hdr_size + 5)
        f.write(b"\xff\xff\xff")
    pf = AsyncPrefetcher(store)
    h = pf.fetch([1, 2])
    assert h.wait(10.0)
    assert 2 in h.failed and h.result(2) is None
    assert h.result(1) is not None          # good blocks still land
    pf.close()


# ---------------------------------------------------------------------------
# HostKVPool two-tier semantics (metadata ↔ bytes coupling, no model)
# ---------------------------------------------------------------------------

def _kv_for(hash_ids, seed=0):
    rng = np.random.default_rng(seed)
    n = len(hash_ids)
    return (rng.standard_normal((L, n * BLOCK_TOKENS, KV, DH))
            .astype(np.float32),
            rng.standard_normal((L, n * BLOCK_TOKENS, KV, DH))
            .astype(np.float32))


def _pool(tmp_path, dram=2, ssd=16, **kw):
    from repro.serving.engine import HostKVPool
    return HostKVPool(capacity_blocks=dram, ssd_capacity_blocks=ssd,
                      ssd_dir=str(tmp_path / "pool_ssd"),
                      writeback_batch=1, **kw)


def test_pool_demotes_bytes_to_disk_and_promotes_back(tmp_path):
    pool = _pool(tmp_path, dram=2)
    ids = [101, 102, 103, 104]
    k, v = _kv_for(ids)
    pool.put(ids, k, v)
    # DRAM cap 2 → the chain head was demoted; bytes must be on disk only
    assert len(pool.data) == 2
    assert len(pool.store) == 2
    assert pool.meta.resident_tier(101) == "ssd"
    n = pool.match_prefix(ids)              # blocking verified fetch
    assert n == 4
    gk, gv = pool.get(ids)
    assert np.array_equal(gk, k) and np.array_equal(gv, v)
    assert pool.store.layer_reads > 0       # bytes really came off disk
    assert pool.meta.ssd_hits > 0 and pool.meta.promotions > 0
    # metadata ↔ bytes coupling: every resident block's bytes live where
    # its tier says (DRAM cap 2 < chain 4 ⇒ promotion thrash is expected;
    # consistency is the invariant, not final placement)
    for h in ids:
        tier = pool.meta.resident_tier(h)
        assert tier is not None
        assert h in (pool.data if tier == "dram" else pool.store)
    pool.close()


def test_pool_corrupt_block_truncates_prefix_and_discards(tmp_path):
    pool = _pool(tmp_path, dram=1)
    ids = [201, 202, 203]
    pool.put(ids, *_kv_for(ids))
    pool.store.flush()
    victim = next(h for h in ids if pool.meta.resident_tier(h) == "ssd")
    off = pool.store._offsets[victim]
    with open(pool.store.path, "r+b") as f:
        f.seek(off + pool.store._hdr_size + 3)
        f.write(b"\x00\x00\x00\x00")
    n = pool.match_prefix(ids)
    assert n == ids.index(victim)           # usable prefix stops before it
    assert victim not in pool.meta          # discarded from the hierarchy
    pool.close()


def test_pool_whole_hierarchy_eviction_frees_store(tmp_path):
    pool = _pool(tmp_path, dram=1, ssd=1)
    ids = [301, 302, 303]
    pool.put(ids, *_kv_for(ids))
    # capacity 1+1: at most two blocks anywhere, dropped keys leave disk too
    assert len(pool.data) + len(pool.store) <= 2
    resident = [h for h in ids if h in pool.meta]
    assert all((h in pool.data) or (h in pool.store) for h in resident)
    pool.close()


def test_pool_restart_serves_prefix_from_disk(tmp_path):
    ids = [501, 502, 503, 504]
    k, v = _kv_for(ids)
    pool1 = _pool(tmp_path, dram=2)
    pool1.put(ids, k, v)
    pool1.store.flush()
    on_disk = sorted(pool1.store.keys())
    assert on_disk                     # the demoted chain head hit the file
    pool1.close()
    pool2 = _pool(tmp_path, dram=2)    # same ssd_dir → recovery
    assert sorted(pool2.store.keys()) == on_disk
    n = pool2.match_prefix(ids)        # chain hashes are stable across runs
    assert n == len(on_disk)           # DRAM bytes died; disk blocks live
    gk, _ = pool2.get(ids[:n])
    assert np.array_equal(gk, k[:, :n * BLOCK_TOKENS])
    pool2.close()


def test_ssd_dir_without_tier_raises(tmp_path):
    from repro.serving.engine import HostKVPool
    with pytest.raises(ValueError, match="ssd_dir"):
        HostKVPool(capacity_blocks=8, ssd_capacity_blocks=0,
                   ssd_dir=str(tmp_path / "nope"))


def test_pool_prefetch_protocol_from_block(tmp_path):
    pool = _pool(tmp_path, dram=2)
    ids = [401, 402, 403, 404]
    k, v = _kv_for(ids)
    pool.put(ids, k, v)
    pool.store.flush()
    plan = pool.plan_fetch(ids)
    assert plan.n_resident == 4 and plan.has_ssd
    s = 1                                    # pretend we recompute block 0
    handle = pool.start_prefetch(plan, from_block=s)
    n_tail = pool.finish_fetch(plan, handle, from_block=s)
    assert n_tail == 3
    gk, _ = pool.get(ids[s:4])
    sl = slice(s * BLOCK_TOKENS, 4 * BLOCK_TOKENS)
    assert np.array_equal(gk, k[:, sl])
    pool.close()


# ---------------------------------------------------------------------------
# engine end-to-end: SSD-loaded generation is bit-exact; corruption falls
# back to recompute (never wrong tokens)
# ---------------------------------------------------------------------------

@pytest.fixture(scope="module")
def setup():
    import jax

    from repro.configs.base import get_config
    from repro.models.transformer import init_params
    cfg = get_config("smollm-360m").reduced()
    params = init_params(cfg, jax.random.PRNGKey(0))
    rng = np.random.default_rng(42)
    doc = rng.integers(0, cfg.vocab_size, 2 * BLOCK_TOKENS)
    q1 = np.concatenate([doc, rng.integers(0, cfg.vocab_size, 48)])
    q2 = np.concatenate([doc, rng.integers(0, cfg.vocab_size, 48)])
    return cfg, params, q1, q2


def _decode_tokens(params, cfg, pres, n=3):
    from repro.serving.engine import DecodeWorker
    dw = DecodeWorker(params, cfg, max_batch=1, max_len=pres.prompt_len + n + 4)
    dw.join(0, pres, max_new=n)
    out = [pres.first_token]
    while dw.n_active:
        out.extend(tok for _rid, tok, _f in dw.step())
    return out


@pytest.fixture(scope="module")
def dram_reference(setup):
    from repro.serving.engine import HostKVPool, PrefillWorker
    cfg, params, q1, q2 = setup
    pool = HostKVPool()
    pw = PrefillWorker(params, cfg, pool, prefill_chunk=128)
    pw(q1)
    return _decode_tokens(params, cfg, pw(q2))


@pytest.mark.parametrize("mode", ["blocking", "overlap"])
def test_ssd_loaded_generation_bit_exact(setup, dram_reference, tmp_path,
                                         mode):
    from repro.serving.engine import HostKVPool, PrefillWorker
    cfg, params, q1, q2 = setup
    pool = HostKVPool(capacity_blocks=1, ssd_capacity_blocks=32,
                      ssd_dir=str(tmp_path / mode), writeback_batch=1)
    pw = PrefillWorker(params, cfg, pool, prefill_chunk=128, ssd_mode=mode)
    pw(q1)
    pool.store.flush()
    assert len(pool.store) >= 1             # revisit must hit the disk tier
    pres = pw(q2)
    assert pres.reused_blocks == 2
    if mode == "overlap":
        assert pres.overlapped
    assert _decode_tokens(params, cfg, pres) == dram_reference
    pool.close()


@pytest.mark.parametrize("mode", ["blocking", "overlap"])
def test_corrupt_ssd_falls_back_to_recompute(setup, dram_reference,
                                             tmp_path, mode):
    from repro.serving.engine import HostKVPool, PrefillWorker
    cfg, params, q1, q2 = setup
    pool = HostKVPool(capacity_blocks=1, ssd_capacity_blocks=32,
                      ssd_dir=str(tmp_path / ("bad_" + mode)),
                      writeback_batch=1)
    pw = PrefillWorker(params, cfg, pool, prefill_chunk=128, ssd_mode=mode)
    pw(q1)
    pool.store.flush()
    with open(pool.store.path, "r+b") as f:  # corrupt EVERY on-disk block
        size = os.path.getsize(pool.store.path)
        f.seek(pool.store._hdr_size + 11)
        f.write(b"\xde\xad\xbe\xef")
        if size > pool.store._slot_size:
            f.truncate(size - pool.store._slot_size // 2)
    pres = pw(q2)
    # wrong tokens are impossible: the engine recomputed what it lost
    assert _decode_tokens(params, cfg, pres) == dram_reference
    assert pool.store.read_failures > 0 or pw.stats()["fallback_blocks"] > 0
    pool.close()


def test_full_hit_revisit_still_correct_with_store(setup, tmp_path):
    """Full-prefix hit: the capped plan must recompute the tail block."""
    from repro.serving.engine import HostKVPool, PrefillWorker
    cfg, params, q1, _ = setup
    doc_only = q1[:2 * BLOCK_TOKENS]
    pool = HostKVPool(capacity_blocks=1, ssd_capacity_blocks=32,
                      ssd_dir=str(tmp_path / "fullhit"), writeback_batch=1)
    pw = PrefillWorker(params, cfg, pool, prefill_chunk=128,
                       ssd_mode="overlap")
    import jax
    import jax.numpy as jnp

    from repro.models.transformer import prefill
    cold_logits, _ = jax.jit(lambda p, t: prefill(p, t, cfg))(
        params, jnp.asarray(doc_only[None]))
    expect = int(jnp.argmax(cold_logits[0]))
    pw(doc_only)
    res = pw(doc_only)                      # full hit, served via the store
    assert res.first_token == expect
    pool.close()


# ---------------------------------------------------------------------------
# kv_pressure decode policy
# ---------------------------------------------------------------------------

def test_kv_pressure_registered_and_diverges_under_naive_accounting():
    from repro.core.conductor import DecodeInstance
    from repro.core.costmodel import CostModel, InstanceSpec
    from repro.core.messenger import Messenger
    from repro.core.policies import get_policy, list_policies
    from repro.core.policies.base import PolicyContext
    from repro.core.trace import Request

    assert "kv_pressure" in list_policies("decode")
    cm = lambda: CostModel(__import__("repro.configs.base",
                                      fromlist=["get_config"])
                           .get_config("llama2-70b"), InstanceSpec())
    cap = cm().decode_capacity_tokens()
    # d_low_tbt: marginally lower CURRENT load, but huge pending
    # commitments invisible to naive accounting; d_safe: more current
    # load, almost nothing pending
    d_low_tbt = DecodeInstance(iid=0, cost=cm(), active=4,
                               kv_tokens=0.40 * cap, pending=6,
                               pending_tokens=0.5 * cap)
    d_safe = DecodeInstance(iid=1, cost=cm(), active=4,
                            kv_tokens=0.45 * cap, pending=0,
                            pending_tokens=0.0)
    ctx = PolicyContext(messenger=Messenger([0, 1], bw=100e9))
    req = Request(req_id=0, timestamp=0, input_length=1024,
                  output_length=64, hash_ids=[1, 2])
    mt = get_policy("decode", "min_tbt")(ctx)
    kvp = get_policy("decode", "kv_pressure")(ctx)
    pick_mt, tbt_mt = mt.select(req, [d_low_tbt, d_safe], 0.0,
                                include_pending=False)
    pick_kvp, tbt_kvp = kvp.select(req, [d_low_tbt, d_safe], 0.0,
                                   include_pending=False)
    assert pick_mt.iid == 0                 # naive accounting: lag victim
    assert pick_kvp.iid == 1                # pressure term sees the pending
    # the returned TBT stays honest (it's the chosen node's predicted TBT)
    assert tbt_kvp == d_safe.predicted_tbt(1, 1024 + 64,
                                           include_pending=False)
    # purity: selection mutated nothing
    assert d_low_tbt.pending_tokens == 0.5 * cap and d_safe.pending == 0


# ---------------------------------------------------------------------------
# layerwise overlap split
# ---------------------------------------------------------------------------

def test_overlap_split_never_worse_than_pure_schedules():
    from repro.serving.layerwise import overlap_split
    for tiers in (["ssd"] * 6, ["dram", "ssd", "ssd", "ssd"],
                  ["ssd", "ssd", "dram", "dram"], ["dram"] * 3, []):
        for tc, tl in ((0.5, 0.5), (1.0, 0.1), (0.1, 1.0)):
            ov = overlap_split(tiers, tc, tl)
            assert ov.t_overlapped <= ov.t_blocking + 1e-12
            n_ssd = tiers.count("ssd")
            pure_recompute = (len(tiers) - ov.dram_head) * tc
            assert ov.t_overlapped <= pure_recompute + 1e-12
            assert ov.dram_head <= ov.split <= len(tiers)


def test_overlap_split_balances_when_costs_match():
    from repro.serving.layerwise import overlap_split
    ov = overlap_split(["ssd"] * 8, 1.0, 1.0)
    assert ov.split == 4                     # half recomputed, half loaded
    assert ov.predicted_speedup == pytest.approx(2.0)
