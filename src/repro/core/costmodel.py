"""Analytic instance cost model (Figure 2) calibrated from hardware specs.

The paper fits a predictive model of prefill/decode time from offline data
(§6.1: "Thanks to the regular computation pattern of Transformers, the error
bound of this prediction is small"). Without GPUs we derive the same model
analytically from the architecture config and the TPU v5e roofline terms —
the derivation is checked against the dry-run's compiled ``cost_analysis()``
in ``benchmarks/roofline.py``, closing the loop the paper closes with
offline measurement.

  * Prefill is compute-bound: quadratic attention + linear MLP FLOPs
    (Figure 2 left: superlinear in input length).
  * Decode is memory-bound: weights + KV bytes per iteration
    (Figure 2 right: sublinear in batch size — weight reads amortize).
"""
from __future__ import annotations

from dataclasses import dataclass

from repro.configs.base import ModelConfig


@dataclass(frozen=True)
class Hardware:
    """TPU v5e chip + interconnect (DESIGN.md §3)."""
    peak_flops: float = 197e12        # bf16 FLOP/s per chip
    hbm_bw: float = 819e9             # bytes/s per chip
    ici_bw: float = 50e9              # bytes/s per ICI link
    dram_bw: float = 100e9            # host DRAM read bw (pool side)
    net_bw: float = 100e9             # inter-node KVCache transfer (RDMA-class)
    ssd_read_bw: float = 6e9          # local NVMe read, PCIe-4 class (SSD tier)
    ssd_write_bw: float = 3e9         # local NVMe write (demotion path)
    hbm_bytes: float = 16e9           # per chip
    mfu_prefill: float = 0.55         # achievable fraction of peak, prefill
    mbu_decode: float = 0.70          # achievable fraction of HBM bw, decode


V5E = Hardware()


@dataclass(frozen=True)
class InstanceSpec:
    """One serving instance = a slice of the pod (paper: one 8xA800 node =
    640 GB VRAM; TPU-native equivalent: a 16-chip v5e slice = 256 GB HBM,
    enough to hold the dummy-70B weights + a KV batch)."""
    n_chips: int = 16
    hw: Hardware = V5E


class CostModel:
    """Per-architecture timing estimates, all in SECONDS."""

    def __init__(self, cfg: ModelConfig, inst: InstanceSpec = InstanceSpec()):
        self.cfg = cfg
        self.inst = inst
        self.n_params_active = cfg.active_param_count()
        self.kv_token_bytes = (2 * cfg.attention_layers * cfg.n_kv_heads
                               * cfg.head_dim * 2)  # bf16 K+V per token
        self.weight_bytes = self.n_params_active * 2  # bf16
        self._ssd_s_per_token = None   # measured override (calibrate_ssd_read)

    # ---- prefill (compute-bound, Figure 2 left) ----
    def prefill_flops(self, L: int, prefix: int = 0) -> float:
        """FLOPs to prefill positions [prefix, L) given a cached prefix.
        A full (or over-covering, block-rounded) prefix still recomputes
        the last position to produce the first-token logits."""
        prefix = min(max(prefix, 0), L - 1) if L > 0 else 0
        new = L - prefix
        lin = 2.0 * self.n_params_active * new
        # attention scores+values: 2 * 2 * H * Dh * sum_{i=prefix}^{L} i
        cfg = self.cfg
        quad = 0.0
        if cfg.attention_layers:
            tri = 0.5 * (L * L - prefix * prefix)
            win = cfg.sliding_window
            if win and L > win:
                tri = min(tri, float(new) * win)
            quad = 4.0 * cfg.attention_layers * cfg.n_heads * cfg.head_dim * tri
        return lin + quad

    def prefill_time(self, L: int, prefix: int = 0) -> float:
        hw, n = self.inst.hw, self.inst.n_chips
        return self.prefill_flops(L, prefix) / (n * hw.peak_flops
                                                * hw.mfu_prefill)

    # ---- decode (memory-bound, Figure 2 right) ----
    def decode_iter_time(self, batch: int, avg_ctx: float) -> float:
        """One continuous-batching iteration: every active request emits one
        token. Weights are read once (amortized over the batch); KV is read
        per request."""
        hw, n = self.inst.hw, self.inst.n_chips
        cfg = self.cfg
        ctx = avg_ctx
        if cfg.sliding_window:
            ctx = min(ctx, cfg.sliding_window)
        kv = batch * ctx * self.kv_token_bytes
        if cfg.kind == "ssm":
            from repro.core.cache import ssm_state_bytes
            kv = batch * ssm_state_bytes(cfg)
        bytes_read = self.weight_bytes + kv
        t_mem = bytes_read / (n * hw.hbm_bw * hw.mbu_decode)
        t_cmp = 2.0 * self.n_params_active * batch / (n * hw.peak_flops * 0.3)
        return max(t_mem, t_cmp)

    def decode_capacity_tokens(self, kv_frac: float = 0.8) -> float:
        """KV tokens that fit in the instance's free HBM after weights.

        ``kv_frac`` is the fraction of free HBM budgeted for KV: a dedicated
        decode node spends nearly all of it on KV (0.8); a coupled
        prefill+decode node must reserve prefill activation space (≈0.5) —
        exactly the VRAM asymmetry §5.2's layer-wise prefill exploits."""
        hw, n = self.inst.hw, self.inst.n_chips
        free = n * hw.hbm_bytes - self.weight_bytes
        if self.kv_token_bytes == 0:
            return float("inf")
        return max(free * kv_frac, 0.0) / self.kv_token_bytes

    # ---- transfers (Messenger) ----
    def kv_bytes(self, tokens: int) -> float:
        return tokens * self.kv_token_bytes

    def transfer_time(self, tokens: int, bw: float | None = None) -> float:
        bw = bw if bw is not None else self.inst.hw.net_bw
        return self.kv_bytes(tokens) / bw

    def dram_load_time(self, tokens: int) -> float:
        """Local DRAM→HBM load of a cached prefix."""
        return self.kv_bytes(tokens) / self.inst.hw.dram_bw

    def ssd_load_time(self, tokens: int) -> float:
        """Local SSD→DRAM/HBM load of a demoted prefix (the 'load' arm of
        the compute-vs-load decision). Prefers the MEASURED per-block read
        time when ``calibrate_ssd_read`` has fed one back (closing the
        modeled-vs-measured loop the paper closes with offline data)."""
        if self._ssd_s_per_token is not None:
            return tokens * self._ssd_s_per_token
        return self.kv_bytes(tokens) / self.inst.hw.ssd_read_bw

    def calibrate_ssd_read(self, seconds_per_block: float,
                           block_tokens: int = 512) -> None:
        """Pin the SSD-load arm's price to a measured seconds-per-block
        (e.g. ``SSDBlockStore``'s read EMA); every later ``ssd_load_time``
        — and therefore every simulator/Conductor arm priced off it —
        uses the measured value instead of the spec-sheet bandwidth."""
        if seconds_per_block <= 0:
            raise ValueError("seconds_per_block must be positive")
        self._ssd_s_per_token = seconds_per_block / block_tokens

    @property
    def ssd_calibrated(self) -> bool:
        return self._ssd_s_per_token is not None

    def ssd_write_time(self, tokens: int) -> float:
        """Demotion write-back DRAM→SSD."""
        return self.kv_bytes(tokens) / self.inst.hw.ssd_write_bw

    def peer_ssd_load_time(self, tokens: int) -> float:
        """Cross-node prefix fetch off a PEER's SSD (the global pool's
        fourth arm): the peer's SSD read followed by the network hop.
        ``Messenger.estimate_peer_ssd`` is the backlog-aware version; this
        is the channel-free fallback price."""
        return self.ssd_load_time(tokens) + self.transfer_time(tokens)
