"""Paged vs dense decode substrate — join latency, page-budget capacity,
per-step latency, bit-exactness.

The decode-side counterpart of the pool benches: §3's "load pool blocks
into these pages" made executable. Three claims are measured against the
dense (L, B, max_len) arena at EQUAL batch:

* ``join()`` — the paged worker ADOPTS the prefill-staged page run (a
  host-side block-table splice + refcounts) where the dense worker
  copies the request's full-depth KV into its arena: paged join must be
  strictly faster (assertion, wall-clock table, not gated).
* capacity — shared-prefix workloads: slots on the same hash chain share
  physical prefix pages, so a fixed page budget must hold ≥ 2× the
  sequences the private-arena equivalent holds (assertion; deterministic
  counts → the ``paged_decode_capacity`` table is CI-gated).
* ``step()`` — paged attention over the live page span (table sliced to
  the deepest active slot) must be no slower than dense attention over
  ``max_len`` at max_len-scale depths (assertion, wall-clock).

Every token emitted by the paged substrate must be bit-exact against the
dense oracle.

Two more lanes ride along:

* width buckets — a depth-skewed batch steps as per-width sub-batches
  (``width_buckets=2``) instead of padding every slot to the deepest
  slot's pow2 width; tokens must stay bit-exact (wall-clock table).
* mesh capacity — on 4 virtual CPU devices (subprocess), the (data,
  model)-sharded pool must serve ≥ 1.9x the KV tokens per device-byte
  when either axis doubles (deterministic, CI-gated), with every mesh's
  streams identical and per-step time within the host-overhead bound.

    PYTHONPATH=src python -m benchmarks.bench_paged_decode [--quick]
"""
from __future__ import annotations

import time

import numpy as np

from benchmarks.common import emit
from repro.core.trace import BLOCK_TOKENS

PAGE_TOKENS = 64


def _workload(vocab, shared_blocks, n_reqs, suffix=64, seed=0):
    """n_reqs prompts sharing a shared_blocks-deep prefix chain, each with
    a distinct suffix (the Figure-6 hot-system-prompt shape)."""
    rng = np.random.default_rng(seed)
    shared = rng.integers(0, vocab, shared_blocks * BLOCK_TOKENS)
    return [np.concatenate([shared, rng.integers(0, vocab, suffix)])
            for _ in range(n_reqs)]


def _build(substrate, params, cfg, reqs, *, max_batch, max_len,
           page_pool=None):
    from repro.serving.engine import DecodeWorker, HostKVPool, PrefillWorker

    pool = HostKVPool()
    pw = PrefillWorker(params, cfg, pool, prefill_chunk=256,
                       page_pool=page_pool)
    dw = DecodeWorker(params, cfg, max_batch=max_batch, max_len=max_len,
                      substrate=substrate, page_pool=page_pool)
    return dw, [pw(t) for t in reqs]


def _probe_step(dw, reps=8):
    """Steady-state per-step latency of a worker's jitted step at its
    CURRENT depth: re-time the (pure) step executable on frozen inputs,
    best-of-reps — immune to one-shot scheduler noise on a shared box."""
    import jax
    import jax.numpy as jnp

    if dw.substrate == "paged":
        pp = dw.page_pool
        pt = pp.page_tokens
        active = [i for i, s in enumerate(dw.slots) if s is not None]
        need = max(int(dw.seq_lens[i]) // pt + 1 for i in active)
        width = 1
        while width < need:
            width *= 2
        width = min(width, dw.max_pages)
        tbl = jnp.asarray(dw.block_table[:, :width].copy())
        lens = jnp.asarray(dw.seq_lens.copy())
        args = (dw.params, dw.tokens, pp.k_pages, pp.v_pages, tbl, lens)
        fn = dw._step_paged
    else:
        args = (dw.params, dw.tokens, dw.caches)
        fn = dw._step
    best = float("inf")
    for _ in range(reps + 1):            # +1 warmup (compile already done)
        t0 = time.perf_counter()
        out = fn(*args)
        jax.block_until_ready(out[0])
        best = min(best, time.perf_counter() - t0)
    return best


def _head_to_head(params, cfg, reqs, *, max_batch, max_len, max_new,
                  page_pool):
    """Join and step the paged and dense workers INTERLEAVED, so load
    noise on a shared box hits both substrates alike; join latency is
    compared on the min and step latency via best-of-N probes of the
    step executables at full depth."""
    import jax

    dw_p, res_p = _build("paged", params, cfg, reqs, max_batch=max_batch,
                         max_len=max_len, page_pool=page_pool)
    dw_d, res_d = _build("dense", params, cfg, reqs, max_batch=max_batch,
                         max_len=max_len)

    times = {"paged": dict(join=[], step=[]), "dense": dict(join=[], step=[])}
    streams = {"paged": {}, "dense": {}}
    for i in range(len(reqs)):
        for name, dw, r in (("paged", dw_p, res_p[i]),
                            ("dense", dw_d, res_d[i])):
            t0 = time.perf_counter()
            dw.join(i, r, max_new=max_new)
            jax.block_until_ready(dw.tokens)
            times[name]["join"].append(time.perf_counter() - t0)
            streams[name][i] = [r.first_token]
    n_steps = 0
    while dw_p.n_active or dw_d.n_active:
        n_steps += 1
        if n_steps == max_new - 1:       # deepest full batch: probe here
            for name, dw in (("paged", dw_p), ("dense", dw_d)):
                times[name]["step"].append(_probe_step(dw))
        for name, dw in (("paged", dw_p), ("dense", dw_d)):
            if not dw.n_active:
                continue
            out = dw.step()
            for rid, tok, _ in out:
                streams[name][rid].append(tok)
    return times, streams, dw_p


def _capacity(params, cfg, budget_pages, *, shared_blocks, cap, max_new=2):
    """How many shared-prefix sequences fit a fixed page budget, vs the
    private-arena equivalent. Deterministic counts (CI-gated)."""
    from repro.serving.engine import DecodeWorker, HostKVPool, PrefillWorker
    from repro.serving.paged_cache import DevicePagePool

    suffix = PAGE_TOKENS                   # one private tail page per seq
    prompt_len = shared_blocks * BLOCK_TOKENS + suffix
    prompt_pages = (prompt_len + PAGE_TOKENS - 1) // PAGE_TOKENS
    dense_fit = budget_pages // prompt_pages   # private pages per sequence

    pp = DevicePagePool(cfg, n_pages=budget_pages + 1,
                        page_tokens=PAGE_TOKENS)
    pool = HostKVPool()
    pw = PrefillWorker(params, cfg, pool, prefill_chunk=256, page_pool=pp)
    dw = DecodeWorker(params, cfg, max_batch=cap, max_len=prompt_len + 64,
                      substrate="paged", page_pool=pp)
    reqs = _workload(cfg.vocab_size, shared_blocks, cap, suffix=suffix,
                     seed=1)
    paged_fit = 0
    for i, t in enumerate(reqs):
        r = pw(t)
        try:
            dw.join(i, r, max_new=max_new)
        except MemoryError:
            break
        paged_fit += 1
    logical = int(sum(dw.n_pages_slot[:]))
    return dict(budget_pages=budget_pages, prompt_pages=prompt_pages,
                dense_fit=dense_fit, paged_fit=paged_fit,
                fit_ratio=round(paged_fit / max(dense_fit, 1), 2),
                logical_pages=logical, physical_pages=pp.used_pages)


def _buckets(params, cfg, *, max_new=6):
    """Per-slot width buckets vs the single global pow2 width on a
    depth-skewed batch: one 10-page slot forces the global width to 16,
    so the shallow slots attend 8x the pages they own. Buckets split the
    step into per-width sub-batches; tokens must stay bit-exact."""
    from repro.serving.engine import DecodeWorker, HostKVPool, PrefillWorker
    from repro.serving.paged_cache import DevicePagePool
    from repro.serving.request import ServingRequest

    rng = np.random.default_rng(4)
    prompts = {0: rng.integers(0, cfg.vocab_size, 600),    # 10 pages
               1: rng.integers(0, cfg.vocab_size, 600),
               2: rng.integers(0, cfg.vocab_size, 70),     # 2 pages
               3: rng.integers(0, cfg.vocab_size, 40)}     # 1 page

    rows, streams = [], {}
    for wb in (1, 2):
        pp = DevicePagePool(cfg, n_pages=1 + 5 * 16, page_tokens=PAGE_TOKENS)
        pool = HostKVPool()
        pw = PrefillWorker(params, cfg, pool, prefill_chunk=256,
                           page_pool=pp)
        dw = DecodeWorker(params, cfg, max_batch=4, max_len=1024,
                          substrate="paged", page_pool=pp, width_buckets=wb)
        outs = {}
        for rid, toks in prompts.items():
            r = pw(toks)
            dw.join(ServingRequest(req_id=rid, tokens=toks,
                                   max_new=max_new), r)
            outs[rid] = [r.first_token]
        steps, t_step = 0, float("inf")
        while dw.n_active:
            steps += 1
            t0 = time.perf_counter()
            out = dw.step()
            t_step = min(t_step, time.perf_counter() - t0)
            for rid, tok, _ in out:
                outs[rid].append(tok)
        streams[wb] = outs
        rows.append(dict(width_buckets=wb, steps=steps,
                         bucket_substeps=dw.stats()["bucket_substeps"],
                         step_ms_min=1e3 * t_step))
        pp.check_leaks()
    assert streams[2] == streams[1], \
        "width-bucketed step diverged from the single-width oracle"
    assert rows[1]["bucket_substeps"] >= 2 * rows[1]["steps"], rows
    return rows


_MESH_SUB = r"""
import dataclasses, json, time
import jax
import numpy as np
from repro.configs.base import get_config
from repro.launch.mesh import make_decode_mesh
from repro.models.transformer import init_params
from repro.serving.engine import DecodeWorker, HostKVPool, PrefillWorker
from repro.serving.paged_cache import DevicePagePool
from repro.serving.request import ServingRequest

assert jax.device_count() == 4, jax.devices()
cfg = dataclasses.replace(get_config("smollm-360m").reduced(),
                          n_heads=16, n_kv_heads=4)
params = init_params(cfg, jax.random.PRNGKey(0))
rng = np.random.default_rng(2)
prompts = [rng.integers(0, cfg.vocab_size, 200) for _ in range(4)]
BANK_PAGES = 65                     # fixed PER-BANK budget incl. null page

rows = []
for d, m in [(1, 1), (2, 1), (1, 2), (2, 2)]:
    mesh = make_decode_mesh(d, m)
    pp = DevicePagePool(cfg, n_pages=BANK_PAGES, mesh=mesh, page_tokens=64)
    pool = HostKVPool()
    pw = PrefillWorker(params, cfg, pool, prefill_chunk=256, page_pool=pp)
    dw = DecodeWorker(params, cfg, max_batch=4, max_len=1024,
                      substrate="paged", page_pool=pp)
    outs = {}
    for rid, toks in enumerate(prompts):
        r = pw(toks)
        dw.join(ServingRequest(req_id=rid, tokens=toks, max_new=5), r)
        outs[rid] = [r.first_token]
    t_step = float("inf")
    while dw.n_active:
        t0 = time.perf_counter()
        out = dw.step()
        t_step = min(t_step, time.perf_counter() - t0)
        for rid, tok, _ in out:
            outs[rid].append(tok)
    pp.check_leaks()
    # per-device KV bytes: one addressable shard of each slab
    shard_b = (pp.k_pages.addressable_shards[0].data.nbytes
               + pp.v_pages.addressable_shards[0].data.nbytes)
    cap = pp.pressure()["capacity"]
    rows.append(dict(mesh=f"{d}x{m}", banks=d, model_shards=m,
                     bank_pages=BANK_PAGES, capacity_pages=cap,
                     capacity_tokens=cap * 64,
                     per_device_kv_kib=shard_b // 1024,
                     step_ms_min=1e3 * t_step,
                     tokens=outs))
print("ROWS_JSON:" + json.dumps(rows))
"""


def _mesh_table():
    """Device-mesh capacity scaling on 4 virtual CPU devices (subprocess:
    the parent's jax is already initialised single-device). Deterministic
    columns are CI-gated; step wall-clock is reported separately."""
    import json
    import os
    import subprocess
    import sys

    env = dict(os.environ)
    env["XLA_FLAGS"] = "--xla_force_host_platform_device_count=4"
    res = subprocess.run([sys.executable, "-c", _MESH_SUB], env=env,
                         capture_output=True, text=True, timeout=900)
    if res.returncode != 0:
        raise RuntimeError(f"mesh subprocess failed:\nSTDOUT:{res.stdout}\n"
                           f"STDERR:{res.stderr}")
    line = [l for l in res.stdout.splitlines()
            if l.startswith("ROWS_JSON:")][0]
    rows = json.loads(line[len("ROWS_JSON:"):])

    # shard invariance rides along: every mesh emitted the same streams
    base = rows[0].pop("tokens")
    for r in rows[1:]:
        assert r.pop("tokens") == base, f"mesh {r['mesh']} diverged"

    # capacity per device-byte: logical KV tokens the mesh serves per KiB
    # of any one device's slab share — data banks add pages, model
    # stripes thin each device's share of every page
    t0 = rows[0]["capacity_tokens"] / rows[0]["per_device_kv_kib"]
    for r in rows:
        r["capacity_per_device_x"] = round(
            (r["capacity_tokens"] / r["per_device_kv_kib"]) / t0, 2)
    step_rows = [dict(mesh=r["mesh"], step_ms_min=r.pop("step_ms_min"))
                 for r in rows]
    return rows, step_rows


def main(fast: bool = False) -> int:
    import jax

    from repro.configs.base import get_config
    from repro.models.transformer import init_params
    from repro.serving.paged_cache import DevicePagePool

    cfg = get_config("smollm-360m").reduced()
    params = init_params(cfg, jax.random.PRNGKey(0))

    # ---- engine head-to-head: join / step / tokens ----
    if fast:
        shared_blocks, max_batch, max_new, max_len = 2, 4, 6, 1536
        extras, cap = [7, 23], 16
    else:
        shared_blocks, max_batch, max_new, max_len = 3, 4, 8, 2048
        extras, cap = [7, 23, 55], 32
    # capacity budgets: one prompt's pages + headroom (sequences beyond the
    # first cost only their private tail under prefix sharing)
    prompt_pages = shared_blocks * (BLOCK_TOKENS // PAGE_TOKENS) + 1
    budgets = [prompt_pages + e for e in extras]
    reqs = _workload(cfg.vocab_size, shared_blocks, max_batch)

    # page pool sized to the live working set, not the dense arena: the
    # shared prefix is physically resident ONCE, each slot adds only its
    # private tail + generated tokens — the §3 memory story in numbers
    suffix_pages = (64 + max_new + PAGE_TOKENS - 1) // PAGE_TOKENS + 1
    n_pages = (1 + shared_blocks * (BLOCK_TOKENS // PAGE_TOKENS)
               + max_batch * (suffix_pages + 1))
    pp = DevicePagePool(cfg, n_pages=n_pages, page_tokens=PAGE_TOKENS)
    times, streams, dw_p = _head_to_head(
        params, cfg, reqs, max_batch=max_batch, max_len=max_len,
        max_new=max_new, page_pool=pp)

    tokens_match = streams["paged"] == streams["dense"]
    if not tokens_match:
        for i in streams["paged"]:
            if streams["paged"][i] != streams["dense"][i]:
                print(f"req {i} diverged:\n  paged: {streams['paged'][i]}"
                      f"\n  dense: {streams['dense'][i]}")
    jp, jd = (float(np.min(times[s]["join"])) for s in ("paged", "dense"))
    sp, sd = (float(np.min(times[s]["step"])) for s in ("paged", "dense"))
    rows = [dict(substrate="paged", join_ms_min=1e3 * jp,
                 step_ms_min=1e3 * sp, tokens_match=tokens_match,
                 kv_tokens_held=pp.n_pages * PAGE_TOKENS,
                 zero_copy_joins=dw_p.stats()["zero_copy_joins"],
                 shared_adoptions=pp.stats()["shared_adoptions"]),
            dict(substrate="dense", join_ms_min=1e3 * jd,
                 step_ms_min=1e3 * sd, tokens_match=True,
                 kv_tokens_held=max_batch * max_len,
                 zero_copy_joins=0, shared_adoptions=0)]
    emit("paged_decode_engine", rows)
    print(f"join: paged {1e3 * jp:.2f} ms vs dense {1e3 * jd:.2f} ms "
          f"({jd / max(jp, 1e-9):.1f}x); step min: paged {1e3 * sp:.2f} ms "
          f"vs dense {1e3 * sd:.2f} ms; tokens_match={tokens_match}")
    assert tokens_match, "paged substrate diverged from the dense oracle"
    assert jp < jd, f"paged join ({jp:.4f}s) must beat dense ({jd:.4f}s)"
    assert sp <= 1.15 * sd, \
        f"paged step {sp:.4f}s worse than dense {sd:.4f}s at depth"

    # ---- capacity at equal page budget (deterministic, CI-gated) ----
    cap_rows = [_capacity(params, cfg, b, shared_blocks=shared_blocks,
                          cap=cap) for b in budgets]
    emit("paged_decode_capacity", cap_rows)
    for r in cap_rows:
        assert r["paged_fit"] >= 2 * max(r["dense_fit"], 1), (
            f"shared-prefix capacity win < 2x: {r}")
        if r["paged_fit"] > 1:        # sharing collapses physical residency
            assert r["physical_pages"] < r["logical_pages"], r

    # ---- per-slot width buckets on a depth-skewed batch ----
    bucket_rows = _buckets(params, cfg)
    emit("paged_decode_buckets", bucket_rows)
    b1, b2 = bucket_rows
    print(f"buckets: 1-width step {b1['step_ms_min']:.2f} ms vs 2-width "
          f"{b2['step_ms_min']:.2f} ms ({b2['bucket_substeps']} substeps, "
          f"tokens bit-exact)")

    # ---- (data, model) mesh capacity scaling (deterministic, CI-gated) ----
    mesh_rows, step_rows = _mesh_table()
    emit("paged_decode_mesh", mesh_rows)
    emit("paged_decode_mesh_step", step_rows)
    by = {r["mesh"]: r for r in mesh_rows}
    # doubling either axis must serve >= 1.9x the KV tokens per byte any
    # one device holds (exactly 2x minus per-bank null-page overhead)
    assert by["2x1"]["capacity_per_device_x"] >= 1.9, by["2x1"]
    assert by["1x2"]["capacity_per_device_x"] >= 1.9, by["1x2"]
    assert by["2x2"]["capacity_per_device_x"] >= 3.8, by["2x2"]
    s0 = step_rows[0]["step_ms_min"]
    for r in step_rows[1:]:
        assert r["step_ms_min"] <= 3.0 * s0 + 10.0, (
            f"mesh {r['mesh']} per-step time blew past the host-overhead "
            f"bound: {r['step_ms_min']:.2f} ms vs 1x1 {s0:.2f} ms")
    return 0


if __name__ == "__main__":
    import argparse

    ap = argparse.ArgumentParser(description=__doc__)
    ap.add_argument("--fast", "--quick", dest="fast", action="store_true")
    raise SystemExit(main(fast=ap.parse_args().fast))
