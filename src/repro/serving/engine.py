"""Serving engines — the executable counterpart of the simulator.

This is a real (CPU-runnable, reduced-model) implementation of the §3
workflow: a host-DRAM KVCache pool holding 512-token blocks keyed by
prefix-chained hashes, a prefill worker that reuses pool blocks and runs
*chunked incremental prefill* (§3 step 2), layer-wise store-back of fresh
blocks (§5.2 semantics), and a continuous-batching decode worker whose
batch slots sit at independent depths (per-slot cache lengths).

The disaggregated pair (PrefillWorker feeding DecodeWorker through the
pool) is what examples/serve_cluster.py drives with a Conductor in front.
"""
from __future__ import annotations

import hashlib
from dataclasses import dataclass, field
from typing import Optional

import jax
import jax.numpy as jnp
import numpy as np

from repro.configs.base import ModelConfig
from repro.core.cache import CachePool
from repro.core.trace import BLOCK_TOKENS
from repro.models.layers import DTYPE
from repro.models.transformer import (Caches, KVCache, decode_step,
                                      init_caches, prefill)


def prefix_hash_ids(tokens: np.ndarray, block: int = BLOCK_TOKENS) -> list[int]:
    """Chained block hashes of a token sequence (Figure 3): block i's key
    commits to all tokens ≤ its end, so equal ids ⇔ equal prefixes."""
    out: list[int] = []
    h = hashlib.sha256()
    n_full = len(tokens) // block
    for i in range(n_full):
        h.update(np.ascontiguousarray(tokens[i * block:(i + 1) * block]).tobytes())
        out.append(int.from_bytes(h.copy().digest()[:8], "little"))
    return out


class HostKVPool:
    """CPU-DRAM KVCache pool: prefix-hash → per-layer KV block bytes.
    Metadata/eviction delegated to ``CachePool``; evicted keys drop their
    bytes. Models Figure 3's 'KVCache pool in CPU memory'.

    With ``ssd_capacity_blocks`` a second (SSD) tier is added: DRAM
    evictions demote to it instead of dropping, and only blocks evicted
    from the *whole hierarchy* lose their bytes — so long-context cold
    prefixes stay loadable (here both tiers are host arrays; the tier
    split is the metadata/cost model's concern)."""

    def __init__(self, capacity_blocks: Optional[int] = None,
                 policy: str = "lru", ssd_capacity_blocks: int = 0,
                 ssd_policy: str = "lru", writeback_batch: int = 8) -> None:
        from repro.configs.base import CacheTierSpec
        self.meta: CachePool = CacheTierSpec(
            dram_blocks=capacity_blocks, ssd_blocks=ssd_capacity_blocks,
            dram_policy=policy, ssd_policy=ssd_policy,
            writeback_batch=writeback_batch).make_pool()
        self.data: dict[int, tuple[np.ndarray, np.ndarray]] = {}

    def match_prefix(self, hash_ids: list[int]) -> int:
        return self.meta.lookup(hash_ids)

    def get(self, hash_ids: list[int]):
        """Stack blocks → (L, n*512, KV, Dh) k and v."""
        ks = [self.data[h][0] for h in hash_ids]
        vs = [self.data[h][1] for h in hash_ids]
        return np.concatenate(ks, axis=1), np.concatenate(vs, axis=1)

    def put(self, hash_ids: list[int], k: np.ndarray, v: np.ndarray,
            start_pos: int = 0) -> None:
        """k/v: (L, n*512, KV, Dh) covering ``hash_ids`` in order."""
        evicted = self.meta.insert(hash_ids, start_pos=start_pos)
        for e in evicted:
            self.data.pop(e, None)
        for i, h in enumerate(hash_ids):
            if h in self.meta and h not in self.data:
                sl = slice(i * BLOCK_TOKENS, (i + 1) * BLOCK_TOKENS)
                self.data[h] = (np.ascontiguousarray(k[:, sl]),
                                np.ascontiguousarray(v[:, sl]))

    @property
    def n_blocks(self) -> int:
        return len(self.data)


@dataclass
class PrefillResult:
    first_token: int
    kv_k: np.ndarray            # (L, S, KV, Dh) full-depth KV of the request
    kv_v: np.ndarray
    prompt_len: int
    reused_blocks: int
    new_blocks: int


class PrefillWorker:
    """§3 steps 1–3: KVCache reuse → incremental (chunked) prefill →
    layer-wise store-back. One request at a time (B = 1)."""

    def __init__(self, params, cfg: ModelConfig, pool: HostKVPool, *,
                 prefill_chunk: int = 1024) -> None:
        self.params = params
        self.cfg = cfg
        self.pool = pool
        self.chunk = prefill_chunk
        self._prefill = jax.jit(
            lambda p, t, off: prefill(p, t, cfg, q_offset=off))
        self._extend = jax.jit(
            lambda p, t, c: decode_step(p, t, c, cfg))
        self.stats = dict(reused_blocks=0, computed_tokens=0, requests=0)

    def __call__(self, tokens: np.ndarray) -> PrefillResult:
        cfg = self.cfg
        assert cfg.attention_layers == cfg.n_layers, \
            "PrefillWorker KV path supports uniform attention stacks"
        S = len(tokens)
        hash_ids = prefix_hash_ids(tokens)
        n_hit = self.pool.match_prefix(hash_ids)
        prefix_tokens = n_hit * BLOCK_TOKENS
        if prefix_tokens >= S:           # full hit: recompute last block's
            n_hit = max((S - 1) // BLOCK_TOKENS, 0)  # tail to get logits
            prefix_tokens = n_hit * BLOCK_TOKENS

        t = jnp.asarray(tokens[None, :], jnp.int32)
        max_len = S
        caches = init_caches(cfg, 1, max_len)
        if n_hit:
            k_np, v_np = self.pool.get(hash_ids[:n_hit])
            kv = KVCache(
                k=caches.kv.k.at[:, 0, :prefix_tokens].set(jnp.asarray(k_np)),
                v=caches.kv.v.at[:, 0, :prefix_tokens].set(jnp.asarray(v_np)))
            caches = caches._replace(kv=kv,
                                     length=jnp.asarray(prefix_tokens, jnp.int32))
            # chunked incremental prefill over the uncached suffix
            logits = None
            for lo in range(prefix_tokens, S, self.chunk):
                hi = min(lo + self.chunk, S)
                logits, caches = self._extend(self.params, t[:, lo:hi], caches)
            first = int(jnp.argmax(logits[0, -1]))
            k_full = np.asarray(caches.kv.k[:, 0])
            v_full = np.asarray(caches.kv.v[:, 0])
        else:
            # cold prefill (still chunk-pipelined in the CPP variant)
            logits, pc = self._prefill(self.params, t, 0)
            first = int(jnp.argmax(logits[0]))
            k_full = np.asarray(pc.kv.k[:, 0])
            v_full = np.asarray(pc.kv.v[:, 0])

        # layer-wise store-back of every fresh full block (§5.2: on TPU the
        # per-layer store launches as soon as that layer's KV exists; here
        # the ordering contract is preserved by storing from the scanned
        # per-layer stack)
        n_total = len(hash_ids)
        if n_total > n_hit:
            sl = slice(n_hit * BLOCK_TOKENS, n_total * BLOCK_TOKENS)
            self.pool.put(hash_ids[n_hit:], k_full[:, sl], v_full[:, sl],
                          start_pos=n_hit)
        self.stats["reused_blocks"] += n_hit
        self.stats["computed_tokens"] += S - prefix_tokens
        self.stats["requests"] += 1
        return PrefillResult(first_token=first, kv_k=k_full, kv_v=v_full,
                             prompt_len=S, reused_blocks=n_hit,
                             new_blocks=n_total - n_hit)


@dataclass
class _Slot:
    req_id: int
    prompt_len: int
    max_new: int
    emitted: list = field(default_factory=list)

    @property
    def done(self) -> bool:
        return len(self.emitted) >= self.max_new


class DecodeWorker:
    """§3 step 4: continuous batching with per-slot cache depths.

    Fixed ``max_batch`` slots share a dense (B, max_len) KV arena; slots
    join/leave at iteration boundaries. ``step()`` is one iteration: every
    active slot emits one token.
    """

    def __init__(self, params, cfg: ModelConfig, *, max_batch: int,
                 max_len: int) -> None:
        self.params = params
        self.cfg = cfg
        self.max_batch = max_batch
        self.max_len = max_len
        self.caches = init_caches(cfg, max_batch, max_len)
        self.caches = self.caches._replace(
            length=jnp.zeros((max_batch,), jnp.int32))
        self.slots: list[Optional[_Slot]] = [None] * max_batch
        self.tokens = jnp.zeros((max_batch, 1), jnp.int32)
        self._step = jax.jit(lambda p, t, c: decode_step(p, t, c, cfg))

    @property
    def n_active(self) -> int:
        return sum(s is not None for s in self.slots)

    def join(self, req_id: int, pres: PrefillResult, max_new: int) -> int:
        """Load a prefilled request's KV into a free slot (§3: 'load the
        KVCache and add the request to the continuous batching process')."""
        slot = next(i for i, s in enumerate(self.slots) if s is None)
        L = pres.prompt_len
        if self.caches.kv is not None:
            kv = self.caches.kv
            kv = KVCache(
                k=kv.k.at[:, slot, :L].set(jnp.asarray(pres.kv_k[:, :L])),
                v=kv.v.at[:, slot, :L].set(jnp.asarray(pres.kv_v[:, :L])))
            self.caches = self.caches._replace(kv=kv)
        self.caches = self.caches._replace(
            length=self.caches.length.at[slot].set(L))
        self.tokens = self.tokens.at[slot, 0].set(pres.first_token)
        self.slots[slot] = _Slot(req_id=req_id, prompt_len=L, max_new=max_new,
                                 emitted=[pres.first_token])
        return slot

    def step(self) -> list[tuple[int, int, bool]]:
        """One continuous-batching iteration.
        Returns [(req_id, token, finished)] for active slots."""
        if self.n_active == 0:
            return []
        logits, self.caches = self._step(self.params, self.tokens, self.caches)
        nxt = jnp.argmax(logits[:, -1], axis=-1).astype(jnp.int32)
        self.tokens = nxt[:, None]
        out = []
        for i, s in enumerate(self.slots):
            if s is None:
                continue
            tok = int(nxt[i])
            s.emitted.append(tok)
            if s.done:
                out.append((s.req_id, tok, True))
                self.slots[i] = None
                self.caches = self.caches._replace(
                    length=self.caches.length.at[i].set(0))
            else:
                out.append((s.req_id, tok, False))
        return out


class StateCheckpointWorker:
    """Prefix caching for SSM architectures (DESIGN.md §Arch-applicability).

    Attention-free models have no append-only KVCache; Mooncake's
    prefix-reuse degenerates to *state checkpointing*: after every
    512-token block boundary we snapshot the (constant-size) recurrent
    state keyed by the same prefix-chained hash. A later request sharing
    a prefix restores the DEEPEST checkpoint on its chain and prefills
    only the suffix — transfer cost is O(state), independent of prefix
    length, which strengthens disaggregation for these archs.
    """

    def __init__(self, params, cfg: ModelConfig, *,
                 capacity_checkpoints: Optional[int] = None,
                 chunk: int = BLOCK_TOKENS) -> None:
        from repro.core.cache import StateCache
        assert cfg.kind == "ssm", "state checkpointing is the SSM path"
        self.params = params
        self.cfg = cfg
        self.chunk = chunk
        self.meta = StateCache(capacity_checkpoints)
        self.data: dict[int, tuple] = {}   # hash -> (ssm np, conv np)
        self._prefill = jax.jit(lambda p, t: prefill(p, t, cfg))
        self._extend = jax.jit(lambda p, t, c: decode_step(p, t, c, cfg))
        self.stats = dict(restored_tokens=0, computed_tokens=0)

    def _snapshot(self, hash_id: int, caches: Caches) -> None:
        evicted = self.meta.insert([hash_id])
        for e in evicted:
            self.data.pop(e, None)
        if hash_id in self.meta:
            self.data[hash_id] = (
                np.asarray(caches.ssm.ssm), np.asarray(caches.ssm.conv))

    def __call__(self, tokens: np.ndarray):
        """Prefill one request (B = 1) with state-checkpoint reuse.
        Returns (first_token, final Caches)."""
        cfg = self.cfg
        S = len(tokens)
        hash_ids = prefix_hash_ids(tokens, self.chunk)
        depth = self.meta.lookup(hash_ids)          # deepest checkpoint
        start = depth * self.chunk
        if start >= S:                              # full hit: redo last blk
            depth -= 1
            start = depth * self.chunk
        t = jnp.asarray(tokens[None, :], jnp.int32)

        if depth > 0:
            ssm_np, conv_np = self.data[hash_ids[depth - 1]]
            from repro.models.mamba import MambaState
            caches = Caches(
                kv=None, enc_kv=None,
                ssm=MambaState(ssm=jnp.asarray(ssm_np),
                               conv=jnp.asarray(conv_np)),
                length=jnp.asarray(start, jnp.int32))
            logits = None
        else:
            caches = None
            logits = None

        # chunked continuation, snapshotting at every block boundary
        lo = start
        while lo < S:
            hi = min(lo + self.chunk, S)
            if caches is None:
                logits, caches = self._prefill(self.params, t[:, :hi])
                logits = logits[:, None] if logits.ndim == 2 else logits
            else:
                logits, caches = self._extend(self.params, t[:, lo:hi],
                                              caches)
            if hi % self.chunk == 0:
                self._snapshot(hash_ids[hi // self.chunk - 1], caches)
            lo = hi
        self.stats["restored_tokens"] += start
        self.stats["computed_tokens"] += S - start
        first = int(jnp.argmax(logits[0, -1]))
        return first, caches
