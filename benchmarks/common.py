"""Shared benchmark utilities: CSV emission + standard cluster builders."""
from __future__ import annotations

import json
import os
import sys
import time

RESULTS_DIR = os.environ.get("BENCH_RESULTS", "benchmarks/results")


def emit(table: str, rows: list[dict]) -> None:
    """Print a paper-table reproduction as CSV and save JSON."""
    if not rows:
        print(f"[{table}] no rows")
        return
    cols: list[str] = []
    for r in rows:                      # union of keys, order-preserving
        for c in r:
            if c not in cols:
                cols.append(c)
    print(f"\n== {table} ==")
    print(",".join(cols))
    for r in rows:
        print(",".join(_fmt(r.get(c, "")) for c in cols))
    os.makedirs(RESULTS_DIR, exist_ok=True)
    with open(os.path.join(RESULTS_DIR, table + ".json"), "w") as f:
        json.dump(rows, f, indent=1)


def _fmt(v) -> str:
    if isinstance(v, float):
        return f"{v:.4g}"
    return str(v)


class timer:
    def __enter__(self):
        self.t0 = time.time()
        return self

    def __exit__(self, *a):
        self.s = time.time() - self.t0
