"""Qwen2.5-3B (GQA, QKV bias). [hf:Qwen/Qwen2.5-0.5B family]"""
from repro.configs.base import ModelConfig, register

CONFIG = register(ModelConfig(
    name="qwen2.5-3b",
    kind="dense",
    n_layers=36,
    d_model=2048,
    n_heads=16,
    n_kv_heads=2,
    head_dim=128,
    d_ff=11008,
    vocab_size=151936,
    attn_bias=True,
    rope_theta=1e6,
    source="hf:Qwen/Qwen2.5-0.5B (assignment: 36L d2048 16H kv2 bias)",
))
