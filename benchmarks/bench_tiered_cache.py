"""Tiered DRAM+SSD KVCache store: hit-rate and goodput vs the flat pool.

Two tables:

* ``tiered_cache_hit_rate`` — replay a long-context synthetic trace
  (doc-heavy sessions, working set ≫ DRAM) through a flat ``CachePool``
  and ``TieredCachePool``s at several DRAM:SSD capacity ratios, all at
  EQUAL DRAM budget. The tiered pool keeps demoted prefixes loadable, so
  its block hit rate strictly dominates the flat pool's.

* ``tiered_cache_goodput`` — the same workload shape through the
  ``MooncakeCluster`` simulator: min(recompute, fetch-peer, load-SSD)
  scheduling with SSD latency on the per-node SSD read channel. Reports
  goodput under the standard SLOs, avg TTFT, and how often the
  compute-vs-load decision chose 'load'.

    PYTHONPATH=src python -m benchmarks.bench_tiered_cache [--fast]
"""
from __future__ import annotations

from benchmarks.common import emit
from repro.configs.base import CacheTierSpec, get_config
from repro.core.cache import CachePool
from repro.core.tiered import TieredCachePool
from repro.core.simulator import MooncakeCluster
from repro.core.trace import TraceSpec, generate_trace

# long-context, session-heavy workload: most traffic is doc sessions whose
# prefixes get revisited after the DRAM working set has turned over
LONG_CONTEXT_SPEC = dict(frac_chat=0.25, frac_doc=0.55, frac_oneshot=0.20,
                         doc_len_mu=9.6, doc_len_sigma=0.6)

SSD_RATIOS = [0, 1, 2, 4, 8]       # SSD capacity as a multiple of DRAM


def _replay(pool, requests) -> dict:
    for r in requests:
        n = pool.lookup(r.hash_ids)
        pool.insert(r.hash_ids[n:], start_pos=n)
    return pool


def run_hit_rate(requests, dram_blocks: int) -> list[dict]:
    rows = []
    flat = _replay(CachePool(dram_blocks, "lru"), requests)
    rows.append(dict(pool="flat", dram_blocks=dram_blocks, ssd_blocks=0,
                     hit_rate=round(flat.hit_rate, 4),
                     evictions=flat.evictions))
    for ratio in SSD_RATIOS[1:]:
        pool = _replay(TieredCachePool(dram_blocks, ratio * dram_blocks,
                                       writeback_batch=8),
                       requests)
        s = pool.tier_stats()
        rows.append(dict(pool=f"tiered_1:{ratio}", dram_blocks=dram_blocks,
                         ssd_blocks=ratio * dram_blocks,
                         hit_rate=round(pool.hit_rate, 4),
                         dram_hits=s["dram_hits"], ssd_hits=s["ssd_hits"],
                         demotions=s["demotions"],
                         promotions=s["promotions"],
                         writebacks=s["n_writebacks"]))
    return rows


def run_goodput(requests, dram_blocks: int, *, speedup: float,
                ttft_slo: float = 30.0, tbt_slo: float = 0.2) -> list[dict]:
    cfg = get_config("llama2-70b")
    # common window for every configuration: the makespan moves with the
    # last completion, which is A/B noise — goodput over the shared trace
    # horizon is the fair comparison
    window = max(r.timestamp for r in requests) / 1000.0 / speedup + 120.0
    rows = []
    for ratio in SSD_RATIOS:
        spec = CacheTierSpec(dram_blocks=dram_blocks,
                             ssd_blocks=ratio * dram_blocks)
        cl = MooncakeCluster(cfg, n_prefill=4, n_decode=4,
                             ttft_slo=ttft_slo, tbt_slo=tbt_slo,
                             cache_spec=spec)
        res = cl.run(requests, speedup=speedup)
        rows.append(dict(
            pool="flat" if ratio == 0 else f"tiered_1:{ratio}",
            dram_blocks=dram_blocks, ssd_blocks=ratio * dram_blocks,
            goodput_rps=round(res.goodput(ttft_slo, tbt_slo, window), 4),
            slo_ok=res.slo_ok_count(ttft_slo, tbt_slo),
            avg_ttft_s=round(res.avg_ttft(), 3),
            ttft_p90_s=round(res.ttft_p90(), 3),
            ssd_loads=res.n_ssd_loads,
            hit_blocks=sum(p.pool.hits for p in cl.prefills),
            completed=len(res.completed()), rejected=len(res.rejected())))
    return rows


def main(fast: bool = False):
    # 2 requests/second at either size — the simulated 4+4 cluster's
    # moderate-load operating point (overload behaviour is bench_overload's
    # subject, not this one's)
    spec = TraceSpec(n_requests=1200 if fast else 6000, seed=7,
                     duration_ms=600_000 if fast else 3_000_000,
                     **LONG_CONTEXT_SPEC)
    requests = generate_trace(spec)
    # DRAM well below the trace's unique-block working set
    uniq = len({h for r in requests for h in r.hash_ids})
    dram = max(uniq // 20, 64)
    print(f"[tiered_cache] {len(requests)} requests, {uniq} unique blocks, "
          f"DRAM budget {dram} blocks (hit-rate replay)")

    hit_rows = run_hit_rate(requests, dram)
    emit("tiered_cache_hit_rate", hit_rows)
    flat_hr = hit_rows[0]["hit_rate"]
    for row in hit_rows[1:]:
        assert row["hit_rate"] > flat_hr, \
            f"tiered pool must beat flat at equal DRAM: {row}"

    # goodput: moderate load (no admission rejects) so the comparison is
    # TTFT-shaped, with DRAM small enough that cold revisits hit SSD
    gp_reqs = requests if fast else requests[:2500]
    uniq_gp = len({h for r in gp_reqs for h in r.hash_ids})
    goodput_rows = run_goodput(gp_reqs, max(uniq_gp // 50, 64), speedup=1.5)
    emit("tiered_cache_goodput", goodput_rows)
    flat_gp = goodput_rows[0]["goodput_rps"]
    for row in goodput_rows[1:]:
        assert row["goodput_rps"] >= flat_gp, \
            f"SSD tier must not hurt goodput: {row}"
    return hit_rows + goodput_rows


if __name__ == "__main__":
    import argparse
    ap = argparse.ArgumentParser()
    ap.add_argument("--fast", "--quick", dest="fast", action="store_true",
                    help="reduced trace sizes (CI smoke lane)")
    main(fast=ap.parse_args().fast)
