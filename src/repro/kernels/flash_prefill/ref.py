"""Pure-jnp oracle for the chunked-prefill flash attention kernel."""
from __future__ import annotations

import jax.numpy as jnp


def flash_prefill_ref(q, k, v, *, q_offset: int = 0, window: int = 0):
    """Causal (optionally sliding-window) GQA attention.

    q: (B, Sq, H, D) — queries at absolute positions q_offset + [0, Sq)
    k, v: (B, Sk, KV, D) — keys/values at absolute positions [0, Sk)
    window: 0 = full causal; else only attend within ``window`` positions.
    Returns (B, Sq, H, D) in q.dtype.
    """
    B, Sq, H, D = q.shape
    KV = k.shape[2]
    group = H // KV
    kh = jnp.repeat(jnp.arange(KV), group)           # (H,) q-head → kv-head
    k_exp = k[:, :, kh, :]                           # (B, Sk, H, D)
    v_exp = v[:, :, kh, :]
    qpos = q_offset + jnp.arange(Sq)
    kpos = jnp.arange(k.shape[1])
    mask = qpos[:, None] >= kpos[None, :]
    if window:
        mask &= (qpos[:, None] - kpos[None, :]) < window
    logits = jnp.einsum("bqhd,bkhd->bhqk", q.astype(jnp.float32),
                        k_exp.astype(jnp.float32)) / (D ** 0.5)
    logits = jnp.where(mask[None, None], logits, -jnp.inf)
    probs = jnp.exp(logits - logits.max(-1, keepdims=True))
    probs = jnp.where(jnp.isfinite(logits), probs, 0.0)
    den = probs.sum(-1, keepdims=True)
    probs = probs / jnp.maximum(den, 1e-30)
    out = jnp.einsum("bhqk,bkhd->bqhd", probs, v_exp.astype(jnp.float32))
    return out.astype(q.dtype)
