"""Serving substrate: paged device KV cache, chunked-prefill +
continuous-batching engines, CPP pipelined prefill (§5.1), layer-wise
prefill semantics (§5.2)."""
from repro.serving.engine import (ChunkedPrefill, DecodeWorker, FetchPlan,
                                  HostKVPool, PeerSource, PrefillResult,
                                  PrefillWorker, PrefixHasher,
                                  StateCheckpointWorker, connect_pools,
                                  prefix_hash_ids, stage_run)
from repro.serving.layerwise import occupation_cost, schedule
from repro.serving.loop import RequestOutput, ServingLoop
from repro.serving.paged_cache import (DevicePagePool, PagedKVCache,
                                       assign_seq, free_seq, gather_kv,
                                       grow_seq, init_paged_cache, write_kv)
