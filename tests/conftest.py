"""Shared fixtures. NOTE: no global XLA device-count flags here — smoke
tests and benches must see the real single CPU device; multi-device tests
(CPP, shard_map, dry-run) spawn subprocesses with their own XLA_FLAGS."""
import os
import subprocess
import sys
import threading
import time

import pytest

REPO = os.path.dirname(os.path.dirname(os.path.abspath(__file__)))
SRC = os.path.join(REPO, "src")


@pytest.fixture(autouse=True)
def _no_thread_leaks(request):
    """Every test must clean up the threads it starts: a surviving
    non-daemon thread, or any thread this repo spawned (``repro-``
    name prefix, daemon or not — the PR-6 ``AsyncPrefetcher.close()``
    leak was a daemon), fails the test. Mark tests whose fixtures
    legitimately outlive them with ``@pytest.mark.leaks_threads``."""
    if request.node.get_closest_marker("leaks_threads"):
        yield
        return
    before = set(threading.enumerate())
    yield

    def leaked():
        return [t for t in threading.enumerate()
                if t not in before and t.is_alive()
                and (not t.daemon or t.name.startswith("repro-"))]

    # short grace period: a close()/join() issued at test end may still
    # be unwinding on a loaded machine
    deadline = time.monotonic() + 2.0
    while leaked() and time.monotonic() < deadline:
        time.sleep(0.02)
    rest = leaked()
    assert not rest, \
        f"test leaked threads: {[t.name for t in rest]} -- close/join " \
        f"every worker (or mark the test leaks_threads)"


def _open_fds() -> dict:
    """(fd -> readlink target) of every interesting open fd. psutil-free:
    /proc/self/fd is the ground truth on Linux. Kernel-/runtime-internal
    fds (epoll, eventfd, jax plugins, devices) are ignored — sockets,
    pipes, and regular files are what tests leak."""
    out = {}
    try:
        fds = os.listdir("/proc/self/fd")
    except OSError:                      # non-procfs platform: detector off
        return out
    for fd in fds:
        try:
            target = os.readlink(f"/proc/self/fd/{fd}")
        except OSError:
            continue                     # raced with a close
        if target.startswith(("anon_inode:", "/dev/", "/proc/", "/sys/",
                              "/memfd:")):
            continue
        out[int(fd)] = target
    return out


@pytest.fixture(autouse=True)
def _no_fd_leaks(request):
    """Every test must close the sockets/files/pipes it opens: an fd
    open after the test that wasn't open before it fails the test (same
    contract as ``_no_thread_leaks``, one layer down — a leaked
    ``BlockServer`` socket survives even after its thread is joined).
    Compared as (fd, target) pairs so an fd number reused for a
    different file still counts. Opt out with
    ``@pytest.mark.leaks_fds``."""
    if request.node.get_closest_marker("leaks_fds"):
        yield
        return
    before = _open_fds()
    yield

    def leaked():
        return {fd: t for fd, t in _open_fds().items()
                if before.get(fd) != t}

    # grace period: TCP teardown and GC-driven closes may trail test end
    deadline = time.monotonic() + 2.0
    while leaked() and time.monotonic() < deadline:
        time.sleep(0.02)
    rest = leaked()
    assert not rest, \
        f"test leaked fds: {rest} -- close every socket/file/pipe " \
        f"(or mark the test leaks_fds)"


@pytest.fixture(scope="session")
def rng_key():
    import jax
    return jax.random.PRNGKey(0)


def run_subprocess(code: str, devices: int = 0, timeout: int = 600):
    """Run python code in a subprocess (optionally with N fake devices)."""
    env = dict(os.environ)
    env["PYTHONPATH"] = SRC + os.pathsep + env.get("PYTHONPATH", "")
    if devices:
        env["XLA_FLAGS"] = f"--xla_force_host_platform_device_count={devices}"
    res = subprocess.run([sys.executable, "-c", code], env=env,
                         capture_output=True, text=True, timeout=timeout)
    if res.returncode != 0:
        raise AssertionError(
            f"subprocess failed:\nSTDOUT:\n{res.stdout}\nSTDERR:\n{res.stderr}")
    return res.stdout
