"""Post-compile HLO analysis for the roofline (DESIGN.md §9).

XLA:CPU's ``compiled.cost_analysis()`` counts each ``while`` body ONCE,
but our layer stacks are ``lax.scan`` loops — a 94-layer model's compute
would be undercounted 94×. This module parses ``compiled.as_text()``
(post-SPMD-partitioning, per-device shapes) and walks the call graph with
multipliers: fusions ×1, while bodies × trip count (extracted from the
loop condition's comparison constant). It returns per-DEVICE totals of

  * dot FLOPs        (2 · result_elems · contracted_dim per ``dot``)
  * HBM byte proxy   (result + operand bytes of every scheduled op;
                      fused subcomputations are covered by their callsite)
  * collective bytes (result bytes of all-gather / all-reduce /
                      reduce-scatter / all-to-all / collective-permute,
                      with all-reduce ×2 for the ring's reduce+broadcast)

which feed the three roofline terms directly (per-device basis — no
division by chip count needed).
"""
from __future__ import annotations

import re
from dataclasses import dataclass, field

_DTYPE_BYTES = {"f64": 8, "f32": 4, "bf16": 2, "f16": 2, "f8e4m3": 1,
                "f8e5m2": 1, "s64": 8, "s32": 4, "s16": 2, "s8": 1,
                "u64": 8, "u32": 4, "u16": 2, "u8": 1, "pred": 1,
                "c64": 8, "c128": 16}

_SHAPE_RE = re.compile(r"([a-z0-9]+)\[([0-9,]*)\]")
_OP_RE = re.compile(
    r"^\s*(?:ROOT\s+)?%([\w.\-]+)\s*=\s*(\(?[^=]*?)\s([\w\-]+)\((.*)$")
_CALLS_RE = re.compile(r"calls=%([\w.\-]+)")
_WHILE_RE = re.compile(r"condition=%([\w.\-]+),\s*body=%([\w.\-]+)")
_CONST_RE = re.compile(r"constant\((\d+)\)")
_GROUPS_RE = re.compile(r"replica_groups=\[(\d+),(\d+)\]")

COLLECTIVES = ("all-gather", "all-reduce", "reduce-scatter", "all-to-all",
               "collective-permute")


def _shape_bytes(type_str: str) -> int:
    """Total bytes of a (possibly tuple) HLO type string."""
    total = 0
    for m in _SHAPE_RE.finditer(type_str):
        dt, dims = m.group(1), m.group(2)
        if dt not in _DTYPE_BYTES:
            continue
        n = _DTYPE_BYTES[dt]
        for d in dims.split(","):
            if d:
                n *= int(d)
        total += n
    return total


def _shape_elems(type_str: str) -> int:
    m = _SHAPE_RE.search(type_str)
    if not m:
        return 0
    n = 1
    for d in m.group(2).split(","):
        if d:
            n *= int(d)
    return n


@dataclass
class _Op:
    name: str
    type_str: str
    opcode: str
    rest: str
    operands: list = field(default_factory=list)


@dataclass
class _Computation:
    name: str
    ops: list = field(default_factory=list)
    is_entry: bool = False
    # local (unscaled) tallies
    flops: float = 0.0
    bytes_: float = 0.0
    coll: dict = None
    coll_counts: dict = None
    calls: list = None          # (callee, kind) kind in {fusion, call}
    whiles: list = None         # (cond_name, body_name)


_ARITH = {"add", "subtract", "multiply", "divide", "dot", "convolution",
          "exponential", "exponential-minus-one", "log", "log-plus-one",
          "rsqrt", "sqrt", "power", "tanh", "logistic", "maximum",
          "minimum", "negate", "abs", "sign", "floor", "ceil", "round",
          "remainder", "reduce", "reduce-window", "cosine", "sine",
          "atan2", "clamp"}


def _is_conversion_artifact(comp: "_Computation") -> bool:
    """True for fusions that only re-type/move data (XLA:CPU's hoisted
    bf16↔f32 promotions of whole weight/cache stacks — ops that do not
    exist on a native-bf16 TPU). A fusion with NO arithmetic and at least
    one dtype convert is such an artifact; pure-bf16 data movement (real
    KV-cache writes) has no converts and stays counted."""
    has_convert = any(op.opcode == "convert" for op in comp.ops)
    has_arith = any(op.opcode in _ARITH for op in comp.ops)
    return has_convert and not has_arith


def parse_hlo(text: str) -> dict:
    """Parse a post-optimization HLO module into computations."""
    comps: dict[str, _Computation] = {}
    cur: _Computation | None = None
    for line in text.splitlines():
        s = re.sub(r"/\*.*?\*/", "", line).strip()   # tuple index comments
        head = re.match(r"^(ENTRY\s+)?%([\w.\-]+)\s*\(.*\)\s*->\s*.*{$", s)
        if head:
            cur = _Computation(name=head.group(2), calls=[], whiles=[],
                               coll={c: 0.0 for c in COLLECTIVES},
                               coll_counts={c: 0 for c in COLLECTIVES},
                               is_entry=bool(head.group(1)))
            comps[cur.name] = cur
            continue
        if s == "}":
            cur = None
            continue
        if cur is None:
            continue
        m = _OP_RE.match(s)
        if not m:
            # parameters: "%p = f32[...] parameter(0)" matches _OP_RE; other
            # non-op lines (metadata continuation) are skipped
            continue
        name, type_str, opcode, rest = m.groups()
        op = _Op(name=name, type_str=type_str, opcode=opcode, rest=rest)
        # operand name list: ``rest`` starts right AFTER the opening paren
        depth = 1
        args = ""
        for ch in rest:
            if ch == "(":
                depth += 1
            elif ch == ")":
                depth -= 1
                if depth == 0:
                    break
            args += ch
        op.operands = re.findall(r"%([\w.\-]+)", args)
        cur.ops.append(op)
        cm = _CALLS_RE.search(s)
        if cm:
            cur.calls.append(cm.group(1))
        elif opcode == "call":
            am = re.search(r"to_apply=%([\w.\-]+)", s)
            if am:
                cur.calls.append(am.group(1))
        wm = _WHILE_RE.search(s)
        if wm and opcode == "while":
            cur.whiles.append((wm.group(1), wm.group(2)))
    return comps


def _analyze_comp(comp: _Computation, comps: dict) -> None:
    """Fill local tallies (flops incl. fused callees; bytes of scheduled
    ops only; collectives)."""
    symtab = {op.name: op.type_str for op in comp.ops}
    for op in comp.ops:
        if op.opcode == "fusion":
            cm = _CALLS_RE.search(op.rest)
            callee = comps.get(cm.group(1)) if cm else None
            if callee is not None and _is_conversion_artifact(callee):
                continue   # hoisted dtype-promotion fusion: not TPU bytes
        if op.opcode in ("dot", "convolution"):
            out_elems = _shape_elems(op.type_str)
            lhs = symtab.get(op.operands[0]) if op.operands else None
            lhs_elems = _shape_elems(lhs) if lhs else 0
            # contracted size = lhs_elems / (out batch*row elems). For dot
            # with single contraction this is exact; fall back to 1.
            cd = re.search(r"lhs_contracting_dims={([\d,]*)}", op.rest)
            contracted = 1
            if lhs and cd:
                dims = _SHAPE_RE.search(lhs).group(2).split(",")
                for i in cd.group(1).split(","):
                    if i:
                        contracted *= int(dims[int(i)])
            comp.flops += 2.0 * out_elems * contracted
        if op.opcode in ("parameter", "get-tuple-element", "bitcast",
                         "tuple", "constant",
                         # control flow: bodies are scaled separately and
                         # the carried tuple is not re-read per call
                         "while", "conditional", "call",
                         # CPU-backend artifacts absent on TPU: XLA:CPU
                         # promotes bf16 compute to f32 (convert/copy pairs)
                         # and materialises layout changes; TPU runs bf16
                         # natively with fused layouts (DESIGN.md §3).
                         "convert", "copy", "transpose", "reshape",
                         "broadcast", "iota"):
            continue
        # HBM byte proxy (TPU-fused pipeline semantics): every tensor is
        # counted once where it is PRODUCED (result bytes); operand reads
        # are added only for ops that stream large inputs through the
        # memory system rather than consuming a just-produced tile —
        # dots/convs (weights + activations), data movement (slice/
        # gather/scatter/concat), reductions, and collectives.
        b = _shape_bytes(op.type_str)
        if op.opcode in ("dot", "convolution", "dynamic-slice",
                         "dynamic-update-slice", "gather", "scatter",
                         "reduce", "reduce-window", "select-and-scatter",
                         "concatenate", "slice", "pad", "sort") \
                or op.opcode.startswith(COLLECTIVES):
            for o in op.operands:
                if o in symtab:
                    b += _shape_bytes(symtab[o])
        comp.bytes_ += b
        for c in COLLECTIVES:
            if op.opcode == c or op.opcode == c + "-start":
                nbytes = _shape_bytes(op.type_str)
                if c == "all-reduce":
                    nbytes *= 2          # ring: reduce-scatter + all-gather
                comp.coll[c] += nbytes
                comp.coll_counts[c] += 1


def _trip_count(cond: _Computation) -> int:
    """Loop condition compares the induction variable against the trip
    count: the largest scalar integer constant in the condition."""
    best = 1
    for op in cond.ops:
        if op.opcode == "constant":
            m = re.match(r"(\d+)\)", op.rest)
            if m:
                best = max(best, int(m.group(1)))
        for m in _CONST_RE.finditer(op.rest):
            best = max(best, int(m.group(1)))
    return best


def analyze(text: str) -> dict:
    """Per-device totals with while-trip scaling."""
    comps = parse_hlo(text)
    for c in comps.values():
        _analyze_comp(c, comps)

    import functools

    @functools.lru_cache(maxsize=None)
    def total(name: str) -> tuple:
        c = comps[name]
        flops, bytes_, coll = c.flops, c.bytes_, dict(c.coll)
        counts = dict(c.coll_counts)
        for callee in c.calls:
            if callee in comps:
                f2, b2, cl2, ct2 = total(callee)
                flops += f2
                # fused internals don't touch HBM: bytes NOT added
                for k in coll:
                    coll[k] += cl2[k]
                    counts[k] += ct2[k]
        for cond_name, body_name in c.whiles:
            trips = _trip_count(comps[cond_name]) if cond_name in comps else 1
            if body_name in comps:
                f2, b2, cl2, ct2 = total(body_name)
                flops += f2 * trips
                bytes_ += b2 * trips
                for k in coll:
                    coll[k] += cl2[k] * trips
                    counts[k] += ct2[k] * trips
        return flops, bytes_, coll, counts

    entry = next((c for c in comps.values() if c.is_entry), None)
    if entry is None:  # fall back: largest computation
        entry = max(comps.values(), key=lambda c: len(c.ops))
    flops, bytes_, coll, counts = total(entry.name)
    return {
        "flops": flops,
        "bytes": bytes_,
        "collective_bytes": coll,
        "collective_counts": counts,
        "collective_total": sum(coll.values()),
        "n_computations": len(comps),
    }


def roofline_terms(analysis: dict, *, peak_flops: float = 197e12,
                   hbm_bw: float = 819e9, ici_bw: float = 50e9,
                   ici_links: int = 4) -> dict:
    """Three roofline terms in SECONDS (per device, hence per step)."""
    t_compute = analysis["flops"] / peak_flops
    t_memory = analysis["bytes"] / hbm_bw
    t_coll = analysis["collective_total"] / (ici_bw * ici_links)
    dom = max((t_compute, "compute"), (t_memory, "memory"),
              (t_coll, "collective"))
    return {
        "t_compute_s": t_compute,
        "t_memory_s": t_memory,
        "t_collective_s": t_coll,
        "bottleneck": dom[1],
        "t_bound_s": dom[0],
    }
