"""Overload-oriented admission policies (§7), on the policy registry.

Load definition (§7.1): with disaggregated pools, load is SLO satisfaction
directly — l_prefill = predicted max TTFT / TTFT_SLO over the prefill pool,
l_decode = predicted TBT / TBT_SLO over the decode pool.

Three policies (Table 3):

  * ``baseline``   — each stage checks its own load when the request
    REACHES it: prefill load at arrival, decode load after prefill
    completes. A decode-side rejection wastes the finished prefill (§7.2).
  * ``early``      — at arrival, reject if max(prefill, decode load)
    exceeds 1. No prefill waste, but scheduling on the *current* decode
    load lags reality by one prefill duration → anti-phase fluctuation
    (§7.3, Figure 9/10a).
  * ``predictive`` — §7.4 system-level prediction: estimate the decode
    load at t_now + TTFT by (i) adding every accepted request whose
    prefill finishes before then, (ii) retiring requests whose decode will
    have exceeded the uniform decode time t_d. Accept against the
    PREDICTED load.

Each policy declares how the Conductor's decode pre-selection should
account for in-flight work via the class-level ``accounting`` knob
("current" = visible decode state only, the §7.2 time lag; "pending" =
count accepted-but-still-prefilling commitments) — applied to
``Conductor.accounting`` at construction. ``decode_double_check`` marks
policies whose decode-side check happens AFTER prefill (the simulator
re-validates at join time and may waste the finished prefill).
"""
from __future__ import annotations

from dataclasses import dataclass

from repro.core.policies.base import get_policy, register_policy
from repro.core.trace import Request


@dataclass
class _InFlight:
    """Accepted request whose prefill will finish at ``prefill_done``."""
    prefill_done: float
    tokens: float
    decode_iid: int


class AdmissionPolicy:
    """Wraps a Conductor with overload admission. Subclasses decide.

    Priority-aware (§10 "advanced policy that accounts for varying
    request priorities"): a request of priority p is admitted while the
    load stays under base_limit + priority_relief·p — higher-priority
    traffic keeps flowing into the overload region that sheds best-effort
    requests.
    """
    name = "base"
    kind = "admission"
    #: how the Conductor's decode pre-selection counts in-flight work
    accounting = "pending"
    #: True -> the decode-side SLO check runs AFTER prefill (§7.2 waste)
    decode_double_check = False

    def __init__(self, conductor, priority_relief: float = 0.25) -> None:
        self.c = conductor
        self.priority_relief = priority_relief
        self.in_flight: list[_InFlight] = []
        conductor.accounting = self.accounting

    # best-effort traffic sheds at base_limit; each priority level buys
    # priority_relief more load headroom (hard SLO checks stay universal)
    base_limit = 0.85

    def load_limit(self, req: Request) -> float:
        return self.base_limit + self.priority_relief * max(req.priority, 0)

    # ---- load measurements (§7.1) ----
    def prefill_load(self, now: float) -> float:
        """max over instances of (queue + typical prefill) / TTFT_SLO."""
        loads = [p.queue_time(now) / self.c.ttft_slo for p in self.c.P]
        return max(loads) if loads else 0.0

    def decode_load(self, now: float) -> float:
        """CURRENT decode load — §7.1. Deliberately blind to accepted
        requests still in prefill: that information lag between the pools
        is what causes the §7.3 fluctuation."""
        loads = [d.predicted_tbt(include_pending=False) / self.c.tbt_slo
                 for d in self.c.D]
        return max(loads) if loads else 0.0

    def admit(self, req: Request, now: float) -> bool:
        raise NotImplementedError

    def schedule(self, req: Request, now: float):
        from repro.core.conductor import Decision
        if not self.admit(req, now):
            return Decision(False, reject_reason=f"{self.name} admission")
        dec = self.c.schedule(req, now)
        if dec.accepted:
            self.in_flight.append(_InFlight(
                prefill_done=now + dec.expected_ttft,
                tokens=req.input_length + req.output_length,
                decode_iid=dec.decode.iid))
        return dec

    def on_decode_join(self, decode_iid: int, now: float) -> None:
        self.in_flight = [f for f in self.in_flight
                          if f.prefill_done > now or f.decode_iid != decode_iid]


@register_policy("admission", "baseline")
class BaselineAdmission(AdmissionPolicy):
    """Stage-local checks only; the decode check happens in the simulator
    AFTER prefill (double-check of §3 step 4) and may waste prefill work.
    The Conductor's decode pre-selection sees only the CURRENT decode state
    (``accounting = "current"``) — the §7.2 time lag."""
    accounting = "current"
    decode_double_check = True

    def admit(self, req: Request, now: float) -> bool:
        return self.prefill_load(now) <= self.load_limit(req)


@register_policy("admission", "early")
class EarlyRejection(AdmissionPolicy):
    """§7.2: gate on the max of both pools' CURRENT loads at arrival.
    The decode view is stale by one prefill duration (the Conductor's
    decode pre-selection shares the stale view), producing the anti-phase
    load fluctuation of Figure 9/10a."""
    accounting = "current"

    def admit(self, req: Request, now: float) -> bool:
        return max(self.prefill_load(now),
                   self.decode_load(now)) <= self.load_limit(req)


@register_policy("admission", "predictive")
class PredictiveEarlyRejection(AdmissionPolicy):
    """§7.4 system-level prediction with uniform decode time t_d."""

    def __init__(self, conductor, t_d: float = 10.0,
                 priority_relief: float = 0.25) -> None:
        super().__init__(conductor, priority_relief)
        self.t_d = t_d

    def predicted_decode_load(self, now: float, horizon: float) -> float:
        """Average TBT ratio over decode instances at ``now + horizon``."""
        t = now + horizon
        per_inst: dict[int, tuple[int, float]] = {}
        for d in self.c.D:
            # requests currently decoding, minus those done within horizon:
            # approximate retirement as a uniform drain over t_d
            frac_left = max(1.0 - horizon / self.t_d, 0.0)
            b = d.active * frac_left
            toks = d.kv_tokens * frac_left
            per_inst[d.iid] = (b, toks)
        # add accepted requests whose prefill completes before t
        for f in self.in_flight:
            if f.prefill_done <= t:
                b, toks = per_inst[f.decode_iid]
                per_inst[f.decode_iid] = (b + 1, toks + f.tokens)
        ratios = []
        for d in self.c.D:
            b, toks = per_inst[d.iid]
            if b < 1:
                ratios.append(0.0)
                continue
            tbt = d.cost.decode_iter_time(max(int(b), 1), toks / b)
            ratios.append(tbt / self.c.tbt_slo)
        return sum(ratios) / len(ratios) if ratios else 0.0

    def admit(self, req: Request, now: float) -> bool:
        limit = self.load_limit(req)
        if self.prefill_load(now) > limit:
            return False
        # horizon = the TTFT this request would see (approx: best queue)
        horizon = min(p.queue_time(now) for p in self.c.P) \
            + self.c.P[0].cost.prefill_time(req.input_length, 0)
        return self.predicted_decode_load(now, horizon) <= limit


def make_admission(name: str, conductor, **kw) -> AdmissionPolicy:
    """Build a registered admission policy around a Conductor."""
    return get_policy("admission", name)(conductor, **kw)
