"""Strategy × admission goodput grid over declarative ClusterSpec scenarios.

The scenario-diversity payoff of the pluggable policy API: every registered
prefill routing policy crossed with every registered admission policy, over
three scenarios a hardcoded scheduler could not have expressed as data:

* ``moderate``  — the standard trace at moderate load, flat DRAM pools:
  the Figure-8 regime, TTFT-shaped.
* ``ssd_tier``  — long-context doc sessions with DRAM far below the
  working set and an NVMe tier: the compute-vs-load regime where the
  ``why_not_both`` overlap arm (head recompute ∥ tail SSD load) pays.
* ``overload``  — decode-binding 3× replay: the §7 regime where admission
  policy dominates and ``load_aware``'s queue-imbalance pricing flattens
  TTFT tails.

Emits one table per scenario (``policy_grid_<scenario>``) plus a summary
of where each NEW policy (load_aware, why_not_both) beats a legacy one —
and asserts at least one such win exists per new policy.

    PYTHONPATH=src python -m benchmarks.bench_policies [--fast|--quick]
"""
from __future__ import annotations

from dataclasses import dataclass, field

import dataclasses

from benchmarks.common import emit
from repro.configs.base import CacheTierSpec, ClusterSpec, get_config
from repro.core.costmodel import V5E, InstanceSpec
from repro.core.policies import list_policies
from repro.core.simulator import MooncakeCluster
from repro.core.trace import TraceSpec, generate_trace

# SATA-class SSD: pure load rarely beats recompute, so the all-or-nothing
# SSD arm goes quiet — the regime where splitting (why_not_both) pays
SATA_INST = InstanceSpec(hw=dataclasses.replace(V5E, ssd_read_bw=1.5e9))

LEGACY_STRATEGIES = ("random", "load_balance", "cache_aware", "kvcache")
NEW_STRATEGIES = ("load_aware", "why_not_both")


@dataclass
class Scenario:
    """One benchmark scenario: a trace recipe + a base ClusterSpec."""
    name: str
    trace: TraceSpec
    spec: ClusterSpec
    speedup: float = 1.0
    #: DRAM budget as a fraction of the trace's unique working set;
    #: None keeps the spec's cache untouched
    dram_frac: float | None = None
    ssd_ratio: int = 0

    def build_requests(self, fast: bool):
        ts = self.trace
        if fast:
            ts = dataclasses.replace(
                ts, n_requests=max(ts.n_requests // 4, 200),
                duration_ms=max(ts.duration_ms // 4, 60_000))
        return generate_trace(ts)

    def build_spec(self, requests) -> ClusterSpec:
        if self.dram_frac is None:
            return self.spec
        uniq = len({h for r in requests for h in r.hash_ids})
        dram = max(int(uniq * self.dram_frac), 64)
        return self.spec.replace(cache=CacheTierSpec(
            dram_blocks=dram, ssd_blocks=self.ssd_ratio * dram))


SCENARIOS = [
    Scenario("moderate",
             TraceSpec(n_requests=2000, duration_ms=600_000, seed=11),
             ClusterSpec(n_prefill=4, n_decode=4),
             speedup=2.0),
    Scenario("ssd_tier",
             TraceSpec(n_requests=1200, duration_ms=900_000, seed=7,
                       frac_chat=0.25, frac_doc=0.55, frac_oneshot=0.20,
                       doc_len_mu=9.6, doc_len_sigma=0.6),
             ClusterSpec(n_prefill=4, n_decode=4, tbt_slo=0.2,
                         inst_spec=SATA_INST),
             speedup=1.0, dram_frac=0.02, ssd_ratio=8),
    Scenario("overload",
             TraceSpec(n_requests=1600, duration_ms=200_000, seed=3,
                       frac_doc=0.5, frac_chat=0.3, frac_oneshot=0.2,
                       out_mu=5.9),
             ClusterSpec(n_prefill=4, n_decode=4,
                         cache=CacheTierSpec(dram_blocks=2000)),
             speedup=3.0),
]


def run_grid(scn: Scenario, strategies, admissions, decodes,
             fast: bool) -> list[dict]:
    requests = scn.build_requests(fast)
    base = scn.build_spec(requests)
    # common window: the makespan moves with the last completion, which is
    # A/B noise — goodput over the shared trace horizon is the fair compare
    window = max(r.timestamp for r in requests) / 1000.0 / scn.speedup + 120.0
    rows = []
    for strategy in strategies:
        for admission in admissions:
            for decode in decodes:
                spec = base.replace(strategy=strategy, admission=admission,
                                    decode_policy=decode, t_d=20.0)
                res = MooncakeCluster.from_spec(get_config("llama2-70b"),
                                                spec).run(requests,
                                                          speedup=scn.speedup)
                slo = (spec.ttft_slo, spec.tbt_slo)
                rows.append(dict(
                    scenario=scn.name, strategy=strategy,
                    admission=admission, decode=decode,
                    goodput_rps=round(res.goodput(*slo, window), 4),
                    avg_ttft_s=round(res.avg_ttft(), 3),
                    ttft_p90_s=round(res.ttft_p90(), 3),
                    completed=len(res.completed()),
                    rejected=len(res.rejected()),
                    migrations=res.n_migrations,
                    ssd_loads=res.n_ssd_loads,
                    reject_top=next(iter(res.reject_breakdown()), "")))
    return rows


def _wins(rows: list[dict], new: str) -> list[str]:
    """Grid cells where ``new`` beats a legacy strategy under the same
    scenario+admission+decode on goodput or TTFT p90."""
    out = []
    for r in rows:
        if r["strategy"] != new:
            continue
        for other in rows:
            if other["strategy"] not in LEGACY_STRATEGIES \
                    or other["scenario"] != r["scenario"] \
                    or other["admission"] != r["admission"] \
                    or other["decode"] != r["decode"]:
                continue
            if r["goodput_rps"] > other["goodput_rps"] \
                    or r["ttft_p90_s"] < other["ttft_p90_s"]:
                metric = "goodput" if r["goodput_rps"] > other["goodput_rps"] \
                    else "ttft_p90"
                out.append(f"{r['scenario']}/{r['admission']}/{r['decode']}: "
                           f"{new} beats {other['strategy']} on {metric}")
    return out


def _decode_wins(rows: list[dict], new: str, base: str) -> list[str]:
    """Cells where decode policy ``new`` beats ``base`` at the same
    scenario+strategy+admission on goodput or TTFT p90."""
    by_cell = {(r["scenario"], r["strategy"], r["admission"], r["decode"]): r
               for r in rows}
    out = []
    for (scn, strat, adm, dec), r in by_cell.items():
        if dec != new:
            continue
        other = by_cell.get((scn, strat, adm, base))
        if other is None:
            continue
        if r["goodput_rps"] > other["goodput_rps"] \
                or r["ttft_p90_s"] < other["ttft_p90_s"]:
            metric = "goodput" if r["goodput_rps"] > other["goodput_rps"] \
                else "ttft_p90"
            out.append(f"{scn}/{strat}/{adm}: {new} beats {base} on {metric}")
    return out


def main(fast: bool = False):
    strategies = list_policies("prefill")
    admissions = list_policies("admission")
    decodes = list_policies("decode")
    all_rows = []
    for scn in SCENARIOS:
        rows = run_grid(scn, strategies, admissions, decodes, fast)
        emit(f"policy_grid_{scn.name}", rows)
        all_rows.extend(rows)

    print("\n== new-policy wins vs legacy ==")
    for new in NEW_STRATEGIES:
        wins = _wins(all_rows, new)
        for w in wins[:6]:
            print("  " + w)
        if len(wins) > 6:
            print(f"  ... and {len(wins) - 6} more")
        assert wins, f"{new} must beat >=1 legacy policy in >=1 scenario"

    print("\n== decode-policy wins (kv_pressure vs min_tbt) ==")
    dwins = _decode_wins(all_rows, "kv_pressure", "min_tbt")
    for w in dwins[:6]:
        print("  " + w)
    if len(dwins) > 6:
        print(f"  ... and {len(dwins) - 6} more")
    assert dwins, "kv_pressure must beat min_tbt in >=1 grid cell"
    return all_rows


if __name__ == "__main__":
    import argparse
    ap = argparse.ArgumentParser()
    ap.add_argument("--fast", "--quick", dest="fast", action="store_true",
                    help="reduced trace sizes (CI smoke lane)")
    main(fast=ap.parse_args().fast)
