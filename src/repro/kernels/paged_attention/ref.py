"""Pure-jnp oracle for paged decode attention.

The numerics deliberately mirror ``models.layers._attend``'s decode path
(fp32 logits, -1e30 masking, fp32 softmax, probabilities cast to the
value dtype before the PV contraction) so the engine's paged substrate is
bit-comparable with the dense arena it replaces: the only difference
between the two is WHERE the KV bytes live, never how they are reduced.

Two head layouts:

* grouped GQA (``qh2kv is None``): requires H % KV == 0; query head h
  attends kv head h // (H // KV) — the layout the Pallas kernel packs.
* explicit map (``qh2kv`` = (H,) int32): arbitrary query-head → kv-head
  assignment, covering archs whose padded query heads are not divisible
  by KV (smollm 16→5); mirrors the dense path's ``qh2kv_map`` expansion.
"""
from __future__ import annotations

import jax.numpy as jnp
from jax import nn

NEG_INF = -1e30


def _linearise(pages, block_table):
    """(P, page, KV, D) pages + (B, max_pages) table -> (B, S, KV, D)."""
    g = pages[block_table]              # (B, max_pages, page, KV, D)
    B = g.shape[0]
    return g.reshape(B, g.shape[1] * g.shape[2], *g.shape[3:])


def _valid_mask(S, seq_lens, window):
    """Mirror ``decode_attention``'s mask: slots < len valid; a linear
    cache of a windowed arch masks slots older than the window."""
    clen = jnp.asarray(seq_lens)[:, None]            # (B, 1)
    valid = jnp.arange(S)[None, :] < jnp.minimum(clen, S)
    if window and S > window:
        valid &= jnp.arange(S)[None, :] >= clen - window
    return valid                                      # (B, S)


def paged_attention_ref(q, k_pages, v_pages, block_table, seq_lens, *,
                        qh2kv=None, window: int = 0):
    """One-token GQA attention over paged KV.

    q:          (B, H, D) — the current token's queries
    k_pages:    (P, page, KV, D) one layer's page store
    v_pages:    (P, page, KV, D)
    block_table:(B, max_pages) int32 page ids (0 = null page)
    seq_lens:   (B,) int32 valid tokens per sequence
    qh2kv:      optional (H,) query-head → kv-head map (padded GQA)
    window:     sliding-window size (0 = full attention)
    Returns (B, H, D) in q.dtype.
    """
    B, H, D = q.shape
    KV = k_pages.shape[2]
    scale = 1.0 / (D ** 0.5)

    k = _linearise(k_pages, block_table)              # (B, S, KV, D)
    v = _linearise(v_pages, block_table)
    S = k.shape[1]
    valid = _valid_mask(S, seq_lens, window)

    if qh2kv is not None:                             # expanded-head path
        k = jnp.take(k, qh2kv, axis=2)                # (B, S, H, D)
        v = jnp.take(v, qh2kv, axis=2)
        logits = jnp.einsum("bqhd,bkhd->bhqk", q[:, None], k,
                            preferred_element_type=jnp.float32) * scale
        logits = jnp.where(valid[:, None, None, :], logits, NEG_INF)
        probs = nn.softmax(logits, axis=-1)
        out = jnp.einsum("bhqk,bkhd->bqhd", probs.astype(v.dtype), v)
        return out[:, 0]

    assert H % KV == 0, (
        f"H={H} not divisible by KV={KV}: pass qh2kv for padded GQA")
    g = H // KV
    qg = q.reshape(B, 1, KV, g, D)
    logits = jnp.einsum("bqkgd,bskd->bkgqs", qg, k,
                        preferred_element_type=jnp.float32) * scale
    logits = jnp.where(valid[:, None, None, None, :], logits, NEG_INF)
    probs = nn.softmax(logits, axis=-1)
    out = jnp.einsum("bkgqs,bskd->bqkgd", probs.astype(v.dtype), v)
    return out.reshape(B, 1, H, D)[:, 0]


def paged_attention_split_ref(q, k_pages, v_pages, block_table, seq_lens,
                              *, n_model: int = 1, n_data: int = 1,
                              window: int = 0):
    """Mesh-free oracle of the SHARDED decomposition: split the KV heads
    into ``n_model`` contiguous stripes and the batch rows into ``n_data``
    banks, run ``paged_attention_ref`` on every (bank, stripe) piece
    independently, and recombine by concatenation — exactly what the
    shard_map entry does per device, minus the mesh. Bitwise equality
    with the plain oracle is the shard-invariance property the device
    suite re-checks on real virtual-device meshes; this version runs in
    the default single-device test lane. Grouped GQA only (the sharded
    path's boundary): H % KV == 0 and KV % n_model == 0."""
    B, H, D = q.shape
    KV = k_pages.shape[2]
    assert H % KV == 0 and KV % n_model == 0 and B % n_data == 0, \
        (H, KV, n_model, B, n_data)
    kv_loc, g = KV // n_model, H // KV
    rows = B // n_data
    outs = []
    for b in range(n_data):
        r = slice(b * rows, (b + 1) * rows)
        shards = []
        for mi in range(n_model):
            h = slice(mi * kv_loc, (mi + 1) * kv_loc)
            qh = slice(mi * kv_loc * g, (mi + 1) * kv_loc * g)
            shards.append(paged_attention_ref(
                q[r, qh], k_pages[:, :, h], v_pages[:, :, h],
                block_table[r], seq_lens[r], window=window))
        outs.append(jnp.concatenate(shards, axis=1))
    return jnp.concatenate(outs, axis=0)


def paged_attention_layers_ref(qs, k_pages, v_pages, block_table, seq_lens,
                               *, qh2kv=None, window: int = 0):
    """Batched-over-layers oracle: qs (L, B, H, D) against the stacked
    (L, P, page, KV, D) page store; one block table / seq_lens shared by
    every layer. Returns (L, B, H, D)."""
    import jax
    return jax.vmap(
        lambda q, kp, vp: paged_attention_ref(
            q, kp, vp, block_table, seq_lens, qh2kv=qh2kv, window=window)
    )(qs, k_pages, v_pages)
