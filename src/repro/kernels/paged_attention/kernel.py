"""Paged decode attention — Pallas TPU kernel.

The continuous-batching hot spot (§3 step 4): each active sequence's
single query token attends its paged KV through a block table. The page
gather is fused into the attention: the BlockSpec index map reads the
block table (scalar-prefetched into SMEM) and pulls exactly the pages the
sequence owns from HBM into VMEM — no materialised contiguous copy.

Grid (B, KV, n_pages): one kv-head's ``group`` query heads are processed
together (GQA packing keeps the MXU matmul at (group × D) · (D × page)).
Online softmax over the page loop; tokens past ``seq_lens[b]`` masked.
VMEM per step: one (page, D) K tile + V tile + (group, D) accumulators —
a few hundred KiB at page = 64, D = 128.

Sharding: the kernel itself is mesh-oblivious. Under the (data, model)
shard_map entries (``ops.paged_decode_attention_sharded``, the engine's
``decode_step_paged_sharded``) each shard invokes this kernel unchanged
on its LOCAL slices — a KV/m head stripe of the page slab and a B/d row
slice of the batch. Attention is head-local and row-local, so the grid
simply shrinks along those axes; no cross-device traffic happens inside
the kernel.
"""
from __future__ import annotations

import functools

import jax
import jax.numpy as jnp
from jax.experimental import pallas as pl
from jax.experimental.pallas import tpu as pltpu

NEG_INF = -1e30


def _paged_kernel(table_ref, lens_ref, q_ref, k_ref, v_ref, o_ref,
                  acc_ref, m_ref, l_ref, *, page: int, scale: float,
                  n_pages: int):
    b = pl.program_id(0)
    ip = pl.program_id(2)

    @pl.when(ip == 0)
    def _init():
        acc_ref[...] = jnp.zeros_like(acc_ref)
        m_ref[...] = jnp.full_like(m_ref, NEG_INF)
        l_ref[...] = jnp.zeros_like(l_ref)

    q = q_ref[0, 0].astype(jnp.float32)              # (group, D)
    k = k_ref[0, :, 0, :].astype(jnp.float32)        # (page, D)
    v = v_ref[0, :, 0, :].astype(jnp.float32)

    # token validity within this page
    pos = ip * page + jax.lax.broadcasted_iota(jnp.int32, (1, page), 1)
    valid = pos < lens_ref[b]                        # (1, page)

    s = jax.lax.dot_general(q, k, (((1,), (1,)), ((), ())),
                            preferred_element_type=jnp.float32) * scale
    s = jnp.where(valid, s, NEG_INF)                 # (group, page)

    m_prev = m_ref[:, 0]
    m_cur = jnp.maximum(m_prev, s.max(axis=1))
    alpha = jnp.exp(m_prev - m_cur)
    p = jnp.exp(s - m_cur[:, None])
    p = jnp.where(valid, p, 0.0)
    l_ref[:, 0] = l_ref[:, 0] * alpha + p.sum(axis=1)
    acc_ref[...] = acc_ref[...] * alpha[:, None] + jax.lax.dot_general(
        p, v, (((1,), (0,)), ((), ())), preferred_element_type=jnp.float32)
    m_ref[:, 0] = m_cur

    @pl.when(ip == n_pages - 1)
    def _finalize():
        den = jnp.maximum(l_ref[:, 0], 1e-30)
        o_ref[0, 0] = (acc_ref[...] / den[:, None]).astype(o_ref.dtype)


@functools.partial(jax.jit, static_argnames=("interpret",))
def paged_attention(q, k_pages, v_pages, block_table, seq_lens, *,
                    interpret: bool = False):
    """q: (B, H, D); k/v_pages: (P, page, KV, D);
    block_table: (B, max_pages) int32; seq_lens: (B,) int32."""
    B, H, D = q.shape
    P, page, KV, _ = k_pages.shape
    max_pages = block_table.shape[1]
    group = H // KV
    qg = q.reshape(B, KV, group, D)

    kernel = functools.partial(_paged_kernel, page=page,
                               scale=1.0 / (D ** 0.5), n_pages=max_pages)

    grid_spec = pltpu.PrefetchScalarGridSpec(
        num_scalar_prefetch=2,
        grid=(B, KV, max_pages),
        in_specs=[
            pl.BlockSpec((1, 1, group, D),
                         lambda b, h, ip, tbl, lens: (b, h, 0, 0)),
            pl.BlockSpec((1, page, 1, D),
                         lambda b, h, ip, tbl, lens: (tbl[b, ip], 0, h, 0)),
            pl.BlockSpec((1, page, 1, D),
                         lambda b, h, ip, tbl, lens: (tbl[b, ip], 0, h, 0)),
        ],
        out_specs=pl.BlockSpec((1, 1, group, D),
                               lambda b, h, ip, tbl, lens: (b, h, 0, 0)),
        scratch_shapes=[
            pltpu.VMEM((group, D), jnp.float32),
            pltpu.VMEM((group, 1), jnp.float32),
            pltpu.VMEM((group, 1), jnp.float32),
        ],
    )
    out = pl.pallas_call(
        kernel,
        grid_spec=grid_spec,
        out_shape=jax.ShapeDtypeStruct((B, KV, group, D), q.dtype),
        interpret=interpret,
    )(block_table, seq_lens, qg, k_pages, v_pages)
    return out.reshape(B, H, D)


def _paged_kernel_layers(table_ref, lens_ref, q_ref, k_ref, v_ref, o_ref,
                         acc_ref, m_ref, l_ref, *, page: int, scale: float,
                         n_pages: int):
    b = pl.program_id(1)
    ip = pl.program_id(3)

    @pl.when(ip == 0)
    def _init():
        acc_ref[...] = jnp.zeros_like(acc_ref)
        m_ref[...] = jnp.full_like(m_ref, NEG_INF)
        l_ref[...] = jnp.zeros_like(l_ref)

    q = q_ref[0, 0, 0].astype(jnp.float32)           # (group, D)
    k = k_ref[0, 0, :, 0, :].astype(jnp.float32)     # (page, D)
    v = v_ref[0, 0, :, 0, :].astype(jnp.float32)

    pos = ip * page + jax.lax.broadcasted_iota(jnp.int32, (1, page), 1)
    valid = pos < lens_ref[b]                        # (1, page)

    s = jax.lax.dot_general(q, k, (((1,), (1,)), ((), ())),
                            preferred_element_type=jnp.float32) * scale
    s = jnp.where(valid, s, NEG_INF)                 # (group, page)

    m_prev = m_ref[:, 0]
    m_cur = jnp.maximum(m_prev, s.max(axis=1))
    alpha = jnp.exp(m_prev - m_cur)
    p = jnp.exp(s - m_cur[:, None])
    p = jnp.where(valid, p, 0.0)
    l_ref[:, 0] = l_ref[:, 0] * alpha + p.sum(axis=1)
    acc_ref[...] = acc_ref[...] * alpha[:, None] + jax.lax.dot_general(
        p, v, (((1,), (0,)), ((), ())), preferred_element_type=jnp.float32)
    m_ref[:, 0] = m_cur

    @pl.when(ip == n_pages - 1)
    def _finalize():
        den = jnp.maximum(l_ref[:, 0], 1e-30)
        o_ref[0, 0, 0] = (acc_ref[...] / den[:, None]).astype(o_ref.dtype)


@functools.partial(jax.jit, static_argnames=("interpret",))
def paged_attention_layers(qs, k_pages, v_pages, block_table, seq_lens, *,
                           interpret: bool = False):
    """Batched-over-layers entry: qs (L, B, H, D) against the stacked
    (L, P, page, KV, D) page store, one block table shared by all layers.
    Grid (L, B, KV, n_pages) — each layer's page gather rides the same
    scalar-prefetched table, so L layers launch as ONE kernel instead of
    L dispatches (the microbench / layer-parallel entry; the scanned
    decode path calls the per-layer ``paged_attention`` inside its scan).
    """
    L, B, H, D = qs.shape
    _, P, page, KV, _ = k_pages.shape
    max_pages = block_table.shape[1]
    group = H // KV
    qg = qs.reshape(L, B, KV, group, D)

    kernel = functools.partial(_paged_kernel_layers, page=page,
                               scale=1.0 / (D ** 0.5), n_pages=max_pages)

    grid_spec = pltpu.PrefetchScalarGridSpec(
        num_scalar_prefetch=2,
        grid=(L, B, KV, max_pages),
        in_specs=[
            pl.BlockSpec((1, 1, 1, group, D),
                         lambda l, b, h, ip, tbl, lens: (l, b, h, 0, 0)),
            pl.BlockSpec((1, 1, page, 1, D),
                         lambda l, b, h, ip, tbl, lens:
                         (l, tbl[b, ip], 0, h, 0)),
            pl.BlockSpec((1, 1, page, 1, D),
                         lambda l, b, h, ip, tbl, lens:
                         (l, tbl[b, ip], 0, h, 0)),
        ],
        out_specs=pl.BlockSpec((1, 1, 1, group, D),
                               lambda l, b, h, ip, tbl, lens:
                               (l, b, h, 0, 0)),
        scratch_shapes=[
            pltpu.VMEM((group, D), jnp.float32),
            pltpu.VMEM((group, 1), jnp.float32),
            pltpu.VMEM((group, 1), jnp.float32),
        ],
    )
    out = pl.pallas_call(
        kernel,
        grid_spec=grid_spec,
        out_shape=jax.ShapeDtypeStruct((L, B, KV, group, D), qs.dtype),
        interpret=interpret,
    )(block_table, seq_lens, qg, k_pages, v_pages)
    return out.reshape(L, B, H, D)
