"""Public request/response surface of the serving engines.

One request shape flows through the whole stack — ``ServingLoop.submit``,
``DecodeWorker.join``, the launchers and the cluster example all speak
``ServingRequest`` and report through ``RequestOutput`` — replacing the
scattered pre-PR-8 surface (``submit(req_id, tokens, max_new, session,
priority)`` kwargs, the private ``_Arrival``, ad-hoc ``outputs`` dict
entries). The legacy keyword forms still work behind a
``DeprecationWarning`` shim (see ``ServingLoop.submit`` /
``DecodeWorker.join``).

``priority`` is the §10 priority class (higher = more important): it buys
admission headroom under backpressure, orders pending joins, and — with
decode preemption enabled — lets a request spill a strictly
lower-priority victim's KV to the host tier instead of waiting behind
it. ``deadline`` is carried for schedulers/telemetry (seconds, same
clock as ``time.monotonic()``); the loop does not enforce it.
"""
from __future__ import annotations

from dataclasses import dataclass, field
from typing import Optional

import numpy as np


@dataclass
class ServingRequest:
    """One generation request, as submitted by a client.

    ``tokens`` may be ``None`` only for the ``DecodeWorker.join`` legacy
    shim (a joined slot doesn't need the prompt); anything submitted to a
    ``ServingLoop`` must carry real tokens — preemption recovery
    (recompute restore) replays them.
    """
    req_id: int
    tokens: Optional[np.ndarray]
    max_new: int
    session: Optional[object] = None
    priority: int = 0
    deadline: Optional[float] = None    # monotonic-clock seconds; advisory

    def __post_init__(self) -> None:
        if self.tokens is not None:
            self.tokens = np.asarray(self.tokens)
        if self.max_new < 1:
            raise ValueError(f"max_new must be >= 1, got {self.max_new}")


@dataclass
class RequestOutput:
    """Per-request result stream + lifecycle telemetry.

    ``tokens``/``token_t`` grow as the engine emits (``token_t`` are
    ``time.monotonic()`` stamps); ``preemptions`` counts how many times
    the request was victim-spilled to the host KV tier; ``restores``
    names the restore arm used for each re-join (``"reload"`` — staged
    back from spilled bytes — or ``"recompute"`` — re-prefilled);
    ``completed_iter`` is the loop iteration the final token landed on
    (deterministic in ``iterate()``-driven mode, the benchmarks' clock).
    """
    req_id: int
    priority: int = 0
    tokens: list = field(default_factory=list)
    token_t: list = field(default_factory=list)
    done: bool = False
    preemptions: int = 0
    restores: list = field(default_factory=list)
    completed_iter: Optional[int] = None
