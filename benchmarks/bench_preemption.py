"""Decode preemption vs defer-only — tail completion latency under a
priority mix.

One low-priority long-decode victim holds the single decode slot while a
burst of high-priority sprinters arrives behind it. The defer-only loop
(PR-6 behaviour, ``--no-preempt``) can only park the sprinters' finished
prefills in the pending-join queue until the victim drains — every
sprinter's completion latency absorbs the victim's remaining decode. The
preempting loop spills the victim's live page run to the host KV tier
(``DevicePagePool.export_run`` → ``HostKVPool`` spill slab), finishes the
sprinters, then restores the victim from the spilled bytes (reload) or
re-prefills it (recompute) — §10's priority classes on top of §4's
store-vs-recompute choice.

Everything is iterate()-driven on one thread: submits interleave with
loop iterations on a seeded token stream, and latency is measured in
iteration indices (engine-local ``completed_iter`` minus the submit
iteration), so the ``preemption_sched`` table is exact integers /
deterministic percentiles and CI-gated at zero tolerance. Asserted
in-process, every mode: 100% completion, every stream bit-exact vs the
request-at-a-time never-preempted oracle, no stranded spill slabs, no
leaked pages — and the preempting modes beat defer-only on p99
completion latency.

    PYTHONPATH=src python -m benchmarks.bench_preemption [--quick]
"""
from __future__ import annotations

import numpy as np

from benchmarks.common import emit

CHUNK = 128
PAGE_TOKENS = 64
MAX_LEN = 640
N_PAGES = 17          # barely one long sequence + churn — the tight regime
VICTIM_LEN = 512      # one full registered block + growth
SPRINT_LEN = 128


def _workload(vocab, n_sprinters, victim_new, seed=5):
    rng = np.random.default_rng(seed)
    reqs = [(0, rng.integers(0, vocab, VICTIM_LEN), victim_new, 0)]
    for i in range(n_sprinters):
        reqs.append((i + 1, rng.integers(0, vocab, SPRINT_LEN), 4, 1))
    return reqs


def _mk(params, cfg):
    from repro.serving.engine import DecodeWorker, HostKVPool, PrefillWorker
    from repro.serving.paged_cache import DevicePagePool

    pp = DevicePagePool(cfg, n_pages=N_PAGES, page_tokens=PAGE_TOKENS)
    pool = HostKVPool()
    pw = PrefillWorker(params, cfg, pool, prefill_chunk=CHUNK, page_pool=pp)
    dw = DecodeWorker(params, cfg, max_batch=1, max_len=MAX_LEN,
                      substrate="paged", page_pool=pp)
    return pw, dw, pp, pool


def _oracle(params, cfg, payloads):
    """Request-at-a-time reference streams (never preempted)."""
    from repro.serving.engine import DecodeWorker, HostKVPool, PrefillWorker
    from repro.serving.request import ServingRequest

    pw = PrefillWorker(params, cfg, HostKVPool(), prefill_chunk=CHUNK)
    dw = DecodeWorker(params, cfg, max_batch=1, max_len=MAX_LEN)
    out = {}
    for rid, toks, mn, _prio in payloads:
        res = pw(toks)
        dw.join(ServingRequest(req_id=rid, tokens=toks, max_new=mn), res)
        out[rid] = [res.first_token]
        while dw.n_active:
            for r, tok, fin in dw.step():
                out[r].append(tok)
    return out


def _run_mode(params, cfg, payloads, *, preempt, restore_mode):
    """Drive one loop configuration deterministically: victim first, the
    sprinter burst lands once the victim is a few tokens into decode."""
    from repro.serving.loop import ServingLoop
    from repro.serving.request import ServingRequest

    pw, dw, pp, pool = _mk(params, cfg)
    loop = ServingLoop([pw], dw, chunks_per_iter=2,
                       max_queue=len(payloads) + 8, admission="baseline",
                       preempt=preempt, restore_mode=restore_mode)
    submit_iter = {}
    it = 0

    def _submit(p):
        rid, toks, mn, prio = p
        assert loop.submit(ServingRequest(req_id=rid, tokens=toks,
                                          max_new=mn, priority=prio))
        submit_iter[rid] = it

    _submit(payloads[0])
    while len(loop.outputs.get(0, _EMPTY).tokens) < 4:   # victim mid-decode
        loop.iterate()
        it += 1
    for p in payloads[1:]:
        _submit(p)
        for _ in range(2):                               # staggered burst
            loop.iterate()
            it += 1
    loop.close_intake()
    while not loop.idle:
        loop.iterate()
        it += 1

    s = loop.stats()
    assert s["iterations"] == it
    assert pool.spill_depth() == 0, "stranded spill slab after drain"
    pp.check_leaks()
    lats = {rid: loop.outputs[rid].completed_iter - submit_iter[rid]
            for rid, _, _, _ in payloads}
    return loop, s, lats


class _EMPTY:
    tokens: list = []


def main(fast: bool = False) -> int:
    import jax

    from repro.configs.base import get_config
    from repro.models.transformer import init_params

    cfg = get_config("smollm-360m").reduced()
    params = init_params(cfg, jax.random.PRNGKey(0))
    n_sprinters, victim_new = (7, 32) if fast else (11, 48)
    payloads = _workload(cfg.vocab_size, n_sprinters, victim_new)
    oracle = _oracle(params, cfg, payloads)

    modes = (("defer", False, "auto"),
             ("preempt-reload", True, "reload"),
             ("preempt-recompute", True, "recompute"))
    rows, p99s = [], {}
    for name, preempt, restore in modes:
        loop, s, lats = _run_mode(params, cfg, payloads,
                                  preempt=preempt, restore_mode=restore)
        assert s["completed"] == len(payloads), \
            f"{name}: {s['completed']}/{len(payloads)} completed"
        bit_exact = all(loop.outputs[rid].tokens == oracle[rid]
                        for rid, _, _, _ in payloads)
        assert bit_exact, f"{name}: streams diverged from oracle"
        sprint = [lats[rid] for rid, _, _, p in payloads if p > 0]
        p99s[name] = float(np.percentile(np.asarray(sprint), 99))
        rows.append(dict(
            mode=name, completed=s["completed"],
            preemptions=s["preemptions"],
            restores_reload=s["restores_reload"],
            restores_recompute=s["restores_recompute"],
            decode_steps=s["decode_steps"],
            prefill_chunks=s["prefill_chunks"],
            victim_iters=lats[0],
            sprint_p50_iters=float(np.percentile(np.asarray(sprint), 50)),
            sprint_p99_iters=p99s[name],
            bit_exact=bit_exact))
    emit("preemption_sched", rows)

    by = {r["mode"]: r for r in rows}
    assert by["defer"]["preemptions"] == 0
    for name in ("preempt-reload", "preempt-recompute"):
        assert by[name]["preemptions"] >= 1, f"{name}: never preempted"
        assert p99s[name] < p99s["defer"], (
            f"{name} sprinter completion p99 {p99s[name]} iters not better "
            f"than defer-only {p99s['defer']}")
    assert by["preempt-reload"]["restores_reload"] >= 1
    assert by["preempt-recompute"]["restores_recompute"] >= 1
    print(f"\nsprinter completion p99 (iterations): "
          + ", ".join(f"{m}={p99s[m]:.1f}" for m in p99s))
    return 0


if __name__ == "__main__":
    import argparse

    ap = argparse.ArgumentParser(description=__doc__)
    ap.add_argument("--fast", "--quick", dest="fast", action="store_true")
    raise SystemExit(main(fast=ap.parse_args().fast))
