#!/usr/bin/env bash
# repro-lint: repo-specific static analysis (stdlib only -- no jax, no
# numpy, no package install). Exits non-zero on any unsuppressed,
# unbaselined finding. See README "Static analysis".
set -euo pipefail
cd "$(dirname "$0")/.."
exec python -m tools.replint "$@"
