"""Training launcher.

    PYTHONPATH=src python -m repro.launch.train --arch smollm-360m \
        --steps 100 --batch 8 --seq 512 [--reduced] [--ckpt dir]

On this CPU container use --reduced (the smoke-scale variant); on a real
TPU slice the same entry point drives the full config on the production
mesh (--mesh prod).
"""
from __future__ import annotations

import argparse
import sys


def main(argv=None) -> int:
    ap = argparse.ArgumentParser(description=__doc__)
    ap.add_argument("--arch", required=True)
    ap.add_argument("--steps", type=int, default=100)
    ap.add_argument("--batch", type=int, default=8)
    ap.add_argument("--seq", type=int, default=512)
    ap.add_argument("--reduced", action="store_true",
                    help="train the reduced smoke-scale variant")
    ap.add_argument("--mesh", choices=["none", "prod", "prod-multipod"],
                    default="none")
    ap.add_argument("--ckpt", default=None)
    ap.add_argument("--ckpt-every", type=int, default=0)
    ap.add_argument("--resume", action="store_true")
    ap.add_argument("--seed", type=int, default=0)
    args = ap.parse_args(argv)

    from repro.configs.base import get_config
    from repro.models.layers import Dist, NO_DIST
    from repro.training.loop import train

    cfg = get_config(args.arch)
    if args.reduced:
        cfg = cfg.reduced()
    dist = NO_DIST
    if args.mesh != "none":
        from repro.launch.mesh import make_production_mesh
        mesh = make_production_mesh(multi_pod=args.mesh == "prod-multipod")
        dist = Dist(mesh=mesh)

    res = train(cfg, steps=args.steps, batch=args.batch, seq=args.seq,
                dist=dist, seed=args.seed, checkpoint_dir=args.ckpt,
                checkpoint_every=args.ckpt_every, resume=args.resume)
    print(f"done: {res.steps} steps, final loss {res.losses[-1]:.4f}, "
          f"{res.tokens_per_s:.0f} tok/s")
    return 0


if __name__ == "__main__":
    sys.exit(main())
