"""Serving loop vs phase-at-a-time driver — sustained mixed-load goodput.

The loop bench makes the ISSUE's headline claim executable: at EQUAL
hardware (one prefill worker, one decode batch) and an equal TBT budget,
the always-on ``ServingLoop`` — prefill split into chunks interleaved
between continuous-batching decode steps — must sustain goodput
(tokens/s whose inter-token gap meets the budget) at least as high as
the request-at-a-time driver that runs full prefills while decode slots
starve. Two tables:

* ``serving_loop_goodput`` — wall-clock head-to-head on an OPEN-LOOP
  arrival schedule (requests land on a fixed clock, staggered output
  lengths — the "sustained mixed load" regime, where the phase driver
  must stall every active decode slot for a full prefill each time a
  slot refills). Both drivers run the same schedule on
  identically-shaped engines (after a warmup pass that pays every jit
  compile, with the KV pools then reset so the timed pass is cold).
  Asserted in-process, uploaded as artifact, NOT gated: at the budget
  the loop actually sustains (its own median p99), the loop lands at
  least as many SLO-attaining tokens as the baseline on the identical
  workload, with a no-worse TBT p99 — and every token stream bit-exact
  between the two drivers. (Wall-clock tokens/s is reported for
  observability but not asserted: on a shared CPU the run-to-run wall
  jitter exceeds the drivers' gap, while the attainment ordering is
  bimodal — baseline stall gaps are ~2× any sane budget — and held in
  every observed trial.)
* ``serving_loop_mixed`` — deterministic scheduling counts (CI-gated):
  the loop driven iteration-by-iteration with submits interleaved, once
  per admission policy under an AMPLE and a TIGHT device page pool.
  Ample: only predictive sheds (in-flight prefills are load the others
  can't see — §7.3's information lag). Tight: pinned-page pressure is
  visible to both occupancy-aware policies, the queue-only baseline
  stays blind and rides the join-deferral path instead. Counts are
  exact integers of a seeded workload; every accepted stream must match
  the request-at-a-time oracle.

    PYTHONPATH=src python -m benchmarks.bench_serving_loop [--quick]
"""
from __future__ import annotations

import time

import numpy as np

from benchmarks.common import emit
from repro.core.trace import BLOCK_TOKENS
from repro.serving.request import ServingRequest

CHUNK = 128        # prefill chunk; prompt lengths are multiples of this
PAGE_TOKENS = 64


def _workload(vocab, n_reqs, lengths, max_news, seed=0, dt=0.0):
    """Mixed load: half the prompts share a one-block prefix (chat-style
    reuse), half are cold docs; lengths and output lengths cycle (all
    prompt lengths multiples of CHUNK so the chunk grid is uniform;
    ``max_news`` staggered so completions spread out and the phase
    driver keeps refilling slots mid-decode). ``dt`` spaces arrivals on
    an open-loop clock (0 = burst)."""
    rng = np.random.default_rng(seed)
    shared = rng.integers(0, vocab, BLOCK_TOKENS)
    out = []
    for i in range(n_reqs):
        S = lengths[i % len(lengths)]
        if i % 2 == 0 and S > BLOCK_TOKENS:
            toks = np.concatenate(
                [shared, rng.integers(0, vocab, S - BLOCK_TOKENS)])
        else:
            toks = rng.integers(0, vocab, S)
        out.append((i, toks, max_news[i % len(max_news)], i * dt))
    return out


def _mk(params, cfg, *, max_batch, max_len, n_pages):
    from repro.serving.engine import DecodeWorker, HostKVPool, PrefillWorker
    from repro.serving.paged_cache import DevicePagePool

    pp = DevicePagePool(cfg, n_pages=n_pages, page_tokens=PAGE_TOKENS)
    pw = PrefillWorker(params, cfg, HostKVPool(), prefill_chunk=CHUNK,
                       page_pool=pp)
    dw = DecodeWorker(params, cfg, max_batch=max_batch, max_len=max_len,
                      substrate="paged", page_pool=pp)
    return pw, dw, pp


def _reset(pws, pp) -> None:
    """Fresh KV state, warm jit caches: swap in empty host pools and drop
    the page registry so the next run reuses nothing from the last."""
    from repro.serving.engine import HostKVPool
    for pw in pws:
        pw.pool = HostKVPool()
    for h in list(pp.runs):
        pp.unregister(h)
    pp.check_leaks()


def _run_baseline(pw, dw, payloads):
    """Phase-at-a-time on the arrival clock: a slot that frees while the
    queue is non-empty runs a FULL blocking prefill immediately — every
    other active slot starves through it (the stall chunked interleave
    removes)."""
    outputs: dict[int, list] = {}
    token_t: dict[int, list] = {}
    sched = sorted(payloads, key=lambda p: p[3])
    i = 0
    t0 = time.monotonic()
    while i < len(sched) or dw.n_active:
        now = time.monotonic() - t0
        while i < len(sched) and sched[i][3] <= now and dw.has_free_slot:
            rid, toks, mn, _ = sched[i]
            i += 1
            pres = pw(toks)
            dw.join(ServingRequest(req_id=rid, tokens=toks, max_new=mn),
                    pres)
            outputs[rid] = [pres.first_token]
            token_t[rid] = [time.monotonic()]
        if dw.n_active:
            for rid, tok, fin in dw.step():
                outputs[rid].append(tok)
                token_t[rid].append(time.monotonic())
        elif i < len(sched):
            time.sleep(max(sched[i][3] - (time.monotonic() - t0), 0.0))
    return outputs, token_t, time.monotonic() - t0


def _run_loop(pw, dw, payloads, **kw):
    """The serving loop on the same arrival clock, driven from this
    thread: submit what has arrived, run one iteration, repeat."""
    from repro.serving.loop import ServingLoop
    loop = ServingLoop([pw], dw, max_queue=len(payloads) + 8, **kw)
    sched = sorted(payloads, key=lambda p: p[3])
    i = 0
    t0 = time.monotonic()
    while i < len(sched):
        now = time.monotonic() - t0
        while i < len(sched) and sched[i][3] <= now:
            rid, toks, mn, _ = sched[i]
            i += 1
            assert loop.submit(ServingRequest(req_id=rid, tokens=toks,
                                              max_new=mn))
        if loop.idle and i < len(sched):
            time.sleep(max(sched[i][3] - (time.monotonic() - t0), 0.0))
        else:
            loop.iterate()
    loop.close_intake()
    loop.run()
    wall = time.monotonic() - t0
    outputs = {rid: o.tokens for rid, o in loop.outputs.items()}
    token_t = {rid: o.token_t for rid, o in loop.outputs.items()}
    return outputs, token_t, wall, loop


def _goodput(outputs, token_t, wall, budget_s):
    """tokens/s counting each request's first token plus every follow-on
    token whose inter-token gap meets the budget (the TBT-SLO view of
    throughput: late tokens are serving failures, not goodput)."""
    good = total = 0
    for rid, ts in token_t.items():
        total += len(ts)
        good += 1                                   # first token: TTFT's job
        good += sum(1 for a, b in zip(ts, ts[1:]) if b - a <= budget_s)
    return good, total, good / wall


def _gaps_p(token_t, q):
    gaps = [b - a for ts in token_t.values() for a, b in zip(ts, ts[1:])]
    return float(np.percentile(np.asarray(gaps), q)) if gaps else 0.0


def main(fast: bool = False) -> int:
    import jax

    from repro.configs.base import get_config
    from repro.models.transformer import init_params
    from repro.serving.loop import ServingLoop

    cfg = get_config("smollm-360m").reduced()
    params = init_params(cfg, jax.random.PRNGKey(0))

    # ---- head-to-head goodput (wall-clock, asserted, not gated) ----
    # Open-loop arrivals every ``dt`` with staggered output lengths: the
    # phase driver refills a freed slot with a full blocking prefill
    # while other slots are mid-decode — each refill stalls every active
    # stream past any reasonable TBT budget. chunks_per_iter=2 keeps the
    # loop's own inter-token gap at ~2 chunks + 1 step.
    if fast:
        n_reqs, lengths, max_news, max_batch = 8, (384, 640), (6, 18), 4
    else:
        n_reqs, lengths, max_news, max_batch = \
            12, (384, 640, 896), (6, 18, 10), 4
    dt = 0.10
    max_len = max(lengths) + max(max_news) + PAGE_TOKENS
    per_seq = (max_len + PAGE_TOKENS - 1) // PAGE_TOKENS
    n_pages = 1 + (max_batch + 2) * per_seq + n_reqs * 2
    payloads = _workload(cfg.vocab_size, n_reqs, lengths, max_news,
                         seed=3, dt=dt)

    # median of 3 timed trials per driver: single-trial wall/p99 jitter
    # on a shared CPU is larger than the loop's margin on a bad draw
    trials = 3
    results = {}
    for driver in ("loop", "baseline"):
        pw, dw, pp = _mk(params, cfg, max_batch=max_batch, max_len=max_len,
                         n_pages=n_pages)
        run = (lambda: _run_loop(pw, dw, payloads, chunks_per_iter=2)[:3]) \
            if driver == "loop" else (lambda: _run_baseline(pw, dw, payloads))
        run()                       # warmup: pays every jit compile
        runs = []
        for _ in range(trials):
            _reset([pw], pp)
            runs.append(run())      # timed: cold pools, warm jits
            pp.check_leaks()
        results[driver] = runs

    # equal budget for both drivers: the loop's own median p99 (so the
    # loop sheds ~nothing by construction and the baseline is judged at
    # the SAME bar)
    budget = max(float(np.median(
        [_gaps_p(tt, 99) for _, tt, _ in results["loop"]])), 1e-3)
    rows = []
    for driver in ("loop", "baseline"):
        scored = sorted(
            (( _goodput(o, tt, w, budget), (o, tt, w))
             for o, tt, w in results[driver]),
            key=lambda s: s[0][2])
        (good, total, gps), (outputs, token_t, wall) = scored[trials // 2]
        rows.append(dict(
            driver=driver, wall_s=round(wall, 2), total_tokens=total,
            good_tokens=good, goodput_tok_s=round(gps, 2),
            tbt_p50_ms=round(1e3 * _gaps_p(token_t, 50), 1),
            tbt_p99_ms=round(1e3 * _gaps_p(token_t, 99), 1),
            budget_ms=round(1e3 * budget, 1)))
    emit("serving_loop_goodput", rows)

    same = all(o == results["baseline"][0][0]
               for o, _, _ in results["loop"] + results["baseline"])
    assert same, "loop token streams diverged from the phase-at-a-time oracle"
    lo, ba = rows
    print(f"at TBT budget {lo['budget_ms']} ms: loop lands "
          f"{lo['good_tokens']}/{lo['total_tokens']} tokens in SLO "
          f"({lo['goodput_tok_s']} tok/s), baseline "
          f"{ba['good_tokens']}/{ba['total_tokens']} ({ba['goodput_tok_s']} "
          f"tok/s); p99 {lo['tbt_p99_ms']} vs {ba['tbt_p99_ms']} ms; "
          f"bit_exact={same}")
    assert lo["good_tokens"] >= ba["good_tokens"], (
        f"serving loop landed {lo['good_tokens']} tokens within the TBT "
        f"budget, fewer than phase-at-a-time's {ba['good_tokens']} on the "
        f"same workload")
    assert lo["tbt_p99_ms"] <= ba["tbt_p99_ms"], (
        f"serving loop TBT p99 {lo['tbt_p99_ms']} ms worse than "
        f"phase-at-a-time {ba['tbt_p99_ms']} ms")

    # ---- deterministic scheduling counts per admission policy (gated) ----
    if fast:
        n2, lengths2, max_news2, max_batch2 = 10, (256, 384), (3, 7), 2
    else:
        n2, lengths2, max_news2, max_batch2 = 14, (256, 384), (4, 8), 2
    max_len2 = max(lengths2) + max(max_news2) + PAGE_TOKENS
    per_seq2 = (max_len2 + PAGE_TOKENS - 1) // PAGE_TOKENS
    pay2 = _workload(cfg.vocab_size, n2, lengths2, max_news2, seed=7)
    # ample: every slot + staging fits, only volume pressure remains;
    # tight: barely two sequences — pinned staged runs of pending joins
    # dominate, the regime the join headroom guard exists for
    pools = (("ample", 1 + (max_batch2 + 1) * per_seq2, 3),
             ("tight", 1 + 2 * per_seq2 - 2, 4))

    det_rows = []
    oracle: dict[int, list] = {}
    for pool_kind, n_pages2, mq in pools:
        pw2, dw2, pp2 = _mk(params, cfg, max_batch=max_batch2,
                            max_len=max_len2, n_pages=n_pages2)
        if not oracle:
            # request-at-a-time oracle streams (pool-size independent)
            for rid, toks, mn, _ in pay2:
                pres = pw2(toks)
                dw2.join(ServingRequest(req_id=rid, tokens=toks,
                                        max_new=mn), pres)
                oracle[rid] = [pres.first_token]
                while dw2.n_active:
                    for r, tok, fin in dw2.step():
                        oracle[r].append(tok)
        for adm in ("baseline", "early", "predictive"):
            _reset([pw2], pp2)
            loop = ServingLoop([pw2], dw2, chunks_per_iter=1, max_queue=mq,
                               admission=adm)
            # submits interleaved with iterations — deterministic arrival
            # pressure, no thread timing in the gated counts
            for rid, toks, mn, _ in pay2:
                loop.submit(ServingRequest(req_id=rid, tokens=toks,
                                           max_new=mn))
                loop.iterate()
            loop.close_intake()
            loop.run()
            pp2.check_leaks()
            bit_exact = all(loop.outputs[rid].tokens == oracle[rid]
                            for rid in loop.outputs
                            if loop.outputs[rid].done)
            s = loop.stats()
            det_rows.append(dict(
                pool=pool_kind, admission=adm, submitted=s["submitted"],
                rejected=s["rejected"], completed=s["completed"],
                total_tokens=sum(
                    len(o.tokens) for o in loop.outputs.values()),
                decode_steps=s["decode_steps"],
                prefill_chunks=s["prefill_chunks"], join_oom=s["join_oom"],
                bit_exact=bit_exact))
            assert bit_exact, \
                f"{pool_kind}/{adm}: accepted streams diverged from oracle"
    emit("serving_loop_mixed", det_rows)
    return 0


if __name__ == "__main__":
    import argparse

    ap = argparse.ArgumentParser(description=__doc__)
    ap.add_argument("--fast", "--quick", dest="fast", action="store_true")
    raise SystemExit(main(fast=ap.parse_args().fast))
