"""Table 1: cache hit rates under different policies and capacities."""
from __future__ import annotations

from benchmarks.common import emit
from repro.core.cache import cache_hit_analysis
from repro.core.trace import TraceSpec, generate_trace

CAPACITIES = [None, 100_000, 50_000, 30_000, 10_000, 1_000]
PAPER = {  # Table 1 reference values
    "lru": [0.51, 0.51, 0.50, 0.48, 0.40, 0.30],
    "lfu": [0.51, 0.51, 0.49, 0.43, 0.35, 0.30],
    "length_aware": [0.51, 0.50, 0.48, 0.42, 0.35, 0.30],
}


def run(n_requests: int = 23_608, seed: int = 0) -> list[dict]:
    reqs = generate_trace(TraceSpec(n_requests=n_requests, seed=seed))
    rows = []
    for policy in ("lru", "lfu", "length_aware"):
        row = {"policy": policy}
        for cap in CAPACITIES:
            label = "inf" if cap is None else str(cap)
            row[label] = round(cache_hit_analysis(reqs, policy, cap), 3)
        row["paper_inf"] = PAPER[policy][0]
        rows.append(row)
    return rows


def main(fast: bool = False):
    rows = run(n_requests=6000 if fast else 23_608)
    emit("table1_cache_policies", rows)
    return rows


if __name__ == "__main__":
    main()
