from repro.kernels.paged_attention.ops import (paged_decode_attention,
                                               paged_decode_attention_layers)
from repro.kernels.paged_attention.ref import (paged_attention_layers_ref,
                                               paged_attention_ref)
