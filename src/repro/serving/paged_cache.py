"""Paged device KVCache with block tables (the vLLM-style substrate that
Mooncake's disaggregated pool feeds — §3 step 1 loads pool blocks into
these pages, step 2 stores new pages back).

Layout (per attention layer stacked on a leading axis):

    k_pages, v_pages : (L, n_pages, page_tokens, KV, Dh)
    block_table      : (B, max_pages_per_seq) int32 — page id per slot
    seq_lens         : (B,) int32

Page allocation is host-side (a free list); attention over pages is the
``paged_attention`` kernel (Pallas) or its jnp oracle. ``page_tokens`` is
the on-device granularity and the pool's 512-token block is a multiple of
it, so a pool block maps to an integer number of pages.
"""
from __future__ import annotations

from dataclasses import dataclass, field
from typing import Optional

import jax
import jax.numpy as jnp
import numpy as np

from repro.configs.base import ModelConfig
from repro.models.layers import DTYPE


@dataclass
class PagedKVCache:
    k_pages: jax.Array          # (L, P, page, KV, Dh)
    v_pages: jax.Array
    block_table: jax.Array      # (B, max_pages) int32
    seq_lens: jax.Array         # (B,) int32
    page_tokens: int
    free: list = field(default_factory=list)   # host-side free page ids

    @property
    def n_layers(self) -> int:
        return self.k_pages.shape[0]

    @property
    def n_pages(self) -> int:
        return self.k_pages.shape[1]

    @property
    def max_pages_per_seq(self) -> int:
        return self.block_table.shape[1]


def init_paged_cache(cfg: ModelConfig, *, batch: int, n_pages: int,
                     page_tokens: int = 64,
                     max_seq: int = 32768) -> PagedKVCache:
    La = cfg.attention_layers
    KV, Dh = cfg.n_kv_heads, cfg.head_dim
    max_pages = (max_seq + page_tokens - 1) // page_tokens
    return PagedKVCache(
        k_pages=jnp.zeros((La, n_pages, page_tokens, KV, Dh), DTYPE),
        v_pages=jnp.zeros((La, n_pages, page_tokens, KV, Dh), DTYPE),
        block_table=jnp.zeros((batch, max_pages), jnp.int32),
        seq_lens=jnp.zeros((batch,), jnp.int32),
        page_tokens=page_tokens,
        free=list(range(n_pages - 1, 0, -1)),  # page 0 = null page
    )


# ---------------------------------------------------------------------------
# host-side allocation
# ---------------------------------------------------------------------------

def alloc_pages(cache: PagedKVCache, n: int) -> list[int]:
    if len(cache.free) < n:
        raise MemoryError(f"paged cache OOM: want {n}, free {len(cache.free)}")
    return [cache.free.pop() for _ in range(n)]


def free_seq(cache: PagedKVCache, slot: int) -> PagedKVCache:
    """Release all pages of a batch slot back to the free list."""
    table = np.asarray(cache.block_table)
    lens = np.asarray(cache.seq_lens)
    n_used = int(np.ceil(lens[slot] / cache.page_tokens))
    cache.free.extend(int(p) for p in table[slot, :n_used] if p != 0)
    table = table.copy()
    table[slot] = 0
    lens = lens.copy()
    lens[slot] = 0
    return PagedKVCache(cache.k_pages, cache.v_pages,
                        jnp.asarray(table), jnp.asarray(lens),
                        cache.page_tokens, cache.free)


def assign_seq(cache: PagedKVCache, slot: int, n_tokens: int) -> PagedKVCache:
    """Allocate pages for a new sequence of ``n_tokens`` in ``slot``."""
    n = (n_tokens + cache.page_tokens - 1) // cache.page_tokens
    pages = alloc_pages(cache, n)
    table = np.asarray(cache.block_table).copy()
    table[slot, :n] = pages
    table[slot, n:] = 0
    lens = np.asarray(cache.seq_lens).copy()
    lens[slot] = n_tokens
    return PagedKVCache(cache.k_pages, cache.v_pages,
                        jnp.asarray(table), jnp.asarray(lens),
                        cache.page_tokens, cache.free)


def grow_seq(cache: PagedKVCache, slot: int, extra: int = 1) -> PagedKVCache:
    """Extend a sequence; allocates a fresh page at a page boundary."""
    table = np.asarray(cache.block_table).copy()
    lens = np.asarray(cache.seq_lens).copy()
    old, new = int(lens[slot]), int(lens[slot]) + extra
    n_old = (old + cache.page_tokens - 1) // cache.page_tokens
    n_new = (new + cache.page_tokens - 1) // cache.page_tokens
    if n_new > n_old:
        pages = alloc_pages(cache, n_new - n_old)
        table[slot, n_old:n_new] = pages
    lens[slot] = new
    return PagedKVCache(cache.k_pages, cache.v_pages,
                        jnp.asarray(table), jnp.asarray(lens),
                        cache.page_tokens, cache.free)


# ---------------------------------------------------------------------------
# device-side reads / writes (jit-able; tables are traced inputs)
# ---------------------------------------------------------------------------

def write_kv(cache: PagedKVCache, slot: int, start: int,
             k_new: jax.Array, v_new: jax.Array) -> PagedKVCache:
    """Write (L, S, KV, Dh) new KV of one sequence into its pages,
    starting at token offset ``start``. Host loop over touched pages
    (S and the table are known host-side at engine level)."""
    pt = cache.page_tokens
    table = np.asarray(cache.block_table)
    S = k_new.shape[1]
    k_pages, v_pages = cache.k_pages, cache.v_pages
    tok = start
    while tok < start + S:
        page_idx = tok // pt
        off = tok % pt
        n = min(pt - off, start + S - tok)   # stop at the page boundary
        pid = int(table[slot, page_idx])
        src = slice(tok - start, tok - start + n)
        k_pages = jax.lax.dynamic_update_slice(
            k_pages, k_new[:, src][:, None],
            (0, pid, off, 0, 0))
        v_pages = jax.lax.dynamic_update_slice(
            v_pages, v_new[:, src][:, None],
            (0, pid, off, 0, 0))
        tok += n
    return PagedKVCache(k_pages, v_pages, cache.block_table, cache.seq_lens,
                        pt, cache.free)


def gather_kv(cache: PagedKVCache, max_tokens: int):
    """Materialise per-sequence contiguous KV (L, B, max_tokens, KV, Dh)
    from pages via the block table — the pure-jnp paged read used by the
    engine on CPU (the Pallas kernel fuses this gather with attention)."""
    pt = cache.page_tokens
    n = max_tokens // pt
    tbl = cache.block_table[:, :n]                     # (B, n)
    k = cache.k_pages[:, tbl]                          # (L, B, n, pt, KV, Dh)
    v = cache.v_pages[:, tbl]
    L, B = k.shape[0], k.shape[1]
    k = k.reshape(L, B, n * pt, *k.shape[4:])
    v = v.reshape(L, B, n * pt, *v.shape[4:])
    return k, v
