"""guarded-by: lock discipline for annotated attributes.

An attribute assignment annotated with a trailing/preceding comment

    self._offsets: dict[str, int] = {}   #: guarded_by self._lock

must only be read or written inside a ``with self._lock`` block (any
``with`` whose context expression is ``self.<that lock>``), in every
method of the owning class.

Conventions honoured:

  * ``__init__``/``__del__``/``__post_init__`` are exempt — no
    concurrent access before construction finishes or during teardown.
  * methods whose name ends in ``_locked`` are exempt: the caller holds
    the lock (documented convention in this repo).
  * nested functions and lambdas RESET the held-lock state — a closure
    created under the lock typically runs later, after release.
"""
from __future__ import annotations

import ast
import re

from tools.replint.core import Finding, ModuleCtx, is_self_attr

RULE = "guarded-by"

_ANNOT_RE = re.compile(r"#:\s*guarded_by\s+self\.(\w+)")
_SELF_ATTR_RE = re.compile(r"self\.(\w+)")

_LOCK_FACTORIES = {"Lock", "RLock", "Condition"}
_EXEMPT_METHODS = {"__init__", "__del__", "__post_init__"}


def _lock_attrs(cls: ast.ClassDef) -> set[str]:
    """self attributes assigned from a Lock/RLock/Condition factory."""
    locks: set[str] = set()
    for node in ast.walk(cls):
        if isinstance(node, (ast.Assign, ast.AnnAssign)):
            targets = node.targets if isinstance(node, ast.Assign) \
                else [node.target]
            value = node.value
            if value is None:
                continue
            is_lock = any(
                isinstance(n, ast.Attribute) and n.attr in _LOCK_FACTORIES
                or isinstance(n, ast.Name) and n.id in _LOCK_FACTORIES
                for n in ast.walk(value))
            if not is_lock:
                continue
            for t in targets:
                if is_self_attr(t):
                    locks.add(t.attr)
    return locks


def _annotations(cls: ast.ClassDef, lines: list[str]) -> dict[str, str]:
    """attr name -> lock name, from ``#: guarded_by self.<lock>`` comments
    on (or immediately above) an attribute line inside the class body."""
    out: dict[str, str] = {}
    end = cls.end_lineno or cls.lineno
    for i in range(cls.lineno, min(end, len(lines)) + 1):
        ln = lines[i - 1]
        m = _ANNOT_RE.search(ln)
        if not m:
            continue
        lock = m.group(1)
        code = ln[:m.start()]
        target = code if code.strip() else \
            (lines[i] if i < len(lines) else "")
        am = _SELF_ATTR_RE.search(target)
        if am:
            out[am.group(1)] = lock
        else:
            # class-level declaration style: ``stats: dict  #: guarded_by``
            fm = re.match(r"\s*(\w+)\s*[:=]", target)
            if fm:
                out[fm.group(1)] = lock
    return out


def _is_lock_expr(node, locks: set[str]) -> str | None:
    """'with self._lock' / 'with self._cv' -> the lock attr name."""
    if is_self_attr(node) and node.attr in locks:
        return node.attr
    return None


def check(ctx: ModuleCtx) -> list[Finding]:
    findings: list[Finding] = []
    for cls in [n for n in ast.walk(ctx.tree)
                if isinstance(n, ast.ClassDef)]:
        guarded = _annotations(cls, ctx.lines)
        if not guarded:
            continue
        locks = _lock_attrs(cls)
        for attr, lock in sorted(guarded.items()):
            if lock not in locks:
                findings.append(Finding(
                    ctx.path, cls.lineno, RULE,
                    f"{cls.name}.{attr} is annotated guarded_by "
                    f"self.{lock}, but the class never creates that "
                    f"lock"))
        for meth in cls.body:
            if not isinstance(meth, (ast.FunctionDef,
                                     ast.AsyncFunctionDef)):
                continue
            if meth.name in _EXEMPT_METHODS \
                    or meth.name.endswith("_locked"):
                continue
            _scan(meth, cls, guarded, locks, ctx, findings)
    return findings


def _scan(meth, cls, guarded, locks, ctx, findings) -> None:
    reported: set[int] = set()

    def visit(node, held: frozenset):
        if isinstance(node, (ast.FunctionDef, ast.AsyncFunctionDef,
                             ast.Lambda)) and node is not meth:
            # closures run later: lock state does not carry in
            for ch in ast.iter_child_nodes(node):
                visit(ch, frozenset())
            return
        if isinstance(node, ast.With):
            acquired = set()
            for item in node.items:
                visit(item.context_expr, held)
                lk = _is_lock_expr(item.context_expr, locks)
                if lk:
                    acquired.add(lk)
                if item.optional_vars:
                    visit(item.optional_vars, held)
            inner = held | frozenset(acquired)
            for ch in node.body:
                visit(ch, inner)
            return
        if isinstance(node, ast.Attribute) and is_self_attr(node) \
                and node.attr in guarded:
            lock = guarded[node.attr]
            if lock not in held and node.lineno not in reported:
                reported.add(node.lineno)
                findings.append(Finding(
                    ctx.path, node.lineno, RULE,
                    f"{cls.name}.{meth.name} touches self.{node.attr} "
                    f"(guarded_by self.{lock}) outside 'with "
                    f"self.{lock}'"))
        for ch in ast.iter_child_nodes(node):
            visit(ch, held)

    for stmt in meth.body:
        visit(stmt, frozenset())
