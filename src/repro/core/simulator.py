"""Discrete-event cluster simulator — Mooncake's evaluation rig (§8).

The paper's own results come from replaying traces against a dummy model;
we do the same: the simulator replays a trace against per-instance cost
models (prefill superlinear in input length, decode memory-bound — Figure
2) whose terms are cross-checked against the dry-run's compiled FLOP/byte
counts (benchmarks/roofline.py).

Two cluster types:

  * ``MooncakeCluster`` — disaggregated prefill/decode pools + Conductor
    (Algorithm 1) + Messenger + overload admission (§7). Layer-wise prefill
    (§5.2) makes the KVCache stream to the decode node DURING prefill, so
    the decode-side arrival is max(prefill_done, transfer_done) with the
    transfer enqueued layer-by-layer — effectively overlapped unless the
    sender link is congested.
  * ``CoupledCluster`` — the vLLM-style baseline: prefill inlined into the
    decode engine; a long prefill blocks every active decode for its whole
    duration (the TBT disruption of §8.1.2).

Time unit: SECONDS. Request timestamps (ms) are converted on entry.
"""
from __future__ import annotations

import heapq
import itertools
from dataclasses import dataclass, field
from typing import Callable, Optional

import numpy as np

from repro.configs.base import CacheTierSpec, ClusterSpec, ModelConfig
from repro.core.cache import CachePool
from repro.core.conductor import (Conductor, DecodeInstance, PrefillInstance)
from repro.core.costmodel import CostModel, InstanceSpec
from repro.core.messenger import Messenger
from repro.core.policies import AdmissionPolicy, make_admission
from repro.core.trace import BLOCK_TOKENS, Request


@dataclass
class ReqRecord:
    req: Request
    arrival: float
    accepted: bool = False
    reject_stage: str = ""         # "admission" | "decode_doublecheck" | ""
    reject_reason: str = ""        # Decision.reject_reason (detailed)
    prefill_start: float = -1.0
    ttft: float = -1.0             # first token latency (s)
    tbts: list = field(default_factory=list)  # per-token gaps (s)
    done: float = -1.0
    prefix_blocks: int = 0
    ssd_blocks: int = 0            # prefix blocks loaded from local SSD
    peer_ssd_blocks: int = 0       # prefix blocks fetched off a peer's SSD
    ssd_load_time: float = 0.0     # seconds spent on the SSD read channel

    @property
    def completed(self) -> bool:
        return self.done >= 0

    def tbt_p(self, q: float) -> float:
        return float(np.percentile(self.tbts, q)) if self.tbts else 0.0


@dataclass
class SimResult:
    records: list
    duration: float
    load_samples: list              # (t, prefill_load, decode_load)
    n_migrations: int = 0
    n_ssd_loads: int = 0            # compute-vs-load chose 'load'
    n_peer_ssd_loads: int = 0       # global pool chose a peer-SSD fetch

    # ---- aggregates ----
    def completed(self):
        return [r for r in self.records if r.completed]

    def rejected(self):
        return [r for r in self.records if not r.accepted]

    def reject_breakdown(self) -> dict:
        """Rejected-request counts by detailed reason (falling back to the
        stage when a reason wasn't recorded), most frequent first."""
        counts: dict = {}
        for r in self.records:
            if r.accepted:
                continue
            key = r.reject_reason or r.reject_stage
            if not key:
                continue
            counts[key] = counts.get(key, 0) + 1
        return dict(sorted(counts.items(), key=lambda kv: (-kv[1], kv[0])))

    def ttft_p90(self) -> float:
        c = [r.ttft for r in self.completed()]
        return float(np.percentile(c, 90)) if c else float("nan")

    def tbt_p90(self) -> float:
        """P90 over requests of each request's P90 token gap."""
        c = [r.tbt_p(90) for r in self.completed() if r.tbts]
        return float(np.percentile(c, 90)) if c else float("nan")

    def slo_ok_count(self, ttft_slo: float, tbt_slo: float) -> int:
        """Completed requests meeting both SLOs (§2: only fully completed
        requests count)."""
        return len([r for r in self.completed()
                    if r.ttft <= ttft_slo and r.tbt_p(90) <= tbt_slo])

    def goodput(self, ttft_slo: float, tbt_slo: float,
                window: float | None = None) -> float:
        """SLO-meeting completions per second. ``window`` defaults to the
        run's makespan; pass a common window when comparing configurations
        (the makespan moves with the last request's completion, which is
        noise for A/B comparisons)."""
        window = self.duration if window is None else window
        return self.slo_ok_count(ttft_slo, tbt_slo) / window if window \
            else 0.0

    def slo_attainment(self, ttft_slo: float, tbt_slo: float):
        c = self.completed()
        if not c:
            return 0.0, 0.0
        ttft_ok = np.mean([r.ttft <= ttft_slo for r in c])
        tbt_ok = np.mean([r.tbt_p(90) <= tbt_slo for r in c])
        return float(ttft_ok), float(tbt_ok)

    def avg_ttft(self) -> float:
        c = [r.ttft for r in self.completed()]
        return float(np.mean(c)) if c else float("nan")


# ---------------------------------------------------------------------------
# event engine
# ---------------------------------------------------------------------------

class _Events:
    def __init__(self) -> None:
        self._h: list = []
        self._c = itertools.count()

    def push(self, t: float, fn: Callable[[], None]) -> None:
        heapq.heappush(self._h, (t, next(self._c), fn))

    def pop(self):
        t, _, fn = heapq.heappop(self._h)
        return t, fn

    def __bool__(self) -> bool:
        return bool(self._h)


# ---------------------------------------------------------------------------
# Mooncake (disaggregated) cluster
# ---------------------------------------------------------------------------

class _DecodeEngine:
    """Continuous-batching decode loop for one DecodeInstance."""

    def __init__(self, inst: DecodeInstance, events: _Events,
                 sim: "MooncakeCluster") -> None:
        self.inst = inst
        self.events = events
        self.sim = sim
        self.batch: list[ReqRecord] = []
        self.ticking = False

    def join(self, rec: ReqRecord, now: float) -> None:
        self.batch.append(rec)
        self.inst.active += 1
        self.inst.kv_tokens += rec.req.input_length
        self.inst.pending -= 1
        self.inst.pending_tokens -= rec.req.input_length + rec.req.output_length
        rec._last_tok = now       # type: ignore[attr-defined]
        rec._emitted = 1          # prefill produced the first token
        if not self.ticking:
            self.ticking = True
            self.events.push(now, lambda: self.tick(now))

    def tick(self, now: float) -> None:
        if not self.batch:
            self.ticking = False
            return
        dt = self.inst.cost.decode_iter_time(
            len(self.batch), self.inst.kv_tokens / len(self.batch))
        t2 = now + dt
        done_recs = []
        for rec in self.batch:
            rec.tbts.append(t2 - rec._last_tok)   # type: ignore[attr-defined]
            rec._last_tok = t2                    # type: ignore[attr-defined]
            rec._emitted += 1                     # type: ignore[attr-defined]
            self.inst.kv_tokens += 1
            if rec._emitted >= rec.req.output_length:  # type: ignore
                done_recs.append(rec)
        for rec in done_recs:
            self.batch.remove(rec)
            self.inst.active -= 1
            self.inst.kv_tokens -= rec.req.input_length + rec._emitted  # type: ignore
            rec.done = t2
        self.events.push(t2, lambda: self.tick(t2))


_UNSET = object()   # sentinel: distinguishes "not passed" from None defaults


class MooncakeCluster:
    """Disaggregated cluster. The scenario is a ``ClusterSpec``:

        MooncakeCluster.from_spec(cfg, ClusterSpec(n_prefill=8, ...))

    The flat-kwarg constructor (``MooncakeCluster(cfg, n_prefill=8, ...)``)
    is a deprecated shim kept for existing callers; it builds the same
    ``ClusterSpec`` internally (``cache_capacity_blocks``/``cache_policy``
    fold into a flat ``CacheTierSpec`` unless ``cache_spec`` is given).
    """

    def __init__(self, cfg: ModelConfig, spec: Optional[ClusterSpec] = None,
                 *, n_prefill: int = _UNSET, n_decode: int = _UNSET,
                 inst_spec: InstanceSpec = _UNSET,
                 ttft_slo: float = _UNSET, tbt_slo: float = _UNSET,
                 cache_capacity_blocks: Optional[int] = _UNSET,
                 cache_policy: str = _UNSET,
                 cache_spec: Optional[CacheTierSpec] = _UNSET,
                 strategy: str = _UNSET,
                 admission: str = _UNSET,
                 balancing_threshold: float = _UNSET,
                 layerwise_prefill: bool = _UNSET,
                 t_d: float = _UNSET, seed: int = _UNSET) -> None:
        legacy = {k: v for k, v in dict(
            n_prefill=n_prefill, n_decode=n_decode, inst_spec=inst_spec,
            ttft_slo=ttft_slo, tbt_slo=tbt_slo, strategy=strategy,
            admission=admission, balancing_threshold=balancing_threshold,
            layerwise_prefill=layerwise_prefill, t_d=t_d,
            seed=seed).items() if v is not _UNSET}
        if spec is not None:
            if legacy or cache_spec is not _UNSET \
                    or cache_capacity_blocks is not _UNSET \
                    or cache_policy is not _UNSET:
                raise ValueError("pass either a ClusterSpec or legacy "
                                 "kwargs, not both")
        else:
            if cache_spec is not _UNSET and cache_spec is not None:
                legacy["cache"] = cache_spec
            elif cache_capacity_blocks is not _UNSET \
                    or cache_policy is not _UNSET:
                legacy["cache"] = CacheTierSpec(
                    dram_blocks=20000 if cache_capacity_blocks is _UNSET
                    else cache_capacity_blocks,
                    dram_policy="lru" if cache_policy is _UNSET
                    else cache_policy)
            spec = ClusterSpec(**legacy)

        self.cfg = cfg
        self.spec = spec
        inst = spec.inst_spec if spec.inst_spec is not None else InstanceSpec()
        cost = lambda: CostModel(cfg, inst)
        self.cache_spec = spec.cache
        self.prefills = [PrefillInstance(
            iid=i, pool=spec.cache.make_pool(),
            cost=cost()) for i in range(spec.n_prefill)]
        self.decodes = [DecodeInstance(iid=1000 + i, cost=cost())
                        for i in range(spec.n_decode)]
        node_ids = [p.iid for p in self.prefills] + [d.iid for d in self.decodes]
        self.messenger = Messenger(node_ids, bw=inst.hw.net_bw)
        if spec.cache.tiered:
            for p in self.prefills:
                self.messenger.add_ssd_channel(p.iid, inst.hw.ssd_read_bw)
        # the Figure-3 global pool: one directory spanning every prefill
        # instance's tiers, so a block demoted on node A proposes a
        # peer-SSD fetch arm for a request routed to node B
        self.directory = None
        if spec.cache.tiered and spec.global_pool:
            from repro.core.directory import GlobalBlockDirectory
            self.directory = GlobalBlockDirectory()
            for p in self.prefills:
                self.directory.bind(p.iid, p.pool)
        import random
        self.conductor = Conductor(
            self.prefills, self.decodes, self.messenger,
            ttft_slo=spec.ttft_slo, tbt_slo=spec.tbt_slo,
            balancing_threshold=spec.balancing_threshold,
            strategy=spec.strategy, decode_policy=spec.decode_policy,
            rng=random.Random(spec.seed), directory=self.directory)
        # forward spec knobs any registered admission policy declares
        # (predictive's t_d, and user policies subclassing it)
        import inspect
        from repro.core.policies import get_policy
        adm_cls = get_policy("admission", spec.admission)
        kw = {"t_d": spec.t_d} if "t_d" in inspect.signature(
            adm_cls.__init__).parameters else {}
        self.admission: AdmissionPolicy = adm_cls(self.conductor, **kw)
        self.ttft_slo = spec.ttft_slo
        self.tbt_slo = spec.tbt_slo
        self.layerwise = spec.layerwise_prefill
        self.admission_name = spec.admission

    @classmethod
    def from_spec(cls, cfg: ModelConfig, spec: ClusterSpec) \
            -> "MooncakeCluster":
        """Build a cluster from a declarative scenario spec."""
        return cls(cfg, spec)

    def run(self, requests: list[Request], *, speedup: float = 1.0,
            load_sample_dt: float = 10.0) -> SimResult:
        events = _Events()
        records = [ReqRecord(req=r, arrival=r.timestamp / 1000.0 / speedup)
                   for r in requests]
        engines = {d.iid: _DecodeEngine(d, events, self) for d in self.decodes}
        load_samples: list = []

        def arrive(rec: ReqRecord):
            now = rec.arrival
            dec = self.admission.schedule(rec.req, now)
            if not dec.accepted:
                rec.reject_stage = "admission"
                rec.reject_reason = dec.reject_reason
                return
            rec.accepted = True
            rec.prefix_blocks = dec.prefix_blocks
            rec.ssd_blocks = dec.ssd_blocks
            rec.peer_ssd_blocks = dec.peer_ssd_blocks
            rec.ssd_load_time = dec.ssd_load_time
            p, d = dec.prefill, dec.decode
            # prefill completion (the conductor queued the work already;
            # any SSD prefix load overlapped the queue wait, so compute
            # start already reflects max(queue drained, load landed))
            t_done = p.queue_free_at
            rec.prefill_start = t_done - dec.compute_time

            # KVCache transfer to the decode node (§5.2 layer-wise overlap):
            # streaming starts when prefill starts, so completion is
            # max(prefill_done, stream_done); without layer-wise it is
            # prefill_done + full transfer.
            nbytes = p.cost.kv_bytes(rec.req.input_length)
            if self.layerwise:
                t_stream = self.messenger.enqueue(p.iid, nbytes,
                                                  rec.prefill_start)
                t_ready = max(t_done, t_stream)
            else:
                t_ready = self.messenger.enqueue(p.iid, nbytes, t_done)

            def finish_prefill():
                rec.ttft = t_done - rec.arrival
                self.admission.on_decode_join(d.iid, t_done)

            events.push(t_done, finish_prefill)

            def join_decode():
                # §3 step 4: the local scheduler double-checks the SLO with
                # the REAL (post-lag) state; under the baseline policy the
                # pre-selection was stale, so this can reject a request
                # whose prefill is already paid for — the §7.2 waste.
                tokens = rec.req.input_length + rec.req.output_length
                over_tbt = d.predicted_tbt(
                    1, tokens, include_pending=False) > self.tbt_slo
                over_vram = not d.vram_ok(tokens, include_pending=False)
                if self.admission.decode_double_check and (over_tbt or over_vram):
                    rec.accepted = False
                    rec.reject_stage = "decode_doublecheck"
                    rec.reject_reason = "decode double-check (%s)" % (
                        "VRAM" if over_vram else "TBT")
                    d.pending -= 1
                    d.pending_tokens -= tokens
                    return
                engines[d.iid].join(rec, t_ready)

            events.push(t_ready, join_decode)

        for rec in records:
            events.push(rec.arrival, lambda rec=rec: arrive(rec))

        # periodic load sampling (Figure 9)
        horizon = max(r.arrival for r in records) + 120.0

        def sample(t: float):
            load_samples.append((t, self.admission.prefill_load(t),
                                 self.admission.decode_load(t)))
            if t < horizon:
                events.push(t + load_sample_dt,
                            lambda: sample(t + load_sample_dt))

        events.push(0.0, lambda: sample(0.0))

        while events:
            t, fn = events.pop()
            fn()
        t_end = max([r.done for r in records if r.completed]
                    + [r.arrival for r in records])
        return SimResult(records=records, duration=t_end,
                         load_samples=load_samples,
                         n_migrations=self.conductor.n_migrations,
                         n_ssd_loads=self.conductor.n_ssd_loads,
                         n_peer_ssd_loads=self.conductor.n_peer_ssd_loads)


# ---------------------------------------------------------------------------
# Coupled (vLLM-style) baseline cluster
# ---------------------------------------------------------------------------

class _CoupledInstance:
    """Prefill inlined into the decode engine. Local prefix cache only."""

    def __init__(self, iid: int, cfg: ModelConfig, inst_spec: InstanceSpec,
                 cache_capacity, cache_policy: str) -> None:
        self.iid = iid
        self.cost = CostModel(cfg, inst_spec)
        self.pool = CachePool(cache_capacity, cache_policy)
        self.batch: list[ReqRecord] = []
        self.waiting: list[ReqRecord] = []
        self.kv_tokens = 0.0
        self.ticking = False
        self.queued_prefill_s = 0.0   # admission-visible backlog

    def load(self) -> float:
        return len(self.batch) + len(self.waiting)


class CoupledCluster:
    """vLLM-[N×M]: N instances, each coupling prefill + decode. Long-context
    prefills block the whole batch (no chunked prefill), reproducing the
    §8.1.2 TBT disruption. Requests go to the least-loaded instance."""

    def __init__(self, cfg: ModelConfig, *, n_instances: int,
                 inst_spec: InstanceSpec = InstanceSpec(),
                 ttft_slo: float = 30.0, tbt_slo: float = 0.1,
                 cache_capacity_blocks: Optional[int] = 20000,
                 cache_policy: str = "lru",
                 max_batch: int = 256, admit_load: float = 1e9) -> None:
        self.cfg = cfg
        self.insts = [_CoupledInstance(i, cfg, inst_spec,
                                       cache_capacity_blocks, cache_policy)
                      for i in range(n_instances)]
        self.ttft_slo = ttft_slo
        self.tbt_slo = tbt_slo
        self.max_batch = max_batch
        self.admit_load = admit_load

    def run(self, requests: list[Request], *, speedup: float = 1.0,
            load_sample_dt: float = 10.0) -> SimResult:
        events = _Events()
        records = [ReqRecord(req=r, arrival=r.timestamp / 1000.0 / speedup)
                   for r in requests]

        def tick(inst: _CoupledInstance, now: float):
            if not inst.batch and not inst.waiting:
                inst.ticking = False
                return
            # vLLM-v0 scheduling (the paper's baseline, §8.1.2): PREFILL
            # PRIORITY — every waiting prefill runs (whole, unchunked)
            # before decode resumes, VRAM permitting (coupled nodes
            # reserve prefill activation space — kv_frac 0.5 vs 0.8 on a
            # dedicated decode node). Long-context arrivals therefore
            # stall the whole decode batch for their full prefill time.
            cap = inst.cost.decode_capacity_tokens(kv_frac=0.5)
            dt = 0.0
            while inst.waiting and len(inst.batch) < self.max_batch and \
                    inst.kv_tokens + inst.waiting[0].req.input_length \
                    + inst.waiting[0].req.output_length <= cap:
                rec = inst.waiting.pop(0)
                n = inst.pool.lookup(rec.req.hash_ids)
                inst.pool.insert(rec.req.hash_ids[n:], start_pos=n)
                t_pf = inst.cost.prefill_time(rec.req.input_length,
                                              n * BLOCK_TOKENS)
                inst.queued_prefill_s -= t_pf
                dt += t_pf
                rec.ttft = now + dt - rec.arrival
                rec.prefix_blocks = n
                rec._last_tok = now + dt      # type: ignore
                rec._emitted = 1              # type: ignore
                inst.batch.append(rec)
                inst.kv_tokens += rec.req.input_length
            if inst.batch:
                dt += inst.cost.decode_iter_time(
                    len(inst.batch), inst.kv_tokens / len(inst.batch))
            t2 = now + dt
            done_recs = []
            for rec in inst.batch:
                if rec._emitted == 1 and rec.ttft + rec.arrival > now:
                    pass  # this request's first token was in this gap
                rec.tbts.append(t2 - rec._last_tok)  # type: ignore
                rec._last_tok = t2                   # type: ignore
                rec._emitted += 1                    # type: ignore
                inst.kv_tokens += 1
                if rec._emitted >= rec.req.output_length:  # type: ignore
                    done_recs.append(rec)
            for rec in done_recs:
                inst.batch.remove(rec)
                inst.kv_tokens -= rec.req.input_length + rec._emitted  # type: ignore
                rec.done = t2
            events.push(t2, lambda: tick(inst, t2))

        def arrive(rec: ReqRecord):
            now = rec.arrival
            inst = min(self.insts, key=lambda i: i.load())
            if inst.load() >= self.admit_load:
                rec.reject_stage = "admission"
                rec.reject_reason = "instance load limit"
                return
            rec.accepted = True
            inst.waiting.append(rec)
            inst.queued_prefill_s += inst.cost.prefill_time(
                rec.req.input_length, 0)
            if not inst.ticking:
                inst.ticking = True
                events.push(now, lambda: tick(inst, now))

        for rec in records:
            events.push(rec.arrival, lambda rec=rec: arrive(rec))

        while events:
            t, fn = events.pop()
            fn()
        t_end = max([r.done for r in records if r.completed]
                    + [r.arrival for r in records])
        return SimResult(records=records, duration=t_end, load_samples=[])
