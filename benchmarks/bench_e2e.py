"""Figures 11/12/13: end-to-end Mooncake vs coupled-vLLM throughput under
TTFT/TBT SLOs.

  * Fig 11 — public-dataset-shaped workloads (ArXiv-Summarization-like:
    ~8k in/229 out, no reuse; L-Eval-like: ~19k in/72 out, >80% reuse),
    Poisson arrivals, Mooncake-[3P+1D]/[2P+2D] vs vLLM-[4M].
  * Fig 12 — simulated data (16k/32k/64k/128k inputs, 50% cache ratio):
    max sustainable RPS under both SLOs.
  * Fig 13 — real-trace replay at scale, Mooncake-[10P+10D] vs vLLM-[20M]:
    TTFT/TBT CDF points + the +X% capacity headline.
"""
from __future__ import annotations

import numpy as np

from benchmarks.common import emit
from repro.configs.base import get_config
from repro.core.simulator import CoupledCluster, MooncakeCluster
from repro.core.trace import TraceSpec, generate_trace, simulated_requests

CFG = get_config("llama2-70b")
TTFT_SLO, TBT_SLO = 30.0, 0.1   # fixed SLOs for the real-trace replay


def _slos_for(input_len: int, cache_ratio: float):
    """§2/§8.1: thresholds = 10× / 5× the unloaded single-request values
    (TTFT_P90 = 10×, TBT_P90 = 5×)."""
    from repro.core.costmodel import CostModel, InstanceSpec
    cm = CostModel(CFG, InstanceSpec())
    ttft1 = cm.prefill_time(input_len, int(input_len * cache_ratio))
    tbt1 = cm.decode_iter_time(1, input_len)
    return 10.0 * ttft1, 5.0 * tbt1


def _dataset_like(n, avg_in, avg_out, cache_ratio, rps, seed=0):
    """Poisson arrivals with dataset-shaped lengths."""
    reqs = simulated_requests(n, avg_in, avg_out,
                              cache_ratio=cache_ratio, rps=rps, seed=seed)
    return reqs


def _max_rps(make_cluster, reqs_at, slos, lo=0.02, hi=8.0, iters=8):
    """Binary-search the highest RPS with ≥90% of requests meeting BOTH
    SLOs (the paper's 'throughput while satisfying SLOs')."""
    ttft_slo, tbt_slo = slos
    best = 0.0
    for _ in range(iters):
        mid = (lo + hi) / 2
        res = make_cluster(ttft_slo, tbt_slo).run(reqs_at(mid))
        t_ok, b_ok = res.slo_attainment(ttft_slo, tbt_slo)
        frac_done = len(res.completed()) / len(res.records)
        if min(t_ok, b_ok) >= 0.9 and frac_done >= 0.9:
            best, lo = mid, mid
        else:
            hi = mid
    return best


def fig11(fast: bool) -> list[dict]:
    n = 80 if fast else 200
    rows = []
    datasets = [("arxiv_sum", 8088, 229, 0.0), ("l_eval", 19019, 72, 0.8)]
    clusters = [
        ("mooncake_3P1D", lambda t, b: MooncakeCluster(
            CFG, n_prefill=3, n_decode=1, ttft_slo=t, tbt_slo=b)),
        ("mooncake_2P2D", lambda t, b: MooncakeCluster(
            CFG, n_prefill=2, n_decode=2, ttft_slo=t, tbt_slo=b)),
        ("vllm_4M", lambda t, b: CoupledCluster(CFG, n_instances=4)),
    ]
    for ds, avg_in, avg_out, cache in datasets:
        slos = _slos_for(avg_in, cache)
        base = None
        for name, mk in clusters:
            rps = _max_rps(mk, lambda r: _dataset_like(
                n, avg_in, avg_out, cache, r), slos)
            if name == "vllm_4M":
                base = rps
            rows.append(dict(dataset=ds, cluster=name,
                             ttft_slo_s=round(slos[0], 2),
                             max_rps_under_slo=round(rps, 3)))
        for r in rows:
            if r["dataset"] == ds and base:
                r["vs_vllm_pct"] = round(
                    100 * (r["max_rps_under_slo"] / base - 1), 1)
    return rows


def fig12(fast: bool) -> list[dict]:
    """§8.1.2: 'the long-context requests in simulated data significantly
    disrupt the decoding stage of vLLM. To counteract this, vLLM processes
    requests individually, rather than in batches' — the baseline runs
    max_batch=1 exactly as the paper configures it; Mooncake keeps full
    continuous batching because disaggregation isolates decode from the
    long prefills."""
    n = 60 if fast else 150
    rows = []
    lengths = (16384, 32768) if fast else (16384, 32768, 65536, 131072)
    for L in lengths:
        slos = _slos_for(L, 0.5)
        mk_mc = lambda t, b: MooncakeCluster(CFG, n_prefill=2, n_decode=2,
                                             ttft_slo=t, tbt_slo=b)
        mk_vl = lambda t, b: CoupledCluster(CFG, n_instances=4, max_batch=1)
        reqs_at = lambda r, L=L: simulated_requests(
            n, L, 512, cache_ratio=0.5, rps=r)
        rps_mc = _max_rps(mk_mc, reqs_at, slos)
        rps_vl = _max_rps(mk_vl, reqs_at, slos)
        rows.append(dict(input_len=L,
                         ttft_slo_s=round(slos[0], 2),
                         tbt_slo_ms=round(slos[1] * 1e3, 1),
                         mooncake_2P2D_rps=round(rps_mc, 3),
                         vllm_4M_rps=round(rps_vl, 3),
                         gain_pct=round(100 * (rps_mc / max(rps_vl, 1e-6) - 1),
                                        1)))
    return rows


def fig13(fast: bool) -> list[dict]:
    """Real-trace replay at increasing speed (10P+10D vs 20M): the paper's
    +75% claim = the extra request volume Mooncake absorbs within SLOs.
    Measured as GOODPUT (fully-completed requests meeting both SLOs per
    second, §2) at each replay speed."""
    n = 4000 if fast else 23_000
    reqs = generate_trace(TraceSpec(n_requests=n, seed=0))
    mk_mc = lambda: MooncakeCluster(CFG, n_prefill=10, n_decode=10,
                                    ttft_slo=TTFT_SLO, tbt_slo=TBT_SLO)
    mk_vl = lambda: CoupledCluster(CFG, n_instances=20,
                                   admit_load=60)   # bounded queue, as prod
    rows = []
    best_mc = best_vl = 0.0
    scale = 23_608 / n      # keep offered RPS comparable in --fast mode
    for sp in (s * scale for s in (2.0, 4.0, 6.0, 8.0, 12.0)):
        sp = round(sp, 1)
        res_mc = mk_mc().run(reqs, speedup=sp)
        res_vl = mk_vl().run(reqs, speedup=sp)
        g_mc = res_mc.goodput(TTFT_SLO, TBT_SLO)
        g_vl = res_vl.goodput(TTFT_SLO, TBT_SLO)
        best_mc, best_vl = max(best_mc, g_mc), max(best_vl, g_vl)
        rows.append(dict(
            replay_speed=sp,
            mc_goodput=round(g_mc, 2), vl_goodput=round(g_vl, 2),
            mc_ttft_p90=round(res_mc.ttft_p90(), 2),
            vl_ttft_p90=round(res_vl.ttft_p90(), 2),
            mc_tbt_p90_ms=round(res_mc.tbt_p90() * 1e3, 1),
            vl_tbt_p90_ms=round(res_vl.tbt_p90() * 1e3, 1),
            mc_slo_ttft=round(res_mc.slo_attainment(TTFT_SLO, TBT_SLO)[0], 3),
            vl_slo_ttft=round(res_vl.slo_attainment(TTFT_SLO, TBT_SLO)[0], 3),
        ))
    rows.append(dict(replay_speed="peak-goodput",
                     mc_goodput=round(best_mc, 2),
                     vl_goodput=round(best_vl, 2),
                     mc_ttft_p90=None, vl_ttft_p90=None,
                     mc_tbt_p90_ms=None, vl_tbt_p90_ms=None,
                     mc_slo_ttft=round(100 * (best_mc / max(best_vl, 1e-9)
                                              - 1), 1),
                     vl_slo_ttft="<- capacity_gain_pct"))
    return rows


def main(fast: bool = False):
    r11 = fig11(fast)
    emit("fig11_public_datasets", r11)
    r12 = fig12(fast)
    emit("fig12_simulated_data", r12)
    r13 = fig13(fast)
    emit("fig13_real_workload", r13)
    return r11 + r12 + r13


if __name__ == "__main__":
    import sys
    main(fast="--fast" in sys.argv)
