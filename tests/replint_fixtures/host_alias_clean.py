"""CLEAN fixture: defensive copies ahead of every jitted call.
Parsed by replint only — never imported."""
import jax
import jax.numpy as jnp
import numpy as np


class DecodeWorker:
    def __init__(self, n):
        self.block_table = np.zeros((n, 16), np.int32)
        self.seq_lens = np.zeros((n,), np.int32)
        self._step = jax.jit(lambda tbl, lens: (tbl, lens))

    def step(self, width):
        # .copy() makes a fresh temporary nothing else can mutate, so
        # the zero-copy device alias is safe
        tbl = jnp.asarray(self.block_table[:, :width].copy())
        lens = jnp.asarray(self.seq_lens.copy())
        return self._step(tbl, lens)

    def host_only(self, width):
        # host-side reads of the live table never reach the jit: fine
        return int(self.block_table[:, :width].sum())

    def step_star(self, width):
        # splatting copies is as safe as passing them positionally
        args = (self.block_table[:, :width].copy(), self.seq_lens.copy())
        return self._step(*args)

    def step_fresh(self, width):
        # an arithmetic result is a fresh array, not a view of the table
        local = self.block_table[:, :width] % 7
        return self._step(local, self.seq_lens.copy())
