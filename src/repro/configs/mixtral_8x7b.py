"""Mixtral-8x7B (8 experts top-2, sliding-window attention). [arXiv:2401.04088]"""
from repro.configs.base import ModelConfig, MoEConfig, register

CONFIG = register(ModelConfig(
    name="mixtral-8x7b",
    kind="moe",
    n_layers=32,
    d_model=4096,
    n_heads=32,
    n_kv_heads=8,
    head_dim=128,
    d_ff=14336,
    vocab_size=32000,
    moe=MoEConfig(n_experts=8, top_k=2, d_ff_expert=14336, parallelism="tp"),
    sliding_window=4096,
    rope_theta=1e6,
    optimizer="adafactor",
    source="arXiv:2401.04088 (assignment: 32L d4096 32H kv8 8e top-2 SWA)",
))
