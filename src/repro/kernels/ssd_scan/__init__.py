from repro.kernels.ssd_scan.ops import ssd_scan_op
from repro.kernels.ssd_scan.ref import ssd_naive_ref, ssd_scan_ref
