"""Layer-wise prefill (§5.2) — overlap KVCache load/store with compute.

Mechanism (paper): before layer l's attention, *wait* for layer l's async
KV load and *launch* layer l+1's; after the attention, *launch* layer l's
async store. Total latency then ≈ max(compute, transfer) instead of
compute + transfer — which is what lets prefill scheduling ignore VRAM
occupancy (the KVCache leaves the device as it is produced).

On real TPU the launch/wait pairs are async host DMAs; on this CPU rig we
(a) reproduce the *timeline semantics* analytically (`schedule`) for the
Figure 7 benchmark and the simulator's transfer model, and (b) verify the
*ordering contract* structurally (`verify_stream_order`): the prefill
layer scan yields layer l's KV before layer l+1's compute ends, so the
store stream can always run one layer behind compute.

Occupation-cost accounting (§5.2): a request's KVCache of size S held for
time T costs S·T; ``occupation_cost`` quantifies the savings vs chunked
inline prefill.
"""
from __future__ import annotations

from dataclasses import dataclass

from repro.configs.base import ModelConfig
from repro.core.costmodel import CostModel, InstanceSpec


@dataclass
class LayerwiseTimeline:
    t_compute_layer: float        # compute time per layer (s)
    t_store_layer: float          # KV store (device→DRAM/remote) per layer
    t_load_layer: float           # prefix KV load per layer
    total_overlapped: float       # layer-wise prefill wall time
    total_serial: float           # store-after-compute wall time
    store_hidden: bool            # store stream fits behind compute?

    @property
    def overhead(self) -> float:
        """Extra latency of layer-wise prefill vs no-store prefill —
        the paper's 'Layer-wise latency' curve in Figure 7."""
        n = max(self.n_layers, 1) if hasattr(self, "n_layers") else 1
        return self.total_overlapped - self.t_compute_layer * n


def schedule(cfg: ModelConfig, input_tokens: int, prefix_tokens: int = 0,
             inst: InstanceSpec = InstanceSpec(),
             store_bw: float | None = None) -> LayerwiseTimeline:
    """Per-layer launch/wait timeline of §5.2.

    Compute proceeds layer by layer; layer l's store starts when its
    attention completes and streams at ``store_bw``. With L layers:

      total_overlapped = t_load_0 + L·t_c + max(0, t_s − t_c)
                         (+ residual if the store stream backlogs)
      total_serial     = t_load_total + L·t_c + L·t_s
    """
    cm = CostModel(cfg, inst)
    L = max(cfg.attention_layers, 1)
    bw = store_bw if store_bw is not None else inst.hw.net_bw
    t_c = cm.prefill_time(input_tokens, prefix_tokens) / L
    per_layer_bytes = cm.kv_bytes(input_tokens) / L
    t_s = per_layer_bytes / bw
    load_bytes = cm.kv_bytes(prefix_tokens) / L
    t_l = load_bytes / inst.hw.dram_bw

    # load of layer l overlaps compute of layer l-1 (wait-before-attend):
    load_exposed = t_l + max(0.0, (L - 1) * (t_l - t_c))
    # stores pipeline behind compute; the last layer's store is exposed,
    # plus any backlog if t_s > t_c
    store_exposed = t_s + max(0.0, (L - 1) * (t_s - t_c))
    total_overlapped = load_exposed + L * t_c + store_exposed
    total_serial = L * (t_l + t_c + t_s)
    tl = LayerwiseTimeline(
        t_compute_layer=t_c, t_store_layer=t_s, t_load_layer=t_l,
        total_overlapped=total_overlapped, total_serial=total_serial,
        store_hidden=t_s <= t_c)
    tl.n_layers = L  # type: ignore[attr-defined]
    return tl


def occupation_cost(cfg: ModelConfig, input_tokens: int, *,
                    inst: InstanceSpec = InstanceSpec(),
                    inline_slowdown: float = 4.0) -> dict:
    """§5.2's S·T argument: VRAM-seconds held by a request's KVCache under
    (a) layer-wise streaming prefill (KV leaves as produced: T ≈ t_layer
    average residency ≈ total/2) and (b) chunked prefill inlined into a
    decode batch (T stretched by ``inline_slowdown``)."""
    cm = CostModel(cfg, inst)
    S = cm.kv_bytes(input_tokens)
    tl = schedule(cfg, input_tokens, inst=inst)
    t_fast = tl.total_overlapped
    return dict(
        kv_bytes=S,
        layerwise_cost=S * t_fast / 2,              # drains as it fills
        inline_cost=S * t_fast * inline_slowdown,   # held for the whole
        ratio=2 * inline_slowdown,                  # stretched prefill
    )


@dataclass
class ChunkOverlapPlan:
    """Per-chunk load-vs-compute schedule for a tiered prefix (§5.2 grafted
    onto Jin et al.'s split): recompute the non-DRAM blocks of
    [dram_head, split) on the accelerator WHILE blocks [split, n) stream
    from SSD layer-by-layer, then compute the uncached suffix once both
    land. DRAM blocks interleaved inside the head span are ASSEMBLED from
    the pool (chunk-skipping), not recomputed: the incremental-prefill
    loop sets their KV into the cache arena and resumes compute after
    them, so only the truly non-resident chunks cost FLOPs.

    ``t_overlapped``/``t_blocking`` cover the prefix phase only (the suffix
    cost is identical in both schedules and cancels out of the compare).
    """
    split: int                 # first block index loaded (not recomputed)
    n_resident: int
    dram_head: int
    t_head: float              # recompute time of non-DRAM in [dram_head, split)
    t_load: float              # load time of SSD blocks in [split, n)
    t_blocking: float          # load ALL SSD blocks, no overlap
    t_overlapped: float        # max(t_head, t_load)
    head_recompute: int = 0    # non-DRAM blocks recomputed in the head span
    head_skipped: int = 0      # DRAM blocks assembled mid-span (not recomputed)

    @property
    def predicted_speedup(self) -> float:
        return self.t_blocking / self.t_overlapped \
            if self.t_overlapped > 0 else 1.0


def overlap_split(tiers: list[str], t_compute_block: float,
                  t_load_block: float) -> ChunkOverlapPlan:
    """Choose the head/tail split of a resident prefix.

    ``tiers`` is the per-block residency ("dram"/"ssd") of the prefix
    chain, as ``HostKVPool.plan_fetch`` reports it. Candidate split s lies
    in [dram_head, n]: the engine recomputes the NON-DRAM blocks of
    [dram_head, s) — DRAM blocks inside the span are chunk-skipped
    (assembled from the pool at memcpy cost, priced free) — and loads the
    SSD blocks in [s, n). The pick minimises max(head recompute, tail
    load); s = dram_head degenerates to the blocking all-load schedule and
    s = n to pure recompute, so the chosen split is never predicted-slower
    than either — the executable ``why_not_both``.
    """
    n = len(tiers)
    d0 = 0
    while d0 < n and tiers[d0] == "dram":
        d0 += 1
    ssd_after = [0] * (n + 1)       # SSD blocks in [s, n)
    for s in range(n - 1, -1, -1):
        ssd_after[s] = ssd_after[s + 1] + (tiers[s] == "ssd")
    t_blocking = ssd_after[d0] * t_load_block
    best = None
    nondram = 0                     # non-DRAM blocks in [d0, s)
    for s in range(d0, n + 1):
        t_head = nondram * t_compute_block
        t_load = ssd_after[s] * t_load_block
        t_ov = max(t_head, t_load)
        if best is None or t_ov < best[0]:
            best = (t_ov, s, t_head, t_load, nondram)
        if s < n:
            nondram += tiers[s] != "dram"
    t_ov, s, t_head, t_load, rec = best if best is not None \
        else (0.0, d0, 0., 0., 0)
    skipped = sum(1 for t in tiers[d0:s] if t == "dram")
    return ChunkOverlapPlan(split=s, n_resident=n, dram_head=d0,
                            t_head=t_head, t_load=t_load,
                            t_blocking=t_blocking, t_overlapped=t_ov,
                            head_recompute=rec, head_skipped=skipped)


def verify_stream_order(cfg: ModelConfig, params, tokens) -> bool:
    """Structural check that per-layer KV is available layer-by-layer:
    the prefill scan's stacked KV equals per-layer recomputation, i.e. the
    KV of layer l is fully determined before layer l+1 runs (no backward
    dependency) — the precondition for §5.2's async store."""
    import jax
    import jax.numpy as jnp
    from repro.models.transformer import prefill

    logits, caches = jax.jit(
        lambda p, t: prefill(p, t, cfg))(params, tokens)
    k = caches.kv.k  # (L, B, S, KV, Dh) — the layer-major stream order
    return bool(jnp.all(jnp.isfinite(k)).item()) and k.shape[0] == \
        cfg.attention_layers
