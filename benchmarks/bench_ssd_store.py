"""Blocking SSD load vs overlapped layer-wise prefetch — measured TTFT.

The executable counterpart of the simulator's compute-vs-load pricing
(PR 1/2): long-context documents are prefilled once, demoted to the
file-backed ``SSDBlockStore`` as DRAM churns, then REVISITED with fresh
query suffixes. Each revisit must bring its prefix KV back from disk;
the two schedules under test are

* ``blocking``  — load every SSD-resident prefix block, then compute
  (the naive §5.2-less schedule), and
* ``overlap``   — ``PrefillWorker``'s head-recompute ∥ tail-load split
  (``layerwise.overlap_split``): chunks of the head are recomputed on
  the accelerator while the tail streams layer-by-layer off the store.

The store's read bandwidth is throttled so that loading one 512-token
block costs ``--ssd-ratio`` × the *measured* compute time of one block —
the reduced CPU model's compute:bytes ratio is nothing like a real
deployment's, so pinning the ratio (default 0.9, a SATA-class tier per
the why_not_both scenario) is what keeps the schedule comparison
meaningful and machine-independent.

Asserts: overlapped TTFT beats blocking on p90 AND mean, and both modes'
emitted tokens (first token + decode steps) are bit-exact vs a DRAM-only
run of the same workload.

    PYTHONPATH=src python -m benchmarks.bench_ssd_store [--fast|--quick]
"""
from __future__ import annotations

import shutil
import tempfile
import time

import numpy as np

from benchmarks.common import emit
from repro.core.trace import BLOCK_TOKENS


def _percentile(xs: list[float], q: float) -> float:
    return float(np.percentile(np.asarray(xs), q))


def _workload(vocab: int, n_docs: int, blocks_per_doc: int, seed: int = 0):
    """Long-context docs + per-visit fresh 64-token query suffixes."""
    rng = np.random.default_rng(seed)
    docs = [rng.integers(0, vocab, blocks_per_doc * BLOCK_TOKENS)
            for _ in range(n_docs)]
    cold = [np.concatenate([d, rng.integers(0, vocab, 64)]) for d in docs]
    revisit = [np.concatenate([d, rng.integers(0, vocab, 64)]) for d in docs]
    return cold, revisit


def _run_mode(mode, params, cfg, cold, revisit, *, dram_blocks,
              read_bw, max_new: int = 4):
    """One full cold+revisit pass; returns (ttfts, token streams, stats)."""
    import jax  # noqa: F401 — ensures backend is up before timing

    from repro.serving.engine import DecodeWorker, HostKVPool, PrefillWorker

    tmp = tempfile.mkdtemp(prefix=f"bench_ssd_{mode}_")
    if mode == "dram":
        pool = HostKVPool(capacity_blocks=None)
        pw = PrefillWorker(params, cfg, pool, prefill_chunk=256)
    else:
        pool = HostKVPool(capacity_blocks=dram_blocks,
                          ssd_capacity_blocks=4096, ssd_dir=tmp,
                          ssd_read_bw=read_bw, writeback_batch=4)
        pw = PrefillWorker(params, cfg, pool, prefill_chunk=256,
                           ssd_mode=mode)
    max_len = len(cold[0]) + max_new + 8
    dw = DecodeWorker(params, cfg, max_batch=1, max_len=max_len)

    streams: list[list[int]] = []
    for toks in cold:
        pw(toks)
    if pool.store is not None:
        pool.store.flush()          # cold KV must be ON DISK, not staged

    ttfts: list[float] = []
    for rid, toks in enumerate(revisit):
        t0 = time.monotonic()
        pres = pw(toks)
        ttfts.append(time.monotonic() - t0)
        out = [pres.first_token]
        dw.join(rid, pres, max_new=max_new)
        while dw.n_active:
            for _, tok, _fin in dw.step():
                out.append(tok)
        streams.append(out)

    stats = dict(pw.stats())
    stats.update(pool.store.stats() if pool.store is not None else {})
    pool.close()
    shutil.rmtree(tmp, ignore_errors=True)
    return ttfts, streams, stats


def main(fast: bool = False, ssd_ratio: float = 0.9):
    import jax

    from repro.configs.base import get_config
    from repro.models.transformer import init_params
    from repro.serving.engine import HostKVPool, PrefillWorker
    from repro.serving.layerwise import overlap_split

    cfg = get_config("smollm-360m").reduced()
    params = init_params(cfg, jax.random.PRNGKey(0))
    n_docs, blocks_per_doc = (3, 4) if fast else (4, 5)
    cold, revisit = _workload(cfg.vocab_size, n_docs, blocks_per_doc)

    # calibrate the compute time of one 512-token block, then throttle the
    # store so one block's load costs ssd_ratio × that (see module doc)
    calib_pool = HostKVPool()
    calib = PrefillWorker(params, cfg, calib_pool, prefill_chunk=256)
    calib(cold[0])
    t_block = calib._t_block_ema
    from repro.core.cache import kv_block_bytes
    block_bytes = kv_block_bytes(cfg)
    read_bw = block_bytes / (ssd_ratio * t_block)
    print(f"[ssd_store] {n_docs} docs × {blocks_per_doc} blocks; measured "
          f"t_compute/block {t_block * 1e3:.0f} ms, block {block_bytes >> 10} "
          f"KiB → throttle {read_bw / 1e6:.2f} MB/s (ratio {ssd_ratio})")

    # DRAM pool sized to one doc: by revisit time every doc's blocks have
    # been demoted to the store (LRU), so each revisit is an SSD-tier hit
    dram_blocks = blocks_per_doc
    results = {}
    rows = []
    for mode in ("dram", "blocking", "overlap"):
        ttfts, streams, stats = _run_mode(
            mode, params, cfg, cold, revisit,
            dram_blocks=dram_blocks, read_bw=read_bw)
        results[mode] = (ttfts, streams)
        row = dict(mode=mode,
                   ttft_avg_s=round(float(np.mean(ttfts)), 3),
                   ttft_p50_s=round(_percentile(ttfts, 50), 3),
                   ttft_p90_s=round(_percentile(ttfts, 90), 3),
                   reused_blocks=stats["reused_blocks"],
                   ssd_loaded_blocks=stats.get("ssd_loaded_blocks", 0),
                   layer_reads=stats.get("layer_reads", 0),
                   writeback_flushes=stats.get("n_flushes", 0),
                   read_failures=stats.get("read_failures", 0))
        rows.append(row)

    # modeled timeline for a representative all-SSD revisit (§5.2 split)
    tiers = ["ssd"] * blocks_per_doc
    ov = overlap_split(tiers, t_block, ssd_ratio * t_block)
    rows.append(dict(mode="model", ttft_avg_s=None, ttft_p50_s=None,
                     ttft_p90_s=None, reused_blocks=blocks_per_doc,
                     split=ov.split,
                     t_blocking_s=round(ov.t_blocking, 3),
                     t_overlapped_s=round(ov.t_overlapped, 3),
                     predicted_speedup=round(ov.predicted_speedup, 3)))
    emit("ssd_store", rows)

    # --- acceptance: overlap strictly beats blocking; both bit-exact ----
    blk, ovl = results["blocking"][0], results["overlap"][0]
    p90_blk, p90_ovl = _percentile(blk, 90), _percentile(ovl, 90)
    print(f"\nTTFT p90: blocking {p90_blk:.2f}s vs overlapped {p90_ovl:.2f}s "
          f"({p90_blk / p90_ovl:.2f}× ; modeled {ov.predicted_speedup:.2f}×)")
    assert p90_ovl < p90_blk, \
        f"overlapped prefetch must beat blocking on TTFT p90 " \
        f"({p90_ovl:.3f} !< {p90_blk:.3f})"
    assert float(np.mean(ovl)) < float(np.mean(blk)), \
        "overlapped prefetch must beat blocking on mean TTFT"
    for mode in ("blocking", "overlap"):
        assert results[mode][1] == results["dram"][1], \
            f"{mode} token streams diverge from DRAM-only (not bit-exact)"
    print("bit-exact: blocking ✓  overlap ✓ (vs DRAM-only token streams)")
    return rows


if __name__ == "__main__":
    import argparse
    ap = argparse.ArgumentParser()
    ap.add_argument("--fast", "--quick", dest="fast", action="store_true")
    ap.add_argument("--ssd-ratio", type=float, default=0.9,
                    help="per-block SSD load cost as a fraction of measured "
                         "per-block compute (throttle; see module doc)")
    a = ap.parse_args()
    main(fast=a.fast, ssd_ratio=a.ssd_ratio)
