"""Trace generator / loader — statistics and format round-trip."""
import json
import os

import numpy as np
import pytest
try:
    from hypothesis import given, settings, strategies as st
except ImportError:  # sandboxed env: vendored shim (seeded random)
    from _hypothesis_shim import given, settings, strategies as st

from repro.core.trace import (BLOCK_TOKENS, Request, TraceSpec,
                              generate_trace, load_trace, save_trace,
                              simulated_requests, trace_stats)


@pytest.fixture(scope="module")
def trace():
    return generate_trace(TraceSpec(n_requests=4000, seed=7))


def test_stats_match_paper(trace):
    s = trace_stats(trace)
    assert 5500 < s["avg_input"] < 10500      # paper: 7,590
    assert 120 < s["avg_output"] < 260        # paper: 182
    assert s["frac_blocks_single_use"] > 0.5  # paper: >50% unused again
    assert 0.4 < s["max_reuse"] < 0.62        # paper: ~50% ceiling


def test_arrivals_sorted_and_in_window(trace):
    ts = [r.timestamp for r in trace]
    assert ts == sorted(ts)
    assert ts[0] >= 0 and ts[-1] <= 3_600_000


def test_hash_chain_lengths(trace):
    for r in trace[:200]:
        assert len(r.hash_ids) >= max(r.input_length // BLOCK_TOKENS, 1) - 1
        assert len(r.hash_ids) <= r.input_length // BLOCK_TOKENS + 1


def test_session_prefix_sharing(trace):
    """Some requests must share non-trivial prefixes (sessions)."""
    by_first = {}
    shared = 0
    for r in trace:
        if len(r.hash_ids) >= 3:
            key = tuple(r.hash_ids[:3])
            shared += by_first.get(key, 0) > 0
            by_first[key] = by_first.get(key, 0) + 1
    assert shared > 50


def test_jsonl_round_trip(tmp_path, trace):
    p = str(tmp_path / "t.jsonl")
    save_trace(trace[:100], p)
    back = load_trace(p)
    assert len(back) == 100
    for a, b in zip(trace[:100], back):
        assert (a.timestamp, a.input_length, a.output_length, a.hash_ids) \
            == (b.timestamp, b.input_length, b.output_length, b.hash_ids)


def test_loads_paper_sample_format(tmp_path):
    """The exact Listing-1 syntax must load."""
    p = str(tmp_path / "paper.jsonl")
    with open(p, "w") as f:
        f.write('{"timestamp": 27482, "input_length": 6955, '
                '"output_length": 52, "hash_ids": [46, 47, 2353]}\n')
        f.write('{"timestamp": 30535, "input_length": 6472, '
                '"output_length": 26, "hash_ids": [46, 47, 2366]}\n')
    reqs = load_trace(p)
    assert reqs[0].input_length == 6955
    assert reqs[0].hash_ids[:2] == reqs[1].hash_ids[:2]


@given(st.floats(0.0, 1.0), st.integers(1000, 65536))
@settings(max_examples=20, deadline=None)
def test_simulated_cache_ratio(ratio, input_len):
    reqs = simulated_requests(100, input_len, cache_ratio=ratio, rps=2.0)
    n_blocks = -(-input_len // BLOCK_TOKENS)
    for r in reqs:
        assert len(r.hash_ids) == n_blocks
        assert r.input_length == input_len
    # shared prefixes appear iff ratio > 0
    firsts = {}
    n_shared = 0
    for r in reqs:
        key = tuple(r.hash_ids[:max(int(n_blocks * ratio), 1)])
        n_shared += firsts.get(key, 0) > 0
        firsts[key] = 1
    if int(n_blocks * ratio) >= 1 and ratio > 0:
        assert n_shared > 0
