"""Pallas kernels vs pure-jnp oracles: shape/dtype sweeps, interpret=True."""
import jax
import jax.numpy as jnp
import numpy as np
import pytest
try:
    from hypothesis import given, settings, strategies as st
except ImportError:  # sandboxed env: vendored shim (seeded random)
    from _hypothesis_shim import given, settings, strategies as st

from repro.kernels.flash_prefill.kernel import flash_prefill
from repro.kernels.flash_prefill.ref import flash_prefill_ref
from repro.kernels.paged_attention.kernel import paged_attention
from repro.kernels.paged_attention.ref import paged_attention_ref
from repro.kernels.ssd_scan.kernel import ssd_scan
from repro.kernels.ssd_scan.ref import ssd_naive_ref, ssd_scan_ref

KEY = jax.random.PRNGKey(0)


# ---------------------------------------------------------------- flash ----
FLASH_CASES = [
    # B, Sq, Sk, H, KV, D, offset, window
    (2, 128, 256, 4, 2, 64, 128, 0),
    (1, 256, 256, 8, 8, 128, 0, 0),      # MHA
    (2, 128, 512, 4, 1, 32, 384, 128),   # MQA + sliding window + offset
    (1, 128, 128, 16, 4, 128, 0, 0),     # GQA 4:1
    (1, 64, 192, 2, 2, 64, 128, 0),      # small blocks
]


@pytest.mark.parametrize("case", FLASH_CASES)
@pytest.mark.parametrize("dtype", [jnp.bfloat16, jnp.float32])
def test_flash_prefill_matches_ref(case, dtype):
    B, Sq, Sk, H, KV, D, off, win = case
    ks = jax.random.split(KEY, 3)
    q = jax.random.normal(ks[0], (B, Sq, H, D), dtype)
    k = jax.random.normal(ks[1], (B, Sk, KV, D), dtype)
    v = jax.random.normal(ks[2], (B, Sk, KV, D), dtype)
    blk = lambda s: next(b for b in (128, 64, 32, 16) if s % b == 0)
    out = flash_prefill(q, k, v, q_offset=off, window=win,
                        bq=blk(Sq), bk=blk(Sk), interpret=True)
    ref = flash_prefill_ref(q, k, v, q_offset=off, window=win)
    tol = 2e-2 if dtype == jnp.bfloat16 else 2e-5
    np.testing.assert_allclose(np.asarray(out, np.float32),
                               np.asarray(ref, np.float32),
                               atol=tol, rtol=tol)


def test_flash_prefill_is_causal():
    """Output at position i must not depend on keys at positions > i."""
    B, S, H, D = 1, 128, 2, 64
    ks = jax.random.split(KEY, 3)
    q = jax.random.normal(ks[0], (B, S, H, D), jnp.float32)
    k = jax.random.normal(ks[1], (B, S, H, D), jnp.float32)
    v = jax.random.normal(ks[2], (B, S, H, D), jnp.float32)
    out1 = flash_prefill(q, k, v, interpret=True, bq=64, bk=64)
    k2 = k.at[:, 100:].set(99.0)     # corrupt the future
    v2 = v.at[:, 100:].set(-99.0)
    out2 = flash_prefill(q, k2, v2, interpret=True, bq=64, bk=64)
    np.testing.assert_allclose(np.asarray(out1[:, :100]),
                               np.asarray(out2[:, :100]), atol=1e-5)
    assert not np.allclose(np.asarray(out1[:, 101:]),
                           np.asarray(out2[:, 101:]))


# ---------------------------------------------------------------- paged ----
PAGED_CASES = [
    # B, H, KV, D, P, page, max_pages
    (4, 8, 2, 64, 32, 64, 4),
    (2, 4, 4, 128, 16, 128, 2),
    (3, 15, 5, 32, 64, 64, 8),      # smollm-style GQA 3:1
    (1, 2, 1, 128, 8, 64, 3),       # MQA
]


@pytest.mark.parametrize("case", PAGED_CASES)
def test_paged_attention_matches_ref(case):
    B, H, KV, D, P, page, mp = case
    ks = jax.random.split(KEY, 3)
    q = jax.random.normal(ks[0], (B, H, D), jnp.bfloat16)
    kp = jax.random.normal(ks[1], (P, page, KV, D), jnp.bfloat16)
    vp = jax.random.normal(ks[2], (P, page, KV, D), jnp.bfloat16)
    rng = np.random.default_rng(0)
    table = jnp.asarray(rng.integers(1, P, (B, mp)), jnp.int32)
    lens = jnp.asarray(rng.integers(1, mp * page + 1, (B,)), jnp.int32)
    out = paged_attention(q, kp, vp, table, lens, interpret=True)
    ref = paged_attention_ref(q, kp, vp, table, lens)
    np.testing.assert_allclose(np.asarray(out, np.float32),
                               np.asarray(ref, np.float32),
                               atol=2e-2, rtol=2e-2)


def test_paged_attention_respects_seq_lens():
    """Tokens past seq_len must not contribute."""
    B, H, KV, D, P, page, mp = 1, 2, 2, 64, 8, 64, 2
    ks = jax.random.split(KEY, 3)
    q = jax.random.normal(ks[0], (B, H, D), jnp.float32)
    kp = jax.random.normal(ks[1], (P, page, KV, D), jnp.float32)
    vp = jax.random.normal(ks[2], (P, page, KV, D), jnp.float32)
    table = jnp.asarray([[1, 2]], jnp.int32)
    out1 = paged_attention(q, kp, vp, table,
                           jnp.asarray([70], jnp.int32), interpret=True)
    kp2 = kp.at[2, 10:].set(50.0)    # corrupt beyond token 70 (page 2 at 64+)
    vp2 = vp.at[2, 10:].set(-50.0)
    out2 = paged_attention(q, kp2, vp2, table,
                           jnp.asarray([70], jnp.int32), interpret=True)
    np.testing.assert_allclose(np.asarray(out1), np.asarray(out2), atol=1e-5)


# ------------------------------------------------------------------ ssd ----
SSD_CASES = [
    # b, s, h, p, n, chunk, with_h0
    (2, 128, 4, 32, 64, 32, False),
    (1, 256, 2, 64, 128, 64, True),
    (2, 64, 8, 16, 32, 64, False),
    (1, 64, 1, 8, 16, 16, True),
]


@pytest.mark.parametrize("case", SSD_CASES)
def test_ssd_scan_matches_refs(case):
    b, s, h, p, n, chunk, with_h0 = case
    ks = jax.random.split(KEY, 6)
    x = jax.random.normal(ks[0], (b, s, h, p), jnp.bfloat16)
    dt = jax.nn.softplus(jax.random.normal(ks[1], (b, s, h))).astype(jnp.float32)
    A = -jnp.exp(jax.random.normal(ks[2], (h,)) * 0.5)
    B = jax.random.normal(ks[3], (b, s, n), jnp.bfloat16)
    C = jax.random.normal(ks[4], (b, s, n), jnp.bfloat16)
    h0 = jax.random.normal(ks[5], (b, h, p, n), jnp.float32) if with_h0 else None
    y_k, hT_k = ssd_scan(x, dt, A, B, C, h0, chunk=chunk, interpret=True)
    y_r, hT_r = ssd_scan_ref(x, dt, A, B, C, chunk=chunk, h0=h0)
    y_n, hT_n = ssd_naive_ref(x, dt, A, B, C, h0=h0)
    np.testing.assert_allclose(np.asarray(y_k), np.asarray(y_r),
                               atol=0.1, rtol=0.1)
    np.testing.assert_allclose(np.asarray(hT_k), np.asarray(hT_r),
                               atol=0.1, rtol=0.1)
    np.testing.assert_allclose(np.asarray(y_r), np.asarray(y_n),
                               atol=0.1, rtol=0.1)


@given(st.integers(1, 3), st.sampled_from([32, 64]), st.integers(1, 4),
       st.sampled_from([8, 16]), st.sampled_from([16, 32]))
@settings(max_examples=10, deadline=None)
def test_ssd_scan_property_sweep(b, s, h, p, n):
    """Kernel ≡ naive recurrence across random small shapes."""
    ks = jax.random.split(jax.random.PRNGKey(b * 100 + s + h), 5)
    x = jax.random.normal(ks[0], (b, s, h, p), jnp.float32)
    dt = jax.nn.softplus(jax.random.normal(ks[1], (b, s, h)))
    A = -jnp.exp(jax.random.normal(ks[2], (h,)) * 0.3)
    B = jax.random.normal(ks[3], (b, s, n), jnp.float32)
    C = jax.random.normal(ks[4], (b, s, n), jnp.float32)
    y_k, hT_k = ssd_scan(x, dt, A, B, C, chunk=min(32, s), interpret=True)
    y_n, hT_n = ssd_naive_ref(x, dt, A, B, C)
    np.testing.assert_allclose(np.asarray(y_k), np.asarray(y_n),
                               atol=5e-3, rtol=5e-3)
    np.testing.assert_allclose(np.asarray(hT_k), np.asarray(hT_n),
                               atol=5e-3, rtol=5e-3)


def test_model_mamba_block_consistency():
    """The model's ssd_chunked (used by mamba2/jamba) agrees with the
    kernel across a chunk-boundary continuation."""
    b, s, h, p, n, chunk = 1, 64, 2, 16, 32, 16
    ks = jax.random.split(KEY, 5)
    x = jax.random.normal(ks[0], (b, s, h, p), jnp.float32)
    dt = jax.nn.softplus(jax.random.normal(ks[1], (b, s, h)))
    A = -jnp.exp(jax.random.normal(ks[2], (h,)) * 0.3)
    B = jax.random.normal(ks[3], (b, s, n), jnp.float32)
    C = jax.random.normal(ks[4], (b, s, n), jnp.float32)
    # full scan vs two halves with state carry
    y_full, hT = ssd_scan(x, dt, A, B, C, chunk=chunk, interpret=True)
    y1, h1 = ssd_scan(x[:, :32], dt[:, :32], A, B[:, :32], C[:, :32],
                      chunk=chunk, interpret=True)
    y2, h2 = ssd_scan(x[:, 32:], dt[:, 32:], A, B[:, 32:], C[:, 32:],
                      h1, chunk=chunk, interpret=True)
    np.testing.assert_allclose(np.asarray(jnp.concatenate([y1, y2], 1)),
                               np.asarray(y_full), atol=5e-3, rtol=5e-3)
    np.testing.assert_allclose(np.asarray(h2), np.asarray(hT),
                               atol=5e-3, rtol=5e-3)


def test_model_prefill_via_pallas_matches_default():
    """End-to-end: the model's prefill with the Pallas flash kernel routed
    in (REPRO_USE_PALLAS=1, interpret mode) equals the jnp path."""
    import subprocess, sys, os
    code = '''
import os, sys
os.environ["REPRO_USE_PALLAS"] = sys.argv[1]
import jax, numpy as np
from repro.configs.base import get_config
from repro.models.transformer import init_params, prefill
cfg = get_config("qwen2.5-3b").reduced()
params = init_params(cfg, jax.random.PRNGKey(0))
tokens = jax.random.randint(jax.random.PRNGKey(1), (1, 64), 0, cfg.vocab_size)
logits, _ = jax.jit(lambda p, t: prefill(p, t, cfg))(params, tokens)
np.save(f"/tmp/pallas_model_{sys.argv[1]}.npy", np.asarray(logits, np.float32))
'''
    env = dict(os.environ)
    env["PYTHONPATH"] = os.path.join(
        os.path.dirname(os.path.dirname(os.path.abspath(__file__))), "src")
    for flag in ("0", "1"):
        subprocess.run([sys.executable, "-c", code, flag], env=env,
                       check=True, timeout=600)
    a = np.load("/tmp/pallas_model_0.npy")
    b = np.load("/tmp/pallas_model_1.npy")
    np.testing.assert_allclose(a, b, atol=5e-2, rtol=5e-2)
    assert int(a[0].argmax()) == int(b[0].argmax())
