"""Overload-oriented scheduling (§7) — compatibility shim.

The admission policies moved onto the policy registry in
``repro.core.policies.admission``; import from there (or build by name via
``make_admission`` / ``get_policy("admission", name)``). This module
re-exports the public names so existing imports keep working.
"""
from repro.core.policies.admission import (AdmissionPolicy,  # noqa: F401
                                           BaselineAdmission, EarlyRejection,
                                           PredictiveEarlyRejection,
                                           make_admission)
