"""Figure 2: normalized throughput/latency of prefill and decoding stages
vs sequence length / batch size (dummy LLaMA2-70B cost model, cross-checked
against the dry-run HLO in benchmarks/roofline.py)."""
from __future__ import annotations

from benchmarks.common import emit
from repro.configs.base import get_config
from repro.core.costmodel import CostModel, InstanceSpec


def main(fast: bool = False):
    cm = CostModel(get_config("llama2-70b"), InstanceSpec())
    rows = []
    base = None
    for L in (1024, 2048, 4096, 8192, 16384, 32768, 65536, 131072):
        t = cm.prefill_time(L)
        base = base or t / L
        rows.append(dict(stage="prefill", x=L, latency_s=round(t, 4),
                         tok_per_s=round(L / t, 1),
                         norm_latency_per_tok=round(t / L / base, 3)))
    emit("fig2_prefill_stage", rows)

    rows2 = []
    base_t = None
    for b in (1, 2, 4, 8, 16, 32, 64, 128, 256):
        t = cm.decode_iter_time(b, avg_ctx=8192)
        base_t = base_t or t
        rows2.append(dict(stage="decode", x=b, iter_ms=round(t * 1e3, 3),
                          tok_per_s=round(b / t, 1),
                          norm_latency=round(t / base_t, 3)))
    emit("fig2_decode_stage", rows2)
    return rows + rows2


if __name__ == "__main__":
    main()
