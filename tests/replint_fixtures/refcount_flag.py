"""FLAG fixture: page-run acquires that can leak. Parsed by replint
only — never imported."""


def stage_unprotected(pool, hash_ids, kv):
    # the pre-fix stage_run shape: a MemoryError-only handler leaks the
    # run on every OTHER exception write_run can raise
    run = pool.alloc(4)
    pool.write_run(run, kv)                            # finding: can raise
    pool.register_block(hash_ids[0], run)
    return run


def partial_handler(pool, kv):
    try:
        run = pool.alloc(4)                            # finding
        pool.write_run(run, kv)
        return run
    except MemoryError:
        pool.release(run)
        return None
    # no catch-all: ValueError from write_run leaks the run


def dropped_result(pool):
    pool.alloc(2)                                      # finding: discarded


def retained_then_branch(pool, pages, flags):
    pool.retain(pages)                                 # finding
    if flags:                                          # branch may skip
        return pages


def export_on_one_branch_only(pool, n_tokens, cold):
    run = pool.alloc(4)                                # finding
    if cold:                                           # warm path leaks
        return pool.export_run(run, n_tokens)
    return None
