"""Conductor — Mooncake's KVCache-centric global scheduler (§6, Algorithm 1).

For each request the Conductor asks its prefill routing policy for a list
of candidate ``Arm``s — ways to serve the prefill, each with a predicted
TTFT — and commits the best one. The built-in arms are

  * recompute (cache-aware, local):  T_queue + T_prefill(len, local_prefix)
  * peer fetch (cache balancing):    T_transfer + T_queue + T_prefill(len, best_prefix)
  * SSD load (compute-vs-load):      max(T_queue, T_ssd_load) + T_prefill(len, tier_prefix)
  * overlap (why-not-both):          max(T_queue + T_head, T_ssd_load) + T_suffix
  * peer SSD (global pool):          max(T_queue, T_peer_ssd + T_hop) + T_prefill(len, ext_prefix)

The SSD load is *prefetched*: it starts immediately on the node's SSD read
channel and overlaps the queue wait, so only the slower of queue-drain and
load delays the compute. The channel serialises loads FIFO
(``Messenger.estimate_ssd``), so a node whose SSD is already streaming one
long prefix makes the next load correctly expensive. Which arms exist for
a request is the routing policy's business (``strategy`` resolves through
the policy registry — see ``repro.core.policies``); the Conductor is only
the commit machinery: SLO admission (line 25), hot-spot migration
bookkeeping (line 28 — hot blocks spread automatically because they keep
winning matches), queue/pool/decode accounting.

Overload-oriented admission policies (§7) wrap ``schedule`` with earlier,
load-based rejection — see ``repro.core.policies.admission``. They set the
``accounting`` knob ("pending" counts accepted-but-still-prefilling work
in decode pre-selection; "current" reproduces the §7.2 time lag).
"""
from __future__ import annotations

from dataclasses import dataclass
from typing import Optional

from repro.core.cache import CachePool, StateCache
from repro.core.costmodel import CostModel
from repro.core.messenger import Messenger
from repro.core.policies.base import Arm, PolicyContext, get_policy
from repro.core.trace import BLOCK_TOKENS, Request


@dataclass
class PrefillInstance:
    """One prefill node (group): local cache pool + FIFO work queue."""
    iid: int
    pool: CachePool
    cost: CostModel
    queue_free_at: float = 0.0     # time the queue drains
    total_busy: float = 0.0
    n_scheduled: int = 0

    def queue_time(self, now: float) -> float:
        return max(self.queue_free_at - now, 0.0)

    def utilization(self, now: float) -> float:
        return self.total_busy / now if now > 0 else 0.0


@dataclass
class DecodeInstance:
    """One decoding node: continuous batch of active requests."""
    iid: int
    cost: CostModel
    active: int = 0                 # requests in the batch
    kv_tokens: float = 0.0          # total context tokens held
    pending: int = 0                # accepted, prefill not yet done
    pending_tokens: float = 0.0
    n_scheduled: int = 0

    def avg_ctx(self) -> float:
        return self.kv_tokens / self.active if self.active else 0.0

    def predicted_tbt(self, extra_reqs: int = 0, extra_tokens: float = 0.0,
                      include_pending: bool = True) -> float:
        b = self.active + extra_reqs + (self.pending if include_pending else 0)
        toks = self.kv_tokens + extra_tokens \
            + (self.pending_tokens if include_pending else 0.0)
        if b == 0:
            return 0.0
        return self.cost.decode_iter_time(b, toks / b)

    def vram_ok(self, extra_tokens: float, include_pending: bool = True) -> bool:
        cap = self.cost.decode_capacity_tokens()
        held = self.kv_tokens + (self.pending_tokens if include_pending else 0.0)
        return held + extra_tokens <= cap


@dataclass
class Decision:
    accepted: bool
    prefill: Optional[PrefillInstance] = None
    decode: Optional[DecodeInstance] = None
    expected_ttft: float = 0.0
    expected_tbt: float = 0.0
    prefix_blocks: int = 0              # blocks reused (local or migrated)
    migrated_blocks: int = 0            # hot-spot replication volume
    transfer_from: Optional[int] = None
    ssd_blocks: int = 0                 # prefix blocks loaded from local SSD
    peer_ssd_blocks: int = 0            # prefix blocks fetched off a peer SSD
    ssd_load_time: float = 0.0          # committed load duration incl. channel
                                        # backlog (overlaps the queue wait)
    compute_time: float = 0.0           # prefill busy-time the arm charges
    arm_kind: str = ""                  # which arm won (see policies.base.Arm)
    reject_reason: str = ""


class Conductor:
    """Algorithm 1 + hot-spot migration, driven by registry policies.

    ``strategy`` names a registered prefill routing policy — built-ins:

    * ``kvcache`` — full Algorithm 1 (cache-aware + cache load balancing)
    * ``cache_aware`` — §6.1 only: always use the local prefix, never
      migrate (the Figure 8 "cache-aware" baseline)
    * ``load_balance`` — pick the least-loaded prefill instance
    * ``random`` — uniform random instance
    * ``load_aware`` — FlowKV-style priced transfers + imbalance penalty
    * ``why_not_both`` — overlapped head-recompute + tail-SSD-load arm

    ``accounting`` ("pending" | "current") controls whether decode
    pre-selection counts accepted-but-still-prefilling requests; §7
    admission policies set it to match their stage model.
    """

    def __init__(self, prefills: list[PrefillInstance],
                 decodes: list[DecodeInstance], messenger: Messenger, *,
                 ttft_slo: float, tbt_slo: float,
                 balancing_threshold: float = 1.3,
                 strategy: str = "kvcache", decode_policy: str = "min_tbt",
                 accounting: str = "pending", rng=None,
                 directory=None) -> None:
        self.P = prefills
        self.D = decodes
        self.messenger = messenger
        self.ttft_slo = ttft_slo
        self.tbt_slo = tbt_slo
        import random as _random
        self.ctx = PolicyContext(messenger=messenger,
                                 balancing_threshold=balancing_threshold,
                                 rng=rng or _random.Random(0),
                                 directory=directory)
        self.strategy = strategy
        self.prefill_policy = get_policy("prefill", strategy)(self.ctx)
        self.decode_policy = get_policy("decode", decode_policy)(self.ctx)
        self.accounting = accounting
        self.n_migrations = 0
        self.migrated_bytes = 0.0
        self.n_ssd_loads = 0
        self.ssd_loaded_bytes = 0.0
        self.n_peer_ssd_loads = 0
        self.peer_ssd_bytes = 0.0

    @property
    def threshold(self) -> float:
        return self.ctx.balancing_threshold

    @property
    def accounting(self) -> str:
        return self._accounting

    @accounting.setter
    def accounting(self, mode: str) -> None:
        if mode not in ("pending", "current"):
            raise ValueError(f"accounting must be 'pending' or 'current', "
                             f"got {mode!r}")
        self._accounting = mode

    @property
    def account_pending(self) -> bool:
        """Whether decode pre-selection counts in-flight commitments."""
        return self.accounting == "pending"

    def propose(self, req: Request, now: float) -> list[Arm]:
        """Candidate arms for a request (pure — no side effects)."""
        return self.prefill_policy.propose(req, self.P, now)

    # ---- the public entry point ---------------------------------------
    def schedule(self, req: Request, now: float) -> Decision:
        arms = self.propose(req, now)
        if not arms:
            return Decision(False, reject_reason="no prefill arm")
        arm = min(arms, key=lambda a: a.sort_key)   # first wins ties
        if arm.ttft > self.ttft_slo:
            # a score-biased pick (e.g. load_aware's imbalance penalty) must
            # not reject a request another proposed arm could serve in SLO
            arm = min(arms, key=lambda a: a.ttft)
        d, tbt = self.decode_policy.select(req, self.D, now,
                                           include_pending=self.account_pending)
        if d is None:
            return Decision(False, reject_reason="no decode slot (VRAM)")
        if arm.ttft > self.ttft_slo or tbt > self.tbt_slo:
            reason = "TTFT SLO" if arm.ttft > self.ttft_slo else "TBT SLO"
            return Decision(False, reject_reason=reason,
                            expected_ttft=arm.ttft, expected_tbt=tbt)

        # ---- commit: the arm's own side effects (peer transfer enqueue +
        # block replication, SSD channel enqueue) happen in its closure;
        # ``load_done`` is when the arm's data lands — compute starts once
        # both the queue has drained and the data is there.
        inst = arm.instance
        load_done = arm.land(now)
        if arm.migrate_blocks and arm.transfer_from is not None:
            self.n_migrations += 1
            self.migrated_bytes += inst.cost.kv_bytes(
                arm.migrate_blocks * BLOCK_TOKENS)
        if arm.ssd_blocks:
            self.n_ssd_loads += 1
            self.ssd_loaded_bytes += inst.cost.kv_bytes(
                arm.ssd_blocks * BLOCK_TOKENS)
        if arm.peer_ssd_blocks:
            self.n_peer_ssd_loads += 1
            self.peer_ssd_bytes += inst.cost.kv_bytes(
                arm.peer_ssd_blocks * BLOCK_TOKENS)

        # queue the prefill work (cache inserts happen at completion in the
        # simulator; here we update the pool optimistically so back-to-back
        # requests in a session see the blocks). For a tiered pool the
        # lookup PROMOTES the loaded SSD blocks into DRAM.
        inst.pool.lookup(req.hash_ids[:arm.prefix_blocks])
        inst.pool.insert(req.hash_ids[arm.prefix_blocks:],
                         start_pos=arm.prefix_blocks)
        inst.queue_free_at = max(inst.queue_free_at, load_done,
                                 now) + arm.compute_time
        inst.total_busy += arm.compute_time
        inst.n_scheduled += 1
        d.pending += 1
        d.pending_tokens += req.input_length + req.output_length
        d.n_scheduled += 1
        return Decision(True, prefill=inst, decode=d, expected_ttft=arm.ttft,
                        expected_tbt=tbt, prefix_blocks=arm.prefix_blocks,
                        migrated_blocks=arm.migrate_blocks,
                        transfer_from=arm.transfer_from.iid
                        if arm.transfer_from else None,
                        ssd_blocks=arm.ssd_blocks,
                        peer_ssd_blocks=arm.peer_ssd_blocks,
                        ssd_load_time=arm.ssd_load_time,
                        compute_time=arm.compute_time, arm_kind=arm.kind)
