"""Wire-codec property tests: the CRC framing must deliver exactly the
bytes that were sent or raise a TYPED error — silent corruption is the
one outcome that must be impossible, at any fragmentation, truncation,
or bit-flip the transport can suffer."""
import random
import socket
import struct
import threading

import numpy as np
import pytest

try:
    from hypothesis import given, settings, strategies as st
except ImportError:
    from _hypothesis_shim import given, settings, strategies as st

from repro.serving.transport import (_FRAME_HDR, BlockServer, FrameConn,
                                     FrameReader, PeerError, PeerUnreachable,
                                     SocketPeer, StaleDirectory, TornFrame,
                                     encode_frame, fallback_reason,
                                     pack_layer, unpack_layer)


def _payload(rng: random.Random, n: int) -> bytes:
    return bytes(rng.randrange(256) for _ in range(n))


# ---------------------------------------------------------------------------
# roundtrip + partial-read reassembly
# ---------------------------------------------------------------------------

@settings(max_examples=60, deadline=None)
@given(st.integers(0, 2**32 - 1), st.integers(0, 3))
def test_roundtrip_any_fragmentation(seed, n_frames_extra):
    """A frame stream fed to FrameReader in arbitrary chunk sizes decodes
    to exactly the frames encoded, in order, regardless of how recv()
    fragmented the bytes."""
    rng = random.Random(seed)
    frames = [(rng.randrange(256), _payload(rng, rng.randrange(0, 200)))
              for _ in range(1 + n_frames_extra)]
    wire = b"".join(encode_frame(t, p) for t, p in frames)
    reader = FrameReader()
    got = []
    i = 0
    while i < len(wire):
        step = rng.randrange(1, 17)
        got += reader.feed(wire[i:i + step])
        i += step
    assert got == frames
    assert reader.pending == 0
    reader.eof()                        # clean close: no partial buffered


@settings(max_examples=40, deadline=None)
@given(st.integers(0, 2**32 - 1))
def test_truncation_never_yields_a_frame(seed):
    """Cutting the stream at ANY byte boundary inside a frame yields no
    frame for it, and eof() raises TornFrame — a mid-frame death can
    never look like a clean close."""
    rng = random.Random(seed)
    payload = _payload(rng, rng.randrange(1, 150))
    wire = encode_frame(3, payload)
    cut = rng.randrange(1, len(wire))   # strictly inside the frame
    reader = FrameReader()
    assert reader.feed(wire[:cut]) == []
    assert reader.pending == cut
    with pytest.raises(TornFrame):
        reader.eof()


@settings(max_examples=60, deadline=None)
@given(st.integers(0, 2**32 - 1))
def test_bitflip_typed_error_never_silent_corruption(seed):
    """Flipping any ONE bit anywhere in a frame — magic, type, length,
    CRC field, or payload — never delivers a frame: either feed() raises
    TornFrame immediately, or the flip changed the length field so the
    parser waits for bytes that never come, and eof() raises TornFrame.
    The CRC covers the header prefix too, so even a mis-typed but
    payload-intact frame counts as corruption."""
    rng = random.Random(seed)
    payload = _payload(rng, rng.randrange(1, 120))
    wire = bytearray(encode_frame(7, payload))
    pos = rng.randrange(len(wire))
    wire[pos] ^= 1 << rng.randrange(8)
    reader = FrameReader()
    try:
        frames = reader.feed(bytes(wire))
    except TornFrame:
        return                          # typed rejection: the contract
    assert frames == [], "silent corruption: a flipped frame decoded!"
    assert reader.pending            # parser is waiting, stream is dead
    with pytest.raises(TornFrame):
        reader.eof()


def test_oversized_length_is_torn():
    hdr = _FRAME_HDR.pack(b"MKW1", 1, 1 << 30, 0)
    with pytest.raises(TornFrame):
        FrameReader().feed(hdr)


def test_bad_magic_is_torn():
    with pytest.raises(TornFrame):
        FrameReader().feed(b"XXXX" + b"\0" * 16)


# ---------------------------------------------------------------------------
# layer payload codec
# ---------------------------------------------------------------------------

@settings(max_examples=30, deadline=None)
@given(st.integers(0, 2**32 - 1))
def test_layer_roundtrip(seed):
    rng = np.random.default_rng(seed)
    shape = (1, int(rng.integers(1, 5)), int(rng.integers(1, 17)))
    k = rng.standard_normal(shape).astype(np.float32)
    v = rng.standard_normal(shape).astype(np.float32)
    meta, k2, v2 = unpack_layer(pack_layer(seed, 3, k, v))
    assert meta["key"] == seed and meta["layer"] == 3
    assert np.array_equal(k, k2) and np.array_equal(v, v2)


def test_layer_meta_mismatch_is_torn():
    k = np.zeros((1, 2, 4), np.float32)
    payload = bytearray(pack_layer(5, 0, k, k))
    # shrink the body by one byte: meta klen now disagrees
    with pytest.raises(TornFrame):
        unpack_layer(bytes(payload[:-1]))
    # garbage meta prefix
    with pytest.raises(TornFrame):
        unpack_layer(struct.pack("<I", 4) + b"nope")


# ---------------------------------------------------------------------------
# FrameConn over a real socketpair
# ---------------------------------------------------------------------------

def test_frameconn_roundtrip_and_taxonomy():
    a, b = socket.socketpair()
    ca, cb = FrameConn(a, timeout=5.0), FrameConn(b, timeout=5.0)
    ca.send(9, b"ping")
    assert cb.recv() == (9, b"ping")
    # close-mid-frame: a partial header then death must raise TornFrame
    b.sendall(encode_frame(2, b"x" * 50)[:10])
    cb.close()
    with pytest.raises(TornFrame):
        ca.recv()
    ca.close()


def test_frameconn_clean_close_is_unreachable():
    a, b = socket.socketpair()
    ca, cb = FrameConn(a, timeout=5.0), FrameConn(b, timeout=5.0)
    cb.close()
    with pytest.raises(PeerUnreachable):
        ca.recv()
    ca.close()


def test_fallback_reason_mapping():
    assert fallback_reason(PeerUnreachable("x")) == "peer_unreachable"
    assert fallback_reason(StaleDirectory("x")) == "stale_directory"
    assert fallback_reason(TornFrame("x")) == "verify_failed"
    assert fallback_reason(PeerError("x")) == "peer_fetch_failed"


# ---------------------------------------------------------------------------
# SocketPeer vs a mangling server: wrong bytes are impossible
# ---------------------------------------------------------------------------

class _ArrayBackend:
    n_layers = 2

    def read_layer(self, key, layer):
        rng = np.random.default_rng(1000 * key + layer)
        a = rng.standard_normal((1, 2, 8)).astype(np.float32)
        return a, a + 1


def test_socket_peer_survives_mangled_frames():
    """A server that corrupts or truncates LAYER frames produces typed
    errors client-side; reconnecting afterwards serves correct bytes."""
    state = dict(mode=None)

    def mangle(frame: bytes):
        if state["mode"] == "flip":
            f = bytearray(frame)
            f[-1] ^= 0xFF
            return bytes(f)
        if state["mode"] == "truncate":
            return frame[:len(frame) // 2]
        return frame

    srv = BlockServer(_ArrayBackend(), mangle=mangle)
    peer = SocketPeer(srv.addr, node=0, timeout=5.0)
    try:
        k, v = peer.read_layer(1, 0)            # clean baseline
        ref = np.random.default_rng(1000).standard_normal(
            (1, 2, 8)).astype(np.float32)
        assert np.array_equal(k, ref)
        state["mode"] = "flip"
        with pytest.raises(TornFrame):
            peer.read_layer(1, 0)
        state["mode"] = "truncate"              # torn at a byte boundary:
        with pytest.raises(TornFrame):          # partial frame + EOF
            peer.read_layer(1, 1)
        state["mode"] = None                    # recovery on reconnect
        k2, _ = peer.read_layer(1, 0)
        assert np.array_equal(k2, ref)
    finally:
        peer.close()
        srv.close()


def test_socket_peer_concurrent_readers_one_server():
    """N client threads fetching disjoint layers through one BlockServer
    each observe exactly their own bytes (per-conn serving, no crosstalk)."""
    srv = BlockServer(_ArrayBackend())
    errs: list = []

    def fetch(key):
        p = SocketPeer(srv.addr, node=0, timeout=10.0)
        try:
            for layer in range(2):
                k, _ = p.read_layer(key, layer)
                ref = np.random.default_rng(
                    1000 * key + layer).standard_normal(
                    (1, 2, 8)).astype(np.float32)
                if not np.array_equal(k, ref):
                    errs.append((key, layer))
        except PeerError as e:
            errs.append((key, repr(e)))
        finally:
            p.close()

    ts = [threading.Thread(target=fetch, args=(i,), name=f"repro-cl-{i}")
          for i in range(4)]
    for t in ts:
        t.start()
    for t in ts:
        t.join()
    srv.close()
    assert not errs, errs
