"""FlowKV-style load-aware transfer routing (Li et al., PAPERS.md).

Algorithm 1 gates the cache-balancing transfer on a prefix-length RATIO
(best/local >= threshold). That heuristic is blind in two regimes:

  * near-complete local prefixes — a node holding 7 of 8 blocks never
    fetches the last one (8/7 < 1.3) even when the transfer is ~free;
  * queue skew — the cache holder keeps winning min-TTFT while its queue
    grows, and the transfer price that would justify spreading the work
    is never even computed.

This policy drops the ratio gate and PRICES the transfer directly: every
instance proposes BOTH its local-recompute arm and the fetch-best-prefix
arm (the Messenger estimate already includes sender-side congestion, so a
jammed holder link makes fetching expensive on its own), and every arm's
selection score carries a queue-imbalance penalty

    score = ttft + alpha * max(queue_time - mean_queue_time, 0)

so hot nodes shed work slightly before raw min-TTFT would move it —
trading a little predicted latency now for a flatter queue distribution
(the FlowKV "load-aware" trade). ``ttft`` itself stays honest: SLO
admission and the simulator see the unpenalised prediction.
"""
from __future__ import annotations

from repro.core.policies.base import Arm, register_policy
from repro.core.policies.routing import (CacheAwareRouting, find_best_prefix,
                                         peer_fetch_arm, recompute_arm)


@register_policy("prefill", "load_aware")
class LoadAwareRouting(CacheAwareRouting):

    alpha = 0.5   # seconds of predicted TTFT paid per second of imbalance

    def propose(self, req, instances, now):
        best_len, best_inst = find_best_prefix(instances, req.hash_ids)
        mean_q = sum(i.queue_time(now) for i in instances) / len(instances)
        arms: list[Arm] = []
        for inst in instances:
            penalty = self.alpha * max(inst.queue_time(now) - mean_q, 0.0)
            prefix_len = inst.pool.prefix_len(req.hash_ids)
            local = recompute_arm(inst, req, now, prefix_len)
            local.score = local.ttft + penalty
            arms.append(local)
            if best_inst is not None and best_inst is not inst \
                    and best_len > prefix_len:
                fetch = peer_fetch_arm(self.ctx, inst, req, now,
                                       best_len, best_inst, prefix_len)
                fetch.score = fetch.ttft + penalty
                arms.append(fetch)
            for ssd in self._ssd_arms(inst, req, now):
                ssd.score = ssd.ttft + penalty
                arms.append(ssd)
            for pa in self._peer_ssd_arms(inst, req, now, instances):
                pa.score = pa.ttft + penalty
                arms.append(pa)
        return arms
