"""Serving loop: interleaved chunked prefill + continuous batching, the
capacity/shutdown bugfixes it depends on, and backpressure admission."""
import threading
import time

import jax
import numpy as np
import pytest

from repro.configs.base import get_config
from repro.models.transformer import init_params
from repro.serving.engine import (DecodeWorker, HostKVPool, PrefillWorker)
from repro.serving.loop import ServingLoop
from repro.serving.paged_cache import DevicePagePool
from repro.serving.request import ServingRequest


def _req(rid, toks, max_new, **kw):
    return ServingRequest(req_id=rid, tokens=toks, max_new=max_new, **kw)


@pytest.fixture(scope="module")
def setup():
    cfg = get_config("smollm-360m").reduced()
    params = init_params(cfg, jax.random.PRNGKey(0))
    return cfg, params


def _mk(cfg, params, *, max_batch=4, max_len=512, n_pages=None,
        n_workers=2, chunk=64):
    n_pages = n_pages or 1 + (max_batch + 2) * (max_len // 64)
    pp = DevicePagePool(cfg, n_pages=n_pages, page_tokens=64)
    pool = HostKVPool()
    pws = [PrefillWorker(params, cfg, pool, prefill_chunk=chunk,
                         page_pool=pp) for _ in range(n_workers)]
    dw = DecodeWorker(params, cfg, max_batch=max_batch, max_len=max_len,
                      substrate="paged", page_pool=pp)
    return pws, dw, pp


def _oracle(cfg, params, reqs, max_new):
    """Request-at-a-time reference streams (fresh engines, one at a time)."""
    pool = HostKVPool()
    pw = PrefillWorker(params, cfg, pool, prefill_chunk=64)
    dw = DecodeWorker(params, cfg, max_batch=1, max_len=512)
    out = {}
    for rid, toks in reqs.items():
        res = pw(toks)
        dw.join(_req(rid, toks, max_new), res)
        seq = [res.first_token]
        while dw.n_active:
            for r, tok, fin in dw.step():
                seq.append(tok)
        out[rid] = seq
    return out


# ---------------------------------------------------------------------------
# bugfix regressions
# ---------------------------------------------------------------------------

def test_join_full_batch_raises_runtime_error(setup):
    """A full decode batch must raise RuntimeError from join — the old
    bare StopIteration (from next() on an exhausted generator expression)
    is swallowed as silent termination inside any driver generator."""
    cfg, params = setup
    pool = HostKVPool()
    pw = PrefillWorker(params, cfg, pool, prefill_chunk=64)
    dw = DecodeWorker(params, cfg, max_batch=1, max_len=512)
    rng = np.random.default_rng(0)
    t1 = rng.integers(0, cfg.vocab_size, 80)
    r1 = pw(t1)
    dw.join(_req(0, t1, 4), r1)
    assert not dw.has_free_slot and dw.free_slots == 0
    t2 = rng.integers(0, cfg.vocab_size, 80)
    r2 = pw(t2)

    with pytest.raises(RuntimeError, match="decode batch full"):
        dw.join(_req(1, t2, 4), r2)

    # the failure mode the bug produced: inside a generator, StopIteration
    # silently ENDS iteration; RuntimeError propagates (PEP 479 makes the
    # raw StopIteration a RuntimeError too, but with a misleading message
    # — the explicit raise is load-bearing for real drivers)
    def driver():
        yield "before"
        dw.join(_req(1, t2, 4), r2)
        yield "after"

    g = driver()
    assert next(g) == "before"
    with pytest.raises(RuntimeError, match="decode batch full"):
        next(g)
    r2.release_pages()


def test_join_overlong_rejects_identically_on_both_substrates(setup):
    """Dense .at[].set past max_len is silently dropped on CPU → wrong
    tokens; the paged branch already rejected. Both substrates must now
    reject an overlong request with the same error."""
    cfg, params = setup
    pool = HostKVPool()
    pw = PrefillWorker(params, cfg, pool, prefill_chunk=64)
    rng = np.random.default_rng(1)
    toks = rng.integers(0, cfg.vocab_size, 100)
    res = pw(toks)

    msgs = {}
    for substrate in ("paged", "dense"):
        dw = DecodeWorker(params, cfg, max_batch=2, max_len=128,
                          substrate=substrate)
        with pytest.raises(ValueError) as ei:
            dw.join(_req(0, toks, 64), res)  # 100 + 64 > 128
        msgs[substrate] = str(ei.value)
        assert dw.n_active == 0              # nothing was admitted
    assert msgs["paged"] == msgs["dense"]
    assert "exceeds max_len" in msgs["paged"]
    res.release_pages()


def test_prefetcher_fetch_after_close_fails_fast(tmp_path):
    """fetch() after close() used to enqueue onto a dead thread and hang
    wait() forever; now the handle fails immediately."""
    from repro.serving.ssd_store import AsyncPrefetcher, SSDBlockStore
    store = SSDBlockStore(str(tmp_path), writeback_batch=1)
    k = np.zeros((2, 8, 1, 4), np.float32)
    store.put(7, k, k)
    store.flush()
    pf = AsyncPrefetcher(store)
    pf.close()
    assert not pf._thread.is_alive()

    h = pf.fetch([7])
    assert h.wait(timeout=1.0)               # pre-fix: hangs forever
    assert 7 in h.failed and h.result(7) is None
    pf.close()                               # idempotent
    store.close()


def test_prefetcher_close_drains_deterministically(tmp_path):
    """close() must join the worker thread (no 2s-timeout leak) even with
    a deep pending queue; in-flight handles complete as failures rather
    than hanging."""
    from repro.serving.ssd_store import AsyncPrefetcher, SSDBlockStore
    store = SSDBlockStore(str(tmp_path), writeback_batch=1)
    k = np.zeros((4, 128, 2, 16), np.float32)
    keys = list(range(40))
    for key in keys:
        store.put(key, k, k)
    store.flush()
    pf = AsyncPrefetcher(store)
    handles = [pf.fetch(keys) for _ in range(4)]   # deep layer-major queue
    pf.close()
    assert not pf._thread.is_alive()               # actually joined
    for h in handles:
        assert h.wait(timeout=5.0)                 # all delivered or failed
    store.close()


# ---------------------------------------------------------------------------
# chunk-resumable prefill
# ---------------------------------------------------------------------------

def test_chunked_prefill_resumable_matches_blocking(setup):
    cfg, params = setup
    pool = HostKVPool()
    pw = PrefillWorker(params, cfg, pool, prefill_chunk=64)
    rng = np.random.default_rng(2)
    toks = rng.integers(0, cfg.vocab_size, 300)

    cp = pw.start(toks)
    n = 0
    while not cp.advance():
        n += 1
    assert cp.done and cp.chunks_done == n + 1
    assert cp.chunks_done == -(-300 // 64)       # ceil: one advance per chunk

    pool2 = HostKVPool()
    pw2 = PrefillWorker(params, cfg, pool2, prefill_chunk=64)
    ref = pw2(toks)
    assert cp.result.first_token == ref.first_token
    np.testing.assert_array_equal(cp.result.kv_k, ref.kv_k)


# ---------------------------------------------------------------------------
# the loop
# ---------------------------------------------------------------------------

def test_loop_mixed_load_bit_exact_with_thread_fed_arrivals(setup):
    """Sustained mixed load: arrivals land WHILE decodes run; every
    emitted stream must equal the request-at-a-time oracle, and shutdown
    must leave the page pool leak-free."""
    cfg, params = setup
    pws, dw, pp = _mk(cfg, params)
    loop = ServingLoop(pws, dw, chunks_per_iter=1, max_queue=16)
    rng = np.random.default_rng(3)
    reqs = {i: rng.integers(0, cfg.vocab_size, int(rng.integers(80, 300)))
            for i in range(6)}

    def feeder():
        for i, t in reqs.items():
            while not loop.submit(_req(i, t, 5)):
                time.sleep(0.01)             # shed → retry (test wants all 6)
            time.sleep(0.005)
        loop.close_intake()

    th = threading.Thread(target=feeder, name="repro-loop-feeder")
    th.start()
    stats = loop.run()
    th.join()

    assert stats["completed"] == 6
    oracle = _oracle(cfg, params, reqs, max_new=5)
    for i in reqs:
        assert loop.outputs[i].done
        assert loop.outputs[i].tokens == oracle[i], f"req {i} diverged"
    pp.check_leaks()                         # clean shutdown, nothing pinned
    assert stats["tbt_n"] > 0 and stats["tbt_p99_s"] >= stats["tbt_p50_s"]


def test_loop_interleaves_prefill_chunks_between_decode_steps(setup):
    """Deterministic mode: while a long prefill is mid-chunks, active
    decode slots must keep emitting — the chunk interleave is visible as
    decode steps strictly interleaved with prefill chunks."""
    cfg, params = setup
    pws, dw, pp = _mk(cfg, params, n_workers=1)
    loop = ServingLoop(pws, dw, chunks_per_iter=1, max_queue=16)
    rng = np.random.default_rng(4)
    short = rng.integers(0, cfg.vocab_size, 80)      # 2 chunks
    long = rng.integers(0, cfg.vocab_size, 448)      # 7 chunks

    assert loop.submit(_req(0, short, 12))
    # let the short request join and start decoding
    while loop.stats()["joined"] == 0:
        loop.iterate()
    steps_before = loop.stats()["decode_steps"]
    assert loop.submit(_req(1, long, 3))
    # drive until the long prefill finishes its chunks
    while loop.stats()["joined"] < 2:
        loop.iterate()
    steps_during = loop.stats()["decode_steps"] - steps_before
    # 7 prefill chunks at 1 chunk/iteration → ≥ 6 decode iterations ran
    # while the long prefill was suspended mid-chunks
    assert steps_during >= 6
    assert len(loop.outputs[0].tokens) > 6   # slot 0 kept emitting
    loop.close_intake()
    loop.run()
    oracle = _oracle(cfg, params, {0: short, 1: long}, max_new=12)
    assert loop.outputs[0].tokens == oracle[0][:12]
    pp.check_leaks()


def test_loop_backpressure_sheds_and_recovers(setup):
    """submit() must shed when the queue saturates (hard cap) and admit
    again once the loop drains; a shed request never consumes compute."""
    cfg, params = setup
    pws, dw, pp = _mk(cfg, params, max_batch=2)
    loop = ServingLoop(pws, dw, chunks_per_iter=1, max_queue=2)
    rng = np.random.default_rng(5)
    toks = [rng.integers(0, cfg.vocab_size, 100) for _ in range(6)]

    accepted = [loop.submit(_req(i, t, 3)) for i, t in enumerate(toks)]
    assert accepted[:2] == [True, True]
    assert not all(accepted), "hard queue cap never triggered"
    n_acc = sum(accepted)
    assert loop.stats()["rejected"] == 6 - n_acc
    chunks_before = loop.stats()["prefill_chunks"]
    assert chunks_before == 0                # rejected ⇒ nothing ran

    # drain, then the loop must admit again
    loop.close_intake()
    loop.run()
    assert loop.stats()["completed"] == n_acc
    pp.check_leaks()


def test_loop_full_batch_defers_joins_until_slots_free(setup):
    """More concurrent requests than decode slots: the loop must hold
    finished prefills in pending-join (no RuntimeError from join) and
    complete everything as slots recycle."""
    cfg, params = setup
    pws, dw, pp = _mk(cfg, params, max_batch=2)
    loop = ServingLoop(pws, dw, chunks_per_iter=2, max_queue=16)
    rng = np.random.default_rng(6)
    reqs = {i: rng.integers(0, cfg.vocab_size, 100) for i in range(5)}
    for i, t in reqs.items():
        assert loop.submit(_req(i, t, 4))
    loop.close_intake()
    stats = loop.run()
    assert stats["completed"] == 5
    oracle = _oracle(cfg, params, reqs, max_new=4)
    for i in reqs:
        assert loop.outputs[i].tokens == oracle[i][:4]
    pp.check_leaks()


def test_loop_tight_pool_defers_joins_instead_of_mid_decode_oom(setup):
    """A join that eats the last free pages OOMs a decode step a few
    iterations later (page growth of active slots can't allocate).
    The loop must hold the join back until headroom covers every active
    slot's worst-case growth — all requests still complete."""
    cfg, params = setup
    # barely two sequences of pages: pending joins pin staged runs while
    # two slots decode
    pws, dw, pp = _mk(cfg, params, max_batch=2, max_len=455, n_pages=15,
                      n_workers=1, chunk=64)
    loop = ServingLoop(pws, dw, chunks_per_iter=1, max_queue=16)
    rng = np.random.default_rng(9)
    reqs = {i: rng.integers(0, cfg.vocab_size, 256 if i % 2 else 384)
            for i in range(6)}
    for i, t in reqs.items():
        assert loop.submit(_req(i, t, 7 if i % 2 else 3))
    loop.close_intake()
    stats = loop.run()                       # pre-fix: MemoryError mid-step
    assert stats["completed"] == 6
    assert stats["join_oom"] > 0             # the guard actually engaged
    pp.check_leaks()


def test_loop_stop_releases_pending_work(setup):
    """stop() mid-flight: queued and mid-prefill work is abandoned, page
    references of never-joined results are dropped (leak check green)."""
    cfg, params = setup
    pws, dw, pp = _mk(cfg, params)
    loop = ServingLoop(pws, dw, chunks_per_iter=1, max_queue=16)
    rng = np.random.default_rng(7)
    for i in range(4):
        loop.submit(_req(i, rng.integers(0, cfg.vocab_size, 200), 8))
    for _ in range(3):                       # partial progress
        loop.iterate()
    loop.stop()
    loop.run()
    assert dw.n_active == 0
    pp.check_leaks()


def test_backpressure_signal_policy_semantics():
    """Engine-side loads mirror §7: baseline is stage-local (blind to
    decode), early sees current occupancy but not in-flight prefills,
    predictive counts them — the information-lag fix."""
    from repro.core.policies.admission import BackpressureSignal
    from repro.core.policies.base import get_policy

    base = get_policy("admission", "baseline")
    early = get_policy("admission", "early")
    pred = get_policy("admission", "predictive")

    # decode saturated + heavy in-flight prefill, but the queue is empty
    sig = BackpressureSignal(queue_depth=0, queue_capacity=8,
                             slots_used=4, slots_total=4,
                             prefills_active=4,
                             pages_pinned=10, pages_total=100)
    assert base.engine_load(sig) == 0.0          # stage-local blindness
    assert early.engine_load(sig) == pytest.approx(4 / 12)
    assert pred.engine_load(sig) == pytest.approx(8 / 12)
    assert base.engine_admit(sig)
    assert not pred.engine_admit(sig, priority=0) or \
        pred.engine_load(sig) <= pred.base_limit
    # priority buys headroom (§10)
    sig2 = BackpressureSignal(queue_depth=8, queue_capacity=8,
                              slots_used=4, slots_total=4)
    assert not early.engine_admit(sig2, priority=0)
    assert early.engine_admit(sig2, priority=1)

    # pinned pages alone must trip the pool-occupancy path
    sig3 = BackpressureSignal(queue_depth=0, queue_capacity=8,
                              slots_used=1, slots_total=4,
                              pages_pinned=95, pages_total=100)
    assert early.engine_load(sig3) == pytest.approx(0.95)
    assert not early.engine_admit(sig3)

    # spilled victims are commitments only the predictive view counts: a
    # slot freed by preemption is NOT free capacity — the victim claims
    # it back at restore
    sig4 = BackpressureSignal(queue_depth=0, queue_capacity=8,
                              slots_used=2, slots_total=4, spilled=4)
    assert early.engine_load(sig4) == pytest.approx(2 / 12)
    assert pred.engine_load(sig4) == pytest.approx(6 / 12)
    assert sig4.committed_frac(include_prefills=True,
                               include_spilled=True) > \
        sig4.committed_frac(include_prefills=True)


def test_page_pool_pressure_distinguishes_pinned_from_evictable(setup):
    cfg, params = setup
    pws, dw, pp = _mk(cfg, params, max_batch=2, max_len=640, n_workers=1)
    rng = np.random.default_rng(8)
    toks = rng.integers(0, cfg.vocab_size, 512)
    res = pws[0](toks)                       # one full block
    p = pp.pressure()
    assert p["capacity"] == pp.n_pages - 1
    assert p["used"] == p["pinned"] + p["evictable"]
    assert p["pinned"] > 0                   # the staged (unjoined) run
    dw.join(_req(0, toks, 2), res)
    while dw.n_active:
        dw.step()
    p2 = pp.pressure()
    # slot done: registered full blocks remain but are registry-only now
    assert p2["pinned"] < p["pinned"]
    assert p2["evictable"] > 0
    assert 0.0 <= p2["pinned_frac"] <= p2["occupancy"] <= 1.0
