"""Shard-invariance suite for the (data, model) mesh-sharded paged
decode path: the mesh must be a pure physical re-layout — page banks
data-parallel over decode slots, KV-head stripes model-parallel — with
every host-side logical op (alloc/refcount/COW/export) and every decoded
token bit-identical to the single-device pool.

Default lane: the mesh-free split oracle, width-bucket planning, and the
API gates. Device lane: ``run_subprocess(devices=4)`` spins up 4 virtual
CPU devices and re-checks the property end-to-end through the engine on
meshes (1,1), (2,1), (1,2) and (2,2).
"""
import dataclasses

import jax
import jax.numpy as jnp
import numpy as np
import pytest

from conftest import run_subprocess
from repro.configs.base import get_config
from repro.launch.mesh import make_decode_mesh, parse_mesh_arg
from repro.models.transformer import init_params, paged_shard_reason
from repro.serving.engine import (DecodeWorker, HostKVPool, PrefillWorker,
                                  bucket_width, plan_width_buckets)
from repro.serving.paged_cache import DevicePagePool
from repro.serving.request import ServingRequest


@pytest.fixture(scope="module")
def setup():
    cfg = get_config("smollm-360m").reduced()
    params = init_params(cfg, jax.random.PRNGKey(0))
    return cfg, params


# ---------------------------------------------------------------------------
# mesh arg / gating


def test_parse_mesh_arg():
    assert parse_mesh_arg("2x2") == (2, 2)
    assert parse_mesh_arg("4x1") == (4, 1)
    with pytest.raises(ValueError):
        parse_mesh_arg("2")
    with pytest.raises(ValueError):
        parse_mesh_arg("0x2")


def test_shard_reason_gates_padded_heads(setup):
    """The reduced smollm arch pads query heads (16 query / 5 effective
    over 2 kv heads) — its explicit qh2kv map cannot head-stripe, so
    model-parallel sharding must be refused with a reason; grouped GQA
    (16 heads / 4 kv) shards cleanly. Data-only sharding is always open
    to paged archs."""
    cfg, _ = setup
    assert paged_shard_reason(cfg, 2) != ""
    assert paged_shard_reason(cfg, 1, 2) == ""
    grouped = dataclasses.replace(cfg, n_heads=16, n_kv_heads=4)
    assert paged_shard_reason(grouped, 2) == ""
    assert paged_shard_reason(grouped, 2, 2) == ""


def test_worker_mesh_gates(setup):
    """API contract: a meshed worker must reject a pool on a different
    mesh, non-divisible batches, unshardable archs, and width buckets
    (bucketed sub-batches would need per-bucket bank splits)."""
    cfg, params = setup
    mesh = make_decode_mesh(1, 1)
    pp_plain = DevicePagePool(cfg, n_pages=32, page_tokens=64)
    with pytest.raises(ValueError, match="mesh"):
        DecodeWorker(params, cfg, max_batch=2, max_len=256,
                     substrate="paged", page_pool=pp_plain, mesh=mesh)
    pp_mesh = DevicePagePool(cfg, n_pages=32, page_tokens=64, mesh=mesh)
    with pytest.raises(ValueError, match="width_buckets"):
        DecodeWorker(params, cfg, max_batch=2, max_len=256,
                     substrate="paged", page_pool=pp_mesh, mesh=mesh,
                     width_buckets=2)


# ---------------------------------------------------------------------------
# width buckets (satellite: per-slot page-count padding)


def test_plan_width_buckets_single_is_global_pow2():
    """One bucket must reproduce the historical padding exactly: the
    deepest slot's need rounded up to a power of two."""
    assert plan_width_buckets([3, 9, 2], 16) == [16]
    assert plan_width_buckets([1, 1], 16) == [1]
    assert plan_width_buckets([5], 16) == [8]
    assert plan_width_buckets([], 16) == [1]


def test_plan_width_buckets_multi():
    plan = plan_width_buckets([1, 2, 9, 3], 16, max_buckets=3)
    assert plan == [16, 4, 2]
    # shallower-than-plan slots merge upward into the smallest kept width
    assert bucket_width(1, plan, 16) == 2
    assert bucket_width(3, plan, 16) == 4
    assert bucket_width(9, plan, 16) == 16
    # widths are capped at max_pages even when need overflows
    assert plan_width_buckets([30], 16) == [16]
    assert bucket_width(30, [16], 16) == 16
    # more buckets than distinct widths: plan just lists them all
    assert plan_width_buckets([8, 2], 16, max_buckets=3) == [8, 2]


def test_bucketed_decode_bit_exact(setup):
    """width_buckets=2 over a depth-skewed batch must emit exactly the
    single-bucket stream — bucketing only changes padding, never math —
    while actually splitting steps into >1 jitted sub-batches."""
    cfg, params = setup
    rng = np.random.default_rng(11)
    prompts = {0: rng.integers(0, cfg.vocab_size, 600),   # 10 pages
               1: rng.integers(0, cfg.vocab_size, 70),    # 2 pages
               2: rng.integers(0, cfg.vocab_size, 40)}    # 1 page

    def run(width_buckets):
        pp = DevicePagePool(cfg, n_pages=1 + 4 * 16, page_tokens=64)
        pool = HostKVPool()
        pw = PrefillWorker(params, cfg, pool, prefill_chunk=256,
                           page_pool=pp)
        dw = DecodeWorker(params, cfg, max_batch=4, max_len=1024,
                          substrate="paged", page_pool=pp,
                          width_buckets=width_buckets)
        outs = {}
        for rid, toks in prompts.items():
            res = pw(toks)
            dw.join(ServingRequest(req_id=rid, tokens=toks, max_new=5), res)
            outs[rid] = [res.first_token]
        steps = 0
        while dw.n_active:
            steps += 1
            for rid, tok, _ in dw.step():
                outs[rid].append(tok)
        pp.check_leaks()
        return outs, steps, dw.stats()

    base, steps, st1 = run(1)
    got, _, st2 = run(2)
    assert got == base
    assert st1["bucket_substeps"] == 0
    # depth skew (10 vs 1-2 pages) guarantees two widths per step
    assert st2["bucket_substeps"] >= 2 * steps


# ---------------------------------------------------------------------------
# mesh-free split oracle


def test_split_ref_matches_ref_bitwise():
    """The (n_data, n_model) split-and-concat decomposition is bitwise
    the plain oracle — head-local and row-local attention make the shard
    boundaries invisible."""
    from repro.kernels.paged_attention.ref import (paged_attention_ref,
                                                  paged_attention_split_ref)
    rng = np.random.default_rng(3)
    B, H, KV, D, P, page = 4, 8, 4, 16, 9, 8
    q = jnp.asarray(rng.standard_normal((B, H, D)), jnp.float32)
    kp = jnp.asarray(rng.standard_normal((P, page, KV, D)), jnp.float32)
    vp = jnp.asarray(rng.standard_normal((P, page, KV, D)), jnp.float32)
    tbl = jnp.asarray(rng.integers(1, P, (B, 4)), jnp.int32)
    lens = jnp.asarray([30, 17, 8, 25], jnp.int32)
    ref = paged_attention_ref(q, kp, vp, tbl, lens)
    for nd, nm in [(1, 1), (2, 1), (1, 2), (2, 2), (4, 4), (2, 4)]:
        got = paged_attention_split_ref(q, kp, vp, tbl, lens,
                                        n_model=nm, n_data=nd)
        np.testing.assert_array_equal(np.asarray(got), np.asarray(ref)), \
            (nd, nm)


# ---------------------------------------------------------------------------
# device lane: 4 virtual CPU devices


_SUB_PRELUDE = """
import dataclasses
import jax
import numpy as np

from repro.configs.base import get_config
from repro.launch.mesh import make_decode_mesh
from repro.serving.engine import (DecodeWorker, HostKVPool, PrefillWorker,
                                  PrefillResult, stage_run)
from repro.serving.paged_cache import DevicePagePool
from repro.serving.request import ServingRequest
from repro.models.transformer import init_params

assert jax.device_count() == 4, jax.devices()
"""


def test_banked_pool_host_invariants():
    """Pure host-side logical ops on a 2-bank pool: per-bank free lists
    and null pages, per-bank registry/adoption, same-bank COW, per-bank
    OOM, cross-bank export/import, and mesh-wide logical pressure()."""
    run_subprocess(_SUB_PRELUDE + """
cfg = get_config("smollm-360m").reduced()
mesh = make_decode_mesh(2, 2)               # d=2 banks, KV=2 stripes over m=2
pp = DevicePagePool(cfg, n_pages=16, mesh=mesh, page_tokens=64)

# geometry: per-bank budget, global id space, one null page per bank
assert pp.n_banks == 2 and pp.bank_pages == 16 and pp.n_pages == 32
assert pp.bank_of(1) == 0 and pp.bank_of(17) == 1
assert sorted(pp._bank_free[1]) == list(range(17, 32))   # 16 is bank-1 null
assert pp.free is pp._bank_free[0] and pp.runs is pp._bank_runs[0]

# logical capacity excludes every bank's null page; occupancy is mesh-wide
press = pp.pressure()
assert press["capacity"] == 30 and press["free"] == 30

a0 = pp.alloc(3, bank=0)
blk = pp.alloc(8, bank=1)               # one full 512-token block run
assert all(pp.bank_of(p) == 0 for p in a0)
assert all(pp.bank_of(p) == 1 for p in blk)
assert pp.free_pages == 19 and pp.pressure()["pinned"] == 11

# a bank exhausts on its own budget even while the other has room
try:
    pp.alloc(13, bank=0)                # bank 0 has 12 free, bank 1 has 7
    raise SystemExit("bank-0 over-alloc must OOM")
except MemoryError:
    pass
assert pp.free_pages == 19      # failed alloc holds nothing

# registry is per bank: the same chain registers independently
import jax.numpy as jnp
L, KV, Dh = cfg.attention_layers, cfg.n_kv_heads, cfg.head_dim
rng = np.random.default_rng(0)
dt = pp.k_pages.dtype                   # slabs quantise to the pool dtype
k = np.asarray(jnp.asarray(rng.standard_normal((L, 512, KV, Dh)), dt))
v = np.asarray(jnp.asarray(rng.standard_normal((L, 512, KV, Dh)), dt))
pp.write_run(blk, k, v)
pp.register_block(77, blk)              # registry holds its own reference
assert pp.lookup_chain([77], bank=1) == 1
assert pp.lookup_chain([77], bank=0) == 0
assert pp.best_stage_bank([77]) == 1
n, got = pp.adopt_chain([77], bank=1)
assert n == 1 and got == blk
pp.release(got)
n, got = pp.adopt_chain([77], bank=0)
assert n == 0 and got == []

# COW stays inside the owning bank
pp.retain(blk[0:1])
moved = pp.make_writable(blk[0])
assert moved != blk[0] and pp.bank_of(moved) == 1
pp.release([moved])

# export releases the caller's references (the registry keeps the run
# warm); import round-trips the bytes into a chosen bank
ek, ev = pp.export_run(blk, 512)
back = pp.import_run(ek, ev, 512, bank=0)
assert all(pp.bank_of(p) == 0 for p in back)
rk, rv = pp.read_seq(back, 512)
np.testing.assert_array_equal(np.asarray(rk), k)
np.testing.assert_array_equal(np.asarray(rv), v)
pp.release(back)
pp.release(a0)
pp.unregister(77, bank=None)
pp.check_leaks()

# check_leaks catches a page filed into the wrong bank's free list
pp._bank_free[0].append(pp._bank_free[1].pop())
try:
    pp.check_leaks()
    raise SystemExit("cross-bank free page must fail check_leaks")
except AssertionError:
    pass
pp._bank_free[1].append(pp._bank_free[0].pop())
pp.check_leaks()
print("OK")
""", devices=4)


def test_mesh_shard_invariance_bit_exact():
    """End-to-end engine property on meshes (1,1), (2,1), (1,2), (2,2):
    prefill -> bank-aware join (incl. one PrefillResult fanned into two
    slots: shared partial tail, COW on first append; and cross-bank
    stage-copy joins once the preferred bank's slots fill) -> decode.
    Every stream must be bitwise the unmeshed single-device run, and the
    banked pools must come out leak-free."""
    out = run_subprocess(_SUB_PRELUDE + """
cfg = dataclasses.replace(get_config("smollm-360m").reduced(),
                          n_heads=16, n_kv_heads=4)   # grouped GQA: stripes
params = init_params(cfg, jax.random.PRNGKey(0))
rng = np.random.default_rng(7)
common = rng.integers(0, cfg.vocab_size, 512)         # one full shared block
prompts = [np.concatenate([common,
                           rng.integers(0, cfg.vocab_size, 88 + 37 * r)])
           for r in range(3)]

def run(mesh_dm):
    mesh = make_decode_mesh(*mesh_dm) if mesh_dm else None
    pp = DevicePagePool(cfg, n_pages=64, mesh=mesh, page_tokens=64)
    pool = HostKVPool()
    pw = PrefillWorker(params, cfg, pool, prefill_chunk=256, page_pool=pp)
    dw = DecodeWorker(params, cfg, max_batch=4, max_len=1024,
                      substrate="paged", page_pool=pp)
    press = [pw(t) for t in prompts]
    outs = {}
    # multi-join first (n-best fan-out shares the partial tail -> COW),
    # while its bank still has two free slots
    for rid, pres in [(2, press[2]), (3, press[2]),
                      (0, press[0]), (1, press[1])]:
        dw.join(ServingRequest(req_id=rid, tokens=None, max_new=6), pres)
        outs[rid] = [pres.first_token]
    while dw.n_active:
        for rid, tok, _ in dw.step():
            outs[rid].append(tok)
    assert pp.stats()["cow_copies"] >= 1, pp.stats()
    pp.check_leaks()
    return outs, dw.stats()

base, _ = run(None)
assert base[3] == base[2]
for dm in [(1, 1), (2, 1), (1, 2), (2, 2)]:
    got, st = run(dm)
    assert got == base, (dm, got, base)
    print(dm, "match:", got == base, "zero_copy:", st["zero_copy_joins"])
print("OK")
""", devices=4)
    assert out.count("match: True") == 4, out


def test_mesh_preempt_restore_bit_exact():
    """Preemption on a (2,2) mesh: a victim's export leaves the banked
    pool, and BOTH restore arms — reload (stage the spilled bytes) and
    recompute (re-prefill prompt + emitted prefix) — resume the stream
    bitwise against the unmeshed never-preempted oracle."""
    out = run_subprocess(_SUB_PRELUDE + """
cfg = dataclasses.replace(get_config("smollm-360m").reduced(),
                          n_heads=16, n_kv_heads=4)
params = init_params(cfg, jax.random.PRNGKey(0))
rng = np.random.default_rng(9)
toks = rng.integers(0, cfg.vocab_size, 600)
max_new = 8

def mk(mesh):
    pp = DevicePagePool(cfg, n_pages=64, mesh=mesh, page_tokens=64)
    pool = HostKVPool()
    pw = PrefillWorker(params, cfg, pool, prefill_chunk=256, page_pool=pp)
    dw = DecodeWorker(params, cfg, max_batch=2, max_len=1024,
                      substrate="paged", page_pool=pp)
    return pp, pw, dw

# unmeshed never-preempted oracle
pp, pw, dw = mk(None)
res = pw(toks)
dw.join(ServingRequest(req_id=0, tokens=toks, max_new=max_new), res)
oracle = [res.first_token]
while dw.n_active:
    for _, tok, _ in dw.step():
        oracle.append(tok)
pp.check_leaks()

for arm in ("reload", "recompute"):
    pp, pw, dw = mk(make_decode_mesh(2, 2))
    res = pw(toks)
    slot = dw.join(ServingRequest(req_id=0, tokens=toks, max_new=max_new),
                   res)
    emitted = [res.first_token]
    for _ in range(3):
        for _, tok, _ in dw.step():
            emitted.append(tok)
    run = dw.preempt(slot)
    assert dw.n_active == 0
    assert run.n_tokens == len(toks) + len(run.emitted) - 1
    if arm == "reload":
        ids = pw.hasher.hash_ids(np.concatenate(
            [toks, np.asarray(run.emitted[:-1], toks.dtype)]))
        pages = stage_run(pp, ids, run.k, run.v, run.n_tokens)
        assert pages is not None
        banks = {pp.bank_of(p) for p in pages if p}
        assert len(banks) == 1, banks          # a run lives in ONE bank
        pres = PrefillResult(
            first_token=run.emitted[-1], kv_k=run.k, kv_v=run.v,
            prompt_len=run.n_tokens, reused_blocks=0, new_blocks=0,
            hash_ids=ids, pages=pages, page_pool=pp,
            page_gens=pp.gens_of(pages))
    else:
        pres = pw(np.concatenate(
            [toks, np.asarray(run.emitted[:-1], toks.dtype)]))
    dw.join(run.request, pres, resume_emitted=run.emitted)
    while dw.n_active:
        for _, tok, _ in dw.step():
            emitted.append(tok)
    assert emitted == oracle, (arm, emitted, oracle)
    pp.check_leaks()
    print(arm, "match:", emitted == oracle)
print("OK")
""", devices=4)
    assert out.count("match: True") == 2, out
