"""Flash attention for chunked prefill — Pallas TPU kernel.

The serving hot spot of §3 step 2 (incremental prefill): a query chunk of
``Sq`` tokens starting at absolute offset ``q_offset`` attends a full
``Sk``-token K/V (cached prefix + itself). Online-softmax accumulation
over K blocks; GQA resolved in the BlockSpec index map (a q-head's grid
step fetches its kv-head's block — no materialised head expansion).

Tiling: grid (B, H, nq, nk) with the K loop as the innermost sequential
dimension; VMEM scratch (acc, m, l) persists across the nk steps of one
(b, h, iq) tile. Block sizes default to the MXU-native 128×128; the
working set per step is q(BQ·D) + k,v(2·BK·D) + acc(BQ·D fp32) ≈ 160 KiB
at D=128 — comfortably inside the ~16 MiB VMEM budget, leaving room for
double buffering.
"""
from __future__ import annotations

import functools

import jax
import jax.numpy as jnp
from jax.experimental import pallas as pl
from jax.experimental.pallas import tpu as pltpu


def _tpu_params(dimension_semantics):
    try:
        return pltpu.CompilerParams(dimension_semantics=dimension_semantics)
    except (AttributeError, TypeError):   # older/newer API spellings
        return dict(dimension_semantics=dimension_semantics)

DEFAULT_BQ = 128
DEFAULT_BK = 128
NEG_INF = -1e30


def _flash_kernel(q_ref, k_ref, v_ref, o_ref, acc_ref, m_ref, l_ref, *,
                  scale: float, q_offset: int, window: int,
                  bq: int, bk: int, nk: int):
    iq = pl.program_id(2)
    ik = pl.program_id(3)

    @pl.when(ik == 0)
    def _init():
        acc_ref[...] = jnp.zeros_like(acc_ref)
        m_ref[...] = jnp.full_like(m_ref, NEG_INF)
        l_ref[...] = jnp.zeros_like(l_ref)

    q = q_ref[0, :, 0, :].astype(jnp.float32)        # (BQ, D)
    k = k_ref[0, :, 0, :].astype(jnp.float32)        # (BK, D)
    v = v_ref[0, :, 0, :].astype(jnp.float32)

    qpos = q_offset + iq * bq + jax.lax.broadcasted_iota(
        jnp.int32, (bq, bk), 0)
    kpos = ik * bk + jax.lax.broadcasted_iota(jnp.int32, (bq, bk), 1)
    mask = qpos >= kpos
    if window:
        mask &= (qpos - kpos) < window

    s = jax.lax.dot_general(q, k, (((1,), (1,)), ((), ())),
                            preferred_element_type=jnp.float32) * scale
    s = jnp.where(mask, s, NEG_INF)

    m_prev = m_ref[:, 0]                              # (BQ,)
    m_cur = jnp.maximum(m_prev, s.max(axis=1))
    alpha = jnp.exp(m_prev - m_cur)
    p = jnp.exp(s - m_cur[:, None])
    p = jnp.where(mask, p, 0.0)

    l_ref[:, 0] = l_ref[:, 0] * alpha + p.sum(axis=1)
    acc_ref[...] = acc_ref[...] * alpha[:, None] + jax.lax.dot_general(
        p, v, (((1,), (0,)), ((), ())), preferred_element_type=jnp.float32)
    m_ref[:, 0] = m_cur

    @pl.when(ik == nk - 1)
    def _finalize():
        den = jnp.maximum(l_ref[:, 0], 1e-30)
        o_ref[0, :, 0, :] = (acc_ref[...] / den[:, None]).astype(o_ref.dtype)


@functools.partial(jax.jit, static_argnames=("q_offset", "window", "bq",
                                             "bk", "interpret"))
def flash_prefill(q, k, v, *, q_offset: int = 0, window: int = 0,
                  bq: int = DEFAULT_BQ, bk: int = DEFAULT_BK,
                  interpret: bool = False):
    """q: (B, Sq, H, D); k, v: (B, Sk, KV, D). Sq % bq == Sk % bk == 0."""
    B, Sq, H, D = q.shape
    _, Sk, KV, _ = k.shape
    assert Sq % bq == 0 and Sk % bk == 0, (Sq, bq, Sk, bk)
    group = H // KV
    nq, nk = Sq // bq, Sk // bk
    grid = (B, H, nq, nk)

    kernel = functools.partial(
        _flash_kernel, scale=1.0 / (D ** 0.5), q_offset=q_offset,
        window=window, bq=bq, bk=bk, nk=nk)

    return pl.pallas_call(
        kernel,
        grid=grid,
        in_specs=[
            pl.BlockSpec((1, bq, 1, D), lambda b, h, iq, ik: (b, iq, h, 0)),
            pl.BlockSpec((1, bk, 1, D),
                         lambda b, h, iq, ik, g=group: (b, ik, h // g, 0)),
            pl.BlockSpec((1, bk, 1, D),
                         lambda b, h, iq, ik, g=group: (b, ik, h // g, 0)),
        ],
        out_specs=pl.BlockSpec((1, bq, 1, D),
                               lambda b, h, iq, ik: (b, iq, h, 0)),
        out_shape=jax.ShapeDtypeStruct((B, Sq, H, D), q.dtype),
        scratch_shapes=[
            pltpu.VMEM((bq, D), jnp.float32),   # acc
            pltpu.VMEM((bq, 1), jnp.float32),   # running max m
            pltpu.VMEM((bq, 1), jnp.float32),   # running sum l
        ],
        compiler_params=_tpu_params(
            ("parallel", "parallel", "parallel", "arbitrary")),
        interpret=interpret,
    )(q, k, v)
