"""Sharding rules: parameters are 2D-sharded — tensor-parallel over
'model', FSDP over 'data' — and replicated over 'pod' (DESIGN.md §7: TP
never crosses the pod fabric). Optimizer state follows its parameter.

Rules are by parameter ROLE (pytree path), not shape, so every
architecture kind maps through one table. All dimensions listed are
verified divisible for the 10 assigned configs in tests/test_shardings.py.
"""
from __future__ import annotations

from typing import Any

import jax
from jax.sharding import NamedSharding, PartitionSpec as P

from repro.configs.base import ModelConfig

# role → spec (leading L/stack axes are added automatically)
_RULES: dict[str, P] = {
    # embeddings
    "embed":        P("model", "data"),
    "lm_head":      P("data", "model"),
    "final_ln":     P(),
    "enc_final_ln": P(),
    # attention (flat head*dim last axes)
    "attn.ln":      P(),
    "attn.wq":      P("data", "model"),
    "attn.wk":      P("data", "model"),
    "attn.wv":      P("data", "model"),
    "attn.wo":      P("model", "data"),
    "attn.bq":      P("model"),
    "attn.bk":      P("model"),
    "attn.bv":      P("model"),
    "attn.q_norm":  P(),
    "attn.k_norm":  P(),
    # dense MLP
    "mlp.ln":       P(),
    "mlp.w1":       P("data", "model"),
    "mlp.w2":       P("model", "data"),
    "mlp.w3":       P("data", "model"),
    # MoE, expert-parallel (experts on 'model'; experts lead after stack)
    "moe.ln":       P(),
    "moe.router":   P("data", None),
    "moe.w1":       P("model", "data", None),
    "moe.w2":       P("model", None, "data"),
    "moe.w3":       P("model", "data", None),
    # MoE, tensor-parallel experts (few-expert models: expert FF hidden on
    # 'model' — mixtral's 8 experts < model axis 16)
    "moe_tp.ln":     P(),
    "moe_tp.router": P("data", None),
    "moe_tp.w1":     P(None, "data", "model"),
    "moe_tp.w2":     P(None, "model", "data"),
    "moe_tp.w3":     P(None, "data", "model"),
    # Mamba2
    "mamba.ln":       P(),
    "mamba.in_proj":  P("data", "model"),
    "mamba.conv_w":   P(None, "model"),
    "mamba.dt_bias":  P(),
    "mamba.A_log":    P(),
    "mamba.D":        P(),
    "mamba.norm":     P("model"),
    "mamba.out_proj": P("model", "data"),
}

# how many leading stack axes each top-level group carries
_STACK_DEPTH = {
    "attn": 1, "mlp": 1, "moe": 1, "mamba": 1,
    "enc_attn": 1, "enc_mlp": 1, "cross_attn": 1,
    # jamba period-scan groups: (n_per, inner, ...)
    "ffn_dense": 2, "ffn_moe": 2,
}
_GROUP_ALIAS = {
    "enc_attn": "attn", "enc_mlp": "mlp", "cross_attn": "attn",
    "ffn_dense": "mlp", "ffn_moe": "moe",
}


def _spec_for(path: tuple[str, ...], leaf, cfg: ModelConfig,
              hybrid: bool) -> P:
    top = path[0]
    if top in ("embed", "lm_head", "final_ln", "enc_final_ln"):
        return _RULES[top]
    group = _GROUP_ALIAS.get(top, top)
    stack = _STACK_DEPTH.get(top, 1)
    if hybrid and top == "mamba":
        stack = 2  # (n_per, inner, ...)
    if group == "moe" and cfg.moe is not None and cfg.moe.parallelism == "tp":
        group = "moe_tp"
    rule = _RULES[f"{group}.{path[-1]}"]
    spec = (None,) * stack + tuple(rule)
    # pad/trim to the leaf rank
    spec = spec[:leaf.ndim]
    spec = spec + (None,) * (leaf.ndim - len(spec))
    return P(*spec)


def param_specs(cfg: ModelConfig, params: Any) -> Any:
    """Pytree of PartitionSpec matching ``params`` (init_params output or
    its eval_shape)."""
    hybrid = bool(cfg.attn_every)
    return jax.tree_util.tree_map_with_path(
        lambda kp, leaf: _spec_for(
            tuple(k.key for k in kp), leaf, cfg, hybrid),
        params)


def check_divisibility(cfg: ModelConfig, params, mesh) -> list[str]:
    """Every sharded dim must divide by its mesh axes. Returns violations
    (empty = good) — used by tests and the dry-run preflight."""
    specs = param_specs(cfg, params)
    bad: list[str] = []

    def visit(path, leaf, spec):
        for dim, ax in zip(leaf.shape, tuple(spec) + (None,) * leaf.ndim):
            if ax is None:
                continue
            axes = ax if isinstance(ax, tuple) else (ax,)
            size = 1
            for a in axes:
                size *= mesh.shape[a]
            if dim % size:
                bad.append(f"{jax.tree_util.keystr(path)}: {dim} % {size}")

    jax.tree_util.tree_map_with_path(visit, params, specs)
    return bad


def named(mesh, spec_tree):
    return jax.tree.map(lambda s: NamedSharding(mesh, s), spec_tree,
                        is_leaf=lambda s: isinstance(s, P))
