"""Minimal, vendored stand-in for the `hypothesis` subset this suite uses.

The sandbox has no network, so `pip install hypothesis` is impossible;
every property-test module imports hypothesis with a try/except falling
back to this shim. Real hypothesis is used whenever it is installed —
the shim only has to keep the tests *runnable and meaningful*, not to
shrink counterexamples.

Semantics: `@given(s1, s2, ...)` reruns the test `max_examples` times
(from an adjacent `@settings`, default 100) with arguments drawn from the
strategies using a per-test seeded `random.Random`, so runs are
deterministic. The first two examples pin every strategy to its
min/max boundary to keep the cheap edge cases that hypothesis would have
found. Failures re-raise with the offending arguments attached.
"""
from __future__ import annotations

import functools
import random
import zlib
from types import SimpleNamespace


class SearchStrategy:
    """A strategy is a draw function plus optional boundary examples."""

    def __init__(self, draw, lo=None, hi=None):
        self._draw = draw
        self._lo = lo        # callable(rng) for the minimal example
        self._hi = hi        # callable(rng) for the maximal example

    def draw(self, rng: random.Random, phase: int = 2):
        if phase == 0 and self._lo is not None:
            return self._lo(rng)
        if phase == 1 and self._hi is not None:
            return self._hi(rng)
        return self._draw(rng)

    def map(self, f):
        return SearchStrategy(lambda rng: f(self._draw(rng)),
                              lo=None if self._lo is None
                              else (lambda rng: f(self._lo(rng))),
                              hi=None if self._hi is None
                              else (lambda rng: f(self._hi(rng))))


def integers(min_value: int, max_value: int) -> SearchStrategy:
    return SearchStrategy(lambda rng: rng.randint(min_value, max_value),
                          lo=lambda rng: min_value,
                          hi=lambda rng: max_value)


def floats(min_value: float, max_value: float) -> SearchStrategy:
    """Finite floats in [min_value, max_value] (no NaN/inf, like the
    suite's bounded usage of hypothesis.strategies.floats)."""
    return SearchStrategy(lambda rng: rng.uniform(min_value, max_value),
                          lo=lambda rng: float(min_value),
                          hi=lambda rng: float(max_value))


def booleans() -> SearchStrategy:
    return SearchStrategy(lambda rng: rng.random() < 0.5,
                          lo=lambda rng: False, hi=lambda rng: True)


def just(value) -> SearchStrategy:
    return SearchStrategy(lambda rng: value)


def sampled_from(seq) -> SearchStrategy:
    seq = list(seq)
    return SearchStrategy(lambda rng: rng.choice(seq),
                          lo=lambda rng: seq[0], hi=lambda rng: seq[-1])


def lists(elements: SearchStrategy, min_size: int = 0,
          max_size: int = 10) -> SearchStrategy:
    def draw(rng):
        n = rng.randint(min_size, max_size)
        return [elements.draw(rng) for _ in range(n)]
    return SearchStrategy(
        draw,
        lo=lambda rng: [elements.draw(rng, 0) for _ in range(min_size)],
        hi=lambda rng: [elements.draw(rng) for _ in range(max_size)])


def tuples(*strats: SearchStrategy) -> SearchStrategy:
    return SearchStrategy(
        lambda rng: tuple(s.draw(rng) for s in strats),
        lo=lambda rng: tuple(s.draw(rng, 0) for s in strats),
        hi=lambda rng: tuple(s.draw(rng, 1) for s in strats))


def one_of(*strats: SearchStrategy) -> SearchStrategy:
    return SearchStrategy(lambda rng: rng.choice(strats).draw(rng))


strategies = SimpleNamespace(
    integers=integers, floats=floats, lists=lists, tuples=tuples,
    sampled_from=sampled_from, booleans=booleans, just=just, one_of=one_of,
    SearchStrategy=SearchStrategy)


def settings(max_examples: int = 100, deadline=None, **_ignored):
    """Decorator recording run parameters for @given (order-independent)."""
    def deco(fn):
        fn._shim_settings = {"max_examples": max_examples}
        return fn
    return deco


def given(*strats: SearchStrategy):
    def deco(fn):
        @functools.wraps(fn)
        def wrapper(*args, **kwargs):
            conf = getattr(wrapper, "_shim_settings", None) or \
                getattr(fn, "_shim_settings", {"max_examples": 100})
            # str.__hash__ is salted per process; crc32 keeps the promised
            # determinism across runs
            seed = zlib.crc32(fn.__qualname__.encode())
            rng = random.Random(seed)
            for i in range(conf["max_examples"]):
                phase = i if i < 2 else 2   # 0 = min-boundary, 1 = max
                vals = tuple(s.draw(rng, phase) for s in strats)
                try:
                    fn(*args, *vals, **kwargs)
                except Exception as e:  # noqa: BLE001 — annotate and re-raise
                    raise AssertionError(
                        f"property failed on example {i} "
                        f"(seed {seed}): args={vals!r}") from e
        # pytest introspects __wrapped__ for the signature and would treat
        # the strategy-filled parameters as fixtures — hide the original.
        del wrapper.__wrapped__
        wrapper.hypothesis_shim = True
        return wrapper
    return deco


def assume(condition) -> bool:
    """Degenerate assume: skip the rest of this example via exception-free
    convention is impossible without hypothesis internals, so just return
    the condition for tests to early-return on."""
    return bool(condition)
