"""Global KVCache pool — cross-node SSD peer handoff vs recompute.

Two-node revisit scenario (the Figure-3 pool's reason to exist): long
documents are prefilled on node A and demoted to A's SSD store as its
DRAM churns; the REVISITS arrive at node B, which never saw them. Without
the global pool B recomputes the whole document; with a shared
``GlobalBlockDirectory`` B fetches the prefix off A's SSD (peer SSD read
+ hop) and computes only the fresh suffix.

Two tables:

* ``global_pool_engine`` — MEASURED wall-clock TTFT in the executable
  engine across every fetch path of the pool (DRAM-only reference, full
  recompute, local SSD, peer SSD, peer DRAM). A's store read bandwidth is
  throttled to ``--ssd-ratio`` × the measured per-block compute time so
  the load:compute ratio — and therefore the schedule comparison — is
  machine-independent. Asserts peer-SSD fetch beats recompute on p90 AND
  mean TTFT, and that every mode's emitted token streams are bit-exact
  vs the DRAM-only run.
* ``global_pool_sim`` — the deterministic simulator counterpart (gated by
  ``check_regression``): the same doc-revisit workload on a 2-prefill
  cluster with the directory on vs off. Asserts the global pool wins p90
  TTFT and actually uses the peer-SSD arm.

    PYTHONPATH=src python -m benchmarks.bench_global_pool [--fast|--quick]
"""
from __future__ import annotations

import os
import shutil
import tempfile
import time

import numpy as np

from benchmarks.common import emit
from repro.core.trace import BLOCK_TOKENS


def _percentile(xs: list[float], q: float) -> float:
    return float(np.percentile(np.asarray(xs), q))


# ---------------------------------------------------------------------------
# simulator part (deterministic — the regression-gated table)
# ---------------------------------------------------------------------------

def _sim_rows(fast: bool) -> list[dict]:
    from repro.configs.base import CacheTierSpec, ClusterSpec, get_config
    from repro.core.simulator import MooncakeCluster
    from repro.core.trace import TraceSpec, generate_trace

    cfg = get_config("llama2-70b")
    n = 400 if fast else 1200
    trace = generate_trace(TraceSpec(
        n_requests=n, duration_ms=300_000 if fast else 900_000, seed=7,
        frac_chat=0.25, frac_doc=0.55, frac_oneshot=0.20,
        doc_len_mu=9.6, doc_len_sigma=0.6))
    uniq = len({h for r in trace for h in r.hash_ids})
    dram = max(int(uniq * 0.02), 64)
    base = ClusterSpec(n_prefill=2, n_decode=2, tbt_slo=0.2,
                       cache=CacheTierSpec(dram_blocks=dram,
                                           ssd_blocks=8 * dram))
    rows = []
    for mode in ("off", "global"):
        res = MooncakeCluster.from_spec(
            cfg, base.replace(global_pool=(mode == "global"))).run(trace)
        rows.append(dict(
            mode=mode,
            avg_ttft_s=round(res.avg_ttft(), 3),
            ttft_p90_s=round(res.ttft_p90(), 3),
            completed=len(res.completed()),
            rejected=len(res.rejected()),
            ssd_loads=res.n_ssd_loads,
            peer_ssd_loads=res.n_peer_ssd_loads,
            migrations=res.n_migrations))
    by = {r["mode"]: r for r in rows}
    assert by["global"]["peer_ssd_loads"] > 0, \
        "the scenario must exercise the peer-SSD arm"
    assert by["global"]["ttft_p90_s"] < by["off"]["ttft_p90_s"], \
        f"global pool must win p90 TTFT in the sim " \
        f"({by['global']['ttft_p90_s']} !< {by['off']['ttft_p90_s']})"
    return rows


# ---------------------------------------------------------------------------
# engine part (measured — asserts orderings + bit-exactness in-process)
# ---------------------------------------------------------------------------

def _workload(vocab: int, n_docs: int, blocks_per_doc: int, seed: int = 0):
    rng = np.random.default_rng(seed)
    docs = [rng.integers(0, vocab, blocks_per_doc * BLOCK_TOKENS)
            for _ in range(n_docs)]
    cold = [np.concatenate([d, rng.integers(0, vocab, 64)]) for d in docs]
    revisit = [np.concatenate([d, rng.integers(0, vocab, 64)]) for d in docs]
    # warmup pair for the fetching worker: a cold pass compiles the full
    # prefill, a revisit of the SAME doc compiles the chunked-extend path
    # timed revisits use — so no mode pays jit inside its timers
    wdoc = rng.integers(0, vocab, blocks_per_doc * BLOCK_TOKENS)
    warm = (np.concatenate([wdoc, rng.integers(0, vocab, 64)]),
            np.concatenate([wdoc, rng.integers(0, vocab, 64)]))
    return cold, revisit, warm


def _decode_streams(params, cfg, dw, rid, pres, max_new):
    out = [pres.first_token]
    dw.join(rid, pres, max_new=max_new)
    while dw.n_active:
        for _, tok, _fin in dw.step():
            out.append(tok)
    return out


def _run_mode(mode, params, cfg, cold, revisit, warm, *, read_bw,
              max_new: int = 4):
    """One cold+revisit pass; returns (revisit ttfts, streams, counters).

    ``mode`` selects where cold prefill runs, where revisits run, and
    which pool tier ends up holding the cold KV when the revisits hit:

      dram       — one unbounded pool; revisit = DRAM hit (reference)
      recompute  — cold on A, revisits on an unrelated B (no directory)
      local_ssd  — cold demoted to A's throttled store; revisits on A
      peer_ssd   — cold demoted to A's throttled store; revisits on B,
                   fetched through the shared directory
      peer_dram  — cold stays in A's DRAM; revisits on B, fetched via
                   the directory off A's DRAM
    """
    from repro.core.directory import GlobalBlockDirectory
    from repro.serving.engine import (DecodeWorker, HostKVPool,
                                     PrefillWorker, connect_pools)

    tmp = tempfile.mkdtemp(prefix=f"bench_gp_{mode}_")
    directory = GlobalBlockDirectory() \
        if mode in ("peer_ssd", "peer_dram") else None
    if mode == "dram":
        pool_a = HostKVPool(capacity_blocks=None)
    else:
        a_cap = None if mode == "peer_dram" else 1
        pool_a = HostKVPool(capacity_blocks=a_cap, ssd_capacity_blocks=4096,
                            ssd_dir=os.path.join(tmp, "a"),
                            ssd_read_bw=read_bw, writeback_batch=4,
                            directory=directory, node_id=0)
    pw_a = PrefillWorker(params, cfg, pool_a, prefill_chunk=256)

    if mode in ("dram", "local_ssd"):
        pool_b, pw_b = pool_a, pw_a
    else:
        pool_b = HostKVPool(
            capacity_blocks=None, ssd_capacity_blocks=4096,
            ssd_dir=os.path.join(tmp, "b") if directory is not None else None,
            directory=directory, node_id=1) if directory is not None \
            else HostKVPool(capacity_blocks=None)
        pw_b = PrefillWorker(params, cfg, pool_b, prefill_chunk=256)
    if directory is not None:
        connect_pools([pool_a, pool_b])

    max_len = len(cold[0]) + max_new + 8
    dw = DecodeWorker(params, cfg, max_batch=1, max_len=max_len)

    for toks in cold:
        pw_a(toks)
    if pool_a.store is not None:
        pool_a.store.flush()        # cold KV must be ON DISK, not staged
    if pw_b is not pw_a:
        pw_b(warm[0])               # pay B's jit compiles outside the
        pw_b(warm[1])               # timers: cold prefill + chunked extend

    ttfts, streams = [], []
    for rid, toks in enumerate(revisit):
        t0 = time.monotonic()
        pres = pw_b(toks)
        ttfts.append(time.monotonic() - t0)
        streams.append(_decode_streams(params, cfg, dw, rid, pres, max_new))

    counters = dict(peer_blocks=pool_b.peer_blocks_fetched,
                    peer_failures=pool_b.peer_fetch_failures,
                    reused_blocks=pw_b.stats()["reused_blocks"],
                    ssd_loaded=pw_b.stats().get("ssd_loaded_blocks", 0))
    for p in {id(pool_a): pool_a, id(pool_b): pool_b}.values():
        p.close()
    shutil.rmtree(tmp, ignore_errors=True)
    return ttfts, streams, counters


def _engine_rows(fast: bool, ssd_ratio: float) -> list[dict]:
    import jax

    from repro.configs.base import get_config
    from repro.core.cache import kv_block_bytes
    from repro.models.transformer import init_params
    from repro.serving.engine import HostKVPool, PrefillWorker

    cfg = get_config("smollm-360m").reduced()
    params = init_params(cfg, jax.random.PRNGKey(0))
    n_docs, blocks_per_doc = (4, 3) if fast else (5, 4)
    cold, revisit, warm = _workload(cfg.vocab_size, n_docs, blocks_per_doc)

    # calibrate one block's compute, then throttle A's store so one
    # block's LOAD costs ssd_ratio × that (machine-independent ratio).
    # The first call pays the jit compile; only the WARM second pass
    # prices compute, or the throttle lands ~2× too loose.
    calib_pool = HostKVPool()
    calib = PrefillWorker(params, cfg, calib_pool, prefill_chunk=256)
    calib(cold[0])
    calib._t_block_ema = None
    calib(warm[0])
    t_block = calib._t_block_ema
    block_bytes = kv_block_bytes(cfg)
    read_bw = block_bytes / (ssd_ratio * t_block)
    print(f"[global_pool] {n_docs} docs × {blocks_per_doc} blocks; "
          f"t_compute/block {t_block * 1e3:.0f} ms → "
          f"throttle {read_bw / 1e6:.2f} MB/s (ratio {ssd_ratio})")

    results, rows = {}, []
    for mode in ("dram", "recompute", "local_ssd", "peer_ssd", "peer_dram"):
        ttfts, streams, c = _run_mode(mode, params, cfg, cold, revisit, warm,
                                      read_bw=read_bw)
        results[mode] = (ttfts, streams)
        rows.append(dict(mode=mode,
                         ttft_avg_s=round(float(np.mean(ttfts)), 3),
                         ttft_p50_s=round(_percentile(ttfts, 50), 3),
                         ttft_p90_s=round(_percentile(ttfts, 90), 3),
                         peer_blocks=c["peer_blocks"],
                         peer_failures=c["peer_failures"],
                         reused_blocks=c["reused_blocks"]))

    # ---- acceptance ----------------------------------------------------
    ref_streams = results["dram"][1]
    for mode in ("recompute", "local_ssd", "peer_ssd", "peer_dram"):
        assert results[mode][1] == ref_streams, \
            f"{mode} token streams diverge from DRAM-only (not bit-exact)"
    rec, ps = results["recompute"][0], results["peer_ssd"][0]
    p90_rec, p90_ps = _percentile(rec, 90), _percentile(ps, 90)
    print(f"\nTTFT p90: recompute {p90_rec:.2f}s vs peer-SSD {p90_ps:.2f}s "
          f"({p90_rec / p90_ps:.2f}×)")
    assert p90_ps < p90_rec, \
        f"peer-SSD fetch must beat recompute on TTFT p90 " \
        f"({p90_ps:.3f} !< {p90_rec:.3f})"
    assert float(np.mean(ps)) < float(np.mean(rec)), \
        "peer-SSD fetch must beat recompute on mean TTFT"
    by = {r["mode"]: r for r in rows}
    assert by["peer_ssd"]["peer_blocks"] > 0
    assert by["peer_dram"]["peer_blocks"] > 0
    print("bit-exact: recompute ✓  local_ssd ✓  peer_ssd ✓  peer_dram ✓ "
          "(vs DRAM-only token streams)")
    return rows


def main(fast: bool = False, ssd_ratio: float = 0.2):
    sim = _sim_rows(fast)
    emit("global_pool_sim", sim)
    eng = _engine_rows(fast, ssd_ratio)
    emit("global_pool_engine", eng)
    return sim + eng


if __name__ == "__main__":
    import argparse
    ap = argparse.ArgumentParser()
    ap.add_argument("--fast", "--quick", dest="fast", action="store_true")
    ap.add_argument("--ssd-ratio", type=float, default=0.2,
                    help="per-block SSD load cost as a fraction of measured "
                         "per-block compute (throttles node A's store)")
    a = ap.parse_args()
    main(fast=a.fast, ssd_ratio=a.ssd_ratio)
