"""CLEAN fixture: every acquire releases or transfers on all paths.
Parsed by replint only — never imported."""


def stage_with_finally(pool, kv):
    run = pool.alloc(4)
    try:
        pool.write_run(run, kv)
        return run
    finally:
        pool.release(run)


def stage_with_handlers(pool, hash_ids, kv):
    held = []
    try:
        adopted, pages = pool.adopt_chain(hash_ids)
        held = list(pages)
        run = pool.alloc(4)
        held += run
        pool.write_run(run, kv)
        pages += run
        return pages
    except MemoryError:
        pool.release(held)
        return None
    except BaseException:
        pool.release(held)
        raise


def park_in_table(pool, table, i):
    # single linear path: nothing between the alloc and the ownership
    # transfer can raise
    (pg,) = pool.alloc(1)
    table[i] = pg


def retain_and_return(pool, pages):
    pool.retain(pages)
    count = len(pages)
    return pages, count


def export_transfers_ownership(pool, run, n_tokens):
    # export_run releases the run inside the pool: the host copies it
    # returns own the bytes from here on
    pool.retain(run)
    k, v = pool.export_run(run, n_tokens)
    return k, v


def alloc_then_export(pool, n_tokens):
    run = pool.alloc(4)
    return pool.export_run(run, n_tokens)


def self_calls_are_the_primitives(self_pool):
    class Pool:
        def adopt(self, run):
            # the pool's own implementation: covered dynamically by
            # check_leaks tests, not by this rule
            self.retain(run)
            self.hot = run
    return Pool
