"""Infrastructure: checkpointing, data pipeline, messenger, HLO analysis."""
import jax
import jax.numpy as jnp
import numpy as np
import pytest
try:
    from hypothesis import given, settings, strategies as st
except ImportError:  # sandboxed env: vendored shim (seeded random)
    from _hypothesis_shim import given, settings, strategies as st

from repro.configs.base import get_config
from repro.core.messenger import Messenger
from repro.data.pipeline import BatchSpec, SyntheticLM
from repro.models.transformer import init_params
from repro.training.checkpoint import (latest_checkpoint, load_checkpoint,
                                       save_checkpoint)
from repro.training.optim import make_optimizer


# ------------------------------------------------------------- checkpoint --
def test_checkpoint_round_trip(tmp_path):
    cfg = get_config("smollm-360m").reduced()
    params = init_params(cfg, jax.random.PRNGKey(0))
    init, _ = make_optimizer("adamw")
    opt = init(params)
    save_checkpoint(str(tmp_path), params, opt, 42)
    zero_p = jax.tree.map(jnp.zeros_like, params)
    zero_o = jax.tree.map(jnp.zeros_like, opt)
    p2, o2, step = load_checkpoint(str(tmp_path), zero_p, zero_o)
    assert step == 42
    for a, b in zip(jax.tree.leaves(params), jax.tree.leaves(p2)):
        np.testing.assert_array_equal(np.asarray(a, np.float32),
                                      np.asarray(b, np.float32))


def test_checkpoint_latest_selection(tmp_path):
    cfg = get_config("smollm-360m").reduced()
    params = init_params(cfg, jax.random.PRNGKey(0))
    init, _ = make_optimizer("adamw")
    opt = init(params)
    save_checkpoint(str(tmp_path), params, opt, 10)
    save_checkpoint(str(tmp_path), params, opt, 200)
    assert latest_checkpoint(str(tmp_path)).endswith("ckpt_00000200.npz")


def test_checkpoint_adafactor_state(tmp_path):
    cfg = get_config("smollm-360m").reduced()
    params = init_params(cfg, jax.random.PRNGKey(0))
    init, _ = make_optimizer("adafactor")
    opt = init(params)
    save_checkpoint(str(tmp_path), params, opt, 1)
    out = load_checkpoint(str(tmp_path), params, opt)
    assert out is not None and out[2] == 1


# ------------------------------------------------------------------- data --
def test_pipeline_deterministic():
    spec = BatchSpec(batch=2, seq=64, vocab=1000)
    a = SyntheticLM(spec, seed=3).batch(7)
    b = SyntheticLM(spec, seed=3).batch(7)
    np.testing.assert_array_equal(a["tokens"], b["tokens"])
    c = SyntheticLM(spec, seed=4).batch(7)
    assert not np.array_equal(a["tokens"], c["tokens"])


def test_pipeline_labels_are_shifted_tokens():
    spec = BatchSpec(batch=2, seq=64, vocab=1000)
    b = SyntheticLM(spec, seed=0).batch(0)
    assert b["tokens"].shape == b["labels"].shape == (2, 64)
    assert (b["tokens"] < 1000).all() and (b["tokens"] >= 0).all()


def test_pipeline_has_learnable_structure():
    """Bigram structure: each row's next-token delta concentrates on that
    row's injected shift — far above the uniform 1/V baseline."""
    from collections import Counter
    spec = BatchSpec(batch=8, seq=512, vocab=256)
    b = SyntheticLM(spec, seed=0).batch(0)
    diffs = (b["labels"].astype(int) - b["tokens"].astype(int)) % 256
    for row in diffs:
        top = Counter(row.tolist()).most_common(1)[0][1]
        assert top > 0.15 * len(row)   # uniform would give ~1/256


# -------------------------------------------------------------- messenger --
def test_messenger_fifo_backlog():
    m = Messenger([0], bw=100.0)
    t1 = m.enqueue(0, 1000.0, now=0.0)       # 10s wire time
    assert t1 == pytest.approx(10.0)
    est = m.estimate(0, 500.0, now=2.0)      # 8s backlog + 5s wire
    assert est == pytest.approx(13.0)
    t2 = m.enqueue(0, 500.0, now=2.0)
    assert t2 == pytest.approx(15.0)
    assert m.congestion(0, 2.0) == pytest.approx(13.0)


@given(st.lists(st.tuples(st.floats(0, 100), st.floats(1, 1e6)),
                min_size=1, max_size=20))
@settings(max_examples=40, deadline=None)
def test_messenger_completion_monotone(events):
    """Completions on one link are FIFO-ordered regardless of enqueue times."""
    m = Messenger([0], bw=1e3)
    last = 0.0
    now = 0.0
    for dt, size in events:
        now += dt
        done = m.enqueue(0, size, now)
        assert done >= last - 1e-9
        assert done >= now
        last = done


# ----------------------------------------------------------- hlo analysis --
def test_hlo_analysis_counts_scan_trips():
    from repro.launch.hlo_analysis import analyze
    W = jnp.ones((7, 64, 64), jnp.float32)

    def g(x):
        def body(c, w):
            return c @ w, None
        return jax.lax.scan(body, x, W)[0]

    comp = jax.jit(g).lower(
        jax.ShapeDtypeStruct((64, 64), jnp.float32)).compile()
    r = analyze(comp.as_text())
    expect = 2 * 64 * 64 * 64 * 7
    assert r["flops"] == pytest.approx(expect, rel=0.01)


def test_hlo_analysis_roofline_terms():
    from repro.launch.hlo_analysis import roofline_terms
    r = roofline_terms({"flops": 197e12, "bytes": 819e9,
                        "collective_total": 0.0})
    assert r["t_compute_s"] == pytest.approx(1.0)
    assert r["t_memory_s"] == pytest.approx(1.0)
    assert r["bottleneck"] in ("compute", "memory")
