"""FLAG fixture: guarded-by violations, including the PR-6 post-close
enqueue shape (check-then-act on an unlocked flag). Parsed by replint
only — never imported."""
import threading


class Pool:
    def __init__(self):
        self._lock = threading.Lock()
        self.refs = [0] * 8          #: guarded_by self._lock
        #: guarded_by self._lock
        self.stats = dict(allocs=0)

    def unguarded_read(self):
        return sum(self.refs)                          # finding

    def unguarded_write(self):
        self.stats["allocs"] += 1                      # finding

    def closure_escapes_lock(self):
        with self._lock:
            return lambda: self.refs[0]                # finding: runs later


class Prefetcher:
    def __init__(self):
        self._lock = threading.Lock()
        self._closed = False         #: guarded_by self._lock
        self.queue = []

    def enqueue(self, task):
        # the PR-6 bug shape: the closed check races close() because it
        # reads the flag without the lock (post-close enqueue onto a
        # dead worker -> the handle hangs forever)
        if self._closed:                               # finding
            raise RuntimeError("closed")
        self.queue.append(task)


class BadAnnotation:
    def __init__(self):
        self.items = []              #: guarded_by self._mutex

    def read(self):
        return len(self.items)
