"""FLAG fixture: the PR-6 class-1 bug — StopIteration misuse around
generators. Parsed by replint only — never imported."""


def chunks(tokens, size):
    for i in range(0, len(tokens), size):
        yield tokens[i:i + size]


def join_stream(gen):
    # the PR-6 join bug verbatim: a bare raise inside a helper consumed
    # by the driver's for-loop silently ENDS the loop instead of
    # surfacing the error
    result = gen.send(None)
    if result is None:
        raise StopIteration                            # finding
    return result


def interleave(a, b):
    it = iter(b)
    for x in a:
        yield x
        yield next(it)                                 # finding


def drain(gen):
    while True:
        yield next(gen)                                # finding
