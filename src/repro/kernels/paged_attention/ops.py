"""Public op: paged decode attention (kernel or oracle dispatch)."""
from __future__ import annotations

import jax

from repro.kernels.paged_attention.kernel import (
    paged_attention as _kernel, paged_attention_layers as _kernel_layers)
from repro.kernels.paged_attention.ref import (
    paged_attention_layers_ref as _ref_layers, paged_attention_ref as _ref)


def _kernel_ok(q_heads: int, kv_heads: int, qh2kv, window: int) -> bool:
    """The Pallas grid packs grouped GQA only: divisible heads, no padded
    query-head remap, full attention. Everything else takes the oracle."""
    return qh2kv is None and window == 0 and q_heads % kv_heads == 0


def paged_decode_attention(q, k_pages, v_pages, block_table, seq_lens, *,
                           qh2kv=None, window: int = 0,
                           use_pallas: bool = False,
                           interpret: bool | None = None):
    """q: (B, H, D) over one layer's paged KV → (B, H, D)."""
    if not use_pallas or not _kernel_ok(q.shape[1], k_pages.shape[2],
                                        qh2kv, window):
        return _ref(q, k_pages, v_pages, block_table, seq_lens,
                    qh2kv=qh2kv, window=window)
    if interpret is None:
        interpret = jax.default_backend() == "cpu"
    return _kernel(q, k_pages, v_pages, block_table, seq_lens,
                   interpret=interpret)


def paged_decode_attention_layers(qs, k_pages, v_pages, block_table,
                                  seq_lens, *, qh2kv=None, window: int = 0,
                                  use_pallas: bool = False,
                                  interpret: bool | None = None):
    """Batched-over-layers variant: qs (L, B, H, D) over the stacked
    (L, P, page, KV, D) store → (L, B, H, D). One kernel launch covers
    every layer (microbench / layer-parallel callers)."""
    if not use_pallas or not _kernel_ok(qs.shape[2], k_pages.shape[3],
                                        qh2kv, window):
        return _ref_layers(qs, k_pages, v_pages, block_table, seq_lens,
                           qh2kv=qh2kv, window=window)
    if interpret is None:
        interpret = jax.default_backend() == "cpu"
    return _kernel_layers(qs, k_pages, v_pages, block_table, seq_lens,
                          interpret=interpret)
