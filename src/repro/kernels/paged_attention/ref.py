"""Pure-jnp oracle for paged decode attention."""
from __future__ import annotations

import jax.numpy as jnp


def paged_attention_ref(q, k_pages, v_pages, block_table, seq_lens):
    """One-token GQA attention over paged KV.

    q:          (B, H, D) — the current token's queries
    k_pages:    (P, page, KV, D) one layer's page store
    v_pages:    (P, page, KV, D)
    block_table:(B, max_pages) int32 page ids (0 = null page)
    seq_lens:   (B,) int32 valid tokens per sequence
    Returns (B, H, D) in q.dtype.
    """
    B, H, D = q.shape
    P, page, KV, _ = k_pages.shape
    max_pages = block_table.shape[1]
    group = H // KV

    k = k_pages[block_table]         # (B, max_pages, page, KV, D)
    v = v_pages[block_table]
    S = max_pages * page
    k = k.transpose(0, 3, 1, 2, 4).reshape(B, KV, S, D)
    v = v.transpose(0, 3, 1, 2, 4).reshape(B, KV, S, D)

    qg = q.reshape(B, KV, group, D).astype(jnp.float32)
    logits = jnp.einsum("bkgd,bksd->bkgs", qg,
                        k.astype(jnp.float32)) / (D ** 0.5)
    valid = jnp.arange(S)[None, :] < seq_lens[:, None]       # (B, S)
    logits = jnp.where(valid[:, None, None, :], logits, -jnp.inf)
    m = logits.max(-1, keepdims=True)
    p = jnp.exp(logits - m)
    p = jnp.where(jnp.isfinite(logits), p, 0.0)
    p = p / jnp.maximum(p.sum(-1, keepdims=True), 1e-30)
    out = jnp.einsum("bkgs,bksd->bkgd", p, v.astype(jnp.float32))
    return out.reshape(B, H, D).astype(q.dtype)
