"""Pallas TPU kernels for the serving hot spots (DESIGN.md §4):
flash_prefill (chunked-prefill attention), paged_attention (continuous-
batching decode over block tables), ssd_scan (Mamba2 SSD mixer).
Each ships kernel.py (pl.pallas_call + BlockSpec), ops.py (dispatch) and
ref.py (pure-jnp oracle); validated with interpret=True on CPU."""
from repro.kernels.flash_prefill import flash_prefill_attention
from repro.kernels.paged_attention import paged_decode_attention
from repro.kernels.ssd_scan import ssd_scan_op
