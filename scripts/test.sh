#!/usr/bin/env bash
# Tier-1 fast lane: everything except the slow 256-device dry-run compiles.
# Usage: scripts/test.sh [extra pytest args]
set -euo pipefail
cd "$(dirname "$0")/.."
exec python -m pytest -q -m "not slow" "$@"
