"""Distribution: sharding rules, CPP pipeline, shard_map MoE, dry-run —
multi-device cases run in subprocesses with forced host device counts."""
import jax
import pytest

from repro.configs.base import get_config, list_configs
from repro.launch.shardings import check_divisibility, param_specs
from repro.models.transformer import init_params

from conftest import run_subprocess


class ProdMeshShape:
    shape = {"pod": 2, "data": 16, "model": 16}


@pytest.mark.parametrize("name", list_configs())
def test_sharding_divisibility_production(name):
    cfg = get_config(name)
    shapes = jax.eval_shape(lambda k: init_params(cfg, k),
                            jax.random.PRNGKey(0))
    bad = check_divisibility(cfg, shapes, ProdMeshShape)
    assert not bad, bad[:5]


@pytest.mark.parametrize("name", ["smollm-360m", "qwen3-moe-235b-a22b",
                                  "jamba-1.5-large-398b", "whisper-large-v3"])
def test_param_specs_cover_tree(name):
    cfg = get_config(name)
    shapes = jax.eval_shape(lambda k: init_params(cfg, k),
                            jax.random.PRNGKey(0))
    specs = param_specs(cfg, shapes)
    n_shapes = len(jax.tree.leaves(shapes))
    n_specs = len(jax.tree.leaves(
        specs, is_leaf=lambda s: isinstance(s, jax.sharding.PartitionSpec)))
    assert n_shapes == n_specs


def test_cpp_pipeline_matches_full_prefill():
    """§5.1 CPP over 4 stages ≡ single-device prefill (bit-exact)."""
    run_subprocess("""
import jax, jax.numpy as jnp, numpy as np
from repro.configs.base import get_config
from repro.models.transformer import init_params
from repro.serving.cpp import cpp_prefill, cpp_reference
import dataclasses
cfg = dataclasses.replace(get_config("smollm-360m").reduced(), n_layers=4)
params = init_params(cfg, jax.random.PRNGKey(0))
from repro.launch.mesh import make_stage_mesh
mesh = make_stage_mesh(4)
tokens = jax.random.randint(jax.random.PRNGKey(1), (1, 256), 0, cfg.vocab_size)
lr, (kr, vr) = jax.jit(lambda p, t: cpp_reference(p, t, cfg))(params, tokens)
with mesh:
    lc, (kc, vc) = jax.jit(lambda p, t: cpp_prefill(
        p, t, cfg, mesh, prefill_chunk=64))(params, tokens)
np.testing.assert_allclose(np.asarray(lr), np.asarray(lc), atol=2e-2, rtol=2e-2)
np.testing.assert_allclose(np.asarray(kr, np.float32),
                           np.asarray(kc, np.float32), atol=1e-2, rtol=1e-2)
print("OK")
""", devices=4)


def test_sharded_train_step_matches_single_device():
    """pjit train step on a 2×2 mesh ≡ unsharded step (same loss)."""
    run_subprocess("""
import jax, jax.numpy as jnp, numpy as np
from jax.sharding import NamedSharding, PartitionSpec as P
from repro.configs.base import get_config
from repro.models.layers import Dist, NO_DIST
from repro.models.transformer import init_params, loss_fn
cfg = get_config("smollm-360m").reduced()
params = init_params(cfg, jax.random.PRNGKey(0))
tokens = jax.random.randint(jax.random.PRNGKey(1), (4, 64), 0, cfg.vocab_size)
batch = {"tokens": tokens, "labels": tokens}
l0 = jax.jit(lambda p, b: loss_fn(p, b, cfg, NO_DIST))(params, batch)
from repro.launch.mesh import make_test_mesh
mesh = make_test_mesh(2, 2)
dist = Dist(mesh=mesh, batch_axes=("data",))
with mesh:
    l1 = jax.jit(lambda p, b: loss_fn(p, b, cfg, dist))(params, batch)
np.testing.assert_allclose(float(l0), float(l1), rtol=2e-2)
print("OK", float(l0), float(l1))
""", devices=4)


def test_moe_shard_map_matches_global_dispatch():
    """Expert-parallel shard_map path ≡ the single-device dispatch."""
    run_subprocess("""
import jax, jax.numpy as jnp, numpy as np
import dataclasses
from jax.sharding import Mesh
from repro.configs.base import get_config
from repro.models.layers import Dist, NO_DIST, moe_block, MOE_GLOBAL_DISPATCH_MAX_TOKENS
from repro.models.transformer import init_params
cfg = get_config("qwen3-moe-235b-a22b").reduced()
params = init_params(cfg, jax.random.PRNGKey(0))
p_moe = jax.tree.map(lambda x: x[0], params["moe"])
B, S, D = 2, 4096, cfg.d_model   # B*S > dispatch threshold -> shard_map path
x = jax.random.normal(jax.random.PRNGKey(2), (B, S, D), jnp.bfloat16) * 0.3
y0, aux0 = moe_block(x, p_moe, cfg, NO_DIST)
from repro.launch.mesh import make_test_mesh
mesh = make_test_mesh(2, 2)
dist = Dist(mesh=mesh, batch_axes=("data",))
with mesh:
    y1, aux1 = jax.jit(lambda x_: moe_block(x_, p_moe, cfg, dist))(x)
# capacity factors differ between group sizes; compare where both routed
diff = np.abs(np.asarray(y0, np.float32) - np.asarray(y1, np.float32))
frac_close = (diff < 0.05).mean()
assert frac_close > 0.98, frac_close
print("OK", frac_close)
""", devices=4)


@pytest.mark.slow
def test_dryrun_one_combo_256dev():
    """End-to-end dry-run on the production 16×16 mesh (256 placeholder
    devices): lower + compile + roofline for one arch × shape."""
    out = run_subprocess("""
from repro.launch.dryrun import lower_one
rec = lower_one("smollm-360m", "decode_32k", verbose=False)
assert rec["hlo_analysis"]["flops"] > 0
assert rec["roofline"]["bottleneck"] in ("compute", "memory", "collective")
print("OK", rec["roofline"]["bottleneck"])
""", devices=512, timeout=900)
    assert "OK" in out


@pytest.mark.slow
def test_dryrun_multipod_smoke():
    out = run_subprocess("""
from repro.launch.dryrun import lower_one
rec = lower_one("smollm-360m", "train_4k", multi_pod=True, verbose=False)
assert rec["mesh"] == "2x16x16" and rec["n_devices"] == 512
print("OK")
""", devices=512, timeout=900)
    assert "OK" in out
