"""Always-on serving loop: continuous batching with interleaved chunked
prefill (§3's workflow as ONE iteration instead of phase-at-a-time).

``ServingLoop`` owns one ``DecodeWorker`` (and through it the shared
``DevicePagePool``) plus N ``PrefillWorker``s, and pulls requests from a
thread-fed arrival queue. Each iteration:

    arrivals → joins → one decode step → prefill chunks in the slack

* **Admission** happens at ``submit()`` against a ``BackpressureSignal``
  snapshot (queue depth, slot occupancy, in-flight prefills, pinned page
  fraction, spilled victims) evaluated by a registered admission policy
  kind — the live engine's counterpart of §7's early/predictive
  rejection. A rejected request never consumes compute. The queue-cap
  check and the enqueue are one atomic step under the loop lock, so
  concurrent submitters cannot race past ``max_queue``.
* **Joins** are slot-level and PRIORITY-ORDERED: finished prefills enter
  the decode batch through ``DecodeWorker.join`` highest priority first
  (FIFO within a class); a join that hits device-page OOM is deferred
  and retried once decodes release pages.
* **Preemption** (``preempt=True``, paged substrate): when a pending
  join can not become obtainable by waiting — the headroom guard says
  active slots' reserved growth plus the candidate exceed what is free
  or evictable — and a STRICTLY lower-priority slot is active, the loop
  spills victims (lowest priority first, then shallowest progress) to
  the ``HostKVPool`` via ``DecodeWorker.preempt``/``export_run`` (the
  device→host demotion rung), joins the competing request, and re-joins
  each victim later from its spilled KV: either RELOADED through the
  ``stage_run`` staging path or RECOMPUTED through chunked prefill,
  priced per ``plan_restore``. Restored streams are bit-exact with a
  never-preempted run.
* **Chunked prefill interleave**: prefills advance one device chunk at a
  time (``ChunkedPrefill.advance``) between decode steps. With a
  ``tbt_budget_s`` the loop fits as many chunks as the measured chunk EMA
  says fit in the slack the budget leaves after a decode step (always at
  least one whenever any decode slot would otherwise starve prefill);
  with no budget it runs a fixed ``chunks_per_iter`` — deterministic, the
  mode tests and the gated benchmark use.

Because chunk boundaries are suspension points of the SAME generator the
blocking ``PrefillWorker.__call__`` drains, every emitted token is
bit-exact with the request-at-a-time oracle regardless of how the loop
slices the work.

The public surface speaks ``ServingRequest``/``RequestOutput``
(``repro.serving.request``); the legacy ``submit(req_id, tokens,
max_new, ...)`` keyword form still works behind a ``DeprecationWarning``.
"""
from __future__ import annotations

import queue
import threading
import time
import warnings
from dataclasses import dataclass, field
from typing import Optional

import numpy as np

from repro.core.policies.admission import BackpressureSignal
from repro.core.policies.base import get_policy
from repro.core.trace import BLOCK_TOKENS
from repro.serving.engine import (ChunkedPrefill, DecodeWorker,
                                  PrefillResult, PrefillWorker, plan_restore,
                                  stage_run)
from repro.serving.request import RequestOutput, ServingRequest


@dataclass
class _Active:
    """A request whose prefill is mid-chunks on some worker. ``emitted``
    is set for a recompute-restore replay: the victim's already-emitted
    tokens, to resume from once the re-prefill finishes."""
    request: ServingRequest
    cp: ChunkedPrefill
    worker_idx: int
    emitted: Optional[list] = None


@dataclass
class _Pending:
    """A unit waiting to enter the decode batch.

    * ``kind="join"``: a finished prefill (``pres`` set); ``emitted`` is
      not None when it replays a recompute restore.
    * ``kind="restore"``: a spilled victim; ``pres`` is None until the
      loop prices the restore and (reload arm) stages the spilled bytes.

    ``n_tokens`` is the KV depth the entry joins at; ``seq`` keeps FIFO
    order within a priority class.
    """
    request: ServingRequest
    pres: Optional[PrefillResult]
    n_tokens: int
    seq: int
    kind: str = "join"
    emitted: Optional[list] = None


class ServingLoop:
    """Continuous-batching loop over one decode worker + N prefill workers.

    ``submit()`` is thread-safe (any number of client threads feed the
    arrival queue); ``run()`` is the engine thread. ``tbt_budget_s=None``
    selects the deterministic interleave (exactly ``chunks_per_iter``
    prefill chunks between decode steps).

    ``preempt=False`` restores the defer-only behaviour (joins wait for
    decodes to release pages, never reclaim them) — the benchmark's
    comparison arm. ``restore_mode`` pins the victim-restore arm
    (``"reload"``/``"recompute"``) or prices it per restore (``"auto"``).
    ``spill_pool`` names the ``HostKVPool`` that parks spilled KV
    (default: the first prefill worker's pool).
    """

    def __init__(self, prefill_workers: list[PrefillWorker],
                 decode_worker: DecodeWorker, *,
                 tbt_budget_s: Optional[float] = None,
                 chunks_per_iter: int = 1, max_queue: int = 64,
                 admission: str = "predictive",
                 preempt: bool = True, restore_mode: str = "auto",
                 spill_pool=None) -> None:
        assert prefill_workers, "need at least one PrefillWorker"
        if restore_mode not in ("auto", "reload", "recompute"):
            raise ValueError(f"unknown restore_mode {restore_mode!r}")
        self.pws = list(prefill_workers)
        self.dw = decode_worker
        self.page_pool = decode_worker.page_pool
        self.tbt_budget_s = tbt_budget_s
        self.chunks_per_iter = max(chunks_per_iter, 1)
        self.max_queue = max_queue
        self.policy = get_policy("admission", admission)
        self.preempt = preempt
        self.restore_mode = restore_mode
        self.spill_pool = spill_pool if spill_pool is not None \
            else self.pws[0].pool
        self._arrivals: "queue.Queue[ServingRequest]" = queue.Queue()
        # guards the client-visible flags/counters that submit() threads
        # and the engine thread both touch
        self._lock = threading.Lock()
        self._intake_open = True              #: guarded_by self._lock
        self._stopping = False                #: guarded_by self._lock
        # engine-thread state
        self._active: list[_Active] = []      # prefills mid-chunks
        self._pending_join: list[_Pending] = []
        self._busy: set[int] = set()          # worker idx with a live gen
        self._rr = 0                          # chunk round-robin cursor
        self._seq = 0                         # FIFO tiebreak for pendings
        self._iter = 0                        # engine-local iteration count
        self._t_step_ema: Optional[float] = None
        self._t_reload_ema: Optional[float] = None   # s / spilled block
        self.outputs: dict[int, RequestOutput] = {}
        #: guarded_by self._lock
        self._counters = dict(
            submitted=0, rejected=0, joined=0, completed=0,
            decode_steps=0, prefill_chunks=0, join_oom=0, iterations=0,
            preemptions=0, pages_spilled=0, restores_reload=0,
            restores_recompute=0)
        # keep staged-but-unjoined prefills from eating the decode
        # batch's reserved growth pages (staging retries at join time)
        for pw in self.pws:
            if pw.page_pool is self.page_pool:
                pw.stage_guard = self._stage_headroom_ok

    # ---- client side ---------------------------------------------------
    def signal(self) -> BackpressureSignal:
        """Live occupancy snapshot the admission policy evaluates."""
        pressure = self.page_pool.pressure() if self.page_pool is not None \
            else {}
        return BackpressureSignal(
            queue_depth=self._arrivals.qsize(),
            queue_capacity=self.max_queue,
            slots_used=self.dw.n_active,
            slots_total=self.dw.max_batch,
            prefills_active=len(self._active) + len(self._pending_join),
            pages_pinned=pressure.get("pinned", 0),
            pages_total=pressure.get("capacity", 0),
            spilled=self.spill_pool.spill_depth())

    def submit(self, request, tokens=None, max_new: Optional[int] = None,
               session=None, priority: int = 0) -> bool:
        """Offer a ``ServingRequest``; False = shed by backpressure
        (nothing ran). The legacy ``submit(req_id, tokens, max_new,
        session, priority)`` form still works behind a
        ``DeprecationWarning``. The queue-cap check and the enqueue are
        atomic under the loop lock (concurrent submitters can not race
        past ``max_queue``)."""
        if not isinstance(request, ServingRequest):
            warnings.warn(
                "ServingLoop.submit(req_id, tokens, max_new, ...) is "
                "deprecated; pass a ServingRequest",
                DeprecationWarning, stacklevel=2)
            request = ServingRequest(
                req_id=int(request), tokens=np.asarray(tokens),
                max_new=int(max_new), session=session, priority=priority)
        if request.tokens is None:
            raise ValueError("ServingRequest.tokens is required for submit")
        if not self._intake_is_open():
            raise RuntimeError("serving loop intake is closed")
        with self._lock:
            self._counters["submitted"] += 1
            if self._arrivals.qsize() >= self.max_queue \
                    or not self.policy.engine_admit(self.signal(),
                                                    request.priority):
                self._counters["rejected"] += 1
                return False
            self._arrivals.put(request)
        return True

    def close_intake(self) -> None:
        """No more submits; ``run()`` returns once in-flight work drains."""
        with self._lock:
            self._intake_open = False

    def stop(self) -> None:
        """Abandon queued + mid-prefill work and spilled victims; finish
        active decodes."""
        with self._lock:
            self._stopping = True
            self._intake_open = False

    def _intake_is_open(self) -> bool:
        with self._lock:
            return self._intake_open

    def _stop_requested(self) -> bool:
        with self._lock:
            return self._stopping

    def _bump(self, key: str, n: int = 1) -> None:
        with self._lock:
            self._counters[key] += n

    # ---- engine side ---------------------------------------------------
    @property
    def idle(self) -> bool:
        return (self._arrivals.empty() and not self._active
                and not self._pending_join and self.dw.n_active == 0)

    def run(self) -> dict:
        """Drive iterations until intake is closed and everything drained.
        Returns a final ``stats()`` snapshot."""
        while not (self.idle and not self._intake_is_open()):
            if self._stop_requested():
                self._drop_pending()
                if self.dw.n_active == 0:
                    break
            self._iteration()
        return self.stats()

    def iterate(self) -> None:
        """One loop iteration (arrivals → joins → decode step → prefill
        chunks) — for drivers that interleave ``submit`` calls with the
        engine deterministically (tests, the gated benchmark) instead of
        feeding from a thread."""
        self._iteration()

    def _drop_pending(self) -> None:
        while True:
            try:
                self._arrivals.get_nowait()
            except queue.Empty:
                break
        for act in self._active:
            self._busy.discard(act.worker_idx)
        self._active.clear()
        for pend in self._pending_join:
            if pend.pres is not None:
                pend.pres.release_pages()
            if pend.kind == "restore":
                # abandon the victim's slab entry (its decode never
                # resumes) — no stranded host bytes after stop()
                self.spill_pool.spill_pop(pend.request.req_id,
                                          restored=False)
        self._pending_join.clear()

    def _iteration(self) -> None:
        self._iter += 1
        self._bump("iterations")
        self._drain_arrivals()
        self._try_joins()
        t_step = self._decode_step()
        self._run_chunks(t_step)

    def _drain_arrivals(self) -> None:
        while True:
            try:
                req = self._arrivals.get_nowait()
            except queue.Empty:
                return
            self._start_prefill(req)

    def _start_prefill(self, req: ServingRequest,
                       tokens_override: Optional[np.ndarray] = None,
                       resume_emitted: Optional[list] = None) -> None:
        """Route to the free worker with the deepest pool residency for
        this prompt (Conductor-style cache-aware routing, loop-local);
        every worker busy → round-robin pile-up is fine, generators are
        cheap until advanced. ``tokens_override``/``resume_emitted``
        replay a preempted victim through the recompute-restore arm."""
        toks = req.tokens if tokens_override is None else tokens_override
        idle = [i for i in range(len(self.pws)) if i not in self._busy]
        cand = idle if idle else list(range(len(self.pws)))
        best, best_depth = cand[0], -1
        for i in cand:
            pw = self.pws[i]
            ids = pw.hasher.hash_ids(toks, session=req.session)
            depth = pw.pool.plan_fetch(ids).n_resident
            if depth > best_depth:
                best, best_depth = i, depth
        cp = self.pws[best].start(toks, session=req.session)
        self._active.append(_Active(req, cp, best, emitted=resume_emitted))
        self._busy.add(best)
        if req.req_id not in self.outputs:
            self.outputs[req.req_id] = RequestOutput(
                req_id=req.req_id, priority=req.priority)

    # ---- page headroom + preemption ------------------------------------
    def _obtainable_pages(self) -> int:
        p = self.page_pool.pressure()
        return p["free"] + p["evictable"]

    def _stage_headroom_ok(self, n_pages: int) -> bool:
        """``PrefillWorker.stage_guard``: staging a finished prefill must
        leave the active slots' reserved growth obtainable, or the staged
        pin turns into a mid-decode alloc OOM that no deferral can fix."""
        if self.page_pool is None or self.dw.n_active == 0:
            return True
        return self._obtainable_pages() - n_pages >= \
            self.dw.reserved_growth_pages()

    def _pend_geometry(self, pend: _Pending) -> tuple[int, int, int]:
        """(join depth, tokens still to emit, pages already held)."""
        extra = pend.request.max_new - \
            (len(pend.emitted) if pend.emitted is not None else 0)
        held = len(pend.pres.pages or ()) if pend.pres is not None else 0
        return pend.n_tokens, extra, held

    def _headroom_ok(self, T: int, extra: int, held: int) -> bool:
        """Admitting a request joining at depth ``T`` with ``extra``
        tokens to go must leave every active slot's worst-case growth
        obtainable — a join that eats the last free pages turns into a
        mid-decode alloc OOM a few steps later, which no amount of
        deferring can fix (pinned pages of pending joins never release
        themselves)."""
        pp = self.page_pool
        if pp is None or self.dw.n_active == 0:
            return True
        cand = max(pp.pages_for(T + extra) - held, 0) + 1
        return self._obtainable_pages() >= \
            self.dw.reserved_growth_pages() + cand

    def _pick_victim(self, priority: int) -> Optional[int]:
        """Victim slot for a priority-``priority`` join: strictly lower
        priority only (equal classes defer, they never preempt each
        other — no cycles), lowest class first, shallowest progress
        breaking ties (least work to redo, fewest bytes to move)."""
        best, key = None, None
        for i, s in enumerate(self.dw.slots):
            if s is None or s.request.priority >= priority:
                continue
            k = (s.request.priority, len(s.emitted), i)
            if key is None or k < key:
                best, key = i, k
        return best

    def _spill(self, slot: int) -> None:
        """Preempt one slot: export its run to the spill slab and queue a
        restore entry (same priority it joined with)."""
        run = self.dw.preempt(slot)
        rid = run.request.req_id
        self.spill_pool.spill_put(rid, run.k, run.v, run.n_tokens)
        self._bump("preemptions")
        self._bump("pages_spilled", self.page_pool.pages_for(run.n_tokens))
        out = self.outputs.get(rid)
        if out is not None:
            out.preemptions += 1
        self._seq += 1
        self._pending_join.append(_Pending(
            request=run.request, pres=None, n_tokens=run.n_tokens,
            seq=self._seq, kind="restore", emitted=run.emitted))

    def _can_preempt(self, priority: int) -> bool:
        return (self.preempt and self.dw.substrate == "paged"
                and any(s is not None and s.request.priority < priority
                        for s in self.dw.slots))

    def _preempt_until(self, pend: _Pending) -> bool:
        """Spill victims until ``pend`` has a free slot AND page headroom;
        False once no eligible victim remains (spills stick — the freed
        pages serve whichever join lands first)."""
        while True:
            T, extra, held = self._pend_geometry(pend)
            if self.dw.has_free_slot and self._headroom_ok(T, extra, held):
                return True
            victim = self._pick_victim(pend.request.priority)
            if victim is None:
                return False
            self._spill(victim)

    # ---- restore arms ---------------------------------------------------
    def _combined_tokens(self, pend: _Pending) -> np.ndarray:
        """prompt + already-decoded tokens whose KV exists (all emitted
        but the last — the pending input's KV was never written)."""
        toks = np.asarray(pend.request.tokens)
        tail = pend.emitted[:-1]
        if not tail:
            return toks
        return np.concatenate([toks, np.asarray(tail, dtype=toks.dtype)])

    def _pick_restore_mode(self, pend: _Pending) -> str:
        emas = [pw._t_block_ema for pw in self.pws
                if pw._t_block_ema is not None]
        plan = plan_restore(
            pend.n_tokens,
            reload_s_per_block=self._t_reload_ema,
            recompute_s_per_block=min(emas) if emas else None,
            mode=self.restore_mode)
        return plan.mode

    def _stage_spilled(self, pend: _Pending) -> Optional[PrefillResult]:
        """Reload arm: stage the slab bytes back into device pages through
        the ordinary ``stage_run`` path — full blocks of the combined
        (prompt + decoded) sequence re-register/adopt, the tail stays
        private. None = the pool can't fit the run right now."""
        rid = pend.request.req_id
        k, v, T = self.spill_pool.spill_get(rid)
        hash_ids = self.pws[0].hasher.hash_ids(self._combined_tokens(pend))
        t0 = time.monotonic()
        pages = stage_run(self.page_pool, hash_ids, k, v, T)
        if pages is None:
            return None
        per_block = (time.monotonic() - t0) / max(-(-T // BLOCK_TOKENS), 1)
        self._t_reload_ema = per_block if self._t_reload_ema is None \
            else 0.7 * self._t_reload_ema + 0.3 * per_block
        return PrefillResult(
            first_token=int(pend.emitted[-1]), kv_k=k, kv_v=v,
            prompt_len=T, reused_blocks=0, new_blocks=0,
            hash_ids=hash_ids, pages=pages, page_pool=self.page_pool,
            page_gens=self.page_pool.gens_of(pages))

    def _reroute_recompute(self, pend: _Pending) -> None:
        """Recompute arm: drop the slab bytes and replay prompt + decoded
        tokens through chunked prefill; the finished result comes back as
        an ordinary pending join carrying ``emitted``."""
        rid = pend.request.req_id
        combined = self._combined_tokens(pend)
        self.spill_pool.spill_pop(rid)
        self._bump("restores_recompute")
        self.outputs[rid].restores.append("recompute")
        self._start_prefill(pend.request, tokens_override=combined,
                            resume_emitted=pend.emitted)

    # ---- joins -----------------------------------------------------------
    def _try_joins(self) -> None:
        if not self._pending_join:
            return
        pending = self._pending_join
        # highest priority first, FIFO within a class (stable on seq)
        pending.sort(key=lambda p: (-p.request.priority, p.seq))
        self._pending_join = []     # _spill() appends freshly-preempted
        for pend in pending:        # victims here for the NEXT pass
            if not self._try_admit_one(pend):
                self._pending_join.append(pend)

    def _try_admit_one(self, pend: _Pending) -> bool:
        """Try to put one pending unit into the decode batch. True =
        consumed (joined, or rerouted through a recompute prefill)."""
        dw = self.dw
        req = pend.request
        T, extra, held = self._pend_geometry(pend)
        ok = dw.has_free_slot and self._headroom_ok(T, extra, held)
        if not ok and self._can_preempt(req.priority):
            ok = self._preempt_until(pend)
        if not ok:
            if dw.has_free_slot:
                self._bump("join_oom")
            return False
        if pend.kind == "restore" and pend.pres is None:
            if self._pick_restore_mode(pend) == "recompute":
                self._reroute_recompute(pend)
                return True
            pres = self._stage_spilled(pend)
            if pres is None:
                self._bump("join_oom")
                if dw.n_active == 0:
                    raise RuntimeError(
                        f"request {req.req_id}'s spilled run cannot fit "
                        f"the device page pool even with an empty decode "
                        f"batch")
                return False
            pend.pres = pres
        try:
            dw.join(req, pend.pres, resume_emitted=pend.emitted)
        except MemoryError:
            # device pages exhausted by live slots: wait for decodes
            # to finish and release pages, then retry. With no active
            # decode there is nothing to wait for — fail loudly
            # instead of spinning.
            self._bump("join_oom")
            if dw.n_active == 0:
                raise RuntimeError(
                    f"request {req.req_id} cannot fit the device page "
                    f"pool even with an empty decode batch") from None
            return False
        self._bump("joined")
        out = self.outputs[req.req_id]
        if pend.emitted is None:
            out.tokens.append(pend.pres.first_token)
            out.token_t.append(time.monotonic())
        elif pend.kind == "restore":
            self._bump("restores_reload")
            out.restores.append("reload")
        if pend.kind == "restore":
            self.spill_pool.spill_pop(req.req_id)
        return True

    def _decode_step(self) -> float:
        """One continuous-batching decode iteration; returns its wall
        seconds (0.0 when no slot is active)."""
        if self.dw.n_active == 0:
            return 0.0
        t0 = time.monotonic()
        emitted = self.dw.step()
        dt = time.monotonic() - t0
        self._bump("decode_steps")
        self._t_step_ema = dt if self._t_step_ema is None \
            else 0.7 * self._t_step_ema + 0.3 * dt
        now = time.monotonic()
        for rid, tok, fin in emitted:
            out = self.outputs[rid]
            out.tokens.append(tok)
            out.token_t.append(now)
            if fin:
                out.done = True
                out.completed_iter = self._iter
                self._bump("completed")
        return dt

    def _advance_one(self) -> bool:
        """Advance the round-robin prefill one chunk; True if any ran."""
        if not self._active:
            return False
        self._rr %= len(self._active)
        act = self._active[self._rr]
        done = act.cp.advance()
        self._bump("prefill_chunks")
        if done:
            self._active.pop(self._rr)
            self._busy.discard(act.worker_idx)
            self._seq += 1
            self._pending_join.append(_Pending(
                request=act.request, pres=act.cp.result,
                n_tokens=act.cp.result.prompt_len, seq=self._seq,
                kind="join", emitted=act.emitted))
        else:
            self._rr += 1
        return True

    def _run_chunks(self, t_step: float) -> None:
        """Interleave prefill chunks into the post-step slack.

        Budget mode: the TBT budget leaves ``tbt_budget_s − step_ema``
        seconds of slack per iteration; fit chunks by the workers' chunk
        EMA, guaranteeing ≥ 1 so prefill can't starve. No active decode →
        run chunks until one prefill completes (nothing to delay).
        Deterministic mode: exactly ``chunks_per_iter`` chunks."""
        if not self._active:
            return
        if self.dw.n_active == 0:
            # decode is idle: chunk until a prefill finishes so the next
            # iteration has something to join (TTFT over unused slack)
            while self._active and not self._pending_join:
                self._advance_one()
            return
        if self.tbt_budget_s is None:
            for _ in range(self.chunks_per_iter):
                if not self._advance_one():
                    return
            return
        step_ema = self._t_step_ema if self._t_step_ema is not None else t_step
        slack = self.tbt_budget_s - step_ema
        deadline = time.monotonic() + max(slack, 0.0)
        ran = 0
        while self._active:
            chunk_s = max(pw.est_chunk_s() for pw in self.pws)
            if ran > 0 and time.monotonic() + chunk_s > deadline:
                break
            self._advance_one()
            ran += 1

    # ---- reporting -----------------------------------------------------
    def stats(self) -> dict:
        """Unified snapshot (cross-component ``stats()`` protocol: taken
        under the loop lock, plain dict, stable key names): lifetime
        counters, the spill-slab gauge, and inter-token-gap percentiles
        over every emitted token (the former ``tbt_stats()``, folded in
        under ``tbt_*`` keys)."""
        with self._lock:
            out = dict(self._counters)
        out["spill_depth"] = self.spill_pool.spill_depth()
        gaps: list[float] = []
        for o in list(self.outputs.values()):
            ts = o.token_t
            gaps += [b - a for a, b in zip(ts, ts[1:])]
        if gaps:
            g = np.sort(np.asarray(gaps))
            out.update(tbt_n=len(g), tbt_p50_s=float(np.percentile(g, 50)),
                       tbt_p99_s=float(np.percentile(g, 99)),
                       tbt_max_s=float(g[-1]))
        else:
            out.update(tbt_n=0, tbt_p50_s=0.0, tbt_p99_s=0.0, tbt_max_s=0.0)
        return out
