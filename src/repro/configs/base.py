"""Model configuration system.

Every assigned architecture gets one module in this package exporting
``CONFIG``; the registry below resolves ``--arch <id>`` strings.

Key derived quantities:
  * ``padded_heads`` — query heads padded up to a multiple of the model-axis
    size (16) so attention can be tensor-parallel on the production mesh.
    Extra heads have zero-initialised projections and are sliced off after
    the output projection contraction is complete (they contribute nothing).
  * ``padded_vocab`` — vocab padded to a multiple of 256 so embedding /
    lm-head can shard on the model axis.
"""
from __future__ import annotations

import dataclasses
from dataclasses import dataclass, field
from typing import Optional

MODEL_AXIS = 16  # tensor-parallel degree of the production mesh


def _pad_to(x: int, m: int) -> int:
    return ((x + m - 1) // m) * m


@dataclass(frozen=True)
class CacheTierSpec:
    """Per-serving-instance KVCache storage hierarchy (Mooncake §3:
    "the underutilized CPU, DRAM and SSD resources of the GPU cluster").

    ``ssd_blocks = 0`` disables the SSD tier (flat DRAM pool — the seed
    behaviour); ``None`` capacities mean unbounded. Consumed by
    ``MooncakeCluster``, ``HostKVPool`` and the serving examples.

    ``ssd_dir`` makes the serving engine's SSD tier REAL: ``HostKVPool``
    backs it with a checksummed file store (``serving/ssd_store.py``) in
    that directory and prefetches demoted blocks asynchronously. Metadata
    pools (simulator) ignore it.
    """
    dram_blocks: Optional[int] = 20_000
    ssd_blocks: Optional[int] = 0
    dram_policy: str = "lru"
    ssd_policy: str = "lru"
    writeback_batch: int = 8   # demotions per batched SSD write
    ssd_dir: Optional[str] = None   # file-backed store location (engine)

    @property
    def tiered(self) -> bool:
        return self.ssd_blocks is None or self.ssd_blocks > 0

    def make_pool(self, block_bytes: int = 0):
        """Build the matching metadata pool (flat or tiered)."""
        from repro.core.cache import CachePool
        from repro.core.tiered import TieredCachePool
        if not self.tiered:
            return CachePool(self.dram_blocks, self.dram_policy,
                             block_bytes=block_bytes)
        return TieredCachePool(
            self.dram_blocks, self.ssd_blocks,
            policy=self.dram_policy, ssd_policy=self.ssd_policy,
            block_bytes=block_bytes, writeback_batch=self.writeback_batch)


@dataclass(frozen=True)
class ClusterSpec:
    """A serving scenario as data: pool sizes, SLOs, cache hierarchy and
    the scheduling policies (by registry name) of one ``MooncakeCluster``.

    Benchmarks and examples declare scenarios by constructing/``replace``-ing
    specs instead of threading 15 kwargs; ``MooncakeCluster.from_spec(cfg,
    spec)`` builds the cluster. ``strategy`` / ``admission`` /
    ``decode_policy`` resolve through ``repro.core.policies`` — any
    registered name (including user policies) is valid.

    ``inst_spec`` is a ``repro.core.costmodel.InstanceSpec`` (``None`` =
    default v5e slice); typed loosely to keep configs import-light.
    """
    n_prefill: int = 4
    n_decode: int = 4
    ttft_slo: float = 30.0
    tbt_slo: float = 0.1
    cache: CacheTierSpec = CacheTierSpec()
    strategy: str = "kvcache"
    admission: str = "early"
    decode_policy: str = "min_tbt"
    balancing_threshold: float = 1.3
    layerwise_prefill: bool = True
    #: share one GlobalBlockDirectory across prefill pools (the Figure-3
    #: cluster-wide pool: demoted blocks become peer-SSD-fetchable). Only
    #: meaningful when the cache is tiered; flat pools have no SSD tier.
    global_pool: bool = True
    t_d: float = 10.0              # predictive admission's uniform decode time
    seed: int = 0
    inst_spec: Optional[object] = None

    def replace(self, **kw) -> "ClusterSpec":
        return dataclasses.replace(self, **kw)


@dataclass(frozen=True)
class MoEConfig:
    n_experts: int
    top_k: int
    d_ff_expert: int
    capacity_factor: float = 1.25
    # 'ep': experts sharded over the model axis (requires n_experts % 16 == 0)
    # 'tp': expert hidden dim sharded over the model axis (few-expert models)
    parallelism: str = "ep"
    # apply MoE every k-th layer (1 = all layers); others use dense MLP
    every: int = 1


@dataclass(frozen=True)
class SSMConfig:
    d_state: int = 128
    d_conv: int = 4
    expand: int = 2
    head_dim: int = 64
    n_groups: int = 1
    chunk: int = 256  # SSD chunk length

    def d_inner(self, d_model: int) -> int:
        return self.expand * d_model

    def n_heads(self, d_model: int) -> int:
        return self.d_inner(d_model) // self.head_dim


@dataclass(frozen=True)
class ModelConfig:
    name: str
    kind: str  # dense | moe | ssm | hybrid | vlm | audio
    n_layers: int
    d_model: int
    n_heads: int
    n_kv_heads: int
    d_ff: int
    vocab_size: int
    head_dim: int = 128
    moe: Optional[MoEConfig] = None
    ssm: Optional[SSMConfig] = None
    # --- attention flavour ---
    qk_norm: bool = False
    attn_bias: bool = False
    sliding_window: int = 0  # 0 = full attention
    rope_theta: float = 1e6
    # --- hybrid (jamba) ---
    attn_every: int = 0  # 1 attention layer per `attn_every` layers; rest SSM
    # --- encoder-decoder (whisper) ---
    encoder_layers: int = 0
    cross_attention: bool = False
    # --- modality frontend stub ---
    frontend: str = "none"  # none | patch | audio
    frontend_tokens: int = 0  # patches / audio frames the stub supplies
    # --- misc ---
    norm_eps: float = 1e-6
    tie_embeddings: bool = False
    max_decode_len: int = 0  # architectural cap on decoder length (whisper)
    optimizer: str = "adamw"  # adamw | adafactor (huge archs)
    remat: bool = True
    source: str = ""  # citation for the config numbers

    # ---------------- derived ----------------
    @property
    def padded_heads(self) -> int:
        return _pad_to(self.n_heads, MODEL_AXIS)

    @property
    def padded_vocab(self) -> int:
        return _pad_to(self.vocab_size, 256)

    @property
    def kv_shardable(self) -> bool:
        return self.n_kv_heads % MODEL_AXIS == 0

    @property
    def is_attention_free(self) -> bool:
        return self.kind == "ssm"

    @property
    def attention_layers(self) -> int:
        """Number of layers that carry a KV cache."""
        if self.kind == "ssm":
            return 0
        if self.attn_every:
            return self.n_layers // self.attn_every
        return self.n_layers

    def param_count(self) -> int:
        """Approximate total parameter count (used for roofline MODEL_FLOPS)."""
        d, L = self.d_model, self.n_layers
        n = self.padded_vocab * d * (1 if self.tie_embeddings else 2)
        per_attn = (d * self.padded_heads * self.head_dim) * 2 \
            + (d * self.n_kv_heads * self.head_dim) * 2
        per_mlp = 3 * d * self.d_ff
        per_ssm = 0
        if self.ssm is not None:
            s = self.ssm
            di = s.d_inner(d)
            nh = s.n_heads(d)
            per_ssm = d * (2 * di + 2 * s.n_groups * s.d_state + nh) \
                + di * d + s.d_conv * (di + 2 * s.n_groups * s.d_state) \
                + 3 * nh + di
        # FFN stack (moe-every-k layers use experts, the rest dense MLP);
        # pure-SSM archs have no FFN stack.
        if self.kind == "ssm":
            ffn = 0
        elif self.moe is not None:
            per_moe = 3 * d * self.moe.d_ff_expert * self.moe.n_experts \
                + d * self.moe.n_experts
            n_moe_layers = L // self.moe.every
            ffn = per_moe * n_moe_layers + per_mlp * (L - n_moe_layers)
        else:
            ffn = per_mlp * L
        # mixer stack
        if self.kind == "ssm":
            mixer = per_ssm * L
        elif self.attn_every:
            n_attn = L // self.attn_every
            mixer = per_attn * n_attn + per_ssm * (L - n_attn)
        else:
            mixer = per_attn * L
        n += mixer + ffn
        # encoder (whisper): self-attn + MLP per encoder layer, plus the
        # decoder's cross-attention K/V/Q/O projections.
        if self.encoder_layers:
            n += self.encoder_layers * (per_attn + per_mlp)
            n += L * per_attn  # cross-attention projections
        return n

    def active_param_count(self) -> int:
        """Activated params per token (MoE: top-k experts only)."""
        if self.moe is None:
            return self.param_count()
        full = self.param_count()
        d, L = self.d_model, self.n_layers
        n_moe_layers = L // self.moe.every
        all_expert = 3 * d * self.moe.d_ff_expert * self.moe.n_experts * n_moe_layers
        active_expert = 3 * d * self.moe.d_ff_expert * self.moe.top_k * n_moe_layers
        return full - all_expert + active_expert

    def reduced(self) -> "ModelConfig":
        """Smoke-test variant: ≤2 layers (or one hybrid period), d_model ≤ 512,
        ≤4 experts — runnable on a single CPU device."""
        d = min(self.d_model, 256)
        kw: dict = dict(
            name=self.name + "-reduced",
            n_layers=2 if not self.attn_every else self.attn_every,
            d_model=d,
            n_heads=4,
            n_kv_heads=2 if self.n_kv_heads < self.n_heads else 4,
            head_dim=32,
            d_ff=max(128, d * 2),
            vocab_size=512,
            frontend_tokens=min(self.frontend_tokens, 16) if self.frontend_tokens else 0,
            encoder_layers=2 if self.encoder_layers else 0,
            remat=False,
            optimizer="adamw",
        )
        if self.moe is not None:
            # capacity_factor E/k ⇒ cap == T: drop-free routing, so
            # incremental decode ≡ full prefill exactly (production
            # configs keep 1.25 — capacity drops are real MoE behaviour)
            kw["moe"] = dataclasses.replace(
                self.moe, n_experts=4, top_k=2, d_ff_expert=128,
                capacity_factor=2.0)
        if self.ssm is not None:
            kw["ssm"] = dataclasses.replace(
                self.ssm, d_state=16, head_dim=16, chunk=32)
        if self.sliding_window:
            kw["sliding_window"] = 64
        if self.attn_every:
            kw["attn_every"] = self.attn_every
        return dataclasses.replace(self, **kw)


# ---------------------------------------------------------------------------
_REGISTRY: dict = {}


def register(cfg: ModelConfig) -> ModelConfig:
    _REGISTRY[cfg.name] = cfg
    return cfg


def get_config(name: str) -> ModelConfig:
    _ensure_loaded()
    if name not in _REGISTRY:
        raise KeyError(f"unknown arch {name!r}; known: {sorted(_REGISTRY)}")
    return _REGISTRY[name]


def list_configs() -> list[str]:
    _ensure_loaded()
    return sorted(_REGISTRY)


_LOADED = False


def _ensure_loaded() -> None:
    global _LOADED
    if _LOADED:
        return
    import importlib
    for mod in [
        "qwen3_moe_235b_a22b", "smollm_360m", "qwen2_5_3b", "mixtral_8x7b",
        "phi3_mini_3_8b", "internvl2_26b", "mamba2_2_7b", "whisper_large_v3",
        "jamba_1_5_large_398b", "qwen3_14b", "llama2_70b",
    ]:
        importlib.import_module(f"repro.configs.{mod}")
    _LOADED = True
