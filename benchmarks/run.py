"""Benchmark driver — one reproduction per paper table/figure.

    PYTHONPATH=src python -m benchmarks.run [--fast] [--with-roofline]

Emits each table as CSV to stdout and JSON to benchmarks/results/.
The roofline table (§Roofline) prints from cache if present (it is
produced by ``python -m benchmarks.roofline``, ~40 compile jobs); pass
--with-roofline to (re)compute missing combos inline.
"""
from __future__ import annotations

import argparse
import json
import os
import sys
import time

from benchmarks.common import RESULTS_DIR, emit


def main(argv=None) -> int:
    ap = argparse.ArgumentParser()
    ap.add_argument("--fast", action="store_true",
                    help="reduced trace sizes (CI-speed)")
    ap.add_argument("--with-roofline", action="store_true")
    ap.add_argument("--only", default=None,
                    help="comma-separated bench names")
    args = ap.parse_args(argv)

    from benchmarks import (bench_cache_policy, bench_cpp, bench_e2e,
                            bench_global_pool, bench_kernels,
                            bench_layerwise, bench_overload,
                            bench_paged_decode, bench_policies,
                            bench_preemption,
                            bench_scheduling, bench_serving_loop,
                            bench_ssd_store, bench_stage_model,
                            bench_tiered_cache, bench_transport)
    benches = {
        "cache_policy": bench_cache_policy.main,     # Table 1
        "tiered_cache": bench_tiered_cache.main,     # DRAM+SSD hierarchy
        "ssd_store": bench_ssd_store.main,           # file-backed tier (§5.2)
        "global_pool": bench_global_pool.main,       # cross-node peer handoff
        "transport": bench_transport.main,           # wire protocol (PR 9)
        "paged_decode": bench_paged_decode.main,     # block-table substrate
        "serving_loop": bench_serving_loop.main,     # continuous batching
        "preemption": bench_preemption.main,         # victim spill vs defer
        "stage_model": bench_stage_model.main,       # Figure 2
        "layerwise": bench_layerwise.main,           # Figure 7
        "scheduling": bench_scheduling.main,         # Figure 8
        "policies": bench_policies.main,             # strategy×admission grid
        "e2e": bench_e2e.main,                       # Figures 11/12/13
        "overload": bench_overload.main,             # Table 3 + Fig 9/10
        "cpp": bench_cpp.main,                       # §5.1 CPP vs SP/TP
        "kernels": bench_kernels.main,
    }
    selected = args.only.split(",") if args.only else list(benches)

    t00 = time.time()
    for name in selected:
        t0 = time.time()
        print(f"\n#### bench: {name}", flush=True)
        try:
            benches[name](fast=args.fast)
        except Exception as e:  # noqa: BLE001
            print(f"BENCH FAIL {name}: {e!r}", file=sys.stderr)
            return 1
        print(f"#### {name} done in {time.time() - t0:.1f}s", flush=True)

    # roofline table (from cache, or computed with --with-roofline)
    cache_path = os.path.join(RESULTS_DIR, "roofline.json")
    if args.with_roofline:
        from benchmarks import roofline
        roofline.main([])
    elif os.path.exists(cache_path):
        with open(cache_path) as f:
            emit("roofline", json.load(f))
    else:
        print("\n[roofline] no cache — run `python -m benchmarks.roofline`")
    print(f"\nall benches done in {time.time() - t00:.1f}s")
    return 0


if __name__ == "__main__":
    sys.exit(main())
