"""Serving engine: prefix reuse correctness, continuous batching, pool."""
import jax
import jax.numpy as jnp
import numpy as np
import pytest

from repro.configs.base import get_config
from repro.core.trace import BLOCK_TOKENS
from repro.data.pipeline import realize_request_tokens
from repro.models.transformer import decode_step, init_caches, init_params, prefill
from repro.serving.engine import (DecodeWorker, HostKVPool, PrefillWorker,
                                  prefix_hash_ids)


@pytest.fixture(scope="module")
def setup():
    cfg = get_config("smollm-360m").reduced()
    params = init_params(cfg, jax.random.PRNGKey(0))
    return cfg, params


def test_prefix_hash_chain_semantics():
    rng = np.random.default_rng(0)
    a = rng.integers(0, 1000, 1024)
    b = a.copy()
    b[600] += 1                       # differ in block 1
    ha, hb = prefix_hash_ids(a), prefix_hash_ids(b)
    assert ha[0] == hb[0]
    assert ha[1] != hb[1]
    # chaining: same block content after different prefix → different hash
    c = np.concatenate([rng.integers(0, 1000, 512), a[512:1024]])
    hc = prefix_hash_ids(c)
    assert hc[1] != ha[1]


def test_reuse_path_matches_cold_path(setup):
    cfg, params = setup
    pool = HostKVPool()
    pw = PrefillWorker(params, cfg, pool, prefill_chunk=64)
    rng = np.random.default_rng(1)
    shared = rng.integers(0, cfg.vocab_size, 1024)
    t1 = np.concatenate([shared, rng.integers(0, cfg.vocab_size, 96)])
    t2 = np.concatenate([shared, rng.integers(0, cfg.vocab_size, 64)])
    r1 = pw(t1)
    assert r1.reused_blocks == 0 and r1.new_blocks == 2
    r2 = pw(t2)
    assert r2.reused_blocks == 2

    logits_cold, _ = jax.jit(lambda p, t: prefill(p, t, cfg))(
        params, jnp.asarray(t2[None]))
    assert r2.first_token == int(jnp.argmax(logits_cold[0]))


def test_full_hit_recomputes_tail_for_logits(setup):
    cfg, params = setup
    pool = HostKVPool()
    pw = PrefillWorker(params, cfg, pool, prefill_chunk=64)
    rng = np.random.default_rng(2)
    t = rng.integers(0, cfg.vocab_size, 1024)    # exactly 2 blocks
    pw(t)
    r2 = pw(t)                                    # 100% cached
    logits_cold, _ = jax.jit(lambda p, t_: prefill(p, t_, cfg))(
        params, jnp.asarray(t[None]))
    assert r2.first_token == int(jnp.argmax(logits_cold[0]))


def test_continuous_batching_matches_sequential(setup):
    cfg, params = setup
    pool = HostKVPool()
    pw = PrefillWorker(params, cfg, pool, prefill_chunk=64)
    rng = np.random.default_rng(3)
    reqs = [rng.integers(0, cfg.vocab_size, rng.integers(80, 400))
            for _ in range(3)]
    results = [pw(t) for t in reqs]
    dw = DecodeWorker(params, cfg, max_batch=4, max_len=512)
    for i, r in enumerate(results):
        dw.join(i, r, max_new=6)
    seqs = {i: [r.first_token] for i, r in enumerate(results)}
    for _ in range(8):
        for rid, tok, fin in dw.step():
            seqs[rid].append(tok)

    # oracle for request 1: lone sequential greedy decode
    t = reqs[1]
    logits, caches = jax.jit(lambda p, t_: prefill(p, t_, cfg))(
        params, jnp.asarray(t[None]))
    full = init_caches(cfg, 1, 512)
    S = len(t)
    full = full._replace(kv=full.kv._replace(
        k=full.kv.k.at[:, :, :S].set(caches.kv.k),
        v=full.kv.v.at[:, :, :S].set(caches.kv.v)), length=caches.length)
    tok = int(jnp.argmax(logits[0]))
    ref = [tok]
    step = jax.jit(lambda p, t_, c: decode_step(p, t_, c, cfg))
    for _ in range(5):
        lg, full = step(params, jnp.asarray([[tok]], jnp.int32), full)
        tok = int(jnp.argmax(lg[0, -1]))
        ref.append(tok)
    assert seqs[1][:6] == ref


def test_slot_reuse_after_completion(setup):
    cfg, params = setup
    pool = HostKVPool()
    pw = PrefillWorker(params, cfg, pool, prefill_chunk=64)
    rng = np.random.default_rng(4)
    dw = DecodeWorker(params, cfg, max_batch=1, max_len=512)
    r1 = pw(rng.integers(0, cfg.vocab_size, 100))
    dw.join(0, r1, max_new=3)
    while dw.n_active:
        dw.step()
    r2 = pw(rng.integers(0, cfg.vocab_size, 120))
    slot = dw.join(1, r2, max_new=3)
    assert slot == 0                      # the slot came back
    out = dw.step()
    assert out and out[0][0] == 1


def test_pool_eviction_drops_bytes(setup):
    cfg, params = setup
    pool = HostKVPool(capacity_blocks=2)
    pw = PrefillWorker(params, cfg, pool, prefill_chunk=64)
    rng = np.random.default_rng(5)
    for i in range(4):
        pw(rng.integers(0, cfg.vocab_size, 1024))   # 2 fresh blocks each
    assert pool.n_blocks <= 2
    assert len(pool.meta) == pool.n_blocks


def test_realized_tokens_honor_hash_structure():
    from repro.core.trace import Request
    r1 = Request(0, 0, 1200, 5, hash_ids=[7, 8, 9])
    r2 = Request(1, 0, 1500, 5, hash_ids=[7, 8, 30])
    t1 = realize_request_tokens(r1, 50000)
    t2 = realize_request_tokens(r2, 50000)
    assert np.array_equal(t1[:1024], t2[:1024])     # shared blocks 7,8
    assert not np.array_equal(t1[1024:1200], t2[1024:1200])


def test_state_checkpoint_worker_ssm_reuse():
    """SSM prefix caching = state checkpoints (DESIGN §Arch-applicability):
    the reuse path must produce the cold path's first token, computing only
    the suffix."""
    from repro.serving.engine import StateCheckpointWorker
    cfg = get_config("mamba2-2.7b").reduced()
    params = init_params(cfg, jax.random.PRNGKey(0))
    w = StateCheckpointWorker(params, cfg)
    rng = np.random.default_rng(7)
    shared = rng.integers(0, cfg.vocab_size, 1024)      # 2 checkpoint blocks
    t1 = np.concatenate([shared, rng.integers(0, cfg.vocab_size, 96)])
    t2 = np.concatenate([shared, rng.integers(0, cfg.vocab_size, 64)])

    f1, _ = w(t1)
    computed_before = w.stats()["computed_tokens"]
    f2, _ = w(t2)
    assert w.stats()["restored_tokens"] >= 1024          # deepest checkpoint hit
    assert w.stats()["computed_tokens"] - computed_before == len(t2) - 1024

    # oracle: cold prefill of t2
    from repro.models.transformer import prefill as _pf
    logits, _ = jax.jit(lambda p, t: _pf(p, t, cfg))(
        params, jnp.asarray(t2[None]))
    assert f2 == int(jnp.argmax(logits[0]))


def test_state_checkpoint_eviction_bounds_memory():
    from repro.serving.engine import StateCheckpointWorker
    cfg = get_config("mamba2-2.7b").reduced()
    params = init_params(cfg, jax.random.PRNGKey(0))
    w = StateCheckpointWorker(params, cfg, capacity_checkpoints=3)
    rng = np.random.default_rng(8)
    for _ in range(4):
        w(rng.integers(0, cfg.vocab_size, 1040))        # 2 fresh ckpts each
    assert len(w.data) <= 3
    assert len(w.meta) == len(w.data)
