"""Shared fixtures. NOTE: no global XLA device-count flags here — smoke
tests and benches must see the real single CPU device; multi-device tests
(CPP, shard_map, dry-run) spawn subprocesses with their own XLA_FLAGS."""
import os
import subprocess
import sys

import pytest

REPO = os.path.dirname(os.path.dirname(os.path.abspath(__file__)))
SRC = os.path.join(REPO, "src")


@pytest.fixture(scope="session")
def rng_key():
    import jax
    return jax.random.PRNGKey(0)


def run_subprocess(code: str, devices: int = 0, timeout: int = 600):
    """Run python code in a subprocess (optionally with N fake devices)."""
    env = dict(os.environ)
    env["PYTHONPATH"] = SRC + os.pathsep + env.get("PYTHONPATH", "")
    if devices:
        env["XLA_FLAGS"] = f"--xla_force_host_platform_device_count={devices}"
    res = subprocess.run([sys.executable, "-c", code], env=env,
                         capture_output=True, text=True, timeout=timeout)
    if res.returncode != 0:
        raise AssertionError(
            f"subprocess failed:\nSTDOUT:\n{res.stdout}\nSTDERR:\n{res.stderr}")
    return res.stdout
