"""Built-in prefill routing policies — Algorithm 1 and its Figure-8
baselines, ported onto the Arm/registry API.

* ``kvcache`` — full Algorithm 1 (cache-aware + cache load balancing +
  hot-spot migration), plus the SSD load arm on tiered pools and the
  peer-SSD fetch arm when the cluster runs a ``GlobalBlockDirectory``.
* ``cache_aware`` — §6.1 only: always the local prefix, never migrate
  (the Figure 8 "cache-aware" baseline). SSD arm still applies; peer
  arms never do (they are transfers).
* ``load_balance`` — least-loaded prefill instance, prefix incidental.
* ``random`` — uniform random instance.

The arm constructors here are the shared vocabulary every routing policy
builds from; new policies (``load_aware``, ``why_not_both``) reuse them.
Estimation (``propose``) never mutates; the returned closures carry the
side effects.
"""
from __future__ import annotations

from typing import TYPE_CHECKING, Optional

from repro.core.policies.base import Arm, PolicyContext, register_policy
from repro.core.trace import BLOCK_TOKENS

if TYPE_CHECKING:
    from repro.core.conductor import PrefillInstance
    from repro.core.trace import Request


def find_best_prefix(instances, block_keys):
    """Longest DRAM prefix across the pool and its holder (Alg. 1 l. 4-7)."""
    best_len, best_inst = 0, None
    for inst in instances:
        n = inst.pool.prefix_len(block_keys)
        if n > best_len:
            best_len, best_inst = n, inst
    return best_len, best_inst


def recompute_arm(inst, req, now: float, prefix_len: int = None) -> Arm:
    """Arm 1 — recompute on the instance's local DRAM prefix.

    ``prefix_len`` skips the O(blocks) prefix walk when the caller already
    computed it (policies call it once per instance)."""
    n = inst.pool.prefix_len(req.hash_ids) if prefix_len is None \
        else prefix_len
    t_prefill = inst.cost.prefill_time(req.input_length, n * BLOCK_TOKENS)
    return Arm("recompute", inst, inst.queue_time(now) + t_prefill,
               t_prefill, prefix_blocks=n)


def peer_fetch_arm(ctx: PolicyContext, inst, req, now: float,
                   best_len: int, best_inst,
                   prefix_len: int = None) -> Arm:
    """Arm 2 — cache balancing: replicate the best peer prefix here
    (hot-spot migration, Alg. 1 line 28, happens at commit)."""
    if prefix_len is None:
        prefix_len = inst.pool.prefix_len(req.hash_ids)
    transfer_blocks = best_len - prefix_len
    nbytes = inst.cost.kv_bytes(transfer_blocks * BLOCK_TOKENS)
    t_transfer = ctx.messenger.estimate(best_inst.iid, nbytes, now)
    t_prefill = inst.cost.prefill_time(req.input_length,
                                       best_len * BLOCK_TOKENS)

    def commit(now: float) -> float:
        ctx.messenger.enqueue(best_inst.iid, nbytes, now)
        inst.pool.insert(req.hash_ids[:best_len], start_pos=0)
        return now

    return Arm("peer_fetch", inst,
               t_transfer + inst.queue_time(now) + t_prefill, t_prefill,
               prefix_blocks=best_len, migrate_blocks=transfer_blocks,
               transfer_from=best_inst, commit=commit)


def ssd_load_arm(ctx: PolicyContext, inst, req, now: float) -> Optional[Arm]:
    """Arm 3 — compute-vs-load (Jin et al.): the prefix extends into the
    node's SSD tier; the load is prefetched on the FIFO SSD read channel
    and overlaps the queue wait."""
    tier_prefix = getattr(inst.pool, "tier_prefix", None)
    if tier_prefix is None:
        return None
    tp = tier_prefix(req.hash_ids)
    if tp.ssd == 0:
        return None
    nbytes = inst.cost.kv_bytes(tp.ssd * BLOCK_TOKENS)
    if ctx.messenger.has_ssd_channel(inst.iid):
        t_ssd = ctx.messenger.estimate_ssd(inst.iid, nbytes, now)
    else:
        t_ssd = inst.cost.ssd_load_time(tp.ssd * BLOCK_TOKENS)
    t_prefill = inst.cost.prefill_time(req.input_length,
                                       tp.total * BLOCK_TOKENS)
    arm = Arm("ssd_load", inst, max(inst.queue_time(now), t_ssd) + t_prefill,
              t_prefill, prefix_blocks=tp.total, ssd_blocks=tp.ssd)

    def commit(now: float) -> float:
        if ctx.messenger.has_ssd_channel(inst.iid):
            done = ctx.messenger.enqueue_ssd(inst.iid, nbytes, now)
        else:
            done = now + inst.cost.ssd_load_time(tp.ssd * BLOCK_TOKENS)
        arm.ssd_load_time = done - now
        return done

    arm.commit = commit
    return arm


def peer_ssd_arm(ctx: PolicyContext, inst, req, now: float,
                 instances) -> Optional[Arm]:
    """Arm 4 — the global pool: the chain extends past this instance's
    local residency onto a PEER's SSD (``GlobalBlockDirectory``). The
    fetch is priced as the peer's SSD read + the network hop, prefetched
    like the local SSD arm (it overlaps the queue wait), and the blocks
    REPLICATE here at commit — the peer keeps its copy, exactly like
    hot-spot migration."""
    if ctx.directory is None:
        return None
    tier_prefix = getattr(inst.pool, "tier_prefix", None)
    tp = tier_prefix(req.hash_ids) if tier_prefix is not None else None
    local = tp.total if tp is not None else inst.pool.prefix_len(req.hash_ids)
    k, peer_iid = ctx.directory.best_ssd_extension(
        req.hash_ids, local, exclude={inst.iid})
    if k == 0:
        return None
    peer = next((p for p in instances if p.iid == peer_iid), None)
    if peer is None:
        return None                 # directory names a node we can't route to
    nbytes = inst.cost.kv_bytes(k * BLOCK_TOKENS)
    if ctx.messenger.has_ssd_channel(peer_iid):
        t_fetch = ctx.messenger.estimate_peer_ssd(peer_iid, nbytes, now)
    else:
        t_fetch = inst.cost.peer_ssd_load_time(k * BLOCK_TOKENS)
    # the local prefix's own SSD span still has to load locally
    n_local_ssd = tp.ssd if tp is not None else 0
    t_local = 0.0
    local_bytes = inst.cost.kv_bytes(n_local_ssd * BLOCK_TOKENS)
    if n_local_ssd:
        if ctx.messenger.has_ssd_channel(inst.iid):
            t_local = ctx.messenger.estimate_ssd(inst.iid, local_bytes, now)
        else:
            t_local = inst.cost.ssd_load_time(n_local_ssd * BLOCK_TOKENS)
    prefix = local + k
    t_prefill = inst.cost.prefill_time(req.input_length, prefix * BLOCK_TOKENS)
    arm = Arm("peer_ssd", inst,
              max(inst.queue_time(now), t_fetch, t_local) + t_prefill,
              t_prefill, prefix_blocks=prefix, ssd_blocks=n_local_ssd,
              peer_ssd_blocks=k, transfer_from=peer)

    def commit(now: float) -> float:
        done = ctx.messenger.enqueue_peer_ssd(peer_iid, nbytes, now) \
            if ctx.messenger.has_ssd_channel(peer_iid) \
            else now + inst.cost.peer_ssd_load_time(k * BLOCK_TOKENS)
        if n_local_ssd:
            if ctx.messenger.has_ssd_channel(inst.iid):
                done = max(done, ctx.messenger.enqueue_ssd(
                    inst.iid, local_bytes, now))
            else:
                done = max(done, now + inst.cost.ssd_load_time(
                    n_local_ssd * BLOCK_TOKENS))
        arm.ssd_load_time = done - now
        # replicate the fetched span into the local pool (the Conductor's
        # generic lookup/insert only covers locally-resident prefixes)
        inst.pool.insert(req.hash_ids[local:prefix], start_pos=local)
        return done

    arm.commit = commit
    return arm


# ---------------------------------------------------------------------------


class _RoutingPolicy:
    """Base for routing policies: holds the PolicyContext."""

    def __init__(self, ctx: PolicyContext) -> None:
        self.ctx = ctx


@register_policy("prefill", "random")
class RandomRouting(_RoutingPolicy):
    def propose(self, req, instances, now):
        return [recompute_arm(self.ctx.rng.choice(instances), req, now)]


@register_policy("prefill", "load_balance")
class LoadBalanceRouting(_RoutingPolicy):
    def propose(self, req, instances, now):
        inst = min(instances, key=lambda i: i.queue_free_at)
        return [recompute_arm(inst, req, now)]


@register_policy("prefill", "cache_aware")
class CacheAwareRouting(_RoutingPolicy):
    """§6.1 only: every instance proposes its local arm (plus SSD load on
    tiered pools); no cross-instance transfers ever."""

    def _ssd_arms(self, inst, req, now) -> list[Arm]:
        arm = ssd_load_arm(self.ctx, inst, req, now)
        return [arm] if arm is not None else []

    def _peer_ssd_arms(self, inst, req, now, instances) -> list[Arm]:
        """Global-pool arm (needs ctx.directory); shared by the
        transfer-proposing subclasses — CacheAwareRouting itself stays
        transfer-free per §6.1."""
        arm = peer_ssd_arm(self.ctx, inst, req, now, instances)
        return [arm] if arm is not None else []

    def propose(self, req, instances, now):
        arms = []
        for inst in instances:
            arms.append(recompute_arm(inst, req, now))
            arms.extend(self._ssd_arms(inst, req, now))
        return arms


@register_policy("prefill", "kvcache")
class KVCacheRouting(CacheAwareRouting):
    """Full Algorithm 1: each instance proposes EITHER local recompute or
    fetch-the-best-peer-prefix, gated by the balancing threshold (line 8),
    plus the SSD arm on tiered pools and the peer-SSD arm when a global
    block directory is wired in."""

    def propose(self, req, instances, now):
        block_keys = req.hash_ids
        best_len, best_inst = find_best_prefix(instances, block_keys)
        arms = []
        for inst in instances:
            prefix_len = inst.pool.prefix_len(block_keys)
            ratio = (best_len / prefix_len) if prefix_len else (
                float("inf") if best_len else 1.0)
            if ratio < self.ctx.balancing_threshold or best_inst is None:
                arms.append(recompute_arm(inst, req, now, prefix_len))
            else:
                arms.append(peer_fetch_arm(self.ctx, inst, req, now,
                                           best_len, best_inst, prefix_len))
            arms.extend(self._ssd_arms(inst, req, now))
            arms.extend(self._peer_ssd_arms(inst, req, now, instances))
        return arms
