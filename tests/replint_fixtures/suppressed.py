"""Suppression fixture: every finding here is silenced by an inline or
preceding-line ``replint: ignore`` comment. Parsed by replint only —
never imported."""
import threading


class Pool:
    def __init__(self):
        self._lock = threading.Lock()
        self.refs = [0] * 8          #: guarded_by self._lock

    def racy_snapshot(self):
        # advisory read for a log line; staleness is acceptable
        return sum(self.refs)  # replint: ignore[guarded-by] -- advisory stat

    def racy_pair(self):
        # replint: ignore[guarded-by] -- standalone comment guards next line
        return self.refs[0]


def legacy_join(gen):
    result = gen.send(None)
    if result is None:
        raise StopIteration  # replint: ignore[stop-iteration] -- caller catches it
    return result
