"""Disaggregated KVCache pool (Figure 3) and eviction policies (Table 1).

A *block* is 512 tokens of KVCache identified by a prefix-chained hash id
(the trace's ``hash_ids``). Each prefill instance owns a local pool in CPU
DRAM; the Conductor sees the union of pools for prefix matching and triggers
Messenger transfers / hot-spot replication between them (§6.2).

``CachePool`` tracks block residency + metadata only — the actual KV bytes
live in the serving engine's ``PagedKVCache`` (device) or are modeled by the
simulator (DRAM). This split mirrors the paper: Conductor schedules block
*ids*; Messenger moves bytes.

SSM / hybrid architectures have no append-only KVCache; ``StateCache``
implements the DESIGN.md §Arch-applicability adaptation — constant-size
state checkpoints at block boundaries keyed by the same prefix hashes.
"""
from __future__ import annotations

import heapq
import itertools
from collections import OrderedDict
from dataclasses import dataclass, field
from typing import Iterable, Optional


class EvictionPolicy:
    """Interface: decide which resident block to evict."""
    name = "base"

    def on_insert(self, key: int, meta: "BlockMeta") -> None: ...
    def on_hit(self, key: int, meta: "BlockMeta") -> None: ...
    def on_evict(self, key: int) -> None: ...
    def victim(self) -> Optional[int]:
        raise NotImplementedError


class LRUPolicy(EvictionPolicy):
    name = "lru"

    def __init__(self) -> None:
        self._order: OrderedDict[int, None] = OrderedDict()

    def on_insert(self, key, meta):
        self._order[key] = None
        self._order.move_to_end(key)

    def on_hit(self, key, meta):
        if key in self._order:
            self._order.move_to_end(key)

    def on_evict(self, key):
        self._order.pop(key, None)

    def victim(self):
        return next(iter(self._order), None)


class _HeapPolicy(EvictionPolicy):
    """Lazy-deletion heap keyed by a (score, tiebreak) tuple; smallest first."""

    def __init__(self) -> None:
        self._heap: list[tuple] = []
        self._entry: dict[int, tuple] = {}
        self._counter = itertools.count()

    def _score(self, meta: "BlockMeta") -> tuple:
        raise NotImplementedError

    def _push(self, key: int, meta: "BlockMeta") -> None:
        entry = (*self._score(meta), next(self._counter), key)
        self._entry[key] = entry
        heapq.heappush(self._heap, entry)

    def on_insert(self, key, meta):
        self._push(key, meta)

    def on_hit(self, key, meta):
        if key in self._entry:
            self._push(key, meta)  # old entry becomes stale

    def on_evict(self, key):
        self._entry.pop(key, None)

    def victim(self):
        while self._heap:
            entry = self._heap[0]
            key = entry[-1]
            if self._entry.get(key) is entry:
                return key
            heapq.heappop(self._heap)  # stale
        return None


class LFUPolicy(_HeapPolicy):
    name = "lfu"

    def _score(self, meta):
        return (meta.hits,)


class LengthAwarePolicy(_HeapPolicy):
    """LFU, but among equal frequencies prefer evicting blocks that occur
    *later* in requests (deeper prefix position) — the paper's
    LengthAwareCache."""
    name = "length_aware"

    def _score(self, meta):
        return (meta.hits, -meta.position)


def make_policy(name: str) -> EvictionPolicy:
    policies = {"lru": LRUPolicy, "lfu": LFUPolicy,
                "length_aware": LengthAwarePolicy}
    try:
        return policies[name]()
    except KeyError:
        raise ValueError(f"unknown eviction policy {name!r}; "
                         f"registered: {sorted(policies)}") from None


# ---------------------------------------------------------------------------


@dataclass
class BlockMeta:
    key: int
    position: int = 0        # block index within its request (depth)
    hits: int = 0
    pinned: int = 0          # in-flight transfers / active prefills
    size_bytes: int = 0


class CachePool:
    """One instance's KVCache pool: residency set + eviction policy.

    ``capacity_blocks`` models the DRAM budget (∞ if None). ``lookup``
    returns the prefix hit length in *blocks* — the longest chain prefix of
    ``hash_ids`` fully resident here (prefix-chained hashes make any
    resident block imply its prefix was resident when written, but eviction
    can break chains, so we check explicitly).
    """

    def __init__(self, capacity_blocks: Optional[int] = None,
                 policy: str = "lru", block_bytes: int = 0) -> None:
        self.capacity = capacity_blocks
        self.policy = make_policy(policy)
        self.block_bytes = block_bytes
        self.blocks: dict[int, BlockMeta] = {}
        self.hits = 0
        self.misses = 0
        self.evictions = 0

    def __len__(self) -> int:
        return len(self.blocks)

    def __contains__(self, key: int) -> bool:
        return key in self.blocks

    def prefix_len(self, hash_ids: list[int]) -> int:
        """Longest resident prefix, in blocks (no metadata side effects)."""
        n = 0
        for h in hash_ids:
            if h in self.blocks:
                n += 1
            else:
                break
        return n

    def lookup(self, hash_ids: list[int], touch: bool = True) -> int:
        """Prefix match + hit accounting (one hit/miss per block)."""
        n = self.prefix_len(hash_ids)
        if touch:
            for h in hash_ids[:n]:
                meta = self.blocks[h]
                meta.hits += 1
                self.policy.on_hit(h, meta)
            self.hits += n
            self.misses += len(hash_ids) - n
        return n

    def touch_keys(self, hash_ids: Iterable[int],
                   count_read: bool = True) -> int:
        """Hit-account an arbitrary set of resident keys (no prefix walk);
        the tiered subclass overrides this to also promote SSD keys."""
        n = 0
        for h in hash_ids:
            meta = self.blocks.get(h)
            if meta is None:
                continue
            meta.hits += 1
            self.policy.on_hit(h, meta)
            self.hits += 1
            n += 1
        return n

    def discard(self, key: int) -> bool:
        """Drop one block outright (no eviction accounting)."""
        return self.remove(key) is not None

    def _make_room(self) -> tuple[list[int], bool]:
        """Evict unpinned victims until one slot is free; returns
        (evicted keys, whether a slot is available)."""
        evicted: list[int] = []
        attempts = 0
        while self.capacity is not None and len(self.blocks) >= self.capacity:
            v = self.policy.victim()
            if v is None or attempts > len(self.blocks):
                break  # nothing evictable (all pinned)
            attempts += 1
            if self.blocks.get(v) is not None and self.blocks[v].pinned:
                # pinned victims are skipped by re-queueing as a hit
                self.policy.on_hit(v, self.blocks[v])
                continue
            self._evict(v)
            evicted.append(v)
        has_room = self.capacity is None or len(self.blocks) < self.capacity
        return evicted, has_room

    def insert(self, hash_ids: Iterable[int], start_pos: int = 0) -> list[int]:
        """Insert blocks (idempotent); returns evicted keys."""
        evicted: list[int] = []
        for i, h in enumerate(hash_ids):
            if h in self.blocks:
                continue
            dropped, has_room = self._make_room()
            evicted.extend(dropped)
            if not has_room:
                break  # everything pinned; drop the insert
            meta = BlockMeta(key=h, position=start_pos + i,
                             size_bytes=self.block_bytes)
            self.blocks[h] = meta
            self.policy.on_insert(h, meta)
        return evicted

    def insert_meta(self, meta: BlockMeta) -> tuple[list[int], bool]:
        """Insert one pre-existing ``BlockMeta`` preserving its hit count /
        pin count / position (tier moves). Returns (evicted keys, placed)."""
        if meta.key in self.blocks:
            return [], True
        evicted, has_room = self._make_room()
        if has_room:
            self.blocks[meta.key] = meta
            self.policy.on_insert(meta.key, meta)
        return evicted, has_room

    def remove(self, key: int) -> Optional[BlockMeta]:
        """Withdraw a block without counting an eviction (tier moves)."""
        meta = self.blocks.pop(key, None)
        if meta is not None:
            self.policy.on_evict(key)
        return meta

    def _evict(self, key: int) -> None:
        self.blocks.pop(key, None)
        self.policy.on_evict(key)
        self.evictions += 1

    def pin(self, hash_ids: Iterable[int]) -> None:
        for h in hash_ids:
            if h in self.blocks:
                self.blocks[h].pinned += 1

    def unpin(self, hash_ids: Iterable[int]) -> None:
        for h in hash_ids:
            if h in self.blocks:
                self.blocks[h].pinned = max(0, self.blocks[h].pinned - 1)

    @property
    def hit_rate(self) -> float:
        t = self.hits + self.misses
        return self.hits / t if t else 0.0


class StateCache(CachePool):
    """SSM/hybrid prefix cache: one *state checkpoint* per block boundary
    instead of a KV slab. A hit on chain position k restores the recurrent
    state after 512·(k+1) tokens and skips that much prefill. Only the
    *deepest* hit matters (states subsume their prefixes), and transfer
    cost is constant-size — see ``state_bytes``."""

    def __init__(self, capacity_blocks: Optional[int] = None,
                 policy: str = "lru", state_bytes: int = 0) -> None:
        super().__init__(capacity_blocks, policy, block_bytes=state_bytes)

    def deepest_hit(self, hash_ids: list[int]) -> int:
        """Deepest resident checkpoint on this chain (0 = none).
        Unlike KV blocks, a checkpoint at depth k alone suffices."""
        best = 0
        for i, h in enumerate(hash_ids):
            if h in self.blocks:
                best = i + 1
        return best

    def lookup(self, hash_ids: list[int], touch: bool = True) -> int:
        best = self.deepest_hit(hash_ids)
        if touch and best:
            h = hash_ids[best - 1]
            meta = self.blocks[h]
            meta.hits += 1
            self.policy.on_hit(h, meta)
            self.hits += best
            self.misses += len(hash_ids) - best
        elif touch:
            self.misses += len(hash_ids)
        return best


# ---------------------------------------------------------------------------
# Table 1 reproduction helper
# ---------------------------------------------------------------------------

def cache_hit_analysis(requests, policy: str, capacity: Optional[int]) -> float:
    """Single global pool, replay in arrival order → block hit rate
    (the paper's Table 1 methodology)."""
    pool = CachePool(capacity_blocks=capacity, policy=policy)
    for r in requests:
        n = pool.lookup(r.hash_ids)
        pool.insert(r.hash_ids[n:], start_pos=n)
    return pool.hit_rate


def kv_block_bytes(cfg, block_tokens: int = 512) -> int:
    """Bytes of one 512-token KVCache block for a model config (bf16)."""
    return 2 * cfg.attention_layers * block_tokens * cfg.n_kv_heads \
        * cfg.head_dim * 2


def ssm_state_bytes(cfg) -> int:
    """Bytes of one SSM state checkpoint (fp32 state + bf16 conv tail)."""
    if cfg.ssm is None:
        return 0
    s = cfg.ssm
    n_ssm = cfg.n_layers - cfg.attention_layers if cfg.attn_every \
        else cfg.n_layers
    nh = s.n_heads(cfg.d_model)
    conv_ch = s.d_inner(cfg.d_model) + 2 * s.n_groups * s.d_state
    return n_ssm * (nh * s.head_dim * s.d_state * 4
                    + (s.d_conv - 1) * conv_ch * 2)
