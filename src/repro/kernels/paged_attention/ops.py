"""Public op: paged decode attention (kernel or oracle dispatch)."""
from __future__ import annotations

import jax

from repro.kernels.paged_attention.kernel import paged_attention as _kernel
from repro.kernels.paged_attention.ref import paged_attention_ref as _ref


def paged_decode_attention(q, k_pages, v_pages, block_table, seq_lens, *,
                           use_pallas: bool = False,
                           interpret: bool | None = None):
    """q: (B, H, D) over one layer's paged KV → (B, H, D)."""
    if not use_pallas:
        return _ref(q, k_pages, v_pages, block_table, seq_lens)
    if interpret is None:
        interpret = jax.default_backend() == "cpu"
    return _kernel(q, k_pages, v_pages, block_table, seq_lens,
                   interpret=interpret)
