"""CLI: ``python -m tools.replint [paths...]``.

Exit status is 0 when every finding is suppressed or baselined, 1 when
new findings exist (or baselined findings went stale without
--write-baseline cleaning them up being run -- stale entries are
reported but do not fail the build).
"""
from __future__ import annotations

import argparse
import os
import sys
import time

from tools.replint.core import (RULES, Finding, lint_paths, load_baseline,
                                write_baseline)

DEFAULT_PATHS = ["src", "benchmarks"]
DEFAULT_BASELINE = os.path.join("tools", "replint", "baseline.txt")


def main(argv=None) -> int:
    ap = argparse.ArgumentParser(
        prog="python -m tools.replint",
        description="repro-lint: repo-specific static analysis "
                    "(concurrency, jax host-aliasing, refcount "
                    "invariants)")
    ap.add_argument("paths", nargs="*", default=None,
                    help=f"files/directories to lint "
                         f"(default: {' '.join(DEFAULT_PATHS)})")
    ap.add_argument("--baseline", default=DEFAULT_BASELINE,
                    help="baseline file of grandfathered findings")
    ap.add_argument("--no-baseline", action="store_true",
                    help="ignore the baseline: every finding fails")
    ap.add_argument("--write-baseline", action="store_true",
                    help="rewrite the baseline file with the current "
                         "findings and exit 0")
    ap.add_argument("--list-rules", action="store_true")
    args = ap.parse_args(argv)

    if args.list_rules:
        for r in RULES:
            print(r)
        return 0

    t0 = time.monotonic()
    findings, n_files = lint_paths(args.paths or DEFAULT_PATHS)
    dt = time.monotonic() - t0

    if args.write_baseline:
        write_baseline(args.baseline, findings)
        print(f"wrote {len(findings)} finding(s) to {args.baseline}")
        return 0

    baseline = set() if args.no_baseline else load_baseline(args.baseline)
    new = [f for f in findings if f.baseline_key not in baseline]
    n_base = len(findings) - len(new)
    stale = baseline - {f.baseline_key for f in findings}

    for f in new:
        print(f.render())
    for key in sorted(stale):
        print(f"stale baseline entry (fixed? run --write-baseline): {key}")

    print(f"replint: {n_files} files in {dt:.2f}s -- "
          f"{len(new)} new finding(s), {n_base} baselined, "
          f"{len(stale)} stale baseline entr(y/ies)")
    return 1 if new else 0


if __name__ == "__main__":
    sys.exit(main())
