"""Config registry + derived quantities."""
import pytest

from repro.configs.base import MODEL_AXIS, get_config, list_configs

ASSIGNED = [
    "qwen3-moe-235b-a22b", "smollm-360m", "qwen2.5-3b", "mixtral-8x7b",
    "phi3-mini-3.8b", "internvl2-26b", "mamba2-2.7b", "whisper-large-v3",
    "jamba-1.5-large-398b", "qwen3-14b",
]

# approximate parameter-count targets implied by the model names (billions)
PARAM_TARGETS = {
    "qwen3-moe-235b-a22b": (150, 300),
    "smollm-360m": (0.25, 0.55),
    "qwen2.5-3b": (2.0, 4.5),
    "mixtral-8x7b": (35, 60),
    "phi3-mini-3.8b": (2.5, 5.0),
    "internvl2-26b": (15, 30),      # language backbone of the 26B VLM
    "mamba2-2.7b": (1.8, 3.5),
    "whisper-large-v3": (1.0, 2.5),   # head padding 20→32 inflates attn
    "jamba-1.5-large-398b": (250, 450),
    "qwen3-14b": (10, 18),
    "llama2-70b": (55, 85),
}


def test_all_assigned_present():
    known = list_configs()
    for a in ASSIGNED:
        assert a in known
    assert "llama2-70b" in known     # the paper's own dummy model


@pytest.mark.parametrize("name", list(PARAM_TARGETS))
def test_param_counts_plausible(name):
    cfg = get_config(name)
    lo, hi = PARAM_TARGETS[name]
    n = cfg.param_count() / 1e9
    assert lo <= n <= hi, f"{name}: {n:.1f}B params outside [{lo},{hi}]"


@pytest.mark.parametrize("name", ASSIGNED)
def test_reduced_is_smoke_sized(name):
    cfg = get_config(name).reduced()
    assert cfg.d_model <= 512
    assert cfg.n_layers <= 8
    if cfg.moe is not None:
        assert cfg.moe.n_experts <= 4
    assert cfg.param_count() < 50e6


def test_moe_active_params():
    cfg = get_config("qwen3-moe-235b-a22b")
    assert cfg.active_param_count() < 0.25 * cfg.param_count()
    dense = get_config("qwen3-14b")
    assert dense.active_param_count() == dense.param_count()


def test_padded_heads_divisible():
    for name in ASSIGNED:
        cfg = get_config(name)
        if cfg.kind != "ssm":
            assert cfg.padded_heads % MODEL_AXIS == 0
        assert cfg.padded_vocab % 256 == 0


def test_unknown_arch_raises():
    with pytest.raises(KeyError):
        get_config("nonexistent-model")
