"""Discrete-event simulator invariants + Figure-8/12 orderings."""
import numpy as np
import pytest

from repro.configs.base import get_config
from repro.core.simulator import CoupledCluster, MooncakeCluster
from repro.core.trace import TraceSpec, generate_trace, simulated_requests

CFG = get_config("llama2-70b")


@pytest.fixture(scope="module")
def trace():
    return generate_trace(TraceSpec(n_requests=800, duration_ms=240_000,
                                    seed=1))


def test_record_invariants(trace):
    mc = MooncakeCluster(CFG, n_prefill=4, n_decode=4)
    res = mc.run(trace)
    for r in res.records:
        if r.completed:
            assert r.accepted
            assert r.ttft > 0
            assert r.done >= r.arrival + r.ttft - 1e-9
            assert len(r.tbts) == max(r.req.output_length - 1, 0)
            assert all(t >= -1e-9 for t in r.tbts)
    n_done = len(res.completed())
    assert n_done + len(res.rejected()) == len(trace)
    assert res.goodput(30, 0.1) <= n_done / res.duration + 1e-9


def test_strategy_ordering_figure8(trace):
    """Fig. 8: kvcache-centric ≤ cache-aware ≤ load-balance ≤ random TTFT."""
    avg = {}
    for s in ("random", "load_balance", "cache_aware", "kvcache"):
        mc = MooncakeCluster(CFG, n_prefill=4, n_decode=4, strategy=s)
        avg[s] = mc.run(trace).avg_ttft()
    assert avg["kvcache"] <= avg["cache_aware"] * 1.05
    assert avg["cache_aware"] < avg["load_balance"] * 1.05
    assert avg["load_balance"] < avg["random"]


def test_kvcache_strategy_migrates(trace):
    mc = MooncakeCluster(CFG, n_prefill=4, n_decode=4, strategy="kvcache")
    res = mc.run(trace)
    assert res.n_migrations > 0


def test_mooncake_beats_coupled_under_long_context_load():
    """Fig. 12: under long-context pressure the coupled baseline breaks
    TBT/TTFT SLOs while Mooncake holds them."""
    reqs = simulated_requests(150, 32768, 512, cache_ratio=0.5, rps=2.0)
    mc = MooncakeCluster(CFG, n_prefill=2, n_decode=2).run(reqs)
    vl = CoupledCluster(CFG, n_instances=4).run(reqs)
    assert mc.goodput(30, .1) > 2 * vl.goodput(30, .1)


def test_layerwise_transfer_overlap_reduces_ttft(trace):
    """§5.2: streaming the KVCache during prefill must not be slower than
    store-after-compute."""
    t_on = MooncakeCluster(CFG, n_prefill=2, n_decode=2,
                           layerwise_prefill=True).run(trace).avg_ttft()
    t_off = MooncakeCluster(CFG, n_prefill=2, n_decode=2,
                            layerwise_prefill=False).run(trace).avg_ttft()
    assert t_on <= t_off + 1e-6


def test_prefix_caching_reduces_ttft(trace):
    with_cache = MooncakeCluster(CFG, n_prefill=4, n_decode=4,
                                 cache_capacity_blocks=50_000)
    r1 = with_cache.run(trace)
    no_cache = MooncakeCluster(CFG, n_prefill=4, n_decode=4,
                               cache_capacity_blocks=1)
    r2 = no_cache.run(trace)
    assert r1.avg_ttft() < r2.avg_ttft()
    reused = sum(r.prefix_blocks for r in r1.records)
    assert reused > 0
