"""Mooncake's primary contribution: KVCache-centric disaggregated
scheduling — cache pool (Figure 3), Conductor (Algorithm 1), Messenger,
overload admission (§7), and the discrete-event cluster simulator (§8)."""
from repro.core.cache import (CachePool, StateCache, cache_hit_analysis,
                              kv_block_bytes, ssm_state_bytes)
from repro.core.tiered import TierPrefix, TieredCachePool
from repro.core.directory import GlobalBlockDirectory
from repro.core.conductor import Conductor, DecodeInstance, PrefillInstance
from repro.core.costmodel import CostModel, Hardware, InstanceSpec, V5E
from repro.core.messenger import Messenger
from repro.core.policies import (AdmissionPolicy, Arm, PolicyContext,
                                 get_policy, list_policies, make_admission,
                                 register_policy)
from repro.core.simulator import CoupledCluster, MooncakeCluster, SimResult
from repro.configs.base import CacheTierSpec, ClusterSpec
from repro.core.trace import (BLOCK_TOKENS, Request, TraceSpec,
                              generate_trace, load_trace, save_trace,
                              simulated_requests, trace_stats)
