"""Serving launcher: a single-host disaggregated Mooncake instance pair.

    PYTHONPATH=src python -m repro.launch.serve --arch smollm-360m \
        --requests 16 [--trace trace.jsonl]

Runs the REAL engines (reduced model on CPU). By default requests flow
through the always-on ``ServingLoop``: a thread feeds arrivals, prefill
chunks interleave between continuous-batching decode steps, and admission
backpressure sheds load when the queue/slots/page pool saturate — the §3
workflow as one sustained iteration. ``--no-loop`` keeps the original
phase-at-a-time driver (full prefill, join, then decode). With --trace,
request arrival order/lengths/prefix structure come from a Mooncake-format
trace (hash chains realised to actual tokens). With --peer-ssd-dir, blocks
a PREVIOUS run demoted to its SSD store become cross-node-fetchable
through a shared GlobalBlockDirectory (the global pool, across launcher
runs — same seed ⇒ same hash chains).
"""
from __future__ import annotations

import argparse
import sys
import time


def main(argv=None) -> int:
    ap = argparse.ArgumentParser(description=__doc__)
    ap.add_argument("--arch", default="smollm-360m")
    ap.add_argument("--requests", type=int, default=16)
    ap.add_argument("--trace", default=None)
    ap.add_argument("--max-batch", type=int, default=8)
    ap.add_argument("--max-new", type=int, default=16)
    ap.add_argument("--no-loop", action="store_true",
                    help="phase-at-a-time driver (full prefill + join + "
                         "decode) instead of the interleaved serving loop")
    ap.add_argument("--prefill-workers", type=int, default=1,
                    help="N PrefillWorkers feeding the loop's decode batch")
    ap.add_argument("--tbt-budget", type=float, default=None,
                    help="loop TBT budget in seconds: fit prefill chunks "
                         "into the slack it leaves per decode step "
                         "(default: deterministic chunks-per-iter mode)")
    ap.add_argument("--chunks-per-iter", type=int, default=1,
                    help="prefill chunks between decode steps when no "
                         "--tbt-budget is given")
    ap.add_argument("--admission", default="predictive",
                    choices=("baseline", "early", "predictive"),
                    help="backpressure policy evaluated at submit()")
    ap.add_argument("--no-preempt", action="store_true",
                    help="disable decode preemption (pending joins only "
                         "defer, never spill a lower-priority victim's KV "
                         "to the host tier)")
    ap.add_argument("--restore-mode", default="auto",
                    choices=("auto", "reload", "recompute"),
                    help="how preempted victims restore: reload spilled "
                         "bytes, recompute through prefill, or priced "
                         "per restore (auto)")
    ap.add_argument("--pool-blocks", type=int, default=4096)
    ap.add_argument("--ssd-blocks", type=int, default=0,
                    help="SSD-tier capacity in blocks (0 = flat DRAM pool)")
    ap.add_argument("--ssd-dir", default=None,
                    help="directory for the file-backed SSD block store; "
                         "with --ssd-blocks, demoted KV really hits disk")
    ap.add_argument("--ssd-mode", default="overlap",
                    choices=("blocking", "overlap"),
                    help="how SSD-resident prefixes load: synchronously, or "
                         "overlapped with head-chunk recompute (§5.2)")
    ap.add_argument("--peer-ssd-dir", default=None,
                    help="a PEER node's SSD store directory (e.g. left by a "
                         "previous run): its blocks join a shared "
                         "GlobalBlockDirectory and local misses resolve to "
                         "cross-node fetches — the Figure-3 global pool "
                         "across launcher runs")
    ap.add_argument("--mesh", default=None, metavar="DxM",
                    help="shard the paged decode engine over a "
                         "(data, model) device mesh, e.g. 2x2: decode "
                         "slots and page-pool banks split over the data "
                         "axis, KV-head stripes over the model axis. "
                         "Needs data*model jax devices (CPU: set "
                         "XLA_FLAGS=--xla_force_host_platform_device_"
                         "count=N) and, for model>1, a grouped-GQA head "
                         "layout (the arch's heads are adjusted with a "
                         "printed note if required)")
    ap.add_argument("--width-buckets", type=int, default=1,
                    help="per-step block-table width buckets (>1 runs "
                         "shallow slots on narrower tables instead of "
                         "padding to the deepest; single-device only)")
    ap.add_argument("--decode-substrate", default="paged",
                    choices=("paged", "dense"),
                    help="decode KV substrate: block-table pages with "
                         "zero-copy prefill→decode handoff and refcounted "
                         "prefix sharing (default), or the dense per-slot "
                         "arena (bit-exactness oracle)")
    ap.add_argument("--seed", type=int, default=0)
    args = ap.parse_args(argv)

    import jax
    import numpy as np

    from repro.configs.base import get_config
    from repro.core.trace import TraceSpec, generate_trace, load_trace
    from repro.data.pipeline import realize_request_tokens
    from repro.models.transformer import init_params
    from repro.serving.engine import DecodeWorker, HostKVPool, PrefillWorker

    cfg = get_config(args.arch).reduced()
    mesh = None
    mesh_d = 1
    if args.mesh:
        import dataclasses

        from repro.launch.mesh import make_decode_mesh, parse_mesh_arg
        from repro.models.transformer import paged_shard_reason
        if args.decode_substrate != "paged":
            ap.error("--mesh shards the PAGED decode engine; drop "
                     "--decode-substrate dense")
        mesh_d, mesh_m = parse_mesh_arg(args.mesh)
        if mesh_m > 1 and paged_shard_reason(cfg, mesh_m, mesh_d):
            kv = max(4, mesh_m)
            if 16 % kv or kv % mesh_m:
                ap.error(f"--mesh model axis {mesh_m} has no grouped-GQA "
                         f"head layout")
            print(f"--mesh {args.mesh}: adjusting the reduced arch to "
                  f"grouped GQA (n_heads=16, n_kv_heads={kv}) so KV heads "
                  f"stripe over the model axis")
            cfg = dataclasses.replace(cfg, n_heads=16, n_kv_heads=kv)
        reason = paged_shard_reason(cfg, mesh_m, mesh_d)
        if reason:
            ap.error(f"--mesh {args.mesh}: {reason}")
        if args.max_batch % mesh_d:
            ap.error(f"--max-batch {args.max_batch} must divide over the "
                     f"mesh data axis ({mesh_d})")
        mesh = make_decode_mesh(mesh_d, mesh_m)
    params = init_params(cfg, jax.random.PRNGKey(args.seed))
    directory = peer_pool = None
    if args.peer_ssd_dir:
        from repro.core.directory import GlobalBlockDirectory
        directory = GlobalBlockDirectory()
        # restart recovery re-indexes the peer's flushed blocks; bind()
        # publishes them, so this run's misses can fetch across "nodes"
        peer_pool = HostKVPool(capacity_blocks=8, ssd_capacity_blocks=None,
                               ssd_dir=args.peer_ssd_dir,
                               directory=directory, node_id=1)
    pool = HostKVPool(capacity_blocks=args.pool_blocks,
                      ssd_capacity_blocks=args.ssd_blocks,
                      ssd_dir=args.ssd_dir,
                      directory=directory, node_id=0)
    if peer_pool is not None:
        pool.add_peer(1, peer_pool)
    max_len = 2048
    page_pool = None
    from repro.serving.engine import paged_supported
    if args.decode_substrate == "paged" and paged_supported(cfg):
        from repro.serving.paged_cache import DevicePagePool
        per_seq = max_len // 64
        page_pool = DevicePagePool(
            cfg, n_pages=1 + (args.max_batch // mesh_d + 1) * per_seq,
            page_tokens=64, mesh=mesh)
    pw = PrefillWorker(params, cfg, pool, prefill_chunk=256,
                       ssd_mode=args.ssd_mode, page_pool=page_pool)

    if args.trace:
        reqs = load_trace(args.trace, limit=args.requests)
    else:
        spec = TraceSpec(n_requests=args.requests, duration_ms=10_000,
                         seed=args.seed, max_input_tokens=2048,
                         chat_turn_mu=5.0, doc_len_mu=6.5)
        reqs = generate_trace(spec)[:args.requests]
    # scale lengths to smoke size
    for r in reqs:
        r.input_length = min(r.input_length, 1536)
        r.hash_ids = r.hash_ids[:max(r.input_length // 512, 1)]

    dw = DecodeWorker(params, cfg, max_batch=args.max_batch, max_len=max_len,
                      substrate=args.decode_substrate, page_pool=page_pool,
                      width_buckets=args.width_buckets)
    payloads = [(r.req_id, realize_request_tokens(r, cfg.vocab_size),
                 min(args.max_new, max(r.output_length, 2)),
                 r.hash_ids[0] if r.hash_ids else None) for r in reqs]
    pws = [pw]
    t0 = time.time()
    if not args.no_loop:
        import threading

        from repro.serving.loop import ServingLoop
        from repro.serving.request import ServingRequest
        pws += [PrefillWorker(params, cfg, pool, prefill_chunk=256,
                              ssd_mode=args.ssd_mode, page_pool=page_pool)
                for _ in range(args.prefill_workers - 1)]
        loop = ServingLoop(pws, dw, tbt_budget_s=args.tbt_budget,
                           chunks_per_iter=args.chunks_per_iter,
                           max_queue=max(args.requests, 8),
                           admission=args.admission,
                           preempt=not args.no_preempt,
                           restore_mode=args.restore_mode)

        def feeder():
            for rid, toks, mn, sess in payloads:
                loop.submit(ServingRequest(req_id=rid, tokens=toks,
                                           max_new=mn, session=sess))
            loop.close_intake()

        th = threading.Thread(target=feeder, name="repro-loop-feeder")
        th.start()
        ls = loop.run()
        th.join()
        done = ls["completed"]
        total_new = sum(len(o.tokens) for o in loop.outputs.values())
        print(f"loop: {ls['iterations']} iterations, {ls['decode_steps']} "
              f"decode steps, {ls['prefill_chunks']} prefill chunks "
              f"interleaved, {ls['rejected']} rejected by "
              f"'{args.admission}' backpressure, {ls['preemptions']} "
              f"preemptions ({ls['restores_reload']} reload / "
              f"{ls['restores_recompute']} recompute restores), TBT p50/p99 "
              f"{ls['tbt_p50_s'] * 1e3:.1f}/{ls['tbt_p99_s'] * 1e3:.1f} ms")
    else:
        done, total_new = 0, 0
        queue = list(payloads)
        outputs: dict = {}
        from repro.serving.request import ServingRequest
        while queue or dw.n_active:
            while queue and dw.n_active < args.max_batch:
                rid, toks, mn, sess = queue.pop(0)
                pres = pw(toks, session=sess)
                dw.join(ServingRequest(req_id=rid, tokens=toks, max_new=mn,
                                       session=sess), pres)
                outputs[rid] = [pres.first_token]
                print(f"req {rid:4d}: prefill {pres.prompt_len:5d} tokens, "
                      f"reused {pres.reused_blocks} blocks, "
                      f"computed "
                      f"{pres.prompt_len - 512 * pres.reused_blocks}")
            for rid, tok, fin in dw.step():
                outputs[rid].append(tok)
                total_new += 1
                if fin:
                    done += 1
    dt = time.time() - t0
    pw_stats = [w.stats() for w in pws]
    st = {k: sum(s[k] for s in pw_stats) for k in pw_stats[0]}
    print(f"\nserved {done} requests in {dt:.1f}s — "
          f"{total_new / dt:.1f} tok/s decode, "
          f"pool: {pool.n_blocks} blocks resident, "
          f"prefix reuse {st['reused_blocks']} blocks "
          f"({512 * st['reused_blocks']} tokens skipped)")
    if page_pool is not None:
        ps = page_pool.stats()
        ds = dw.stats()
        if mesh is not None:
            print(f"mesh {args.mesh}: {page_pool.n_banks} page banks × "
                  f"{page_pool.bank_pages} pages (capacity "
                  f"{ps['capacity']} logical pages), "
                  f"{dw.slots_per_bank} slots per data shard")
        print(f"paged substrate: {page_pool.used_pages}/{page_pool.n_pages} "
              f"pages held, {ps['pages_written']} written, "
              f"{ps['shared_adoptions']} shared-prefix adoptions, "
              f"{ps['cow_copies']} COW, {ds['zero_copy_joins']} "
              f"zero-copy joins, {ps['pages_exported']} pages spilled / "
              f"{ps['pages_imported']} imported; hasher: "
              f"{pw.hasher.blocks_hashed} blocks SHA'd, "
              f"{pw.hasher.memo_hits} memo hits")
    if pool.store is not None:
        s = pool.store.stats()
        print(f"ssd store: {s['blocks']} blocks on disk "
              f"({s['file_bytes'] >> 10} KiB), {s['n_flushes']} write-back "
              f"flushes, {s['layer_reads']} layer reads, "
              f"{s['read_failures']} read failures; overlapped "
              f"{st['overlapped_requests']} prefills")
    if peer_pool is not None:
        print(f"global pool: fetched {pool.peer_blocks_fetched} blocks off "
              f"the peer store ({pool.peer_fetch_failures} failures"
              f"{', fallbacks ' + str(pool.fallback_reasons) if pool.fallback_reasons else ''}); "
              f"directory {directory.stats()}")
        peer_pool.close()
    pool.close()
    return 0


if __name__ == "__main__":
    sys.exit(main())
