"""CRC-framed wire protocol for cross-node KVCache transfer.

The Transfer-Engine role (§3 step 3) made real: until now "peer fetch"
meant reading a sibling ``HostKVPool`` object in the same process.  This
module puts an actual socket between the nodes, reusing the
``SSDBlockStore`` header discipline — a magic tag, an explicit length,
and a CRC32 over every payload — so a truncated stream, a torn frame, or
flipped bits produce a *typed error*, never wrong KV bytes.

One frame = ``MAGIC | msg-type | payload-len | crc32(payload) | payload``.
A ``FETCH_BLOCK`` is served layer-major as one ``LAYER`` frame per layer
(the frame CRC is that layer's integrity check, mirroring the store's
per-layer CRCs), which is exactly the unit ``AsyncPrefetcher.fetch``
already consumes — a ``SocketPeer`` plugs into the engine's
``PeerSource`` unchanged, it just reads sockets instead of sibling pools.

Error taxonomy, shared by the in-process and socket transports (the
engine maps these to the ``fallback_reasons`` it has always recorded):

* ``PeerUnreachable`` — the node is gone (dead process, refused/reset
  connection, timeout).  Reason ``peer_unreachable``.
* ``StaleDirectory`` — the node is alive but no longer holds the block
  (the advisory directory lagged).  Reason ``stale_directory``.
* ``TornFrame`` — bytes arrived but failed integrity (bad magic, CRC
  mismatch, mid-frame EOF, or the owner's own store rejected the slot).
  Reason ``verify_failed``.

Every failure mode degrades to recompute upstream; wrong bytes are
impossible by construction.

``python -m repro.serving.transport --store DIR`` runs a standalone
block node over an existing ``SSDBlockStore`` directory (no jax import
on that path) — the chaos harness kill -9's these.
"""
from __future__ import annotations

import json
import socket
import struct
import threading
import time
import zlib
from typing import Callable, Optional

import numpy as np

_WIRE_MAGIC = b"MKW1"
_FRAME_HDR = struct.Struct("<4sBII")    # magic, msg type, payload len, crc
_HDR_PREFIX = struct.Struct("<4sBI")    # the CRC'd part of the header
_MAX_PAYLOAD = 256 << 20                # sanity bound: beyond this = torn
_RECV_CHUNK = 1 << 16


def _frame_crc(mtype: int, n: int, payload: bytes) -> int:
    """CRC32 over header prefix (magic, type, length) AND payload: a bit
    flip anywhere in the frame — a mis-typed header is corruption too —
    must fail the check, not just flips inside the payload."""
    crc = zlib.crc32(_HDR_PREFIX.pack(_WIRE_MAGIC, mtype, n))
    return zlib.crc32(payload, crc) & 0xFFFFFFFF

# ---- message types ---------------------------------------------------------
MSG_GEOM = 1            # -> OK {"n_layers": L}
MSG_FETCH_LAYER = 2     # {"key": k, "layer": l} -> LAYER | ERR
MSG_LAYER = 3           # binary: json meta + k bytes + v bytes
MSG_OK = 4              # json reply
MSG_ERR = 5             # {"code": taxonomy, "msg": detail}
MSG_HELLO = 16          # {"node": id, "port": block server port}
MSG_PUBLISH = 17        # {"key": k, "node": id, "tier": t}
MSG_WITHDRAW = 18       # {"key": k, "node": id}
MSG_LOOKUP = 19         # {"key": k} -> OK {"holders": [[node, tier], ...]}
MSG_NODES = 20          # {} -> OK {"nodes": [[node, host, port], ...]}
MSG_BARRIER = 21        # {"name": s, "n": int} -> OK {"arrived": int}
MSG_STATS = 22          # {} -> OK {directory stats}


class PeerError(Exception):
    """Base of the cross-node transfer taxonomy."""


class PeerUnreachable(PeerError):
    """The peer process/socket is gone — connection refused, reset,
    timed out, or the node was killed."""


class TornFrame(PeerError):
    """Bytes arrived but failed integrity: bad magic, length out of
    bounds, CRC mismatch, or EOF mid-frame."""


class StaleDirectory(PeerError):
    """The peer is alive but does not hold the requested block — the
    advisory directory entry lagged reality."""


def fallback_reason(exc: PeerError) -> str:
    """Map a taxonomy error to the engine's ``fallback_reasons`` key
    (one vocabulary across the in-process and socket transports)."""
    if isinstance(exc, PeerUnreachable):
        return "peer_unreachable"
    if isinstance(exc, StaleDirectory):
        return "stale_directory"
    if isinstance(exc, TornFrame):
        return "verify_failed"
    return "peer_fetch_failed"


# ---------------------------------------------------------------------------
# frame codec
# ---------------------------------------------------------------------------

def encode_frame(mtype: int, payload: bytes) -> bytes:
    """One wire frame: header (magic, type, length, CRC32) + payload."""
    if not 0 <= mtype < 256:
        raise ValueError(f"msg type {mtype} out of range")
    crc = _frame_crc(mtype, len(payload), payload)
    return _FRAME_HDR.pack(_WIRE_MAGIC, mtype, len(payload), crc) + payload


class FrameReader:
    """Incremental frame parser with partial-read reassembly.

    ``feed(data)`` accepts bytes as they arrive off ``recv`` — at any
    fragmentation — and returns every COMPLETE ``(mtype, payload)``
    decoded so far.  Integrity failures (bad magic, oversized length,
    CRC mismatch) raise ``TornFrame``; call ``eof()`` when the stream
    ends to turn a buffered partial frame into ``TornFrame`` too
    (a connection that dies mid-frame must never look like a clean
    close)."""

    def __init__(self) -> None:
        self._buf = bytearray()

    @property
    def pending(self) -> int:
        """Bytes buffered toward an incomplete frame."""
        return len(self._buf)

    def feed(self, data: bytes) -> list:
        self._buf.extend(data)
        out = []
        while len(self._buf) >= _FRAME_HDR.size:
            magic, mtype, n, crc = _FRAME_HDR.unpack_from(self._buf)
            if magic != _WIRE_MAGIC:
                raise TornFrame(f"bad frame magic {bytes(magic)!r}")
            if n > _MAX_PAYLOAD:
                raise TornFrame(f"frame length {n} exceeds bound")
            end = _FRAME_HDR.size + n
            if len(self._buf) < end:
                break                   # wait for the rest of the payload
            payload = bytes(self._buf[_FRAME_HDR.size:end])
            del self._buf[:end]
            if _frame_crc(mtype, n, payload) != crc:
                raise TornFrame(f"frame CRC mismatch (type {mtype})")
            out.append((mtype, payload))
        return out

    def eof(self) -> None:
        """The stream closed: raise if it died mid-frame."""
        if self._buf:
            raise TornFrame(
                f"stream closed mid-frame ({len(self._buf)} bytes buffered)")


def _pack_json(obj) -> bytes:
    return json.dumps(obj, separators=(",", ":")).encode()


def _unpack_json(payload: bytes):
    try:
        return json.loads(payload.decode())
    except (ValueError, UnicodeDecodeError) as e:
        raise TornFrame(f"undecodable control payload: {e}") from None


def pack_layer(key: int, layer: int, k: np.ndarray, v: np.ndarray) -> bytes:
    """LAYER payload: length-prefixed json meta, then raw k and v bytes
    (the frame CRC covers all of it — the per-layer integrity check)."""
    kb = np.ascontiguousarray(k).tobytes()
    vb = np.ascontiguousarray(v).tobytes()
    meta = _pack_json(dict(key=int(key), layer=int(layer),
                           shape=list(np.asarray(k).shape),
                           dtype=str(np.asarray(k).dtype), klen=len(kb)))
    return struct.pack("<I", len(meta)) + meta + kb + vb


def unpack_layer(payload: bytes):
    """Inverse of ``pack_layer`` -> (meta dict, k, v)."""
    if len(payload) < 4:
        raise TornFrame("layer payload shorter than its meta prefix")
    (jlen,) = struct.unpack_from("<I", payload)
    if 4 + jlen > len(payload):
        raise TornFrame("layer meta length exceeds payload")
    meta = _unpack_json(payload[4:4 + jlen])
    try:
        shape = tuple(meta["shape"])
        dtype = np.dtype(meta["dtype"])
        klen = int(meta["klen"])
    except (KeyError, TypeError, ValueError) as e:
        raise TornFrame(f"malformed layer meta: {e}") from None
    body = payload[4 + jlen:]
    if len(body) != klen + klen or klen != int(np.prod(shape)) * dtype.itemsize:
        raise TornFrame("layer body size disagrees with its meta")
    k = np.frombuffer(body[:klen], dtype=dtype).reshape(shape)
    v = np.frombuffer(body[klen:], dtype=dtype).reshape(shape)
    return meta, k, v


class FrameConn:
    """A framed, blocking request/response connection over one socket.

    Raises the taxonomy instead of raw socket errors: OS-level failures
    (reset, refused, timeout, clean close while a reply is owed) become
    ``PeerUnreachable``; integrity failures become ``TornFrame``."""

    def __init__(self, sock: socket.socket, timeout: Optional[float] = 5.0):
        sock.settimeout(timeout)
        self._sock = sock
        self._reader = FrameReader()

    def settimeout(self, timeout: Optional[float]) -> None:
        """Adjust the read timeout (long-blocking RPCs like BARRIER wait
        server-side longer than an ordinary reply would)."""
        self._sock.settimeout(timeout)

    def send(self, mtype: int, payload: bytes) -> None:
        try:
            self._sock.sendall(encode_frame(mtype, payload))
        except OSError as e:
            raise PeerUnreachable(f"send failed: {e}") from None

    def recv(self):
        """Next (mtype, payload) frame; blocks up to the timeout."""
        while True:
            frames = self._reader.feed(b"")
            if frames:
                # feed() drains at most what's buffered; loop below reads
                return frames[0]
            try:
                data = self._sock.recv(_RECV_CHUNK)
            except socket.timeout:
                raise PeerUnreachable("peer read timed out") from None
            except OSError as e:
                raise PeerUnreachable(f"recv failed: {e}") from None
            if not data:
                self._reader.eof()      # mid-frame close -> TornFrame
                raise PeerUnreachable("peer closed the connection")
            frames = self._reader.feed(data)
            if frames:
                if len(frames) > 1:
                    # requests are strictly serial on a FrameConn; extra
                    # frames mean the stream desynced
                    raise TornFrame("unexpected pipelined frames")
                return frames[0]

    def request(self, mtype: int, payload: bytes):
        self.send(mtype, payload)
        return self.recv()

    def close(self) -> None:
        try:
            self._sock.close()
        except OSError:
            pass


# ---------------------------------------------------------------------------
# peer backends: what a node serves its blocks FROM
# ---------------------------------------------------------------------------


class InProcPeer:
    """Peer backed by a sibling ``HostKVPool`` object in this process.

    The in-process transport, now speaking the same taxonomy as the
    socket one: a ``kill()``-ed pool raises ``PeerUnreachable`` exactly
    like a dead socket, a missing block raises ``StaleDirectory``, and a
    CRC-rejected store slot raises ``TornFrame`` — so the engine's
    fallback accounting cannot tell the transports apart."""

    def __init__(self, pool) -> None:
        self.pool = pool

    def _check_alive(self) -> None:
        if self.pool is None or not self.pool.alive:
            raise PeerUnreachable("peer pool is dead (killed node)")

    @property
    def n_layers(self) -> int:
        self._check_alive()
        store = self.pool.store
        if store is not None and store.n_layers:
            return store.n_layers
        for kv in self.pool.data.values():
            return kv[0].shape[0]
        return 0

    def read_layer(self, key: int, layer: int):
        self._check_alive()
        kv = self.pool.data.get(key)
        if kv is not None:
            return np.asarray(kv[0][layer]), np.asarray(kv[1][layer])
        store = self.pool.store
        if store is None or key not in store:
            raise StaleDirectory(f"peer no longer holds block {key}")
        pair = store.read_layer(key, layer)
        if pair is None:                # store CRC / truncation reject
            raise TornFrame(f"peer store rejected block {key} layer {layer}")
        return pair

    def close(self) -> None:
        pass


class StorePeer:
    """Peer backend over a bare ``SSDBlockStore`` (no pool, no jax) —
    what the standalone block-node main serves from."""

    def __init__(self, store) -> None:
        self.store = store

    @property
    def n_layers(self) -> int:
        return self.store.n_layers

    def read_layer(self, key: int, layer: int):
        if key not in self.store:
            raise StaleDirectory(f"store has no block {key}")
        pair = self.store.read_layer(key, layer)
        if pair is None:
            raise TornFrame(f"store rejected block {key} layer {layer}")
        return pair

    def close(self) -> None:
        pass


# ---------------------------------------------------------------------------
# block server (the serving side of FETCH_BLOCK)
# ---------------------------------------------------------------------------


class BlockServer:
    """Serves ``GEOM``/``FETCH_LAYER`` for one node's blocks.

    Thread-per-connection over a listening TCP socket (loopback by
    default).  ``stall_s`` delays every LAYER frame — the chaos harness
    uses it to widen the mid-transfer window it kill -9's into — and
    ``mangle`` lets tests corrupt or truncate outgoing LAYER frames at
    exact byte boundaries (return ``None`` to drop the connection
    instead, simulating death mid-block)."""

    def __init__(self, backend, host: str = "127.0.0.1", port: int = 0, *,
                 stall_s: float = 0.0,
                 mangle: Optional[Callable[[bytes], Optional[bytes]]] = None,
                 timeout: float = 30.0) -> None:
        self.backend = backend
        self.stall_s = stall_s
        self.mangle = mangle
        self.timeout = timeout
        self._lock = threading.Lock()
        #: guarded_by self._lock
        self._conns: dict[int, socket.socket] = {}
        self._closed = False            #: guarded_by self._lock
        self._next_conn = 0             #: guarded_by self._lock
        self._threads: list[threading.Thread] = []  #: guarded_by self._lock
        self.frames_served = 0          #: guarded_by self._lock
        self.bytes_served = 0           #: guarded_by self._lock
        sock = socket.socket(socket.AF_INET, socket.SOCK_STREAM)
        try:
            sock.setsockopt(socket.SOL_SOCKET, socket.SO_REUSEADDR, 1)
            sock.bind((host, port))
            sock.listen(32)
        except BaseException:
            sock.close()
            raise
        self._sock = sock
        self.host, self.port = sock.getsockname()[:2]
        self._accept_thread = threading.Thread(
            target=self._accept_loop, daemon=True, name="repro-wire-accept")
        self._accept_thread.start()

    @property
    def addr(self) -> tuple:
        return (self.host, self.port)

    def _accept_loop(self) -> None:
        while True:
            try:
                conn, _ = self._sock.accept()
            except OSError:
                return                  # listener closed -> shut down
            alive = self._adopt(conn)
            if not alive:
                return

    def _adopt(self, conn: socket.socket) -> bool:
        """Take ownership of an accepted conn: register it and spawn its
        serve thread, or close it if the server already shut down."""
        with self._lock:
            if self._closed:
                conn.close()
                return False
            cid = self._next_conn
            self._next_conn += 1
            self._conns[cid] = conn
            t = threading.Thread(target=self._serve, args=(conn, cid),
                                 daemon=True,
                                 name=f"repro-wire-serve-{cid}")
            self._threads.append(t)
        t.start()
        return True

    def _reply_layer(self, conn: socket.socket, key: int, layer: int) -> None:
        k, v = self.backend.read_layer(key, layer)
        frame = encode_frame(MSG_LAYER, pack_layer(key, layer, k, v))
        if self.stall_s:
            time.sleep(self.stall_s)
        torn = False
        if self.mangle is not None:
            mangled = self.mangle(frame)
            if mangled is None:         # simulated death mid-block
                raise OSError("mangle dropped the connection")
            # a SHORTENED frame is a tear at a byte boundary: send the
            # partial bytes then kill the stream, so the client sees
            # exactly what a mid-frame crash produces (partial + EOF)
            torn = len(mangled) != len(frame)
            frame = mangled
        conn.sendall(frame)
        with self._lock:
            self.frames_served += 1
            self.bytes_served += len(frame)
        if torn:
            raise OSError("mangle tore the stream mid-frame")

    def _serve(self, conn: socket.socket, cid: int) -> None:
        reader = FrameReader()
        try:
            conn.settimeout(self.timeout)
            while True:
                data = conn.recv(_RECV_CHUNK)
                if not data:
                    return
                for mtype, payload in reader.feed(data):
                    if mtype == MSG_GEOM:
                        try:
                            L = self.backend.n_layers
                        except PeerError as e:
                            conn.sendall(encode_frame(MSG_ERR, _pack_json(
                                dict(code=fallback_reason(e), msg=str(e)))))
                            continue
                        conn.sendall(encode_frame(
                            MSG_OK, _pack_json(dict(n_layers=L))))
                    elif mtype == MSG_FETCH_LAYER:
                        req = _unpack_json(payload)
                        try:
                            self._reply_layer(conn, int(req["key"]),
                                              int(req["layer"]))
                        except PeerError as e:
                            conn.sendall(encode_frame(MSG_ERR, _pack_json(
                                dict(code=fallback_reason(e), msg=str(e)))))
                    else:
                        conn.sendall(encode_frame(MSG_ERR, _pack_json(
                            dict(code="peer_fetch_failed",
                                 msg=f"unknown request type {mtype}"))))
        except (OSError, PeerError):
            return                      # torn request stream / dead client
        finally:
            conn.close()
            with self._lock:
                self._conns.pop(cid, None)

    def stats(self) -> dict:
        with self._lock:
            return dict(frames_served=self.frames_served,
                        bytes_served=self.bytes_served,
                        open_conns=len(self._conns))

    def close(self) -> None:
        """Deterministic shutdown: stop accepting, drop every open
        connection, join every serve thread. Idempotent."""
        with self._lock:
            if self._closed:
                return
            self._closed = True
            conns = list(self._conns.values())
            threads = list(self._threads)
        try:
            # closing the fd alone does NOT wake a thread blocked in
            # accept() on Linux; shutdown makes accept raise immediately
            self._sock.shutdown(socket.SHUT_RDWR)
        except OSError:
            pass
        self._sock.close()
        for conn in conns:
            try:
                conn.shutdown(socket.SHUT_RDWR)
            except OSError:
                pass
            conn.close()
        self._accept_thread.join()
        for t in threads:
            t.join()


# ---------------------------------------------------------------------------
# socket peer (the fetching side)
# ---------------------------------------------------------------------------


class SocketPeer:
    """Client over a peer's ``BlockServer`` — the socket-backed peer type
    for ``HostKVPool.add_peer``.

    Duck-types ``InProcPeer`` (``n_layers`` + ``read_layer`` raising the
    shared taxonomy), so the engine's ``PeerSource``/``AsyncPrefetcher``
    stream remote blocks through the same layer-major queue with zero
    changes.  Connections are lazy and re-established per call after a
    failure; a ``TornFrame`` drops the (desynced) connection before
    re-raising.  ``bw_ema`` is the measured payload bandwidth — what the
    Messenger's link calibration feeds on."""

    def __init__(self, addr, node=None, timeout: float = 5.0) -> None:
        self.addr = (addr[0], int(addr[1]))
        self.node = node
        self.timeout = timeout
        self._lock = threading.Lock()
        self._conn: Optional[FrameConn] = None  #: guarded_by self._lock
        self._n_layers: Optional[int] = None
        self.layer_reads = 0
        self.bytes_read = 0
        self._bw_ema: Optional[float] = None    # measured payload bytes/s

    # ---- connection management ----------------------------------------
    def _ensure_locked(self) -> FrameConn:
        if self._conn is None:
            try:
                sock = socket.create_connection(self.addr,
                                                timeout=self.timeout)
            except OSError as e:
                raise PeerUnreachable(
                    f"cannot connect to {self.addr}: {e}") from None
            self._conn = FrameConn(sock, timeout=self.timeout)
        return self._conn

    def _drop_locked(self) -> None:
        if self._conn is not None:
            self._conn.close()
            self._conn = None

    def _rpc(self, mtype: int, payload: bytes):
        with self._lock:
            conn = self._ensure_locked()
            try:
                rtype, rpayload = conn.request(mtype, payload)
            except PeerError:
                self._drop_locked()     # dead or desynced either way
                raise
            if rtype == MSG_ERR:
                err = _unpack_json(rpayload)
                raise _ERR_TYPES.get(err.get("code"), PeerError)(
                    err.get("msg", "peer error"))
            return rtype, rpayload

    # ---- peer protocol -------------------------------------------------
    @property
    def n_layers(self) -> int:
        if self._n_layers is None:
            rtype, payload = self._rpc(MSG_GEOM, b"")
            if rtype != MSG_OK:
                raise TornFrame(f"GEOM answered with frame type {rtype}")
            self._n_layers = int(_unpack_json(payload).get("n_layers", 0))
        return self._n_layers

    def read_layer(self, key: int, layer: int):
        t0 = time.monotonic()
        rtype, payload = self._rpc(
            MSG_FETCH_LAYER, _pack_json(dict(key=int(key), layer=int(layer))))
        if rtype != MSG_LAYER:
            with self._lock:
                self._drop_locked()
            raise TornFrame(f"FETCH_LAYER answered with frame type {rtype}")
        meta, k, v = unpack_layer(payload)
        if meta["key"] != int(key) or meta["layer"] != int(layer):
            with self._lock:
                self._drop_locked()
            raise TornFrame(
                f"layer frame for ({meta['key']}, {meta['layer']}) "
                f"answered a fetch of ({key}, {layer})")
        dt = time.monotonic() - t0
        nbytes = len(payload)
        self.layer_reads += 1
        self.bytes_read += nbytes
        if dt > 0:
            bw = nbytes / dt
            self._bw_ema = bw if self._bw_ema is None \
                else 0.7 * self._bw_ema + 0.3 * bw
        return k, v

    @property
    def bw_ema(self) -> Optional[float]:
        """Measured wire bandwidth (payload bytes/s EMA; None until the
        first read) — feed it to ``Messenger.set_link_bw`` to calibrate
        the peer-fetch arm against reality instead of the spec sheet."""
        return self._bw_ema

    def close(self) -> None:
        with self._lock:
            self._drop_locked()


_ERR_TYPES = {
    "peer_unreachable": PeerUnreachable,
    "stale_directory": StaleDirectory,
    "verify_failed": TornFrame,
    "torn_frame": TornFrame,
}


# ---------------------------------------------------------------------------
# standalone block node (no jax): the chaos harness's kill -9 target
# ---------------------------------------------------------------------------


def main(argv=None) -> int:
    import argparse
    import sys

    ap = argparse.ArgumentParser(
        prog="python -m repro.serving.transport",
        description="standalone block node: serve an existing "
                    "SSDBlockStore directory over the wire protocol")
    ap.add_argument("--store", required=True,
                    help="SSDBlockStore directory to serve (read-only use)")
    ap.add_argument("--port", type=int, default=0)
    ap.add_argument("--node-id", type=int, default=0)
    ap.add_argument("--directory", default=None,
                    help="host:port of the directory service to HELLO and "
                         "publish this store's keys to")
    ap.add_argument("--tier", default="ssd", choices=("dram", "ssd"))
    ap.add_argument("--stall", type=float, default=0.0,
                    help="seconds to stall before every LAYER frame "
                         "(chaos-window widening)")
    args = ap.parse_args(argv)

    from repro.serving.ssd_store import SSDBlockStore
    store = SSDBlockStore(args.store)
    server = BlockServer(StorePeer(store), port=args.port,
                         stall_s=args.stall)
    rdir = None
    if args.directory:
        from repro.serving.directory_service import RemoteDirectory
        host, port = args.directory.rsplit(":", 1)
        rdir = RemoteDirectory((host, int(port)), node_id=args.node_id,
                               block_port=server.port)
        for key in store.keys():
            rdir.register(key, args.node_id, args.tier)
    print(f"PORT {server.port}", flush=True)
    try:
        threading.Event().wait()        # until SIGTERM/SIGKILL
    except KeyboardInterrupt:
        pass
    finally:
        server.close()
        if rdir is not None:
            rdir.close()
        store.close()
    return 0


if __name__ == "__main__":
    raise SystemExit(main())
