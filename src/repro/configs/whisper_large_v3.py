"""Whisper-large-v3 — encoder-decoder transformer backbone; mel/conv frontend
is the sanctioned stub supplying frame embeddings. [arXiv:2212.04356]

Simplification noted in DESIGN.md: RoPE + RMSNorm are used in place of
Whisper's sinusoidal/learned positions + LayerNorm (dummy-model spirit — the
serving-system behaviour under study does not depend on the norm flavour).
"""
from repro.configs.base import ModelConfig, register

CONFIG = register(ModelConfig(
    name="whisper-large-v3",
    kind="audio",
    n_layers=32,            # decoder layers
    encoder_layers=32,
    cross_attention=True,
    d_model=1280,
    n_heads=20,
    n_kv_heads=20,
    head_dim=64,
    d_ff=5120,
    vocab_size=51866,
    frontend="audio",
    frontend_tokens=1500,   # encoder frames after the conv stub
    rope_theta=1e4,
    max_decode_len=448,     # architectural decoder cap → long_500k skipped
    source="arXiv:2212.04356 (assignment: 32L d1280 20H enc-dec, conv stub)",
))
