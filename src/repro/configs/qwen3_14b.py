"""Qwen3-14B (qk_norm, GQA). [hf:Qwen/Qwen3-8B family]"""
from repro.configs.base import ModelConfig, register

CONFIG = register(ModelConfig(
    name="qwen3-14b",
    kind="dense",
    n_layers=40,
    d_model=5120,
    n_heads=40,
    n_kv_heads=8,
    head_dim=128,
    d_ff=17408,
    vocab_size=151936,
    qk_norm=True,
    rope_theta=1e6,
    source="hf:Qwen/Qwen3-8B (assignment: 40L d5120 40H kv8 qk_norm)",
))
