"""CLEAN fixture: correct lock discipline for guarded-by. Parsed by
replint only — never imported."""
import threading


class Pool:
    def __init__(self):
        self._lock = threading.RLock()
        self.refs = [0] * 8          #: guarded_by self._lock
        #: guarded_by self._lock
        self.stats = dict(allocs=0)
        self.hint = 0                # unannotated: free to race

    def guarded_read(self):
        with self._lock:
            return sum(self.refs)

    def guarded_write(self):
        with self._lock:
            self.stats["allocs"] += 1
            return self.refs[0]

    def _sweep_locked(self):
        # _locked suffix: the caller holds self._lock by convention
        return [r for r in self.refs if r > 0]

    def unrelated(self):
        return self.hint + 1

    def __del__(self):
        self.refs.clear()            # teardown is single-threaded


class Prefetcher:
    def __init__(self):
        self._lock = threading.Lock()
        self._closed = False         #: guarded_by self._lock
        self.queue = []

    def enqueue(self, task):
        with self._lock:
            if self._closed:
                raise RuntimeError("closed")
            self.queue.append(task)

    def close(self):
        with self._lock:
            self._closed = True
