"""socket-pair: every socket acquired must reach close() on all paths.

The wire-protocol sibling of ``refcount-pair``: a statement that
acquires an OS socket —

    sock = socket.socket(socket.AF_INET, socket.SOCK_STREAM)
    conn = socket.create_connection(addr)
    a, b = socket.socketpair()
    conn, peer = listener.accept()

— must, on EVERY exit path including exceptions, either close it
(``close``/``detach``) or transfer ownership.  A leaked socket is a
leaked fd: the chaos harness's conftest detector catches it dynamically,
this rule catches the shape statically.  Accepted transfers:

  * return the socket;
  * park it in an object/structure whose lifecycle owns it
    (``self._conns[cid] = conn``);
  * pass it as an ARGUMENT to a call — handing the conn to a
    ``FrameConn``, a serve thread, or an adopt method makes the callee
    the owner (the receiver position does not transfer: ``conn.recv()``
    still leaves you holding it);
  * a ``with`` acquisition (never flagged: the context manager closes);
  * a ``try`` whose ``finally`` closes, or whose handlers ALL close and
    include a catch-all.

Like refcount-pair, a single linear path from the acquire to a
close/transfer must have no statement in between that can raise or
branch away.  Acquires via ``self.X()`` calls are exempt (a class's own
``accept``-like primitive, covered by its own tests).
"""
from __future__ import annotations

import ast

from tools.replint.core import (Finding, ModuleCtx, dotted, functions_in,
                                names_in, own_nodes)
# the CFG walk and try-protection analysis are shape-generic; reuse the
# refcount-pair machinery rather than fork it
from tools.replint.refcount import (_SAFE_BUILTINS, _SAFE_METHODS, _Blocks,
                                    _is_catchall)

RULE = "socket-pair"

# module-level constructors (matched as dotted names) and the accept verb
_MODULE_ACQUIRES = {"socket.socket", "socket.create_connection",
                    "socket.socketpair"}
_BARE_ACQUIRES = {"create_connection", "socketpair"}
ACQUIRE_VERB = "accept"
RELEASE = {"close", "detach"}


def _acquire_call(stmt) -> ast.Call | None:
    """The socket-acquiring Call in an Assign/AnnAssign/Expr statement,
    if any.  ``with socket.create_connection(...) as s:`` is not an
    Assign/Expr and is never flagged — the context manager closes."""
    value = getattr(stmt, "value", None)
    if not isinstance(stmt, (ast.Assign, ast.AnnAssign, ast.Expr)) \
            or value is None:
        return None
    for node in ast.walk(value):
        if not isinstance(node, ast.Call):
            continue
        f = node.func
        if isinstance(f, ast.Name) and f.id in _BARE_ACQUIRES:
            return node
        if not isinstance(f, ast.Attribute):
            continue
        if dotted(f) in _MODULE_ACQUIRES:
            return node
        if f.attr == ACQUIRE_VERB:
            recv = f.value
            if isinstance(recv, ast.Name) and recv.id == "self":
                continue                # a class's own accept primitive
            return node
    return None


def _held_names(stmt) -> set[str]:
    if isinstance(stmt, ast.Assign):
        out = set()
        for t in stmt.targets:
            out |= names_in(t)
        return out
    if isinstance(stmt, ast.AnnAssign):
        return names_in(stmt.target)
    return set()                        # bare Expr: the fd is discarded


def _is_release_call(node) -> bool:
    return (isinstance(node, ast.Call)
            and isinstance(node.func, ast.Attribute)
            and node.func.attr in RELEASE)


def _contains_release(stmts) -> bool:
    for s in stmts:
        for node in ast.walk(s):
            if _is_release_call(node):
                return True
    return False


def _try_protects(tr: ast.Try) -> bool:
    if _contains_release(tr.finalbody):
        return True
    return bool(tr.handlers) \
        and all(_contains_release(h.body) for h in tr.handlers) \
        and any(_is_catchall(h) for h in tr.handlers)


def _call_arg_transfer(node, held: set[str]) -> bool:
    """A held socket passed as an ARGUMENT (not the receiver) hands
    ownership to the callee: FrameConn(sock), Thread(args=(conn,)),
    self._adopt(conn)."""
    if not isinstance(node, ast.Call):
        return False
    for a in list(node.args) + [kw.value for kw in node.keywords]:
        if names_in(a) & held:
            return True
    return False


def _stmt_satisfies(stmt, held: set[str]) -> bool:
    """Does this statement close or transfer the held socket?"""
    if isinstance(stmt, ast.Return) and stmt.value is not None \
            and names_in(stmt.value) & held:
        return True
    value = getattr(stmt, "value", None)
    if isinstance(stmt, (ast.Expr, ast.Assign)) and value is not None:
        for node in ast.walk(value):
            if _is_release_call(node) and names_in(node) & held:
                return True
            if _call_arg_transfer(node, held):
                return True
    if isinstance(stmt, ast.Assign) and names_in(stmt.value) & held:
        # parked in a structure the owner closes (conn registry)
        if any(isinstance(t, (ast.Attribute, ast.Subscript))
               for t in stmt.targets):
            return True
    if isinstance(stmt, ast.Try) and _try_protects(stmt):
        return True
    return False


def _stmt_aliases(stmt, held: set[str]) -> set[str]:
    if isinstance(stmt, ast.Assign) and names_in(stmt.value) & held:
        return {t.id for t in stmt.targets if isinstance(t, ast.Name)}
    return set()


def _stmt_risky(stmt) -> str | None:
    """Reason this statement can raise or branch away, else None."""
    if isinstance(stmt, (ast.If, ast.For, ast.While, ast.With,
                         ast.AsyncWith, ast.AsyncFor, ast.Try,
                         ast.Match)):
        return "control flow"
    if isinstance(stmt, ast.Raise):
        return "raise"
    if isinstance(stmt, ast.Assert):
        return "assert"
    if isinstance(stmt, (ast.Break, ast.Continue)):
        return "loop exit"
    for node in ast.walk(stmt):
        if not isinstance(node, ast.Call):
            continue
        f = node.func
        if isinstance(f, ast.Name) and f.id in _SAFE_BUILTINS:
            continue
        if isinstance(f, ast.Attribute) and f.attr in _SAFE_METHODS:
            continue
        if _is_release_call(node):
            continue
        name = f.attr if isinstance(f, ast.Attribute) else \
            getattr(f, "id", "call")
        return f"call to {name}()"
    return None


def _satisfies_anywhere(stmt, held: set[str]) -> bool:
    if _stmt_satisfies(stmt, held):
        return True
    for node in ast.walk(stmt):
        if isinstance(node, ast.stmt) and node is not stmt \
                and _stmt_satisfies(node, held):
            return True
    return False


def check(ctx: ModuleCtx) -> list[Finding]:
    findings: list[Finding] = []
    for func in functions_in(ctx.tree):
        blocks = None
        for stmt in [n for n in own_nodes(func) if isinstance(n, ast.stmt)]:
            call = _acquire_call(stmt)
            if call is None:
                continue
            if blocks is None:
                blocks = _Blocks(func)
            f = call.func
            verb = f.attr if isinstance(f, ast.Attribute) else f.id
            what = f"socket acquired via .{verb}()"
            held = _held_names(stmt)
            if not held:
                findings.append(Finding(
                    ctx.path, stmt.lineno, RULE,
                    f"{what} is discarded: the fd is never bound, so it "
                    f"can never be closed"))
                continue
            exception_safe = any(_try_protects(tr) for tr in
                                 blocks.enclosing_trys(stmt, func))
            satisfied = False
            risky_reason = None
            risky_line = None
            for nxt in blocks.path_after(stmt, func):
                if _satisfies_anywhere(nxt, held) if exception_safe \
                        else _stmt_satisfies(nxt, held):
                    satisfied = True
                    break
                held |= _stmt_aliases(nxt, held)
                if not exception_safe and risky_reason is None:
                    r = _stmt_risky(nxt)
                    if r is not None:
                        risky_reason, risky_line = r, nxt.lineno
            if satisfied and risky_reason is None:
                continue
            if risky_reason is not None:
                findings.append(Finding(
                    ctx.path, stmt.lineno, RULE,
                    f"{what} can leak: {risky_reason} at line "
                    f"{risky_line} may raise or branch before the fd is "
                    f"closed or ownership is transferred -- wrap in "
                    f"try/finally (or handlers that all close and "
                    f"include a catch-all)"))
            else:
                findings.append(Finding(
                    ctx.path, stmt.lineno, RULE,
                    f"{what} never reaches close() or an ownership "
                    f"transfer on the fall-through path"))
    return findings
