"""Figure 7: latency of storing KVCache for different request lengths —
layer-wise (overlapped) prefill vs store-after-compute, plus the exposed
'layer-wise latency' overhead the paper plots."""
from __future__ import annotations

from benchmarks.common import emit
from repro.configs.base import get_config
from repro.serving.layerwise import occupation_cost, schedule


def main(fast: bool = False):
    cfg = get_config("llama2-70b")
    rows = []
    for L in (2048, 4096, 8192, 16384, 32768, 65536, 131072):
        tl = schedule(cfg, L)
        no_store = tl.t_compute_layer * tl.n_layers
        rows.append(dict(
            input_tokens=L,
            prefill_no_store_s=round(no_store, 3),
            layerwise_s=round(tl.total_overlapped, 3),
            serial_store_s=round(tl.total_serial, 3),
            layerwise_overhead_ms=round(
                (tl.total_overlapped - no_store) * 1e3, 2),
            store_hidden=tl.store_hidden,
        ))
    emit("fig7_layerwise_prefill", rows)

    oc_rows = []
    for L in (8192, 32768, 131072):
        oc = occupation_cost(cfg, L)
        oc_rows.append(dict(input_tokens=L,
                            kv_gb=round(oc["kv_bytes"] / 1e9, 2),
                            layerwise_gb_s=round(oc["layerwise_cost"] / 1e9, 1),
                            inline_gb_s=round(oc["inline_cost"] / 1e9, 1)))
    emit("sec52_occupation_cost", oc_rows)
    return rows


if __name__ == "__main__":
    main()
