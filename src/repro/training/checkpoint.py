"""Checkpointing: flat-name .npz snapshots of (params, opt_state, step).

Pytrees are flattened with jax.tree_util key paths as archive names, so a
checkpoint round-trips bit-exactly regardless of nesting, and partial
restores (params only) are possible. Atomic rename for crash safety.
"""
from __future__ import annotations

import os
import re
import tempfile
from typing import Any, Optional

import jax
import numpy as np


def _flatten(tree: Any, prefix: str) -> dict:
    flat = {}
    leaves = jax.tree_util.tree_flatten_with_path(tree)[0]
    for path, leaf in leaves:
        key = prefix + jax.tree_util.keystr(path)
        arr = np.asarray(leaf)
        if arr.dtype.kind == "V":   # bfloat16 → store as f32 (lossless)
            arr = np.asarray(leaf, dtype=np.float32)
        flat[key] = arr
    return flat


def _unflatten(tree: Any, prefix: str, archive) -> Any:
    paths, treedef = jax.tree_util.tree_flatten_with_path(tree)
    leaves = []
    for path, leaf in paths:
        key = prefix + jax.tree_util.keystr(path)
        stored = archive[key]
        leaves.append(jax.numpy.asarray(stored).astype(leaf.dtype))
    return jax.tree_util.tree_unflatten(treedef, leaves)


def save_checkpoint(dir_: str, params, opt_state, step: int) -> str:
    os.makedirs(dir_, exist_ok=True)
    flat = {"__step__": np.asarray(step)}
    flat.update(_flatten(params, "p"))
    flat.update(_flatten(opt_state, "o"))
    path = os.path.join(dir_, f"ckpt_{step:08d}.npz")
    fd, tmp = tempfile.mkstemp(dir=dir_, suffix=".tmp")
    os.close(fd)
    np.savez(tmp, **flat)
    os.replace(tmp if tmp.endswith(".npz") else tmp + ".npz"
               if os.path.exists(tmp + ".npz") else tmp, path)
    return path


def latest_checkpoint(dir_: str) -> Optional[str]:
    if not os.path.isdir(dir_):
        return None
    ckpts = sorted(f for f in os.listdir(dir_)
                   if re.match(r"ckpt_\d+\.npz$", f))
    return os.path.join(dir_, ckpts[-1]) if ckpts else None


def load_checkpoint(dir_: str, params, opt_state):
    """Restore into the given (shape-matched) pytrees.
    Returns (params, opt_state, step) or None if no checkpoint."""
    path = latest_checkpoint(dir_)
    if path is None:
        return None
    with np.load(path) as z:
        step = int(z["__step__"])
        params = _unflatten(params, "p", z)
        opt_state = _unflatten(opt_state, "o", z)
    return params, opt_state, step
