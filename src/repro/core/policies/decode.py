"""Built-in decode-placement policies.

``min_tbt`` is the paper's SelectDecodingInstance: among instances with
VRAM headroom, the one whose predicted TBT after joining is lowest.

``kv_pressure`` additionally penalises placement by per-node KVCache
occupancy — and, crucially, its occupancy term ALWAYS counts pending
(accepted-but-still-prefilling) commitments, independent of the
``accounting`` knob. The knob reproduces the §7.2 time-lag ablation in
the TBT *estimate*; occupancy is about future VRAM pressure, where a
committed request consumes bytes whether or not it has started decoding.
Under naive ("current") accounting min_tbt piles concurrent arrivals
onto the momentarily-emptiest node; kv_pressure's lag-free pressure term
spreads them, so fewer later arrivals bounce off the ``vram_ok`` gate in
KV-heavy regimes. The returned TBT stays the honest ``predicted_tbt``
(SLO checks see latency, not the shaped score), mirroring the
Arm.score / Arm.ttft split.

``include_pending`` is the Conductor's ``accounting`` knob (§7.2): the
naive baseline pre-selects on the CURRENT decode state only — accepted
requests still prefilling are invisible (the time lag that causes wasted
prefill) — while pending-aware accounting counts in-flight commitments.
"""
from __future__ import annotations

from repro.core.policies.base import PolicyContext, register_policy


@register_policy("decode", "min_tbt")
class MinTBTDecode:
    def __init__(self, ctx: PolicyContext) -> None:
        self.ctx = ctx

    def select(self, req, instances, now, include_pending: bool = True):
        tokens = req.input_length + req.output_length
        ok = [d for d in instances if d.vram_ok(tokens, include_pending)]
        if not ok:
            return None, float("inf")
        d = min(ok, key=lambda d: d.predicted_tbt(
            1, tokens, include_pending=include_pending))
        return d, d.predicted_tbt(1, tokens, include_pending=include_pending)


@register_policy("decode", "kv_pressure")
class KVPressureDecode:
    """min_tbt shaped by per-node KV occupancy (see module docstring)."""

    alpha = 4.0     # quadratic penalty weight: mild until ~50% occupancy

    def __init__(self, ctx: PolicyContext) -> None:
        self.ctx = ctx

    def _occupancy(self, d, tokens: float) -> float:
        # pending commitments always count: bytes are promised to the node
        # regardless of the §7.2 accounting knob (see module docstring)
        held = d.kv_tokens + tokens + d.pending_tokens
        return held / max(d.cost.decode_capacity_tokens(), 1.0)

    def select(self, req, instances, now, include_pending: bool = True):
        tokens = req.input_length + req.output_length
        ok = [d for d in instances if d.vram_ok(tokens, include_pending)]
        if not ok:
            return None, float("inf")

        def score(d) -> float:
            tbt = d.predicted_tbt(1, tokens, include_pending=include_pending)
            occ = self._occupancy(d, tokens)
            return tbt * (1.0 + self.alpha * occ * occ) + 1e-9 * occ

        d = min(ok, key=score)
        return d, d.predicted_tbt(1, tokens, include_pending=include_pending)
