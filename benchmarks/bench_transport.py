"""Wire-protocol microbench: codec throughput and FETCH_BLOCK bandwidth,
socket vs in-process.

Three measured rows (no jax on this path — pure transport):

* ``codec``   — encode_frame + FrameReader decode of LAYER frames in a
  tight loop: the CRC-framing overhead ceiling, in MB/s.
* ``inproc``  — the same blocks read through the in-process peer surface
  (direct ``read_layer`` calls): what PR-8's "peer fetch" cost.
* ``socket``  — the same blocks streamed through a real ``BlockServer``/
  ``SocketPeer`` pair over loopback, layer-major like the prefetcher.

Asserts socket bytes are bit-exact vs the in-process reads (the
transport's whole contract) and that the in-process path is faster (it
skips the kernel); the absolute socket bandwidth row is what
``Messenger.set_link_bw`` calibration feeds on, so it is reported, not
gated — wall-clock numbers are machine-dependent.

    PYTHONPATH=src python -m benchmarks.bench_transport [--fast|--quick]
"""
from __future__ import annotations

import time

import numpy as np

from benchmarks.common import emit
from repro.serving.transport import (BlockServer, FrameReader, SocketPeer,
                                     encode_frame, pack_layer, unpack_layer)


class _SyntheticBackend:
    """Deterministic per-(key, layer) KV arrays, generated on demand."""

    def __init__(self, n_layers: int, shape: tuple) -> None:
        self.n_layers = n_layers
        self.shape = shape

    def read_layer(self, key: int, layer: int):
        rng = np.random.default_rng(100_003 * key + layer)
        k = rng.standard_normal(self.shape).astype(np.float32)
        return k, k + 1.0


def _bench_codec(backend, keys, repeats: int) -> dict:
    frames = [encode_frame(3, pack_layer(key, layer,
                                         *backend.read_layer(key, layer)))
              for key in keys for layer in range(backend.n_layers)]
    nbytes = sum(len(f) for f in frames)
    t0 = time.perf_counter()
    for _ in range(repeats):
        reader = FrameReader()
        for f in frames:
            ((_, payload),) = reader.feed(f)
            unpack_layer(payload)
    dt = time.perf_counter() - t0
    return dict(path="codec", blocks=len(keys), layers=backend.n_layers,
                mb=nbytes * repeats / 1e6, s=dt,
                mb_per_s=nbytes * repeats / 1e6 / dt)


def _bench_inproc(backend, keys) -> tuple[dict, int, list]:
    out = []
    nbytes = 0
    t0 = time.perf_counter()
    for key in keys:
        for layer in range(backend.n_layers):
            k, v = backend.read_layer(key, layer)
            nbytes += k.nbytes + v.nbytes
            out.append((k, v))
    dt = time.perf_counter() - t0
    row = dict(path="inproc", blocks=len(keys), layers=backend.n_layers,
               mb=nbytes / 1e6, s=dt, mb_per_s=nbytes / 1e6 / dt)
    return row, nbytes, out


def _bench_socket(backend, keys) -> tuple[dict, list]:
    server = BlockServer(backend)
    peer = SocketPeer(server.addr, node=0, timeout=30.0)
    out = []
    try:
        peer.read_layer(keys[0], 0)     # connect + warm outside the clock
        t0 = time.perf_counter()
        for key in keys:
            for layer in range(backend.n_layers):
                out.append(peer.read_layer(key, layer))
        dt = time.perf_counter() - t0
        nbytes = sum(k.nbytes + v.nbytes for k, v in out)
        row = dict(path="socket", blocks=len(keys), layers=backend.n_layers,
                   mb=nbytes / 1e6, s=dt, mb_per_s=nbytes / 1e6 / dt,
                   bw_ema_mb_s=(peer.bw_ema or 0.0) / 1e6)
    finally:
        peer.close()
        server.close()
    return row, out


def main(fast: bool = False) -> None:
    n_layers = 4 if fast else 8
    shape = (1, 256 if fast else 512, 64)
    keys = list(range(4 if fast else 16))
    backend = _SyntheticBackend(n_layers, shape)

    rows = [_bench_codec(backend, keys, repeats=2 if fast else 5)]
    inproc_row, _, inproc_kv = _bench_inproc(backend, keys)
    socket_row, socket_kv = _bench_socket(backend, keys)
    rows += [inproc_row, socket_row]
    emit("transport_wire", rows)

    # the contract: the wire delivers exactly the in-process bytes
    assert len(inproc_kv) == len(socket_kv)
    for (k1, v1), (k2, v2) in zip(inproc_kv, socket_kv):
        assert np.array_equal(k1, k2) and np.array_equal(v1, v2), \
            "socket fetch is not bit-exact vs in-process"
    assert inproc_row["mb_per_s"] > socket_row["mb_per_s"], \
        "in-process reads should beat loopback sockets"
    print(f"[transport] socket {socket_row['mb_per_s']:.0f} MB/s vs "
          f"inproc {inproc_row['mb_per_s']:.0f} MB/s -- bit-exact")


if __name__ == "__main__":
    import argparse
    ap = argparse.ArgumentParser()
    ap.add_argument("--fast", "--quick", dest="fast", action="store_true")
    main(**vars(ap.parse_args()))
