"""Per-architecture smoke tests (reduced variants, one forward/train step
on CPU, output shapes + no NaNs) and decode-path consistency."""
import jax
import jax.numpy as jnp
import numpy as np
import pytest

from repro.configs.base import get_config, list_configs
from repro.models.transformer import (decode_step, init_caches, init_params,
                                      loss_fn, prefill)

ARCHS = list_configs()


def _batch(cfg, key, B=2, S=64):
    tokens = jax.random.randint(key, (B, S), 0, cfg.vocab_size)
    batch = {"tokens": tokens, "labels": tokens}
    kw = {}
    if cfg.frontend == "patch":
        kw["patches"] = jax.random.normal(
            key, (B, cfg.frontend_tokens, cfg.d_model))
        batch["patches"] = kw["patches"]
    if cfg.frontend == "audio":
        kw["frames"] = jax.random.normal(
            key, (B, cfg.frontend_tokens, cfg.d_model))
        batch["frames"] = kw["frames"]
    return batch, kw


@pytest.mark.parametrize("name", ARCHS)
def test_smoke_train_step(name, rng_key):
    """One train step (forward + backward + update) on the reduced config."""
    from repro.training.optim import make_optimizer
    cfg = get_config(name).reduced()
    params = init_params(cfg, rng_key)
    batch, _ = _batch(cfg, rng_key)
    opt_init, opt_update = make_optimizer(cfg.optimizer)
    opt = opt_init(params)

    @jax.jit
    def step(p, o, b):
        loss, g = jax.value_and_grad(lambda p_: loss_fn(p_, b, cfg))(p)
        p2, o2 = opt_update(p, g, o)
        return loss, p2, o2

    loss, params2, _ = step(params, opt, batch)
    assert jnp.isfinite(loss), f"{name} loss NaN"
    assert 2.0 < float(loss) < 12.0
    # parameters actually moved
    moved = jax.tree.map(lambda a, b: float(jnp.abs(
        a.astype(jnp.float32) - b.astype(jnp.float32)).max()),
        params, params2)
    assert max(jax.tree.leaves(moved)) > 0


@pytest.mark.parametrize("name", ARCHS)
def test_smoke_prefill_and_decode(name, rng_key):
    cfg = get_config(name).reduced()
    params = init_params(cfg, rng_key)
    B, S = 2, 64
    batch, kw = _batch(cfg, rng_key, B, S)
    logits, caches = jax.jit(
        lambda p, t: prefill(p, t, cfg, **kw))(params, batch["tokens"])
    assert logits.shape == (B, cfg.padded_vocab)
    assert bool(jnp.all(jnp.isfinite(logits)))
    # VLMs prepend patch embeddings to the sequence
    S_eff = S + (cfg.frontend_tokens if cfg.frontend == "patch" else 0)
    assert int(caches.length) == S_eff

    # decode one token against a padded cache
    full = init_caches(cfg, B, S_eff + 8, enc_len=cfg.frontend_tokens
                       if cfg.encoder_layers else 0)
    kv = full.kv
    if kv is not None:
        sl = (slice(None), slice(None), slice(0, S_eff))
        kv = kv._replace(k=kv.k.at[sl].set(caches.kv.k),
                         v=kv.v.at[sl].set(caches.kv.v))
    full = full._replace(kv=kv, ssm=caches.ssm if caches.ssm is not None
                         else full.ssm, enc_kv=caches.enc_kv,
                         length=caches.length)
    tok = jnp.argmax(logits, -1)[:, None].astype(jnp.int32)
    lg, full2 = jax.jit(
        lambda p, t, c: decode_step(p, t, c, cfg))(params, tok, full)
    assert lg.shape == (B, 1, cfg.padded_vocab)
    assert bool(jnp.all(jnp.isfinite(lg)))
    assert int(full2.length) == S_eff + 1


@pytest.mark.parametrize("name", ["smollm-360m", "mamba2-2.7b",
                                  "jamba-1.5-large-398b"])
def test_incremental_decode_matches_prefill(name, rng_key):
    """prefill(S) ≡ prefill(S-k) + k decode steps (greedy path identical)."""
    cfg = get_config(name).reduced()
    params = init_params(cfg, rng_key)
    B, S, k = 1, 48, 4
    tokens = jax.random.randint(rng_key, (B, S), 0, cfg.vocab_size)

    logits_full, _ = jax.jit(lambda p, t: prefill(p, t, cfg))(params, tokens)

    logits_pre, caches = jax.jit(
        lambda p, t: prefill(p, t, cfg))(params, tokens[:, :S - k])
    full = init_caches(cfg, B, S)
    if full.kv is not None:
        full = full._replace(kv=full.kv._replace(
            k=full.kv.k.at[:, :, :S - k].set(caches.kv.k),
            v=full.kv.v.at[:, :, :S - k].set(caches.kv.v)))
    if caches.ssm is not None:
        full = full._replace(ssm=caches.ssm)
    full = full._replace(length=caches.length)
    step = jax.jit(lambda p, t, c: decode_step(p, t, c, cfg))
    lg = None
    for i in range(S - k, S):
        lg, full = step(params, tokens[:, i:i + 1], full)
    np.testing.assert_allclose(
        np.asarray(lg[:, -1]), np.asarray(logits_full),
        rtol=0.15, atol=0.15)
    # greedy argmax must agree exactly
    assert int(jnp.argmax(lg[:, -1])) == int(jnp.argmax(logits_full))


def test_sliding_window_ring_decode(rng_key):
    """Windowed arch (mixtral-reduced): ring cache decode == linear cache
    decode with window masking."""
    cfg = get_config("mixtral-8x7b").reduced()
    assert cfg.sliding_window == 64
    params = init_params(cfg, rng_key)
    B, S = 1, 96   # context longer than the window
    tokens = jax.random.randint(rng_key, (B, S), 0, cfg.vocab_size)

    # linear cache, window-masked attention
    logits_lin, _ = jax.jit(
        lambda p, t: prefill(p, t, cfg))(params, tokens)

    # ring decode: prefill window-1 then feed rest one by one
    ring = init_caches(cfg, B, S, window=cfg.sliding_window)
    assert ring.kv.k.shape[2] == cfg.sliding_window
    pre = cfg.sliding_window
    _, caches = jax.jit(lambda p, t: prefill(p, t, cfg))(params,
                                                         tokens[:, :pre])
    ring = ring._replace(kv=ring.kv._replace(
        k=ring.kv.k.at[:, :, :pre].set(caches.kv.k[:, :, -pre:]),
        v=ring.kv.v.at[:, :, :pre].set(caches.kv.v[:, :, -pre:])),
        length=caches.length)
    step = jax.jit(lambda p, t, c: decode_step(p, t, c, cfg, ring=True))
    lg = None
    for i in range(pre, S):
        lg, ring = step(params, tokens[:, i:i + 1], ring)
    assert int(jnp.argmax(lg[0, -1])) == int(jnp.argmax(logits_lin[0]))


def test_loss_decreases_under_training(rng_key):
    from repro.training.loop import train
    cfg = get_config("smollm-360m").reduced()
    res = train(cfg, steps=30, batch=4, seq=128, log_every=0)
    first = sum(res.losses[:5]) / 5
    last = sum(res.losses[-5:]) / 5
    assert last < first - 0.05, (first, last)
