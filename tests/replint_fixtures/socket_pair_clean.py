"""CLEAN fixture: every socket acquire closes or transfers on all
paths. Parsed by replint only — never imported."""
import socket
import threading


def probe_with_finally(addr):
    s = socket.create_connection(addr, timeout=1.0)
    try:
        s.sendall(b"ping")
        return s.recv(16)
    finally:
        s.close()


def with_statement_owns(addr):
    # context-manager acquisition is never flagged: __exit__ closes
    with socket.create_connection(addr) as s:
        s.sendall(b"ping")
        return s.recv(16)


def bind_guard_then_park(self, host, port):
    # the BlockServer.__init__ shape: catch-all handler closes + re-raises,
    # then ownership parks in the instance
    sock = socket.socket(socket.AF_INET, socket.SOCK_STREAM)
    try:
        sock.bind((host, port))
        sock.listen(32)
    except BaseException:
        sock.close()
        raise
    self._sock = sock
    return self


def accept_loop_hands_off(listener, adopt):
    # the accept-loop shape: the conn is immediately handed to an owner
    while True:
        try:
            conn, _ = listener.accept()
        except OSError:
            return
        alive = adopt(conn)
        if not alive:
            return


def linear_park_in_registry(listener, conns, cid):
    conn, _ = listener.accept()
    conns[cid] = conn


def wrap_transfers_ownership(addr, timeout):
    sock = socket.create_connection(addr, timeout=timeout)
    return FramedConn(sock, timeout)


def spawn_thread_owner(listener):
    conn, _ = listener.accept()
    t = threading.Thread(target=_serve_one, args=(conn,), daemon=True)
    t.start()
    return t


def pair_returned_to_caller():
    a, b = socket.socketpair()
    return a, b


def handlers_all_close_with_catchall(addr):
    try:
        s = socket.create_connection(addr)
        s.sendall(b"x")
        return s.recv(4)
    except OSError:
        s.close()
        return None
    except BaseException:
        s.close()
        raise


def own_accept_primitive_is_exempt(self):
    # a class's accept() wrapper calling itself: covered by its tests
    conn = self.accept()
    conn.start()
    return None


class FramedConn:
    def __init__(self, sock, timeout):
        self.sock = sock
        self.timeout = timeout


def _serve_one(conn):
    conn.close()
