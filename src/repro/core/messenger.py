"""Messenger — the cross-machine KVCache transfer service (§3 step 3).

One Messenger per instance; transfers are point-to-point (sender-node
egress is the contended resource, matching the paper's congestion concern
in §6.1: "whether the sending node is under congestion"). We model each
node's egress link as a FIFO pipe of bandwidth ``bw``; a transfer of B
bytes enqueued at time t on a link whose backlog drains at time t' ≥ t
completes at max(t, t') + B/bw.

This same object answers Conductor's ``EstimateKVCacheTransferTime`` —
the estimate includes the current backlog, which is how congestion feeds
back into Algorithm 1's instance selection and drives hot-spot
replication (§6.2).

Nodes with a tiered DRAM+SSD pool additionally register an *SSD channel*
(``add_ssd_channel``): a per-node FIFO pipe at NVMe read bandwidth that
serialises SSD→DRAM prefix loads. Its backlog feeds the Conductor's
estimate for the third TTFT arm (load-from-SSD), so a node whose SSD is
busy loading one long prefix correctly looks expensive for the next one.
A cross-node peer-SSD fetch (the global pool's fourth arm) composes two
pipes serially — the owner's SSD read channel, then the owner's egress
link — via ``estimate_peer_ssd``/``enqueue_peer_ssd``.
"""
from __future__ import annotations

from dataclasses import dataclass, field


@dataclass
class Link:
    bw: float                   # bytes/s
    busy_until: float = 0.0     # time the current backlog drains
    bytes_sent: float = 0.0
    n_transfers: int = 0


class Messenger:
    """Transfer-time bookkeeping for a set of named nodes."""

    def __init__(self, node_ids, bw: float) -> None:
        self.links: dict = {i: Link(bw=bw) for i in node_ids}
        self.ssd_links: dict = {}

    def add_node(self, node_id, bw: float) -> None:
        self.links[node_id] = Link(bw=bw)

    def add_ssd_channel(self, node_id, read_bw: float) -> None:
        """Register a node's local SSD read pipe (tiered pools only)."""
        self.ssd_links[node_id] = Link(bw=read_bw)

    def has_ssd_channel(self, node_id) -> bool:
        return node_id in self.ssd_links

    # shared FIFO-pipe math (egress and SSD channels are the same model)
    @staticmethod
    def _estimate(link: Link, nbytes: float, now: float) -> float:
        return max(link.busy_until - now, 0.0) + nbytes / link.bw

    @staticmethod
    def _commit(link: Link, nbytes: float, now: float) -> float:
        start = max(link.busy_until, now)
        done = start + nbytes / link.bw
        link.busy_until = done
        link.bytes_sent += nbytes
        link.n_transfers += 1
        return done

    def estimate(self, src, nbytes: float, now: float) -> float:
        """Predicted transfer duration if enqueued now (queue + wire)."""
        return self._estimate(self.links[src], nbytes, now)

    def enqueue(self, src, nbytes: float, now: float) -> float:
        """Commit a transfer; returns its completion TIME."""
        return self._commit(self.links[src], nbytes, now)

    def congestion(self, src, now: float) -> float:
        """Seconds of backlog on a node's egress link."""
        return max(self.links[src].busy_until - now, 0.0)

    # ---- local SSD tier (same FIFO-pipe model, per-node read channel) ----
    def estimate_ssd(self, node, nbytes: float, now: float) -> float:
        """Predicted SSD-load duration if enqueued now (queue + media)."""
        link = self.ssd_links.get(node)
        if link is None:
            return float("inf")     # node has no SSD tier
        return self._estimate(link, nbytes, now)

    def enqueue_ssd(self, node, nbytes: float, now: float) -> float:
        """Commit an SSD load; returns its completion TIME."""
        return self._commit(self.ssd_links[node], nbytes, now)

    def set_ssd_bw(self, node, read_bw: float) -> None:
        """Recalibrate a node's SSD read channel to a MEASURED bandwidth
        (the serving engine feeds ``SSDBlockStore``'s read EMA back so the
        Conductor's load-arm estimates track reality, not the spec sheet)."""
        link = self.ssd_links.get(node)
        if link is None:
            self.add_ssd_channel(node, read_bw)
        else:
            link.bw = read_bw

    def set_link_bw(self, node, bw: float) -> None:
        """Recalibrate a node's EGRESS link to a MEASURED bandwidth — the
        wire-protocol counterpart of ``set_ssd_bw``. A multi-process
        cluster feeds ``SocketPeer.bw_ema`` (payload bytes/s actually
        observed on FETCH_BLOCK reads off that node) back here, so the
        peer-fetch arms price the socket the cluster really has, not the
        construction-time constant."""
        link = self.links.get(node)
        if link is None:
            self.add_node(node, bw)
        else:
            link.bw = bw

    # ---- cross-node SSD fetch (global pool: peer SSD read + egress hop) ----
    def estimate_peer_ssd(self, node, nbytes: float, now: float) -> float:
        """Predicted duration of fetching bytes OFF a peer's SSD: the
        peer's SSD read channel drains first, then the peer's egress link
        carries the bytes — two FIFO pipes composed serially, each with
        its current backlog."""
        link = self.ssd_links.get(node)
        if link is None:
            return float("inf")     # peer has no SSD tier
        t_read = self._estimate(link, nbytes, now)
        net = self.links[node]
        t_net = max(net.busy_until - (now + t_read), 0.0) + nbytes / net.bw
        return t_read + t_net

    def enqueue_peer_ssd(self, node, nbytes: float, now: float) -> float:
        """Commit a peer-SSD fetch; returns its completion TIME."""
        done_read = self._commit(self.ssd_links[node], nbytes, now)
        return self._commit(self.links[node], nbytes, done_read)
