"""Priority-aware overload admission (§10 extension)."""
import pytest

from repro.configs.base import get_config
from repro.core.simulator import MooncakeCluster
from repro.core.trace import TraceSpec, generate_trace


@pytest.fixture(scope="module")
def overloaded_trace():
    reqs = generate_trace(TraceSpec(n_requests=1200, duration_ms=200_000,
                                    seed=5, out_mu=5.9))
    # tag every 4th request high-priority
    for r in reqs:
        r.priority = 2 if r.req_id % 4 == 0 else 0
    return reqs


def run(trace, relief=0.5, **kw):
    cfg = get_config("llama2-70b")
    mc = MooncakeCluster(cfg, n_prefill=2, n_decode=2, ttft_slo=30,
                         tbt_slo=0.1, admission="early", **kw)
    mc.admission.priority_relief = relief
    return mc.run(trace, speedup=6.0)


def test_priority_shifts_rejections_to_best_effort(overloaded_trace):
    res = run(overloaded_trace)
    rej = [r for r in res.records if not r.accepted
           and r.reject_stage == "admission"]
    assert rej, "scenario must actually overload"
    hi_rej = sum(1 for r in rej if r.req.priority > 0)
    lo_rej = len(rej) - hi_rej
    n_hi = sum(1 for r in overloaded_trace if r.priority > 0)
    n_lo = len(overloaded_trace) - n_hi
    # rejection RATE of high-priority must be well below best-effort's
    assert hi_rej / n_hi < 0.5 * (lo_rej / n_lo)


def test_zero_relief_is_priority_blind(overloaded_trace):
    cfg = get_config("llama2-70b")
    mc = MooncakeCluster(cfg, n_prefill=2, n_decode=2, ttft_slo=30,
                         tbt_slo=0.1, admission="early")
    mc.admission.priority_relief = 0.0
    res = mc.run(overloaded_trace, speedup=6.0)
    rej = [r for r in res.records if not r.accepted
           and r.reject_stage == "admission"]
    if rej:
        hi_rej = sum(1 for r in rej if r.req.priority > 0)
        n_hi = sum(1 for r in overloaded_trace if r.priority > 0)
        n_lo = len(overloaded_trace) - n_hi
        lo_rate = (len(rej) - hi_rej) / n_lo
        hi_rate = hi_rej / n_hi
        assert abs(hi_rate - lo_rate) < 0.15
