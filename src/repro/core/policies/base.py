"""Pluggable scheduling-policy API: candidate Arms + a policy registry.

Mooncake's scheduling decisions (Algorithm 1's instance selection, the §7
admission policies, the compute-vs-load arm of Jin et al.) were originally
branches inside one Conductor method. This package makes each decision a
first-class object:

  * ``Arm`` — one candidate way to serve a request's prefill: a predicted
    TTFT, the block counts behind it (prefix / migrate / SSD), and a
    ``commit(now)`` closure that performs the arm's messenger/pool side
    effects exactly once, when the Conductor picks it. ``propose`` is pure;
    only ``commit`` mutates.
  * ``PrefillPolicy`` — ``propose(req, instances, now) -> list[Arm]``. The
    Conductor takes the min-TTFT arm (first wins on ties), so a policy is
    just "which arms exist" — strategies compose by proposing more arms.
  * ``DecodePolicy`` — ``select(req, instances, now) -> (instance, tbt)``.
  * ``AdmissionPolicy`` (see ``policies.admission``) — wraps a Conductor
    with §7 overload admission.

All three kinds share one string-keyed registry: ``@register_policy(kind,
name)`` at class level, ``get_policy(kind, name)`` to resolve (raising a
``ValueError`` that lists what IS registered), ``list_policies(kind)`` to
enumerate. Built-in policies live in sibling modules and are loaded
lazily on first lookup; user policies register by decorating a class
anywhere before the cluster is built.
"""
from __future__ import annotations

import random
from dataclasses import dataclass, field
from typing import TYPE_CHECKING, Callable, Optional, Protocol

if TYPE_CHECKING:  # import cycles: conductor imports this module
    from repro.core.conductor import DecodeInstance, PrefillInstance
    from repro.core.messenger import Messenger
    from repro.core.trace import Request


@dataclass
class Arm:
    """One candidate (instance, data-placement) pair for a request's prefill.

    ``ttft`` is the predicted time to first token and is what SLO checks
    see; ``score`` (defaults to ``ttft``) is what the Conductor minimises —
    policies that shape routing beyond raw latency (e.g. load-aware
    imbalance penalties) bias ``score`` while keeping ``ttft`` honest.

    ``compute_time`` is the prefill busy-time the arm charges to the
    instance's queue; for plain arms it equals ``prefill_time(L, prefix)``
    but overlapped arms (head recompute + tail load) charge more compute
    while finishing earlier.

    ``commit(now)`` performs the arm's messenger/pool side effects
    (peer-transfer enqueue, SSD-channel enqueue, block replication) and
    returns the time the arm's data lands — the Conductor starts compute at
    ``max(queue drained, data landed)``. ``None`` means nothing to do.
    Committing may fill ``ssd_load_time`` (the committed channel time).
    """
    kind: str                       # "recompute" | "peer_fetch" | "ssd_load" | "overlap" | "peer_ssd"
    instance: "PrefillInstance"
    ttft: float
    compute_time: float
    prefix_blocks: int = 0          # blocks reused (local, migrated or loaded)
    migrate_blocks: int = 0         # hot-spot replication volume
    transfer_from: Optional["PrefillInstance"] = None
    ssd_blocks: int = 0             # prefix blocks loaded from local SSD
    peer_ssd_blocks: int = 0        # prefix blocks fetched off a PEER's SSD
    ssd_load_time: float = 0.0      # filled by commit for SSD-loading arms
    score: Optional[float] = None   # selection key; None -> ttft
    commit: Optional[Callable[[float], float]] = None

    @property
    def sort_key(self) -> float:
        return self.ttft if self.score is None else self.score

    def land(self, now: float) -> float:
        """Run the commit closure; returns when the arm's data is ready."""
        return now if self.commit is None else self.commit(now)


@dataclass
class PolicyContext:
    """Everything a policy may consult besides the instances themselves.

    ``directory`` is the cluster's ``GlobalBlockDirectory`` when the
    shared KVCache pool is enabled (None otherwise); routing policies use
    it to propose the peer-SSD fetch arm. Reads only — commits go through
    the messenger/pools like every other arm side effect.
    """
    messenger: "Messenger"
    balancing_threshold: float = 1.3
    rng: random.Random = field(default_factory=lambda: random.Random(0))
    directory: Optional[object] = None   # GlobalBlockDirectory | None


class PrefillPolicy(Protocol):
    """Routing strategy: propose candidate arms for a request's prefill."""
    kind: str
    name: str

    def __init__(self, ctx: PolicyContext) -> None: ...

    def propose(self, req: "Request", instances: list["PrefillInstance"],
                now: float) -> list[Arm]: ...


class DecodePolicy(Protocol):
    """Decode placement: pick the instance a request will decode on."""
    kind: str
    name: str

    def __init__(self, ctx: PolicyContext) -> None: ...

    def select(self, req: "Request", instances: list["DecodeInstance"],
               now: float, include_pending: bool = True): ...


# ---------------------------------------------------------------------------
# registry
# ---------------------------------------------------------------------------

POLICY_KINDS = ("prefill", "decode", "admission")

_REGISTRY: dict[tuple[str, str], type] = {}


def register_policy(kind: str, name: str):
    """Class decorator: register under ``(kind, name)`` and stamp the class
    with ``kind``/``name`` attributes."""
    if kind not in POLICY_KINDS:
        raise ValueError(f"unknown policy kind {kind!r}; "
                         f"kinds: {list(POLICY_KINDS)}")

    def deco(cls):
        cls.kind = kind
        cls.name = name
        _REGISTRY[(kind, name)] = cls
        return cls
    return deco


_BUILTINS_LOADED = False


def _ensure_builtins() -> None:
    global _BUILTINS_LOADED
    if _BUILTINS_LOADED:
        return
    _BUILTINS_LOADED = True  # before the imports: admission re-enters here
    import importlib
    for mod in ("routing", "load_aware", "why_not_both", "decode",
                "admission"):
        importlib.import_module(f"repro.core.policies.{mod}")


def get_policy(kind: str, name: str) -> type:
    """Resolve a registered policy class; unknown names raise a
    ``ValueError`` listing what is registered for that kind."""
    _ensure_builtins()
    try:
        return _REGISTRY[(kind, name)]
    except KeyError:
        known = sorted(n for k, n in _REGISTRY if k == kind)
        raise ValueError(
            f"unknown {kind} policy {name!r}; registered: {known}") from None


def list_policies(kind: Optional[str] = None) -> list:
    """Registered names for ``kind``, or all ``(kind, name)`` pairs."""
    _ensure_builtins()
    if kind is None:
        return sorted(_REGISTRY)
    return sorted(n for k, n in _REGISTRY if k == kind)
