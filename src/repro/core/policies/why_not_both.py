"""The overlapped compute-AND-load arm — Jin et al., "Compute Or Load KV
Cache? Why Not Both?" (PAPERS.md; closes the ROADMAP open item).

The plain SSD arm is all-or-nothing: load the WHOLE SSD-resident part of
the prefix, then prefill the suffix. Jin et al. observe that recompute and
load use disjoint resources (GPU flops vs SSD read bandwidth), so the
optimal plan splits the prefix: RECOMPUTE the head on the accelerator
*while* the tail streams from SSD, then compute the suffix when both land.

With a tier prefix of ``dram`` free blocks and ``ssd`` demoted blocks, the
arm picks the number of tail blocks ``k`` to load (recomputing the other
``ssd - k`` head blocks) that minimises

    TTFT(k) = max(t_queue + t_head(ssd - k),  t_load(k)) + t_suffix

where ``t_load`` prices the node's FIFO SSD channel backlog and ``t_head``
prices recomputing blocks [dram, dram + ssd - k) of the sequence (the
demoted span is treated as contiguous after the DRAM prefix — block
interleaving makes this an approximation, in the same way the cost model's
leading-prefix accounting already is). ``k = ssd`` degenerates to the
plain SSD arm and ``k = 0`` to pure recompute, so the chosen split is
never predicted-slower than either pure arm — the split search is why not
both.

Everything else (local/peer arms, balancing threshold) is inherited from
``kvcache``; only the SSD arm is replaced by the split-search arm.
"""
from __future__ import annotations

from typing import Optional

from repro.core.policies.base import Arm, register_policy
from repro.core.policies.routing import KVCacheRouting
from repro.core.trace import BLOCK_TOKENS


@register_policy("prefill", "why_not_both")
class WhyNotBothRouting(KVCacheRouting):

    #: split granularity — candidate k values per arm (quartiles of the
    #: SSD span); the TTFT(k) surface is piecewise-linear in k with one
    #: crossover, so a coarse scan lands within a quartile of optimal
    n_splits = 4

    def _overlap_arm(self, inst, req, now: float) -> Optional[Arm]:
        tier_prefix = getattr(inst.pool, "tier_prefix", None)
        if tier_prefix is None:
            return None
        tp = tier_prefix(req.hash_ids)
        if tp.ssd == 0:
            return None
        L = req.input_length
        d_tok = tp.dram * BLOCK_TOKENS
        t_queue = inst.queue_time(now)
        t_suffix = inst.cost.prefill_time(L, tp.total * BLOCK_TOKENS)
        has_chan = self.ctx.messenger.has_ssd_channel(inst.iid)

        def t_load(k: int) -> float:
            if k == 0:
                return 0.0
            nbytes = inst.cost.kv_bytes(k * BLOCK_TOKENS)
            if has_chan:
                return self.ctx.messenger.estimate_ssd(inst.iid, nbytes, now)
            return inst.cost.ssd_load_time(k * BLOCK_TOKENS)

        ks = sorted({max(round(tp.ssd * f / self.n_splits), 0)
                     for f in range(self.n_splits + 1)})
        best_k, best_ttft, best_head = None, float("inf"), 0.0
        for k in ks:
            m = tp.ssd - k            # head blocks recomputed
            t_head = inst.cost.prefill_time((tp.dram + m) * BLOCK_TOKENS,
                                            d_tok)
            ttft = max(t_queue + t_head, t_load(k)) + t_suffix
            if ttft < best_ttft:
                best_k, best_ttft, best_head = k, ttft, t_head
        if best_k is None:
            return None
        if best_k == 0:
            # recompute the whole demoted span: nothing to enqueue, but the
            # arm must still exist — the inherited gate may have proposed
            # peer_fetch instead of a local recompute for this instance
            return Arm("overlap", inst, best_ttft, best_head + t_suffix,
                       prefix_blocks=tp.total)
        k = best_k
        nbytes = inst.cost.kv_bytes(k * BLOCK_TOKENS)
        arm = Arm("overlap", inst, best_ttft, best_head + t_suffix,
                  prefix_blocks=tp.total, ssd_blocks=k)

        def commit(now: float) -> float:
            if has_chan:
                done = self.ctx.messenger.enqueue_ssd(inst.iid, nbytes, now)
            else:
                done = now + inst.cost.ssd_load_time(k * BLOCK_TOKENS)
            arm.ssd_load_time = done - now
            # the head recompute runs while the tail streams: shifting the
            # land time left by t_head makes the Conductor's generic
            # max(queue, landed) + compute_time reproduce
            # max(queue + t_head, load) + t_suffix exactly
            return done - best_head

        arm.commit = commit
        return arm

    def _ssd_arms(self, inst, req, now) -> list[Arm]:
        arm = self._overlap_arm(inst, req, now)
        return [arm] if arm is not None else []
