"""LLaMA2-70B — the paper's own dummy evaluation model (§8.1). Used by the
simulator cost-model calibration and the end-to-end benchmarks."""
from repro.configs.base import ModelConfig, register

CONFIG = register(ModelConfig(
    name="llama2-70b",
    kind="dense",
    n_layers=80,
    d_model=8192,
    n_heads=64,
    n_kv_heads=8,
    head_dim=128,
    d_ff=28672,
    vocab_size=32000,
    rope_theta=1e4,
    optimizer="adafactor",
    source="arXiv:2307.09288 (Mooncake §8.1 dummy model)",
))
