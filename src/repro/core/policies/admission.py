"""Overload-oriented admission policies (§7), on the policy registry.

Load definition (§7.1): with disaggregated pools, load is SLO satisfaction
directly — l_prefill = predicted max TTFT / TTFT_SLO over the prefill pool,
l_decode = predicted TBT / TBT_SLO over the decode pool.

Three policies (Table 3):

  * ``baseline``   — each stage checks its own load when the request
    REACHES it: prefill load at arrival, decode load after prefill
    completes. A decode-side rejection wastes the finished prefill (§7.2).
  * ``early``      — at arrival, reject if max(prefill, decode load)
    exceeds 1. No prefill waste, but scheduling on the *current* decode
    load lags reality by one prefill duration → anti-phase fluctuation
    (§7.3, Figure 9/10a).
  * ``predictive`` — §7.4 system-level prediction: estimate the decode
    load at t_now + TTFT by (i) adding every accepted request whose
    prefill finishes before then, (ii) retiring requests whose decode will
    have exceeded the uniform decode time t_d. Accept against the
    PREDICTED load.

Each policy declares how the Conductor's decode pre-selection should
account for in-flight work via the class-level ``accounting`` knob
("current" = visible decode state only, the §7.2 time lag; "pending" =
count accepted-but-still-prefilling commitments) — applied to
``Conductor.accounting`` at construction. ``decode_double_check`` marks
policies whose decode-side check happens AFTER prefill (the simulator
re-validates at join time and may waste the finished prefill).
"""
from __future__ import annotations

from dataclasses import dataclass

from repro.core.policies.base import get_policy, register_policy
from repro.core.trace import Request


@dataclass
class _InFlight:
    """Accepted request whose prefill will finish at ``prefill_done``."""
    prefill_done: float
    tokens: float
    decode_iid: int


@dataclass
class BackpressureSignal:
    """Engine-side load snapshot the serving loop hands to admission.

    The simulator's policies gate on SLO-derived loads; a live engine has
    direct occupancy counters instead: the arrival queue, the decode slot
    table, the device page pool, and (for the predictive view) prefills
    that were accepted but have not joined a slot yet — the §7.3/§7.4
    information-lag term, measured rather than predicted.
    """
    queue_depth: int
    queue_capacity: int
    slots_used: int
    slots_total: int
    prefills_active: int = 0        # accepted, still mid-chunks (not joined)
    pages_pinned: int = 0           # DevicePagePool pressure()["pinned"]
    pages_total: int = 0
    spilled: int = 0                # preempted victims parked on the host
                                    # tier, each owed device pages back

    @property
    def queue_frac(self) -> float:
        return self.queue_depth / self.queue_capacity \
            if self.queue_capacity else 0.0

    @property
    def slot_frac(self) -> float:
        return self.slots_used / self.slots_total if self.slots_total else 0.0

    @property
    def page_frac(self) -> float:
        return self.pages_pinned / self.pages_total if self.pages_total \
            else 0.0

    def committed_frac(self, include_prefills: bool,
                       include_spilled: bool = False) -> float:
        """Committed work over serving capacity (queued + decoding, plus —
        for the predictive view — accepted-but-not-yet-joined prefills and
        preempted victims awaiting restore: both are admitted requests the
        decode pool has not finished paying for)."""
        cap = self.queue_capacity + self.slots_total
        if not cap:
            return 0.0
        n = self.queue_depth + self.slots_used
        if include_prefills:
            n += self.prefills_active
        if include_spilled:
            n += self.spilled
        return n / cap


class AdmissionPolicy:
    """Wraps a Conductor with overload admission. Subclasses decide.

    Priority-aware (§10 "advanced policy that accounts for varying
    request priorities"): a request of priority p is admitted while the
    load stays under base_limit + priority_relief·p — higher-priority
    traffic keeps flowing into the overload region that sheds best-effort
    requests.
    """
    name = "base"
    kind = "admission"
    #: how the Conductor's decode pre-selection counts in-flight work
    accounting = "pending"
    #: True -> the decode-side SLO check runs AFTER prefill (§7.2 waste)
    decode_double_check = False

    def __init__(self, conductor, priority_relief: float = 0.25) -> None:
        self.c = conductor
        self.priority_relief = priority_relief
        self.in_flight: list[_InFlight] = []
        conductor.accounting = self.accounting

    # best-effort traffic sheds at base_limit; each priority level buys
    # priority_relief more load headroom (hard SLO checks stay universal)
    base_limit = 0.85
    default_relief = 0.25           # priority_relief when no instance exists

    def load_limit(self, req: Request) -> float:
        return self.base_limit + self.priority_relief * max(req.priority, 0)

    # ---- load measurements (§7.1) ----
    def prefill_load(self, now: float) -> float:
        """max over instances of (queue + typical prefill) / TTFT_SLO."""
        loads = [p.queue_time(now) / self.c.ttft_slo for p in self.c.P]
        return max(loads) if loads else 0.0

    def decode_load(self, now: float) -> float:
        """CURRENT decode load — §7.1. Deliberately blind to accepted
        requests still in prefill: that information lag between the pools
        is what causes the §7.3 fluctuation."""
        loads = [d.predicted_tbt(include_pending=False) / self.c.tbt_slo
                 for d in self.c.D]
        return max(loads) if loads else 0.0

    def admit(self, req: Request, now: float) -> bool:
        raise NotImplementedError

    # ---- engine-side backpressure (serving loop) ----
    @classmethod
    def engine_load(cls, sig: BackpressureSignal) -> float:
        """Load the policy sees from a live-engine snapshot. Mirrors the
        simulator semantics: base/stage-local policies only look at the
        stage in front of them."""
        raise NotImplementedError

    @classmethod
    def engine_admit(cls, sig: BackpressureSignal, priority: int = 0) -> bool:
        limit = cls.base_limit + cls.default_relief * max(priority, 0)
        return cls.engine_load(sig) <= limit

    def schedule(self, req: Request, now: float):
        from repro.core.conductor import Decision
        if not self.admit(req, now):
            return Decision(False, reject_reason=f"{self.name} admission")
        dec = self.c.schedule(req, now)
        if dec.accepted:
            self.in_flight.append(_InFlight(
                prefill_done=now + dec.expected_ttft,
                tokens=req.input_length + req.output_length,
                decode_iid=dec.decode.iid))
        return dec

    def on_decode_join(self, decode_iid: int, now: float) -> None:
        self.in_flight = [f for f in self.in_flight
                          if f.prefill_done > now or f.decode_iid != decode_iid]


@register_policy("admission", "baseline")
class BaselineAdmission(AdmissionPolicy):
    """Stage-local checks only; the decode check happens in the simulator
    AFTER prefill (double-check of §3 step 4) and may waste prefill work.
    The Conductor's decode pre-selection sees only the CURRENT decode state
    (``accounting = "current"``) — the §7.2 time lag."""
    accounting = "current"
    decode_double_check = True

    def admit(self, req: Request, now: float) -> bool:
        return self.prefill_load(now) <= self.load_limit(req)

    @classmethod
    def engine_load(cls, sig: BackpressureSignal) -> float:
        # stage-local: only the intake queue in front of prefill — blind
        # to decode saturation (the §7.2 waste shows up as joins that
        # stall after the prefill already ran)
        return sig.queue_frac


@register_policy("admission", "early")
class EarlyRejection(AdmissionPolicy):
    """§7.2: gate on the max of both pools' CURRENT loads at arrival.
    The decode view is stale by one prefill duration (the Conductor's
    decode pre-selection shares the stale view), producing the anti-phase
    load fluctuation of Figure 9/10a."""
    accounting = "current"

    def admit(self, req: Request, now: float) -> bool:
        return max(self.prefill_load(now),
                   self.decode_load(now)) <= self.load_limit(req)

    @classmethod
    def engine_load(cls, sig: BackpressureSignal) -> float:
        # both pools' CURRENT state — but blind to accepted requests still
        # mid-prefill, the engine-side analogue of the §7.3 stale view
        return max(sig.committed_frac(include_prefills=False), sig.page_frac)


@register_policy("admission", "predictive")
class PredictiveEarlyRejection(AdmissionPolicy):
    """§7.4 system-level prediction with uniform decode time t_d."""

    def __init__(self, conductor, t_d: float = 10.0,
                 priority_relief: float = 0.25) -> None:
        super().__init__(conductor, priority_relief)
        self.t_d = t_d

    def predicted_decode_load(self, now: float, horizon: float) -> float:
        """Average TBT ratio over decode instances at ``now + horizon``."""
        t = now + horizon
        per_inst: dict[int, tuple[int, float]] = {}
        for d in self.c.D:
            # requests currently decoding, minus those done within horizon:
            # approximate retirement as a uniform drain over t_d
            frac_left = max(1.0 - horizon / self.t_d, 0.0)
            b = d.active * frac_left
            toks = d.kv_tokens * frac_left
            per_inst[d.iid] = (b, toks)
        # add accepted requests whose prefill completes before t
        for f in self.in_flight:
            if f.prefill_done <= t:
                b, toks = per_inst[f.decode_iid]
                per_inst[f.decode_iid] = (b + 1, toks + f.tokens)
        ratios = []
        for d in self.c.D:
            b, toks = per_inst[d.iid]
            if b < 1:
                ratios.append(0.0)
                continue
            tbt = d.cost.decode_iter_time(max(int(b), 1), toks / b)
            ratios.append(tbt / self.c.tbt_slo)
        return sum(ratios) / len(ratios) if ratios else 0.0

    def admit(self, req: Request, now: float) -> bool:
        limit = self.load_limit(req)
        if self.prefill_load(now) > limit:
            return False
        # horizon = the TTFT this request would see (approx: best queue)
        horizon = min(p.queue_time(now) for p in self.c.P) \
            + self.c.P[0].cost.prefill_time(req.input_length, 0)
        return self.predicted_decode_load(now, horizon) <= limit

    @classmethod
    def engine_load(cls, sig: BackpressureSignal) -> float:
        # §7.4 without prediction error: the engine KNOWS its in-flight
        # prefills AND its restorable preemption victims, so counting both
        # closes the information lag directly — a slot freed by a spill is
        # not free capacity, the victim will claim it back
        return max(sig.committed_frac(include_prefills=True,
                                      include_spilled=True), sig.page_frac)


def make_admission(name: str, conductor, **kw) -> AdmissionPolicy:
    """Build a registered admission policy around a Conductor."""
    return get_policy("admission", name)(conductor, **kw)
