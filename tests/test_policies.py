"""Policy registry + an invariant suite over EVERY registered policy.

The invariants (run for each registered prefill routing policy and each
admission policy, so user-registered policies get them for free by being
in the registry when pytest collects):

  * propose is PURE — no pool/queue/messenger mutation, and repeatable;
  * proposed arms are well-formed (no negative TTFT, instance assigned);
  * accept ⇒ prefill+decode instances assigned and the queue advanced;
  * reject ⇒ no pool/queue/messenger mutation (nothing was committed);
  * commit happens exactly once, at schedule time, not at propose time.
"""
import random

import pytest

from repro.configs.base import ClusterSpec, get_config
from repro.core.cache import CachePool, make_policy
from repro.core.conductor import Conductor, DecodeInstance, PrefillInstance
from repro.core.costmodel import CostModel, InstanceSpec
from repro.core.messenger import Messenger
from repro.core.policies import (get_policy, list_policies, make_admission,
                                 register_policy)
from repro.core.policies.base import _REGISTRY
from repro.core.simulator import MooncakeCluster
from repro.core.tiered import TieredCachePool
from repro.core.trace import BLOCK_TOKENS, Request, TraceSpec, generate_trace

CFG = get_config("llama2-70b")

PREFILL_POLICIES = list_policies("prefill")
ADMISSION_POLICIES = list_policies("admission")
DECODE_POLICIES = list_policies("decode")


def make_cluster(strategy="kvcache", n_p=3, n_d=2, *, ttft_slo=30.0,
                 tbt_slo=0.1, tiered=True):
    """Small cluster with a seeded cache state that exercises every arm
    kind: instance 1 holds a full DRAM prefix, instance 2 a partial one
    spilling into SSD, instance 0 is cold."""
    cost = lambda: CostModel(CFG, InstanceSpec())
    mk = (lambda: TieredCachePool(64, 512)) if tiered else (lambda: CachePool())
    P = [PrefillInstance(iid=i, pool=mk(), cost=cost()) for i in range(n_p)]
    D = [DecodeInstance(iid=100 + i, cost=cost()) for i in range(n_d)]
    msg = Messenger([p.iid for p in P] + [d.iid for d in D], bw=100e9)
    if tiered:
        for p in P:
            msg.add_ssd_channel(p.iid, 6e9)
    P[1].pool.insert(range(8))
    if tiered:
        P[2].pool.insert(range(5))
        for k in (3, 4):            # demote the tail of P2's prefix to SSD
            meta = P[2].pool.remove(k)
            P[2].pool.ssd.insert_meta(meta)
    c = Conductor(P, D, msg, ttft_slo=ttft_slo, tbt_slo=tbt_slo,
                  strategy=strategy)
    return c, P, D


def req(rid=0, n_blocks=8, out=64):
    return Request(req_id=rid, timestamp=0,
                   input_length=n_blocks * BLOCK_TOKENS, output_length=out,
                   hash_ids=list(range(n_blocks)))


def snapshot(c):
    """Everything a scheduling decision may mutate."""
    return (
        tuple((p.queue_free_at, p.total_busy, p.n_scheduled,
               tuple(sorted(p.pool.blocks)),
               tuple(sorted(getattr(p.pool, "ssd", p.pool).blocks)))
              for p in c.P),
        tuple((d.pending, d.pending_tokens, d.n_scheduled) for d in c.D),
        tuple(sorted((k, l.busy_until, l.n_transfers)
                     for k, l in c.messenger.links.items())),
        tuple(sorted((k, l.busy_until, l.n_transfers)
                     for k, l in c.messenger.ssd_links.items())),
        (c.n_migrations, c.n_ssd_loads),
    )


# ---------------------------------------------------------------------------
# registry
# ---------------------------------------------------------------------------

def test_unknown_policy_raises_valueerror_listing_names():
    with pytest.raises(ValueError) as e:
        get_policy("prefill", "nope")
    for name in PREFILL_POLICIES:
        assert name in str(e.value)


def test_make_admission_unknown_name():
    c, _, _ = make_cluster()
    with pytest.raises(ValueError) as e:
        make_admission("nope", c)
    assert "early" in str(e.value) and "predictive" in str(e.value)


def test_conductor_unknown_strategy():
    with pytest.raises(ValueError, match="kvcache"):
        make_cluster(strategy="definitely_not_registered")


def test_eviction_make_policy_unknown_name():
    with pytest.raises(ValueError, match="lru"):
        make_policy("nope")


def test_register_policy_roundtrip():
    @register_policy("prefill", "_test_local_only")
    class LocalOnly:
        def __init__(self, ctx):
            self.ctx = ctx

        def propose(self, req, instances, now):
            from repro.core.policies.routing import recompute_arm
            return [recompute_arm(instances[0], req, now)]

    try:
        assert "_test_local_only" in list_policies("prefill")
        c, P, D = make_cluster(strategy="_test_local_only")
        dec = c.schedule(req(), 0.0)
        assert dec.accepted and dec.prefill is P[0]
    finally:
        del _REGISTRY[("prefill", "_test_local_only")]


def test_register_policy_bad_kind():
    with pytest.raises(ValueError, match="kind"):
        register_policy("sideways", "x")


# ---------------------------------------------------------------------------
# invariants over every registered prefill policy
# ---------------------------------------------------------------------------

@pytest.mark.parametrize("strategy", PREFILL_POLICIES)
def test_propose_is_pure_and_wellformed(strategy):
    c, P, D = make_cluster(strategy)
    P[0].queue_free_at = 3.0          # some queue skew for load-aware paths
    before = snapshot(c)
    c.ctx.rng = random.Random(0)
    arms = c.propose(req(), now=0.0)
    assert arms, "policy must propose at least one arm for a live pool"
    for a in arms:
        assert a.ttft >= 0.0 and a.compute_time >= 0.0
        assert a.sort_key >= 0.0
        assert a.instance in P
        assert a.prefix_blocks >= 0 and a.ssd_blocks >= 0
    assert snapshot(c) == before, "propose must not mutate state"
    c.ctx.rng = random.Random(0)
    arms2 = c.propose(req(), now=0.0)
    assert [a.ttft for a in arms] == [a.ttft for a in arms2]
    assert snapshot(c) == before


@pytest.mark.parametrize("strategy", PREFILL_POLICIES)
def test_accept_assigns_instances_and_commits_once(strategy):
    c, P, D = make_cluster(strategy)
    before = snapshot(c)
    dec = c.schedule(req(), now=0.0)
    assert dec.accepted
    assert dec.prefill is not None and dec.decode is not None
    assert dec.expected_ttft >= 0.0 and dec.compute_time > 0.0
    assert dec.prefill.queue_free_at > 0.0, "commit must charge the queue"
    assert dec.prefill.n_scheduled == 1
    assert dec.decode.pending == 1
    assert snapshot(c) != before
    # the request's blocks are now resident on the chosen instance
    assert dec.prefill.pool.lookup(req().hash_ids, touch=False) \
        == req().n_blocks


@pytest.mark.parametrize("strategy", PREFILL_POLICIES)
def test_reject_leaves_state_untouched(strategy):
    c, P, D = make_cluster(strategy, ttft_slo=1e-12)   # nothing can meet it
    before = snapshot(c)
    dec = c.schedule(req(), now=0.0)
    assert not dec.accepted and dec.reject_reason
    assert snapshot(c) == before, "a rejected request must commit nothing"


@pytest.mark.parametrize("strategy", PREFILL_POLICIES)
def test_flat_pool_still_schedules(strategy):
    c, P, D = make_cluster(strategy, tiered=False)
    dec = c.schedule(req(), now=0.0)
    assert dec.accepted and dec.ssd_blocks == 0


# ---------------------------------------------------------------------------
# invariants over every registered decode policy
# ---------------------------------------------------------------------------

@pytest.mark.parametrize("dec", DECODE_POLICIES)
def test_decode_select_is_pure_and_honest(dec):
    """select() must not mutate cluster state, must be repeatable, must
    only pick instances with VRAM headroom, and must return the pick's
    honest predicted TBT (stateful policies like session_affinity may
    keep internal memory, but repeated selection stays stable)."""
    c, P, D = make_cluster()
    D[0].active, D[0].kv_tokens = 2, 60_000.0
    D[1].pending, D[1].pending_tokens = 1, 30_000.0
    pol = get_policy("decode", dec)(c.ctx)
    before = snapshot(c)
    r = req()
    tokens = r.input_length + r.output_length
    pick1, tbt1 = pol.select(r, D, 0.0)
    pick2, tbt2 = pol.select(r, D, 0.0)
    assert pick1 is pick2 and tbt1 == tbt2, "selection must be stable"
    assert snapshot(c) == before, "select must not mutate cluster state"
    assert pick1.vram_ok(tokens)
    assert tbt1 == pick1.predicted_tbt(1, tokens, include_pending=True)


@pytest.mark.parametrize("dec", DECODE_POLICIES)
def test_decode_policy_runs_end_to_end(dec):
    reqs = generate_trace(TraceSpec(n_requests=150, duration_ms=60_000,
                                    seed=4))
    spec = ClusterSpec(n_prefill=2, n_decode=2, decode_policy=dec)
    res = MooncakeCluster.from_spec(CFG, spec).run(reqs)
    assert res.completed(), f"{dec} must complete requests"
    for r in res.completed():
        assert r.ttft >= 0.0


# ---------------------------------------------------------------------------
# invariants over every registered admission policy
# ---------------------------------------------------------------------------

@pytest.fixture(scope="module")
def overload_trace():
    return generate_trace(TraceSpec(n_requests=600, duration_ms=100_000,
                                    seed=5, out_mu=5.9))


@pytest.mark.parametrize("adm", ADMISSION_POLICIES)
def test_admission_records_and_breakdown(adm, overload_trace):
    spec = ClusterSpec(n_prefill=2, n_decode=2, admission=adm, t_d=20.0)
    res = MooncakeCluster.from_spec(CFG, spec).run(overload_trace,
                                                   speedup=6.0)
    rejected = res.rejected()
    assert rejected, "scenario must actually overload"
    for r in rejected:
        assert r.reject_reason, "every rejection must carry a reason"
    bd = res.reject_breakdown()
    assert sum(bd.values()) == len(rejected)
    for r in res.records:
        if r.completed:
            assert r.ttft >= 0.0


def test_baseline_breakdown_separates_doublecheck(overload_trace):
    spec = ClusterSpec(n_prefill=2, n_decode=2, admission="baseline")
    res = MooncakeCluster.from_spec(CFG, spec).run(overload_trace,
                                                   speedup=6.0)
    bd = res.reject_breakdown()
    assert any(k.startswith("decode double-check") for k in bd), bd


@pytest.mark.parametrize("adm", ADMISSION_POLICIES)
def test_admission_sets_conductor_accounting(adm):
    c, _, _ = make_cluster()
    pol = make_admission(adm, c)
    assert c.accounting == pol.accounting
    assert c.account_pending == (pol.accounting == "pending")


# ---------------------------------------------------------------------------
# ClusterSpec
# ---------------------------------------------------------------------------

def test_from_spec_matches_legacy_kwargs():
    reqs = generate_trace(TraceSpec(n_requests=200, duration_ms=60_000,
                                    seed=9))
    legacy = MooncakeCluster(CFG, n_prefill=2, n_decode=2, ttft_slo=30,
                             tbt_slo=0.1, strategy="kvcache",
                             admission="early").run(reqs)
    spec = ClusterSpec(n_prefill=2, n_decode=2, ttft_slo=30, tbt_slo=0.1,
                       strategy="kvcache", admission="early")
    modern = MooncakeCluster.from_spec(CFG, spec).run(reqs)
    assert legacy.avg_ttft() == modern.avg_ttft()
    assert len(legacy.completed()) == len(modern.completed())


def test_spec_and_kwargs_are_exclusive():
    with pytest.raises(ValueError, match="not both"):
        MooncakeCluster(CFG, ClusterSpec(), n_prefill=2)


def test_spec_replace():
    s = ClusterSpec(strategy="kvcache")
    assert s.replace(strategy="load_aware").strategy == "load_aware"
    assert s.strategy == "kvcache"


# ---------------------------------------------------------------------------
# the new policies
# ---------------------------------------------------------------------------

def test_why_not_both_never_predicts_slower_than_kvcache():
    """The overlap arm's split search includes k=ssd (pure load) and the
    other inherited arms, so its best predicted TTFT is <= kvcache's on
    the same cluster state."""
    for n_blocks in (4, 8, 12):
        a, _, _ = make_cluster("kvcache")
        b, _, _ = make_cluster("why_not_both")
        r = req(n_blocks=n_blocks)
        t_kv = min(x.ttft for x in a.propose(r, 0.0))
        t_wnb = min(x.ttft for x in b.propose(r, 0.0))
        assert t_wnb <= t_kv + 1e-12


def test_why_not_both_overlap_beats_pure_arms():
    """With an idle queue and NVMe-class SSD (load and recompute times
    comparable), the split arm's predicted TTFT beats both the pure-load
    and pure-recompute plans on the same instance."""
    c, P, D = make_cluster("why_not_both")
    kv, _, _ = make_cluster("kvcache")
    r = req(n_blocks=5)                           # P2: 3 DRAM + 2 SSD blocks
    overlap = [a for a in c.propose(r, 0.0) if a.kind == "overlap"]
    assert overlap, "tier prefix must yield an overlap arm"
    arm = min(overlap, key=lambda a: a.ttft)
    assert 0 < arm.ssd_blocks < 2, "the split must load only the tail"
    # vs the pure-load plan (kvcache's all-or-nothing SSD arm)
    pure_load = [a for a in kv.propose(r, 0.0) if a.kind == "ssd_load"
                 and a.instance.iid == arm.instance.iid]
    assert pure_load and arm.ttft <= min(a.ttft for a in pure_load) + 1e-12
    # vs the pure-recompute plan on the same instance's DRAM prefix
    inst = next(p for p in c.P if p.iid == arm.instance.iid)
    from repro.core.policies.routing import recompute_arm
    assert arm.ttft <= recompute_arm(inst, r, 0.0).ttft + 1e-12


def test_load_aware_prices_transfers_the_ratio_gate_skips():
    """Holder has 8/8 blocks, rival 7/8: kvcache's ratio gate (8/7 < 1.3)
    never proposes the fetch; load_aware prices it."""
    cost = lambda: CostModel(CFG, InstanceSpec())
    P = [PrefillInstance(iid=i, pool=CachePool(), cost=cost())
         for i in range(2)]
    D = [DecodeInstance(iid=100, cost=cost())]
    msg = Messenger([0, 1, 100], bw=100e9)
    P[0].pool.insert(range(8))
    P[1].pool.insert(range(7))
    kv = Conductor(P, D, msg, ttft_slo=30, tbt_slo=0.1, strategy="kvcache")
    la = Conductor(P, D, msg, ttft_slo=30, tbt_slo=0.1, strategy="load_aware")
    r = req(n_blocks=8)
    assert not any(a.kind == "peer_fetch" for a in kv.propose(r, 0.0))
    fetches = [a for a in la.propose(r, 0.0) if a.kind == "peer_fetch"]
    assert fetches and fetches[0].instance is P[1]
    assert fetches[0].migrate_blocks == 1


def test_load_aware_penalty_biases_score_not_ttft():
    c, P, D = make_cluster("load_aware")
    P[1].queue_free_at = 50.0          # hot holder
    arms = c.propose(req(), now=0.0)
    hot = [a for a in arms if a.instance is P[1]]
    assert hot and all(a.score is not None and a.score > a.ttft for a in hot)
    cold = [a for a in arms if a.instance is P[0]]
    assert all(a.score == pytest.approx(a.ttft) for a in cold)
