"""End-to-end training driver: a ~100M-parameter dense model for a few
hundred steps on CPU, with cosine LR, checkpointing and resume.

    PYTHONPATH=src python examples/train_e2e.py [--steps 300]

(The paper's systems serve models; training is the substrate that makes
the ``train_4k`` input shape and the dummy-model methodology real — the
same train_step lowers on the production mesh in the dry-run.)
"""
import argparse
import dataclasses
import tempfile

from repro.configs.base import ModelConfig, get_config
from repro.training.loop import train


def hundred_m_config() -> ModelConfig:
    """~100M-param llama-family config (between smollm-reduced and 360M)."""
    base = get_config("smollm-360m")
    return dataclasses.replace(
        base, name="smollm-100m", n_layers=12, d_model=512, n_heads=8,
        n_kv_heads=4, head_dim=64, d_ff=1536, vocab_size=49152,
        remat=False)


def main():
    ap = argparse.ArgumentParser()
    ap.add_argument("--steps", type=int, default=300)
    ap.add_argument("--batch", type=int, default=4)
    ap.add_argument("--seq", type=int, default=256)
    ap.add_argument("--ckpt", default=None)
    args = ap.parse_args()

    cfg = hundred_m_config()
    n = cfg.param_count() / 1e6
    print(f"training {cfg.name}: {n:.0f}M params, "
          f"{args.steps} steps × {args.batch}×{args.seq} tokens")
    ckpt = args.ckpt or tempfile.mkdtemp(prefix="mooncake_ckpt_")

    res = train(cfg, steps=args.steps, batch=args.batch, seq=args.seq,
                checkpoint_dir=ckpt, checkpoint_every=100, log_every=20)
    first = sum(res.losses[:10]) / 10
    last = sum(res.losses[-10:]) / 10
    print(f"\nloss {first:.3f} -> {last:.3f} over {res.steps} steps "
          f"({res.tokens_per_s:.0f} tok/s); checkpoints in {ckpt}")
    assert last < first, "training must make progress"

    # resume from the checkpoint (restores step counter + optimizer)
    res2 = train(cfg, steps=20, batch=args.batch, seq=args.seq,
                 checkpoint_dir=ckpt, resume=True, log_every=10)
    print(f"resumed fine: continued to loss {res2.losses[-1]:.3f}")


if __name__ == "__main__":
    main()
