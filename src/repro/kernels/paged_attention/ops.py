"""Public op: paged decode attention (kernel or oracle dispatch)."""
from __future__ import annotations

import jax

from repro.kernels.paged_attention.kernel import (
    paged_attention as _kernel, paged_attention_layers as _kernel_layers)
from repro.kernels.paged_attention.ref import (
    paged_attention_layers_ref as _ref_layers, paged_attention_ref as _ref)


def _kernel_ok(q_heads: int, kv_heads: int, qh2kv, window: int) -> bool:
    """The Pallas grid packs grouped GQA only: divisible heads, no padded
    query-head remap, full attention. Everything else takes the oracle."""
    return qh2kv is None and window == 0 and q_heads % kv_heads == 0


def paged_decode_attention(q, k_pages, v_pages, block_table, seq_lens, *,
                           qh2kv=None, window: int = 0,
                           use_pallas: bool = False,
                           interpret: bool | None = None):
    """q: (B, H, D) over one layer's paged KV → (B, H, D)."""
    if not use_pallas or not _kernel_ok(q.shape[1], k_pages.shape[2],
                                        qh2kv, window):
        return _ref(q, k_pages, v_pages, block_table, seq_lens,
                    qh2kv=qh2kv, window=window)
    if interpret is None:
        interpret = jax.default_backend() == "cpu"
    return _kernel(q, k_pages, v_pages, block_table, seq_lens,
                   interpret=interpret)


def paged_decode_attention_sharded(q, k_pages, v_pages, block_table,
                                   seq_lens, *, mesh, window: int = 0,
                                   use_pallas: bool = False,
                                   interpret: bool | None = None):
    """Mesh entry: one layer's paged decode attention shard_mapped over a
    (data, model) mesh — batch rows over 'data', KV-head stripes (and the
    grouped query heads that attend them) over 'model'. The inner loop is
    collective-free (attention is head-local); outputs reassemble to the
    global (B, H, D) by construction of the out_specs, so the result is
    bitwise ``paged_decode_attention`` on the unsharded arrays. Grouped
    GQA only. At this kernel-level entry the page store is replicated
    across the data axis (one shared bank, global page ids); the engine's
    ``DevicePagePool(mesh=…)`` additionally banks pages per data shard
    and hands the step bank-local tables."""
    from jax.sharding import PartitionSpec as P
    from repro.launch.mesh import compat_shard_map
    m = int(mesh.shape.get("model", 1))
    H, KV = q.shape[1], k_pages.shape[2]
    assert H % KV == 0 and KV % m == 0, \
        f"sharded paged attention is grouped-GQA only (H={H}, KV={KV}, m={m})"

    def local(q, kp, vp, tbl, lens):
        return paged_decode_attention(q, kp, vp, tbl, lens, window=window,
                                      use_pallas=use_pallas,
                                      interpret=interpret)

    f = compat_shard_map(
        local, mesh=mesh,
        in_specs=(P("data", "model", None), P(None, None, "model", None),
                  P(None, None, "model", None), P("data", None), P("data")),
        out_specs=P("data", "model", None), check_vma=False)
    return f(q, k_pages, v_pages, block_table, seq_lens)


def paged_decode_attention_layers(qs, k_pages, v_pages, block_table,
                                  seq_lens, *, qh2kv=None, window: int = 0,
                                  use_pallas: bool = False,
                                  interpret: bool | None = None):
    """Batched-over-layers variant: qs (L, B, H, D) over the stacked
    (L, P, page, KV, D) store → (L, B, H, D). One kernel launch covers
    every layer (microbench / layer-parallel callers)."""
    if not use_pallas or not _kernel_ok(qs.shape[2], k_pages.shape[3],
                                        qh2kv, window):
        return _ref_layers(qs, k_pages, v_pages, block_table, seq_lens,
                           qh2kv=qh2kv, window=window)
    if interpret is None:
        interpret = jax.default_backend() == "cpu"
    return _kernel_layers(qs, k_pages, v_pages, block_table, seq_lens,
                          interpret=interpret)
