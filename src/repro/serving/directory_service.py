"""Directory service over the wire protocol: one process serves the
``GlobalBlockDirectory``, every other node caches it advisorily.

Until now the directory was one shared Python object — fine for the
in-process cluster, impossible across OS processes.  This module splits
it along the paper's Conductor/node boundary:

* ``DirectoryServer`` wraps a real ``GlobalBlockDirectory`` behind the
  CRC-framed transport (``PUBLISH``/``WITHDRAW``/``LOOKUP`` plus node
  membership: ``HELLO``, ``NODES``, a crash-tolerant ``BARRIER``).  A
  node's connection doubles as its liveness lease — when the socket of a
  HELLO'd node dies (including kill -9), the server ``drop_node``s every
  claim, so the directory self-heals exactly as it does in-process.
* ``RemoteDirectory`` duck-types the directory surface the serving
  engine consumes (``register``/``unregister``/``pick_owner``/
  ``holders``/``nodes_with``/``best_ssd_extension``/``bind``/``stats``)
  over a socket, with a small TTL'd positive-lookup cache.  The cache is
  *advisory* in precisely the directory's own sense: a stale hit is
  re-verified at fetch time by CRC and degrades to recompute, so serving
  correctness never depends on cache freshness.

Partition tolerance: when the directory service is unreachable, reads
answer "nobody holds it" (pick_owner → None — requests degrade to
recompute, the same path as any other fallback) and writes are dropped
and counted.  Nothing blocks the serving loop on a dead directory.

``python -m repro.serving.directory_service`` runs a standalone server
(no jax import) and prints ``PORT <p>``.
"""
from __future__ import annotations

import socket
import threading
import time
from typing import Iterable, Optional

from repro.core.directory import (GlobalBlockDirectory, bind_pool,
                                  select_owner)
from repro.serving.transport import (MSG_BARRIER, MSG_ERR, MSG_HELLO,
                                     MSG_LOOKUP, MSG_NODES, MSG_OK,
                                     MSG_PUBLISH, MSG_STATS, MSG_WITHDRAW,
                                     FrameConn, FrameReader, PeerError,
                                     PeerUnreachable, _pack_json,
                                     _unpack_json, encode_frame)

_RECV_CHUNK = 1 << 16


class DirectoryServer:
    """Serve one ``GlobalBlockDirectory`` to a cluster of processes."""

    def __init__(self, directory: Optional[GlobalBlockDirectory] = None,
                 host: str = "127.0.0.1", port: int = 0, *,
                 barrier_timeout: float = 30.0) -> None:
        self.directory = directory if directory is not None \
            else GlobalBlockDirectory()
        self.barrier_timeout = barrier_timeout
        self._lock = threading.Lock()
        self._cond = threading.Condition(self._lock)
        self._conns: dict[int, socket.socket] = {}  #: guarded_by self._lock
        self._conn_node: dict[int, int] = {}        #: guarded_by self._lock
        #: guarded_by self._lock — node id -> (host, block port)
        self._endpoints: dict[int, tuple] = {}
        self._barriers: dict[str, int] = {}         #: guarded_by self._cond
        self._closed = False                        #: guarded_by self._lock
        self._next_conn = 0                         #: guarded_by self._lock
        self._threads: list[threading.Thread] = []  #: guarded_by self._lock
        self.n_drops = 0                            #: guarded_by self._lock
        sock = socket.socket(socket.AF_INET, socket.SOCK_STREAM)
        try:
            sock.setsockopt(socket.SOL_SOCKET, socket.SO_REUSEADDR, 1)
            sock.bind((host, port))
            sock.listen(32)
        except BaseException:
            sock.close()
            raise
        self._sock = sock
        self.host, self.port = sock.getsockname()[:2]
        self._accept_thread = threading.Thread(
            target=self._accept_loop, daemon=True, name="repro-dir-accept")
        self._accept_thread.start()

    @property
    def addr(self) -> tuple:
        return (self.host, self.port)

    def endpoints(self) -> dict:
        """node id -> (host, block port) of every HELLO'd node."""
        with self._lock:
            return dict(self._endpoints)

    # ---- server plumbing ----------------------------------------------
    def _accept_loop(self) -> None:
        while True:
            try:
                conn, peer = self._sock.accept()
            except OSError:
                return
            alive = self._adopt(conn, peer[0])
            if not alive:
                return

    def _adopt(self, conn: socket.socket, host: str) -> bool:
        """Take ownership of an accepted conn: register it and spawn its
        serve thread, or close it if the server already shut down."""
        with self._lock:
            if self._closed:
                conn.close()
                return False
            cid = self._next_conn
            self._next_conn += 1
            self._conns[cid] = conn
            t = threading.Thread(target=self._serve,
                                 args=(conn, cid, host), daemon=True,
                                 name=f"repro-dir-serve-{cid}")
            self._threads.append(t)
        t.start()
        return True

    def _node_left(self, cid: int) -> None:
        """A HELLO'd node's connection died: revoke its claims."""
        with self._lock:
            node = self._conn_node.pop(cid, None)
            if node is None:
                return
            self._endpoints.pop(node, None)
            self.n_drops += 1
        self.directory.drop_node(node)

    def _handle(self, conn: socket.socket, cid: int, host: str,
                mtype: int, payload: bytes) -> None:
        d = self.directory
        if mtype == MSG_HELLO:
            req = _unpack_json(payload)
            node = int(req["node"])
            with self._lock:
                self._conn_node[cid] = node
                self._endpoints[node] = (req.get("host") or host,
                                         int(req.get("port", 0)))
            reply = dict(ok=True, node=node)
        elif mtype == MSG_PUBLISH:
            req = _unpack_json(payload)
            d.register(int(req["key"]), int(req["node"]), req["tier"])
            reply = dict(ok=True)
        elif mtype == MSG_WITHDRAW:
            req = _unpack_json(payload)
            removed = d.unregister(int(req["key"]), int(req["node"]))
            reply = dict(ok=True, removed=removed)
        elif mtype == MSG_LOOKUP:
            req = _unpack_json(payload)
            holders = d.holders(int(req["key"]))
            # node ids as list pairs: json would stringify dict keys
            reply = dict(holders=[[n, t] for n, t in sorted(holders.items())])
        elif mtype == MSG_NODES:
            with self._lock:
                nodes = [[n, h, p] for n, (h, p)
                         in sorted(self._endpoints.items())]
            reply = dict(nodes=nodes)
        elif mtype == MSG_BARRIER:
            req = _unpack_json(payload)
            reply = self._barrier(req["name"], int(req["n"]),
                                  float(req.get("timeout",
                                                self.barrier_timeout)))
        elif mtype == MSG_STATS:
            reply = dict(d.stats())
            with self._lock:
                reply.update(nodes=len(self._endpoints),
                             node_drops=self.n_drops)
        else:
            conn.sendall(encode_frame(MSG_ERR, _pack_json(
                dict(code="peer_fetch_failed",
                     msg=f"unknown directory request {mtype}"))))
            return
        conn.sendall(encode_frame(MSG_OK, _pack_json(reply)))

    def _barrier(self, name: str, n: int, timeout: float) -> dict:
        """Block until ``n`` arrivals at ``name`` or timeout; reports the
        arrival count either way so survivors of a crashed participant
        can proceed (crash tolerance over strictness)."""
        deadline = time.monotonic() + timeout
        with self._cond:
            self._barriers[name] = self._barriers.get(name, 0) + 1
            self._cond.notify_all()
            while self._barriers.get(name, 0) < n and \
                    not self._closed:  # replint: ignore[guarded-by] -- self._cond wraps self._lock; 'with self._cond' holds that same lock
                left = deadline - time.monotonic()
                if left <= 0:
                    break
                self._cond.wait(min(left, 0.1))
            arrived = self._barriers.get(name, 0)
        return dict(arrived=arrived, met=arrived >= n)

    def _serve(self, conn: socket.socket, cid: int, host: str) -> None:
        reader = FrameReader()
        try:
            conn.settimeout(None)       # a directory conn idles legally
            while True:
                data = conn.recv(_RECV_CHUNK)
                if not data:
                    return
                for mtype, payload in reader.feed(data):
                    self._handle(conn, cid, host, mtype, payload)
        except (OSError, PeerError):
            return
        finally:
            conn.close()
            self._node_left(cid)
            with self._lock:
                self._conns.pop(cid, None)
                self._cond.notify_all()

    def close(self) -> None:
        with self._lock:
            if self._closed:
                return
            self._closed = True
            conns = list(self._conns.values())
            threads = list(self._threads)
            self._cond.notify_all()
        try:
            # closing the fd alone does NOT wake a thread blocked in
            # accept() on Linux; shutdown makes accept raise immediately
            self._sock.shutdown(socket.SHUT_RDWR)
        except OSError:
            pass
        self._sock.close()
        for conn in conns:
            try:
                conn.shutdown(socket.SHUT_RDWR)
            except OSError:
                pass
            conn.close()
        self._accept_thread.join()
        for t in threads:
            t.join()


class RemoteDirectory:
    """Socket client for a ``DirectoryServer``; duck-types the directory
    surface the serving engine uses, with an advisory TTL lookup cache."""

    def __init__(self, addr, *, node_id: Optional[int] = None,
                 block_port: int = 0, host: Optional[str] = None,
                 timeout: float = 5.0, cache_ttl: float = 2.0) -> None:
        self.addr = (addr[0], int(addr[1]))
        self.node_id = node_id
        self.timeout = timeout
        self.cache_ttl = cache_ttl
        self.barrier_default_timeout = 30.0
        self._lock = threading.Lock()
        self._conn: Optional[FrameConn] = None  #: guarded_by self._lock
        #: guarded_by self._lock — key -> (expiry, {node: tier})
        self._cache: dict[int, tuple] = {}
        self.n_errors = 0               #: guarded_by self._lock
        self.n_dropped_writes = 0       #: guarded_by self._lock
        self.n_cache_hits = 0           #: guarded_by self._lock
        self.n_lookups = 0              #: guarded_by self._lock
        if node_id is not None:
            # announce membership; the conn is our liveness lease
            self._call(MSG_HELLO, dict(node=node_id, port=block_port,
                                       host=host), required=True)

    # ---- rpc plumbing --------------------------------------------------
    def _call(self, mtype: int, obj, required: bool = False,
              rpc_timeout: Optional[float] = None):
        """One request/response; on socket failure returns None (callers
        treat the directory as partitioned) unless ``required``.
        ``rpc_timeout`` widens the read timeout for RPCs that legally
        block server-side (BARRIER)."""
        payload = _pack_json(obj if obj is not None else {})
        with self._lock:
            try:
                if self._conn is None:
                    try:
                        sock = socket.create_connection(
                            self.addr, timeout=self.timeout)
                    except OSError as e:
                        raise PeerUnreachable(
                            f"cannot connect to directory {self.addr}: {e}"
                        ) from None
                    self._conn = FrameConn(sock, timeout=self.timeout)
                    if self.node_id is not None and mtype != MSG_HELLO:
                        # re-HELLO after a reconnect: the lease follows
                        # the connection, not the process
                        self._conn.request(MSG_HELLO, _pack_json(
                            dict(node=self.node_id)))
                if rpc_timeout is not None:
                    self._conn.settimeout(rpc_timeout)
                try:
                    rtype, rpayload = self._conn.request(mtype, payload)
                finally:
                    if rpc_timeout is not None and self._conn is not None:
                        self._conn.settimeout(self.timeout)
            except PeerError as e:
                if self._conn is not None:
                    self._conn.close()
                    self._conn = None
                self.n_errors += 1
                if required:
                    raise PeerUnreachable(
                        f"directory service at {self.addr}: {e}") from None
                return None
            if rtype != MSG_OK:
                self.n_errors += 1
                return None
            return _unpack_json(rpayload)

    # ---- directory surface --------------------------------------------
    def register(self, key: int, node, tier: str) -> None:
        r = self._call(MSG_PUBLISH, dict(key=key, node=node, tier=tier))
        with self._lock:
            self._cache.pop(key, None)
            if r is None:
                self.n_dropped_writes += 1

    def unregister(self, key: int, node) -> bool:
        r = self._call(MSG_WITHDRAW, dict(key=key, node=node))
        with self._lock:
            self._cache.pop(key, None)
            if r is None:
                self.n_dropped_writes += 1
        return bool(r and r.get("removed"))

    def holders(self, key: int) -> dict:
        now = time.monotonic()
        with self._lock:
            self.n_lookups += 1
            hit = self._cache.get(key)
            if hit is not None and hit[0] > now:
                self.n_cache_hits += 1
                return dict(hit[1])
        r = self._call(MSG_LOOKUP, dict(key=key))
        if r is None:
            return {}                   # partitioned: nobody holds it
        holders = {int(n): t for n, t in r.get("holders", [])}
        if holders:                     # positive entries only: a miss
            with self._lock:            # now may be a publish in flight
                self._cache[key] = (now + self.cache_ttl, dict(holders))
        return holders

    def nodes_with(self, key: int, tier: Optional[str] = None) -> list:
        h = self.holders(key)
        return sorted(n for n, t in h.items() if tier is None or t == tier)

    def pick_owner(self, key: int, exclude: Iterable = (),
                   among: Optional[Iterable] = None):
        exclude = set(exclude)
        among = None if among is None else set(among)
        cands = [(n, t) for n, t in self.holders(key).items()
                 if n not in exclude and (among is None or n in among)]
        return select_owner(cands)

    def best_ssd_extension(self, hash_ids: list, start: int = 0,
                           exclude: Iterable = ()) -> tuple:
        """Same contract as ``GlobalBlockDirectory.best_ssd_extension``,
        built from (cached) per-key lookups."""
        if start >= len(hash_ids):
            return 0, None
        exclude = set(exclude)
        best_k, best_node = 0, None
        for node in self.nodes_with(hash_ids[start], tier="ssd"):
            if node in exclude:
                continue
            k = 0
            for h in hash_ids[start:]:
                if self.holders(h).get(node) != "ssd":
                    break
                k += 1
            if k > best_k:
                best_k, best_node = k, node
        return best_k, best_node

    def bind(self, node, pool) -> None:
        bind_pool(self, node, pool)

    # ---- membership ----------------------------------------------------
    def nodes(self) -> dict:
        """node id -> (host, block port) for every live node."""
        r = self._call(MSG_NODES, {})
        if r is None:
            return {}
        return {int(n): (h, int(p)) for n, h, p in r.get("nodes", [])}

    def barrier(self, name: str, n: int,
                timeout: Optional[float] = None) -> dict:
        """Crash-tolerant rendezvous: returns {arrived, met}."""
        t = self.barrier_default_timeout if timeout is None else timeout
        r = self._call(MSG_BARRIER, dict(name=name, n=n, timeout=t),
                       required=True, rpc_timeout=t + 10.0)
        return r if r is not None else dict(arrived=0, met=False)

    def stats(self) -> dict:
        r = self._call(MSG_STATS, {})
        with self._lock:
            local = dict(client_errors=self.n_errors,
                         dropped_writes=self.n_dropped_writes,
                         lookups=self.n_lookups,
                         cache_hits=self.n_cache_hits)
        if r is None:
            local["partitioned"] = True
            return local
        r.update(local)
        return r

    def close(self) -> None:
        with self._lock:
            if self._conn is not None:
                self._conn.close()
                self._conn = None


def main(argv=None) -> int:
    import argparse

    ap = argparse.ArgumentParser(
        prog="python -m repro.serving.directory_service",
        description="standalone directory service for a multi-process "
                    "serve_cluster run")
    ap.add_argument("--port", type=int, default=0)
    ap.add_argument("--host", default="127.0.0.1")
    args = ap.parse_args(argv)

    server = DirectoryServer(host=args.host, port=args.port)
    print(f"PORT {server.port}", flush=True)
    try:
        threading.Event().wait()
    except KeyboardInterrupt:
        pass
    finally:
        server.close()
    return 0


if __name__ == "__main__":
    raise SystemExit(main())
