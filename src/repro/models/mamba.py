"""Mamba2 (SSD — state-space duality) block in pure JAX. [arXiv:2405.21060]

Follows the paper's minimal chunked SSD algorithm: intra-chunk "attention"
via the 1-semiseparable mask L = exp(segsum(dt*A)), inter-chunk recurrence
over chunk states via an associative scan. Single B/C group (n_groups = 1).

The decode path is the classic selective-scan recurrence on a constant-size
state — this is what makes SSM/hybrid archs run the 500k-context shape, and
what Mooncake's KVCache scheduling degenerates to for these archs (state
checkpoints instead of KV blocks; see DESIGN.md §Arch-applicability).
"""
from __future__ import annotations

from typing import NamedTuple, Optional

import jax
import jax.numpy as jnp

from repro.configs.base import ModelConfig
from repro.models.layers import Dist, rms_norm


class MambaState(NamedTuple):
    ssm: jax.Array   # (B, H, P, N) fp32
    conv: jax.Array  # (B, d_conv - 1, conv_channels)


def _segsum(x: jax.Array) -> jax.Array:
    """x: (..., L) -> (..., L, L) with out[i, j] = sum(x[j+1 .. i]), -inf above
    the diagonal (strict lower-triangular cumulative sums, diagonal = 0)."""
    L = x.shape[-1]
    cs = jnp.cumsum(x, axis=-1)
    out = cs[..., :, None] - cs[..., None, :]
    mask = jnp.tril(jnp.ones((L, L), dtype=bool))
    return jnp.where(mask, out, -jnp.inf)


def ssd_chunked(x, dt, A, B, C, chunk: int, h0: Optional[jax.Array] = None):
    """Chunked SSD scan.

    x:  (b, s, h, p)   inputs (already multiplied by nothing; dt applied here)
    dt: (b, s, h)      positive step sizes
    A:  (h,)           negative decay rates
    B:  (b, s, n)      input projections (single group)
    C:  (b, s, n)      output projections
    Returns (y (b,s,h,p), final_state (b,h,p,n) fp32).
    """
    b, s, h, p = x.shape
    n = B.shape[-1]
    assert s % chunk == 0, f"seq {s} not divisible by chunk {chunk}"
    c = s // chunk
    f32 = jnp.float32

    xdt = (x * dt[..., None]).astype(f32)            # (b,s,h,p)
    dA = (dt * A[None, None, :]).astype(f32)         # (b,s,h)

    # chunked views
    xc = xdt.reshape(b, c, chunk, h, p)
    dAc = jnp.moveaxis(dA.reshape(b, c, chunk, h), -1, 1)   # (b,h,c,l)
    Bc = B.reshape(b, c, chunk, n).astype(f32)
    Cc = C.reshape(b, c, chunk, n).astype(f32)

    cum = jnp.cumsum(dAc, axis=-1)                   # (b,h,c,l)

    # 1. intra-chunk (diagonal blocks): Y_diag = (C B^T ∘ L) (x*dt)
    Lmask = jnp.exp(_segsum(dAc))                    # (b,h,c,l,l)
    Y_diag = jnp.einsum("bcln,bcsn,bhcls,bcshp->bclhp", Cc, Bc, Lmask, xc)

    # 2. per-chunk states: right factor with decay to the chunk end
    decay_states = jnp.exp(cum[..., -1:] - cum)      # (b,h,c,l)
    states = jnp.einsum("bcln,bhcl,bclhp->bchpn", Bc, decay_states, xc)

    # 3. inter-chunk recurrence over chunk states
    chunk_decay = jnp.exp(cum[..., -1])              # (b,h,c)

    def scan_body(h_prev, inp):
        st, dec = inp                                # (b,h,p,n), (b,h)
        h_new = h_prev * dec[..., None, None] + st
        return h_new, h_prev                         # emit the INCOMING state

    states_t = jnp.moveaxis(states, 1, 0)            # (c,b,h,p,n)
    decay_t = jnp.moveaxis(chunk_decay, -1, 0)       # (c,b,h)
    if h0 is None:
        h0 = jnp.zeros((b, h, p, n), dtype=f32)
    h_final, h_in = jax.lax.scan(scan_body, h0, (states_t, decay_t))
    h_in = jnp.moveaxis(h_in, 0, 1)                  # (b,c,h,p,n)

    # 4. inter-chunk outputs: state contribution decayed to each position
    state_decay = jnp.exp(cum)                       # (b,h,c,l)
    Y_off = jnp.einsum("bcln,bchpn,bhcl->bclhp", Cc, h_in, state_decay)

    y = (Y_diag + Y_off).reshape(b, s, h, p)
    return y, h_final


def ssd_decode(x, dt, A, B, C, state):
    """Single-step recurrence. x: (b,h,p); dt: (b,h); B,C: (b,n);
    state: (b,h,p,n) fp32. Returns (y (b,h,p), new_state)."""
    f32 = jnp.float32
    dA = jnp.exp((dt * A[None, :]).astype(f32))                # (b,h)
    dBx = jnp.einsum("bn,bhp->bhpn", B.astype(f32),
                     (x * dt[..., None]).astype(f32))
    new_state = state * dA[..., None, None] + dBx
    y = jnp.einsum("bhpn,bn->bhp", new_state, C.astype(f32))
    return y, new_state


def _causal_conv(x, w, prev: Optional[jax.Array] = None):
    """Depthwise causal conv. x: (b, s, ch); w: (k, ch).
    prev: (b, k-1, ch) history for decode/chunked prefill.
    Returns (y (b, s, ch), new_prev (b, k-1, ch))."""
    k = w.shape[0]
    b, s, ch = x.shape
    if prev is None:
        prev = jnp.zeros((b, k - 1, ch), dtype=x.dtype)
    xp = jnp.concatenate([prev, x], axis=1)          # (b, s+k-1, ch)
    y = sum(xp[:, i:i + s, :] * w[i][None, None, :] for i in range(k))
    new_prev = xp[:, -(k - 1):, :] if k > 1 else jnp.zeros((b, 0, ch), x.dtype)
    return y, new_prev


def mamba_block(x, p, cfg: ModelConfig, dist: Dist, *,
                state: Optional[MambaState] = None, return_state: bool = False):
    """Mamba2 mixer block (pre-norm, residual added by the caller).

    x: (B, S, D). If ``state`` is given this is a decode step (S == 1) or a
    chunk continuation; returns (y, new_state) — else (y, final_state or None).
    """
    s_cfg = cfg.ssm
    B_, S, D = x.shape
    di = s_cfg.d_inner(D)
    nh = s_cfg.n_heads(D)
    hd = s_cfg.head_dim
    n = s_cfg.d_state

    h = rms_norm(x, p["ln"], cfg.norm_eps)
    zxbcdt = h @ p["in_proj"]  # (B, S, 2*di + 2n + nh)
    z, xs, Bc, Cc, dt = jnp.split(
        zxbcdt, [di, 2 * di, 2 * di + n, 2 * di + 2 * n], axis=-1)

    conv_in = jnp.concatenate([xs, Bc, Cc], axis=-1)  # (B,S,di+2n)
    prev = state.conv if state is not None else None
    conv_out, new_conv = _causal_conv(conv_in, p["conv_w"], prev)
    conv_out = jax.nn.silu(conv_out)
    xs, Bc, Cc = jnp.split(conv_out, [di, di + n], axis=-1)

    dt = jax.nn.softplus(dt.astype(jnp.float32) + p["dt_bias"])  # (B,S,nh)
    A = -jnp.exp(p["A_log"].astype(jnp.float32))                 # (nh,)
    xh = xs.reshape(B_, S, nh, hd)
    if dist.active:
        xh = dist.constrain(xh, dist.batch_spec(None, dist.model_axis, None))

    ssm0 = state.ssm if state is not None else None
    if S == 1 and ssm0 is not None:
        y1, new_ssm = ssd_decode(xh[:, 0], dt[:, 0], A, Bc[:, 0], Cc[:, 0], ssm0)
        y = y1[:, None]
    else:
        chunk = min(s_cfg.chunk, S)
        pad = (-S) % chunk
        if pad:
            xh_p = jnp.pad(xh, ((0, 0), (0, pad), (0, 0), (0, 0)))
            dt_p = jnp.pad(dt, ((0, 0), (0, pad), (0, 0)))
            Bp = jnp.pad(Bc, ((0, 0), (0, pad), (0, 0)))
            Cp = jnp.pad(Cc, ((0, 0), (0, pad), (0, 0)))
        else:
            xh_p, dt_p, Bp, Cp = xh, dt, Bc, Cc
        y, new_ssm = ssd_chunked(xh_p, dt_p, A, Bp, Cp, chunk, h0=ssm0)
        y = y[:, :S]

    y = y.astype(jnp.float32) + xh.astype(jnp.float32) * p["D"][None, None, :, None]
    y = y.reshape(B_, S, di).astype(x.dtype)
    y = rms_norm(y * jax.nn.silu(z), p["norm"], cfg.norm_eps)
    out = y @ p["out_proj"]

    if state is not None or return_state:
        return out, MambaState(ssm=new_ssm, conv=new_conv)
    return out, None
