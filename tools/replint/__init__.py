"""repro-lint: repo-specific static analysis for this codebase.

Five AST checkers targeting the bug classes the repo has actually
shipped (and fixed) in past PRs:

  guarded-by      lock discipline for ``#: guarded_by self._lock``
                  annotated attributes
  host-alias      mutable numpy buffers flowing into jitted callables
                  without a defensive ``.copy()`` (the PR-5 race)
  stop-iteration  bare ``raise StopIteration`` / default-less ``next()``
                  inside generator bodies (the PR-6 class-1 bug)
  refcount-pair   page-run acquires must reach a release or an ownership
                  transfer on every exit path
  policy-purity   registered policy bodies must not mutate shared state
                  outside ``Arm.commit`` closures

Stdlib-only (``ast`` + ``re``); never imports jax or the repro package,
so it runs anywhere python runs, in well under five seconds.
"""
from tools.replint.core import Finding, lint_paths, RULES  # noqa: F401
