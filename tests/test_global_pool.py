"""Global KVCache pool: directory invariants, peer-SSD routing arm, and
the failure-injection suite for cross-node handoff (ISSUE 4).

The invariant under test throughout: the directory is ADVISORY. Peers may
die mid-transfer, remote slots may be torn or corrupt, directory entries
may point at evicted slots, and blocks may demote while a fetch is in
flight — every case must degrade to recompute with CORRECT bytes and a
recorded fallback reason. No test may ever observe wrong bytes: decode
output in a two-instance engine is asserted bit-exact vs DRAM-only.
"""
import os

import numpy as np
import pytest

try:
    from hypothesis import given, settings, strategies as st
except ImportError:
    from _hypothesis_shim import given, settings, strategies as st

from repro.core.cache import CachePool
from repro.core.conductor import Conductor, DecodeInstance, PrefillInstance
from repro.core.costmodel import CostModel, InstanceSpec
from repro.core.directory import GlobalBlockDirectory
from repro.core.messenger import Messenger
from repro.core.policies import get_policy, list_policies
from repro.core.policies.base import PolicyContext
from repro.core.policies.routing import ssd_load_arm
from repro.core.tiered import TieredCachePool
from repro.core.trace import BLOCK_TOKENS, Request

CFG_NAME = "llama2-70b"


def _cost():
    from repro.configs.base import get_config
    return CostModel(get_config(CFG_NAME), InstanceSpec())


def _req(rid=0, n_blocks=8, out=64):
    return Request(req_id=rid, timestamp=0,
                   input_length=n_blocks * BLOCK_TOKENS, output_length=out,
                   hash_ids=list(range(n_blocks)))


# ---------------------------------------------------------------------------
# directory unit behaviour
# ---------------------------------------------------------------------------

def test_register_is_at_most_once_per_node_key():
    d = GlobalBlockDirectory()
    d.register(1, "a", "dram")
    d.register(1, "a", "ssd")           # tier move, not a second owner
    assert d.holders(1) == {"a": "ssd"}
    d.register(1, "b", "dram")
    assert d.holders(1) == {"a": "ssd", "b": "dram"}
    assert len(d) == 1


def test_unregister_and_drop_node_leave_no_danglers():
    d = GlobalBlockDirectory()
    for k in (1, 2, 3):
        d.register(k, "a", "ssd")
    d.register(2, "b", "dram")
    assert d.unregister(1, "a") and not d.unregister(1, "a")
    assert d.nodes_with(1) == []
    assert d.drop_node("a") == 2
    assert d.holders(2) == {"b": "dram"}
    assert len(d) == 1                  # keys with zero owners disappear


def test_pick_owner_prefers_dram_and_is_deterministic():
    d = GlobalBlockDirectory()
    d.register(5, 2, "ssd")
    d.register(5, 3, "dram")
    d.register(5, 1, "dram")
    assert d.pick_owner(5) == (1, "dram")       # dram first, smallest id
    assert d.pick_owner(5, exclude=(1,)) == (3, "dram")
    assert d.pick_owner(5, among=(2,)) == (2, "ssd")
    assert d.pick_owner(5, among=()) is None
    with pytest.raises(ValueError, match="tier"):
        d.register(5, 1, "tape")


def test_best_ssd_extension_single_source_run():
    d = GlobalBlockDirectory()
    for k in (0, 1, 2):
        d.register(k, "a", "ssd")
    d.register(0, "b", "ssd")
    d.register(3, "b", "ssd")
    k, node = d.best_ssd_extension([0, 1, 2, 3, 4], start=0)
    assert (k, node) == (3, "a")        # the longest single-node run wins
    assert d.best_ssd_extension([0, 1, 2], start=0,
                                exclude={"a", "b"}) == (0, None)
    assert d.best_ssd_extension([9], start=0) == (0, None)
    assert d.best_ssd_extension([0], start=5) == (0, None)


# ---------------------------------------------------------------------------
# property tests: directory vs a reference model, and vs a bound pool
# ---------------------------------------------------------------------------

@settings(max_examples=80)
@given(st.lists(st.tuples(st.integers(0, 3), st.integers(0, 7),
                          st.integers(0, 2)), min_size=0, max_size=60))
def test_directory_matches_reference_model(ops):
    """register/unregister/drop interleavings vs a dict-of-dicts model:
    at-most-once per (node, key), and lookups never name a dropped node."""
    d = GlobalBlockDirectory()
    model: dict = {}
    for op, key, node in ops:
        if op == 0:
            d.register(key, node, "dram")
            model.setdefault(key, {})[node] = "dram"
        elif op == 1:
            d.register(key, node, "ssd")
            model.setdefault(key, {})[node] = "ssd"
        elif op == 2:
            d.unregister(key, node)
            model.get(key, {}).pop(node, None)
        else:
            d.drop_node(node)
            for h in model.values():
                h.pop(node, None)
        model = {k: h for k, h in model.items() if h}
        assert d.holders(key) == model.get(key, {})
        for t in (None, "dram", "ssd"):
            assert d.nodes_with(key, t) == sorted(
                n for n, tier in model.get(key, {}).items()
                if t is None or tier == t)
    assert d.snapshot() == model


@settings(max_examples=60)
@given(st.lists(st.tuples(st.integers(0, 3), st.integers(0, 9)),
                min_size=0, max_size=50))
def test_bound_pool_view_stays_consistent(ops):
    """Random demote/promote/drop/insert traffic through a bound
    TieredCachePool: the directory's view of the node equals the pool's
    actual residency after EVERY operation (hooks can't drift)."""
    d = GlobalBlockDirectory()
    pool = TieredCachePool(3, 5)
    d.bind("n0", pool)
    for op, key in ops:
        if op == 0:
            pool.insert([key])
        elif op == 1:
            pool.lookup([key])          # SSD hits promote
        elif op == 2:
            pool.discard(key)
        else:
            pool.insert([key, (key + 1) % 10])
        view = {k: h["n0"] for k, h in d.snapshot().items() if "n0" in h}
        actual = {k: "dram" for k in pool.blocks}
        actual.update({k: "ssd" for k in pool.ssd.blocks})
        assert view == actual


def test_bind_seeds_existing_residency_and_chains_hooks():
    pool = TieredCachePool(2, 4)
    demoted, dropped = [], []
    pool.on_demote = demoted.append
    pool.on_drop = dropped.append
    pool.insert([1, 2, 3])              # 1 demoted to SSD (cap 2)
    d = GlobalBlockDirectory()
    d.bind("x", pool)
    assert d.holders(1) == {"x": "ssd"}
    assert d.holders(2) == {"x": "dram"}
    pool.insert([4])                    # demotes another block
    assert demoted == [1, 2], "bind must preserve pre-existing hooks"
    assert d.nodes_with(2) == ["x"] and d.holders(2) == {"x": "ssd"}


# ---------------------------------------------------------------------------
# the peer-SSD routing arm (simulator side)
# ---------------------------------------------------------------------------

def make_global_cluster(strategy="kvcache", ttft_slo=30.0):
    """Two prefill instances sharing a directory: B holds chain [0..6)
    with the head [0,1,2] demoted to its SSD (via the real demotion
    path, so the directory learned it); A is cold and its queue is free
    while B's is jammed — the regime where A fetching B's SSD prefix
    beats both A-recompute and anything on B."""
    d = GlobalBlockDirectory()
    P = [PrefillInstance(iid=i, pool=TieredCachePool(64, 512), cost=_cost())
         for i in range(2)]
    # B's pool: insert 6, then cap-3 churn demotes the head
    pb = TieredCachePool(3, 512)
    P[1] = PrefillInstance(iid=1, pool=pb, cost=_cost())
    for p in P:
        d.bind(p.iid, p.pool)
    pb.insert(range(6))                 # LRU: 0,1,2 demote to SSD
    assert pb.tier_prefix(list(range(6))).ssd == 3
    D = [DecodeInstance(iid=100, cost=_cost())]
    msg = Messenger([0, 1, 100], bw=100e9)
    for p in P:
        msg.add_ssd_channel(p.iid, 6e9)
    P[1].queue_free_at = 25.0           # jam B
    c = Conductor(P, D, msg, ttft_slo=ttft_slo, tbt_slo=0.1,
                  strategy=strategy, directory=d)
    return c, P, D, d


def snapshot(c, d):
    return (
        tuple((p.queue_free_at, p.total_busy, p.n_scheduled,
               tuple(sorted(p.pool.blocks)),
               tuple(sorted(getattr(p.pool, "ssd", p.pool).blocks)))
              for p in c.P),
        tuple((dd.pending, dd.pending_tokens, dd.n_scheduled) for dd in c.D),
        tuple(sorted((k, l.busy_until, l.n_transfers)
                     for k, l in c.messenger.links.items())),
        tuple(sorted((k, l.busy_until, l.n_transfers)
                     for k, l in c.messenger.ssd_links.items())),
        (c.n_migrations, c.n_ssd_loads, c.n_peer_ssd_loads),
        d.snapshot(),
    )


@pytest.mark.parametrize("strategy", ["kvcache", "why_not_both",
                                      "load_aware"])
def test_peer_ssd_arm_proposed_and_pure(strategy):
    c, P, D, d = make_global_cluster(strategy)
    before = snapshot(c, d)
    arms = c.propose(_req(), now=0.0)
    peer = [a for a in arms if a.kind == "peer_ssd"]
    assert peer, f"{strategy} must propose the peer-SSD arm"
    a = min(peer, key=lambda a: a.ttft)
    assert a.instance is P[0] and a.transfer_from is P[1]
    assert a.peer_ssd_blocks == 3 and a.prefix_blocks == 3
    assert snapshot(c, d) == before, "propose must not mutate state"
    arms2 = c.propose(_req(), now=0.0)
    assert [x.ttft for x in arms] == [x.ttft for x in arms2]


def test_peer_ssd_commit_happens_once_and_replicates():
    c, P, D, d = make_global_cluster()
    dec = c.schedule(_req(), now=0.0)
    assert dec.accepted and dec.arm_kind == "peer_ssd"
    assert dec.prefill is P[0] and dec.peer_ssd_blocks == 3
    assert c.n_peer_ssd_loads == 1
    # the fetched span REPLICATED into A (B keeps its SSD copy), and the
    # directory learned A's new DRAM residency through the bound hooks
    assert P[0].pool.prefix_len(list(range(8))) == 8
    assert d.holders(0)[0] == "dram" and d.holders(0)[1] == "ssd"
    # both of B's pipes carried the fetch: SSD read, then the egress hop
    assert c.messenger.ssd_links[1].n_transfers == 1
    assert c.messenger.links[1].n_transfers == 1
    assert dec.ssd_load_time > 0.0


def test_peer_ssd_reject_leaves_state_untouched():
    c, P, D, d = make_global_cluster(ttft_slo=1e-12)
    before = snapshot(c, d)
    dec = c.schedule(_req(), now=0.0)
    assert not dec.accepted and dec.reject_reason
    assert snapshot(c, d) == before


def test_no_directory_means_no_peer_arm():
    c, P, D, d = make_global_cluster()
    c.ctx.directory = None
    assert not any(a.kind == "peer_ssd" for a in c.propose(_req(), 0.0))


def test_cache_aware_never_proposes_peer_arms():
    c, P, D, d = make_global_cluster("cache_aware")
    kinds = {a.kind for a in c.propose(_req(), 0.0)}
    assert "peer_ssd" not in kinds and "peer_fetch" not in kinds


def test_two_node_sim_uses_peer_ssd_and_wins_ttft():
    """End-to-end deterministic sim: doc revisits on a 2-node cluster —
    the global pool must engage the peer-SSD arm and not lose p90 TTFT."""
    from repro.configs.base import CacheTierSpec, ClusterSpec, get_config
    from repro.core.simulator import MooncakeCluster
    from repro.core.trace import TraceSpec, generate_trace
    trace = generate_trace(TraceSpec(
        n_requests=300, duration_ms=240_000, seed=7, frac_chat=0.25,
        frac_doc=0.55, frac_oneshot=0.20, doc_len_mu=9.6, doc_len_sigma=0.6))
    uniq = len({h for r in trace for h in r.hash_ids})
    dram = max(int(uniq * 0.02), 64)
    spec = ClusterSpec(n_prefill=2, n_decode=2, tbt_slo=0.2,
                       cache=CacheTierSpec(dram_blocks=dram,
                                           ssd_blocks=8 * dram))
    res = {}
    for gp in (False, True):
        res[gp] = MooncakeCluster.from_spec(
            get_config(CFG_NAME), spec.replace(global_pool=gp)).run(trace)
    assert res[True].n_peer_ssd_loads > 0
    assert res[False].n_peer_ssd_loads == 0
    assert res[True].ttft_p90() <= res[False].ttft_p90()
    assert any(r.peer_ssd_blocks for r in res[True].records)


# ---------------------------------------------------------------------------
# modeled-vs-measured: the store's read EMA pins simulator arm prices
# ---------------------------------------------------------------------------

def test_measured_ema_pins_costmodel_and_arm_prices():
    cost = _cost()
    spec_sheet = cost.ssd_load_time(1024)
    measured = 0.004                     # 4 ms per 512-token block
    cost.calibrate_ssd_read(measured)
    assert cost.ssd_calibrated
    assert cost.ssd_load_time(1024) == pytest.approx(2 * measured)
    assert cost.ssd_load_time(1024) != pytest.approx(spec_sheet)
    assert cost.peer_ssd_load_time(1024) == pytest.approx(
        2 * measured + cost.transfer_time(1024))
    with pytest.raises(ValueError):
        cost.calibrate_ssd_read(0.0)

    # an SSD-load arm priced WITHOUT a messenger channel must charge the
    # measured value (the simulator's channel-free fallback path)
    pool = TieredCachePool(2, 64)
    pool.insert(range(4))                # head demoted (cap 2)
    n_ssd = pool.tier_prefix(list(range(4))).ssd
    assert n_ssd == 2
    inst = PrefillInstance(iid=0, pool=pool, cost=cost)
    ctx = PolicyContext(messenger=Messenger([], bw=100e9))
    r = _req(n_blocks=4)
    arm = ssd_load_arm(ctx, inst, r, 0.0)
    assert arm.ttft == pytest.approx(
        n_ssd * measured + cost.prefill_time(r.input_length, 4 * 512))
    assert arm.land(0.0) == pytest.approx(n_ssd * measured)


def test_messenger_set_ssd_bw_recalibrates_channel():
    msg = Messenger([0], bw=100e9)
    msg.add_ssd_channel(0, 6e9)
    assert msg.estimate_ssd(0, 6e9, 0.0) == pytest.approx(1.0)
    msg.set_ssd_bw(0, 3e9)               # measured: half the spec sheet
    assert msg.estimate_ssd(0, 6e9, 0.0) == pytest.approx(2.0)
    msg.set_ssd_bw(7, 1e9)               # unknown node: channel appears
    assert msg.has_ssd_channel(7)


def test_peer_ssd_messenger_pricing_composes_both_pipes():
    msg = Messenger([0, 1], bw=10e9)
    msg.add_ssd_channel(1, 5e9)
    nbytes = 10e9
    # idle: read 2s + hop 1s
    assert msg.estimate_peer_ssd(1, nbytes, 0.0) == pytest.approx(3.0)
    # backlogged egress that drains DURING the read costs only the excess
    msg.links[1].busy_until = 1.5
    assert msg.estimate_peer_ssd(1, nbytes, 0.0) == pytest.approx(3.0)
    msg.links[1].busy_until = 2.5
    assert msg.estimate_peer_ssd(1, nbytes, 0.0) == pytest.approx(3.5)
    assert msg.estimate_peer_ssd(0, nbytes, 0.0) == float("inf")
    done = msg.enqueue_peer_ssd(1, nbytes, 0.0)
    assert done == pytest.approx(3.5)
    assert msg.ssd_links[1].n_transfers == 1
    assert msg.links[1].n_transfers == 1


# ---------------------------------------------------------------------------
# session_affinity decode policy
# ---------------------------------------------------------------------------

def test_session_affinity_registered_and_swept():
    assert "session_affinity" in list_policies("decode")


def test_session_affinity_sticks_within_bound_then_degrades():
    ctx = PolicyContext(messenger=Messenger([0, 1], bw=100e9))
    pol = get_policy("decode", "session_affinity")(ctx)
    mk = lambda iid: DecodeInstance(iid=iid, cost=_cost())
    d0, d1 = mk(0), mk(1)
    turn1 = Request(req_id=0, timestamp=0, input_length=1024,
                    output_length=64, hash_ids=[11, 12])
    pick, tbt = pol.select(turn1, [d0, d1], 0.0)
    home = pick
    assert tbt == pick.predicted_tbt(1, 1024 + 64)
    # next turn extends the chain; mildly disadvantage the home node —
    # within the 1.5× bound the session must return home anyway
    other = d1 if home is d0 else d0
    home.active, home.kv_tokens = 2, 60_000.0
    turn2 = Request(req_id=1, timestamp=0, input_length=2048,
                    output_length=64, hash_ids=[11, 12, 13])
    t_home = home.predicted_tbt(1, 2048 + 64)
    t_other = other.predicted_tbt(1, 2048 + 64)
    assert t_other < t_home <= pol.max_tbt_ratio * t_other
    pick2, tbt2 = pol.select(turn2, [d0, d1], 0.0)
    assert pick2 is home, "within the bound the session stays home"
    assert tbt2 == t_home, "returned TBT stays the honest prediction"
    # overload home past the bound: stickiness must yield to min_tbt
    home.active, home.kv_tokens = 64, 8_000_000.0
    turn3 = Request(req_id=2, timestamp=0, input_length=2048,
                    output_length=64, hash_ids=[11, 12, 13, 14])
    assert home.predicted_tbt(1, 2048 + 64) \
        > pol.max_tbt_ratio * other.predicted_tbt(1, 2048 + 64)
    pick3, _ = pol.select(turn3, [d0, d1], 0.0)
    assert pick3 is other, "past the bound the session degrades to min_tbt"
    # a fresh session is unaffected by the old one's map
    fresh = Request(req_id=3, timestamp=0, input_length=512,
                    output_length=32, hash_ids=[99])
    pick4, _ = pol.select(fresh, [d0, d1], 0.0)
    assert pick4 is other


def test_session_affinity_map_is_bounded_lru():
    ctx = PolicyContext(messenger=Messenger([0, 1], bw=100e9))
    pol = get_policy("decode", "session_affinity")(ctx)
    pol.max_tracked_blocks = 8
    D = [DecodeInstance(iid=0, cost=_cost()),
         DecodeInstance(iid=1, cost=_cost())]
    for i in range(20):
        r = Request(req_id=i, timestamp=0, input_length=512,
                    output_length=32, hash_ids=[1000 + i])
        pol.select(r, D, 0.0)
    assert len(pol._home) == 8, "placement map must stay bounded"
    assert 1019 in pol._home and 1000 not in pol._home, \
        "eviction must be LRU (old idle sessions age out first)"


def test_session_affinity_ignores_home_without_headroom():
    ctx = PolicyContext(messenger=Messenger([0, 1], bw=100e9))
    pol = get_policy("decode", "session_affinity")(ctx)
    cost = _cost()
    d0 = DecodeInstance(iid=0, cost=cost)
    d1 = DecodeInstance(iid=1, cost=cost)
    r = Request(req_id=0, timestamp=0, input_length=1024, output_length=64,
                hash_ids=[5])
    pick, _ = pol.select(r, [d0, d1], 0.0)
    pick.kv_tokens = cost.decode_capacity_tokens()   # home now VRAM-full
    r2 = Request(req_id=1, timestamp=0, input_length=1024, output_length=64,
                 hash_ids=[5, 6])
    pick2, _ = pol.select(r2, [d0, d1], 0.0)
    assert pick2 is not pick


# ---------------------------------------------------------------------------
# failure injection: two-instance engine, every case degrades to
# recompute with CORRECT bytes — decode bit-exact vs DRAM-only
# ---------------------------------------------------------------------------

@pytest.fixture(scope="module")
def setup():
    import jax

    from repro.configs.base import get_config
    from repro.models.transformer import init_params
    cfg = get_config("smollm-360m").reduced()
    params = init_params(cfg, jax.random.PRNGKey(0))
    rng = np.random.default_rng(42)
    doc = rng.integers(0, cfg.vocab_size, 2 * BLOCK_TOKENS)
    q1 = np.concatenate([doc, rng.integers(0, cfg.vocab_size, 48)])
    q2 = np.concatenate([doc, rng.integers(0, cfg.vocab_size, 48)])
    return cfg, params, q1, q2


def _decode_tokens(params, cfg, pres, n=3):
    from repro.serving.engine import DecodeWorker
    dw = DecodeWorker(params, cfg, max_batch=1,
                      max_len=pres.prompt_len + n + 4)
    dw.join(0, pres, max_new=n)
    out = [pres.first_token]
    while dw.n_active:
        out.extend(tok for _rid, tok, _f in dw.step())
    return out


@pytest.fixture(scope="module")
def dram_reference(setup):
    from repro.serving.engine import HostKVPool, PrefillWorker
    cfg, params, q1, q2 = setup
    pool = HostKVPool()
    pw = PrefillWorker(params, cfg, pool, prefill_chunk=128)
    pw(q1)
    return _decode_tokens(params, cfg, pw(q2))


def _two_nodes(setup, tmp_path, *, a_dram=1, b_dram=None, ssd_mode="overlap",
               flush=True, run_cold=True):
    """Shared-directory A/B pair: cold prefill lands on A (cap ``a_dram``
    demotes the doc to A's store when 1); returns (dir, pools, workers)."""
    from repro.serving.engine import HostKVPool, PrefillWorker, connect_pools
    cfg, params, q1, _ = setup
    d = GlobalBlockDirectory()
    pa = HostKVPool(capacity_blocks=a_dram, ssd_capacity_blocks=64,
                    ssd_dir=str(tmp_path / "a"), writeback_batch=1,
                    directory=d, node_id=0)
    pb = HostKVPool(capacity_blocks=b_dram, ssd_capacity_blocks=64,
                    ssd_dir=str(tmp_path / "b"), directory=d, node_id=1)
    connect_pools([pa, pb])
    pw_a = PrefillWorker(params, cfg, pa, prefill_chunk=128,
                         ssd_mode=ssd_mode)
    pw_b = PrefillWorker(params, cfg, pb, prefill_chunk=128,
                         ssd_mode=ssd_mode)
    if run_cold:
        pw_a(q1)
        if flush:
            pa.store.flush()
    return d, pa, pb, pw_a, pw_b


@pytest.mark.parametrize("mode", ["blocking", "overlap"])
def test_peer_ssd_handoff_bit_exact(setup, dram_reference, tmp_path, mode):
    cfg, params, _, q2 = setup
    d, pa, pb, _, pw_b = _two_nodes(setup, tmp_path / mode, ssd_mode=mode)
    pres = pw_b(q2)
    assert pres.peer_blocks == 2 and pres.reused_blocks == 2
    assert _decode_tokens(params, cfg, pres) == dram_reference
    assert pb.peer_fetch_failures == 0 and not pb.fallback_reasons
    # B now owns the blocks too — the directory reflects the replication
    assert any(t == "dram" for t in d.holders(
        next(iter(pb.data))).values())
    pa.close()
    pb.close()


def test_peer_dram_handoff_bit_exact(setup, dram_reference, tmp_path):
    cfg, params, _, q2 = setup
    d, pa, pb, _, pw_b = _two_nodes(setup, tmp_path, a_dram=None,
                                    flush=False)
    pres = pw_b(q2)
    assert pres.peer_blocks == 2
    assert _decode_tokens(params, cfg, pres) == dram_reference
    assert pa.store.layer_reads == 0, "bytes came off A's DRAM, not disk"
    pa.close()
    pb.close()


@pytest.mark.parametrize("mode", ["blocking", "overlap"])
def test_dead_peer_falls_back_to_recompute(setup, dram_reference, tmp_path,
                                           mode):
    """Peer dies before the transfer: every read against it fails, the
    fetch degrades to recompute with the reason recorded."""
    cfg, params, _, q2 = setup
    d, pa, pb, _, pw_b = _two_nodes(setup, tmp_path / ("dead_" + mode),
                                    ssd_mode=mode)
    pa.kill()
    pres = pw_b(q2)
    assert pres.peer_blocks == 0
    assert _decode_tokens(params, cfg, pres) == dram_reference
    assert pb.fallback_reasons.get("peer_unreachable", 0) >= 1
    assert pb.peer_fetch_failures >= 1
    pa.close()
    pb.close()


def test_peer_dies_mid_transfer_protocol(setup, tmp_path):
    """Pool-level protocol: the peer dies AFTER the plan resolved to it
    (the directory still names it) — start/finish must fail every layer
    and report zero usable blocks, never partial garbage."""
    from repro.serving.engine import prefix_hash_ids
    cfg, params, q1, q2 = setup
    d, pa, pb, _, _ = _two_nodes(setup, tmp_path)
    hids = prefix_hash_ids(q2)[:2]
    plan = pb.plan_fetch(hids)
    assert plan.tiers == ["peer", "peer"]
    pa.kill()                            # dies between plan and transfer
    handle = pb.start_prefetch(plan)
    n = pb.finish_fetch(plan, handle)
    assert n == 0
    assert pb.fallback_reasons.get("peer_unreachable", 0) >= 1
    assert all(h not in pb.data for h in hids), "no partial installs"
    assert all(h not in pb.meta for h in hids), "no metadata claims"
    pa.close()
    pb.close()


@pytest.mark.parametrize("mode", ["blocking", "overlap"])
def test_corrupt_remote_block_falls_back(setup, dram_reference, tmp_path,
                                         mode):
    """Torn/corrupt remote slots: the peer's per-layer CRCs reject the
    bytes; the fetch truncates to recompute — wrong bytes impossible."""
    cfg, params, _, q2 = setup
    d, pa, pb, _, pw_b = _two_nodes(setup, tmp_path / ("bad_" + mode),
                                    ssd_mode=mode)
    with open(pa.store.path, "r+b") as f:    # corrupt EVERY on-disk block
        size = os.path.getsize(pa.store.path)
        f.seek(pa.store._hdr_size + 11)
        f.write(b"\xde\xad\xbe\xef")
        if size > pa.store._slot_size:
            f.truncate(size - pa.store._slot_size // 2)   # torn tail slot
    pres = pw_b(q2)
    assert pres.peer_blocks == 0
    assert _decode_tokens(params, cfg, pres) == dram_reference
    assert pb.fallback_reasons, "a reject reason must be recorded"
    assert set(pb.fallback_reasons) <= {"verify_failed", "stale_directory",
                                        "peer_unreachable"}
    pa.close()
    pb.close()


def test_stale_directory_entry_heals_and_recomputes(setup, dram_reference,
                                                    tmp_path):
    """Directory points at an evicted slot: A freed the block's slot but
    the (stale) plan still names A — fetch fails with stale_directory,
    the bogus claim is withdrawn, decode stays bit-exact."""
    from repro.serving.engine import prefix_hash_ids
    cfg, params, _, q2 = setup
    d, pa, pb, _, pw_b = _two_nodes(setup, tmp_path)
    hids = prefix_hash_ids(q2)
    plan = pb.plan_fetch(hids[:2])
    assert plan.has_remote
    for h in hids[:2]:                   # slots evicted behind the plan
        pa.store.delete(h)
    n = pb.finish_fetch(plan)
    assert n == 0
    assert pb.fallback_reasons.get("stale_directory", 0) >= 1
    assert 0 not in d.holders(hids[0]), "the stale claim must be withdrawn"
    pres = pw_b(q2)                      # full revisit now recomputes
    assert _decode_tokens(params, cfg, pres) == dram_reference
    pa.close()
    pb.close()


def test_demote_during_fetch_still_serves_correct_bytes(setup, tmp_path):
    """Concurrent demote-during-fetch: the plan resolved to A's DRAM, then
    A demotes the blocks to its store mid-flight. The peer read falls
    through DRAM→store and must deliver the SAME bytes (or fail clean —
    never wrong bytes). Here the staged store copy serves them."""
    from repro.serving.engine import prefix_hash_ids
    cfg, params, q1, q2 = setup
    d, pa, pb, _, _ = _two_nodes(setup, tmp_path, a_dram=None, flush=False)
    hids = prefix_hash_ids(q2)[:2]
    expected = {h: (pa.data[h][0].copy(), pa.data[h][1].copy())
                for h in hids}
    plan = pb.plan_fetch(hids)
    assert plan.tiers == ["peer", "peer"]
    for h in hids:                       # A's DRAM churns mid-fetch
        pa.meta._evict(h)
    assert all(h not in pa.data for h in hids)
    handle = pb.start_prefetch(plan)
    n = pb.finish_fetch(plan, handle)
    assert n == 2
    gk, gv = pb.get(hids)
    assert np.array_equal(gk, np.concatenate(
        [expected[h][0] for h in hids], axis=1))
    assert np.array_equal(gv, np.concatenate(
        [expected[h][1] for h in hids], axis=1))
    pa.close()
    pb.close()

