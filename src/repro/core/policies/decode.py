"""Built-in decode-placement policy.

``min_tbt`` is the paper's SelectDecodingInstance: among instances with
VRAM headroom, the one whose predicted TBT after joining is lowest.

``include_pending`` is the Conductor's ``accounting`` knob (§7.2): the
naive baseline pre-selects on the CURRENT decode state only — accepted
requests still prefilling are invisible (the time lag that causes wasted
prefill) — while pending-aware accounting counts in-flight commitments.
"""
from __future__ import annotations

from repro.core.policies.base import PolicyContext, register_policy


@register_policy("decode", "min_tbt")
class MinTBTDecode:
    def __init__(self, ctx: PolicyContext) -> None:
        self.ctx = ctx

    def select(self, req, instances, now, include_pending: bool = True):
        tokens = req.input_length + req.output_length
        ok = [d for d in instances if d.vram_ok(tokens, include_pending)]
        if not ok:
            return None, float("inf")
        d = min(ok, key=lambda d: d.predicted_tbt(
            1, tokens, include_pending=include_pending))
        return d, d.predicted_tbt(1, tokens, include_pending=include_pending)
