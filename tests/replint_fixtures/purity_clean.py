"""CLEAN fixture: effects captured in commit closures. Parsed by
replint only — never imported."""
from repro.core.policies.base import Arm, register_policy


@register_policy("routing", "patient_sender")
class PatientSender:
    def propose(self, ctx, inst):
        cost = ctx.messenger.eta(inst.nid)   # read-only query: fine

        def commit(now):
            # effects live HERE: only the winning arm's commit runs
            ctx.messenger.enqueue(inst.nid, ctx.blocks)
            ctx.pool.insert(ctx.key, ctx.blocks)

        return [Arm("peer_fetch", cost, commit=commit)]

    def select(self, arms, ctx):
        self._last = arms[0].kind            # policy-internal memory: fine
        self.history.append(arms[0].kind)    # self attribute: fine
        return min(arms, key=lambda a: a.cost)
