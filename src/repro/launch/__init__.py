"""Distribution & launch: production meshes, sharding rules, step
functions, the multi-pod dry-run driver, and train/serve CLIs."""
