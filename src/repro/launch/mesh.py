"""Production meshes (DESIGN.md §7).

Single pod: a 16×16 TPU v5e slice (256 chips), axes (data, model).
Multi-pod: 2 pods = 512 chips, axes (pod, data, model) — the ``pod`` axis
carries only data/pipeline parallelism, never weight sharding (the paper's
"don't extend TP across the slow fabric" mapped to ICI-vs-DCI).

Defined as FUNCTIONS so importing this module never touches jax device
state (device count is locked at first jax init; the dry-run sets
``xla_force_host_platform_device_count=512`` before importing jax).

Supports jax >= 0.4.35 (first release with ``jax.make_mesh``):
``jax.sharding.AxisType`` only exists from 0.5, so ``compat_make_mesh``
passes ``axis_types`` only where available — Auto is the default there
anyway, and pre-0.5 meshes are implicitly Auto.
"""
from __future__ import annotations

import jax


def compat_shard_map(f, *, mesh, in_specs, out_specs, check_vma=None):
    """``jax.shard_map`` across jax versions: the top-level alias and its
    ``check_vma`` kwarg arrived post-0.4.37; before that it lives in
    ``jax.experimental.shard_map`` with the kwarg spelled ``check_rep``."""
    sm = getattr(jax, "shard_map", None)
    if sm is not None:
        kw = {} if check_vma is None else {"check_vma": check_vma}
        return sm(f, mesh=mesh, in_specs=in_specs, out_specs=out_specs, **kw)
    from jax.experimental.shard_map import shard_map as sm
    kw = {} if check_vma is None else {"check_rep": check_vma}
    return sm(f, mesh=mesh, in_specs=in_specs, out_specs=out_specs, **kw)


def compat_make_mesh(shape, axes):
    """``jax.make_mesh`` with explicit Auto axis_types where supported."""
    axis_type = getattr(jax.sharding, "AxisType", None)
    kw = {} if axis_type is None else {
        "axis_types": (axis_type.Auto,) * len(axes)}
    return jax.make_mesh(tuple(shape), tuple(axes), **kw)


def make_production_mesh(*, multi_pod: bool = False):
    shape = (2, 16, 16) if multi_pod else (16, 16)
    axes = ("pod", "data", "model") if multi_pod else ("data", "model")
    return compat_make_mesh(shape, axes)


def make_test_mesh(data: int = 2, model: int = 2):
    """Small mesh for CPU unit tests (requires forced host device count)."""
    return compat_make_mesh((data, model), ("data", "model"))


def parse_mesh_arg(spec: str) -> tuple[int, int]:
    """``--mesh DxM`` → (data, model). Accepts '2x2', '4x1', '1x2'."""
    try:
        d, m = spec.lower().split("x")
        d, m = int(d), int(m)
    except ValueError:
        raise ValueError(
            f"--mesh wants DATAxMODEL (e.g. 2x2), got {spec!r}") from None
    if d < 1 or m < 1:
        raise ValueError(f"--mesh axes must be >= 1, got {spec!r}")
    return d, m


def make_decode_mesh(data: int, model: int):
    """(data, model) mesh for the sharded paged decode engine. Unlike
    ``compat_make_mesh`` this takes the FIRST data*model devices rather
    than requiring an exact device-count match, so ``--mesh 2x2`` works
    on any host with >= 4 (virtual) devices."""
    import numpy as np
    need = data * model
    devs = jax.devices()
    if len(devs) < need:
        raise RuntimeError(
            f"mesh {data}x{model} needs {need} devices but jax sees "
            f"{len(devs)} — on CPU set "
            f"XLA_FLAGS=--xla_force_host_platform_device_count={need} "
            f"before the first jax import")
    if len(devs) == need:
        return compat_make_mesh((data, model), ("data", "model"))
    return jax.sharding.Mesh(
        np.asarray(devs[:need]).reshape(data, model), ("data", "model"))


def make_stage_mesh(stages: int):
    """CPP pipeline mesh (§5.1): one axis of prefill-group stages."""
    return compat_make_mesh((stages,), ("stage",))


def batch_axes_of(mesh) -> tuple:
    """Mesh axes that carry the batch dimension (everything except
    'model' / 'stage')."""
    return tuple(a for a in mesh.axis_names if a in ("pod", "data"))
