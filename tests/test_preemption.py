"""Decode preemption with victim spill to the host KV tier, the unified
ServingRequest/RequestOutput surface, the cross-component stats()
protocol, and the submit() queue-cap race fix."""
import threading
import warnings

import jax
import numpy as np
import pytest

from repro.configs.base import get_config
from repro.models.transformer import init_params
from repro.serving.engine import (DecodeWorker, HostKVPool, PrefillWorker,
                                  plan_restore)
from repro.serving.loop import ServingLoop
from repro.serving.paged_cache import DevicePagePool
from repro.serving.request import RequestOutput, ServingRequest


@pytest.fixture(scope="module")
def setup():
    cfg = get_config("smollm-360m").reduced()
    params = init_params(cfg, jax.random.PRNGKey(0))
    return cfg, params


def _mk(cfg, params, *, max_batch=4, max_len=512, n_pages=None,
        n_workers=1, chunk=64):
    n_pages = n_pages or 1 + (max_batch + 2) * (max_len // 64)
    pp = DevicePagePool(cfg, n_pages=n_pages, page_tokens=64)
    pool = HostKVPool()
    pws = [PrefillWorker(params, cfg, pool, prefill_chunk=chunk,
                         page_pool=pp) for _ in range(n_workers)]
    dw = DecodeWorker(params, cfg, max_batch=max_batch, max_len=max_len,
                      substrate="paged", page_pool=pp)
    return pws, dw, pp, pool


def _req(rid, toks, max_new, **kw):
    return ServingRequest(req_id=rid, tokens=toks, max_new=max_new, **kw)


def _oracle(cfg, params, reqs, max_news):
    """Request-at-a-time reference streams (never preempted)."""
    pool = HostKVPool()
    pw = PrefillWorker(params, cfg, pool, prefill_chunk=64)
    dw = DecodeWorker(params, cfg, max_batch=1, max_len=1024)
    out = {}
    for rid, toks in reqs.items():
        res = pw(toks)
        dw.join(_req(rid, toks, max_news[rid]), res)
        seq = [res.first_token]
        while dw.n_active:
            for r, tok, fin in dw.step():
                seq.append(tok)
        out[rid] = seq
    return out


# ---------------------------------------------------------------------------
# export/import: the device→host demotion primitive
# ---------------------------------------------------------------------------

def test_export_run_roundtrip_transfers_ownership(setup):
    """export_run returns host copies and RELEASES the run (ownership
    transfer); import_run brings the bytes back page-exact. The exported
    arrays must not alias device pages that get recycled in between."""
    cfg, params = setup
    pp = DevicePagePool(cfg, n_pages=32, page_tokens=64)
    rng = np.random.default_rng(0)
    L, _, _, KV, Dh = pp.k_pages.shape
    n_tokens = 150                          # 3 pages, partial tail
    k = rng.standard_normal((L, n_tokens, KV, Dh)).astype(np.float32)
    v = rng.standard_normal((L, n_tokens, KV, Dh)).astype(np.float32)

    pages = pp.alloc(pp.pages_for(n_tokens))
    pp.write_run(pages, k, v)
    # reference in the pool's own KV dtype (write_run may downcast)
    k_ref, v_ref = (np.asarray(a).copy()
                    for a in pp.read_seq(pages, n_tokens))
    held_before = pp.used_pages
    ek, ev = pp.export_run(pages, n_tokens)
    assert pp.used_pages == held_before - len(pages)   # released
    assert pp.counters["pages_exported"] == len(pages)

    # clobber the freed pages: the export must have deep-copied
    junk = pp.alloc(pp.pages_for(n_tokens))
    pp.write_run(junk, np.zeros_like(k), np.zeros_like(v))
    np.testing.assert_array_equal(np.asarray(ek), k_ref)
    np.testing.assert_array_equal(np.asarray(ev), v_ref)

    back = pp.import_run(ek, ev, n_tokens)
    rk, rv = pp.read_seq(back, n_tokens)
    np.testing.assert_array_equal(np.asarray(rk), k_ref)
    np.testing.assert_array_equal(np.asarray(rv), v_ref)
    assert pp.counters["pages_imported"] == len(back)
    pp.release(junk)
    pp.release(back)
    pp.check_leaks()


def test_decode_worker_preempt_and_resume_bit_exact(setup):
    """preempt() mid-decode + join(resume_emitted=...) from the spilled
    bytes must continue the stream bit-exactly, and the slot's completion
    bound (reserved_growth_pages) must not drift across the cycle."""
    cfg, params = setup
    pws, dw, pp, _ = _mk(cfg, params, max_batch=2)
    pw = pws[0]
    rng = np.random.default_rng(1)
    toks = rng.integers(0, cfg.vocab_size, 200)
    max_new = 8

    res = pw(toks)
    slot = dw.join(_req(0, toks, max_new), res)
    for _ in range(3):
        dw.step()
    reserved_before = dw.reserved_growth_pages()
    run = dw.preempt(slot)
    assert dw.n_active == 0 and dw.stats()["preemptions"] == 1
    assert run.n_tokens == 200 + len(run.emitted) - 1  # pending input unwritten

    # restore through the stage path at the spilled depth
    from repro.serving.engine import stage_run
    ids = pw.hasher.hash_ids(np.concatenate(
        [toks, np.asarray(run.emitted[:-1], toks.dtype)]))
    pages = stage_run(pp, ids, run.k, run.v, run.n_tokens)
    assert pages is not None
    from repro.serving.engine import PrefillResult
    pres = PrefillResult(first_token=run.emitted[-1], kv_k=run.k,
                         kv_v=run.v, prompt_len=run.n_tokens,
                         reused_blocks=0, new_blocks=0, hash_ids=ids,
                         pages=pages, page_pool=pp, page_gens=pp.gens_of(pages))
    dw.join(run.request, pres, resume_emitted=run.emitted)
    assert dw.reserved_growth_pages() == reserved_before  # bound invariant
    assert dw.stats()["resumed_joins"] == 1
    emitted = list(run.emitted)
    while dw.n_active:
        for _, tok, _ in dw.step():
            emitted.append(tok)

    oracle = _oracle(cfg, params, {0: toks}, {0: max_new})
    assert emitted == oracle[0]
    pp.check_leaks()


def test_preempt_dense_substrate_rejected(setup):
    cfg, params = setup
    dw = DecodeWorker(params, cfg, max_batch=1, max_len=256,
                      substrate="dense")
    with pytest.raises(RuntimeError, match="paged substrate"):
        dw.preempt(0)


def test_plan_restore_pricing():
    # forced modes win regardless of estimates
    assert plan_restore(512, reload_s_per_block=9.0,
                        recompute_s_per_block=1.0, mode="reload").mode \
        == "reload"
    # auto: cheaper measured arm wins; reload takes ties and unwarmed cases
    assert plan_restore(1024, reload_s_per_block=1.0,
                        recompute_s_per_block=2.0).mode == "reload"
    assert plan_restore(1024, reload_s_per_block=2.0,
                        recompute_s_per_block=1.0).mode == "recompute"
    assert plan_restore(1024, reload_s_per_block=1.0,
                        recompute_s_per_block=1.0).mode == "reload"
    assert plan_restore(1024, reload_s_per_block=None,
                        recompute_s_per_block=None).mode == "reload"
    p = plan_restore(1024, reload_s_per_block=None,
                     recompute_s_per_block=0.5)
    assert p.mode == "recompute" and p.est_recompute_s == pytest.approx(1.0)
    with pytest.raises(ValueError, match="unknown restore mode"):
        plan_restore(512, reload_s_per_block=1.0,
                     recompute_s_per_block=1.0, mode="warp")


def test_spill_slab_lifecycle():
    pool = HostKVPool()
    k = np.zeros((2, 8, 1, 4), np.float32)
    pool.spill_put(7, k, k, 8)
    assert pool.spill_depth() == 1
    with pytest.raises(RuntimeError, match="already has a spilled run"):
        pool.spill_put(7, k, k, 8)
    _, _, n = pool.spill_get(7)
    assert n == 8
    assert pool.spill_pop(7) and not pool.spill_pop(7)
    st = pool.stats()
    assert st["spills"] == 1 and st["spill_restores"] == 1
    assert st["spill_entries"] == 0 and st["spill_bytes"] == 0
    pool.close()


# ---------------------------------------------------------------------------
# the loop: preemption under mixed-priority contention
# ---------------------------------------------------------------------------

def _drive(loop):
    loop.close_intake()
    return loop.run()


def test_loop_preempts_low_priority_victim_bit_exact(setup):
    """Tight pool + full batch: a high-priority arrival that can never
    become obtainable by waiting must spill the low-priority victim,
    finish, and the victim must restore and complete — every stream
    bit-exact vs the never-preempted oracle."""
    cfg, params = setup
    for restore_mode in ("reload", "recompute", "auto"):
        pws, dw, pp, pool = _mk(cfg, params, max_batch=1, max_len=640,
                                n_pages=17)
        loop = ServingLoop(pws, dw, chunks_per_iter=2, max_queue=16,
                           restore_mode=restore_mode)
        rng = np.random.default_rng(10)
        victim_toks = rng.integers(0, cfg.vocab_size, 512)
        sprinter_toks = rng.integers(0, cfg.vocab_size, 128)
        max_news = {0: 24, 1: 4}
        assert loop.submit(_req(0, victim_toks, 24, priority=0))
        # let the victim join and decode a bit
        while len(loop.outputs.get(0, RequestOutput(0)).tokens) < 4:
            loop.iterate()
        assert loop.submit(_req(1, sprinter_toks, 4, priority=1))
        stats = _drive(loop)

        assert stats["completed"] == 2, restore_mode
        assert stats["preemptions"] >= 1, restore_mode
        out0 = loop.outputs[0]
        assert out0.preemptions >= 1 and len(out0.restores) >= 1
        if restore_mode != "auto":
            assert set(out0.restores) == {restore_mode}
        assert loop.outputs[1].preemptions == 0     # priority held
        oracle = _oracle(cfg, params,
                         {0: victim_toks, 1: sprinter_toks}, max_news)
        for rid in (0, 1):
            assert loop.outputs[rid].tokens == oracle[rid], \
                f"req {rid} diverged under restore_mode={restore_mode}"
        assert pool.spill_depth() == 0              # slab drained
        pp.check_leaks()
        assert stats["spill_depth"] == 0


def test_loop_preempt_disabled_and_equal_priority_defer(setup):
    """preempt=False — and equal priority classes even with it on — must
    degrade to the PR-6 defer-only behaviour: no preemptions, everything
    still completes."""
    cfg, params = setup
    for preempt, prio in ((False, 1), (True, 0)):
        pws, dw, pp, pool = _mk(cfg, params, max_batch=1, max_len=640,
                                n_pages=17)
        loop = ServingLoop(pws, dw, chunks_per_iter=2, max_queue=16,
                           preempt=preempt)
        rng = np.random.default_rng(11)
        assert loop.submit(_req(0, rng.integers(0, cfg.vocab_size, 384), 6))
        assert loop.submit(_req(1, rng.integers(0, cfg.vocab_size, 128), 3,
                                priority=prio))
        stats = _drive(loop)
        assert stats["completed"] == 2
        assert stats["preemptions"] == 0
        assert pool.spill_depth() == 0
        pp.check_leaks()


def test_loop_priority_orders_pending_joins(setup):
    """With one slot and several finished prefills pending, the higher
    priority class must join (and finish) first, FIFO within a class."""
    cfg, params = setup
    pws, dw, pp, _ = _mk(cfg, params, max_batch=1, max_len=512,
                         n_workers=2)
    loop = ServingLoop(pws, dw, chunks_per_iter=4, max_queue=16,
                       preempt=False)
    rng = np.random.default_rng(12)
    # a long blocker holds the single slot so every contender's prefill
    # finishes while it decodes — the pending-join queue then really has
    # all four at once and must drain in priority order
    assert loop.submit(_req(99, rng.integers(0, cfg.vocab_size, 64), 24,
                            priority=9))
    while dw.n_active == 0:
        loop.iterate()
    prios = {0: 3, 1: 2, 2: 1, 3: 2}
    for i, p in prios.items():
        assert loop.submit(_req(i, rng.integers(0, cfg.vocab_size, 96), 2,
                                priority=p))
    while len(loop._pending_join) < 4:
        loop.iterate()
        assert dw.n_active == 1          # blocker still pinning the slot
    stats = _drive(loop)
    assert stats["completed"] == 5
    order = [r for r in sorted(loop.outputs,
                               key=lambda r: loop.outputs[r].completed_iter)
             if r != 99]
    # non-increasing priority along the completion order
    ps = [prios[r] for r in order]
    assert ps == sorted(ps, reverse=True), (order, ps)
    assert [r for r in order if prios[r] == 2] == [1, 3]   # FIFO in class
    pp.check_leaks()


def test_loop_stop_mid_spill_releases_everything(setup):
    """stop() while a victim sits in the spill slab: no stranded slab
    entries, no leaked device pages, no stranded staged runs."""
    cfg, params = setup
    pws, dw, pp, pool = _mk(cfg, params, max_batch=1, max_len=640,
                            n_pages=17)
    loop = ServingLoop(pws, dw, chunks_per_iter=2, max_queue=16)
    rng = np.random.default_rng(13)
    assert loop.submit(_req(0, rng.integers(0, cfg.vocab_size, 512), 24))
    while len(loop.outputs.get(0, RequestOutput(0)).tokens) < 4:
        loop.iterate()
    assert loop.submit(_req(1, rng.integers(0, cfg.vocab_size, 128), 64,
                            priority=1))
    # drive until the spill happened but the victim has NOT restored
    # (the sprinter's 64 new tokens keep the slot busy a long time)
    while loop.stats()["preemptions"] == 0:
        loop.iterate()
    assert pool.spill_depth() == 1
    loop.stop()
    loop.run()
    assert dw.n_active == 0
    assert pool.spill_depth() == 0               # slab purged
    assert pool.stats()["spill_drops"] == 1      # abandoned, not restored
    pp.check_leaks()
    pool.close()


def test_loop_stop_mid_restore_releases_everything(setup):
    """stop() after the victim re-entered the pending-join path (restore
    staged or rerouted through recompute prefill) must still unwind."""
    cfg, params = setup
    pws, dw, pp, pool = _mk(cfg, params, max_batch=1, max_len=640,
                            n_pages=17)
    loop = ServingLoop(pws, dw, chunks_per_iter=1, max_queue=16,
                       restore_mode="recompute")
    rng = np.random.default_rng(14)
    assert loop.submit(_req(0, rng.integers(0, cfg.vocab_size, 512), 24))
    while len(loop.outputs.get(0, RequestOutput(0)).tokens) < 4:
        loop.iterate()
    assert loop.submit(_req(1, rng.integers(0, cfg.vocab_size, 128), 4,
                            priority=1))
    # run until the victim's recompute prefill is mid-chunks
    while loop.stats()["restores_recompute"] == 0:
        loop.iterate()
    loop.stop()
    loop.run()
    assert dw.n_active == 0
    assert pool.spill_depth() == 0
    pp.check_leaks()
    pool.close()


# ---------------------------------------------------------------------------
# unified request API + deprecation shims
# ---------------------------------------------------------------------------

def test_serving_request_validation():
    with pytest.raises(ValueError, match="max_new"):
        ServingRequest(req_id=0, tokens=np.arange(4), max_new=0)
    r = ServingRequest(req_id=1, tokens=[1, 2, 3], max_new=2)
    assert isinstance(r.tokens, np.ndarray)          # coerced


def test_submit_legacy_kwargs_deprecated(setup):
    cfg, params = setup
    pws, dw, pp, _ = _mk(cfg, params)
    loop = ServingLoop(pws, dw, max_queue=8)
    rng = np.random.default_rng(15)
    toks = rng.integers(0, cfg.vocab_size, 80)
    with pytest.warns(DeprecationWarning, match="pass a ServingRequest"):
        assert loop.submit(0, toks, max_new=2)
    stats = _drive(loop)
    assert stats["completed"] == 1
    assert loop.outputs[0].done and len(loop.outputs[0].tokens) == 2
    pp.check_leaks()


def test_join_legacy_positional_deprecated(setup):
    cfg, params = setup
    pws, dw, pp, _ = _mk(cfg, params)
    rng = np.random.default_rng(16)
    toks = rng.integers(0, cfg.vocab_size, 80)
    res = pws[0](toks)
    with pytest.warns(DeprecationWarning, match="pass a ServingRequest"):
        dw.join(0, res, max_new=2)
    while dw.n_active:
        dw.step()
    # conflicting explicit max_new must be rejected, not silently ignored
    res2 = pws[0](toks)
    with pytest.raises(ValueError, match="conflicts with request.max_new"):
        dw.join(_req(1, toks, 3), res2, max_new=4)
    res2.release_pages()
    pp.check_leaks()


def test_submit_requires_tokens(setup):
    cfg, params = setup
    pws, dw, _, _ = _mk(cfg, params)
    loop = ServingLoop(pws, dw)
    with pytest.raises(ValueError, match="tokens is required"):
        loop.submit(ServingRequest(req_id=0, tokens=None, max_new=2))


# ---------------------------------------------------------------------------
# submit() queue-cap TOCTOU
# ---------------------------------------------------------------------------

def test_submit_queue_cap_atomic_under_contention(setup):
    """The old submit read qsize() then put() without holding the lock:
    N racing submitters could all pass the cap check and overfill the
    queue. The check+enqueue are now one atomic step."""
    cfg, params = setup
    pws, dw, _, _ = _mk(cfg, params)

    class RacyLoop(ServingLoop):
        """Widen the race window: every qsize() read yields the GIL, so
        the pre-fix interleave (all threads read a below-cap size, then
        all put) is effectively guaranteed."""
        def signal(self):
            import time as _t
            sig = super().signal()
            _t.sleep(0.002)
            return sig

    cap = 4
    loop = RacyLoop(pws, dw, max_queue=cap, admission="baseline")
    rng = np.random.default_rng(17)
    toks = rng.integers(0, cfg.vocab_size, 64)
    n_threads = 16
    barrier = threading.Barrier(n_threads)
    results = [None] * n_threads

    def submitter(i):
        barrier.wait()
        results[i] = loop.submit(_req(i, toks, 1))

    threads = [threading.Thread(target=submitter, args=(i,),
                                name=f"repro-submit-{i}")
               for i in range(n_threads)]
    for t in threads:
        t.start()
    for t in threads:
        t.join()
    accepted = sum(bool(r) for r in results)
    assert loop._arrivals.qsize() == accepted
    assert accepted <= cap, \
        f"{accepted} submits raced past the max_queue={cap} cap"
    st = loop.stats()
    assert st["submitted"] == n_threads
    assert st["rejected"] == n_threads - accepted
    loop.stop()
    loop.run()
