"""Pluggable scheduling policies: Arm candidates, prefill/decode routing,
§7 admission — one string-keyed registry for all three kinds.

    from repro.core.policies import register_policy, list_policies

    @register_policy("prefill", "my_router")
    class MyRouter:
        def __init__(self, ctx): self.ctx = ctx
        def propose(self, req, instances, now): ...

See README "Adding a scheduling policy" for a worked example.
"""
from repro.core.policies.base import (Arm, DecodePolicy, PolicyContext,
                                      PrefillPolicy, get_policy,
                                      list_policies, register_policy)
from repro.core.policies.admission import (AdmissionPolicy, BaselineAdmission,
                                           EarlyRejection,
                                           PredictiveEarlyRejection,
                                           make_admission)
from repro.core.policies.routing import (CacheAwareRouting, KVCacheRouting,
                                         LoadBalanceRouting, RandomRouting,
                                         find_best_prefix, peer_fetch_arm,
                                         peer_ssd_arm, recompute_arm,
                                         ssd_load_arm)
from repro.core.policies.load_aware import LoadAwareRouting
from repro.core.policies.why_not_both import WhyNotBothRouting
from repro.core.policies.decode import (KVPressureDecode, MinTBTDecode,
                                        SessionAffinityDecode)
