"""Overload-scenario replay (§7/§8.2): watch the three admission policies
handle a 4×-speed trace replay on an 8P+8D simulated cluster — rejected
counts, wasted prefill, goodput, and the anti-phase load fluctuation that
prediction-based early rejection damps (Figures 9/10, Table 3).

    PYTHONPATH=src python examples/overload_replay.py [--requests 4000]
"""
import argparse

import numpy as np

from repro.configs.base import get_config
from repro.core import (ClusterSpec, MooncakeCluster, TraceSpec,
                        generate_trace, list_policies)


def sparkline(vals, width=60):
    bars = " ▁▂▃▄▅▆▇█"
    if not len(vals):
        return ""
    vals = np.asarray(vals, dtype=float)
    idx = np.linspace(0, len(vals) - 1, width).astype(int)
    v = vals[idx]
    hi = max(v.max(), 1e-9)
    return "".join(bars[int(min(x / hi, 1.0) * (len(bars) - 1))] for x in v)


def main():
    ap = argparse.ArgumentParser()
    ap.add_argument("--requests", type=int, default=4000)
    ap.add_argument("--speedup", type=float, default=4.0)
    args = ap.parse_args()

    cfg = get_config("llama2-70b")
    reqs = generate_trace(TraceSpec(n_requests=args.requests, seed=2,
                                    out_mu=5.9))
    print(f"replaying {len(reqs)} requests at {args.speedup}x on 8P+8D\n")
    for adm in list_policies("admission"):
        spec = ClusterSpec(n_prefill=8, n_decode=8, ttft_slo=30,
                           tbt_slo=0.1, admission=adm, t_d=20.0)
        mc = MooncakeCluster.from_spec(cfg, spec)
        res = mc.run(reqs, speedup=args.speedup, load_sample_dt=5.0)
        waste = sum(1 for r in res.records
                    if r.reject_stage == "decode_doublecheck")
        dload = [d for _, _, d in res.load_samples]
        pload = [p for _, p, _ in res.load_samples]
        print(f"--- {adm} ---")
        print(f"rejected {len(res.rejected())} "
              f"(after prefill: {waste}) | completed "
              f"{len(res.completed())} | goodput "
              f"{res.goodput(30, .1):.2f} req/s")
        print(f"rejects by reason: {res.reject_breakdown()}")
        print(f"prefill load |{sparkline(pload)}|")
        print(f"decode load  |{sparkline(dload)}|  "
              f"std={np.std(dload):.2f}\n")


if __name__ == "__main__":
    main()
