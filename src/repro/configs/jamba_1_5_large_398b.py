"""Jamba-1.5-large 398B — hybrid Mamba+attention 1:7 interleave, MoE 16e top-2.
[arXiv:2403.19887]"""
from repro.configs.base import ModelConfig, MoEConfig, SSMConfig, register

CONFIG = register(ModelConfig(
    name="jamba-1.5-large-398b",
    kind="hybrid",
    n_layers=72,
    d_model=8192,
    n_heads=64,
    n_kv_heads=8,
    head_dim=128,
    d_ff=24576,
    vocab_size=65536,
    attn_every=8,  # 1 attention layer per 8 (1:7 mamba:attn interleave)
    moe=MoEConfig(n_experts=16, top_k=2, d_ff_expert=24576,
                  parallelism="ep", every=2),
    ssm=SSMConfig(d_state=64, d_conv=4, expand=2, head_dim=64),
    rope_theta=1e6,
    optimizer="adafactor",
    source="arXiv:2403.19887 (assignment: 72L d8192 64H kv8 1:7 16e top-2)",
))
