"""Paged decode substrate: kernel-vs-oracle parity, page refcount/COW
properties, zero-copy prefill→decode handoff, incremental prefix hashing,
and chunk-skipping overlap assembly."""
import jax
import jax.numpy as jnp
import numpy as np
import pytest
try:
    from hypothesis import given, settings, strategies as st
except ImportError:  # sandboxed env: vendored shim (seeded random)
    from _hypothesis_shim import given, settings, strategies as st

from repro.configs.base import get_config
from repro.core.trace import BLOCK_TOKENS
from repro.kernels.paged_attention.kernel import (paged_attention,
                                                  paged_attention_layers)
from repro.kernels.paged_attention.ref import (paged_attention_layers_ref,
                                               paged_attention_ref)
from repro.serving.engine import (DecodeWorker, HostKVPool, PrefillWorker,
                                  PrefixHasher, prefix_hash_ids)
from repro.serving.paged_cache import DevicePagePool

CFG = get_config("smollm-360m").reduced()
KEY = jax.random.PRNGKey(0)


@pytest.fixture(scope="module")
def setup():
    params = __import__("repro.models.transformer",
                        fromlist=["init_params"]).init_params(CFG, KEY)
    return CFG, params


# ---------------------------------------------------------------- kernel ----

def _rand_paged(B, H, KV, D, P, page, mp, seed=0):
    ks = jax.random.split(jax.random.PRNGKey(seed), 3)
    q = jax.random.normal(ks[0], (B, H, D), jnp.bfloat16)
    kp = jax.random.normal(ks[1], (P, page, KV, D), jnp.bfloat16)
    vp = jax.random.normal(ks[2], (P, page, KV, D), jnp.bfloat16)
    return q, kp, vp


@pytest.mark.parametrize("lens", [
    [1, 64, 65, 128],          # ragged incl. exact page boundaries
    [63, 64, 127, 256],        # page-1 / page / page·2-1 / max
    [10, 10, 10, 10],
])
def test_kernel_oracle_parity_ragged_and_boundary(lens):
    B, H, KV, D, P, page, mp = 4, 8, 2, 64, 32, 64, 4
    q, kp, vp = _rand_paged(B, H, KV, D, P, page, mp)
    rng = np.random.default_rng(3)
    table = jnp.asarray(rng.integers(1, P, (B, mp)), jnp.int32)
    out = paged_attention(q, kp, vp, table,
                          jnp.asarray(lens, jnp.int32), interpret=True)
    ref = paged_attention_ref(q, kp, vp, table,
                              jnp.asarray(lens, jnp.int32))
    np.testing.assert_allclose(np.asarray(out, np.float32),
                               np.asarray(ref, np.float32),
                               atol=2e-2, rtol=2e-2)


def test_kernel_oracle_parity_null_page():
    """Rows padded with the null page (id 0) beyond their used span must
    agree — the masked tail never contributes, whatever page 0 holds."""
    B, H, KV, D, P, page, mp = 2, 4, 4, 64, 16, 64, 4
    q, kp, vp = _rand_paged(B, H, KV, D, P, page, mp)
    kp = kp.at[0].set(1e4)     # poison the null page
    vp = vp.at[0].set(-1e4)
    table = jnp.asarray([[3, 0, 0, 0], [5, 7, 0, 0]], jnp.int32)
    lens = jnp.asarray([40, 100], jnp.int32)
    out = paged_attention(q, kp, vp, table, lens, interpret=True)
    ref = paged_attention_ref(q, kp, vp, table, lens)
    np.testing.assert_allclose(np.asarray(out, np.float32),
                               np.asarray(ref, np.float32),
                               atol=2e-2, rtol=2e-2)
    assert np.isfinite(np.asarray(out, np.float32)).all()


def test_batched_over_layers_entry_matches_per_layer():
    L, B, H, KV, D, P, page, mp = 3, 2, 8, 2, 64, 16, 64, 2
    ks = jax.random.split(KEY, 3)
    qs = jax.random.normal(ks[0], (L, B, H, D), jnp.bfloat16)
    kp = jax.random.normal(ks[1], (L, P, page, KV, D), jnp.bfloat16)
    vp = jax.random.normal(ks[2], (L, P, page, KV, D), jnp.bfloat16)
    rng = np.random.default_rng(0)
    table = jnp.asarray(rng.integers(1, P, (B, mp)), jnp.int32)
    lens = jnp.asarray([70, 128], jnp.int32)
    out = paged_attention_layers(qs, kp, vp, table, lens, interpret=True)
    per_layer = jnp.stack([
        paged_attention(qs[l], kp[l], vp[l], table, lens, interpret=True)
        for l in range(L)])
    np.testing.assert_allclose(np.asarray(out, np.float32),
                               np.asarray(per_layer, np.float32),
                               atol=2e-2, rtol=2e-2)
    ref = paged_attention_layers_ref(qs, kp, vp, table, lens)
    np.testing.assert_allclose(np.asarray(out, np.float32),
                               np.asarray(ref, np.float32),
                               atol=2e-2, rtol=2e-2)


def test_ref_qh2kv_matches_manual_expansion():
    """The padded-GQA oracle (explicit query→kv map) equals attention over
    manually expanded pages."""
    B, H, KV, D, P, page, mp = 2, 6, 2, 32, 8, 16, 2
    q, kp, vp = _rand_paged(B, H, KV, D, P, page, mp, seed=5)
    qh2kv = jnp.asarray([0, 0, 0, 1, 1, 0], jnp.int32)  # padded head -> kv 0
    table = jnp.asarray([[2, 3], [4, 0]], jnp.int32)
    lens = jnp.asarray([20, 9], jnp.int32)
    out = paged_attention_ref(q, kp, vp, table, lens, qh2kv=qh2kv)
    kp_x = jnp.take(kp, qh2kv, axis=2)     # (P, page, H, D)
    vp_x = jnp.take(vp, qh2kv, axis=2)
    ref = paged_attention_ref(q, kp_x, vp_x, table, lens)  # grouped H==KV
    np.testing.assert_allclose(np.asarray(out, np.float32),
                               np.asarray(ref, np.float32),
                               atol=2e-2, rtol=2e-2)


# ------------------------------------------------------------ page pool ----

def _tiny_pool(n_pages=24, page_tokens=64):
    return DevicePagePool(CFG, n_pages=n_pages, page_tokens=page_tokens)


def _rand_kv(n_tokens, seed=0):
    rng = np.random.default_rng(seed)
    L, KV, Dh = CFG.attention_layers, CFG.n_kv_heads, CFG.head_dim
    k = rng.standard_normal((L, n_tokens, KV, Dh)).astype(np.float32)
    return k, -k


def test_page_tokens_must_divide_block():
    with pytest.raises(ValueError):
        DevicePagePool(CFG, n_pages=8, page_tokens=96)


def test_double_free_raises():
    pool = _tiny_pool()
    run = pool.alloc(2)
    pool.release(run)
    with pytest.raises(RuntimeError):
        pool.release(run)
    pool.check_leaks()


def test_registry_adopt_shares_physical_pages():
    pool = _tiny_pool(n_pages=24)
    k, v = _rand_kv(BLOCK_TOKENS)
    run = pool.alloc(pool.pages_per_block)
    pool.write_run(run, k, v)
    pool.register_block(1234, run)
    n_free0 = len(pool.free)
    n, pages = pool.adopt_chain([1234, 999])
    assert n == 1 and pages == run
    assert len(pool.free) == n_free0        # no new pages for the adopter
    pool.release(pages)
    pool.release(run)                        # the staging reference
    pool.check_leaks()
    # registry still holds the run; eviction under pressure frees it
    pool.alloc(len(pool.free) + pool.pages_per_block)
    assert 1234 not in pool.runs
    assert pool.stats()["registry_evictions"] == 1


def test_registry_eviction_pins_live_runs():
    pool = _tiny_pool(n_pages=1 + 2 * 8)
    k, v = _rand_kv(BLOCK_TOKENS)
    run = pool.alloc(pool.pages_per_block)
    pool.write_run(run, k, v)
    pool.register_block(7, run)             # run refs: staging + registry
    with pytest.raises(MemoryError):        # live ref pins the run
        pool.alloc(2 * pool.pages_per_block)
    pool.release(run)                       # drop staging ref -> evictable
    pool.alloc(2 * pool.pages_per_block)    # now eviction makes room
    assert 7 not in pool.runs


def test_cow_never_mutates_shared_page():
    pool = _tiny_pool()
    k, v = _rand_kv(64, seed=3)
    run = pool.alloc(1)
    pool.write_run(run, k, v)
    pool.retain(run)                        # second owner -> shared
    before = np.asarray(pool.k_pages[:, run[0]]).copy()
    new = pool.make_writable(run[0])
    assert new != run[0]
    pool.k_pages = pool.k_pages.at[:, new, 0].set(99.0)  # append-style write
    np.testing.assert_array_equal(np.asarray(pool.k_pages[:, run[0]]), before)
    np.testing.assert_array_equal(                      # copy carried bytes
        np.asarray(pool.k_pages[:, new, 1:]), before[:, 1:])
    assert pool.refs[run[0]] == 1 and pool.refs[new] == 1
    pool.release([new])
    pool.release(run)
    pool.check_leaks()


def test_exclusive_page_skips_cow():
    pool = _tiny_pool()
    run = pool.alloc(1)
    assert pool.make_writable(run[0]) == run[0]
    assert pool.stats()["cow_copies"] == 0
    pool.release(run)


@given(st.lists(st.tuples(st.integers(0, 2), st.integers(1, 6)),
                min_size=1, max_size=20))
@settings(max_examples=30, deadline=None)
def test_alloc_retain_release_conserve_pages(ops):
    """Random alloc/retain/release cycles: every page is either free or
    referenced, never both, never leaked (op 0 alloc, 1 retain, 2 release)."""
    pool = _tiny_pool(n_pages=16)
    held: list[list[int]] = []
    for op, n in ops:
        if op == 0:
            try:
                held.append(pool.alloc(n))
            except MemoryError:
                pass
        elif op == 1 and held:
            run = held[n % len(held)]
            pool.retain(run)
            held.append(list(run))
        elif op == 2 and held:
            pool.release(held.pop(n % len(held)))
        pool.check_leaks()
        n_held = sum(len(r) for r in held)
        assert int(pool.refs.sum()) == n_held
    for run in held:
        pool.release(run)
    pool.check_leaks()
    assert len(pool.free) == pool.n_pages - 1


# ------------------------------------------------- engine: paged decode ----

def test_paged_matches_dense_with_prefix_sharing(setup):
    """Continuous batching over the paged substrate — zero-copy joins,
    shared prefix pages — emits exactly the dense arena's tokens."""
    cfg, params = setup
    rng = np.random.default_rng(1)
    shared = rng.integers(0, cfg.vocab_size, 1024)
    reqs = [np.concatenate([shared, rng.integers(0, cfg.vocab_size, n)])
            for n in (96, 64, 200)]

    pool = HostKVPool()
    pp = DevicePagePool(cfg, n_pages=1 + 5 * 32, page_tokens=64)
    pw = PrefillWorker(params, cfg, pool, prefill_chunk=256, page_pool=pp)
    dw = DecodeWorker(params, cfg, max_batch=4, max_len=2048,
                      substrate="paged", page_pool=pp)
    pool_d = HostKVPool()
    pw_d = PrefillWorker(params, cfg, pool_d, prefill_chunk=256)
    dw_d = DecodeWorker(params, cfg, max_batch=4, max_len=2048,
                        substrate="dense")

    outs, outs_d = {}, {}
    for i, t in enumerate(reqs):
        r = pw(t)
        assert r.pages is not None
        dw.join(i, r, max_new=6)
        outs[i] = [r.first_token]
        rd = pw_d(t)
        dw_d.join(i, rd, max_new=6)
        outs_d[i] = [rd.first_token]
    while dw.n_active or dw_d.n_active:
        for rid, tok, _ in dw.step():
            outs[rid].append(tok)
        for rid, tok, _ in dw_d.step():
            outs_d[rid].append(tok)
    assert outs == outs_d
    assert dw.stats()["zero_copy_joins"] == 3      # adoption, no dense copy
    assert pp.stats()["shared_adoptions"] >= 2     # reqs 2,3 shared 2 blocks
    pp.check_leaks()


def test_slots_leaving_release_pages(setup):
    cfg, params = setup
    rng = np.random.default_rng(4)
    pool = HostKVPool()
    pw = PrefillWorker(params, cfg, pool, prefill_chunk=128)
    dw = DecodeWorker(params, cfg, max_batch=2, max_len=512,
                      substrate="paged")
    pp = dw.page_pool
    for i in range(3):                      # more requests than slots
        r = pw(rng.integers(0, cfg.vocab_size, 80 + 40 * i))
        dw.join(i, r, max_new=2)
        while dw.n_active == dw.max_batch:
            dw.step()
    while dw.n_active:
        dw.step()
    pp.check_leaks()
    # only registry-held runs may remain; they are evictable
    for h, run in pp.runs.items():
        assert all(pp.refs[p] == 1 for p in run)


def test_multi_join_cow_bit_exact(setup):
    """One PrefillResult joined into two slots (n-best fan-out): the slots
    share every page incl. the partial tail; the first append COWs and
    both decode exactly like the lone sequential oracle."""
    from repro.models.transformer import (decode_step, init_caches,
                                          init_params, prefill)
    cfg, params = setup
    rng = np.random.default_rng(5)
    t = rng.integers(0, cfg.vocab_size, 600)
    pool = HostKVPool()
    pp = DevicePagePool(cfg, n_pages=1 + 4 * 16, page_tokens=64)
    pw = PrefillWorker(params, cfg, pool, prefill_chunk=256, page_pool=pp)
    dw = DecodeWorker(params, cfg, max_batch=4, max_len=1024,
                      substrate="paged", page_pool=pp)
    r = pw(t)
    dw.join(0, r, max_new=5)
    dw.join(1, r, max_new=5)
    outs = {0: [r.first_token], 1: [r.first_token]}
    while dw.n_active:
        for rid, tok, _ in dw.step():
            outs[rid].append(tok)
    assert outs[0] == outs[1]
    assert dw.stats()["zero_copy_joins"] == 2
    assert pp.stats()["cow_copies"] >= 1
    pp.check_leaks()

    logits, caches = jax.jit(lambda p, t_: prefill(p, t_, cfg))(
        params, jnp.asarray(t[None]))
    full = init_caches(cfg, 1, 1024)
    S = len(t)
    full = full._replace(kv=full.kv._replace(
        k=full.kv.k.at[:, :, :S].set(caches.kv.k),
        v=full.kv.v.at[:, :, :S].set(caches.kv.v)), length=caches.length)
    tok = int(jnp.argmax(logits[0]))
    ref = [tok]
    step = jax.jit(lambda p, t_, c: decode_step(p, t_, c, cfg))
    for _ in range(4):
        lg, full = step(params, jnp.asarray([[tok]], jnp.int32), full)
        tok = int(jnp.argmax(lg[0, -1]))
        ref.append(tok)
    assert outs[0] == ref


def test_rejoin_after_release_raises_not_corrupts(setup):
    """Joining a PrefillResult AFTER its joined slot finished (staging
    reference long gone, tail pages recycled) must raise, never attend
    another request's recycled pages."""
    cfg, params = setup
    rng = np.random.default_rng(11)
    pool = HostKVPool()
    pp = DevicePagePool(cfg, n_pages=1 + 4 * 8, page_tokens=64)
    pw = PrefillWorker(params, cfg, pool, prefill_chunk=128, page_pool=pp)
    dw = DecodeWorker(params, cfg, max_batch=2, max_len=512,
                      substrate="paged", page_pool=pp)
    r1 = pw(rng.integers(0, cfg.vocab_size, 100))
    dw.join(0, r1, max_new=2)
    while dw.n_active:
        dw.step()                        # slot done -> r1's pages released
    r2 = pw(rng.integers(0, cfg.vocab_size, 100))   # recycles the pages
    dw.join(1, r2, max_new=2)
    with pytest.raises(RuntimeError):
        dw.join(0, r1, max_new=2)        # stale run must be refused
    pp.check_leaks()


def test_release_pages_for_never_joined_result(setup):
    cfg, params = setup
    rng = np.random.default_rng(12)
    pool = HostKVPool()
    pp = DevicePagePool(cfg, n_pages=1 + 2 * 8, page_tokens=64)
    pw = PrefillWorker(params, cfg, pool, prefill_chunk=128, page_pool=pp)
    r = pw(rng.integers(0, cfg.vocab_size, 100))
    held = pp.used_pages
    assert held > 0
    r.release_pages()                    # cancelled before any join
    r.release_pages()                    # idempotent
    pp.check_leaks()
    assert pp.used_pages < held


def test_join_rejects_prompt_that_would_outgrow_table(setup):
    cfg, params = setup
    rng = np.random.default_rng(13)
    pool = HostKVPool()
    pw = PrefillWorker(params, cfg, pool, prefill_chunk=128)
    dw = DecodeWorker(params, cfg, max_batch=1, max_len=512,
                      substrate="paged")
    r = pw(rng.integers(0, cfg.vocab_size, 510))
    with pytest.raises(ValueError):      # 510 + 8 > 512
        dw.join(0, r, max_new=8)
    dw.join(0, r, max_new=2)             # 510 + 2 fits exactly
    while dw.n_active:
        dw.step()
    dw.page_pool.check_leaks()


# ------------------------------------------------- incremental hashing ----

def test_prefix_hasher_matches_reference():
    rng = np.random.default_rng(7)
    t = rng.integers(0, 50000, 1700)
    assert PrefixHasher().hash_ids(t) == prefix_hash_ids(t)


def test_prefix_hasher_session_hashes_only_suffix():
    rng = np.random.default_rng(8)
    turn1 = rng.integers(0, 50000, 1024)            # 2 blocks
    turn2 = np.concatenate([turn1, rng.integers(0, 50000, 1024)])  # +2
    h = PrefixHasher()
    ids1 = h.hash_ids(turn1, session="s")
    assert h.blocks_hashed == 2
    ids2 = h.hash_ids(turn2, session="s")
    assert h.blocks_hashed == 4                     # only the suffix hashed
    assert h.memo_hits == 1
    assert ids2[:2] == ids1
    assert ids2 == prefix_hash_ids(turn2)


def test_prefix_hasher_divergence_falls_back():
    rng = np.random.default_rng(9)
    a = rng.integers(0, 50000, 1024)
    b = a.copy()
    b[10] += 1                                      # diverge in block 0
    h = PrefixHasher()
    h.hash_ids(a, session="s")
    ids_b = h.hash_ids(b, session="s")
    assert h.memo_hits == 0
    assert ids_b == prefix_hash_ids(b)
    # memo replaced: a third call extending b resumes from b's chain
    c = np.concatenate([b, rng.integers(0, 50000, 512)])
    assert h.hash_ids(c, session="s") == prefix_hash_ids(c)
    assert h.memo_hits == 1


def test_prefix_hasher_memo_is_bounded():
    rng = np.random.default_rng(14)
    h = PrefixHasher(capacity_sessions=4)
    for s in range(10):
        h.hash_ids(rng.integers(0, 50000, 512), session=s)
    assert len(h._memo) == 4
    assert list(h._memo) == [6, 7, 8, 9]     # LRU: oldest sessions evicted


# --------------------------------------------- chunk-skipping assembly ----

def test_chunk_skipping_bit_exact_and_fewer_tokens(setup, tmp_path):
    """A fragmented chain (DRAM blocks interleaved past SSD ones inside
    the head span) assembles the DRAM blocks from the pool instead of
    recomputing them: bit-exact first token, strictly fewer computed
    tokens, skipped blocks counted."""
    from repro.models.transformer import prefill
    cfg, params = setup
    rng = np.random.default_rng(10)
    t = rng.integers(0, cfg.vocab_size, 6 * 512 + 100)

    pool = HostKVPool(capacity_blocks=2, ssd_capacity_blocks=16,
                      ssd_dir=str(tmp_path), writeback_batch=1)
    pw = PrefillWorker(params, cfg, pool, prefill_chunk=256,
                       ssd_mode="overlap")
    r1 = pw(t)                          # cold: blocks 0-5 inserted; DRAM
    first_cold = r1.first_token         # holds the 2 most recent (4, 5)
    ids = prefix_hash_ids(t)
    pool.meta.touch_keys(ids[2:4])      # promote 2,3 -> 4,5 demote: the
    tiers = [pool.meta.resident_tier(h) for h in ids]  # chain fragments
    d0 = 0
    while d0 < len(tiers) and tiers[d0] == "dram":
        d0 += 1
    assert any(x == "dram" for x in tiers[d0:]), f"not fragmented: {tiers}"
    assert any(x == "ssd" for x in tiers[max(i for i, x in enumerate(tiers)
                                             if x == "dram"):]), tiers

    # expensive loads + ~free compute -> the split recomputes every SSD
    # block, chunk-skipping the DRAM blocks embedded in the span
    pool.store._read_s_ema = 10.0
    pw._t_block_ema = 1e-6
    computed0 = pw.stats()["computed_tokens"]
    r2 = pw(t)
    assert r2.first_token == first_cold
    logits, _ = jax.jit(lambda p, t_: prefill(p, t_, cfg))(
        params, jnp.asarray(t[None]))
    assert r2.first_token == int(jnp.argmax(logits[0]))

    assert r2.skipped_blocks >= 1       # DRAM blocks mid-span not recomputed
    computed = pw.stats()["computed_tokens"] - computed0
    # wholesale head recompute (the pre-chunk-skipping schedule) computes
    # every head-span block, skipped ones included
    wholesale = len(t) - (r2.reused_blocks - r2.skipped_blocks) * 512
    assert computed < wholesale         # strictly fewer than wholesale
    assert computed == len(t) - r2.reused_blocks * 512
    pool.close()


def test_overlap_split_prices_skipped_dram_free():
    from repro.serving.layerwise import overlap_split
    # ssd ssd dram dram ssd: with cheap compute the whole span recomputes
    # EXCEPT the dram blocks, which are skipped
    ov = overlap_split(["ssd", "ssd", "dram", "dram", "ssd"], 0.1, 10.0)
    assert ov.split == 5
    assert ov.head_recompute == 3
    assert ov.head_skipped == 2
    assert ov.t_head == pytest.approx(0.3)
