from repro.data.pipeline import (BatchSpec, SyntheticLM, batch_spec_for,
                                 realize_request_tokens)
