"""Public op: chunked SSD scan (kernel or oracle dispatch)."""
from __future__ import annotations

import jax

from repro.kernels.ssd_scan.kernel import ssd_scan as _kernel
from repro.kernels.ssd_scan.ref import ssd_scan_ref as _ref


def ssd_scan_op(x, dt, A, B, C, h0=None, *, chunk: int = 256,
                use_pallas: bool = False, interpret: bool | None = None):
    """x: (b, s, h, p); dt: (b, s, h); A: (h,); B, C: (b, s, n)."""
    if not use_pallas:
        return _ref(x, dt, A, B, C, chunk=chunk, h0=h0)
    if interpret is None:
        interpret = jax.default_backend() == "cpu"
    return _kernel(x, dt, A, B, C, h0, chunk=chunk, interpret=interpret)
