"""policy-purity: propose/select must not mutate shared state.

The policy contract (PR 2, ``core/policies/base.py``): ``propose``
returns Arms with effects captured in ``commit`` CLOSURES; only the
Conductor landing the chosen arm runs ``commit``.  A mutating call
executed directly in a policy body fires for every CANDIDATE arm, not
just the winner — double-sending KV, double-counting transfers.

In any module that registers policies (``register_policy`` appears in
the file), every top-level function and every method of every class is
scanned for direct calls to known mutating Messenger/pool/directory
methods.  Calls inside nested ``def``/``lambda`` (the commit closures)
are allowed — that is exactly where effects belong.  Calls on ``self``
directly (policy-internal memory like an affinity map) are allowed.
"""
from __future__ import annotations

import ast

from tools.replint.core import Finding, ModuleCtx, dotted

RULE = "policy-purity"

MUTATING = {
    # Messenger / transfer-engine sends
    "enqueue", "enqueue_ssd", "enqueue_peer_ssd", "send", "kill",
    # pool / cache mutation
    "insert", "insert_meta", "put", "touch", "touch_keys", "discard",
    "write_run", "register_block", "account_pending",
    # directory / registry mutation
    "register", "unregister", "drop_node", "bind", "delete", "flush",
}

_SCAN_EXEMPT = {"__init__", "__post_init__"}


def _scanned_funcs(tree):
    for node in tree.body:
        if isinstance(node, (ast.FunctionDef, ast.AsyncFunctionDef)):
            yield node
        elif isinstance(node, ast.ClassDef):
            for sub in node.body:
                if isinstance(sub, (ast.FunctionDef,
                                    ast.AsyncFunctionDef)) \
                        and sub.name not in _SCAN_EXEMPT:
                    yield sub


def check(ctx: ModuleCtx) -> list[Finding]:
    if "register_policy" not in ctx.src:
        return []
    findings: list[Finding] = []
    for func in _scanned_funcs(ctx.tree):
        # walk the body, skipping nested defs/lambdas (commit closures)
        todo = list(ast.iter_child_nodes(func))
        while todo:
            node = todo.pop()
            if isinstance(node, (ast.FunctionDef, ast.AsyncFunctionDef,
                                 ast.Lambda)):
                continue
            todo.extend(ast.iter_child_nodes(node))
            if not (isinstance(node, ast.Call)
                    and isinstance(node.func, ast.Attribute)
                    and node.func.attr in MUTATING):
                continue
            recv = node.func.value
            if isinstance(recv, ast.Name) and recv.id == "self":
                continue  # policy-internal memory is the policy's own
            target = dotted(node.func) or node.func.attr
            findings.append(Finding(
                ctx.path, node.lineno, RULE,
                f"policy body '{func.name}' calls mutating "
                f"'{target}()' outside an Arm.commit closure -- "
                f"propose/select run once per CANDIDATE, so this "
                f"side effect fires for arms that never land"))
    return findings
