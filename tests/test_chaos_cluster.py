"""Chaos harness for the multi-process cluster: every failure the wire
can suffer — dead owner (kill -9 mid-FETCH_BLOCK), torn frame at a byte
boundary, stale directory entry, partitioned directory service — must
end bit-exact vs a DRAM-only oracle with the right ``fallback_reasons``
entry and nothing leaked (threads, sockets, fds — the conftest
detectors run on every test here).

Fast lane: in-process ``BlockServer``/``DirectoryServer`` over real
ephemeral TCP sockets (CI-speed). ``@slow`` lane: real OS processes,
including the jax-free block-node main killed mid-transfer and the full
``serve_cluster --processes 3 --chaos kill-owner`` acceptance run.
"""
import os
import signal
import subprocess
import sys
import threading
import time

import numpy as np
import pytest

from repro.core.directory import GlobalBlockDirectory
from repro.core.trace import BLOCK_TOKENS
from repro.serving.engine import prefix_hash_ids
from repro.serving.request import ServingRequest
from repro.serving.transport import BlockServer, InProcPeer, SocketPeer

REPO = os.path.dirname(os.path.dirname(os.path.abspath(__file__)))
SRC = os.path.join(REPO, "src")


@pytest.fixture(scope="module")
def setup():
    import jax

    from repro.configs.base import get_config
    from repro.models.transformer import init_params
    cfg = get_config("smollm-360m").reduced()
    params = init_params(cfg, jax.random.PRNGKey(0))
    rng = np.random.default_rng(42)
    doc = rng.integers(0, cfg.vocab_size, 2 * BLOCK_TOKENS)
    q1 = np.concatenate([doc, rng.integers(0, cfg.vocab_size, 48)])
    q2 = np.concatenate([doc, rng.integers(0, cfg.vocab_size, 48)])
    return cfg, params, q1, q2


def _decode_tokens(params, cfg, pres, tokens, n=3):
    from repro.serving.engine import DecodeWorker
    dw = DecodeWorker(params, cfg, max_batch=1,
                      max_len=pres.prompt_len + n + 4)
    dw.join(ServingRequest(req_id=0, tokens=tokens, max_new=n), pres)
    out = [pres.first_token]
    while dw.n_active:
        out.extend(tok for _rid, tok, _f in dw.step())
    return out


@pytest.fixture(scope="module")
def dram_reference(setup):
    from repro.serving.engine import HostKVPool, PrefillWorker
    cfg, params, q1, q2 = setup
    pool = HostKVPool()
    pw = PrefillWorker(params, cfg, pool, prefill_chunk=128)
    pw(q1)
    return _decode_tokens(params, cfg, pw(q2), q2)


def _socket_nodes(setup, tmp_path, *, stall_s=0.0, mangle=None):
    """A/B pair where B reaches A ONLY over the wire: A's pool sits
    behind a ``BlockServer`` and B holds a ``SocketPeer`` to it (shared
    in-process directory; the directory's own wire path has its own
    tests). A's doc is cold-prefilled and demoted to its store."""
    from repro.serving.engine import HostKVPool, PrefillWorker
    cfg, params, q1, _ = setup
    d = GlobalBlockDirectory()
    pa = HostKVPool(capacity_blocks=1, ssd_capacity_blocks=64,
                    ssd_dir=str(tmp_path / "a"), writeback_batch=1,
                    directory=d, node_id=0)
    pb = HostKVPool(capacity_blocks=None, ssd_capacity_blocks=64,
                    ssd_dir=str(tmp_path / "b"), directory=d, node_id=1)
    server = BlockServer(InProcPeer(pa), stall_s=stall_s, mangle=mangle)
    peer = SocketPeer(server.addr, node=0)
    pb.add_peer(0, peer)
    pw_a = PrefillWorker(params, cfg, pa, prefill_chunk=128)
    pw_b = PrefillWorker(params, cfg, pb, prefill_chunk=128,
                         ssd_mode="overlap")
    pw_a(q1)
    pa.store.flush()
    return d, pa, pb, pw_b, server, peer


def _teardown(pa, pb, server, peer):
    peer.close()
    server.close()
    pa.close()
    pb.close()


# ---------------------------------------------------------------------------
# fast lane: real TCP, in-process endpoints
# ---------------------------------------------------------------------------

def test_socket_fetch_bit_exact(setup, dram_reference, tmp_path):
    """The happy path over the wire IS the in-process path: peer blocks
    stream through the AsyncPrefetcher off a socket, bit-exact."""
    cfg, params, _, q2 = setup
    d, pa, pb, pw_b, server, peer = _socket_nodes(setup, tmp_path)
    pres = pw_b(q2)
    assert pres.peer_blocks == 2 and pres.reused_blocks == 2
    assert _decode_tokens(params, cfg, pres, q2) == dram_reference
    assert not pb.fallback_reasons and pb.peer_fetch_failures == 0
    assert peer.bw_ema and peer.bw_ema > 0
    assert server.stats()["frames_served"] >= 2 * cfg.n_layers
    _teardown(pa, pb, server, peer)


def test_kill9_identical_reasons_in_proc_vs_socket(setup, dram_reference,
                                                   tmp_path):
    """Satellite-4 regression: a killed node must look the SAME through
    both transports. Before the shared taxonomy, ``kill()`` was a flag
    only the in-process read path checked — a socket peer whose process
    died surfaced differently. Now ``InProcPeer`` raises the same
    ``PeerUnreachable`` a dead socket does, so the prefetcher records
    identical ``fallback_reasons`` for both."""
    from repro.serving.engine import HostKVPool, PrefillWorker, connect_pools
    cfg, params, q1, q2 = setup

    # transport 1: in-process peer, killed via the legacy kill() switch
    d1 = GlobalBlockDirectory()
    pa1 = HostKVPool(capacity_blocks=1, ssd_capacity_blocks=64,
                     ssd_dir=str(tmp_path / "in_a"), writeback_batch=1,
                     directory=d1, node_id=0)
    pb1 = HostKVPool(capacity_blocks=None, ssd_capacity_blocks=64,
                     ssd_dir=str(tmp_path / "in_b"), directory=d1, node_id=1)
    connect_pools([pa1, pb1])
    pw_a1 = PrefillWorker(params, cfg, pa1, prefill_chunk=128)
    pw_b1 = PrefillWorker(params, cfg, pb1, prefill_chunk=128,
                          ssd_mode="overlap")
    pw_a1(q1)
    pa1.store.flush()
    pa1.kill()
    pres1 = pw_b1(q2)

    # transport 2: socket peer whose server process is gone
    d2, pa2, pb2, pw_b2, server, peer = _socket_nodes(
        setup, tmp_path / "sock")
    server.close()                      # the kill -9 stand-in
    pres2 = pw_b2(q2)

    assert pb1.fallback_reasons == pb2.fallback_reasons \
        == {"peer_unreachable": 1}
    assert pres1.peer_blocks == pres2.peer_blocks == 0
    ref = dram_reference
    assert _decode_tokens(params, cfg, pres1, q2) == ref
    assert _decode_tokens(params, cfg, pres2, q2) == ref
    pa1.close()
    pb1.close()
    _teardown(pa2, pb2, server, peer)


def test_server_death_mid_block_bit_exact(setup, dram_reference, tmp_path):
    """The server dies BETWEEN layer frames of one block (kill -9
    mid-FETCH_BLOCK, fast-lane edition): the client sees the stream die,
    degrades to recompute, stays bit-exact."""
    cfg, params, _, q2 = setup
    d, pa, pb, pw_b, server, peer = _socket_nodes(setup, tmp_path,
                                                  stall_s=0.05)
    killer = threading.Timer(0.12, server.close)
    killer.name = "repro-chaos-killer"
    killer.start()
    try:
        pres = pw_b(q2)
    finally:
        killer.cancel()
        killer.join()
    assert _decode_tokens(params, cfg, pres, q2) == dram_reference
    assert set(pb.fallback_reasons) <= {"peer_unreachable", "verify_failed"}
    assert pb.fallback_reasons, "the death mid-block went unaccounted"
    _teardown(pa, pb, server, peer)


def test_torn_frame_at_byte_boundary(setup, dram_reference, tmp_path):
    """Every LAYER frame is truncated at a byte boundary: the reader
    sees a partial frame + EOF → TornFrame → ``verify_failed``, never
    wrong bytes — and the stale claim self-heals out of the directory."""
    cfg, params, _, q2 = setup
    d, pa, pb, pw_b, server, peer = _socket_nodes(
        setup, tmp_path, mangle=lambda f: f[:max(1, len(f) // 3)])
    pres = pw_b(q2)
    assert pres.peer_blocks == 0
    assert _decode_tokens(params, cfg, pres, q2) == dram_reference
    assert pb.fallback_reasons == {"verify_failed": 1}
    # self-heal: the claim that served torn bytes was withdrawn
    h0 = prefix_hash_ids(q2)[0]
    assert 0 not in d.holders(h0)
    _teardown(pa, pb, server, peer)


def test_stale_directory_entry_over_wire(setup, dram_reference, tmp_path):
    """The directory claims node 0 holds the blocks but its store no
    longer does (lagging advisory entry): the peer answers
    ``StaleDirectory``, the claim heals out, the query recomputes."""
    cfg, params, _, q2 = setup
    d, pa, pb, pw_b, server, peer = _socket_nodes(setup, tmp_path)
    for h in prefix_hash_ids(q2):
        pa.store.delete(h)              # bytes gone, directory not told
        pa.data.pop(h, None)
    pa.store.flush()
    pres = pw_b(q2)
    assert pres.peer_blocks == 0
    assert _decode_tokens(params, cfg, pres, q2) == dram_reference
    assert pb.fallback_reasons == {"stale_directory": 1}
    assert 0 not in d.holders(prefix_hash_ids(q2)[0])
    _teardown(pa, pb, server, peer)


def test_remote_directory_end_to_end(setup, dram_reference, tmp_path):
    """Full wire wiring, single process: both pools publish to a
    ``DirectoryServer`` through ``RemoteDirectory`` clients and fetch
    through ``SocketPeer``s — the exact topology of one serve_cluster
    worker — and stay bit-exact."""
    from repro.serving.directory_service import (DirectoryServer,
                                                 RemoteDirectory)
    from repro.serving.engine import HostKVPool, PrefillWorker
    cfg, params, q1, q2 = setup
    ds = DirectoryServer()
    pools, servers, rdirs, peers = [], [], [], []
    for i in range(2):
        pool = HostKVPool(capacity_blocks=1 if i == 0 else None,
                          ssd_capacity_blocks=64, writeback_batch=1,
                          ssd_dir=str(tmp_path / f"p{i}"))
        server = BlockServer(InProcPeer(pool))
        rdir = RemoteDirectory(ds.addr, node_id=i, block_port=server.port)
        pool.directory = rdir
        pool.node_id = i
        rdir.bind(i, pool.meta)
        pools.append(pool)
        servers.append(server)
        rdirs.append(rdir)
    for i, pool in enumerate(pools):
        for nid, (host, port) in rdirs[i].nodes().items():
            if nid != i:
                sp = SocketPeer((host, port), node=nid)
                peers.append(sp)
                pool.add_peer(nid, sp)
    pw_a = PrefillWorker(params, cfg, pools[0], prefill_chunk=128)
    pw_b = PrefillWorker(params, cfg, pools[1], prefill_chunk=128,
                         ssd_mode="overlap")
    pw_a(q1)
    pools[0].store.flush()
    time.sleep(0)                       # publishes are synchronous RPCs
    pres = pw_b(q2)
    assert pres.peer_blocks == 2
    assert _decode_tokens(params, cfg, pres, q2) == dram_reference
    assert not pools[1].fallback_reasons
    st = rdirs[1].stats()
    assert st["keys"] >= 2 and st["nodes"] == 2
    for sp in peers:
        sp.close()
    for s in servers:
        s.close()
    for r in rdirs:
        r.close()
    for p in pools:
        p.close()
    ds.close()


def test_directory_partition_degrades_to_recompute(setup, dram_reference,
                                                   tmp_path):
    """The directory service is unreachable: publishes drop (counted),
    lookups answer 'nobody', the peer arm never forms — requests still
    complete from recompute with no exception anywhere."""
    from repro.serving.directory_service import (DirectoryServer,
                                                 RemoteDirectory)
    from repro.serving.engine import HostKVPool, PrefillWorker
    cfg, params, _, q2 = setup
    dead = DirectoryServer()
    dead_addr = dead.addr
    dead.close()                        # nothing listens here any more
    rdir = RemoteDirectory(dead_addr)
    pool = HostKVPool(capacity_blocks=None, ssd_capacity_blocks=64,
                      ssd_dir=str(tmp_path / "b"))
    pool.directory = rdir
    pool.node_id = 1
    rdir.bind(1, pool.meta)
    pool.add_peer(0, SocketPeer(("127.0.0.1", 1), node=0))
    pw = PrefillWorker(params, cfg, pool, prefill_chunk=128,
                       ssd_mode="overlap")
    pres = pw(q2)
    assert pres.peer_blocks == 0 and pres.reused_blocks == 0
    assert _decode_tokens(params, cfg, pres, q2) == dram_reference
    assert not pool.fallback_reasons    # partition ≠ failed fetch
    st = rdir.stats()
    assert st.get("partitioned") and st["client_errors"] > 0
    pool.peers[0].close()
    rdir.close()
    pool.close()


# ---------------------------------------------------------------------------
# @slow lane: real OS processes
# ---------------------------------------------------------------------------

def _env():
    env = dict(os.environ)
    env["PYTHONPATH"] = SRC + os.pathsep + env.get("PYTHONPATH", "")
    return env


def _read_port(proc) -> int:
    line = proc.stdout.readline()
    assert line.startswith("PORT "), f"unexpected banner: {line!r}"
    return int(line.split()[1])


@pytest.mark.slow
def test_kill9_owner_process_mid_fetch(setup, dram_reference, tmp_path):
    """The real thing: a separate OS process (the jax-free block-node
    main) owns the blocks; it is SIGKILL'd mid-FETCH_BLOCK while this
    process fetches through it. The fetch degrades to recompute,
    bit-exact, the dead node's directory claims drop via its connection
    lease, and nothing leaks."""
    from repro.serving.directory_service import (DirectoryServer,
                                                 RemoteDirectory)
    from repro.serving.engine import HostKVPool, PrefillWorker
    cfg, params, q1, q2 = setup

    # populate a store on disk, then hand it to the owner process
    seed_pool = HostKVPool(capacity_blocks=1, ssd_capacity_blocks=64,
                           writeback_batch=1,
                           ssd_dir=str(tmp_path / "owner"))
    seed_pw = PrefillWorker(params, cfg, seed_pool, prefill_chunk=128)
    seed_pw(q1)
    seed_pool.store.flush()
    seed_pool.close()

    ds = DirectoryServer()
    owner = subprocess.Popen(
        [sys.executable, "-m", "repro.serving.transport",
         "--store", str(tmp_path / "owner"), "--node-id", "0",
         "--directory", f"127.0.0.1:{ds.port}", "--stall", "0.3"],
        stdout=subprocess.PIPE, stderr=subprocess.DEVNULL, text=True,
        env=_env())
    try:
        port = _read_port(owner)
        pool = HostKVPool(capacity_blocks=None, ssd_capacity_blocks=64,
                          ssd_dir=str(tmp_path / "b"))
        rdir = RemoteDirectory(ds.addr, node_id=1, block_port=0)
        pool.directory = rdir
        pool.node_id = 1
        rdir.bind(1, pool.meta)
        pool.add_peer(0, SocketPeer(("127.0.0.1", port), node=0))
        pw = PrefillWorker(params, cfg, pool, prefill_chunk=128,
                           ssd_mode="overlap")

        killer = threading.Timer(
            0.15, os.kill, args=(owner.pid, signal.SIGKILL))
        killer.name = "repro-chaos-killer"
        killer.start()
        try:
            pres = pw(q2)               # owner dies mid-stream (0.3s/layer)
        finally:
            killer.cancel()
            killer.join()
        owner.wait(timeout=30)
        assert owner.returncode == -signal.SIGKILL

        assert _decode_tokens(params, cfg, pres, q2) == dram_reference
        assert pool.fallback_reasons, "unaccounted degradation"
        assert set(pool.fallback_reasons) <= {"peer_unreachable",
                                              "verify_failed"}
        # lease-based self-heal: the dead node's claims drop without any
        # explicit withdraw
        h0 = prefix_hash_ids(q2)[0]
        deadline = time.monotonic() + 10
        while time.monotonic() < deadline and 0 in ds.directory.holders(h0):
            time.sleep(0.05)
        assert 0 not in ds.directory.holders(h0)
        pool.peers[0].close()
        rdir.close()
        pool.close()
    finally:
        if owner.poll() is None:
            owner.kill()
            owner.wait()
        owner.stdout.close()
        ds.close()


@pytest.mark.slow
def test_serve_cluster_three_process_chaos(tmp_path):
    """Acceptance criterion: a 3-process serve_cluster run whose block
    owner is kill -9'd mid-transfer completes every surviving request
    bit-exact vs the single-process oracle, with the degradation in
    fallback_reasons. (The example's parent process asserts all of it
    and exits nonzero otherwise.)"""
    res = subprocess.run(
        [sys.executable, os.path.join(REPO, "examples", "serve_cluster.py"),
         "--processes", "3", "--chaos", "kill-owner", "--max-new", "4",
         "--ssd-dir", str(tmp_path)],
        capture_output=True, text=True, timeout=900, env=_env())
    assert res.returncode == 0, \
        f"chaos run failed:\n{res.stdout}\n{res.stderr}"
    assert "PASS" in res.stdout and "bit-exact" in res.stdout
