"""Messenger — the cross-machine KVCache transfer service (§3 step 3).

One Messenger per instance; transfers are point-to-point (sender-node
egress is the contended resource, matching the paper's congestion concern
in §6.1: "whether the sending node is under congestion"). We model each
node's egress link as a FIFO pipe of bandwidth ``bw``; a transfer of B
bytes enqueued at time t on a link whose backlog drains at time t' ≥ t
completes at max(t, t') + B/bw.

This same object answers Conductor's ``EstimateKVCacheTransferTime`` —
the estimate includes the current backlog, which is how congestion feeds
back into Algorithm 1's instance selection and drives hot-spot
replication (§6.2).
"""
from __future__ import annotations

from dataclasses import dataclass, field


@dataclass
class Link:
    bw: float                   # bytes/s
    busy_until: float = 0.0     # time the current backlog drains
    bytes_sent: float = 0.0
    n_transfers: int = 0


class Messenger:
    """Transfer-time bookkeeping for a set of named nodes."""

    def __init__(self, node_ids, bw: float) -> None:
        self.links: dict = {i: Link(bw=bw) for i in node_ids}

    def add_node(self, node_id, bw: float) -> None:
        self.links[node_id] = Link(bw=bw)

    def estimate(self, src, nbytes: float, now: float) -> float:
        """Predicted transfer duration if enqueued now (queue + wire)."""
        link = self.links[src]
        wait = max(link.busy_until - now, 0.0)
        return wait + nbytes / link.bw

    def enqueue(self, src, nbytes: float, now: float) -> float:
        """Commit a transfer; returns its completion TIME."""
        link = self.links[src]
        start = max(link.busy_until, now)
        done = start + nbytes / link.bw
        link.busy_until = done
        link.bytes_sent += nbytes
        link.n_transfers += 1
        return done

    def congestion(self, src, now: float) -> float:
        """Seconds of backlog on a node's egress link."""
        return max(self.links[src].busy_until - now, 0.0)
