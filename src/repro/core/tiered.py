"""Tiered DRAM+SSD KVCache store — the paper's "underutilized CPU, DRAM
and SSD resources" made concrete (§3, Figure 3).

``CachePool`` models a single flat DRAM tier: evicted blocks are destroyed,
so long-context cold prefixes — the workload Mooncake wins hardest on — are
recomputed from scratch. ``TieredCachePool`` adds the next rung of the
hierarchy: DRAM evictions *demote* block metadata to a per-instance SSD
tier with its own capacity and eviction policy; SSD hits *promote* back to
DRAM. The Conductor can then choose, per request, between recomputing a
prefix, fetching it from a peer's DRAM, and loading it from local SSD —
the compute-vs-load decision of Jin et al. ("Compute Or Load KV Cache?
Why Not Both?") grafted onto Algorithm 1.

Like ``CachePool`` this tracks residency + metadata only; bytes live in the
serving engine (``HostKVPool`` keeps demoted blocks' bytes) or are modeled
by the simulator. Invariants maintained here and asserted by
``tests/test_tiered_cache.py``:

  * a block is resident in at most ONE tier at any time;
  * neither tier ever exceeds its capacity;
  * pinned blocks are never evicted from either tier, and promotion /
    demotion carries the pin count with the block.

Write-back batching: demotions are staged and accounted as one SSD write
per ``writeback_batch`` blocks (sequential batched writes are how real
tiers avoid write-amplification); ``flush_writeback()`` forces a partial
batch out, e.g. at checkpoint boundaries.

Tier-event hooks: a byte-holder (``HostKVPool`` with a file-backed
``SSDBlockStore``) mirrors metadata moves by setting ``on_demote(key)``
/ ``on_promote(key, count_read)`` / ``on_drop(key)``. They fire exactly
when a block changes tier or leaves the hierarchy, with ``on_demote``
guaranteed to run while the caller still holds the DRAM bytes — so the
hook can stage the write-back — and ``on_drop`` when the bytes may be
freed. ``on_insert(key, tier)`` fires when a FRESH block enters the
hierarchy (normally DRAM; "ssd" on the pinned-full straight-to-SSD path)
so a ``GlobalBlockDirectory`` can track DRAM residency too. All default
to ``None`` (the simulator's metadata-only use).
"""
from __future__ import annotations

from dataclasses import dataclass
from typing import Iterable, Optional

from repro.core.cache import BlockMeta, CachePool


@dataclass(frozen=True)
class TierPrefix:
    """Longest contiguous resident prefix across the hierarchy.

    ``total`` counts blocks resident in *either* tier (the chain may
    interleave, e.g. D,S,D); ``dram``/``ssd`` split that prefix by tier.
    Note ``dram`` can exceed the DRAM-only ``prefix_len`` — e.g. chain
    [S, D] has ``prefix_len() == 0`` but ``TierPrefix(2, 1, 1)``.
    """
    total: int
    dram: int
    ssd: int


class TieredCachePool(CachePool):
    """Two-tier block store: DRAM (primary, inherited) + SSD (demotion).

    The inherited ``CachePool`` state IS the DRAM tier — ``prefix_len``,
    ``__len__`` and the eviction counters keep their DRAM-only meaning, so
    a ``TieredCachePool`` drops into every ``CachePool`` slot (Conductor,
    simulator, ``HostKVPool``) unchanged. ``__contains__`` answers for the
    whole hierarchy. ``insert``/``lookup`` return values keep base
    semantics except that ``insert``'s evicted list contains only blocks
    dropped from the hierarchy entirely (callers holding bytes may free
    exactly those).
    """

    def __init__(self, capacity_blocks: Optional[int] = None,
                 ssd_capacity_blocks: Optional[int] = 0,
                 policy: str = "lru", ssd_policy: str = "lru",
                 block_bytes: int = 0, writeback_batch: int = 1) -> None:
        super().__init__(capacity_blocks, policy, block_bytes)
        self.ssd = CachePool(ssd_capacity_blocks, ssd_policy, block_bytes)
        self.writeback_batch = max(int(writeback_batch), 1)
        # tier-traffic accounting
        self.demotions = 0          # DRAM → SSD moves
        self.promotions = 0         # SSD → DRAM moves
        self.dram_hits = 0
        self.ssd_hits = 0
        self.ssd_blocks_written = 0
        self.ssd_blocks_read = 0
        self.n_writebacks = 0       # batched SSD write operations issued
        self._wb_pending = 0        # demoted blocks awaiting a batch flush
        self._dropped: list[int] = []   # keys that left the hierarchy
        # tier-event hooks (see module docstring); None = metadata-only
        self.on_demote = None       # fn(key) — DRAM bytes still readable
        self.on_promote = None      # fn(key, count_read)
        self.on_drop = None         # fn(key) — bytes may be freed
        self.on_insert = None       # fn(key, tier) — fresh block entered

    # ---- residency ----------------------------------------------------
    def __contains__(self, key: int) -> bool:
        return key in self.blocks or key in self.ssd.blocks

    def resident_tier(self, key: int) -> Optional[str]:
        if key in self.blocks:
            return "dram"
        if key in self.ssd.blocks:
            return "ssd"
        return None

    @property
    def total_blocks(self) -> int:
        return len(self.blocks) + len(self.ssd.blocks)

    def tier_prefix(self, hash_ids: list[int]) -> TierPrefix:
        """Longest resident prefix across both tiers (no side effects)."""
        total = dram = ssd = 0
        for h in hash_ids:
            if h in self.blocks:
                dram += 1
            elif h in self.ssd.blocks:
                ssd += 1
            else:
                break
            total += 1
        return TierPrefix(total, dram, ssd)

    # ---- demotion / promotion -----------------------------------------
    def _drop(self, keys: Iterable[int]) -> None:
        """Blocks leaving the hierarchy: record + notify the byte-holder."""
        for k in keys:
            self._dropped.append(k)
            if self.on_drop is not None:
                self.on_drop(k)

    def _evict(self, key: int) -> None:
        """DRAM eviction = demotion (metadata moves; SSD does the drop)."""
        meta = self.blocks.pop(key, None)
        self.policy.on_evict(key)
        self.evictions += 1
        if meta is None:
            return
        if self.ssd.capacity == 0:
            self._drop([key])
            return  # no SSD tier configured — behave like the flat pool
        ssd_evicted, placed = self.ssd.insert_meta(meta)
        self._drop(ssd_evicted)             # end of the hierarchy
        if placed:
            self.demotions += 1
            self._account_ssd_write()
            if self.on_demote is not None:
                self.on_demote(key)
        else:
            self._drop([key])               # SSD full of pinned blocks

    def _account_ssd_write(self) -> None:
        """Every block written to SSD joins the current write-back batch."""
        self.ssd_blocks_written += 1
        self._wb_pending += 1
        if self._wb_pending >= self.writeback_batch:
            self.n_writebacks += 1
            self._wb_pending = 0

    def flush_writeback(self) -> int:
        """Force a partial write-back batch out; returns blocks flushed."""
        n, self._wb_pending = self._wb_pending, 0
        if n:
            self.n_writebacks += 1
        return n

    def _promote(self, key: int, count_read: bool = True) -> bool:
        """SSD → DRAM move (metadata, including pin count, travels).

        ``count_read=False`` for blocks re-inserted from above (recomputed
        or migrated in): they get rewritten in DRAM, not read off SSD, so
        they must not inflate the SSD read-traffic counter."""
        meta = self.ssd.remove(key)
        if meta is None:
            return False
        if count_read:
            self.ssd_blocks_read += 1
        # making DRAM room may itself demote victims back into the SSD
        # tier — that's the hierarchy working, not recursion: _promote is
        # only entered on an SSD hit.
        _, placed = self.insert_meta(meta)
        if placed:
            self.promotions += 1
            if self.on_promote is not None:
                self.on_promote(key, count_read)
            return True
        # DRAM entirely pinned: put the block back where it was
        ssd_evicted, _ = self.ssd.insert_meta(meta)
        self._drop(ssd_evicted)
        return False

    # ---- CachePool interface ------------------------------------------
    def lookup(self, hash_ids: list[int], touch: bool = True) -> int:
        """Prefix match across the hierarchy; SSD hits promote to DRAM."""
        if not touch:
            return self.tier_prefix(hash_ids).total
        n = 0
        for h in hash_ids:
            if h in self.blocks:
                meta = self.blocks[h]
                meta.hits += 1
                self.policy.on_hit(h, meta)
                self.dram_hits += 1
            elif h in self.ssd.blocks:
                # count the hit even if promotion fails (pinned-full DRAM);
                # the block is still readable from SSD
                self.ssd.blocks[h].hits += 1
                self._promote(h)
                self.ssd_hits += 1
            else:
                break
            n += 1
        self.hits += n
        self.misses += len(hash_ids) - n
        return n

    def insert(self, hash_ids: Iterable[int], start_pos: int = 0) -> list[int]:
        """Insert into DRAM (SSD-resident duplicates are promoted instead);
        returns keys dropped from the WHOLE hierarchy since the last insert
        (lookup-time promotions can drop SSD victims too — callers holding
        bytes free exactly the returned keys)."""
        for i, h in enumerate(hash_ids):
            if h in self.blocks:
                continue
            if h in self.ssd.blocks:
                self._promote(h, count_read=False)
                continue
            _, has_room = self._make_room()   # overflow demotes via _evict
            if not has_room:
                # DRAM all pinned — try writing the fresh block straight to
                # the SSD tier rather than losing it
                meta = BlockMeta(key=h, position=start_pos + i,
                                 size_bytes=self.block_bytes)
                if self.ssd.capacity != 0:
                    ssd_evicted, placed = self.ssd.insert_meta(meta)
                    self._drop(ssd_evicted)
                    if placed:
                        self._account_ssd_write()
                        if self.on_insert is not None:
                            self.on_insert(h, "ssd")
                        continue
                break
            meta = BlockMeta(key=h, position=start_pos + i,
                             size_bytes=self.block_bytes)
            self.blocks[h] = meta
            self.policy.on_insert(h, meta)
            if self.on_insert is not None:
                self.on_insert(h, "dram")
        dropped, self._dropped = self._dropped, []
        return dropped

    def touch_keys(self, hash_ids: Iterable[int],
                   count_read: bool = True) -> int:
        """Hit-account an arbitrary VERIFIED set of resident keys (no
        prefix semantics): DRAM keys are touched, SSD keys promoted.
        Unlike ``lookup`` this never walks past the given keys, so the
        serving engine can commit a loaded tail segment without touching
        the head blocks it chose to recompute instead. Returns the number
        of keys found resident."""
        n = 0
        for h in hash_ids:
            if h in self.blocks:
                meta = self.blocks[h]
                meta.hits += 1
                self.policy.on_hit(h, meta)
                self.dram_hits += 1
            elif h in self.ssd.blocks:
                self.ssd.blocks[h].hits += 1
                self._promote(h, count_read=count_read)
                self.ssd_hits += 1
            else:
                continue
            n += 1
            self.hits += 1
        return n

    def discard(self, key: int) -> bool:
        """Drop a block from whichever tier holds it (e.g. a block whose
        on-disk bytes failed their checksum — the metadata must never
        claim residency the store can't honour)."""
        meta = self.remove(key)
        if meta is None:
            meta = self.ssd.remove(key)
        if meta is None:
            return False
        self._drop([key])
        return True

    def pin(self, hash_ids: Iterable[int]) -> None:
        for h in hash_ids:
            if h in self.blocks:
                self.blocks[h].pinned += 1
            elif h in self.ssd.blocks:
                self.ssd.blocks[h].pinned += 1

    def unpin(self, hash_ids: Iterable[int]) -> None:
        for h in hash_ids:
            meta = self.blocks.get(h) or self.ssd.blocks.get(h)
            if meta is not None:
                meta.pinned = max(0, meta.pinned - 1)

    # ---- reporting -----------------------------------------------------
    def tier_stats(self) -> dict:
        return dict(dram_blocks=len(self.blocks),
                    ssd_blocks=len(self.ssd.blocks),
                    dram_hits=self.dram_hits, ssd_hits=self.ssd_hits,
                    misses=self.misses, hit_rate=self.hit_rate,
                    demotions=self.demotions, promotions=self.promotions,
                    ssd_evictions=self.ssd.evictions,
                    ssd_blocks_written=self.ssd_blocks_written,
                    ssd_blocks_read=self.ssd_blocks_read,
                    n_writebacks=self.n_writebacks)
