from repro.training.checkpoint import (latest_checkpoint, load_checkpoint,
                                       save_checkpoint)
from repro.training.loop import TrainResult, train
from repro.training.optim import (OptState, adafactor_init, adafactor_update,
                                  adamw_init, adamw_update, cosine_lr,
                                  make_optimizer)
