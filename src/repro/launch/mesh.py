"""Production meshes (DESIGN.md §7).

Single pod: a 16×16 TPU v5e slice (256 chips), axes (data, model).
Multi-pod: 2 pods = 512 chips, axes (pod, data, model) — the ``pod`` axis
carries only data/pipeline parallelism, never weight sharding (the paper's
"don't extend TP across the slow fabric" mapped to ICI-vs-DCI).

Defined as FUNCTIONS so importing this module never touches jax device
state (device count is locked at first jax init; the dry-run sets
``xla_force_host_platform_device_count=512`` before importing jax).
"""
from __future__ import annotations

import jax


def make_production_mesh(*, multi_pod: bool = False):
    shape = (2, 16, 16) if multi_pod else (16, 16)
    axes = ("pod", "data", "model") if multi_pod else ("data", "model")
    return jax.make_mesh(
        shape, axes,
        axis_types=(jax.sharding.AxisType.Auto,) * len(axes))


def make_test_mesh(data: int = 2, model: int = 2):
    """Small mesh for CPU unit tests (requires forced host device count)."""
    return jax.make_mesh(
        (data, model), ("data", "model"),
        axis_types=(jax.sharding.AxisType.Auto,) * 2)


def make_stage_mesh(stages: int):
    """CPP pipeline mesh (§5.1): one axis of prefill-group stages."""
    return jax.make_mesh(
        (stages,), ("stage",),
        axis_types=(jax.sharding.AxisType.Auto,))


def batch_axes_of(mesh) -> tuple:
    """Mesh axes that carry the batch dimension (everything except
    'model' / 'stage')."""
    return tuple(a for a in mesh.axis_names if a in ("pod", "data"))
