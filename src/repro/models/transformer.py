"""Model assembly for all six architecture kinds.

Layers are SCANNED: per-layer parameters are stacked on a leading axis and
the decoder runs ``jax.lax.scan`` over it, so the lowered HLO contains one
layer body regardless of depth (94-layer models compile on this 1-core CPU
container at 512 placeholder devices).

Three forward modes share the same block code:
  * train    — no caches; returns hidden states for the chunked-CE loss.
  * prefill  — returns per-layer KV (stacked) / final SSM state; logits of
               the last position only (the "first generated token").
  * decode   — one (or a few, for chunked-prefill extension) tokens against
               preallocated caches updated in place (functionally).

Hybrid (jamba) runs a PERIOD scan: one attention layer + (attn_every-1)
Mamba layers per period, FFN alternating dense/MoE inside the period.
Whisper adds a (scanned) bidirectional encoder and per-layer cross-attention
whose K/V are computed once at prefill ("enc_kv" cache).
"""
from __future__ import annotations

import dataclasses
from dataclasses import dataclass
from functools import partial
from typing import Any, NamedTuple, Optional

import jax
import jax.numpy as jnp

from repro.configs.base import ModelConfig
from repro.models.layers import (DTYPE, Dist, NO_DIST, attention_block,
                                 mlp_block, moe_block, rms_norm)
from repro.models.mamba import MambaState, mamba_block

# Sequence-chunk length for the chunked cross-entropy (bounds the logits
# buffer to (B, CE_CHUNK, V) instead of (B, S, V)).
CE_CHUNK = 512


class KVCache(NamedTuple):
    """Stacked attention KV: k, v are (L_attn, B, S, KV, Dh)."""
    k: jax.Array
    v: jax.Array


class Caches(NamedTuple):
    kv: Optional[KVCache]          # self-attention KV (None for pure SSM)
    ssm: Optional[MambaState]      # stacked (L_ssm, ...) (None if no SSM)
    enc_kv: Optional[KVCache]      # whisper cross-attn KV (L, B, S_enc, KV, Dh)
    length: jax.Array              # int32 scalar: tokens written so far


# ---------------------------------------------------------------------------
# parameter initialisation (stacked)
# ---------------------------------------------------------------------------

def _norm(key, shape, scale):
    return (scale * jax.random.normal(key, shape, jnp.float32)).astype(DTYPE)


def _init_attn(key, cfg: ModelConfig, L: int, cross: bool = False) -> dict:
    D, Hp, KV, Dh = cfg.d_model, cfg.padded_heads, cfg.n_kv_heads, cfg.head_dim
    ks = jax.random.split(key, 4)
    s_in = (2.0 / (D + Hp * Dh)) ** 0.5
    wq = _norm(ks[0], (L, D, Hp * Dh), s_in)
    # zero the padded query heads' projections so they contribute nothing
    if Hp != cfg.n_heads:
        m = jnp.repeat(jnp.arange(Hp) < cfg.n_heads, Dh)
        wq = wq * m[None, None, :].astype(DTYPE)
    p = dict(
        ln=jnp.ones((L, D), DTYPE),
        wq=wq,
        wk=_norm(ks[1], (L, D, KV * Dh), s_in),
        wv=_norm(ks[2], (L, D, KV * Dh), s_in),
        wo=_norm(ks[3], (L, Hp * Dh, D), s_in),
    )
    if cfg.attn_bias and not cross:
        p["bq"] = jnp.zeros((L, Hp * Dh), DTYPE)
        p["bk"] = jnp.zeros((L, KV * Dh), DTYPE)
        p["bv"] = jnp.zeros((L, KV * Dh), DTYPE)
    if cfg.qk_norm:
        p["q_norm"] = jnp.ones((L, Dh), DTYPE)
        p["k_norm"] = jnp.ones((L, Dh), DTYPE)
    return p


def _init_mlp(key, cfg: ModelConfig, L: int) -> dict:
    D, F = cfg.d_model, cfg.d_ff
    ks = jax.random.split(key, 3)
    s = (2.0 / (D + F)) ** 0.5
    return dict(
        ln=jnp.ones((L, D), DTYPE),
        w1=_norm(ks[0], (L, D, F), s),
        w2=_norm(ks[1], (L, F, D), s),
        w3=_norm(ks[2], (L, D, F), s),
    )


def _init_moe(key, cfg: ModelConfig, L: int) -> dict:
    D, E, F = cfg.d_model, cfg.moe.n_experts, cfg.moe.d_ff_expert
    ks = jax.random.split(key, 4)
    s = (2.0 / (D + F)) ** 0.5
    return dict(
        ln=jnp.ones((L, D), DTYPE),
        router=_norm(ks[0], (L, D, E), D ** -0.5),
        w1=_norm(ks[1], (L, E, D, F), s),
        w2=_norm(ks[2], (L, E, F, D), s),
        w3=_norm(ks[3], (L, E, D, F), s),
    )


def _init_mamba(key, cfg: ModelConfig, L: int) -> dict:
    s_cfg = cfg.ssm
    D = cfg.d_model
    di = s_cfg.d_inner(D)
    nh = s_cfg.n_heads(D)
    n = s_cfg.d_state
    conv_ch = di + 2 * s_cfg.n_groups * n
    ks = jax.random.split(key, 4)
    proj_out = 2 * di + 2 * s_cfg.n_groups * n + nh
    return dict(
        ln=jnp.ones((L, D), DTYPE),
        in_proj=_norm(ks[0], (L, D, proj_out), (2.0 / (D + proj_out)) ** 0.5),
        conv_w=_norm(ks[1], (L, s_cfg.d_conv, conv_ch), conv_ch ** -0.5),
        dt_bias=jnp.zeros((L, nh), jnp.float32),
        A_log=jnp.zeros((L, nh), jnp.float32),  # A = -exp(0) = -1
        D=jnp.ones((L, nh), jnp.float32),
        norm=jnp.ones((L, di), DTYPE),
        out_proj=_norm(ks[2], (L, di, D), (2.0 / (di + D)) ** 0.5),
    )


def init_params(cfg: ModelConfig, key: jax.Array) -> dict:
    """Stacked parameter pytree for the full model."""
    keys = jax.random.split(key, 10)
    D, V = cfg.d_model, cfg.padded_vocab
    L = cfg.n_layers
    params: dict = {
        "embed": _norm(keys[0], (V, D), D ** -0.5),
        "final_ln": jnp.ones((D,), DTYPE),
    }
    if not cfg.tie_embeddings:
        params["lm_head"] = _norm(keys[1], (D, V), D ** -0.5)

    if cfg.kind == "ssm":
        params["mamba"] = _init_mamba(keys[2], cfg, L)
    elif cfg.attn_every:  # hybrid (jamba): period scan stacks
        n_per = L // cfg.attn_every
        inner = cfg.attn_every - 1  # mamba layers per period
        params["attn"] = _init_attn(keys[2], cfg, n_per)
        params["mamba"] = jax.tree.map(
            lambda x: x.reshape((n_per, inner) + x.shape[1:]),
            _init_mamba(keys[3], cfg, n_per * inner))
        # FFN after every mixer: alternate dense (even pos) / MoE (odd pos)
        n_moe = cfg.attn_every // cfg.moe.every
        n_dense = cfg.attn_every - n_moe
        params["ffn_dense"] = jax.tree.map(
            lambda x: x.reshape((n_per, n_dense) + x.shape[1:]),
            _init_mlp(keys[4], cfg, n_per * n_dense))
        params["ffn_moe"] = jax.tree.map(
            lambda x: x.reshape((n_per, n_moe) + x.shape[1:]),
            _init_moe(keys[5], cfg, n_per * n_moe))
    else:
        params["attn"] = _init_attn(keys[2], cfg, L)
        if cfg.moe is not None and cfg.moe.every == 1:
            params["moe"] = _init_moe(keys[4], cfg, L)
        else:
            params["mlp"] = _init_mlp(keys[4], cfg, L)

    if cfg.encoder_layers:
        Le = cfg.encoder_layers
        params["enc_attn"] = _init_attn(keys[6], cfg, Le)
        params["enc_mlp"] = _init_mlp(keys[7], cfg, Le)
        params["enc_final_ln"] = jnp.ones((D,), DTYPE)
        params["cross_attn"] = _init_attn(keys[8], cfg, L, cross=True)
    return params


def param_count_exact(params) -> int:
    return sum(x.size for x in jax.tree.leaves(params))


# ---------------------------------------------------------------------------
# cache allocation
# ---------------------------------------------------------------------------

def init_caches(cfg: ModelConfig, batch: int, max_len: int, *,
                enc_len: int = 0, window: int = 0) -> Caches:
    """Preallocated decode caches. ``window`` > 0 bounds the attention cache
    to a ring buffer of that many slots (sliding-window serving)."""
    KV, Dh = cfg.n_kv_heads, cfg.head_dim
    eff_window = window or cfg.sliding_window
    S = min(max_len, eff_window) if eff_window else max_len
    kv = None
    if cfg.attention_layers:
        La = cfg.attention_layers
        kv = KVCache(k=jnp.zeros((La, batch, S, KV, Dh), DTYPE),
                     v=jnp.zeros((La, batch, S, KV, Dh), DTYPE))
    ssm = None
    if cfg.ssm is not None and cfg.kind in ("ssm", "hybrid"):
        s_cfg = cfg.ssm
        L_ssm = cfg.n_layers - cfg.attention_layers if cfg.attn_every \
            else cfg.n_layers
        nh = s_cfg.n_heads(cfg.d_model)
        conv_ch = s_cfg.d_inner(cfg.d_model) + 2 * s_cfg.n_groups * s_cfg.d_state
        if cfg.attn_every:
            n_per = cfg.n_layers // cfg.attn_every
            inner = cfg.attn_every - 1
            shape_ssm = (n_per, inner, batch, nh, s_cfg.head_dim, s_cfg.d_state)
            shape_conv = (n_per, inner, batch, s_cfg.d_conv - 1, conv_ch)
        else:
            shape_ssm = (L_ssm, batch, nh, s_cfg.head_dim, s_cfg.d_state)
            shape_conv = (L_ssm, batch, s_cfg.d_conv - 1, conv_ch)
        ssm = MambaState(ssm=jnp.zeros(shape_ssm, jnp.float32),
                         conv=jnp.zeros(shape_conv, DTYPE))
    enc_kv = None
    if cfg.encoder_layers and enc_len:
        enc_kv = KVCache(
            k=jnp.zeros((cfg.n_layers, batch, enc_len, KV, Dh), DTYPE),
            v=jnp.zeros((cfg.n_layers, batch, enc_len, KV, Dh), DTYPE))
    return Caches(kv=kv, ssm=ssm, enc_kv=enc_kv,
                  length=jnp.zeros((), jnp.int32))


# ---------------------------------------------------------------------------
# encoder (whisper) — bidirectional, scanned
# ---------------------------------------------------------------------------

def encode(params, frames, cfg: ModelConfig, dist: Dist):
    """frames: (B, F, D) stub embeddings -> (B, F, D) encoder output."""
    x = frames.astype(DTYPE)

    def block(x, p):
        pa, pm = p
        y, _ = attention_block(x, pa, cfg, dist, causal=False)
        x = x + y
        x = x + mlp_block(x, pm, cfg)
        x = dist.constrain(x, dist.residual_spec(x.shape[1]))
        return x, None

    fn = block
    if cfg.remat:
        fn = jax.checkpoint(block)
    x, _ = jax.lax.scan(fn, x, (params["enc_attn"], params["enc_mlp"]))
    return rms_norm(x, params["enc_final_ln"], cfg.norm_eps)


def build_enc_kv(params, enc_out, cfg: ModelConfig) -> KVCache:
    """Per-decoder-layer cross-attention K/V from the encoder output."""
    B, F, D = enc_out.shape
    KV, Dh = cfg.n_kv_heads, cfg.head_dim

    def one(p):
        k = (enc_out @ p["wk"]).reshape(B, F, KV, Dh)
        v = (enc_out @ p["wv"]).reshape(B, F, KV, Dh)
        return k, v

    k, v = jax.vmap(one)(params["cross_attn"])
    return KVCache(k=k, v=v)


# ---------------------------------------------------------------------------
# decoder stacks
# ---------------------------------------------------------------------------

def _ffn(x, p_mlp, p_moe, cfg, dist, use_moe: bool):
    if use_moe:
        y, aux = moe_block(x, p_moe, cfg, dist)
        return y, aux
    return mlp_block(x, p_mlp, cfg), 0.0


def _uniform_stack(params, x, cfg: ModelConfig, dist: Dist, *, mode: str,
                   caches: Optional[Caches], q_offset, ring: bool,
                   window_override, kv_out: bool):
    """dense / moe / vlm / audio-decoder / ssm stacks (one block per layer)."""
    use_moe = cfg.moe is not None and cfg.moe.every == 1 and cfg.kind != "ssm"
    is_ssm = cfg.kind == "ssm"
    cross = cfg.encoder_layers > 0

    if is_ssm:
        st_xs = caches.ssm if caches is not None else None
        want_state = mode != "train"

        def block_ssm(carry, xs_):
            x, aux = carry
            p_m, st = xs_ if st_xs is not None else (xs_, None)
            y, new_st = mamba_block(x, p_m, cfg, dist, state=st,
                                    return_state=want_state)
            x = x + y
            x = dist.constrain(x, dist.residual_spec(x.shape[1]))
            return (x, aux), (new_st if want_state else 0.0)

        xs = (params["mamba"], st_xs) if st_xs is not None else params["mamba"]
        fn = jax.checkpoint(block_ssm) if (cfg.remat and mode == "train") \
            else block_ssm
        (x, aux), new_states = jax.lax.scan(fn, (x, 0.0), xs)
        return x, aux, (new_states if want_state else None)

    p_f = params["moe"] if use_moe else params["mlp"]
    p_c = params["cross_attn"] if cross else _none_like_stack(cfg.n_layers)
    cache_xs = (caches.kv.k, caches.kv.v) if (mode == "decode" and caches is not None
                                              and caches.kv is not None) else None
    e_kv = (caches.enc_kv.k, caches.enc_kv.v) if (cross and caches is not None
                                                  and caches.enc_kv is not None) \
        else None

    # assemble scan xs — always pass placeholders so the structure is static
    L = cfg.n_layers
    dummy = jnp.zeros((L, 1), DTYPE)
    xs = (params["attn"], p_f,
          p_c if cross else dummy,
          cache_xs if cache_xs is not None else dummy,
          e_kv if e_kv is not None else dummy)

    def block2(carry, xs_):
        x, aux = carry
        p_a, p_fl, p_cl, cache_l, e_kv_l = xs_
        cache_pair = cache_l if cache_xs is not None else None
        ekv_pair = e_kv_l if e_kv is not None else None
        if mode == "train":
            y, kv = attention_block(x, p_a, cfg, dist, q_offset=q_offset,
                                    window_override=window_override)
        elif mode == "prefill":
            y, kv = attention_block(x, p_a, cfg, dist, q_offset=q_offset,
                                    kv_out=True, window_override=window_override)
        else:
            y, kv = attention_block(x, p_a, cfg, dist, cache=cache_pair,
                                    cache_len=caches.length, ring=ring,
                                    window_override=window_override)
        x = x + y
        if cross:
            yc, _ = attention_block(x, p_cl, cfg, dist, enc_kv=ekv_pair)
            x = x + yc
        y, a = _ffn(x, p_fl, p_fl, cfg, dist, use_moe)
        x = x + y
        x = dist.constrain(x, dist.residual_spec(x.shape[1]))
        return (x, aux + a), kv

    fn = jax.checkpoint(block2) if (cfg.remat and mode == "train") else block2
    (x, aux), kv_stack = jax.lax.scan(fn, (x, 0.0), xs)
    return x, aux, kv_stack


def _none_like_stack(L):
    return jnp.zeros((L, 1), DTYPE)


def _hybrid_stack(params, x, cfg: ModelConfig, dist: Dist, *, mode: str,
                  caches: Optional[Caches], q_offset, ring: bool,
                  window_override):
    """jamba period scan: [attn, mamba ×(attn_every-1)], FFN after each mixer
    alternating dense / MoE (MoE on odd in-period positions)."""
    period = cfg.attn_every
    inner = period - 1
    decode = mode == "decode"

    kv_xs = (caches.kv.k, caches.kv.v) if (decode and caches is not None) else None
    st_xs = caches.ssm if caches is not None and caches.ssm is not None else None

    def period_block(carry, xs):
        x, aux = carry
        p_a, p_m, p_fd, p_fm, kv_l, st_l = xs
        new_kv = None
        new_ssm_list, new_conv_list = [], []
        i_d = i_m = 0
        for pos in range(period):
            if pos == 0:  # attention mixer
                if mode == "train":
                    y, kv = attention_block(
                        x, p_a, cfg, dist, q_offset=q_offset,
                        window_override=window_override)
                elif mode == "prefill":
                    y, kv = attention_block(
                        x, p_a, cfg, dist, q_offset=q_offset, kv_out=True,
                        window_override=window_override)
                else:
                    y, kv = attention_block(
                        x, p_a, cfg, dist, cache=kv_l,
                        cache_len=caches.length, ring=ring,
                        window_override=window_override)
                new_kv = kv
            else:  # mamba mixer
                pm = jax.tree.map(lambda t, j=pos - 1: t[j], p_m)
                st = MambaState(ssm=st_l.ssm[pos - 1], conv=st_l.conv[pos - 1]) \
                    if st_xs is not None else None
                y, new_st = mamba_block(x, pm, cfg, dist, state=st,
                                        return_state=(mode != "train"))
                if new_st is not None:
                    new_ssm_list.append(new_st.ssm)
                    new_conv_list.append(new_st.conv)
            x = x + y
            # FFN: MoE every cfg.moe.every-th position (odd positions)
            if (pos % cfg.moe.every) == (cfg.moe.every - 1):
                pf = jax.tree.map(lambda t, j=i_m: t[j], p_fm)
                y, a = moe_block(x, pf, cfg, dist)
                aux = aux + a
                i_m += 1
            else:
                pf = jax.tree.map(lambda t, j=i_d: t[j], p_fd)
                y = mlp_block(x, pf, cfg)
                i_d += 1
            x = x + y
            x = dist.constrain(x, dist.residual_spec(x.shape[1]))
        new_st = MambaState(ssm=jnp.stack(new_ssm_list),
                            conv=jnp.stack(new_conv_list)) \
            if new_ssm_list else _none_like_stack(1)
        return (x, aux), (new_kv if new_kv is not None else _none_like_stack(1),
                          new_st)

    n_per = cfg.n_layers // period
    dummy = jnp.zeros((n_per, 1), DTYPE)
    xs = (params["attn"], params["mamba"], params["ffn_dense"],
          params["ffn_moe"],
          kv_xs if kv_xs is not None else dummy,
          st_xs if st_xs is not None else dummy)

    fn = jax.checkpoint(period_block) if (cfg.remat and mode == "train") \
        else period_block
    (x, aux), (kv_stack, st_stack) = jax.lax.scan(fn, (x, 0.0), xs)
    return x, aux, kv_stack, st_stack


# ---------------------------------------------------------------------------
# top-level forwards
# ---------------------------------------------------------------------------

def _embed(params, tokens, cfg):
    return jnp.take(params["embed"], tokens, axis=0)


def _unembed_chunked(params, h, labels, cfg: ModelConfig, dist: Dist):
    """Chunked cross-entropy: scan over CE_CHUNK-token slices so the
    (B, S, V) logits never materialise. labels -100 = masked."""
    B, S, D = h.shape
    w = params["embed"].T if cfg.tie_embeddings else params["lm_head"]
    V = cfg.padded_vocab
    n = max(S // CE_CHUNK, 1)
    C = S // n
    hs = jnp.moveaxis(h[:, :n * C].reshape(B, n, C, D), 1, 0)
    ls = jnp.moveaxis(labels[:, :n * C].reshape(B, n, C), 1, 0)

    def body(acc, xs):
        hc, lc = xs
        logits = (hc @ w).astype(jnp.float32)  # (B, C, V)
        logz = jax.nn.logsumexp(logits, axis=-1)
        gold = jnp.take_along_axis(
            logits, jnp.clip(lc, 0, V - 1)[..., None], axis=-1)[..., 0]
        mask = (lc >= 0).astype(jnp.float32)
        loss = jnp.sum((logz - gold) * mask)
        return (acc[0] + loss, acc[1] + jnp.sum(mask)), None

    (tot, cnt), _ = jax.lax.scan(body, (0.0, 0.0), (hs, ls))
    return tot / jnp.maximum(cnt, 1.0)


def _logits_at(params, h_last, cfg: ModelConfig):
    """h_last: (B, k, D) -> (B, k, V) logits (small k only)."""
    w = params["embed"].T if cfg.tie_embeddings else params["lm_head"]
    return (h_last @ w).astype(jnp.float32)


def _run_stack(params, x, cfg, dist, *, mode, caches, q_offset, ring,
               window_override):
    if cfg.attn_every:
        h, aux, kv_stack, st_stack = _hybrid_stack(
            params, x, cfg, dist, mode=mode, caches=caches, q_offset=q_offset,
            ring=ring, window_override=window_override)
        new_caches = None
        if mode != "train":
            kv = KVCache(k=kv_stack[0], v=kv_stack[1]) \
                if isinstance(kv_stack, tuple) else None
            ssm = st_stack if isinstance(st_stack, MambaState) else None
            new_caches = (kv, ssm)
        return h, aux, new_caches
    h, aux, out = _uniform_stack(
        params, x, cfg, dist, mode=mode, caches=caches, q_offset=q_offset,
        ring=ring, window_override=window_override, kv_out=(mode == "prefill"))
    new_caches = None
    if mode != "train":
        if cfg.kind == "ssm":
            new_caches = (None, out)
        else:
            kv = KVCache(k=out[0], v=out[1]) if isinstance(out, tuple) else None
            new_caches = (kv, None)
    return h, aux, new_caches


def loss_fn(params, batch: dict, cfg: ModelConfig, dist: Dist = NO_DIST):
    """Training loss. batch: tokens (B,S), labels (B,S) and optionally
    frames/patches (B,F,d_model) for audio/vlm frontends."""
    tokens = batch["tokens"]
    x = _embed(params, tokens, cfg)
    x = dist.constrain(x, dist.residual_spec(x.shape[1]))
    labels = batch["labels"]

    if cfg.encoder_layers:  # whisper: encode stub frames, cross-attend
        enc_out = encode(params, batch["frames"], cfg, dist)
        enc_kv = build_enc_kv(params, enc_out, cfg)
        caches = Caches(kv=None, ssm=None, enc_kv=enc_kv,
                        length=jnp.zeros((), jnp.int32))
        h, aux, _ = _run_stack(params, x, cfg, dist, mode="train",
                               caches=caches, q_offset=0, ring=False,
                               window_override=None)
    else:
        if cfg.frontend == "patch":  # vlm: prepend patch embeddings
            patches = batch["patches"].astype(DTYPE)
            x = jnp.concatenate([patches, x], axis=1)
            pad = jnp.full(patches.shape[:2], -1, labels.dtype)
            labels = jnp.concatenate([pad, labels], axis=1)
        h, aux, _ = _run_stack(params, x, cfg, dist, mode="train",
                               caches=None, q_offset=0, ring=False,
                               window_override=None)

    h = rms_norm(h, params["final_ln"], cfg.norm_eps)
    loss = _unembed_chunked(params, h, labels, cfg, dist)
    return loss + 0.01 * aux


def prefill(params, tokens, cfg: ModelConfig, dist: Dist = NO_DIST, *,
            frames=None, patches=None, q_offset=0,
            window_override=None):
    """Full-sequence prefill. Returns (last-token logits (B, V),
    Caches with exact-length KV / final SSM state)."""
    x = _embed(params, tokens, cfg)
    x = dist.constrain(x, dist.residual_spec(x.shape[1]))
    enc_kv = None
    caches_in = None
    if cfg.encoder_layers:
        enc_out = encode(params, frames, cfg, dist)
        enc_kv = build_enc_kv(params, enc_out, cfg)
        caches_in = Caches(kv=None, ssm=None, enc_kv=enc_kv,
                           length=jnp.zeros((), jnp.int32))
    elif cfg.frontend == "patch" and patches is not None:
        x = jnp.concatenate([patches.astype(DTYPE), x], axis=1)

    h, aux, out = _run_stack(params, x, cfg, dist, mode="prefill",
                             caches=caches_in, q_offset=q_offset, ring=False,
                             window_override=window_override)
    h = rms_norm(h, params["final_ln"], cfg.norm_eps)
    logits = _logits_at(params, h[:, -1:, :], cfg)[:, 0]
    kv, ssm = out
    S_new = x.shape[1]
    caches = Caches(kv=kv, ssm=ssm, enc_kv=enc_kv,
                    length=jnp.asarray(q_offset + S_new, jnp.int32))
    return logits, caches


def decode_step(params, tokens, caches: Caches, cfg: ModelConfig,
                dist: Dist = NO_DIST, *, ring=False, window_override=None):
    """Decode (S small, usually 1) against preallocated caches.
    Returns (logits (B, S, V), updated caches)."""
    x = _embed(params, tokens, cfg)
    x = dist.constrain(x, dist.residual_spec(x.shape[1]))
    h, aux, out = _run_stack(params, x, cfg, dist, mode="decode",
                             caches=caches, q_offset=None, ring=ring,
                             window_override=window_override)
    h = rms_norm(h, params["final_ln"], cfg.norm_eps)
    logits = _logits_at(params, h, cfg)
    kv, ssm = out
    new = Caches(kv=kv if kv is not None else caches.kv,
                 ssm=ssm if ssm is not None else caches.ssm,
                 enc_kv=caches.enc_kv,
                 length=caches.length + tokens.shape[1])
    return logits, new


def decode_step_paged(params, tokens, k_pages, v_pages, block_table,
                      seq_lens, cfg: ModelConfig, dist: Dist = NO_DIST, *,
                      use_pallas: bool = False, window_override=None,
                      shard=None):
    """One continuous-batching decode iteration over the PAGED substrate.

    tokens: (B, 1); k_pages/v_pages: (L, P, page, KV, Dh) — the shared
    device page store, stacked on the layer axis so it rides the layer
    scan as xs exactly like the dense arena does; block_table: (B,
    max_pages) int32 (0-padded with the null page); seq_lens: (B,) tokens
    already written per slot. Returns (logits (B, 1, V), k_pages,
    v_pages) — the block table and lengths are host-managed by the
    engine (growth, COW, slot free), not traced state.

    Supports uniform attention stacks only (the engine's serving archs);
    hybrid/SSM/encoder models keep the dense path.
    """
    from repro.models.layers import paged_attention_block
    assert cfg.attention_layers == cfg.n_layers and not cfg.encoder_layers, \
        "paged decode supports uniform attention stacks"
    use_moe = cfg.moe is not None and cfg.moe.every == 1
    x = _embed(params, tokens, cfg)
    x = dist.constrain(x, dist.residual_spec(x.shape[1]))
    p_f = params["moe"] if use_moe else params["mlp"]

    def block(carry, xs_):
        x, aux = carry
        p_a, p_fl, kp, vp = xs_
        y, (kp, vp) = paged_attention_block(
            x, p_a, cfg, dist, k_pages=kp, v_pages=vp,
            block_table=block_table, seq_lens=seq_lens,
            use_pallas=use_pallas, window_override=window_override,
            shard=shard)
        x = x + y
        y, a = _ffn(x, p_fl, p_fl, cfg, dist, use_moe)
        x = x + y
        x = dist.constrain(x, dist.residual_spec(x.shape[1]))
        return (x, aux + a), (kp, vp)

    (x, aux), (kps, vps) = jax.lax.scan(
        block, (x, 0.0), (params["attn"], p_f, k_pages, v_pages))
    h = rms_norm(x, params["final_ln"], cfg.norm_eps)
    logits = _logits_at(params, h, cfg)
    return logits, kps, vps


def paged_shard_reason(cfg: ModelConfig, model_shards: int,
                       data_shards: int = 1) -> str:
    """Why the sharded paged decode step can NOT cover ``cfg`` on a
    (data, model) mesh — empty string when it can. KV heads stripe over
    the model axis only for grouped GQA (contiguous query-head groups per
    KV head; the padded ``qh2kv`` remap scatters query heads across KV
    heads, so a head stripe is not self-contained — the same boundary as
    the Pallas kernel's ``_kernel_ok``)."""
    from repro.models.layers import GROUPED_ATTN
    if not paged_supported_cfg(cfg):
        return "paged decode covers uniform attention stacks only"
    if cfg.moe is not None and cfg.moe.every == 1:
        return ("MoE layers route through their own shard_map dispatch; "
                "the sharded paged step covers dense-MLP stacks")
    if model_shards > 1:
        Hp, KV = cfg.padded_heads, cfg.n_kv_heads
        if not (GROUPED_ATTN and Hp == cfg.n_heads and Hp % KV == 0):
            return (f"model-parallel KV heads need grouped GQA "
                    f"(padded_heads == n_heads, divisible groups); "
                    f"{cfg.name} pads {cfg.n_heads}→{Hp} query heads "
                    f"over {KV} KV heads")
        if KV % model_shards != 0:
            return (f"n_kv_heads={KV} not divisible by model axis "
                    f"{model_shards}")
    del data_shards   # any data axis works: slots shard row-wise
    return ""


def paged_supported_cfg(cfg: ModelConfig) -> bool:
    return cfg.attention_layers == cfg.n_layers and not cfg.encoder_layers


def decode_step_paged_sharded(params, tokens, k_pages, v_pages, block_table,
                              seq_lens, cfg: ModelConfig, mesh, *,
                              use_pallas: bool = False,
                              window_override=None):
    """``decode_step_paged`` under ``compat_shard_map`` on a (data, model)
    mesh: decode slots data-parallel (tokens / block table / seq_lens
    shard by row; every per-slot op is row-independent, so each data
    shard's math is bitwise the full-batch math), KV heads model-parallel
    (each model shard holds (L, P, page, KV/m, Dh) page-slab stripes; the
    inner attention loop is all_gather/psum-free because attention is
    head-local, and the only model-axis collective is the exact
    head-concatenating combine ahead of the output projection inside
    ``paged_attention_block``).

    The block table arrives with BANK-LOCAL page ids (each data shard's
    rows index its own page-slab bank directly — ``DevicePagePool``
    converts global→local host-side), so the per-shard body is literally
    the single-device step. Weights are replicated; logits come back
    row-sharded and reassemble to the global (B, 1, V).
    """
    from jax.sharding import PartitionSpec as P
    from repro.launch.mesh import compat_shard_map
    from repro.models.layers import PagedShard
    m = int(mesh.shape.get("model", 1))
    d = int(mesh.shape.get("data", 1))
    reason = paged_shard_reason(cfg, m, d)
    if reason:
        raise ValueError(f"cannot shard paged decode over {d}x{m}: {reason}")
    shard = PagedShard("model", m)
    pages_spec = P(None, "data", None, "model", None)

    def local_step(p, t, kp, vp, tbl, lens):
        return decode_step_paged(p, t, kp, vp, tbl, lens, cfg,
                                 use_pallas=use_pallas,
                                 window_override=window_override,
                                 shard=shard)

    f = compat_shard_map(
        local_step, mesh=mesh,
        in_specs=(P(), P("data", None), pages_spec, pages_spec,
                  P("data", None), P("data")),
        out_specs=(P("data", None, None), pages_spec, pages_spec),
        check_vma=False)
    return f(params, tokens, k_pages, v_pages, block_table, seq_lens)
