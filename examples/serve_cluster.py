"""End-to-end disaggregated serving driver (§3 workflow, executable).

A miniature Mooncake deployment in one process: TWO prefill workers with
a shared CPU-DRAM KVCache pool, TWO continuous-batching decode workers,
and a Conductor (Algorithm 1) in front deciding, per request, which
prefill instance serves it (cache-aware + balancing) and which decode
instance it joins. Requests come from a generated Mooncake-format trace
and are realised to actual tokens whose block structure matches the hash
chains — so the engine's measured prefix reuse equals the trace's.

With ``--global-pool`` the two pools share one ``GlobalBlockDirectory``
(the Figure-3 cluster-wide pool): a block demoted to one instance's SSD
store is fetchable by the other, the Conductor prices the peer-SSD arm,
and the stores' measured read EMAs feed back into the arm prices.

With ``--processes N`` the cluster is N REAL OS processes: one parent
hosting the wire-protocol ``DirectoryServer``, N workers that each own a
``HostKVPool`` + ``BlockServer`` and fetch peer blocks over CRC-framed
sockets (``SocketPeer``). Each worker prefills its own document, then
serves a query extending ANOTHER node's document — a cross-process
socket fetch — and the parent checks every decoded token bit-exact
against a single-process DRAM-only oracle. ``--chaos kill-owner``
SIGKILLs the block owner mid-transfer; survivors must still match the
oracle, with the degradation accounted in ``fallback_reasons``.

    PYTHONPATH=src python examples/serve_cluster.py [--requests 24]
    PYTHONPATH=src python examples/serve_cluster.py --ssd-blocks 64 \
        --ssd-dir /tmp/kvssd --dram-blocks 8 --global-pool
    PYTHONPATH=src python examples/serve_cluster.py --processes 3 \
        --chaos kill-owner
"""
import argparse
import json
import os
import signal
import subprocess
import sys
import time

import jax
import numpy as np

from repro.configs.base import get_config
from repro.core.cache import CachePool
from repro.core.conductor import Conductor, DecodeInstance, PrefillInstance
from repro.core.costmodel import CostModel, InstanceSpec
from repro.core.messenger import Messenger
from repro.core.policies import list_policies
from repro.core.trace import BLOCK_TOKENS, TraceSpec, generate_trace
from repro.data.pipeline import realize_request_tokens
from repro.models.transformer import init_params
from repro.serving.engine import (DecodeWorker, HostKVPool, PrefillWorker,
                                  prefix_hash_ids)
from repro.serving.request import ServingRequest


def _cluster_workload(n: int, vocab: int):
    """Deterministic docs + queries shared by parent, workers, and the
    oracle: query i extends node (i+1)%n's document, so serving it from
    node i forces a cross-process socket fetch."""
    rng = np.random.default_rng(42)
    docs = [rng.integers(0, vocab, size=2 * BLOCK_TOKENS, dtype=np.int32)
            for _ in range(n)]
    extras = [rng.integers(0, vocab, size=48, dtype=np.int32)
              for _ in range(n)]
    queries = [np.concatenate([docs[(i + 1) % n], extras[i]])
               for i in range(n)]
    return docs, queries


def _decode_all(params, cfg, pw, dw, tokens, max_new: int) -> list:
    pres = pw(tokens)
    dw.join(ServingRequest(req_id=0, tokens=tokens, max_new=max_new), pres)
    out = [pres.first_token]
    while dw.n_active:
        for _, tok, fin in dw.step():
            out.append(tok)
    return [int(t) for t in out]


def _worker_main(args) -> int:
    """One cluster node: HELLO the directory, serve blocks over a
    ``BlockServer``, fetch peers over ``SocketPeer``s, answer one query."""
    from repro.serving.directory_service import RemoteDirectory
    from repro.serving.transport import BlockServer, InProcPeer, SocketPeer

    n, i = args.processes, args.worker_node
    cfg = get_config("smollm-360m").reduced()
    params = init_params(cfg, jax.random.PRNGKey(0))
    docs, queries = _cluster_workload(n, cfg.vocab_size)

    # tiny DRAM tier: the node's own doc demotes straight to its SSD
    # store, so peers fetch it through the store's CRC'd slots
    pool = HostKVPool(capacity_blocks=1, ssd_capacity_blocks=64,
                      writeback_batch=1,
                      ssd_dir=os.path.join(args.ssd_dir, f"p{i}"))
    server = BlockServer(InProcPeer(pool), stall_s=args.serve_stall)
    host, port = args.directory.rsplit(":", 1)
    rdir = RemoteDirectory((host, int(port)), node_id=i,
                           block_port=server.port)
    pool.directory = rdir
    pool.node_id = i
    rdir.bind(i, pool.meta)
    pw = PrefillWorker(params, cfg, pool, prefill_chunk=256,
                       ssd_mode=args.ssd_mode)
    dw = DecodeWorker(params, cfg, max_batch=1, max_len=2048,
                      substrate="dense")

    pw(docs[i])                         # round 1: publish own doc
    br = rdir.barrier("published", n, timeout=600)
    if not br["met"]:
        print(f"node {i}: cluster failed to assemble ({br})", flush=True)
        return 2
    peers = {}
    for nid, (phost, pport) in sorted(rdir.nodes().items()):
        if nid != i:
            peers[nid] = SocketPeer((phost, pport), node=nid)
            pool.add_peer(nid, peers[nid])
    # parent joins this barrier too: it times the chaos kill off it
    br = rdir.barrier("round2", n + 1, timeout=600)
    if not br["met"]:
        print(f"node {i}: round-2 barrier failed ({br})", flush=True)
        return 2
    toks = _decode_all(params, cfg, pw, dw, queries[i], args.max_new)

    # modeled-vs-measured, wire edition: feed each peer's observed socket
    # bandwidth back into the Messenger's egress links
    msg = Messenger(list(range(n)), bw=100e9)
    bw = {}
    for nid, sp in peers.items():
        if sp.bw_ema:
            msg.set_link_bw(nid, sp.bw_ema)
            bw[str(nid)] = int(sp.bw_ema)
    print("RESULT " + json.dumps(dict(
        node=i, tokens=toks, peer_blocks=pool.peer_blocks_fetched,
        fallback=pool.fallback_reasons, bw=bw)), flush=True)
    for sp in peers.values():
        sp.close()
    server.close()
    rdir.close()
    pool.close()
    return 0


def _parent_main(args) -> int:
    """Launch N worker processes around an in-process DirectoryServer,
    optionally kill -9 the block owner mid-transfer, and hold every
    surviving answer bit-exact against a single-process oracle."""
    import shutil
    import tempfile

    from repro.serving.directory_service import (DirectoryServer,
                                                 RemoteDirectory)

    n = args.processes
    chaos = args.chaos == "kill-owner"
    stall = args.serve_stall if args.serve_stall is not None else \
        (0.2 if chaos else 0.0)
    cfg = get_config("smollm-360m").reduced()
    params = init_params(cfg, jax.random.PRNGKey(0))
    docs, queries = _cluster_workload(n, cfg.vocab_size)

    # single-process DRAM-only oracle for every query
    opool = HostKVPool(capacity_blocks=4096)
    opw = PrefillWorker(params, cfg, opool, prefill_chunk=256)
    odw = DecodeWorker(params, cfg, max_batch=1, max_len=2048,
                       substrate="dense")
    oracle = {i: _decode_all(params, cfg, opw, odw, queries[i], args.max_new)
              for i in range(n)}
    opool.close()

    dserver = DirectoryServer()
    base = args.ssd_dir or tempfile.mkdtemp(prefix="serve-cluster-")
    made_tmp = args.ssd_dir is None
    env = dict(os.environ)
    src = os.path.abspath(os.path.join(os.path.dirname(__file__), "..",
                                       "src"))
    env["PYTHONPATH"] = src + (os.pathsep + env["PYTHONPATH"]
                               if env.get("PYTHONPATH") else "")
    procs = []
    print(f"cluster: directory @ 127.0.0.1:{dserver.port}, "
          f"{n} worker processes"
          + (f", chaos={args.chaos} (stall {stall}s/layer)" if chaos else ""),
          flush=True)
    for i in range(n):
        procs.append(subprocess.Popen(
            [sys.executable, os.path.abspath(__file__),
             "--processes", str(n), "--worker-node", str(i),
             "--directory", f"127.0.0.1:{dserver.port}",
             "--ssd-dir", base, "--ssd-mode", args.ssd_mode,
             "--max-new", str(args.max_new), "--serve-stall", str(stall)],
            stdout=subprocess.PIPE, stderr=subprocess.STDOUT, text=True,
            env=env))
    rd = RemoteDirectory(dserver.addr)
    failures = 0
    try:
        br = rd.barrier("round2", n + 1, timeout=600)
        if not br["met"]:
            print(f"cluster never reached round 2: {br}", flush=True)
            return 1
        print(f"round 2 underway: nodes {sorted(dserver.endpoints())}",
              flush=True)
        if chaos:
            time.sleep(args.chaos_delay)
            print(f"chaos: SIGKILL node 0 (pid {procs[0].pid}) "
                  f"mid-FETCH_BLOCK", flush=True)
            os.kill(procs[0].pid, signal.SIGKILL)
            # the dead node's directory conn is its lease: its claims
            # must drop without any explicit withdraw
            doc0 = prefix_hash_ids(docs[0])
            deadline = time.time() + 10
            while time.time() < deadline and \
                    0 in dserver.directory.holders(doc0[0]):
                time.sleep(0.05)
            if 0 in dserver.directory.holders(doc0[0]):
                print("FAIL: dead node 0 still owns blocks in the "
                      "directory", flush=True)
                failures += 1
            else:
                print("directory self-healed: node 0's claims dropped",
                      flush=True)

        results = {}
        for i, p in enumerate(procs):
            try:
                out, _ = p.communicate(timeout=600)
            except subprocess.TimeoutExpired:
                p.kill()
                out, _ = p.communicate()
            for line in out.splitlines():
                if line.startswith("RESULT "):
                    r = json.loads(line[len("RESULT "):])
                    results[r["node"]] = r
            if chaos and i == 0:
                if p.returncode != -signal.SIGKILL:
                    print(f"FAIL: node 0 exited {p.returncode}, "
                          f"expected SIGKILL", flush=True)
                    failures += 1
            elif p.returncode != 0:
                print(f"FAIL: node {i} exited {p.returncode}:\n{out}",
                      flush=True)
                failures += 1

        survivors = range(1 if chaos else 0, n)
        reasons: dict = {}
        for i in survivors:
            r = results.get(i)
            if r is None:
                print(f"FAIL: no RESULT from node {i}", flush=True)
                failures += 1
                continue
            ok = r["tokens"] == oracle[i]
            if not ok:
                failures += 1
            for k, v in r["fallback"].items():
                reasons[k] = reasons.get(k, 0) + v
            print(f"node {i}: {len(r['tokens'])} tokens "
                  f"{'bit-exact' if ok else 'MISMATCH'} vs oracle — "
                  f"peer_blocks={r['peer_blocks']} "
                  f"fallback={r['fallback']} bw={r['bw']}", flush=True)
        if chaos:
            if not (reasons.get("peer_unreachable")
                    or reasons.get("verify_failed")):
                print("FAIL: no survivor accounted the dead owner in "
                      f"fallback_reasons ({reasons})", flush=True)
                failures += 1
        else:
            expect = 2 * len(list(survivors))   # every query = 2 peer blocks
            got = sum(results[i]["peer_blocks"] for i in survivors
                      if i in results)
            if got != expect:
                print(f"FAIL: {got} peer blocks fetched over the wire, "
                      f"expected {expect}", flush=True)
                failures += 1
        print(("PASS" if not failures else f"FAIL ({failures})")
              + f": {len([i for i in survivors if i in results])}/{n} "
              f"nodes answered, degradations {reasons or '{}'}", flush=True)
    finally:
        for p in procs:
            if p.poll() is None:
                p.kill()
                p.wait()
        rd.close()
        dserver.close()
        if made_tmp:
            shutil.rmtree(base, ignore_errors=True)
    return 1 if failures else 0


def main():
    ap = argparse.ArgumentParser()
    ap.add_argument("--requests", type=int, default=24)
    ap.add_argument("--max-new", type=int, default=8)
    ap.add_argument("--dram-blocks", type=int, default=2048,
                    help="per-instance DRAM KVCache tier capacity (blocks)")
    ap.add_argument("--ssd-blocks", type=int, default=0,
                    help="per-instance SSD tier capacity (blocks); "
                         "0 = flat DRAM pool (seed behaviour)")
    ap.add_argument("--ssd-dir", default=None,
                    help="base directory for the file-backed SSD store "
                         "(one subdir per prefill instance); omit to keep "
                         "demoted bytes in host arrays")
    ap.add_argument("--ssd-mode", default="overlap",
                    choices=("blocking", "overlap"),
                    help="SSD prefix loads: synchronous, or overlapped "
                         "with head-chunk recompute (§5.2)")
    ap.add_argument("--strategy", default="kvcache",
                    choices=list_policies("prefill"),
                    help="prefill routing policy (from the registry)")
    ap.add_argument("--global-pool", action="store_true",
                    help="share one GlobalBlockDirectory across the prefill "
                         "instances' pools: blocks demoted on one node are "
                         "peer-fetchable from the other, and the Conductor "
                         "prices the peer-SSD arm (requires --ssd-blocks)")
    ap.add_argument("--decode-substrate", default="paged",
                    choices=("paged", "dense"),
                    help="decode KV substrate: block-table pages shared "
                         "prefill→decode (zero-copy join, prefix-sharing "
                         "slots), or the dense per-slot arena (the "
                         "bit-exactness oracle)")
    ap.add_argument("--mesh", default=None, metavar="DxM",
                    help="shard the paged decode engine over a (data, "
                         "model) device mesh (e.g. 2x2): slots + page "
                         "banks over data, KV-head stripes over model. "
                         "Needs D*M jax devices; on CPU set XLA_FLAGS="
                         "--xla_force_host_platform_device_count=N")
    ap.add_argument("--device-pages", type=int, default=0,
                    help="device page-pool size (0 = sized from the decode "
                         "workers' slot budget)")
    ap.add_argument("--loop", action="store_true",
                    help="drive the always-on ServingLoop (thread-fed "
                         "arrivals, chunked prefill interleaved with decode "
                         "steps, admission backpressure) instead of the "
                         "Conductor's phase-at-a-time dispatch")
    ap.add_argument("--tbt-budget", type=float, default=None,
                    help="loop TBT budget in seconds (default: "
                         "deterministic one-chunk-per-iteration interleave)")
    ap.add_argument("--processes", type=int, default=0,
                    help="run the cluster as N real OS processes over the "
                         "wire protocol (directory service + CRC-framed "
                         "block fetches), checked bit-exact against a "
                         "single-process oracle")
    ap.add_argument("--chaos", default="none",
                    choices=("none", "kill-owner"),
                    help="with --processes: SIGKILL the block owner "
                         "mid-FETCH_BLOCK; survivors must stay bit-exact "
                         "with the degradation in fallback_reasons")
    ap.add_argument("--chaos-delay", type=float, default=0.08,
                    help="seconds after the round-2 barrier to fire the "
                         "chaos kill")
    ap.add_argument("--serve-stall", type=float, default=None,
                    help="per-LAYER serving stall in each worker's "
                         "BlockServer (widens the mid-transfer window the "
                         "chaos kill lands in; default 0, or 0.2 under "
                         "--chaos kill-owner)")
    ap.add_argument("--worker-node", type=int, default=None,
                    help=argparse.SUPPRESS)   # internal: spawned by parent
    ap.add_argument("--directory", default=None,
                    help=argparse.SUPPRESS)   # internal: host:port
    args = ap.parse_args()

    if args.worker_node is not None:
        if args.serve_stall is None:
            args.serve_stall = 0.0
        sys.exit(_worker_main(args))
    if args.processes:
        sys.exit(_parent_main(args))

    if args.global_pool and not args.ssd_blocks:
        ap.error("--global-pool needs an SSD tier (--ssd-blocks > 0)")

    cfg = get_config("smollm-360m").reduced()
    mesh = None
    mesh_d = 1
    if args.mesh:
        import dataclasses

        from repro.launch.mesh import make_decode_mesh, parse_mesh_arg
        from repro.models.transformer import paged_shard_reason
        if args.decode_substrate != "paged":
            ap.error("--mesh shards the PAGED decode substrate")
        mesh_d, mesh_m = parse_mesh_arg(args.mesh)
        if mesh_m > 1 and paged_shard_reason(cfg, mesh_m, mesh_d):
            kv = max(4, mesh_m)
            if 16 % kv or kv % mesh_m:
                ap.error(f"--mesh model axis {mesh_m} has no grouped-GQA "
                         f"head layout")
            print(f"--mesh {args.mesh}: adjusting the reduced arch to "
                  f"grouped GQA (n_heads=16, n_kv_heads={kv}) so KV heads "
                  f"stripe over the model axis")
            cfg = dataclasses.replace(cfg, n_heads=16, n_kv_heads=kv)
        reason = paged_shard_reason(cfg, mesh_m, mesh_d)
        if reason:
            ap.error(f"--mesh {args.mesh}: {reason}")
        mesh = make_decode_mesh(mesh_d, mesh_m)
    params = init_params(cfg, jax.random.PRNGKey(0))

    # ---- build the disaggregated cluster ----
    n_p, n_d = 2, 2
    directory = None
    if args.global_pool:
        from repro.core.directory import GlobalBlockDirectory
        directory = GlobalBlockDirectory()
    # --ssd-dir without --ssd-blocks raises in HostKVPool (a store nothing
    # can reach is a config error, not a silent flat pool)
    pools = [HostKVPool(capacity_blocks=args.dram_blocks,
                        ssd_capacity_blocks=args.ssd_blocks,
                        ssd_dir=(os.path.join(args.ssd_dir, f"p{i}")
                                 if args.ssd_dir else None),
                        directory=directory, node_id=i)
             for i in range(n_p)]
    if directory is not None:
        from repro.serving.engine import connect_pools
        connect_pools(pools)
    # ONE device page pool for the whole in-process cluster (the HBM the
    # paged substrate pages live in): prefill workers stage fresh KV into
    # it and decode workers adopt the runs — the zero-copy §3 handoff
    max_batch, max_len, page_tokens = 4, 2048, 64
    page_pool = None
    from repro.serving.engine import paged_supported
    if args.decode_substrate == "paged" and paged_supported(cfg):
        from repro.serving.paged_cache import DevicePagePool
        per_seq = (max_len + page_tokens - 1) // page_tokens
        # mesh: n_pages is the PER-BANK budget (capacity scales ×data)
        n_pages = args.device_pages or \
            1 + ((n_d * max_batch) // mesh_d + n_p) * per_seq
        page_pool = DevicePagePool(cfg, n_pages=n_pages,
                                   page_tokens=page_tokens, mesh=mesh)
        if mesh is not None:
            print(f"decode mesh {args.mesh}: {page_pool.n_banks} page "
                  f"banks × {page_pool.bank_pages} pages, KV heads / "
                  f"{mesh_m} model shards")
    pws = [PrefillWorker(params, cfg, pools[i], prefill_chunk=256,
                         ssd_mode=args.ssd_mode, page_pool=page_pool)
           for i in range(n_p)]
    dws = [DecodeWorker(params, cfg, max_batch=max_batch, max_len=max_len,
                        substrate=args.decode_substrate, page_pool=page_pool)
           for _ in range(n_d)]

    cost = lambda: CostModel(get_config("llama2-70b"), InstanceSpec())
    P = [PrefillInstance(iid=i, pool=pools[i].meta, cost=cost())
         for i in range(n_p)]
    D = [DecodeInstance(iid=100 + i, cost=cost()) for i in range(n_d)]
    msg = Messenger([p.iid for p in P] + [d.iid for d in D], bw=100e9)
    if args.ssd_blocks:
        for p in P:
            msg.add_ssd_channel(p.iid, InstanceSpec().hw.ssd_read_bw)
    conductor = Conductor(P, D, msg, ttft_slo=30.0, tbt_slo=0.1,
                          strategy=args.strategy, directory=directory)

    # ---- workload: session-structured trace, scaled to smoke size ----
    trace = generate_trace(TraceSpec(
        n_requests=args.requests, duration_ms=5_000, seed=1,
        max_input_tokens=1536, chat_turn_mu=5.5, doc_len_mu=6.8,
        frac_oneshot=0.2, frac_chat=0.6, frac_doc=0.2))[:args.requests]
    for r in trace:
        r.input_length = min(max(r.input_length, 64), 1536)
        r.hash_ids = r.hash_ids[:max(r.input_length // BLOCK_TOKENS, 1)]

    if args.loop:
        # always-on mode: ONE ServingLoop owns the page pool, a single
        # decode batch, and both prefill workers; routing (deepest pool
        # residency) and backpressure live in the loop, so the Conductor
        # is bypassed. A feeder thread plays the trace's arrival order.
        import threading

        from repro.serving.loop import ServingLoop
        print(f"serving loop: {n_p} prefill workers -> 1 decode batch "
              f"(max_batch={dws[0].max_batch}); {len(trace)} requests\n")
        loop = ServingLoop(pws, dws[0], tbt_budget_s=args.tbt_budget,
                           max_queue=max(args.requests, 8))
        payloads = [(r.req_id, realize_request_tokens(r, cfg.vocab_size),
                     min(args.max_new, max(r.output_length, 2)),
                     r.hash_ids[0] if r.hash_ids else None) for r in trace]

        def feeder():
            for rid, toks, mn, sess in payloads:
                loop.submit(ServingRequest(req_id=rid, tokens=toks,
                                           max_new=mn, session=sess))
            loop.close_intake()

        t0 = time.time()
        th = threading.Thread(target=feeder, name="repro-loop-feeder")
        th.start()
        ls = loop.run()
        th.join()
        dt = time.time() - t0
        total_tokens = sum(len(o.tokens) for o in loop.outputs.values())
        reused = sum(pw.stats()["reused_blocks"] for pw in pws)
        print(f"served {ls['completed']} requests, {total_tokens} tokens "
              f"in {dt:.1f}s — {ls['decode_steps']} decode steps, "
              f"{ls['prefill_chunks']} prefill chunks interleaved, "
              f"{ls['rejected']} rejected by backpressure, "
              f"{ls['preemptions']} preemptions")
        print(f"prefix reuse: {reused} blocks; TBT p50/p99 "
              f"{ls['tbt_p50_s'] * 1e3:.1f}/{ls['tbt_p99_s'] * 1e3:.1f} ms")
        # every component reports through the same stats() protocol —
        # one uniform snapshot of the whole serving stack
        snapshots = {"loop": ls, "decode": dws[0].stats(),
                     "pool[0]": pools[0].stats()}
        if page_pool is not None:
            snapshots["pages"] = page_pool.stats()
        for name, snap in snapshots.items():
            line = ", ".join(f"{k}={v}" for k, v in sorted(snap.items())
                             if not isinstance(v, float))
            print(f"  {name:8s} {line}")
        for pool in pools:
            pool.close()
        return

    print(f"cluster: {n_p} prefill + {n_d} decode workers; "
          f"{len(trace)} requests\n")
    t0 = time.time()
    stats = dict(reused=0, computed=0, migrations=0)
    active: dict[int, int] = {}       # req_id -> decode worker idx
    outputs: dict[int, list] = {}
    queue = list(trace)

    while queue or any(dw.n_active for dw in dws):
        # admit as many as fit
        while queue and any(dw.n_active < dw.max_batch for dw in dws):
            req = queue.pop(0)
            dec = conductor.schedule(req, now=time.time() - t0)
            if not dec.accepted:
                print(f"req {req.req_id:3d}: REJECTED ({dec.reject_reason})")
                continue
            pi = dec.prefill.iid
            di = dec.decode.iid - 100
            if dws[di].n_active >= dws[di].max_batch:
                di = next(i for i, d in enumerate(dws)
                          if d.n_active < d.max_batch)
            # hot-spot migration: copy blocks between the REAL pools
            if dec.migrated_blocks and dec.transfer_from is not None:
                src = pools[dec.transfer_from]
                dstp = pools[pi]
                hit = src.meta.prefix_len(req.hash_ids)
                if hit:
                    k, v = src.get(req.hash_ids[:hit])
                    dstp.put(req.hash_ids[:hit], k, v)
                    stats["migrations"] += 1
            tokens = realize_request_tokens(req, cfg.vocab_size)
            # session key = chain root: turns of one session extend the same
            # chain, so the incremental hasher re-hashes only the suffix
            pres = pws[pi](tokens,
                           session=req.hash_ids[0] if req.hash_ids else None)
            stats["reused"] += pres.reused_blocks
            stats["computed"] += pres.prompt_len - 512 * pres.reused_blocks
            # close the modeled-vs-measured loop: feed the store's measured
            # read EMA back into the Conductor's arm prices (CostModel) and
            # the Messenger's SSD channel bandwidth. The channel bw must be
            # in the COST MODEL's byte units (the conductor prices 70B-sized
            # blocks; the engine stores reduced-model blocks), so one
            # modeled block load costs exactly one measured read
            store = pools[pi].store
            if store is not None and store.read_s_ema is not None:
                P[pi].cost.calibrate_ssd_read(store.read_s_ema)
                msg.set_ssd_bw(P[pi].iid,
                               P[pi].cost.kv_bytes(BLOCK_TOKENS)
                               / store.read_s_ema)
            dws[di].join(ServingRequest(
                req_id=req.req_id, tokens=tokens,
                max_new=min(args.max_new, max(req.output_length, 2))), pres)
            active[req.req_id] = di
            outputs[req.req_id] = [pres.first_token]
            print(f"req {req.req_id:3d}: prefill@P{pi} "
                  f"({pres.prompt_len:5d} tok, reuse {pres.reused_blocks:2d} "
                  f"blk{', migrated' if dec.migrated_blocks else ''}) "
                  f"-> decode@D{di}")
        # one continuous-batching iteration on every decode worker
        for dw in dws:
            for rid, tok, fin in dw.step():
                outputs[rid].append(tok)
                if fin:
                    active.pop(rid, None)

    dt = time.time() - t0
    total_tokens = sum(len(v) for v in outputs.values())
    print(f"\nserved {len(outputs)} requests, {total_tokens} tokens "
          f"in {dt:.1f}s")
    print(f"prefix reuse: {stats['reused']} blocks "
          f"({512 * stats['reused']} tokens skipped), "
          f"computed {stats['computed']} tokens, "
          f"hot-spot migrations: {stats['migrations']}")
    hashed = sum(pw.hasher.blocks_hashed for pw in pws)
    memo = sum(pw.hasher.memo_hits for pw in pws)
    print(f"prefix hashing: {hashed} blocks SHA'd, {memo} session memo hits")
    if page_pool is not None:
        ps = page_pool.stats()
        zc = sum(dw.stats()["zero_copy_joins"] for dw in dws)
        print(f"paged substrate: {page_pool.n_pages} pages "
              f"({page_pool.page_tokens} tok), {page_pool.used_pages} held, "
              f"{ps['pages_written']} written, {ps['shared_adoptions']} "
              f"shared-prefix adoptions, {ps['cow_copies']} COW copies, "
              f"{ps['registry_evictions']} registry evictions; "
              f"{zc} zero-copy joins")
    print(f"conductor migrations (metadata): {conductor.n_migrations}")
    if directory is not None:
        d = directory.stats()
        fetched = sum(p.peer_blocks_fetched for p in pools)
        failures = sum(p.peer_fetch_failures for p in pools)
        reasons: dict = {}
        for p in pools:
            for k, v in p.fallback_reasons.items():
                reasons[k] = reasons.get(k, 0) + v
        print(f"global pool: directory {d['keys']} keys "
              f"({d['dram_claims']} dram / {d['ssd_claims']} ssd claims), "
              f"conductor peer-SSD arms won {conductor.n_peer_ssd_loads}, "
              f"engine fetched {fetched} peer blocks "
              f"({failures} failures{', ' + str(reasons) if reasons else ''})")
    if args.ssd_blocks:
        print(f"conductor SSD prefix loads: {conductor.n_ssd_loads}")
        for i, pool in enumerate(pools):
            s = pool.meta.tier_stats()
            print(f"P{i} tiers: dram={s['dram_blocks']} ssd={s['ssd_blocks']} "
                  f"hits(dram/ssd)={s['dram_hits']}/{s['ssd_hits']} "
                  f"demote={s['demotions']} promote={s['promotions']} "
                  f"writebacks={s['n_writebacks']}")
            if pool.store is not None:
                st = pool.store.stats()
                print(
                    f"   store: {st['blocks']} on disk "
                    f"({st['file_bytes'] >> 10} KiB), wrote "
                    f"{st['blocks_written']} blk / {st['n_flushes']} flushes, "
                    f"read {st['layer_reads']} layers, "
                    f"{st['read_failures']} failures; engine overlapped "
                    f"{pws[i].stats()['overlapped_requests']} prefills "
                    f"({pws[i].stats()['ssd_loaded_blocks']} blocks "
                    f"prefetched)")
    for pool in pools:
        pool.close()


if __name__ == "__main__":
    main()
