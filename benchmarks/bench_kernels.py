"""Kernel micro-benchmarks: Pallas (interpret) vs jnp oracle — correctness
deltas + CPU wall time (the TPU perf story lives in the roofline; here we
verify the kernels at serving-realistic shapes and report call latency)."""
from __future__ import annotations

import time

import jax
import jax.numpy as jnp
import numpy as np

from benchmarks.common import emit
from repro.kernels.flash_prefill.kernel import flash_prefill
from repro.kernels.flash_prefill.ref import flash_prefill_ref
from repro.kernels.paged_attention.kernel import paged_attention
from repro.kernels.paged_attention.ref import paged_attention_ref
from repro.kernels.ssd_scan.kernel import ssd_scan
from repro.kernels.ssd_scan.ref import ssd_scan_ref


def _time(fn, *args, reps=3):
    fn(*args)  # compile
    t0 = time.time()
    for _ in range(reps):
        jax.block_until_ready(fn(*args))
    return (time.time() - t0) / reps


def main(fast: bool = False):
    key = jax.random.PRNGKey(0)
    rows = []

    # flash_prefill at a chunked-prefill shape (chunk 512 against 2k ctx)
    B, Sq, Sk, H, KV, D = 1, 512, 2048, 8, 2, 128
    ks = jax.random.split(key, 3)
    q = jax.random.normal(ks[0], (B, Sq, H, D), jnp.bfloat16)
    k = jax.random.normal(ks[1], (B, Sk, KV, D), jnp.bfloat16)
    v = jax.random.normal(ks[2], (B, Sk, KV, D), jnp.bfloat16)
    out = flash_prefill(q, k, v, q_offset=Sk - Sq, interpret=True)
    ref = flash_prefill_ref(q, k, v, q_offset=Sk - Sq)
    err = float(jnp.abs(out.astype(jnp.float32) -
                        ref.astype(jnp.float32)).max())
    rows.append(dict(kernel="flash_prefill", shape=f"{B}x{Sq}q/{Sk}k h{H}",
                     max_err=round(err, 4),
                     us_ref=round(_time(lambda *a: flash_prefill_ref(
                         *a, q_offset=Sk - Sq), q, k, v) * 1e6, 1),
                     us_pallas_interp=round(_time(
                         lambda *a: flash_prefill(
                             *a, q_offset=Sk - Sq, interpret=True),
                         q, k, v) * 1e6, 1)))

    # paged_attention at a decode shape
    B, H, KV, D, P, page, mp = 8, 8, 2, 128, 128, 64, 16
    ks = jax.random.split(key, 3)
    q2 = jax.random.normal(ks[0], (B, H, D), jnp.bfloat16)
    kp = jax.random.normal(ks[1], (P, page, KV, D), jnp.bfloat16)
    vp = jax.random.normal(ks[2], (P, page, KV, D), jnp.bfloat16)
    rng = np.random.default_rng(0)
    table = jnp.asarray(rng.integers(1, P, (B, mp)), jnp.int32)
    lens = jnp.asarray(rng.integers(page, mp * page, (B,)), jnp.int32)
    out = paged_attention(q2, kp, vp, table, lens, interpret=True)
    ref = paged_attention_ref(q2, kp, vp, table, lens)
    err = float(jnp.abs(out.astype(jnp.float32) -
                        ref.astype(jnp.float32)).max())
    rows.append(dict(kernel="paged_attention", shape=f"b{B} {mp}x{page}tok",
                     max_err=round(err, 4),
                     us_ref=round(_time(paged_attention_ref, q2, kp, vp,
                                        table, lens) * 1e6, 1),
                     us_pallas_interp=round(_time(
                         lambda *a: paged_attention(*a, interpret=True),
                         q2, kp, vp, table, lens) * 1e6, 1)))

    # ssd_scan at a mamba2-ish shape
    b, s, h, p, n, chunk = 1, 1024, 8, 64, 128, 256
    ks = jax.random.split(key, 5)
    x = jax.random.normal(ks[0], (b, s, h, p), jnp.bfloat16)
    dt = jax.nn.softplus(jax.random.normal(ks[1], (b, s, h)))
    A = -jnp.exp(jax.random.normal(ks[2], (h,)) * 0.5)
    Bm = jax.random.normal(ks[3], (b, s, n), jnp.bfloat16)
    Cm = jax.random.normal(ks[4], (b, s, n), jnp.bfloat16)
    y_k, _ = ssd_scan(x, dt, A, Bm, Cm, chunk=chunk, interpret=True)
    y_r, _ = ssd_scan_ref(x, dt, A, Bm, Cm, chunk=chunk)
    err = float(jnp.abs(y_k - y_r).max())
    rows.append(dict(kernel="ssd_scan", shape=f"s{s} h{h} p{p} n{n}",
                     max_err=round(err, 4),
                     us_ref=round(_time(lambda *a: ssd_scan_ref(
                         *a, chunk=chunk), x, dt, A, Bm, Cm) * 1e6, 1),
                     us_pallas_interp=round(_time(
                         lambda *a: ssd_scan(*a, chunk=chunk,
                                             interpret=True),
                         x, dt, A, Bm, Cm) * 1e6, 1)))
    emit("kernels_correctness_latency", rows)
    return rows


if __name__ == "__main__":
    main()
