"""Optimizers (pure JAX, no optax): AdamW and a factored-second-moment
Adafactor variant for the ≥70B configs where full fp32 Adam state would not
fit the 16 GB/chip HBM budget at 256 chips (see DESIGN.md §7)."""
from __future__ import annotations

from typing import Any, NamedTuple

import jax
import jax.numpy as jnp


class OptState(NamedTuple):
    step: jax.Array
    m: Any
    v: Any          # full v (adamw) or (v_row, v_col) tuples (adafactor)


def cosine_lr(step, base_lr=3e-4, warmup=100, total=10_000, min_frac=0.1):
    warm = base_lr * (step + 1) / warmup
    t = jnp.clip((step - warmup) / jnp.maximum(total - warmup, 1), 0.0, 1.0)
    cos = base_lr * (min_frac + (1 - min_frac) * 0.5 * (1 + jnp.cos(jnp.pi * t)))
    return jnp.where(step < warmup, warm, cos)


# ----------------------------- AdamW ---------------------------------------

def adamw_init(params):
    zeros32 = lambda p: jnp.zeros(p.shape, jnp.float32)
    return OptState(step=jnp.zeros((), jnp.int32),
                    m=jax.tree.map(zeros32, params),
                    v=jax.tree.map(zeros32, params))


def adamw_update(params, grads, state: OptState, *, lr=None, b1=0.9, b2=0.95,
                 eps=1e-8, weight_decay=0.1):
    step = state.step + 1
    if lr is None:
        lr = cosine_lr(step)
    b1t = 1 - b1 ** step.astype(jnp.float32)
    b2t = 1 - b2 ** step.astype(jnp.float32)

    def upd(p, g, m, v):
        g = g.astype(jnp.float32)
        m2 = b1 * m + (1 - b1) * g
        v2 = b2 * v + (1 - b2) * g * g
        u = (m2 / b1t) / (jnp.sqrt(v2 / b2t) + eps)
        u = u + weight_decay * p.astype(jnp.float32)
        return (p.astype(jnp.float32) - lr * u).astype(p.dtype), m2, v2

    out = jax.tree.map(upd, params, grads, state.m, state.v)
    new_p = jax.tree.map(lambda t: t[0], out, is_leaf=lambda t: isinstance(t, tuple))
    new_m = jax.tree.map(lambda t: t[1], out, is_leaf=lambda t: isinstance(t, tuple))
    new_v = jax.tree.map(lambda t: t[2], out, is_leaf=lambda t: isinstance(t, tuple))
    return new_p, OptState(step=step, m=new_m, v=new_v)


# --------------------------- Adafactor -------------------------------------

def _factored(p) -> bool:
    return p.ndim >= 2


def adafactor_init(params):
    def v_init(p):
        if _factored(p):
            return (jnp.zeros(p.shape[:-1], jnp.float32),
                    jnp.zeros(p.shape[:-2] + p.shape[-1:], jnp.float32))
        return jnp.zeros(p.shape, jnp.float32)
    return OptState(step=jnp.zeros((), jnp.int32),
                    m=jax.tree.map(lambda p: jnp.zeros(p.shape, jnp.bfloat16),
                                   params),
                    v=jax.tree.map(v_init, params))


def adafactor_update(params, grads, state: OptState, *, lr=None, b1=0.9,
                     decay=0.99, eps=1e-30, weight_decay=0.0):
    step = state.step + 1
    if lr is None:
        lr = cosine_lr(step, base_lr=1e-3)

    def upd(p, g, m, v):
        g = g.astype(jnp.float32)
        g2 = g * g + eps
        if _factored(p):
            vr, vc = v
            vr2 = decay * vr + (1 - decay) * jnp.mean(g2, axis=-1)
            vc2 = decay * vc + (1 - decay) * jnp.mean(g2, axis=-2)
            denom = (vr2[..., None] / jnp.mean(vr2, axis=-1, keepdims=True)[..., None]
                     ) * vc2[..., None, :]
            u = g * jax.lax.rsqrt(denom + eps)
            v2 = (vr2, vc2)
        else:
            v2 = decay * v + (1 - decay) * g2
            u = g * jax.lax.rsqrt(v2 + eps)
        # update clipping at RMS 1.0
        rms = jnp.sqrt(jnp.mean(u * u) + eps)
        u = u / jnp.maximum(1.0, rms)
        m2 = (b1 * m.astype(jnp.float32) + (1 - b1) * u)
        out = p.astype(jnp.float32) - lr * (m2 + weight_decay * p.astype(jnp.float32))
        return out.astype(p.dtype), m2.astype(jnp.bfloat16), v2

    flat_p, tdef = jax.tree.flatten(params)
    flat_g = tdef.flatten_up_to(grads)
    flat_m = tdef.flatten_up_to(state.m)
    flat_v = tdef.flatten_up_to(state.v)
    res = [upd(p, g, m, v) for p, g, m, v in zip(flat_p, flat_g, flat_m, flat_v)]
    new_p = tdef.unflatten([r[0] for r in res])
    new_m = tdef.unflatten([r[1] for r in res])
    new_v = tdef.unflatten([r[2] for r in res])
    return new_p, OptState(step=step, m=new_m, v=new_v)


def make_optimizer(name: str):
    if name == "adamw":
        return adamw_init, adamw_update
    if name == "adafactor":
        return adafactor_init, adafactor_update
    raise ValueError(f"unknown optimizer {name!r}")
