"""Conductor / Algorithm 1 behaviour."""
import pytest

from repro.configs.base import get_config
from repro.core.cache import CachePool
from repro.core.conductor import Conductor, DecodeInstance, PrefillInstance
from repro.core.costmodel import CostModel, InstanceSpec
from repro.core.messenger import Messenger
from repro.core.trace import BLOCK_TOKENS, Request


def make_cluster(n_p=3, n_d=2, *, strategy="kvcache", threshold=1.3,
                 ttft_slo=30.0, tbt_slo=0.1):
    cfg = get_config("llama2-70b")
    cost = lambda: CostModel(cfg, InstanceSpec())
    P = [PrefillInstance(iid=i, pool=CachePool(), cost=cost())
         for i in range(n_p)]
    D = [DecodeInstance(iid=100 + i, cost=cost()) for i in range(n_d)]
    msg = Messenger([p.iid for p in P] + [d.iid for d in D], bw=100e9)
    c = Conductor(P, D, msg, ttft_slo=ttft_slo, tbt_slo=tbt_slo,
                  balancing_threshold=threshold, strategy=strategy)
    return c, P, D


def req(rid, n_blocks=8, out=128, base=0):
    return Request(req_id=rid, timestamp=0,
                   input_length=n_blocks * BLOCK_TOKENS, output_length=out,
                   hash_ids=[base + i for i in range(n_blocks)])


def test_prefers_instance_with_prefix():
    c, P, D = make_cluster()
    P[1].pool.insert(range(8))         # instance 1 holds the whole prefix
    dec = c.schedule(req(0, 8), now=0.0)
    assert dec.accepted and dec.prefill is P[1]
    assert dec.prefix_blocks == 8


def test_balances_away_from_busy_instance():
    c, P, D = make_cluster()
    P[1].pool.insert(range(8))
    P[1].queue_free_at = 100.0         # deep queue on the cache holder
    dec = c.schedule(req(0, 8), now=0.0)
    assert dec.accepted and dec.prefill is not P[1]
    # hot-spot migration replicated the prefix to the chosen instance
    assert dec.migrated_blocks == 8
    assert dec.prefill.pool.prefix_len(list(range(8))) == 8
    assert c.n_migrations == 1


def test_no_migration_when_local_prefix_close():
    # 2 instances only: a third empty instance would legitimately win via
    # the transfer branch (its best/local ratio is ∞ → Algorithm 1 line 14)
    c, P, D = make_cluster(n_p=2, threshold=1.3)
    P[0].pool.insert(range(8))         # best = 8
    P[1].pool.insert(range(7))         # 8/7 < 1.3 → local compute is fine
    P[0].queue_free_at = 50.0
    dec = c.schedule(req(0, 8), now=0.0)
    assert dec.prefill is P[1]
    assert dec.migrated_blocks == 0


def test_rejects_on_ttft_slo():
    c, P, D = make_cluster(ttft_slo=0.5)
    for p in P:
        p.queue_free_at = 10.0         # all queues too deep
    dec = c.schedule(req(0, 8), now=0.0)
    assert not dec.accepted and "TTFT" in dec.reject_reason


def test_rejects_on_decode_vram():
    c, P, D = make_cluster(n_d=1)
    cap = D[0].cost.decode_capacity_tokens()
    D[0].kv_tokens = cap               # decode pool is full
    dec = c.schedule(req(0, 8), now=0.0)
    assert not dec.accepted and "decode" in dec.reject_reason


def test_queue_time_accumulates():
    c, P, D = make_cluster(n_p=1)
    d1 = c.schedule(req(0, 8), now=0.0)
    free1 = P[0].queue_free_at
    d2 = c.schedule(req(1, 8, base=100), now=0.0)
    assert P[0].queue_free_at > free1
    assert d2.expected_ttft > d1.expected_ttft


def test_cache_aware_never_migrates():
    c, P, D = make_cluster(strategy="cache_aware")
    P[1].pool.insert(range(8))
    P[1].queue_free_at = 100.0
    dec = c.schedule(req(0, 8), now=0.0)
    assert dec.migrated_blocks == 0 and c.n_migrations == 0


def test_transfer_congestion_discourages_migration():
    """A congested holder link makes local compute win Algorithm 1's
    min-TTFT comparison."""
    c, P, D = make_cluster()
    P[1].pool.insert(range(64))
    P[1].queue_free_at = 8.0                   # busy holder
    c.messenger.links[P[1].iid].busy_until = 1e4   # and congested egress
    dec = c.schedule(req(0, 64), now=0.0)
    # with the transfer path blocked, waiting for the holder or computing
    # locally must win; either way no migration through the jammed link
    assert dec.accepted
    assert dec.migrated_blocks == 0
