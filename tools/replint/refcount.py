"""refcount-pair: page-run acquires must reach a release on every path.

Mirrors ``DevicePagePool.check_leaks`` statically.  A statement that
acquires page references on some pool object —

    adopted, pages = pool.adopt_chain(hash_ids)
    run = pool.alloc(n)
    pp.retain(pages)

— must, on EVERY exit path including exceptions, either release them
(``release``/``free``/``release_pages``) or transfer ownership (return
the held run, or store it into an object/structure whose lifecycle owns
it).  Accepted shapes:

  * the acquire sits in a ``try`` whose ``finally`` releases, or whose
    handlers ALL release and include a catch-all (``except MemoryError``
    alone is not enough: any other exception leaks the run);
  * a single linear path from the acquire to a release/transfer with no
    statement in between that can raise (calls, raises, asserts) or
    branch (if/for/while/with) — the ``_prepare_writes`` shape:
    ``(pg,) = pp.alloc(1)`` immediately parked in the block table.

Calls on ``self`` are exempt — those are the pool primitives' own
implementations, covered dynamically by ``check_leaks`` tests.
"""
from __future__ import annotations

import ast

from tools.replint.core import (Finding, ModuleCtx, functions_in,
                                names_in, own_nodes)

RULE = "refcount-pair"

ACQUIRE = {"alloc", "adopt_chain", "retain"}
# export_run releases the run inside the pool (ownership transfer to the
# returned host copies) — holding pages reach it just like a release()
RELEASE = {"release", "free", "release_pages", "export_run"}

_SAFE_BUILTINS = {"len", "int", "float", "str", "bool", "list", "dict",
                  "set", "tuple", "min", "max", "sum", "abs", "range",
                  "enumerate", "zip", "sorted", "reversed", "isinstance",
                  "repr", "id", "print"}
_SAFE_METHODS = {"append", "extend", "add", "get", "items", "keys",
                 "values", "copy"}


def _acquire_call(stmt) -> ast.Call | None:
    """The acquire Call in an Assign/Expr statement, if any (non-self
    receiver only)."""
    value = getattr(stmt, "value", None)
    if not isinstance(stmt, (ast.Assign, ast.AnnAssign, ast.Expr)) \
            or value is None:
        return None
    for node in ast.walk(value):
        if isinstance(node, ast.Call) \
                and isinstance(node.func, ast.Attribute) \
                and node.func.attr in ACQUIRE:
            recv = node.func.value
            if isinstance(recv, ast.Name) and recv.id == "self":
                continue
            return node
    return None


def _held_names(stmt, call) -> set[str]:
    if isinstance(stmt, ast.Assign):
        out = set()
        for t in stmt.targets:
            out |= names_in(t)
        return out
    if isinstance(stmt, ast.AnnAssign):
        return names_in(stmt.target)
    # Expr statement: retain(pages) holds whatever was passed in
    if call.func.attr == "retain":
        out = set()
        for a in call.args:
            out |= names_in(a)
        return out
    return set()


def _is_release_call(node) -> bool:
    return (isinstance(node, ast.Call)
            and isinstance(node.func, ast.Attribute)
            and node.func.attr in RELEASE)


def _contains_release(stmts) -> bool:
    for s in stmts:
        for node in ast.walk(s):
            if _is_release_call(node):
                return True
    return False


def _is_catchall(handler: ast.ExceptHandler) -> bool:
    t = handler.type
    if t is None:
        return True
    types = t.elts if isinstance(t, ast.Tuple) else [t]
    for ty in types:
        name = ty.attr if isinstance(ty, ast.Attribute) else \
            (ty.id if isinstance(ty, ast.Name) else "")
        if name in ("Exception", "BaseException"):
            return True
    return False


def _try_protects(tr: ast.Try) -> bool:
    if _contains_release(tr.finalbody):
        return True
    return bool(tr.handlers) \
        and all(_contains_release(h.body) for h in tr.handlers) \
        and any(_is_catchall(h) for h in tr.handlers)


class _Blocks:
    """Locates each statement: (owning stmt-or-function, list, index)."""

    def __init__(self, func):
        self.loc = {}
        self._index(func)

    def _index(self, node):
        for field in ("body", "orelse", "finalbody"):
            lst = getattr(node, field, None)
            if not isinstance(lst, list):
                continue
            for i, s in enumerate(lst):
                if not isinstance(s, ast.stmt):
                    break
                self.loc[id(s)] = (node, lst, i)
                if not isinstance(s, (ast.FunctionDef,
                                      ast.AsyncFunctionDef)):
                    self._index(s)
        for h in getattr(node, "handlers", []):
            for i, s in enumerate(h.body):
                self.loc[id(s)] = (node, h.body, i)
                self._index(s)

    def path_after(self, stmt, func):
        """Statements executed after ``stmt`` on the fall-through path,
        bubbling out of enclosing blocks up to the function body."""
        cur = stmt
        while id(cur) in self.loc:
            owner, lst, idx = self.loc[id(cur)]
            for s in lst[idx + 1:]:
                yield s
            if owner is func:
                return
            cur = owner

    def enclosing_trys(self, stmt, func):
        cur = stmt
        while id(cur) in self.loc:
            owner, lst, _ = self.loc[id(cur)]
            if isinstance(owner, ast.Try) and lst is owner.body:
                yield owner
            if owner is func:
                return
            cur = owner


def _stmt_satisfies(stmt, held: set[str]) -> bool:
    """Does this statement release or transfer the held references?"""
    if isinstance(stmt, ast.Return) and stmt.value is not None \
            and names_in(stmt.value) & held:
        return True
    value = getattr(stmt, "value", None)
    if isinstance(stmt, (ast.Expr, ast.Assign)) and value is not None:
        for node in ast.walk(value):
            if _is_release_call(node) and names_in(node) & held:
                return True
    if isinstance(stmt, ast.Assign) and names_in(stmt.value) & held:
        # parked in a structure the caller owns (block table, result obj)
        if any(isinstance(t, (ast.Attribute, ast.Subscript))
               for t in stmt.targets):
            return True
    if isinstance(stmt, ast.AugAssign) \
            and isinstance(stmt.target, (ast.Attribute, ast.Subscript)) \
            and names_in(stmt.value) & held:
        return True
    if isinstance(stmt, ast.Try) and _try_protects(stmt):
        return True
    return False


def _stmt_aliases(stmt, held: set[str]) -> set[str]:
    """New names that now also reference the held run."""
    if isinstance(stmt, ast.Assign) and names_in(stmt.value) & held:
        out = set()
        for t in stmt.targets:
            if isinstance(t, ast.Name):
                out.add(t.id)
        return out
    if isinstance(stmt, ast.AugAssign) \
            and isinstance(stmt.target, ast.Name) \
            and names_in(stmt.value) & held:
        return {stmt.target.id}
    return set()


def _stmt_risky(stmt) -> str | None:
    """Reason this statement can raise or branch away, else None."""
    if isinstance(stmt, (ast.If, ast.For, ast.While, ast.With,
                         ast.AsyncWith, ast.AsyncFor, ast.Try,
                         ast.Match)):
        return "control flow"
    if isinstance(stmt, ast.Raise):
        return "raise"
    if isinstance(stmt, (ast.Assert,)):
        return "assert"
    if isinstance(stmt, (ast.Break, ast.Continue)):
        return "loop exit"
    for node in ast.walk(stmt):
        if not isinstance(node, ast.Call):
            continue
        f = node.func
        if isinstance(f, ast.Name) and f.id in _SAFE_BUILTINS:
            continue
        if isinstance(f, ast.Attribute) and f.attr in _SAFE_METHODS:
            continue
        if _is_release_call(node):
            continue
        name = f.attr if isinstance(f, ast.Attribute) else \
            getattr(f, "id", "call")
        return f"call to {name}()"
    return None


def _satisfies_anywhere(stmt, held: set[str]) -> bool:
    """Lenient search: any satisfying statement inside ``stmt``."""
    if _stmt_satisfies(stmt, held):
        return True
    for node in ast.walk(stmt):
        if isinstance(node, ast.stmt) and node is not stmt \
                and _stmt_satisfies(node, held):
            return True
    return False


def check(ctx: ModuleCtx) -> list[Finding]:
    findings: list[Finding] = []
    for func in functions_in(ctx.tree):
        blocks = None
        for stmt in [n for n in own_nodes(func) if isinstance(n, ast.stmt)]:
            call = _acquire_call(stmt)
            if call is None:
                continue
            if blocks is None:
                blocks = _Blocks(func)
            held = _held_names(stmt, call)
            what = f"pages acquired via .{call.func.attr}()"
            if not held:
                findings.append(Finding(
                    ctx.path, stmt.lineno, RULE,
                    f"{what} are discarded: the result is never bound, "
                    f"so the references can never be released"))
                continue
            exception_safe = any(_try_protects(tr) for tr in
                                 blocks.enclosing_trys(stmt, func))
            satisfied = False
            risky_reason = None
            risky_line = None
            for nxt in blocks.path_after(stmt, func):
                if _satisfies_anywhere(nxt, held) if exception_safe \
                        else _stmt_satisfies(nxt, held):
                    satisfied = True
                    break
                held |= _stmt_aliases(nxt, held)
                if not exception_safe and risky_reason is None:
                    r = _stmt_risky(nxt)
                    if r is not None:
                        risky_reason, risky_line = r, nxt.lineno
            if satisfied and risky_reason is None:
                continue
            if risky_reason is not None:
                findings.append(Finding(
                    ctx.path, stmt.lineno, RULE,
                    f"{what} can leak: {risky_reason} at line "
                    f"{risky_line} may raise or branch before the run "
                    f"is released or ownership is transferred -- wrap "
                    f"in try/finally (or handlers that all release and "
                    f"include a catch-all)"))
            else:
                findings.append(Finding(
                    ctx.path, stmt.lineno, RULE,
                    f"{what} are never released or transferred on the "
                    f"fall-through path"))
    return findings
