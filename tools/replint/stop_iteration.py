"""stop-iteration: PEP 479 hazards (the PR-6 class-1 bug).

Since PEP 479 a ``StopIteration`` escaping a generator frame is
converted to ``RuntimeError`` — and, worse, one raised inside a driver
loop that consumes the generator silently TERMINATES the consuming
``for`` loop instead of propagating.  Flagged:

  * ``raise StopIteration`` (bare or called) anywhere — return from a
    generator with ``return``; signal exhaustion to a caller with a
    sentinel or a dedicated exception type;
  * ``next(it)`` with no default inside a generator body — exhaustion
    raises StopIteration into the generator frame, where it is
    swallowed into RuntimeError/loop-termination.  Use
    ``next(it, sentinel)`` and test explicitly.
"""
from __future__ import annotations

import ast

from tools.replint.core import Finding, ModuleCtx, dotted, own_nodes

RULE = "stop-iteration"


def _is_generator(func) -> bool:
    return any(isinstance(n, (ast.Yield, ast.YieldFrom))
               for n in own_nodes(func))


def check(ctx: ModuleCtx) -> list[Finding]:
    findings: list[Finding] = []
    for node in ast.walk(ctx.tree):
        if isinstance(node, ast.Raise) and node.exc is not None:
            exc = node.exc
            name = dotted(exc.func) if isinstance(exc, ast.Call) \
                else dotted(exc)
            if name == "StopIteration":
                findings.append(Finding(
                    ctx.path, node.lineno, RULE,
                    "raise StopIteration is PEP-479-unsafe: inside a "
                    "generator it becomes RuntimeError, and in a driver "
                    "loop it silently ends the consuming for-loop -- "
                    "use 'return' or a dedicated exception"))
        elif isinstance(node, (ast.FunctionDef, ast.AsyncFunctionDef)) \
                and _is_generator(node):
            for sub in own_nodes(node):
                if isinstance(sub, ast.Call) \
                        and isinstance(sub.func, ast.Name) \
                        and sub.func.id == "next" \
                        and len(sub.args) == 1 and not sub.keywords:
                    findings.append(Finding(
                        ctx.path, sub.lineno, RULE,
                        f"default-less next() inside generator "
                        f"'{node.name}': exhaustion raises "
                        f"StopIteration into the generator frame "
                        f"(PEP 479) -- use next(it, sentinel)"))
    return findings
