"""FLAG fixture: socket acquires that can leak the fd. Parsed by
replint only — never imported."""
import socket


def send_may_raise_before_close(addr):
    # the classic shape: sendall() raising ConnectionReset leaks the fd
    s = socket.create_connection(addr)                 # finding
    s.sendall(b"ping")
    s.close()


def dropped_accept(listener):
    listener.accept()                                  # finding: discarded


def handler_missing_catchall(addr):
    try:
        s = socket.create_connection(addr)             # finding
        s.sendall(b"x")
        return s
    except OSError:
        s.close()
        return None
    # no catch-all: a timeout raised as socket.timeout subclassing
    # OSError is fine, but anything else leaks the fd


def branch_skips_close(cold):
    s = socket.socket(socket.AF_INET, socket.SOCK_STREAM)  # finding
    if cold:                                           # warm path leaks
        s.close()


def pair_used_before_any_close(payload):
    a, b = socket.socketpair()                         # finding
    a.sendall(payload)                                 # may raise: both
    return a, b                                        # ends leak


def receiver_position_is_not_a_transfer(listener):
    conn, _ = listener.accept()                        # finding
    conn.settimeout(5.0)                               # call ON the conn
    conn.close()                                       # can raise first
