"""Roofline table: per (architecture × input shape), the three roofline
terms derived from the compiled dry-run (§Roofline deliverable).

Each combination is lowered+compiled in a SUBPROCESS with 512 forced host
devices (jax locks device count at first init), its post-SPMD HLO walked
by launch/hlo_analysis (while-trip-scaled per-device FLOPs / byte proxy /
collective bytes), and the terms computed against TPU v5e constants:
197 TFLOP/s bf16, 819 GB/s HBM, ~50 GB/s/link ICI.

Also reports MODEL_FLOPS = 6·N(_active)·D and its ratio to HLO FLOPs
(compute "usefulness" — catches remat/redundancy waste), and an analytic
per-chip memory-fit estimate (weights + optimizer + KV caches from the
sharding specs — XLA:CPU's memory_analysis is not per-partition).

Usage:
    python -m benchmarks.roofline [--arch all] [--shape all] [--multi-pod]
Results cached at benchmarks/results/roofline.json (used by benchmarks.run).
"""
from __future__ import annotations

import argparse
import json
import os
import subprocess
import sys

from benchmarks.common import RESULTS_DIR, emit

SRC = os.path.join(os.path.dirname(os.path.dirname(os.path.abspath(__file__))),
                   "src")

ARCHS = ["qwen3-moe-235b-a22b", "smollm-360m", "qwen2.5-3b", "mixtral-8x7b",
         "phi3-mini-3.8b", "internvl2-26b", "mamba2-2.7b", "whisper-large-v3",
         "jamba-1.5-large-398b", "qwen3-14b"]
SHAPES = ["train_4k", "prefill_32k", "decode_32k", "long_500k"]

TOKENS = {"train_4k": 4096 * 256, "prefill_32k": 32768 * 32,
          "decode_32k": 128, "long_500k": 1}


def run_one(arch: str, shape: str, multi_pod: bool = False,
            timeout: int = 3600) -> dict:
    """Dry-run one combo in a fresh 512-device subprocess."""
    out = f"/tmp/roofline_{arch}_{shape}{'_mp' if multi_pod else ''}.json"
    env = dict(os.environ)
    env["PYTHONPATH"] = SRC + os.pathsep + env.get("PYTHONPATH", "")
    env.pop("XLA_FLAGS", None)   # dryrun sets its own
    cmd = [sys.executable, "-m", "repro.launch.dryrun", "--arch", arch,
           "--shape", shape, "--json-out", out]
    if multi_pod:
        cmd.append("--multi-pod")
    res = subprocess.run(cmd, env=env, capture_output=True, text=True,
                         timeout=timeout)
    if res.returncode != 0:
        return {"arch": arch, "shape": shape, "error":
                (res.stderr or res.stdout)[-400:]}
    with open(out) as f:
        recs = json.load(f)
    return recs[0] if recs else {"arch": arch, "shape": shape,
                                 "error": "no record"}


def summarize(rec: dict) -> dict:
    from repro.configs.base import get_config
    arch, shape = rec["arch"], rec["shape"]
    if "skipped" in rec:
        return dict(arch=arch, shape=shape, status="skip",
                    note=rec["skipped"])
    if "error" in rec:
        return dict(arch=arch, shape=shape, status="FAIL",
                    note=rec["error"][:120])
    cfg = get_config(arch)
    ha, rf = rec["hlo_analysis"], rec["roofline"]
    n_tokens = TOKENS[shape]
    n_active = cfg.active_param_count()
    factor = 3 if shape == "train_4k" else 1      # fwd+bwd
    model_flops = 2.0 * factor * n_active * n_tokens / rec["n_devices"]
    return dict(
        arch=arch, shape=shape, status="ok", mesh=rec["mesh"],
        t_compute_s=round(rf["t_compute_s"], 5),
        t_memory_s=round(rf["t_memory_s"], 5),
        t_collective_s=round(rf["t_collective_s"], 5),
        bottleneck=rf["bottleneck"],
        hlo_gflops_dev=round(ha["flops"] / 1e9, 2),
        model_gflops_dev=round(model_flops / 1e9, 2),
        useful_flops_ratio=round(model_flops / ha["flops"], 3)
        if ha["flops"] else 0.0,
        coll_gb_dev=round(ha["collective_total"] / 1e9, 3),
        compile_s=rec.get("compile_s"),
        note=rec.get("note", ""),
    )


def main(argv=None):
    ap = argparse.ArgumentParser()
    ap.add_argument("--arch", default="all")
    ap.add_argument("--shape", default="all")
    ap.add_argument("--multi-pod", action="store_true")
    ap.add_argument("--refresh", action="store_true",
                    help="re-run combos already cached")
    args = ap.parse_args(argv)

    archs = ARCHS if args.arch == "all" else [args.arch]
    shapes = SHAPES if args.shape == "all" else [args.shape]

    cache_path = os.path.join(
        RESULTS_DIR, "roofline_mp.json" if args.multi_pod
        else "roofline.json")
    cache: dict = {}
    if os.path.exists(cache_path):
        with open(cache_path) as f:
            cache = {f"{r['arch']}|{r['shape']}": r for r in json.load(f)}

    rows = []
    for arch in archs:
        for shape in shapes:
            key = f"{arch}|{shape}"
            if key in cache and not args.refresh \
                    and cache[key].get("status") == "ok":
                rows.append(cache[key])
                continue
            print(f"[roofline] {arch} × {shape} "
                  f"({'2x16x16' if args.multi_pod else '16x16'}) ...",
                  flush=True)
            rec = run_one(arch, shape, multi_pod=args.multi_pod)
            rows.append(summarize(rec))
            cache[key] = rows[-1]
            os.makedirs(RESULTS_DIR, exist_ok=True)
            with open(cache_path, "w") as f:
                json.dump(list(cache.values()), f, indent=1)
    emit("roofline_mp" if args.multi_pod else "roofline", rows)
    return rows


if __name__ == "__main__":
    main()
