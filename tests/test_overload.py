"""Overload-oriented scheduling (§7): the three admission policies."""
import numpy as np
import pytest

from repro.configs.base import get_config
from repro.core.simulator import MooncakeCluster
from repro.core.trace import TraceSpec, generate_trace


@pytest.fixture(scope="module")
def heavy_trace():
    return generate_trace(TraceSpec(
        n_requests=1500, duration_ms=300_000, seed=3,
        frac_doc=0.5, frac_chat=0.3, frac_oneshot=0.2, out_mu=5.9))


def run(adm, trace, **kw):
    cfg = get_config("llama2-70b")
    mc = MooncakeCluster(cfg, n_prefill=4, n_decode=4, ttft_slo=30,
                         tbt_slo=0.1, admission=adm, **kw)
    return mc.run(trace, speedup=3.0, load_sample_dt=5.0)


def test_baseline_wastes_prefill(heavy_trace):
    res = run("baseline", heavy_trace)
    waste = sum(1 for r in res.records
                if r.reject_stage == "decode_doublecheck")
    assert waste > 0, "baseline must reject some requests AFTER prefill"


def test_early_rejection_eliminates_waste(heavy_trace):
    res = run("early", heavy_trace)
    waste = sum(1 for r in res.records
                if r.reject_stage == "decode_doublecheck")
    assert waste == 0


def test_predictive_beats_baseline_goodput(heavy_trace):
    g_base = run("baseline", heavy_trace).goodput(30, 0.1)
    g_pred = run("predictive", heavy_trace, t_d=20.0).goodput(30, 0.1)
    assert g_pred > g_base


def test_predictive_smooths_decode_load(heavy_trace):
    """§7.3/7.4: prediction damps the anti-phase decode-load fluctuation."""
    r_early = run("early", heavy_trace)
    r_pred = run("predictive", heavy_trace, t_d=20.0)
    std = lambda r: float(np.std([d for _, _, d in r.load_samples]))
    assert std(r_pred) < std(r_early)


def test_accepted_requests_complete(heavy_trace):
    res = run("early", heavy_trace)
    for r in res.records:
        if r.accepted:
            assert r.completed and r.ttft >= 0 and r.done >= r.arrival
        else:
            assert r.reject_stage != ""
