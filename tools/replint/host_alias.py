"""host-alias: mutable numpy buffers flowing into jitted callables.

jax on CPU zero-copies aligned 2-D numpy arrays passed to a jitted
function: the device buffer ALIASES host memory, and a host-side write
while the async step still reads it corrupts the computation (the PR-5
paged-decode race).  Any numpy-backed instance buffer — or a view of
one — handed to a known-jitted callable must be defensively copied:

    tbl = jnp.asarray(self.block_table[:, :width].copy())   # ok
    tbl = jnp.asarray(self.block_table[:, :width])          # flagged

Jitted callables recognised: names/attributes assigned from
``jax.jit(...)`` / ``jit(...)`` / ``functools.partial(jax.jit, ...)``,
and functions decorated with jit.  Taint roots: ``self.<attr>`` buffers
assigned from ``np.*`` anywhere in the class.  Taint propagates through
subscripts/slices and ``asarray``-style wrappers, and is cleared by
``.copy()`` or an array-constructing call (``np.array`` copies by
default).  ``ascontiguousarray`` does NOT clear taint: it returns the
input unchanged when already contiguous.
"""
from __future__ import annotations

import ast

from tools.replint.core import (Finding, ModuleCtx, dotted, is_self_attr)

RULE = "host-alias"

_JIT_NAMES = {"jax.jit", "jit"}
_NP_ROOTS = ("np.", "numpy.")
_PASSTHROUGH = {"asarray", "ascontiguousarray", "atleast_1d", "atleast_2d",
                "ravel", "reshape", "squeeze", "transpose", "view"}
_COPYING = {"np.array", "numpy.array", "jnp.array", "jax.numpy.array",
            "np.copy", "numpy.copy"}


def _is_jit_value(value) -> bool:
    """True when ``value`` evaluates to a jitted callable."""
    if not isinstance(value, ast.Call):
        return False
    f = dotted(value.func)
    if f in _JIT_NAMES:
        return True
    if f in ("functools.partial", "partial") and value.args:
        return dotted(value.args[0]) in _JIT_NAMES
    return False


def _collect_jitted(tree) -> tuple[set[str], set[str]]:
    """(module/local names, self.<attr> names) bound to jitted callables."""
    names: set[str] = set()
    attrs: set[str] = set()
    for node in ast.walk(tree):
        if isinstance(node, ast.Assign) and _is_jit_value(node.value):
            for t in node.targets:
                if isinstance(t, ast.Name):
                    names.add(t.id)
                elif is_self_attr(t):
                    attrs.add(t.attr)
        elif isinstance(node, (ast.FunctionDef, ast.AsyncFunctionDef)):
            for dec in node.decorator_list:
                d = dotted(dec.func if isinstance(dec, ast.Call) else dec)
                if d in _JIT_NAMES:
                    names.add(node.name)
    return names, attrs


def _collect_np_attrs(cls: ast.ClassDef) -> set[str]:
    """self attributes assigned from np.* anywhere in the class."""
    out: set[str] = set()
    for node in ast.walk(cls):
        if isinstance(node, (ast.Assign, ast.AnnAssign)) and node.value:
            targets = node.targets if isinstance(node, ast.Assign) \
                else [node.target]
            v = node.value
            f = dotted(v.func) if isinstance(v, ast.Call) else None
            if f and f.startswith(_NP_ROOTS) and f not in _COPYING:
                for t in targets:
                    if is_self_attr(t):
                        out.add(t.attr)
    return out


class _FuncScan:
    def __init__(self, func, np_attrs, jit_names, jit_attrs, ctx,
                 findings):
        self.func = func
        self.np_attrs = np_attrs
        self.jit_names = jit_names
        self.jit_attrs = jit_attrs
        self.ctx = ctx
        self.findings = findings
        self.tainted: set[str] = set()

    # -- taint of an expression: (is_tainted, human-readable root) --
    def taint(self, e) -> tuple[bool, str]:
        if isinstance(e, ast.Name):
            return e.id in self.tainted, e.id
        if is_self_attr(e):
            return e.attr in self.np_attrs, f"self.{e.attr}"
        if isinstance(e, ast.Subscript):
            return self.taint(e.value)
        if isinstance(e, ast.Starred):
            return self.taint(e.value)
        if isinstance(e, (ast.Tuple, ast.List)):
            for el in e.elts:
                t, root = self.taint(el)
                if t:
                    return True, root
            return False, ""
        if isinstance(e, ast.Call):
            f = dotted(e.func)
            if isinstance(e.func, ast.Attribute) and e.func.attr == "copy":
                return False, ""
            if f in _COPYING:
                return False, ""
            leaf = (f or "").rsplit(".", 1)[-1]
            if leaf in _PASSTHROUGH:
                base = e.args[0] if e.args else \
                    (e.func.value if isinstance(e.func, ast.Attribute)
                     else None)
                if base is not None:
                    return self.taint(base)
            return False, ""
        return False, ""

    def is_jitted_call(self, call: ast.Call) -> str | None:
        f = call.func
        if isinstance(f, ast.Name) and f.id in self.jit_names:
            return f.id
        if is_self_attr(f) and f.attr in self.jit_attrs:
            return f"self.{f.attr}"
        return None

    def run(self):
        for stmt in self.func.body:
            self.visit(stmt)

    def visit(self, node):
        if isinstance(node, (ast.FunctionDef, ast.AsyncFunctionDef,
                             ast.Lambda)) and node is not self.func:
            return
        for ch in ast.iter_child_nodes(node):
            self.visit(ch)
        if isinstance(node, ast.Call):
            target = self.is_jitted_call(node)
            if target:
                for arg in list(node.args) + \
                        [kw.value for kw in node.keywords]:
                    t, root = self.taint(arg)
                    if t:
                        self.findings.append(Finding(
                            self.ctx.path, node.lineno, RULE,
                            f"numpy buffer '{root}' reaches jitted "
                            f"callable '{target}' without .copy() -- "
                            f"jax CPU zero-copies host arrays and an "
                            f"async step races host mutation"))
        elif isinstance(node, ast.Assign):
            t, _ = self.taint(node.value)
            for tgt in node.targets:
                if isinstance(tgt, ast.Name):
                    (self.tainted.add if t
                     else self.tainted.discard)(tgt.id)
        elif isinstance(node, ast.AnnAssign) and node.value is not None:
            t, _ = self.taint(node.value)
            if isinstance(node.target, ast.Name):
                (self.tainted.add if t
                 else self.tainted.discard)(node.target.id)
        elif isinstance(node, (ast.For, ast.AsyncFor)):
            for n in ast.walk(node.target):
                if isinstance(n, ast.Name):
                    self.tainted.discard(n.id)


def check(ctx: ModuleCtx) -> list[Finding]:
    jit_names, jit_attrs = _collect_jitted(ctx.tree)
    if not jit_names and not jit_attrs:
        return []
    findings: list[Finding] = []
    for cls in [n for n in ast.walk(ctx.tree)
                if isinstance(n, ast.ClassDef)]:
        np_attrs = _collect_np_attrs(cls)
        for meth in cls.body:
            if isinstance(meth, (ast.FunctionDef, ast.AsyncFunctionDef)):
                _FuncScan(meth, np_attrs, jit_names, jit_attrs, ctx,
                          findings).run()
    return findings
