"""Benchmark regression gate — compare fresh --quick results to committed
baselines within tolerance.

CI runs the quick benchmark lanes with ``BENCH_RESULTS`` pointed at a
scratch dir, then invokes this module to diff the scratch JSON against
the committed quick baselines (``benchmarks/results/quick/``). Only
DETERMINISTIC headline metrics are gated (seeded-simulator outputs:
goodput, TTFT, completion/rejection counts, hit rates) — wall-clock
benchmarks like ``ssd_store`` assert their own orderings in-process and
are uploaded as artifacts, not gated here.

Rows are matched positionally (the benches are deterministic) and their
identity columns (every non-gated field) must agree exactly; a schema
change therefore fails loudly, which is the point — intentional changes
regenerate the baselines in the same PR:

    BENCH_RESULTS=benchmarks/results/quick \
        python -m benchmarks.bench_policies --quick
    BENCH_RESULTS=benchmarks/results/quick \
        python -m benchmarks.bench_tiered_cache --quick

    python -m benchmarks.check_regression --fresh <scratch-dir>
"""
from __future__ import annotations

import argparse
import json
import os
import sys

#: table -> (gated metric columns, relative tolerance, absolute floor).
#: The simulators are fully seeded, so drift beyond float-formatting noise
#: means behaviour changed; the tolerance absorbs rounding + minor
#: platform float differences only.
GATED_TABLES: dict[str, tuple[tuple[str, ...], float, float]] = {
    "policy_grid_moderate": (
        ("goodput_rps", "avg_ttft_s", "ttft_p90_s", "completed", "rejected"),
        0.02, 0.01),
    "policy_grid_ssd_tier": (
        ("goodput_rps", "avg_ttft_s", "ttft_p90_s", "completed", "rejected"),
        0.02, 0.01),
    "policy_grid_overload": (
        ("goodput_rps", "avg_ttft_s", "ttft_p90_s", "completed", "rejected"),
        0.02, 0.01),
    "tiered_cache_hit_rate": (
        ("hit_rate", "dram_hits", "ssd_hits", "demotions", "promotions"),
        0.02, 0.01),
    "tiered_cache_goodput": (
        ("goodput_rps", "avg_ttft_s", "ttft_p90_s", "slo_ok", "completed"),
        0.02, 0.01),
    # the engine table (global_pool_engine) is wall-clock and asserts its
    # own orderings in-process; only the seeded simulator rows are gated
    "global_pool_sim": (
        ("avg_ttft_s", "ttft_p90_s", "completed", "rejected", "ssd_loads",
         "peer_ssd_loads"),
        0.02, 0.01),
    # paged substrate capacity counts are exact (seeded workload, integer
    # page accounting); the paged_decode_engine table is wall-clock and
    # asserts its own orderings (join/step/bit-exactness) in-process
    "paged_decode_capacity": (
        ("dense_fit", "paged_fit", "fit_ratio", "logical_pages",
         "physical_pages"),
        0.0, 0.0),
    # mesh capacity scaling is exact page/byte accounting (fixed per-bank
    # budget, slab shard sizes); the step-time companion table
    # (paged_decode_mesh_step) is wall-clock and asserted in-process
    "paged_decode_mesh": (
        ("capacity_pages", "capacity_tokens", "per_device_kv_kib",
         "capacity_per_device_x"),
        0.0, 0.0),
    # serving-loop scheduling counts are exact (deterministic interleave:
    # no TBT budget, submits interleaved with iterations on one thread);
    # the serving_loop_goodput table is wall-clock and asserts its own
    # orderings (SLO attainment, p99, bit-exactness) in-process
    "serving_loop_mixed": (
        ("submitted", "rejected", "completed", "total_tokens",
         "decode_steps", "prefill_chunks", "join_oom"),
        0.0, 0.0),
    # preemption scheduling is a fully deterministic iterate()-driven
    # interleave: counts and iteration-index percentiles are exact; the
    # p99 ordering (preempt beats defer) is asserted in-process
    "preemption_sched": (
        ("completed", "preemptions", "restores_reload",
         "restores_recompute", "decode_steps", "prefill_chunks",
         "victim_iters", "sprint_p50_iters", "sprint_p99_iters"),
        0.0, 0.0),
}


def _load(directory: str, table: str):
    path = os.path.join(directory, table + ".json")
    if not os.path.exists(path):
        return None
    with open(path) as f:
        return json.load(f)


def compare_table(table: str, baseline: list[dict], fresh: list[dict],
                  metrics: tuple[str, ...], rel_tol: float,
                  abs_floor: float) -> list[str]:
    errors = []
    if len(baseline) != len(fresh):
        return [f"{table}: row count {len(fresh)} != baseline "
                f"{len(baseline)} (regenerate baselines if intentional)"]
    for i, (b, f) in enumerate(zip(baseline, fresh)):
        ident_b = {k: v for k, v in b.items() if k not in metrics}
        ident_f = {k: v for k, v in f.items() if k not in metrics}
        if ident_b != ident_f:
            errors.append(f"{table}[{i}]: identity columns differ: "
                          f"{ident_f} != baseline {ident_b}")
            continue
        for m in metrics:
            if m not in b and m not in f:
                continue
            bv, fv = b.get(m), f.get(m)
            if bv is None or fv is None:
                if bv != fv:
                    errors.append(f"{table}[{i}].{m}: {fv} != {bv}")
                continue
            tol = max(abs(float(bv)) * rel_tol, abs_floor)
            if abs(float(fv) - float(bv)) > tol:
                errors.append(
                    f"{table}[{i}].{m}: {fv} vs baseline {bv} "
                    f"(|Δ|={abs(float(fv) - float(bv)):.4g} > tol {tol:.4g}) "
                    f"[{ident_b}]")
    return errors


def main(argv=None) -> int:
    ap = argparse.ArgumentParser(description=__doc__)
    ap.add_argument("--baseline", default="benchmarks/results/quick",
                    help="committed quick-lane baseline dir")
    ap.add_argument("--fresh", required=True,
                    help="dir the quick benches just wrote (BENCH_RESULTS)")
    ap.add_argument("--tol-scale", type=float, default=1.0,
                    help="multiply every table's tolerance (debugging aid)")
    args = ap.parse_args(argv)

    all_errors: list[str] = []
    checked = 0
    for table, (metrics, rel, floor) in sorted(GATED_TABLES.items()):
        baseline = _load(args.baseline, table)
        fresh = _load(args.fresh, table)
        if baseline is None:
            print(f"[gate] {table}: no committed baseline — SKIP "
                  f"(commit one under {args.baseline}/)")
            continue
        if fresh is None:
            all_errors.append(f"{table}: baseline exists but the quick lane "
                              f"produced no {table}.json in {args.fresh}")
            continue
        errs = compare_table(table, baseline, fresh, metrics,
                             rel * args.tol_scale, floor * args.tol_scale)
        checked += 1
        status = "OK" if not errs else f"{len(errs)} violations"
        print(f"[gate] {table}: {len(fresh)} rows, "
              f"{len(metrics)} metrics — {status}")
        all_errors.extend(errs)

    if all_errors:
        print(f"\nREGRESSION GATE FAILED ({len(all_errors)} violations):",
              file=sys.stderr)
        for e in all_errors[:40]:
            print("  " + e, file=sys.stderr)
        if len(all_errors) > 40:
            print(f"  ... and {len(all_errors) - 40} more", file=sys.stderr)
        print("\nIf the change is intentional, regenerate the committed "
              "baselines (see module docstring).", file=sys.stderr)
        return 1
    print(f"\nregression gate: {checked} tables within tolerance")
    return 0


if __name__ == "__main__":
    sys.exit(main())
