"""FLAG fixture: policy bodies with direct side effects. Parsed by
replint only — never imported."""
from repro.core.policies.base import Arm, register_policy


@register_policy("routing", "eager_sender")
class EagerSender:
    def propose(self, ctx, inst):
        # the bug the Arm.commit split exists to prevent: propose runs
        # once per CANDIDATE instance, so this sends the KV for arms
        # that never land (double transfer, double accounting)
        ctx.messenger.enqueue(inst.nid, ctx.blocks)    # finding
        ctx.pool.insert(ctx.key, ctx.blocks)           # finding
        return [Arm("dram_hit", 0.0, commit=lambda now: None)]

    def select(self, arms, ctx):
        ctx.directory.touch(ctx.key)                   # finding
        return arms[0]
